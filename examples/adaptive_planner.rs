//! Adaptive plan compiler walkthrough: compare the four fixed strategies
//! against the per-pair adaptive plan on a two-tier topology, show which
//! shape each pair selected, verify the mixed plan executes exactly, and
//! demonstrate the pattern-keyed plan cache (memory + disk).
//!
//!     cargo run --release --example adaptive_planner -- --ranks 16

use shiro::comm::{self, Strategy};
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::metrics::Table;
use shiro::partition::{split_1d, RowPartition};
use shiro::plan::{self, cache::PlanCache, PlanParams, Shape};
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::{cli::Args, human_bytes, human_secs, rng::Rng};

fn main() {
    let args = Args::from_env();
    let ranks = args.get_usize("ranks", 16);
    let n_dense = args.get_usize("n", 32);

    // Web-style pattern: hubs on both sides, so different pairs genuinely
    // prefer different shapes.
    let n = 4096;
    let a = gen::powerlaw(n, 60_000, 1.45, 11);
    println!("matrix: {}x{} nnz={}", a.nrows, a.ncols, a.nnz());

    let part = RowPartition::balanced(n, ranks);
    let blocks = split_1d(&a, &part);
    let topo = Topology::tsubame4(ranks);
    let params = PlanParams { n_dense, ..Default::default() };

    // Fixed strategies vs adaptive, under the same α-β(+compute) model.
    let mut t = Table::new(&["strategy", "volume", "modeled cost", "plan time"]);
    for shape in Shape::ALL {
        let t0 = std::time::Instant::now();
        let fixed = comm::plan(&blocks, &part, shape.strategy(), None);
        let secs = t0.elapsed().as_secs_f64();
        t.row(vec![
            shape.name().into(),
            human_bytes(fixed.total_volume(n_dense) as f64),
            human_secs(plan::modeled_cost(&fixed, &topo, n_dense)),
            human_secs(secs),
        ]);
    }
    let t0 = std::time::Instant::now();
    let compiled = plan::compile(&blocks, &part, &topo, &params);
    let secs = t0.elapsed().as_secs_f64();
    t.row(vec![
        "adaptive".into(),
        human_bytes(compiled.plan.total_volume(n_dense) as f64),
        human_secs(compiled.modeled_cost),
        human_secs(secs),
    ]);
    println!("\n{}", t.render());

    let counts = compiled.shape_counts();
    println!(
        "per-pair choices on {} ({} groups of {}): block={} column={} row={} joint={}",
        topo.name,
        topo.ngroups(),
        topo.group_size,
        counts[0],
        counts[1],
        counts[2],
        counts[3]
    );

    // The mixed plan drops into the existing engine unchanged.
    let spec = PlanSpec::new(topo.clone())
        .strategy(Strategy::Adaptive)
        .params(params.clone());
    let d = spec.plan(&a);
    let mut rng = Rng::new(5);
    let b = Dense::random(n, n_dense, &mut rng);
    let (c, stats) = d
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    let want = a.spmm(&b);
    let err = want.diff_norm(&c) / want.max_abs() as f64;
    println!(
        "\nexecuted on {ranks} in-process ranks: rel err {err:.2e}, \
         intra {} / inter {}",
        human_bytes(stats.total_intra_bytes() as f64),
        human_bytes(stats.total_inter_bytes() as f64)
    );
    assert!(err < 1e-3);

    // Plan cache: second plan of the same operator is a lookup, not a solve.
    let cache_dir = std::env::temp_dir().join("shiro_plan_cache_example");
    let mut cache = PlanCache::with_dir(&cache_dir);
    let t0 = std::time::Instant::now();
    let _ = spec.plan_cached(&a, &mut cache);
    let cold = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let _ = spec.plan_cached(&a, &mut cache);
    let warm = t0.elapsed().as_secs_f64();
    println!(
        "\nplan cache: cold {} → warm {} (hits {}, misses {}, dir {})",
        human_secs(cold),
        human_secs(warm),
        cache.hits,
        cache.misses,
        cache_dir.display()
    );

    println!("\nadaptive_planner OK");
}
