//! Communication-plan analyzer: for a chosen dataset, show what each
//! strategy would transfer — per-strategy totals, the MWVC statistics per
//! off-diagonal block, the Fig. 5 pattern taxonomy, and an ASCII heatmap of
//! the per-rank-pair volumes (Fig. 9 style).
//!
//!     cargo run --release --example comm_planner -- --dataset mawi --ranks 16

use shiro::comm::{self, Strategy};
use shiro::cover::{self, Solver, Weights};
use shiro::metrics::{reduction_pct, Table};
use shiro::partition::{split_1d, RowPartition};
use shiro::sparse::{dataset_by_name, gen};
use shiro::topology::Topology;
use shiro::util::{cli::Args, human_bytes};

fn main() {
    let args = Args::from_env();
    let name = args.get_or("dataset", "mawi");
    let ranks = args.get_usize("ranks", 16);
    let n_dense = args.get_usize("n", 32);
    let scale = args.get_f64("scale", 0.05);

    // Fig. 5 didactic patterns first.
    println!("Fig. 5 pattern taxonomy (per off-diagonal block):");
    let mut t = Table::new(&["pattern", "|Rows|", "|Cols|", "mu", "reduction%"]);
    for (pname, m) in gen::fig5_patterns() {
        let sol = cover::solve(&m, Solver::Koenig, &Weights::default());
        let single = m.nonempty_rows().len().min(m.nonempty_cols().len());
        t.row(vec![
            pname.to_string(),
            m.nonempty_rows().len().to_string(),
            m.nonempty_cols().len().to_string(),
            sol.mu().to_string(),
            format!("{:.0}", reduction_pct(single as u64, sol.mu() as u64)),
        ]);
    }
    println!("{}", t.render());

    let spec = dataset_by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; try `shiro datasets`");
        std::process::exit(1);
    });
    let a = spec.generate(scale);
    println!(
        "dataset {} (analog of {} rows / {} nnz): {}x{} nnz={}",
        spec.name, spec.paper_rows, spec.paper_nnz, a.nrows, a.ncols, a.nnz()
    );

    let part = RowPartition::balanced(a.nrows, ranks);
    let blocks = split_1d(&a, &part);

    let mut t = Table::new(&["strategy", "volume", "vs column", "imbalance", "asymmetry"]);
    let mut col_vol = 0u64;
    for strategy in [
        Strategy::Block,
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Greedy),
        Strategy::Joint(Solver::Koenig),
    ] {
        let plan = comm::plan(&blocks, &part, strategy, None);
        let vol = plan.total_volume(n_dense);
        if strategy == Strategy::Column {
            col_vol = vol;
        }
        let m = plan.volume_matrix(n_dense);
        t.row(vec![
            strategy.name().to_string(),
            human_bytes(vol as f64),
            if col_vol > 0 {
                format!("{:+.1}%", -reduction_pct(col_vol, vol))
            } else {
                "-".into()
            },
            format!("{:.2}", m.imbalance()),
            format!("{:.3}", m.asymmetry()),
        ]);
    }
    println!("{}", t.render());

    // Heatmaps before/after (Fig. 9).
    let col_plan = comm::plan(&blocks, &part, Strategy::Column, None);
    let joint_plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
    println!("per-pair volume heatmap, column-based (src rows × dst cols):");
    println!("{}", col_plan.volume_matrix(n_dense).to_ascii());
    println!("per-pair volume heatmap, joint row-column:");
    println!("{}", joint_plan.volume_matrix(n_dense).to_ascii());

    // Hierarchical inter-node savings on TSUBAME.
    let topo = Topology::tsubame4(ranks);
    let sched = shiro::hierarchy::build(&joint_plan, &topo);
    let flat = shiro::hierarchy::flat_inter_group_bytes(&joint_plan, &topo, n_dense);
    let hier = sched.inter_group_bytes(n_dense);
    println!(
        "inter-node volume: flat {} → hierarchical {} ({:.1}% reduction)",
        human_bytes(flat as f64),
        human_bytes(hier as f64),
        reduction_pct(flat, hier)
    );
}
