//! End-to-end driver (DESIGN.md deliverable): full-batch 2-layer GCN
//! training on a synthetic social graph, with every aggregation running
//! through SHIRO's distributed SpMM and the local compute running through
//! the AOT-compiled JAX/Pallas artifacts (L1+L2) via PJRT — Python is not
//! involved at run time.
//!
//!     make artifacts && cargo run --release --example gnn_training
//!
//! Flags: --epochs N (default 200) --ranks R (default 8) --native
//! (skip PJRT, use the pure-Rust kernel).

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::exec::kernel::NativeKernel;
use shiro::gnn::{Gcn, GcnConfig, NativeDense, PjrtDense};
use shiro::runtime::{PjrtKernel, Runtime};
use shiro::sparse::gen;
use shiro::topology::Topology;
use shiro::util::{cli::Args, human_bytes, human_secs};

fn main() {
    let args = Args::from_env();
    let epochs = args.get_usize("epochs", 200);
    let ranks = args.get_usize("ranks", 8);
    let use_native = args.has_flag("native");

    // Graph sized so every per-rank block is 512 rows — the shape exported
    // by aot.py (4096 nodes / 8 ranks). Symmetric (undirected), so Âᵀ = Â.
    let n = (512 * ranks).next_power_of_two();
    let adj = gen::rmat(n, n * 10, (0.55, 0.2, 0.19), true, 42);
    println!(
        "graph: {} nodes, {} undirected edges (nnz {})",
        adj.nrows,
        adj.nnz() / 2,
        adj.nnz()
    );

    let cfg = GcnConfig {
        feature_dim: 32,
        hidden_dim: 32,
        epochs,
        lr: 2.0,
        log_every: (epochs / 20).max(1),
        seed: 42,
    };
    let topo = Topology::tsubame4(ranks);
    println!(
        "planning joint row-column + hierarchical schedule on {} ranks ({} groups of {})",
        ranks,
        topo.ngroups(),
        topo.group_size
    );
    let mut gcn = Gcn::new(&adj, Strategy::Joint(Solver::Koenig), topo, true, cfg);
    println!(
        "one-time preprocessing (MWVC plan + Âᵀ mirror + session warm-up): {}",
        human_secs(gcn.prep_secs())
    );

    let pjrt = if use_native {
        None
    } else {
        match PjrtKernel::load(&Runtime::default_dir()) {
            Ok(k) => {
                k.with_runtime(|rt| {
                    println!(
                        "PJRT runtime up: platform={} artifacts={}",
                        rt.platform(),
                        rt.artifact_names().len()
                    )
                });
                Some(k)
            }
            Err(e) => {
                println!("PJRT unavailable ({e:#}); falling back to native kernel");
                None
            }
        }
    };

    println!("\ntraining {epochs} epochs (3 distributed SpMM / epoch):");
    let report = match &pjrt {
        Some(k) => {
            let dense = PjrtDense { kernel: k, chunk: 512 };
            gcn.train(k, &dense)
        }
        None => gcn.train(&NativeKernel, &NativeDense),
    };

    println!("\nloss curve:");
    for (epoch, loss) in &report.losses {
        println!("  epoch {epoch:>4}  loss {loss:.6}");
    }
    let first = report.losses.first().unwrap().1;
    let last = report.losses.last().unwrap().1;
    assert!(last < first, "training failed to reduce loss");

    println!("\nsummary (Tab. 3 shape):");
    println!("  SpMM calls          {}", report.spmm_calls);
    println!("  SpMM wall time      {}", human_secs(report.spmm_secs));
    println!("  training total      {}", human_secs(report.train_secs));
    println!("  prep (MWVC)         {}", human_secs(report.prep_secs));
    println!(
        "  prep ratio          {:.1}%",
        100.0 * report.prep_secs / (report.prep_secs + report.train_secs)
    );
    println!(
        "  traffic             intra {} / inter {}",
        human_bytes(report.intra_bytes as f64),
        human_bytes(report.inter_bytes as f64)
    );
    if let Some(k) = &pjrt {
        let fb = k.fallbacks.load(std::sync::atomic::Ordering::Relaxed);
        println!("  PJRT kernel fallbacks: {fb}");
    }
    let (fa, ba) = (gcn.fwd.amortization(), gcn.bwd.amortization());
    println!(
        "  epoch reuse         {} session executes, {} fresh allocs after warm-up (steady: {})",
        fa.calls() + ba.calls(),
        fa.total_allocs() + ba.total_allocs(),
        fa.steady_state() && ba.steady_state()
    );
    println!("\ngnn_training OK (loss {first:.4} → {last:.4})");
}
