//! Hierarchical-communication demo (paper §6): show the two-stage
//! complementary overlap on a deep hierarchy (TSUBAME: 72× bandwidth
//! cliff) vs a shallow one (Aurora: ~0.9×), reproducing the Fig. 12
//! finding that hierarchy-awareness only pays off past a bandwidth cliff.
//!
//!     cargo run --release --example hierarchy_demo -- --ranks 24

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::metrics::Table;
use shiro::sparse::gen;
use shiro::spmm::PlanSpec;
use shiro::topology::Topology;
use shiro::util::{cli::Args, human_bytes, human_secs};

fn main() {
    let args = Args::from_env();
    let ranks = args.get_usize("ranks", 24);
    let n_dense = args.get_usize("n", 64);

    let a = gen::rmat(1 << 13, (1 << 13) * 12, (0.55, 0.2, 0.19), false, 9);
    println!("matrix: {}x{} nnz={}\n", a.nrows, a.ncols, a.nnz());

    let mut t = Table::new(&[
        "topology", "cliff", "schedule", "inter bytes", "time/SpMM", "speedup",
    ]);
    for topo in [Topology::tsubame4(ranks), Topology::aurora(ranks)] {
        let mut flat_time = 0.0;
        for hier in [false, true] {
            let d = PlanSpec::new(topo.clone())
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(hier)
                .plan(&a);
            let rep = d.simulate(n_dense);
            if !hier {
                flat_time = rep.total;
            }
            t.row(vec![
                topo.name.clone(),
                format!("{:.1}x", topo.bandwidth_cliff()),
                if hier { "hierarchical".into() } else { "flat".into() },
                human_bytes(rep.inter_bytes as f64),
                human_secs(rep.total),
                format!("{:.2}x", flat_time / rep.total),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "Shape check (paper §7.7): hierarchy wins on tsubame4 (deep cliff), \n\
         and is neutral-to-negative on aurora (shallow cliff) — the flat\n\
         joint schedule already saturates Aurora's balanced links."
    );

    // Stage-level breakdown on TSUBAME: the complementary overlap.
    let topo = Topology::tsubame4(ranks);
    let d = PlanSpec::new(topo).strategy(Strategy::Joint(Solver::Koenig)).plan(&a);
    let rep = d.simulate(n_dense);
    println!("TSUBAME stage breakdown (Alg. 1 overlap):");
    for (name, secs) in &rep.per_stage {
        println!("  {name:<40} {}", human_secs(*secs));
    }
}
