//! Quickstart: plan and run one distributed SpMM with SHIRO's joint
//! row-column strategy on a simulated 8-GPU (2-node) TSUBAME topology,
//! verify the result against the serial reference, and print the
//! communication savings.
//!
//!     cargo run --release --example quickstart

use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::metrics::{load_imbalance, reduction_pct};
use shiro::partition::{rank_nnz, Partitioner};
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::{human_bytes, human_secs, rng::Rng};

fn main() {
    // A web-style power-law matrix: hubs on both row and column sides —
    // the pattern class where joint row-column planning shines (Fig. 5).
    let n = 4096;
    let a = gen::powerlaw(n, 60_000, 1.45, 42);
    println!("matrix: {}x{} nnz={} density={:.2e}", a.nrows, a.ncols, a.nnz(), a.density());

    let topo = Topology::tsubame4(8);
    let n_dense = 32;

    // Plan under three strategies: `PlanSpec` is the one planning entry
    // point (joint + hierarchical are its defaults).
    let col = PlanSpec::new(topo.clone()).strategy(Strategy::Column).flat().plan(&a);
    let joint =
        PlanSpec::new(topo.clone()).strategy(Strategy::Joint(Solver::Koenig)).flat().plan(&a);
    let hier = PlanSpec::new(topo.clone()).plan(&a);

    let vc = col.plan.total_volume(n_dense);
    let vj = joint.plan.total_volume(n_dense);
    println!("\ncommunication volume (N = {n_dense}):");
    println!("  column-based: {}", human_bytes(vc as f64));
    println!(
        "  joint row-column: {}  ({:.1}% reduction)",
        human_bytes(vj as f64),
        reduction_pct(vc, vj)
    );
    let flat_inter = shiro::hierarchy::flat_inter_group_bytes(&joint.plan, &topo, n_dense);
    let hier_inter = hier.sched.as_ref().unwrap().inter_group_bytes(n_dense);
    println!(
        "  inter-node: flat {} → hierarchical {}  ({:.1}% reduction)",
        human_bytes(flat_inter as f64),
        human_bytes(hier_inter as f64),
        reduction_pct(flat_inter, hier_inter)
    );
    println!("  one-time planning (MWVC): {}", human_secs(hier.prep_secs));

    // Execute for real on 8 in-process ranks and verify.
    let mut rng = Rng::new(7);
    let b = Dense::random(n, n_dense, &mut rng);
    let (c, stats) = hier
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    let want = a.spmm(&b);
    let err = want.diff_norm(&c) / want.max_abs() as f64;
    println!("\nexecuted on 8 in-process ranks: rel err vs serial = {err:.2e}");
    assert!(err < 1e-3);
    println!(
        "measured traffic: intra {}  inter {}",
        human_bytes(stats.total_intra_bytes() as f64),
        human_bytes(stats.total_inter_bytes() as f64),
    );

    // Load-aware partitioning (`--partitioner nnz-balanced` on the CLI):
    // boundaries follow the nnz prefix sum, shrinking the straggler rank.
    let nnz_part = PlanSpec::new(topo.clone())
        .params(shiro::plan::PlanParams { n_dense, ..Default::default() })
        .partitioner(Partitioner::NnzBalanced)
        .plan(&a);
    let bal_loads = rank_nnz(&a, &hier.part);
    let nnz_loads = rank_nnz(&a, &nnz_part.part);
    println!(
        "\nload-aware partitioning: max-rank nnz {} → {} (imbalance {:.2}x → {:.2}x)",
        bal_loads.iter().copied().max().unwrap_or(0),
        nnz_loads.iter().copied().max().unwrap_or(0),
        load_imbalance(&bal_loads),
        load_imbalance(&nnz_loads)
    );
    let (c2, _) = nnz_part
        .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
        .expect("thread-backend SpMM")
        .into_dense();
    assert!(want.diff_norm(&c2) / want.max_abs() as f64 < 1e-3);

    // And simulate the same plan at paper scale (128 GPUs).
    let topo128 = Topology::tsubame4(128);
    let big = PlanSpec::new(topo128).plan(&a);
    let rep = big.simulate(n_dense);
    println!("\nsimulated at 128 GPUs: {} per SpMM", human_secs(rep.total));
    for (name, secs) in &rep.per_stage {
        println!("  {name:<36} {}", human_secs(*secs));
    }
    println!("\nquickstart OK");
}
