"""AOT bridge: lower the L2 jax functions to HLO *text* artifacts.

HLO text (NOT `lowered.compile()`/`.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the Rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: cd python && python -m compile.aot --out ../artifacts

Emits one .hlo.txt per (function, shape variant) plus manifest.txt mapping
artifact names to shapes for the Rust runtime's loader.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape variants exported for the Rust runtime. Keep in sync with
# rust/src/runtime/mod.rs (the loader reads manifest.txt, so adding a
# variant here is enough).
#
# spmm_ell variants: (M, KMAX, K, N) — M = padded block rows, K = B rows.
SPMM_VARIANTS = [
    (512, 16, 512, 32),
    (512, 16, 512, 64),
    (512, 16, 512, 128),
    (256, 16, 256, 32),
    (128, 8, 128, 32),
]
# gcn dense variants: (M, F, H) — h_agg f32[M,F], w f32[F,H].
GCN_VARIANTS = [
    (512, 32, 32),
    (512, 64, 64),
]
# mse variants: (M, H).
MSE_VARIANTS = [
    (512, 32),
    (512, 64),
]
# fused GCN layer variants: (M, KMAX, K, N, H).
FUSED_VARIANTS = [
    (512, 16, 512, 32, 32),
]


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []

    def emit(name, fn, *specs):
        text = to_hlo_text(fn, *specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            f"{s.dtype}[{','.join(str(d) for d in s.shape)}]" for s in specs
        )
        manifest.append(f"{name} {shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    for m, kmax, k, n in SPMM_VARIANTS:
        emit(
            f"spmm_ell_m{m}_x{kmax}_k{k}_n{n}",
            model.spmm_block,
            i32(m, kmax),
            f32(m, kmax),
            f32(k, n),
        )
    for m, f, h in GCN_VARIANTS:
        emit(f"gcn_fwd_m{m}_f{f}_h{h}", model.gcn_dense_fwd, f32(m, f), f32(f, h))
        emit(
            f"gcn_bwd_m{m}_f{f}_h{h}",
            model.gcn_dense_bwd,
            f32(m, f),
            f32(f, h),
            f32(m, h),
            f32(m, h),
        )
    for m, h in MSE_VARIANTS:
        emit(f"mse_m{m}_h{h}", model.mse_loss_grad, f32(m, h), f32(m, h))
    from compile.kernels.gcn_fused import gcn_fused as fused
    for m, kmax, k, n, h in FUSED_VARIANTS:
        emit(
            f"gcn_fused_m{m}_x{kmax}_k{k}_n{n}_h{h}",
            lambda idx, val, b, w: fused(idx, val, b, w),
            i32(m, kmax),
            f32(m, kmax),
            f32(k, n),
            f32(n, h),
        )

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
