"""L1 Pallas kernel: tiled dense matmul (the GCN weight multiply).

Unlike the sparse gather in spmm_ell.py, this kernel is MXU-shaped: each
grid step contracts a (BM, BK) × (BK, BN) pair into a (BM, BN) VMEM
accumulator — the direct analogue of the paper's cuBLAS/tensor-core path,
retargeted at the systolic array (DESIGN.md §2 Hardware adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(a_ref, b_ref, o_ref):
    """Grid (i, j, k): accumulate a[i,k] @ b[k,j] into o[i,j]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def dense_mm(a, b, bm=128, bk=128, bn=128):
    """C = A @ B with (bm, bk, bn) tiling. Dimensions must divide evenly;
    callers pad (the AOT variants are generated pre-padded)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
