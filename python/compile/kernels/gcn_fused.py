"""L1 extension: fused GCN layer kernel — ELL-SpMM + weight matmul + ReLU in
one Pallas call.

The unfused pipeline (spmm_ell → dense_mm → relu) writes the aggregated
features (M×N) to HBM and reads them back twice. Fusing keeps the (BM, N)
aggregation tile in VMEM and feeds it straight into the MXU matmul with W —
the on-TPU analogue of the kernel fusion CoLa does on GPUs ("computational
optimizations", paper §7.2). VMEM per grid step (BM=128, KMAX=16, K=512,
N=32, H=32): panes 16 KiB + B 64 KiB + W 4 KiB + acc/out 32 KiB ≈ 116 KiB.

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(idx_ref, val_ref, b_ref, w_ref, z_ref, h_ref, *, kmax):
    bm = z_ref.shape[0]
    n = b_ref.shape[1]
    agg = jnp.zeros((bm, n), dtype=jnp.float32)
    for k in range(kmax):
        rows = idx_ref[:, k]
        agg = agg + val_ref[:, k][:, None] * b_ref[rows, :]
    z = jnp.dot(agg, w_ref[...], preferred_element_type=jnp.float32)
    z_ref[...] = z
    h_ref[...] = jnp.maximum(z, 0.0)


@functools.partial(jax.jit, static_argnames=("bm",))
def gcn_fused(idx, val, b, w, bm=128):
    """(z, h) = (ELL(idx,val)·b)·w, relu(z) — one kernel, no HBM round trip
    for the aggregated features."""
    m, kmax = idx.shape
    k_rows, n = b.shape
    n2, h = w.shape
    assert n == n2
    bm = min(bm, m)
    assert m % bm == 0
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_fused_kernel, kmax=kmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kmax), lambda i: (i, 0)),
            pl.BlockSpec((bm, kmax), lambda i: (i, 0)),
            pl.BlockSpec((k_rows, n), lambda i: (0, 0)),
            pl.BlockSpec((n, h), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, h), jnp.float32),
            jax.ShapeDtypeStruct((m, h), jnp.float32),
        ],
        interpret=True,
    )(idx, val, b, w)


def gcn_fused_ref(idx, val, b, w):
    """Oracle: unfused composition."""
    gathered = b[idx]
    agg = jnp.einsum("mk,mkn->mn", val, gathered)
    z = agg @ w
    return z, jnp.maximum(z, 0.0)
