"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Every kernel in this package must match its `*_ref` here to float32
tolerance under pytest (python/tests/) before it is AOT-exported.
"""

import jax.numpy as jnp


def ell_spmm_ref(idx, val, b):
    """Blocked-ELL SpMM reference: out[m, :] = sum_k val[m, k] * b[idx[m, k], :].

    idx: i32[M, KMAX] column indices into b's rows (padded slots may point
         anywhere as long as the matching val is 0).
    val: f32[M, KMAX] values (0 at padded slots).
    b:   f32[K, N] dense operand.
    """
    gathered = b[idx]  # [M, KMAX, N]
    return jnp.einsum("mk,mkn->mn", val, gathered)


def dense_mm_ref(a, b):
    """Dense matmul reference."""
    return a @ b


def gcn_dense_fwd_ref(h_agg, w):
    """GCN dense half forward: z = h_agg @ w, h = relu(z)."""
    z = h_agg @ w
    return z, jnp.maximum(z, 0.0)


def gcn_dense_bwd_ref(h_agg, w, z, dh):
    """GCN dense half backward.

    Returns (d_h_agg, d_w) where dz = dh * relu'(z).
    """
    dz = dh * (z > 0.0).astype(dh.dtype)
    d_h_agg = dz @ w.T
    d_w = h_agg.T @ dz
    return d_h_agg, d_w
