"""L1 Pallas kernel: blocked-ELL SpMM (the paper's per-GPU compute hot spot).

Hardware adaptation (DESIGN.md §2): the paper's cuSPARSE CSR kernel assigns a
warp per row and stages B tiles in shared memory. On TPU-style hardware we
instead tile the *output* into (BM, N) VMEM blocks via BlockSpec; each grid
step loads a (BM, KMAX) pane of ELL indices/values plus the B operand and
performs KMAX vectorized rank-1 gather-accumulates on the VPU (the sparse
gather has no MXU shape, unlike the dense GCN matmul in dense_mm.py).

VMEM working set per grid step (f32):
    BM*KMAX*(4+4) [idx+val] + BM*N*4 [acc] + K*N*4 [B operand]
— B dominates; for the exported variants (K<=1024, N<=128) this stays under
1 MiB, far below the ~16 MiB VMEM budget, leaving room to scale BM.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md §6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 keeps the idx/val panes register-friendly while
# amortizing the per-step B load.
DEFAULT_BM = 128


def _ell_kernel(idx_ref, val_ref, b_ref, o_ref, *, kmax):
    """One (BM, N) output tile: KMAX gather-accumulate steps."""
    bm = o_ref.shape[0]
    n = o_ref.shape[1]
    acc = jnp.zeros((bm, n), dtype=jnp.float32)
    # KMAX is a compile-time constant: unrolled vector steps, no dynamic
    # control flow inside the kernel.
    for k in range(kmax):
        rows = idx_ref[:, k]            # i32[BM]
        coeff = val_ref[:, k][:, None]  # f32[BM, 1]
        acc = acc + coeff * b_ref[rows, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm",))
def ell_spmm(idx, val, b, bm=DEFAULT_BM):
    """Blocked-ELL SpMM via Pallas: out[m] = Σ_k val[m,k] · b[idx[m,k]].

    idx: i32[M, KMAX] (M divisible by bm; pad rows with val=0 slots).
    val: f32[M, KMAX].
    b:   f32[K, N].
    """
    m, kmax = idx.shape
    k_rows, n = b.shape
    bm = min(bm, m)
    assert m % bm == 0, f"M={m} not divisible by BM={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_ell_kernel, kmax=kmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kmax), lambda i: (i, 0)),
            pl.BlockSpec((bm, kmax), lambda i: (i, 0)),
            # B is resident for every grid step (no blocking): the paper's
            # "stage B in shared memory" becomes "hold B in VMEM".
            pl.BlockSpec((k_rows, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(idx, val, b)


def csr_to_ell(indptr, indices, data, kmax, m_pad=None):
    """Host-side helper: pack CSR arrays into (idx, val) ELL panes.

    Rows with more than `kmax` nonzeros spill into additional slabs; the
    caller sums the slab outputs. Returns a list of (idx, val) pairs.
    Used by tests; the Rust runtime has its own packer (runtime/ell.rs).
    """
    import numpy as np

    m = len(indptr) - 1
    m_out = m_pad or m
    slabs = []
    remaining = [(int(indptr[r]), int(indptr[r + 1])) for r in range(m)]
    while True:
        idx = np.zeros((m_out, kmax), dtype=np.int32)
        val = np.zeros((m_out, kmax), dtype=np.float32)
        any_left = False
        for r in range(m):
            lo, hi = remaining[r]
            take = min(kmax, hi - lo)
            if take > 0:
                idx[r, :take] = indices[lo : lo + take]
                val[r, :take] = data[lo : lo + take]
                remaining[r] = (lo + take, hi)
                if lo + take < hi:
                    any_left = True
        slabs.append((idx, val))
        if not any_left:
            return slabs
