"""L2: the JAX compute graphs AOT-exported for the Rust runtime.

Each function here is a pure jax function (calling the L1 Pallas kernels)
that `aot.py` lowers to HLO text. The Rust coordinator (L3) composes them:
the distributed SpMM engine invokes `spmm_block` per local block, and the
GNN case study invokes the GCN dense halves around it.

Python never runs at serving/training time — these graphs are compiled once
by `make artifacts`.
"""

import jax.numpy as jnp

from compile.kernels.dense_mm import dense_mm
from compile.kernels.spmm_ell import ell_spmm


def spmm_block(idx, val, b):
    """One local SpMM: blocked-ELL sparse block times dense B block.

    Returned as a 1-tuple (the AOT bridge lowers with return_tuple=True).
    """
    return (ell_spmm(idx, val, b),)


def gcn_dense_fwd(h_agg, w):
    """GCN layer dense half, forward: z = h_agg @ w (Pallas MXU matmul),
    h = relu(z). Returns (z, h) — z is cached for the backward pass."""
    z = dense_mm(h_agg, w)
    h = jnp.maximum(z, 0.0)
    return (z, h)


def gcn_dense_bwd(h_agg, w, z, dh):
    """GCN layer dense half, backward: given upstream dh and the cached
    pre-activation z, produce (d_h_agg, d_w). The surrounding sparse
    gradient propagation (A^T · d_h_agg) is another distributed SpMM handled
    by L3 with the same communication-plan machinery."""
    dz = dh * (z > 0.0).astype(dh.dtype)
    d_h_agg = dense_mm(dz, w.T)
    d_w = dense_mm(h_agg.T, dz)
    return (d_h_agg, d_w)


def mse_loss_grad(pred, target):
    """Mean-squared-error loss and its gradient wrt pred."""
    diff = pred - target
    n = jnp.float32(diff.size)
    loss = jnp.sum(diff * diff) / n
    grad = 2.0 * diff / n
    return (jnp.reshape(loss, (1,)), grad)
