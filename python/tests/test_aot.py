"""AOT pipeline smoke: lowering produces parseable HLO text with the right
entry computation shapes."""

from compile import aot, model


def test_to_hlo_text_emits_hlo():
    text = aot.to_hlo_text(
        model.spmm_block, aot.i32(16, 4), aot.f32(16, 4), aot.f32(16, 8)
    )
    assert "HloModule" in text
    assert "f32[16,8]" in text  # output tile shape appears


def test_gcn_fwd_lowering():
    text = aot.to_hlo_text(model.gcn_dense_fwd, aot.f32(32, 16), aot.f32(16, 16))
    assert "HloModule" in text
    # Tuple of (z, h), both f32[32,16].
    assert text.count("f32[32,16]") >= 2


def test_variants_tables_consistent():
    # Every exported spmm variant has M divisible by the kernel BM default.
    from compile.kernels.spmm_ell import DEFAULT_BM
    for (m, kmax, k, n) in aot.SPMM_VARIANTS:
        assert m % DEFAULT_BM == 0 or m % 8 == 0
        assert kmax >= 1 and k >= 1 and n >= 1
