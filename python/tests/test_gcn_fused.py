"""Fused GCN kernel vs unfused oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.gcn_fused import gcn_fused, gcn_fused_ref


def random_case(rng, m, kmax, k, n, h):
    idx = jnp.asarray(rng.integers(0, k, size=(m, kmax), dtype=np.int32))
    val = rng.standard_normal((m, kmax), dtype=np.float32)
    val[rng.random((m, kmax)) < 0.3] = 0.0
    b = jnp.asarray(rng.standard_normal((k, n), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((n, h), dtype=np.float32))
    return idx, jnp.asarray(val), b, w


@settings(max_examples=15, deadline=None)
@given(
    mb=st.integers(1, 3),
    kmax=st.integers(1, 8),
    k=st.integers(1, 64),
    n=st.integers(1, 24),
    h=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_matches_ref(mb, kmax, k, n, h, seed):
    rng = np.random.default_rng(seed)
    m = mb * 8
    idx, val, b, w = random_case(rng, m, kmax, k, n, h)
    z, out = gcn_fused(idx, val, b, w, bm=8)
    zr, outr = gcn_fused_ref(idx, val, b, w)
    np.testing.assert_allclose(z, zr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out, outr, rtol=1e-4, atol=1e-4)


def test_fused_aot_variant_shape():
    rng = np.random.default_rng(1)
    idx, val, b, w = random_case(rng, 512, 16, 512, 32, 32)
    z, out = gcn_fused(idx, val, b, w)
    assert z.shape == (512, 32)
    assert float(jnp.min(out)) >= 0.0


def test_fused_relu_boundary():
    # All-negative weights: relu output must be exactly zero where z < 0.
    idx = jnp.zeros((8, 2), dtype=jnp.int32)
    val = jnp.ones((8, 2), dtype=jnp.float32)
    b = jnp.ones((4, 3), dtype=jnp.float32)
    w = -jnp.ones((3, 5), dtype=jnp.float32)
    z, out = gcn_fused(idx, val, b, w, bm=8)
    assert float(jnp.max(out)) == 0.0
    assert float(jnp.max(z)) < 0.0
