"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes; fixed cases pin the AOT-exported variants.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense_mm import dense_mm
from compile.kernels.ref import dense_mm_ref, ell_spmm_ref
from compile.kernels.spmm_ell import csr_to_ell, ell_spmm


def random_ell(rng, m, kmax, k):
    """Random ELL panes with ~30% padded slots (val = 0)."""
    idx = rng.integers(0, k, size=(m, kmax), dtype=np.int32)
    val = rng.standard_normal((m, kmax), dtype=np.float32)
    mask = rng.random((m, kmax)) < 0.3
    val[mask] = 0.0
    return jnp.asarray(idx), jnp.asarray(val)


@settings(max_examples=25, deadline=None)
@given(
    mb=st.integers(1, 4),      # M = mb * bm
    kmax=st.integers(1, 12),
    k=st.integers(1, 96),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_spmm_matches_ref(mb, kmax, k, n, seed):
    rng = np.random.default_rng(seed)
    bm = 8
    m = mb * bm
    idx, val = random_ell(rng, m, kmax, k)
    b = jnp.asarray(rng.standard_normal((k, n), dtype=np.float32))
    got = ell_spmm(idx, val, b, bm=bm)
    want = ell_spmm_ref(idx, val, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,kmax,k,n", [(512, 16, 512, 32), (256, 16, 256, 32), (128, 8, 128, 32)])
def test_ell_spmm_aot_variants(m, kmax, k, n):
    """The exact shapes exported by aot.py."""
    rng = np.random.default_rng(7)
    idx, val = random_ell(rng, m, kmax, k)
    b = jnp.asarray(rng.standard_normal((k, n), dtype=np.float32))
    got = ell_spmm(idx, val, b)
    want = ell_spmm_ref(idx, val, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ell_spmm_zero_vals_zero_out():
    idx = jnp.zeros((8, 4), dtype=jnp.int32)
    val = jnp.zeros((8, 4), dtype=jnp.float32)
    b = jnp.ones((16, 5), dtype=jnp.float32)
    out = ell_spmm(idx, val, b, bm=8)
    assert float(jnp.abs(out).max()) == 0.0


def test_ell_spmm_duplicate_indices_accumulate():
    # Two slots pointing at the same B row must sum.
    idx = jnp.asarray([[3, 3]], dtype=jnp.int32).repeat(8, axis=0)
    val = jnp.asarray([[2.0, 5.0]], dtype=jnp.float32).repeat(8, axis=0)
    b = jnp.zeros((8, 3), dtype=jnp.float32).at[3].set(1.0)
    out = ell_spmm(idx, val, b, bm=8)
    np.testing.assert_allclose(out, np.full((8, 3), 7.0), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 24),
    k=st.integers(1, 48),
    kmax=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_csr_to_ell_roundtrip(r, k, kmax, seed):
    """CSR → ELL slabs → sum of slab SpMMs == dense reference."""
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((r, k)).astype(np.float32)
    dense[rng.random((r, k)) < 0.7] = 0.0
    # Build CSR.
    indptr = [0]
    indices, data = [], []
    for i in range(r):
        nz = np.nonzero(dense[i])[0]
        indices.extend(nz.tolist())
        data.extend(dense[i, nz].tolist())
        indptr.append(len(indices))
    m_pad = ((r + 7) // 8) * 8
    slabs = csr_to_ell(
        np.asarray(indptr), np.asarray(indices, dtype=np.int32),
        np.asarray(data, dtype=np.float32), kmax, m_pad=m_pad,
    )
    b = rng.standard_normal((k, 6)).astype(np.float32)
    out = np.zeros((m_pad, 6), dtype=np.float32)
    for idx, val in slabs:
        out += np.asarray(ell_spmm(jnp.asarray(idx), jnp.asarray(val), jnp.asarray(b), bm=8))
    np.testing.assert_allclose(out[:r], dense @ b, rtol=1e-4, atol=1e-4)
    assert np.abs(out[r:]).max() == 0.0 if m_pad > r else True


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 3),
    ki=st.integers(1, 3),
    ni=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_mm_matches_ref(mi, ki, ni, seed):
    rng = np.random.default_rng(seed)
    bm = bk = bn = 16
    a = jnp.asarray(rng.standard_normal((mi * bm, ki * bk), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((ki * bk, ni * bn), dtype=np.float32))
    got = dense_mm(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, dense_mm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_dense_mm_identity():
    eye = jnp.eye(32, dtype=jnp.float32)
    b = jnp.arange(32 * 32, dtype=jnp.float32).reshape(32, 32)
    got = dense_mm(eye, b, bm=16, bk=16, bn=16)
    np.testing.assert_allclose(got, b, rtol=1e-6)
