"""L2 graph correctness: GCN dense halves + loss against numpy references,
plus a finite-difference check on the backward pass."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import gcn_dense_bwd_ref, gcn_dense_fwd_ref


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 3),
    f=st.sampled_from([16, 32]),
    h=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gcn_fwd_matches_ref(mi, f, h, seed):
    rng = np.random.default_rng(seed)
    m = mi * 16
    h_agg = jnp.asarray(rng.standard_normal((m, f), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((f, h), dtype=np.float32))
    z, out = model.gcn_dense_fwd(h_agg, w)
    zr, outr = gcn_dense_fwd_ref(h_agg, w)
    np.testing.assert_allclose(z, zr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out, outr, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gcn_bwd_matches_ref(seed):
    rng = np.random.default_rng(seed)
    m, f, h = 32, 16, 16
    h_agg = jnp.asarray(rng.standard_normal((m, f), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((f, h), dtype=np.float32))
    z, _ = model.gcn_dense_fwd(h_agg, w)
    dh = jnp.asarray(rng.standard_normal((m, h), dtype=np.float32))
    d_h_agg, d_w = model.gcn_dense_bwd(h_agg, w, z, dh)
    d_h_agg_r, d_w_r = gcn_dense_bwd_ref(h_agg, w, z, dh)
    np.testing.assert_allclose(d_h_agg, d_h_agg_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d_w, d_w_r, rtol=1e-4, atol=1e-4)


def test_gcn_bwd_finite_difference():
    """dW from the backward graph ≈ numerical gradient of sum(relu(HW))·G."""
    rng = np.random.default_rng(0)
    m, f, h = 16, 16, 16
    h_agg = rng.standard_normal((m, f)).astype(np.float32)
    w = rng.standard_normal((f, h)).astype(np.float32)
    g = rng.standard_normal((m, h)).astype(np.float32)

    def loss(wv):
        z = h_agg @ wv
        return float((np.maximum(z, 0.0) * g).sum())

    z, _ = model.gcn_dense_fwd(jnp.asarray(h_agg), jnp.asarray(w))
    _, d_w = model.gcn_dense_bwd(
        jnp.asarray(h_agg), jnp.asarray(w), z, jnp.asarray(g)
    )
    eps = 1e-2
    for (i, j) in [(0, 0), (3, 5), (15, 15)]:
        wp = w.copy()
        wp[i, j] += eps
        wm = w.copy()
        wm[i, j] -= eps
        num = (loss(wp) - loss(wm)) / (2 * eps)
        assert abs(num - float(d_w[i, j])) < 2e-1, (num, float(d_w[i, j]))


def test_mse_loss_grad():
    pred = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], dtype=jnp.float32)
    target = jnp.asarray([[0.0, 2.0], [3.0, 2.0]], dtype=jnp.float32)
    loss, grad = model.mse_loss_grad(pred, target)
    np.testing.assert_allclose(float(loss[0]), (1.0 + 4.0) / 4.0, rtol=1e-6)
    np.testing.assert_allclose(grad, 2.0 * (pred - target) / 4.0, rtol=1e-6)
