//! Ablation (PR 4): epoch-persistent training sessions × transpose-aware
//! plan mirroring. The GNN loop multiplies the same Â (and Âᵀ) every epoch;
//! this bench contrasts [`Gcn::train`] — frozen plans, persistent exchange
//! buffers, mirrored backward plan — against [`Gcn::train_cold`], which
//! re-enters `DistSpmm` cold every epoch, and gates the session contract.
//!
//! Flags (after `--`):
//!   --preset ci|full   ci = smaller graph / fewer epochs (perf-smoke job)
//!   --check            assert the epoch-reuse guarantees (CI gate, all
//!                      deterministic — no wall-clock thresholds):
//!                      (1) from the second execute call onward both
//!                          sessions report zero plan seconds and zero
//!                          fresh exchange-buffer allocations;
//!                      (2) the full training loss trajectory is bitwise
//!                          identical between session and cold execution;
//!                      (3) on an integer-exact asymmetric matrix, the
//!                          mirrored transpose plan's output is bitwise
//!                          identical to planning Aᵀ from scratch.

use shiro::bench::{int_matrix, write_csv, Preset};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::gnn::{Gcn, GcnConfig, NativeDense};
use shiro::metrics::Table;
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    let check = args.has_flag("check");
    let (n, epochs, ranks) = match preset {
        Preset::Full => (4096usize, 30usize, 8usize),
        Preset::Ci => (512, 10, 8),
    };
    let adj = gen::rmat(n, n * 8, (0.55, 0.2, 0.19), true, 42);
    let topo = Topology::tsubame4(ranks);
    let cfg = GcnConfig { epochs, log_every: 1, lr: 2.0, ..Default::default() };

    let mut session_gcn = Gcn::new(
        &adj,
        Strategy::Joint(Solver::Koenig),
        topo.clone(),
        true,
        cfg.clone(),
    );
    let warm = session_gcn.train(&NativeKernel, &NativeDense);

    let mut cold_gcn = Gcn::new(
        &adj,
        Strategy::Joint(Solver::Koenig),
        topo.clone(),
        true,
        cfg.clone(),
    );
    let cold = cold_gcn.train_cold(&NativeKernel, &NativeDense);

    let mut table = Table::new(&[
        "mode",
        "epochs",
        "prep (ms)",
        "spmm (ms)",
        "train (ms)",
        "plan calls amortized",
    ]);
    let fa = session_gcn.fwd.amortization();
    let ba = session_gcn.bwd.amortization();
    table.row(vec![
        "session (reuse)".into(),
        epochs.to_string(),
        format!("{:.1}", warm.prep_secs * 1e3),
        format!("{:.1}", warm.spmm_secs * 1e3),
        format!("{:.1}", warm.train_secs * 1e3),
        format!("1 plan + mirror, {} executes", fa.calls() + ba.calls()),
    ]);
    table.row(vec![
        "cold (per-epoch)".into(),
        epochs.to_string(),
        format!("{:.1}", cold.prep_secs * 1e3),
        format!("{:.1}", cold.spmm_secs * 1e3),
        format!("{:.1}", cold.train_secs * 1e3),
        format!("{epochs} plans"),
    ]);
    println!(
        "Ablation — epoch-reuse sessions vs cold per-epoch execution \
         ({n} nodes, {ranks} ranks, {epochs} epochs, 3 SpMM/epoch)\n"
    );
    println!("{}", table.render());
    println!(
        "Expectation: session prep is one plan + one O(plan) transpose mirror;\n\
         cold prep grows linearly with epochs. SpMM wall time favors sessions\n\
         (no per-call buffer churn); numerics are bitwise identical.\n"
    );
    let csv = format!(
        "mode,epochs,prep_secs,spmm_secs,train_secs\n\
         session,{epochs},{:.6},{:.6},{:.6}\ncold,{epochs},{:.6},{:.6},{:.6}\n",
        warm.prep_secs, warm.spmm_secs, warm.train_secs, cold.prep_secs, cold.spmm_secs,
        cold.train_secs
    );
    write_csv("ablation_epoch_reuse.csv", &csv);

    if check {
        // (1) Steady state: zero plan time, zero fresh allocations from the
        // second call onward — and, because Gcn warms at build time, zero
        // allocations in *every* call.
        for (name, a) in [("fwd", fa), ("bwd", ba)] {
            assert!(
                a.steady_state(),
                "{name} session left steady state: plan {:?} allocs {:?}",
                a.plan_secs,
                a.alloc_events
            );
            assert_eq!(a.total_allocs(), 0, "{name} session allocated after warm-up");
            assert!(
                a.plan_secs.iter().all(|&t| t == 0.0),
                "{name} session re-planned inside execute"
            );
            assert_eq!(a.calls(), epochs * if name == "fwd" { 2 } else { 1 });
        }

        // (2) Bitwise-equal training trajectories, session vs cold.
        assert_eq!(warm.losses.len(), cold.losses.len());
        for ((e1, l1), (e2, l2)) in warm.losses.iter().zip(&cold.losses) {
            assert_eq!(e1, e2);
            assert_eq!(
                l1.to_bits(),
                l2.to_bits(),
                "epoch {e1}: session loss {l1} != cold loss {l2}"
            );
        }

        // (3) Transpose mirror gate on an integer-exact *asymmetric*
        // matrix: mirrored-plan output must match a from-scratch plan of
        // Aᵀ bit for bit (float addition is associative on these inputs,
        // so different cover splits cannot hide behind rounding).
        let a = int_matrix(256, 256 * 8, 77);
        let b = Dense::from_fn(256, 8, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
        let spec = PlanSpec::new(topo).strategy(Strategy::Joint(Solver::Koenig));
        let fwd = spec.plan(&a);
        let mirrored = fwd.transposed();
        let scratch = spec.plan(&a.transpose());
        let (got_m, _) = mirrored
            .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        let (got_s, _) = scratch
            .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
            .expect("thread-backend SpMM")
            .into_dense();
        assert_eq!(got_m.data, got_s.data, "mirrored Aᵀ plan bits differ from scratch plan");
        assert_eq!(got_m.data, a.transpose().spmm(&b).data, "Aᵀ·B oracle mismatch");

        println!(
            "[check] OK: steady-state sessions (0 plan ms, 0 allocs from epoch 2), \
             bitwise-equal trajectories over {epochs} epochs, bitwise transpose mirror"
        );
    }
}
