//! Ablation (PR 5): fused SDDMM→SpMM vs the two-pass alternative on one
//! shared communication plan. The fused kernel ships X and Y rows once and
//! the aggregated partials back; a two-pass attention layer pays the SDDMM
//! exchange, an **edge-value gather** (row-served values shipped home to
//! materialize E at the pattern owners), and then a full SpMM pass that
//! re-ships the plan's whole B side. Every byte here is *measured* on the
//! executed pipeline (the gather, which the executor never performs, is
//! modeled from the plan's row-served nonzero counts).
//!
//! Flags (after `--`):
//!   --preset ci|full   ci = smaller graphs (perf-smoke job)
//!   --check            assert the fused-kernel guarantees (CI gate, all
//!                      deterministic — no wall-clock thresholds):
//!                      (1) fused exchanged bytes are *strictly* less than
//!                          the measured SDDMM + SpMM passes alone — i.e.
//!                          the gate holds even with the gather priced at
//!                          zero — on every dataset × routing mode;
//!                      (2) SpMM and SDDMM report identical B-side
//!                          measured volume off the shared plan;
//!                      (3) on integer-exact inputs, distributed SDDMM is
//!                          bitwise the serial oracle and fused is bitwise
//!                          the oracle SDDMM-then-SpMM chain.

use shiro::bench::{int_matrix, write_csv, Preset};
use shiro::comm::{Strategy, SZ_DT};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecStats;
use shiro::metrics::{reduction_pct, Table};
use shiro::sparse::{gen, Csr};
use shiro::spmm::{DistSpmm, ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::cli::Args;
use shiro::util::rng::Rng;

fn total(s: &ExecStats) -> u64 {
    s.total_intra_bytes() + s.total_inter_bytes()
}

/// Bytes a two-pass pipeline pays to materialize E at the pattern owners:
/// every row-served edge value travels home once.
fn gather_bytes(d: &DistSpmm) -> u64 {
    let mut v = 0u64;
    for p in 0..d.part.nparts {
        for q in 0..d.part.nparts {
            if p != q {
                v += d.plan.pairs[p][q].a_row_part.nnz() as u64 * SZ_DT;
            }
        }
    }
    v
}

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    let check = args.has_flag("check");
    let (n, n_dense, ranks) = match preset {
        Preset::Full => (4096usize, 32usize, 8usize),
        Preset::Ci => (512, 8, 8),
    };
    let datasets: [(&str, Csr); 2] = [
        ("powerlaw", gen::powerlaw(n, n * 8, 1.4, 42)),
        ("rmat", gen::rmat(n, n * 8, (0.55, 0.2, 0.19), false, 42)),
    ];
    let topo = Topology::tsubame4(ranks);
    let mut rng = Rng::new(7);

    let mut table = Table::new(&[
        "dataset",
        "routing",
        "fused B",
        "two-pass B",
        "saved %",
        "gather B",
        "B-side equal",
    ]);
    let mut csv = String::from("dataset,routing,fused_bytes,two_pass_bytes,gather_bytes\n");
    for (name, a) in &datasets {
        let x = Dense::random(a.nrows, n_dense, &mut rng);
        let y = Dense::random(a.nrows, n_dense, &mut rng);
        for hier in [false, true] {
            let d = PlanSpec::new(topo.clone())
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(hier)
                .plan(a);
            let (_, fused) = d
                .execute(&ExecRequest::fused(&x, &y).kernel(&NativeKernel))
                .expect("thread-backend fused kernel")
                .into_dense();
            let (_, sddmm) = d
                .execute(&ExecRequest::sddmm(&x, &y).kernel(&NativeKernel))
                .expect("thread-backend SDDMM")
                .into_sparse();
            let (_, spmm) = d
                .execute(&ExecRequest::spmm(&y).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            let gather = gather_bytes(&d);
            let two_pass = total(&sddmm) + total(&spmm) + gather;
            let b_equal = spmm.measured_b_volume() == sddmm.measured_b_volume();
            let routing = if hier { "hier" } else { "flat" };
            table.row(vec![
                (*name).into(),
                routing.into(),
                total(&fused).to_string(),
                two_pass.to_string(),
                format!("{:.1}", reduction_pct(two_pass, total(&fused))),
                gather.to_string(),
                b_equal.to_string(),
            ]);
            csv.push_str(&format!(
                "{name},{routing},{},{two_pass},{gather}\n",
                total(&fused)
            ));
            if check {
                // (1) Strict cut, with the gather priced at ZERO: the
                // fused kernel's saving is the SpMM pass's B-side
                // re-shipment, which is positive on these plans.
                assert!(
                    spmm.measured_b_volume().total() > 0,
                    "{name}/{routing}: degenerate plan, B side empty"
                );
                assert!(
                    total(&fused) < total(&sddmm) + total(&spmm),
                    "{name}/{routing}: fused {} !< two-pass {} (sans gather)",
                    total(&fused),
                    total(&sddmm) + total(&spmm)
                );
                // (2) One plan, identical B-side bytes for both kernels.
                assert!(b_equal, "{name}/{routing}: B-side volume differs across kernels");
            }
        }
    }
    println!(
        "Ablation — fused SDDMM→SpMM vs two-pass on one shared plan \
         ({n} nodes, {ranks} ranks, N={n_dense})\n"
    );
    println!("{}", table.render());
    println!(
        "two-pass = measured SDDMM exchange + measured SpMM exchange + modeled\n\
         edge-value gather; fused is measured end-to-end. The saving is the\n\
         SpMM pass's B-side re-shipment plus the gather.\n"
    );
    write_csv("ablation_fused.csv", &csv);

    if check {
        // (3) Bitwise gates on integer-exact inputs.
        let a = int_matrix(256, 256 * 8, 77);
        let xi = Dense::from_fn(256, 4, |i, j| ((i * 3 + j) % 5) as f32 - 2.0);
        let yi = Dense::from_fn(256, 4, |i, j| ((i * 7 + j * 2) % 5) as f32 - 2.0);
        let e_want = a.sddmm(&xi, &yi);
        let c_want = e_want.spmm(&yi);
        for hier in [false, true] {
            let d = PlanSpec::new(topo.clone())
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(hier)
                .plan(&a);
            let (e, _) = d
                .execute(&ExecRequest::sddmm(&xi, &yi))
                .expect("thread-backend SDDMM")
                .into_sparse();
            assert_eq!(e, e_want, "hier={hier}: SDDMM bits differ from oracle");
            let (c, _) = d
                .execute(&ExecRequest::fused(&xi, &yi))
                .expect("thread-backend fused kernel")
                .into_dense();
            assert_eq!(
                c.data, c_want.data,
                "hier={hier}: fused bits differ from oracle chain"
            );
        }
        println!(
            "[check] OK: fused strictly cuts exchanged bytes vs two-pass (gather \
             priced at zero), identical B-side volume across kernels, bitwise \
             SDDMM + fused vs serial oracles"
        );
    }
}
