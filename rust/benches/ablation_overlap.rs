//! Ablation (§6.2): the complementary two-stage overlap vs tier-serialized
//! execution of the *same* hierarchical message sets — isolates the benefit
//! of Alg. 1's scheduling from the benefit of deduplication. nGPUs=32, N=64.

use shiro::bench::{ms, write_csv, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::hierarchy;
use shiro::metrics::Table;
use shiro::partition::{split_1d, RowPartition};
use shiro::sim::{hier_comm_stages, hier_comm_stages_sequential, simulate, SimJob};
use shiro::sparse::datasets::spmm_datasets;
use shiro::topology::Topology;

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let topo = Topology::tsubame4(ranks);
    let mut table = Table::new(&[
        "dataset", "sequential (ms)", "overlapped (ms)", "overlap speedup",
    ]);
    let mut csv = String::from("dataset,sequential_ms,overlapped_ms\n");
    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let sched = hierarchy::build(&plan, &topo);
        let [s1, s2] = hier_comm_stages(&sched, n_dense);
        let overlapped = simulate(&SimJob { stages: vec![s1, s2] }, &topo);
        let seq = hier_comm_stages_sequential(&sched, n_dense);
        let sequential = simulate(&SimJob { stages: seq.to_vec() }, &topo);
        table.row(vec![
            spec.name.into(),
            ms(sequential.total),
            ms(overlapped.total),
            format!("{:.2}x", sequential.total / overlapped.total),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6}\n",
            spec.name,
            sequential.total * 1e3,
            overlapped.total * 1e3
        ));
    }
    println!("Ablation — complementary stage overlap (Alg. 1) vs serialized tiers\n");
    println!("{}", table.render());
    println!(
        "Expectation: overlap ≥ 1x everywhere (same bytes, concurrent tiers);\n\
         largest gains where intra- and inter-tier times are balanced."
    );
    write_csv("ablation_overlap.csv", &csv);
}
