//! Ablation (§6.2): the complementary two-stage overlap vs tier-serialized
//! execution of the *same* hierarchical message sets — isolates the benefit
//! of Alg. 1's scheduling from the benefit of deduplication. Two parts:
//!
//! 1. **Simulated** (nGPUs=32, N=64): the α-β model on the full dataset
//!    registry — deterministic, so `overlap >= sequential` is asserted.
//! 2. **Executed**: the real in-process pipeline (`ExecOpts::overlap`
//!    on/off) on a skewed preset, with bit-identical results checked and
//!    the chrome traces (simulated + executed, same phase names) written
//!    as artifacts.
//!
//! Flags (after `--`): --preset ci|full (ci = smaller scale, fewer sets).

use shiro::bench::{ms, write_artifact, write_csv, Preset, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecOpts;
use shiro::hierarchy;
use shiro::metrics::Table;
use shiro::partition::{split_1d, RowPartition};
use shiro::sim::trace::{exec_to_chrome_json, to_chrome_json, trace};
use shiro::sim::{hier_comm_stages, hier_comm_stages_sequential, simulate, SimJob};
use shiro::sparse::datasets::spmm_datasets;
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::cli::Args;
use shiro::util::rng::Rng;
use shiro::util::timer::benchmark;

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    let (scale, max_sets) = match preset {
        Preset::Full => (BENCH_SCALE, usize::MAX),
        Preset::Ci => (BENCH_SCALE * 0.25, 4),
    };

    // ---- Part 1: simulated schedule ablation ----
    let ranks = 32;
    let n_dense = 64;
    let topo = Topology::tsubame4(ranks);
    let mut table = Table::new(&[
        "dataset", "sequential (ms)", "overlapped (ms)", "overlap speedup",
    ]);
    let mut csv = String::from("dataset,sequential_ms,overlapped_ms\n");
    let mut trace_written = false;
    for spec in spmm_datasets().into_iter().take(max_sets) {
        let a = spec.generate(scale);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let sched = hierarchy::build(&plan, &topo);
        let [s1, s2] = hier_comm_stages(&sched, n_dense);
        let job = SimJob { stages: vec![s1, s2] };
        let overlapped = simulate(&job, &topo);
        let seq = hier_comm_stages_sequential(&sched, n_dense);
        let sequential = simulate(&SimJob { stages: seq.to_vec() }, &topo);
        // Same bytes, concurrent tiers: the simulator is deterministic, so
        // this is an invariant, not a flake risk.
        assert!(
            overlapped.total <= sequential.total * 1.0001,
            "{}: overlap {} > sequential {}",
            spec.name,
            overlapped.total,
            sequential.total
        );
        if !trace_written {
            write_artifact(
                "ablation_overlap_sim_trace.json",
                &to_chrome_json(&trace(&job, &topo), &job),
            );
            trace_written = true;
        }
        table.row(vec![
            spec.name.into(),
            ms(sequential.total),
            ms(overlapped.total),
            format!("{:.2}x", sequential.total / overlapped.total),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6}\n",
            spec.name,
            sequential.total * 1e3,
            overlapped.total * 1e3
        ));
    }
    println!("Ablation — complementary stage overlap (Alg. 1) vs serialized tiers\n");
    println!("{}", table.render());
    println!(
        "Expectation: overlap >= 1x everywhere (same bytes, concurrent tiers);\n\
         largest gains where intra- and inter-tier times are balanced.\n"
    );
    write_csv("ablation_overlap.csv", &csv);

    // ---- Part 2: executed pipeline ablation ----
    let (n, exec_ranks, exec_n, warmup, runs) = match preset {
        Preset::Full => (1 << 14, 16, 64, 2, 8),
        Preset::Ci => (1 << 12, 8, 32, 1, 5),
    };
    let a = gen::powerlaw(n, n * 10, 1.45, 5);
    let d = PlanSpec::new(Topology::tsubame4(exec_ranks))
        .strategy(Strategy::Joint(Solver::Koenig))
        .plan(&a);
    let mut rng = Rng::new(11);
    let b = Dense::random(a.nrows, exec_n, &mut rng);
    let on = ExecOpts::default();
    let off = ExecOpts::sequential();
    let run = |opts: &ExecOpts| {
        d.execute(&ExecRequest::spmm(&b).kernel(&NativeKernel).opts(*opts))
            .expect("thread-backend SpMM")
            .into_dense()
    };
    let (c_on, stats_on) = run(&on);
    let (c_off, _) = run(&off);
    assert_eq!(c_on.data, c_off.data, "executed overlap on/off differ");
    write_artifact("ablation_overlap_exec_trace.json", &exec_to_chrome_json(&stats_on));
    let t_on = benchmark(warmup, runs, || run(&on));
    let t_off = benchmark(warmup, runs, || run(&off));
    let w = stats_on.overlap_window();
    let mut t2 = Table::new(&[
        "executed scenario", "sequential (ms)", "overlapped (ms)", "speedup", "overlap frac",
    ]);
    t2.row(vec![
        format!("web-{}k x{} N{}", n >> 10, exec_ranks, exec_n),
        format!("{:.2}", t_off.median * 1e3),
        format!("{:.2}", t_on.median * 1e3),
        format!("{:.2}x", t_off.median / t_on.median),
        format!("{:.0}%", w.overlapped_fraction() * 100.0),
    ]);
    println!("Executed pipeline (real in-process ranks, bit-identical results):\n");
    println!("{}", t2.render());
    write_csv(
        "ablation_overlap_exec.csv",
        &format!(
            "scenario,sequential_ms,overlapped_ms,speedup,overlapped_fraction\n\
             web-{}k x{} N{},{:.4},{:.4},{:.4},{:.4}\n",
            n >> 10,
            exec_ranks,
            exec_n,
            t_off.median * 1e3,
            t_on.median * 1e3,
            t_off.median / t_on.median,
            w.overlapped_fraction()
        ),
    );
}
