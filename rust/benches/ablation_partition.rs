//! Ablation (PR 3): load-aware 1D partitioning × SHIRO's joint planning.
//! Partitioning decides *which* nonzeros are remote; the cover machinery
//! decides *how* the remaining remote nonzeros are served — this bench
//! measures both halves across the three [`Partitioner`]s on the skewed
//! dataset presets: max-rank nnz (the straggler the overlapped executor
//! stalls on), the nnz load-imbalance factor, and joint-plan volume.
//!
//! Flags (after `--`):
//!   --preset ci|full   ci = smaller scale / fewer ranks (perf-smoke job)
//!   --check            assert the load-aware guarantees (CI gate):
//!                      NnzBalanced and CostRefined strictly reduce
//!                      max-rank nnz vs Balanced on the index-skewed
//!                      (rmat) datasets, and executed results stay
//!                      bit-identical to the serial reference under every
//!                      partitioner on an integer-exact input.

use shiro::bench::{int_matrix, write_csv, Preset, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::metrics::{load_imbalance, Table};
use shiro::partition::{max_rank_nnz, rank_nnz, split_1d, Partitioner};
use shiro::sparse::datasets::dataset_by_name;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    let check = args.has_flag("check");
    let (scale, ranks) = match preset {
        Preset::Full => (BENCH_SCALE, 16),
        Preset::Ci => (BENCH_SCALE * 0.25, 8),
    };
    let n_dense = 32;
    let topo = Topology::tsubame4(ranks);

    // The skewed presets: rmat social graphs concentrate nnz in low row
    // indices (index skew — balanced row counts are maximally unfair);
    // uk-2002/mawi add hub skew with randomly placed heavy rows.
    let rmat_sets = ["Pokec", "sx-SO"];
    let report_sets = ["Pokec", "sx-SO", "uk-2002", "mawi"];

    let mut table = Table::new(&[
        "dataset",
        "partitioner",
        "max-rank nnz",
        "imbalance",
        "joint volume (KiB)",
    ]);
    let mut csv =
        String::from("dataset,partitioner,max_rank_nnz,load_imbalance,joint_volume_bytes\n");
    let mut checks_run = 0usize;
    for name in report_sets {
        let spec = dataset_by_name(name).expect("dataset registry entry");
        let a = spec.generate(scale);
        let mut max_by_partitioner = Vec::new();
        for partitioner in Partitioner::ALL {
            let part = partitioner.partition(&a, ranks, &topo, n_dense);
            let blocks = split_1d(&a, &part);
            let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
            let loads = rank_nnz(&a, &part);
            let max_nnz = max_rank_nnz(&a, &part);
            let imb = load_imbalance(&loads);
            let vol = plan.total_volume(n_dense);
            max_by_partitioner.push(max_nnz);
            table.row(vec![
                name.into(),
                partitioner.name().into(),
                max_nnz.to_string(),
                format!("{imb:.2}x"),
                format!("{:.1}", vol as f64 / 1024.0),
            ]);
            csv.push_str(&format!(
                "{},{},{},{:.4},{}\n",
                name,
                partitioner.name(),
                max_nnz,
                imb,
                vol
            ));
        }
        if check && rmat_sets.contains(&name) {
            let [bal, nnz, refined] = [
                max_by_partitioner[0],
                max_by_partitioner[1],
                max_by_partitioner[2],
            ];
            assert!(
                nnz < bal,
                "{name}: NnzBalanced max-rank nnz {nnz} !< Balanced {bal}"
            );
            assert!(
                refined <= bal,
                "{name}: CostRefined max-rank nnz {refined} > Balanced {bal}"
            );
            checks_run += 1;
        }
    }
    println!("Ablation — load-aware partitioning × joint planning ({ranks} ranks, N={n_dense})\n");
    println!("{}", table.render());
    println!(
        "Expectation: nnz-balanced/cost-refined cut max-rank nnz hardest on the\n\
         index-skewed rmat sets; volume shifts are second-order (partitioning\n\
         and cover planning compose, like the reordering ablation).\n"
    );
    write_csv("ablation_partition.csv", &csv);

    // Executed correctness gate: identical bits to the serial reference
    // under every partitioner on an integer-exact input.
    if check {
        let n = match preset {
            Preset::Full => 1 << 10,
            Preset::Ci => 1 << 8,
        };
        let a = int_matrix(n, n * 8, 33);
        let b = Dense::from_fn(n, 8, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
        let want = a.spmm(&b);
        for partitioner in Partitioner::ALL {
            let d = PlanSpec::new(Topology::tsubame4(ranks))
                .strategy(Strategy::Joint(Solver::Koenig))
                .partitioner(partitioner)
                .plan(&a);
            let (got, _) = d
                .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            assert_eq!(
                got.data,
                want.data,
                "{}: executed bits differ from serial",
                partitioner.name()
            );
        }
        assert!(checks_run > 0, "no skewed dataset was checked");
        println!(
            "[check] OK: straggler reduction on {checks_run} rmat sets + bit-identical \
             execution under all partitioners"
        );
    }
}
