//! Ablation (related work §8.1): SHIRO composes with matrix reordering —
//! partitioning/reordering optimizes *which* nonzeros are remote, SHIRO
//! optimizes *how* the remaining remote nonzeros are served. We measure
//! joint-plan volume under natural, random, degree, and RCM orderings.
//! nGPUs=32, N=64.

use shiro::bench::{write_csv, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::metrics::Table;
use shiro::partition::{split_1d, RowPartition};
use shiro::sparse::{datasets::spmm_datasets, reorder, Csr};

fn volume(a: &Csr, ranks: usize, n_dense: usize) -> u64 {
    let part = RowPartition::balanced(a.nrows, ranks);
    let blocks = split_1d(a, &part);
    comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None).total_volume(n_dense)
}

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let mut table = Table::new(&[
        "dataset", "natural (MiB)", "random (MiB)", "degree (MiB)", "RCM (MiB)",
    ]);
    let mut csv = String::from("dataset,natural,random,degree,rcm\n");
    let mib = |b: u64| format!("{:.2}", b as f64 / (1u64 << 20) as f64);
    // Representative subset (reordering is O(nnz log n) per variant).
    for spec in spmm_datasets().into_iter().filter(|s| {
        ["Pokec", "del24", "mawi", "uk-2002", "GAP-web"].contains(&s.name)
    }) {
        let a = spec.generate(BENCH_SCALE);
        let natural = volume(&a, ranks, n_dense);
        let rand = volume(
            &reorder::permute_symmetric(&a, &reorder::random_perm(a.nrows, 1)),
            ranks,
            n_dense,
        );
        let deg = volume(
            &reorder::permute_symmetric(&a, &reorder::degree_order(&a)),
            ranks,
            n_dense,
        );
        let rcm = volume(
            &reorder::permute_symmetric(&a, &reorder::rcm_order(&a)),
            ranks,
            n_dense,
        );
        table.row(vec![
            spec.name.into(),
            mib(natural),
            mib(rand),
            mib(deg),
            mib(rcm),
        ]);
        csv.push_str(&format!("{},{natural},{rand},{deg},{rcm}\n", spec.name));
    }
    println!("Ablation — joint-plan volume under matrix reorderings\n");
    println!("{}", table.render());
    println!(
        "Expectation: random ≥ natural (destroys locality); RCM ≤ natural on\n\
         mesh/road matrices (restores locality) — reordering and SHIRO\n\
         compose, as §8.1 argues."
    );
    write_csv("ablation_reorder.csv", &csv);
}
