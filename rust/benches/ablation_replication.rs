//! Ablation (tentpole): the 1.5D replicated decomposition (DESIGN.md §13)
//! vs the flat 1D engine. Replication groups of `c` ranks replicate their
//! group's A block and deal the group's inter-group flows across members,
//! so the cover-named rows of the *group plan* — a joint plan over the
//! `ranks/c`-way coarsened partition — are all that crosses group
//! boundaries. Because the group boundaries are the rank boundaries
//! coarsened, per-pair covers merge and dedup, and modeled inter-group
//! volume can only fall as `c` grows. This bench reports modeled and
//! measured inter-group wire bytes plus the intra-group reduce-scatter
//! cost across the dataset presets.
//!
//! Flags (after `--`):
//!   --preset ci|full   ci = smaller scale / fewer ranks (perf-smoke job)
//!   --check            assert the replication guarantees (CI gate):
//!                      modeled inter-group wire bytes strictly below the
//!                      c=1 flat volume for every c>1 on the index-skewed
//!                      (rmat) datasets, measured inter-group traffic
//!                      exactly equal to the schedule's model for every
//!                      c>1, and executed results bit-identical to the
//!                      serial reference for every factor on an
//!                      integer-exact input.

use shiro::bench::{int_matrix, write_csv, Preset, BENCH_SCALE};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::hierarchy::build_replicated;
use shiro::metrics::{reduction_pct, Table};
use shiro::sparse::datasets::dataset_by_name;
use shiro::spmm::{ExecRequest, PlanSpec, Replicate};
use shiro::topology::{ReplicaMap, Topology};
use shiro::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    let check = args.has_flag("check");
    let (scale, ranks, factors): (f64, usize, &[usize]) = match preset {
        Preset::Full => (BENCH_SCALE, 16, &[1, 2, 4, 8]),
        Preset::Ci => (BENCH_SCALE * 0.25, 8, &[1, 2, 4]),
    };
    let n_dense = 16;
    // rmat social graphs concentrate nnz in low row indices, so coarsened
    // covers dedup hardest there — the strict-decrease gate runs on them.
    let rmat_sets = ["Pokec", "sx-SO"];
    let report_sets = ["Pokec", "sx-SO", "uk-2002", "mawi"];

    let mut table = Table::new(&[
        "dataset",
        "c",
        "inter model (KiB)",
        "inter measured (KiB)",
        "vs c=1 %",
        "reduce-scatter (KiB)",
    ]);
    let mut csv = String::from(
        "dataset,c,inter_model_bytes,inter_measured_bytes,intra_model_bytes\n",
    );
    let mut strict_sets = 0usize;
    for name in report_sets {
        let spec = dataset_by_name(name).expect("dataset registry entry");
        let a = spec.generate(scale);
        let b = Dense::from_fn(a.nrows, n_dense, |i, j| ((i * 13 + j * 7) % 17) as f32 - 8.0);
        let mut base_model = 0u64;
        let mut all_below = true;
        for &c in factors {
            // group_size = c keeps the executor's tier accounting aligned
            // with the replication-group boundaries, so measured
            // inter-group bytes are comparable to the schedule's model.
            let mut topo = Topology::tsubame4(ranks);
            topo.group_size = c.max(1);
            // Flat routing at c=1: the comparison is against the plain 1D
            // engine's per-pair sends, not the two-stage hierarchy (which
            // has its own dedup and would confound the replication delta).
            let d = PlanSpec::new(topo)
                .strategy(Strategy::Joint(Solver::Koenig))
                .flat()
                .n_dense(n_dense)
                .replicate(Replicate::Factor(c))
                .plan(&a);
            // The c=1 model prices the flat plan through the same wire
            // formula (each shipped row carries its u32 index + N f32s):
            // a degenerate one-member-per-group schedule over the flat
            // plan, so the columns are directly comparable across c.
            let (model, intra_model) = match &d.rep {
                Some(rep) => {
                    (rep.inter_wire_bytes(&d.plan, n_dense), rep.intra_wire_bytes(n_dense))
                }
                None => {
                    let deg = build_replicated(&d.plan, &ReplicaMap::new(ranks, 1));
                    (deg.inter_wire_bytes(&d.plan, n_dense), 0)
                }
            };
            let (_, stats) = d
                .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            let measured = stats.total_inter_bytes();
            if c == 1 {
                base_model = model;
            } else {
                all_below &= model < base_model;
                if check {
                    assert_eq!(
                        measured, model,
                        "{name} c={c}: measured inter-group bytes drifted from the model"
                    );
                }
            }
            table.row(vec![
                name.into(),
                c.to_string(),
                format!("{:.1}", model as f64 / 1024.0),
                format!("{:.1}", measured as f64 / 1024.0),
                if c == 1 { "-".into() } else { format!("{:.1}", reduction_pct(base_model, model)) },
                format!("{:.1}", intra_model as f64 / 1024.0),
            ]);
            csv.push_str(&format!("{name},{c},{model},{measured},{intra_model}\n"));
        }
        if rmat_sets.contains(&name) && all_below {
            strict_sets += 1;
        }
        if check && rmat_sets.contains(&name) {
            assert!(
                all_below,
                "{name}: some c>1 failed to strictly cut modeled inter-group bytes"
            );
        }
    }
    println!(
        "Ablation — 1.5D replication vs the flat engine ({ranks} ranks, N={n_dense})\n"
    );
    println!("{}", table.render());
    println!(
        "Expectation: inter-group bytes fall monotonically with c (nested\n\
         coarsened covers dedup), steepest on the index-skewed rmat sets; the\n\
         price is the intra-group reduce-scatter column and c-fold A memory.\n"
    );
    write_csv("ablation_replication.csv", &csv);

    // Executed correctness gate: identical bits to the serial reference at
    // every replication factor on an integer-exact input — c=1 pins the
    // replicated planner's pass-through to the flat engine, c>1 pins the
    // two-level fold against both.
    if check {
        let n = match preset {
            Preset::Full => 1 << 9,
            Preset::Ci => 1 << 8,
        };
        let a = int_matrix(n, n * 8, 47);
        let b = Dense::from_fn(n, 8, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
        let want = a.spmm(&b);
        for &c in factors {
            let d = PlanSpec::new(Topology::tsubame4(ranks))
                .strategy(Strategy::Joint(Solver::Koenig))
                .n_dense(8)
                .replicate(Replicate::Factor(c))
                .plan(&a);
            if let Some(rep) = &d.rep {
                rep.validate(&d.plan).expect("replication schedule must validate");
            }
            let (got, _) = d
                .execute(&ExecRequest::spmm(&b).kernel(&NativeKernel))
                .expect("thread-backend SpMM")
                .into_dense();
            assert_eq!(got.data, want.data, "c={c}: executed bits differ from serial");
        }
        assert!(
            strict_sets >= 2,
            "strict inter-group reduction held on only {strict_sets} rmat sets"
        );
        println!(
            "[check] OK: strict modeled reduction on {strict_sets} rmat sets, \
             measured == modeled inter-group bytes for every c>1, and \
             bit-identical execution at every factor"
        );
    }
}
