//! Ablation (extension of §5.2's cost coefficients): uniform-cost joint
//! MWVC vs hierarchy-aware *weighted* MWVC, where vertex costs reflect the
//! dedup/pre-aggregation discounts of the two-tier schedule. Measures
//! inter-node bytes after hierarchical scheduling and simulated time.
//! nGPUs=32, N=64.

use shiro::bench::{ms, write_csv, BENCH_SCALE};
use shiro::comm::{self, weighted, Strategy};
use shiro::cover::Solver;
use shiro::hierarchy;
use shiro::metrics::{reduction_pct, Table};
use shiro::partition::{split_1d, RowPartition};
use shiro::sim::{hier_comm_stages, simulate, SimJob};
use shiro::sparse::datasets::spmm_datasets;
use shiro::topology::Topology;

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let topo = Topology::tsubame4(ranks);
    let mut table = Table::new(&[
        "dataset",
        "uniform inter (KiB)",
        "weighted inter (KiB)",
        "reduction %",
        "uniform (ms)",
        "weighted (ms)",
    ]);
    let mut csv =
        String::from("dataset,uniform_inter_bytes,weighted_inter_bytes,uniform_ms,weighted_ms\n");
    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);

        let uni_plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let wei_plan = weighted::plan_hier_weighted(&blocks, &part, &topo);

        let run = |plan: &comm::CommPlan| {
            let sched = hierarchy::build(plan, &topo);
            let inter = sched.inter_group_bytes(n_dense);
            let [s1, s2] = hier_comm_stages(&sched, n_dense);
            let rep = simulate(&SimJob { stages: vec![s1, s2] }, &topo);
            (inter, rep.total)
        };
        let (ui, ut) = run(&uni_plan);
        let (wi, wt) = run(&wei_plan);
        table.row(vec![
            spec.name.into(),
            format!("{:.1}", ui as f64 / 1024.0),
            format!("{:.1}", wi as f64 / 1024.0),
            format!("{:.1}", reduction_pct(ui, wi)),
            ms(ut),
            ms(wt),
        ]);
        csv.push_str(&format!(
            "{},{},{},{:.6},{:.6}\n",
            spec.name,
            ui,
            wi,
            ut * 1e3,
            wt * 1e3
        ));
    }
    println!(
        "Ablation — hierarchy-aware weighted MWVC vs uniform-cost joint plan\n"
    );
    println!("{}", table.render());
    println!(
        "Expectation: weighted never increases inter-node bytes; gains are\n\
         largest where dedup factors differ strongly between B and C sides."
    );
    write_csv("ablation_weighted.csv", &csv);
}
