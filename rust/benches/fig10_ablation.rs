//! Fig. 10 — step-wise optimization ablation: column-based (flat) →
//! + joint row-column (flat) → + hierarchical overlap. Simulated runtime
//! per SpMM. nGPUs = 32, N = 64 (paper setting).

use shiro::bench::{ms, write_csv, BENCH_SCALE};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::metrics::Table;
use shiro::sparse::datasets::spmm_datasets;
use shiro::spmm::PlanSpec;
use shiro::topology::Topology;

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let mut table = Table::new(&[
        "dataset",
        "column (ms)",
        "+joint (ms)",
        "+hier (ms)",
        "+adaptive (ms)",
        "joint speedup",
        "hier speedup",
        "adaptive speedup",
    ]);
    let mut csv = String::from("dataset,column_ms,joint_ms,hier_ms,adaptive_ms\n");
    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        let t_col = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(Strategy::Column)
            .flat()
            .plan(&a)
            .simulate(n_dense)
            .total;
        let t_joint = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(Strategy::Joint(Solver::Koenig))
            .flat()
            .plan(&a)
            .simulate(n_dense)
            .total;
        let t_hier = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(Strategy::Joint(Solver::Koenig))
            .plan(&a)
            .simulate(n_dense)
            .total;
        let t_adaptive = PlanSpec::new(Topology::tsubame4(ranks))
            .strategy(Strategy::Adaptive)
            .n_dense(n_dense)
            .plan(&a)
            .simulate(n_dense)
            .total;
        table.row(vec![
            spec.name.into(),
            ms(t_col),
            ms(t_joint),
            ms(t_hier),
            ms(t_adaptive),
            format!("{:.2}x", t_col / t_joint),
            format!("{:.2}x", t_col / t_hier),
            format!("{:.2}x", t_col / t_adaptive),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6}\n",
            spec.name,
            t_col * 1e3,
            t_joint * 1e3,
            t_hier * 1e3,
            t_adaptive * 1e3
        ));
    }
    println!("Fig. 10 — step-wise ablation (nGPUs=32, N=64)\n");
    println!("{}", table.render());
    println!(
        "Paper shape: joint speeds up ALL datasets; hierarchical helps most\n\
         datasets but can hurt on del24 (imbalanced decomposed collectives)."
    );
    write_csv("fig10_ablation.csv", &csv);
}
