//! Fig. 11 — sensitivity to the dense column count: every dataset × every
//! system at N = 64 and N = 128 (paper setting), 32 simulated GPUs.

use shiro::baselines::{simulate, System};
use shiro::bench::{ms, write_csv, ABLATION_RANKS, BENCH_SCALE};
use shiro::metrics::Table;
use shiro::sparse::datasets::spmm_datasets;
use shiro::topology::Topology;

fn main() {
    let mut csv = String::from("dataset,system,n,seconds\n");
    for &n_dense in &[64usize, 128] {
        println!("\n=== N = {n_dense} (nGPUs = {ABLATION_RANKS}) — simulated SpMM ms ===");
        let mut table =
            Table::new(&["dataset", "CAGNET", "SPA", "BCL", "CoLa", "SHIRO", "SHIRO-A"]);
        for spec in spmm_datasets() {
            let a = spec.generate(BENCH_SCALE);
            let topo = Topology::tsubame4(ABLATION_RANKS);
            let mut cells = vec![spec.name.to_string()];
            for sys in System::all() {
                let r = simulate(sys, &a, n_dense, &topo);
                cells.push(ms(r.total));
                csv.push_str(&format!(
                    "{},{},{},{:.9}\n",
                    spec.name,
                    sys.name(),
                    n_dense,
                    r.total
                ));
            }
            table.row(cells);
        }
        println!("{}", table.render());
    }
    println!(
        "Paper shape: SHIRO wins on most datasets at both N; times scale\n\
         ~linearly with N (communication-throughput-bound)."
    );
    write_csv("fig11_density.csv", &csv);
}
