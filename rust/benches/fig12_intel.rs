//! Fig. 12 — portability: the same step-wise ablation on the Aurora
//! topology (12 tiles/node, shallow bandwidth cliff: 15 GB/s intra vs
//! ~17 GB/s inter). nGPUs = 24 (paper setting). Expected shape: the joint
//! strategy still helps; whole-node hierarchical aggregation does NOT
//! (flat joint ≥ hierarchical), because there is no bandwidth cliff to
//! amortize the extra packing/collective stages against.

use shiro::bench::{ms, write_csv, BENCH_SCALE};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::metrics::Table;
use shiro::sparse::datasets::spmm_datasets;
use shiro::spmm::PlanSpec;
use shiro::topology::Topology;

fn main() {
    let ranks = 24;
    let n_dense = 64;
    let mut table = Table::new(&[
        "dataset",
        "column (ms)",
        "+joint (ms)",
        "+hier (ms)",
        "joint speedup",
        "hier vs joint",
    ]);
    let mut csv = String::from("dataset,column_ms,joint_ms,hier_ms\n");
    let mut hier_wins = 0usize;
    let mut total = 0usize;
    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        let topo = || Topology::aurora(ranks);
        let t_col = PlanSpec::new(topo())
            .strategy(Strategy::Column)
            .flat()
            .plan(&a)
            .simulate(n_dense)
            .total;
        let t_joint = PlanSpec::new(topo())
            .strategy(Strategy::Joint(Solver::Koenig))
            .flat()
            .plan(&a)
            .simulate(n_dense)
            .total;
        let t_hier = PlanSpec::new(topo())
            .strategy(Strategy::Joint(Solver::Koenig))
            .plan(&a)
            .simulate(n_dense)
            .total;
        if t_hier < t_joint {
            hier_wins += 1;
        }
        total += 1;
        table.row(vec![
            spec.name.into(),
            ms(t_col),
            ms(t_joint),
            ms(t_hier),
            format!("{:.2}x", t_col / t_joint),
            format!("{:.2}x", t_joint / t_hier),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6}\n",
            spec.name,
            t_col * 1e3,
            t_joint * 1e3,
            t_hier * 1e3
        ));
    }
    println!("Fig. 12 — Aurora (Intel) portability study (nGPUs=24, N=64)\n");
    println!("{}", table.render());
    println!(
        "hierarchical beat flat-joint on {hier_wins}/{total} datasets — paper shape:\n\
         on Aurora the flat joint schedule is preferable (shallow cliff),\n\
         unlike TSUBAME (Fig. 10)."
    );
    write_csv("fig12_intel.csv", &csv);
}
