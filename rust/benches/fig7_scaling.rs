//! Fig. 7 — runtime comparison and strong scaling: every dataset × every
//! system (CAGNET, SPA, BCL, CoLa, SHIRO) from 2 to 128 simulated GPUs,
//! N = 32 (paper setting). Prints per-dataset scaling curves and the §7.2
//! headline geomean speedups at 128 GPUs.

use shiro::baselines::{simulate, System};
use shiro::bench::{ms, write_csv, BENCH_SCALE, FIG7_RANKS};
use shiro::metrics::Table;
use shiro::sparse::datasets::spmm_datasets;
use shiro::topology::Topology;
use shiro::util::geomean;

fn main() {
    let n_dense = 32;
    let mut csv = String::from("dataset,system,ranks,seconds\n");
    // speedup[system] at 128 ranks, per dataset.
    let mut speedups: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();

    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        println!(
            "\n=== {} ({}x{}, nnz {}) — simulated SpMM ms per rank count ===",
            spec.name, a.nrows, a.ncols, a.nnz()
        );
        let mut table = Table::new(&[
            "system", "p=2", "p=4", "p=8", "p=16", "p=32", "p=64", "p=128",
        ]);
        let mut at128: std::collections::BTreeMap<&str, f64> = Default::default();
        for sys in System::all() {
            let mut cells = vec![sys.name().to_string()];
            for &ranks in FIG7_RANKS.iter() {
                let topo = Topology::tsubame4(ranks);
                let r = simulate(sys, &a, n_dense, &topo);
                cells.push(ms(r.total));
                csv.push_str(&format!(
                    "{},{},{},{:.9}\n",
                    spec.name,
                    sys.name(),
                    ranks,
                    r.total
                ));
                if ranks == 128 {
                    at128.insert(sys.name(), r.total);
                }
            }
            table.row(cells);
        }
        println!("{}", table.render());
        let shiro = at128["SHIRO"];
        for sys in [System::Cagnet, System::Spa, System::Bcl, System::Cola] {
            speedups
                .entry(sys.name())
                .or_default()
                .push(at128[sys.name()] / shiro);
        }
    }

    println!("\n=== §7.2 headline: geomean speedup of SHIRO at 128 GPUs ===");
    let mut t = Table::new(&["baseline", "geomean speedup", "paper reports"]);
    let paper = [("CAGNET", "221.5x"), ("SPA", "56.0x"), ("BCL", "23.4x"), ("CoLa", "8.8x")];
    for (name, paper_x) in paper {
        let g = geomean(&speedups[name]);
        t.row(vec![name.into(), format!("{g:.1}x"), paper_x.into()]);
    }
    println!("{}", t.render());
    println!(
        "Shape expectations: ordering CAGNET > SPA > BCL > CoLa > SHIRO at\n\
         scale; baselines stop scaling past ~8 ranks while SHIRO keeps\n\
         improving on most datasets; absolute factors differ (simulator, \n\
         laptop-scale matrices)."
    );
    write_csv("fig7_scaling.csv", &csv);
}
