//! Fig. 8 — (a) total communication volume: column-based vs joint
//! row-column, with reduction %; (b) inter-node volume: flat joint vs
//! hierarchical. nGPUs = 32 (paper setting), N = 64.

use shiro::bench::{write_csv, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::hierarchy;
use shiro::metrics::{reduction_pct, Table};
use shiro::partition::{split_1d, RowPartition};
use shiro::sparse::datasets::spmm_datasets;
use shiro::topology::Topology;

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let topo = Topology::tsubame4(ranks);
    let mut table = Table::new(&[
        "dataset",
        "col vol (MiB)",
        "joint vol (MiB)",
        "reduction %",
        "flat inter (MiB)",
        "hier inter (MiB)",
        "inter red %",
    ]);
    let mut csv = String::from(
        "dataset,col_bytes,joint_bytes,reduction_pct,flat_inter_bytes,hier_inter_bytes,inter_reduction_pct\n",
    );
    let mib = |b: u64| b as f64 / (1u64 << 20) as f64;
    for spec in spmm_datasets() {
        let a = spec.generate(BENCH_SCALE);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let col = comm::plan(&blocks, &part, Strategy::Column, None);
        let joint = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let vc = col.total_volume(n_dense);
        let vj = joint.total_volume(n_dense);
        let flat_inter = hierarchy::flat_inter_group_bytes(&joint, &topo, n_dense);
        let sched = hierarchy::build(&joint, &topo);
        let hier_inter = sched.inter_group_bytes(n_dense);
        table.row(vec![
            spec.name.into(),
            format!("{:.2}", mib(vc)),
            format!("{:.2}", mib(vj)),
            format!("{:.1}", reduction_pct(vc, vj)),
            format!("{:.2}", mib(flat_inter)),
            format!("{:.2}", mib(hier_inter)),
            format!("{:.1}", reduction_pct(flat_inter, hier_inter)),
        ]);
        csv.push_str(&format!(
            "{},{},{},{:.2},{},{},{:.2}\n",
            spec.name,
            vc,
            vj,
            reduction_pct(vc, vj),
            flat_inter,
            hier_inter,
            reduction_pct(flat_inter, hier_inter)
        ));
    }
    println!("Fig. 8 — communication volume reduction (nGPUs=32, N=64)\n");
    println!("{}", table.render());
    println!(
        "Paper shape: joint reduces volume on ALL datasets (up to 96% on mawi);\n\
         hierarchical reduces inter-node volume on all datasets, most on the\n\
         social graphs (com-LJ / Orkut / Pokec / sx-SO)."
    );
    write_csv("fig8_volume.csv", &csv);
}
