//! Fig. 9 — inter-process communication patterns before (column-based) and
//! after (joint row-column), as normalized volume heatmaps. The paper shows
//! del24 / mawi / EU: imbalanced patterns that the joint strategy both
//! shrinks and re-symmetrizes. nGPUs = 32.

use shiro::bench::{write_csv, BENCH_SCALE};
use shiro::comm::{self, Strategy};
use shiro::cover::Solver;
use shiro::metrics::Table;
use shiro::partition::{split_1d, RowPartition};
use shiro::sparse::dataset_by_name;

fn main() {
    let ranks = 32;
    let n_dense = 64;
    let mut table = Table::new(&[
        "dataset",
        "col max pair (KiB)",
        "joint max pair (KiB)",
        "col imbalance",
        "joint imbalance",
        "col asym",
        "joint asym",
    ]);
    for name in ["del24", "mawi", "EU"] {
        let spec = dataset_by_name(name).unwrap();
        let a = spec.generate(BENCH_SCALE);
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let col = comm::plan(&blocks, &part, Strategy::Column, None).volume_matrix(n_dense);
        let joint = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None)
            .volume_matrix(n_dense);
        write_csv(&format!("fig9_{name}_column.csv"), &col.to_csv(true));
        write_csv(&format!("fig9_{name}_joint.csv"), &joint.to_csv(true));
        println!("\n=== {name}: column-based (left) vs joint (right) ===");
        let left: Vec<&str> = Box::leak(col.to_ascii().into_boxed_str()).lines().collect();
        let right: Vec<&str> = Box::leak(joint.to_ascii().into_boxed_str()).lines().collect();
        for (l, r) in left.iter().zip(&right) {
            println!("{l}   |   {r}");
        }
        table.row(vec![
            name.into(),
            format!("{:.1}", col.max() as f64 / 1024.0),
            format!("{:.1}", joint.max() as f64 / 1024.0),
            format!("{:.2}", col.imbalance()),
            format!("{:.2}", joint.imbalance()),
            format!("{:.3}", col.asymmetry()),
            format!("{:.3}", joint.asymmetry()),
        ]);
    }
    println!("\nFig. 9 summary (nGPUs=32):\n{}", table.render());
    println!(
        "Paper shape: joint strategy removes the bright hot-spots (lower max\n\
         pair volume), balances load, and restores symmetry on the symmetric\n\
         datasets (del24, mawi: asymmetry → ~0)."
    );
}
