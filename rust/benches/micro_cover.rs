//! Microbenchmark: MWVC solver throughput (König vs Dinic vs greedy) on
//! off-diagonal blocks of increasing size — the §Perf hot path of the
//! offline planning phase (Tab. 3 preprocessing column).

use shiro::bench::write_csv;
use shiro::cover::{solve, Solver, Weights};
use shiro::metrics::Table;
use shiro::sparse::gen;
use shiro::util::timer::benchmark;

fn main() {
    let mut table = Table::new(&[
        "block", "nnz", "König (ms)", "Dinic (ms)", "greedy (ms)", "μ König", "μ greedy",
    ]);
    let mut csv = String::from("n,nnz,koenig_ms,dinic_ms,greedy_ms\n");
    for &n in &[256usize, 1024, 4096, 16384] {
        let a = gen::powerlaw(n, n * 8, 1.4, 7);
        let w = Weights::default();
        let sk = benchmark(1, 5, || solve(&a, Solver::Koenig, &w));
        let sd = benchmark(1, 5, || solve(&a, Solver::Dinic, &w));
        let sg = benchmark(1, 3, || solve(&a, Solver::Greedy, &w));
        let mu_k = solve(&a, Solver::Koenig, &w).mu();
        let mu_g = solve(&a, Solver::Greedy, &w).mu();
        table.row(vec![
            format!("{n}x{n}"),
            a.nnz().to_string(),
            format!("{:.3}", sk.median * 1e3),
            format!("{:.3}", sd.median * 1e3),
            format!("{:.3}", sg.median * 1e3),
            mu_k.to_string(),
            mu_g.to_string(),
        ]);
        csv.push_str(&format!(
            "{n},{},{:.6},{:.6},{:.6}\n",
            a.nnz(),
            sk.median * 1e3,
            sd.median * 1e3,
            sg.median * 1e3
        ));
    }
    println!("MWVC solver microbenchmark (powerlaw blocks):\n");
    println!("{}", table.render());
    println!("König must dominate Dinic at uniform weights; greedy is never\nbetter than optimal (μ greedy ≥ μ König).");
    write_csv("micro_cover.csv", &csv);
}
