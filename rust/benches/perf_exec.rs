//! §Perf harness: end-to-end executor hot path (the L3 target). Measures
//! wall time of one distributed SpMM (plan reused) on in-process ranks,
//! native kernel — the number the EXPERIMENTS.md §Perf iteration log tracks.

use shiro::bench::write_csv;
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::metrics::Table;
use shiro::sparse::gen;
use shiro::spmm::DistSpmm;
use shiro::topology::Topology;
use shiro::util::rng::Rng;
use shiro::util::timer::benchmark;

fn main() {
    let mut table = Table::new(&[
        "scenario", "median (ms)", "mean (ms)", "min (ms)", "runs",
    ]);
    let mut csv = String::from("scenario,median_ms,mean_ms,min_ms\n");
    let scenarios: Vec<(&str, shiro::sparse::Csr, usize, usize, bool)> = vec![
        (
            "rmat-16k x8 N32 hier",
            gen::rmat(1 << 14, (1 << 14) * 12, (0.55, 0.2, 0.19), false, 1),
            8,
            32,
            true,
        ),
        (
            "rmat-16k x8 N32 flat",
            gen::rmat(1 << 14, (1 << 14) * 12, (0.55, 0.2, 0.19), false, 1),
            8,
            32,
            false,
        ),
        (
            "web-16k x16 N64 hier",
            gen::powerlaw(1 << 14, (1 << 14) * 10, 1.45, 2),
            16,
            64,
            true,
        ),
        (
            "mesh-16k x8 N32 hier",
            gen::mesh2d(128, 3),
            8,
            32,
            true,
        ),
    ];
    for (name, a, ranks, n_dense, hier) in scenarios {
        let d = DistSpmm::plan(
            &a,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(ranks),
            hier,
        );
        let mut rng = Rng::new(7);
        let b = Dense::random(a.nrows, n_dense, &mut rng);
        let stats = benchmark(2, 8, || d.execute(&b, &NativeKernel));
        table.row(vec![
            name.into(),
            format!("{:.2}", stats.median * 1e3),
            format!("{:.2}", stats.mean * 1e3),
            format!("{:.2}", stats.min * 1e3),
            stats.n.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{:.4},{:.4},{:.4}\n",
            name,
            stats.median * 1e3,
            stats.mean * 1e3,
            stats.min * 1e3
        ));
    }
    println!("§Perf — executor end-to-end (native kernel):\n");
    println!("{}", table.render());
    write_csv("perf_exec.csv", &csv);
}
