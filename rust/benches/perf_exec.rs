//! §Perf harness: end-to-end executor hot path (the L3 target). Measures
//! wall time of one distributed SpMM (plan reused) on in-process ranks,
//! native kernel, with the overlapped pipeline ON vs OFF — the number the
//! EXPERIMENTS.md §Perf iteration log tracks and the CI perf-smoke job
//! gates.
//!
//! Flags (after `--`):
//!   --preset ci|full          smaller matrices + fewer runs for CI
//!   --check <baseline.json>   enforce committed min-speedup floors
//!                             (exit 1 on regression) — see
//!                             bench_results/baseline.json

use shiro::bench::{load_baseline, write_artifact, write_csv, Preset};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::dense::Dense;
use shiro::exec::kernel::NativeKernel;
use shiro::exec::ExecOpts;
use shiro::metrics::Table;
use shiro::sim::trace::exec_to_chrome_json;
use shiro::sparse::gen;
use shiro::spmm::{ExecRequest, PlanSpec};
use shiro::topology::Topology;
use shiro::util::cli::Args;
use shiro::util::rng::Rng;
use shiro::util::timer::benchmark;

struct Scenario {
    name: &'static str,
    a: shiro::sparse::Csr,
    ranks: usize,
    n_dense: usize,
}

fn scenarios(preset: Preset) -> Vec<Scenario> {
    // Skewed patterns (powerlaw, banded-hub) carry the overlap win: eager
    // posts let light ranks run their remote compute while the heavy rank
    // is still producing, which phase-ordered execution serializes.
    match preset {
        Preset::Full => vec![
            Scenario {
                name: "rmat-16k x8 N32",
                a: gen::rmat(1 << 14, (1 << 14) * 12, (0.55, 0.2, 0.19), false, 1),
                ranks: 8,
                n_dense: 32,
            },
            Scenario {
                name: "web-16k x16 N64",
                a: gen::powerlaw(1 << 14, (1 << 14) * 10, 1.45, 2),
                ranks: 16,
                n_dense: 64,
            },
            Scenario {
                name: "traffic-16k x8 N32",
                a: gen::banded_hub(1 << 14, 3, 6, 400, 3),
                ranks: 8,
                n_dense: 32,
            },
            Scenario {
                name: "mesh-16k x8 N32",
                a: gen::mesh2d(128, 3),
                ranks: 8,
                n_dense: 32,
            },
        ],
        Preset::Ci => vec![
            Scenario {
                name: "rmat-4k x8 N16",
                a: gen::rmat(1 << 12, (1 << 12) * 12, (0.55, 0.2, 0.19), false, 1),
                ranks: 8,
                n_dense: 16,
            },
            Scenario {
                name: "web-4k x8 N32",
                a: gen::powerlaw(1 << 12, (1 << 12) * 10, 1.45, 2),
                ranks: 8,
                n_dense: 32,
            },
        ],
    }
}

fn main() {
    let args = Args::from_env();
    let preset = Preset::from_args(&args);
    // CI runs on small, oversubscribed shared runners, so the ci preset
    // takes more samples per median to damp scheduler noise.
    let (warmup, runs) = match preset {
        Preset::Full => (2, 8),
        Preset::Ci => (2, 9),
    };
    let on = ExecOpts::default();
    let off = ExecOpts::sequential();

    let mut table = Table::new(&[
        "scenario", "overlap (ms)", "sequential (ms)", "speedup", "overlap frac", "runs",
    ]);
    let mut csv = String::from(
        "scenario,overlap_ms,sequential_ms,speedup,overlapped_fraction\n",
    );
    let mut speedups: Vec<(String, f64)> = Vec::new();
    let mut trace_written = false;

    for sc in scenarios(preset) {
        let d = PlanSpec::new(Topology::tsubame4(sc.ranks))
            .strategy(Strategy::Joint(Solver::Koenig))
            .plan(&sc.a);
        let mut rng = Rng::new(7);
        let b = Dense::random(sc.a.nrows, sc.n_dense, &mut rng);
        let run = |opts: &ExecOpts| {
            d.execute(&ExecRequest::spmm(&b).kernel(&NativeKernel).opts(*opts))
                .expect("thread-backend SpMM")
                .into_dense()
        };

        // Correctness gate: the two schedules must produce the same bits.
        let (c_on, stats_on) = run(&on);
        let (c_off, _) = run(&off);
        assert_eq!(c_on.data, c_off.data, "{}: overlap on/off results differ", sc.name);
        if !trace_written {
            write_artifact("perf_exec_trace.json", &exec_to_chrome_json(&stats_on));
            trace_written = true;
        }
        let frac = stats_on.overlap_window().overlapped_fraction();

        let t_on = benchmark(warmup, runs, || run(&on));
        let t_off = benchmark(warmup, runs, || run(&off));
        let speedup = t_off.median / t_on.median;
        table.row(vec![
            sc.name.into(),
            format!("{:.2}", t_on.median * 1e3),
            format!("{:.2}", t_off.median * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.0}%", frac * 100.0),
            t_on.n.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{:.4},{:.4},{:.4},{:.4}\n",
            sc.name,
            t_on.median * 1e3,
            t_off.median * 1e3,
            speedup,
            frac
        ));
        speedups.push((sc.name.to_string(), speedup));
    }

    println!("§Perf — executor end-to-end, overlapped pipeline vs phase-ordered:\n");
    println!("{}", table.render());
    write_csv("perf_exec.csv", &csv);

    if let Some(path) = args.get("check") {
        check_baseline(std::path::Path::new(path), &speedups);
    }
}

/// Enforce the committed perf-smoke floors: for every
/// `min_speedup/<scenario>` key in the baseline, the measured
/// overlap-vs-sequential speedup must stay within `tolerance` of it
/// (machine-independent ratios, not absolute milliseconds).
fn check_baseline(path: &std::path::Path, measured: &[(String, f64)]) {
    let baseline = match load_baseline(path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("perf-smoke: cannot read baseline {}: {e}", path.display());
            std::process::exit(1);
        }
    };
    let tolerance = baseline.get("tolerance").copied().unwrap_or(0.10);
    let mut failures = Vec::new();
    let mut checked = 0;
    for (key, &floor) in &baseline {
        let Some(scenario) = key.strip_prefix("min_speedup/") else {
            continue;
        };
        checked += 1;
        match measured.iter().find(|(n, _)| n == scenario) {
            None => failures.push(format!(
                "baseline scenario {scenario:?} was not measured — preset drift?"
            )),
            Some((_, speedup)) => {
                let need = floor * (1.0 - tolerance);
                if *speedup < need {
                    failures.push(format!(
                        "{scenario}: speedup {speedup:.3} < floor {floor} \
                         (tolerance {tolerance}, effective {need:.3})"
                    ));
                } else {
                    println!(
                        "perf-smoke OK: {scenario} speedup {speedup:.3} >= {need:.3}"
                    );
                }
            }
        }
    }
    if checked == 0 {
        failures.push("baseline has no min_speedup/ keys".to_string());
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("perf-smoke FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("perf-smoke: all {checked} baseline floors hold");
}
