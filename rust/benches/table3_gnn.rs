//! Tab. 3 — GNN training case study: for the three GNN benchmark analogs
//! (Papers, Mag240M, IGB260M), measure
//!   (a) simulated per-SpMM communication/total time at 128 GPUs for
//!       column-based (PyG-like), BCL, and SHIRO;
//!   (b) real executed training (small scale) with prep-overhead ratio.

use shiro::baselines::{simulate, System};
use shiro::bench::{write_csv, BENCH_SCALE};
use shiro::comm::Strategy;
use shiro::cover::Solver;
use shiro::exec::kernel::NativeKernel;
use shiro::gnn::{Gcn, GcnConfig, NativeDense};
use shiro::metrics::Table;
use shiro::sparse::datasets::gnn_datasets;
use shiro::spmm::PlanSpec;
use shiro::topology::Topology;

fn main() {
    let ranks = 128;
    let mut csv = String::from(
        "dataset,n_dense,pyg_ms,bcl_ms,shiro_ms,shiro_comm_ms,prep_ratio_pct\n",
    );
    let mut table = Table::new(&[
        "dataset",
        "N",
        "PyG-like (ms)",
        "BCL (ms)",
        "SHIRO (ms)",
        "SHIRO comm (ms)",
        "SpMM speedup vs PyG",
    ]);
    let mut prep_table = Table::new(&[
        "dataset",
        "epochs",
        "train (s)",
        "prep (s)",
        "prep ratio",
        "loss first→last",
        "steady allocs",
    ]);
    for spec in gnn_datasets() {
        // Paper: N=128 for Papers/Mag240M, 64 for IGB260M.
        let n_dense = if spec.name == "IGB260M" { 64 } else { 128 };
        let a = spec.generate(BENCH_SCALE);
        let topo = Topology::tsubame4(ranks);
        // (a) per-SpMM times at 128 simulated GPUs.
        let pyg = PlanSpec::new(topo.clone())
            .strategy(Strategy::Column)
            .flat()
            .plan(&a)
            .simulate(n_dense);
        let bcl = simulate(System::Bcl, &a, n_dense, &topo);
        let shiro = PlanSpec::new(topo.clone())
            .strategy(Strategy::Joint(Solver::Koenig))
            .plan(&a)
            .simulate(n_dense);
        table.row(vec![
            spec.name.into(),
            n_dense.to_string(),
            format!("{:.3}", pyg.total * 1e3),
            format!("{:.3}", bcl.total * 1e3),
            format!("{:.3}", shiro.total * 1e3),
            format!("{:.3}", shiro.comm_time * 1e3),
            format!("{:.2}x", pyg.total / shiro.total),
        ]);

        // (b) real training at executor scale (8 ranks) for prep ratio and
        // loss curve.
        let epochs = 20;
        let mut gcn = Gcn::new(
            &a,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(8),
            true,
            GcnConfig { epochs, log_every: epochs - 1, lr: 2.0, ..Default::default() },
        );
        let rep = gcn.train(&NativeKernel, &NativeDense);
        let ratio = 100.0 * rep.prep_secs / (rep.prep_secs + rep.train_secs);
        // Training runs on epoch-persistent sessions: all planning is in
        // the prep column, and the steady-state allocation count must be
        // zero (asserted hard by ablation_epoch_reuse --check).
        let steady_allocs =
            gcn.fwd.amortization().total_allocs() + gcn.bwd.amortization().total_allocs();
        prep_table.row(vec![
            spec.name.into(),
            epochs.to_string(),
            format!("{:.2}", rep.train_secs),
            format!("{:.3}", rep.prep_secs),
            format!("{ratio:.1}%"),
            format!(
                "{:.4} → {:.4}",
                rep.losses.first().unwrap().1,
                rep.losses.last().unwrap().1
            ),
            steady_allocs.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.2}\n",
            spec.name,
            n_dense,
            pyg.total * 1e3,
            bcl.total * 1e3,
            shiro.total * 1e3,
            shiro.comm_time * 1e3,
            ratio
        ));
    }
    println!("Tab. 3(a) — per-SpMM time at 128 simulated GPUs:\n");
    println!("{}", table.render());
    println!(
        "Paper shape: SHIRO beats PyG-like column SpMM by 1.2–1.6x and BCL by\n\
         3–6x; communication dominates SpMM time.\n"
    );
    println!("Tab. 3(b) — executed training (8 in-process ranks):\n");
    println!("{}", prep_table.render());
    println!("Paper shape: one-time MWVC preprocessing stays ≤ ~13% of training.");
    write_csv("table3_gnn.csv", &csv);
}
