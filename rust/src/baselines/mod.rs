//! The four baselines of the paper's evaluation (§7.1.5), reimplemented on
//! our simulator substrate so Fig. 7/11 compare *strategies* on identical
//! hardware assumptions (DESIGN.md §1 explains the approximations):
//!
//! - **CAGNET** — 1.5D stationary-A, sparsity-oblivious synchronous
//!   broadcast rounds (NCCL); suffers process idling and a cuSPARSE
//!   pathology (grid (1,1,1) launches) modeled as a kernel-efficiency knob.
//! - **SPA** — 1.5D stationary-A, column-based sparsity-aware alltoallv.
//! - **BCL** — 2D stationary-C, sparsity-oblivious, asynchronous one-sided
//!   RDMA (comm/compute overlap).
//! - **CoLa** — 1D stationary-A, column-based sparsity-aware with
//!   hierarchical B deduplication and fine-grained overlap.

use crate::comm::{self, Strategy, SZ_DT};
use crate::cover::Solver;
use crate::partition::{split_1d, Grid2D, RowPartition};
use crate::sim::{SimJob, SimMsg, SimReport, Stage};
use crate::sparse::Csr;
use crate::spmm::{DistSpmm, PlanSpec};
use crate::topology::Topology;

/// Which system to model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    Cagnet,
    Spa,
    Bcl,
    Cola,
    Shiro,
    /// SHIRO with the adaptive per-pair plan compiler ([`crate::plan`])
    /// instead of the global joint strategy.
    ShiroAdaptive,
}

impl System {
    pub fn name(&self) -> &'static str {
        match self {
            System::Cagnet => "CAGNET",
            System::Spa => "SPA",
            System::Bcl => "BCL",
            System::Cola => "CoLa",
            System::Shiro => "SHIRO",
            System::ShiroAdaptive => "SHIRO-A",
        }
    }

    pub fn all() -> [System; 6] {
        [
            System::Cagnet,
            System::Spa,
            System::Bcl,
            System::Cola,
            System::Shiro,
            System::ShiroAdaptive,
        ]
    }
}

/// Replication factor used by the 1.5D baselines (paper sets 4).
pub const REPLICATION: usize = 4;

/// CAGNET's effective compute slowdown from synchronous scheduling and the
/// cuSPARSE launch pathology observed in the paper (§7.2: "poor performance
/// stems from suboptimal cuSPARSE usage and synchronous broadcast-based
/// communication").
const CAGNET_KERNEL_PENALTY: f64 = 6.0;

/// Build a simulation job for `system` on matrix `a` with `n_dense` columns.
pub fn build_job(system: System, a: &Csr, n_dense: usize, topo: &Topology) -> SimJob {
    match system {
        System::Cagnet => cagnet_job(a, n_dense, topo),
        System::Spa => spa_job(a, n_dense, topo),
        System::Bcl => bcl_job(a, n_dense, topo),
        System::Cola => cola_job(a, n_dense, topo),
        System::Shiro => PlanSpec::new(topo.clone())
            .strategy(Strategy::Joint(Solver::Koenig))
            .plan(a)
            .sim_job(n_dense),
        System::ShiroAdaptive => PlanSpec::new(topo.clone())
            .strategy(Strategy::Adaptive)
            .n_dense(n_dense)
            .plan(a)
            .sim_job(n_dense),
    }
}

/// Simulate `system` end to end.
pub fn simulate(system: System, a: &Csr, n_dense: usize, topo: &Topology) -> SimReport {
    crate::sim::simulate(&build_job(system, a, n_dense, topo), topo)
}

/// CAGNET: p/c broadcast rounds; in round k the owner of B block k
/// broadcasts the *entire* block to every rank (sparsity-oblivious, Eq. 1),
/// synchronously, then all ranks compute against it.
fn cagnet_job(a: &Csr, n_dense: usize, topo: &Topology) -> SimJob {
    let p = topo.nranks;
    let c = REPLICATION.min(p);
    let rounds = (p / c).max(1);
    let round_part = RowPartition::balanced(a.nrows, rounds);
    let flops_per_round: Vec<f64> = {
        // Each rank computes A(:, round) · B_round for its own rows.
        let part = RowPartition::balanced(a.nrows, p);
        let blocks = split_1d(a, &part);
        (0..rounds)
            .map(|k| {
                let (c0, c1) = round_part.range(k);
                // nnz of global column stripe [c0,c1), max over ranks.
                let mut max_nnz = 0usize;
                for b in &blocks {
                    let (r0, _) = part.range(b.rank);
                    let _ = r0;
                    let mut nnz = 0usize;
                    nnz += count_nnz_in_cols(&b.diag, &part, b.rank, c0, c1);
                    for (q, blk) in b.off_diag.iter().enumerate() {
                        nnz += count_nnz_in_cols(blk, &part, q, c0, c1);
                    }
                    max_nnz = max_nnz.max(nnz);
                }
                2.0 * max_nnz as f64 * n_dense as f64
            })
            .collect()
    };
    let mut stages = Vec::new();
    for (k, flops) in flops_per_round.iter().enumerate() {
        let (c0, c1) = round_part.range(k);
        let bytes_full = ((c1 - c0) * n_dense) as u64 * SZ_DT;
        let owner = k % p;
        // Binomial-tree broadcast: log2(p) sub-stages; every rank receives
        // the full block once (bytes exact; time ≈ log2(p)·bytes/bw, close
        // to NCCL's pipelined tree at these message sizes).
        for (step, msgs) in binomial_tree(owner, p, bytes_full).into_iter().enumerate() {
            stages.push(Stage::comm(&format!("bcast round {k} step {step}"), msgs));
        }
        // Synchronous: compute happens only after the broadcast completes.
        let mut st = Stage::compute_only(
            &format!("round {k} spmm"),
            vec![
                flops * CAGNET_KERNEL_PENALTY / topo.compute_rate + topo.kernel_launch;
                p
            ],
        );
        st.overlap = false;
        stages.push(st);
    }
    SimJob { stages }
}

/// Binomial-tree broadcast from `root` over `p` ranks: returns the message
/// list of each of the ⌈log2 p⌉ steps.
fn binomial_tree(root: usize, p: usize, bytes: u64) -> Vec<Vec<SimMsg>> {
    let mut have: Vec<usize> = vec![root];
    let mut steps = Vec::new();
    let mut next = 1usize;
    while have.len() < p {
        let mut msgs = Vec::new();
        let mut new = Vec::new();
        for &src in &have {
            if have.len() + new.len() >= p {
                break;
            }
            // Deterministic target assignment: rank (src + next) mod p.
            let dst = (src + next) % p;
            if !have.contains(&dst) && !new.contains(&dst) {
                msgs.push(SimMsg { src, dst, bytes });
                new.push(dst);
            }
        }
        // Fallback: cover any stragglers the arithmetic pattern missed.
        if new.is_empty() {
            let dst = (0..p).find(|d| !have.contains(d)).unwrap();
            msgs.push(SimMsg { src: have[0], dst, bytes });
            new.push(dst);
        }
        have.extend_from_slice(&new);
        steps.push(msgs);
        next *= 2;
    }
    steps
}

fn count_nnz_in_cols(
    block: &Csr,
    part: &RowPartition,
    owner: usize,
    c0: usize,
    c1: usize,
) -> usize {
    // block columns are owner-local; translate global col range.
    let (o0, o1) = part.range(owner);
    let lo = c0.max(o0);
    let hi = c1.min(o1);
    if lo >= hi {
        return 0;
    }
    let (l0, l1) = (lo - o0, hi - o0);
    let mut nnz = 0;
    for r in 0..block.nrows {
        let cols = block.row_indices(r);
        nnz += cols.partition_point(|&c| (c as usize) < l1)
            - cols.partition_point(|&c| (c as usize) < l0);
    }
    nnz
}

/// SPA: column-based sparsity-aware alltoallv, flat network, with
/// replication clusters of size c acting as a single memory domain (pairs
/// inside a cluster are local).
fn spa_job(a: &Csr, n_dense: usize, topo: &Topology) -> SimJob {
    let p = topo.nranks;
    let c = REPLICATION.min(p);
    let part = RowPartition::balanced(a.nrows, p);
    let blocks = split_1d(a, &part);
    let plan = comm::plan(&blocks, &part, Strategy::Column, None);
    let d = DistSpmm {
        part,
        blocks,
        plan,
        sched: None,
        rep: None,
        topo: topo.clone(),
        prep_secs: 0.0,
    };
    let (pre, post) = d.compute_profile(n_dense);
    let mut msgs = Vec::new();
    for dst in 0..p {
        for src in 0..p {
            if src == dst || src / c == dst / c {
                continue; // same replication cluster: local copy
            }
            let bytes = d.plan.volume(dst, src, n_dense);
            if bytes > 0 {
                msgs.push(SimMsg { src, dst, bytes });
            }
        }
    }
    SimJob {
        stages: vec![
            Stage::compute_only("local", pre),
            Stage::comm("alltoallv", msgs),
            Stage::compute_only("remote", post),
        ],
    }
}

/// BCL: 2D stationary-C on a near-square grid (SUMMA-style k-rounds); in
/// round k, rank (i,j) pulls A tile (i,k) (sparse; bytes ∝ nnz) and B tile
/// (k,j) (dense) via one-sided RDMA and accumulates. Async: each round's
/// compute overlaps its pulls, but rounds serialize (pipeline depth 1),
/// which is what limits BCL's strong scaling past a couple of nodes.
fn bcl_job(a: &Csr, n_dense: usize, topo: &Topology) -> SimJob {
    let p = topo.nranks;
    let grid = Grid2D::near_square(p);
    let rpart = RowPartition::balanced(a.nrows, grid.pr);
    let cpart = RowPartition::balanced(a.ncols, grid.pc);
    let npart = RowPartition::balanced(n_dense, grid.pc);
    let mut stages = Vec::new();
    for k in 0..grid.pc {
        let mut msgs = Vec::new();
        let mut compute = vec![0.0; p];
        let (c0, c1) = cpart.range(k);
        for i in 0..grid.pr {
            let (r0, r1) = rpart.range(i);
            let tile = a.block(r0, r1, c0, c1);
            let tile_nnz = tile.nnz();
            for j in 0..grid.pc {
                let me = grid.rank(i, j);
                let (nc0, nc1) = npart.range(j);
                let nj = nc1 - nc0;
                // A tile (i,k): stored at rank (i,k); fetched unless local.
                if k != j && tile_nnz > 0 {
                    let a_bytes = tile_nnz as u64 * (SZ_DT + 4);
                    msgs.push(SimMsg { src: grid.rank(i, k), dst: me, bytes: a_bytes });
                }
                // B tile (k,j): owner approximated as rank (k mod pr, j).
                let b_owner = grid.rank(k % grid.pr, j);
                if b_owner != me {
                    let b_bytes = ((c1 - c0) * nj) as u64 * SZ_DT;
                    msgs.push(SimMsg { src: b_owner, dst: me, bytes: b_bytes });
                }
                compute[me] = 2.0 * tile_nnz as f64 * nj as f64 / topo.compute_rate
                    + topo.kernel_launch;
            }
        }
        let mut st = Stage::comm(&format!("2D round {k}"), msgs);
        st.compute = compute;
        st.overlap = true; // one-sided RDMA hides compute within the round
        stages.push(st);
    }
    SimJob { stages }
}

/// CoLa: 1D column-based plan + hierarchical B dedup (no row-based path,
/// no C aggregation), fine-grained RDMA overlap of compute and both stages.
fn cola_job(a: &Csr, n_dense: usize, topo: &Topology) -> SimJob {
    let d = PlanSpec::new(topo.clone()).strategy(Strategy::Column).plan(a);
    let (pre, post) = d.compute_profile(n_dense);
    let [mut s1, mut s2] = crate::sim::hier_comm_stages(d.sched.as_ref().unwrap(), n_dense);
    // Fine-grained overlap: local compute hides under stage I, remote
    // compute under stage II.
    s1.compute = pre;
    s1.overlap = true;
    s2.compute = post;
    s2.overlap = true;
    SimJob { stages: vec![s1, s2] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn matrix() -> Csr {
        // Large enough that the simulation is bandwidth-dominated (the
        // paper's regime) rather than latency-dominated.
        gen::rmat(8192, 130_000, (0.55, 0.2, 0.19), false, 11)
    }

    #[test]
    fn all_systems_produce_time() {
        let a = matrix();
        let topo = Topology::tsubame4(16);
        for sys in System::all() {
            let r = simulate(sys, &a, 32, &topo);
            assert!(r.total > 0.0, "{}", sys.name());
            assert!(r.total.is_finite());
        }
    }

    #[test]
    fn shiro_beats_baselines_at_scale() {
        // The paper's headline shape: at ≥8 ranks (multi-node), SHIRO wins.
        // Use the traffic-pattern (mawi-like) matrix — a structured sparse
        // workload where sparsity-aware planning matters (Fig. 7/8's
        // biggest gap).
        let a = gen::banded_hub(4096, 4, 8, 96, 11);
        let topo = Topology::tsubame4(32);
        let shiro = simulate(System::Shiro, &a, 32, &topo).total;
        for sys in [System::Cagnet, System::Spa, System::Bcl] {
            let t = simulate(sys, &a, 32, &topo).total;
            assert!(
                shiro < t,
                "SHIRO {shiro} !< {} {t}",
                sys.name()
            );
        }
    }

    #[test]
    fn cagnet_slowest() {
        // CAGNET's sync broadcast + kernel pathology makes it the slowest
        // baseline at scale (paper Fig. 7 ordering).
        let a = matrix();
        let topo = Topology::tsubame4(32);
        // N = 128 (Fig. 11's upper point) puts the comparison in the
        // bandwidth-dominated regime where the paper's ordering holds.
        let cagnet = simulate(System::Cagnet, &a, 128, &topo).total;
        for sys in [System::Spa, System::Cola, System::Shiro] {
            let t = simulate(sys, &a, 128, &topo).total;
            assert!(cagnet > t, "CAGNET {cagnet} !> {} {t}", sys.name());
        }
    }

    #[test]
    fn cola_competitive_single_node() {
        // ≤4 GPUs (one NVLink island): CoLa's overlap wins or ties —
        // paper §7.2: "our method is slower when using 4 or fewer GPUs".
        let a = matrix();
        let topo = Topology::tsubame4(4);
        let cola = simulate(System::Cola, &a, 32, &topo).total;
        let shiro = simulate(System::Shiro, &a, 32, &topo).total;
        assert!(
            cola < shiro * 1.05,
            "CoLa should be competitive at 4 ranks: cola {cola} shiro {shiro}"
        );
    }

    #[test]
    fn sparsity_aware_beats_oblivious_volume() {
        let a = matrix();
        let topo = Topology::tsubame4(16);
        let spa = simulate(System::Spa, &a, 32, &topo);
        let cagnet = simulate(System::Cagnet, &a, 32, &topo);
        let total_bytes =
            |r: &SimReport| r.inter_bytes + r.intra_bytes;
        assert!(total_bytes(&spa) < total_bytes(&cagnet));
    }
}
