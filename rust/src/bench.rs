//! Shared helpers for the figure/table bench binaries (`rust/benches/`).
//!
//! Each bench regenerates one table or figure from the paper's evaluation
//! (DESIGN.md §4 maps them); results are printed as tables and also written
//! as CSV under `bench_results/` for plotting.

use std::path::PathBuf;

/// Output directory for bench CSVs.
pub fn out_dir() -> PathBuf {
    let dir = PathBuf::from("bench_results");
    std::fs::create_dir_all(&dir).expect("create bench_results/");
    dir
}

/// Write a CSV artifact and echo its path.
pub fn write_csv(name: &str, content: &str) {
    let path = out_dir().join(name);
    std::fs::write(&path, content).expect("write csv");
    println!("[csv] {}", path.display());
}

/// Write a non-CSV artifact (chrome trace, report) under `bench_results/`.
pub fn write_artifact(name: &str, content: &str) {
    let path = out_dir().join(name);
    std::fs::write(&path, content).expect("write artifact");
    println!("[artifact] {}", path.display());
}

/// Bench size preset, selected with `--preset=ci|full` (default full).
/// `ci` shrinks datasets and repeat counts so the perf-smoke CI job
/// finishes in minutes while still exercising the measured pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    Ci,
    Full,
}

impl Preset {
    pub fn from_args(args: &crate::util::cli::Args) -> Preset {
        match args.get("preset") {
            Some("ci") => Preset::Ci,
            Some("full") | None => Preset::Full,
            Some(other) => {
                eprintln!("--preset expects ci|full, got {other:?}");
                std::process::exit(2);
            }
        }
    }
}

/// Minimal flat-JSON reader for the committed perf-smoke baseline
/// (`bench_results/baseline.json`): a single object mapping string keys to
/// numbers. Keys may contain any character except `"`; nesting, arrays,
/// and string values are out of scope (serde is unavailable offline —
/// DESIGN.md §1). Returns key → value.
pub fn load_baseline(
    path: &std::path::Path,
) -> std::io::Result<std::collections::BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)?;
    let mut map = std::collections::BTreeMap::new();
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if c != '"' {
            continue;
        }
        let mut key = String::new();
        for k in chars.by_ref() {
            if k == '"' {
                break;
            }
            key.push(k);
        }
        // Skip to the separating colon, then over whitespace.
        for s in chars.by_ref() {
            if s == ':' {
                break;
            }
        }
        while chars.peek().is_some_and(|n| n.is_whitespace()) {
            chars.next();
        }
        // Read the numeric value up to , } or whitespace.
        let mut num = String::new();
        while let Some(&n) = chars.peek() {
            if n.is_ascii_digit() || n == '.' || n == '-' || n == '+' || n == 'e' || n == 'E' {
                num.push(n);
                chars.next();
            } else {
                break;
            }
        }
        if let Ok(v) = num.parse::<f64>() {
            map.insert(key, v);
        }
    }
    Ok(map)
}

/// Integer-exact random matrix (values 1..=4): every product and partial
/// sum stays well inside f32's exact-integer range, so float addition is
/// associative on it and the serial reference is a legitimate *bitwise*
/// oracle. Shared by the determinism test suites and the bitwise bench
/// gates — one definition, one validity argument.
pub fn int_matrix(n: usize, nnz: usize, seed: u64) -> crate::sparse::Csr {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut coo = crate::sparse::Coo::new(n, n);
    for _ in 0..nnz {
        let r = rng.below(n);
        let c = rng.below(n);
        coo.push(r, c, (1 + rng.below(4)) as f32);
    }
    coo.to_csr()
}

/// Bench-scale defaults: small enough for minutes-long runs, large enough
/// to sit in the bandwidth-dominated regime the paper evaluates.
pub const BENCH_SCALE: f64 = 0.02;

/// The paper's fixed evaluation points.
pub const FIG7_RANKS: [usize; 7] = [2, 4, 8, 16, 32, 64, 128];
pub const ABLATION_RANKS: usize = 32;

/// Format seconds as milliseconds with 3 decimals (bench table unit).
pub fn ms(secs: f64) -> String {
    format!("{:.3}", secs * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_format() {
        assert_eq!(ms(0.001234), "1.234");
    }

    #[test]
    fn preset_parses() {
        let parse = |v: &[&str]| {
            Preset::from_args(&crate::util::cli::Args::parse(
                v.iter().map(|s| s.to_string()),
            ))
        };
        assert_eq!(parse(&[]), Preset::Full);
        assert_eq!(parse(&["--preset=ci"]), Preset::Ci);
        assert_eq!(parse(&["--preset", "full"]), Preset::Full);
    }

    #[test]
    fn baseline_roundtrip() {
        let dir = std::env::temp_dir().join("shiro_baseline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("baseline.json");
        std::fs::write(
            &p,
            "{\n  \"tolerance\": 0.15,\n  \"min_speedup/web x16 N64\": 1.0,\n  \
             \"note_ms\": -2.5e-1\n}\n",
        )
        .unwrap();
        let m = load_baseline(&p).unwrap();
        assert_eq!(m.len(), 3);
        assert!((m["tolerance"] - 0.15).abs() < 1e-12);
        assert!((m["min_speedup/web x16 N64"] - 1.0).abs() < 1e-12);
        assert!((m["note_ms"] + 0.25).abs() < 1e-12);
    }

    #[test]
    fn baseline_missing_file_errors() {
        assert!(load_baseline(std::path::Path::new("/nonexistent/b.json")).is_err());
    }
}
