//! Shared helpers for the figure/table bench binaries (`rust/benches/`).
//!
//! Each bench regenerates one table or figure from the paper's evaluation
//! (DESIGN.md §4 maps them); results are printed as tables and also written
//! as CSV under `bench_results/` for plotting.

use std::path::PathBuf;

/// Output directory for bench CSVs.
pub fn out_dir() -> PathBuf {
    let dir = PathBuf::from("bench_results");
    std::fs::create_dir_all(&dir).expect("create bench_results/");
    dir
}

/// Write a CSV artifact and echo its path.
pub fn write_csv(name: &str, content: &str) {
    let path = out_dir().join(name);
    std::fs::write(&path, content).expect("write csv");
    println!("[csv] {}", path.display());
}

/// Bench-scale defaults: small enough for minutes-long runs, large enough
/// to sit in the bandwidth-dominated regime the paper evaluates.
pub const BENCH_SCALE: f64 = 0.02;

/// The paper's fixed evaluation points.
pub const FIG7_RANKS: [usize; 7] = [2, 4, 8, 16, 32, 64, 128];
pub const ABLATION_RANKS: usize = 32;

/// Format seconds as milliseconds with 3 decimals (bench table unit).
pub fn ms(secs: f64) -> String {
    format!("{:.3}", secs * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_format() {
        assert_eq!(ms(0.001234), "1.234");
    }
}
