//! Communication planning (paper §3.1, §5.1): turn per-pair off-diagonal
//! blocks into a [`CommPlan`] that says exactly which B rows and partial C
//! rows cross each process pair, under each of the four strategies.
//!
//! Planning is the *offline preprocessing* phase (workflow steps 1–2); the
//! plan is reused across SpMM calls with the same sparsity pattern.

pub mod validate;
pub mod weighted;

use crate::cover::{self, CoverSolution, Solver, Weights};
use crate::partition::{LocalBlocks, RowPartition};
use crate::sparse::Csr;

/// Element size (f32) used in all volume formulas (sz_dt in Tab. 1).
pub const SZ_DT: u64 = 4;

/// Communication strategy (paper §3.1 taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Sparsity-oblivious: fetch whole remote row blocks of B (Eq. 1).
    Block,
    /// Column-based sparsity-aware: fetch needed B rows (Eq. 2).
    Column,
    /// Row-based sparsity-aware: receive partial C rows (Eq. 3).
    Row,
    /// SHIRO's joint row-column strategy via MWVC (Eq. 9).
    Joint(Solver),
    /// Per-pair cost-model-driven selection among the four shapes above
    /// ([`crate::plan`]): each (q→p) pair gets the cheapest candidate under
    /// the topology's α-β(+compute) model.
    Adaptive,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Block => "block",
            Strategy::Column => "column",
            Strategy::Row => "row",
            Strategy::Joint(Solver::Koenig) => "joint",
            Strategy::Joint(Solver::Dinic) => "joint-weighted",
            Strategy::Joint(Solver::Greedy) => "joint-greedy",
            Strategy::Joint(_) => "joint-degenerate",
            Strategy::Adaptive => "adaptive",
        }
    }

    /// Inverse of [`Strategy::name`] for config/CLI parsing.
    pub fn by_name(name: &str) -> Option<Strategy> {
        match name {
            "block" => Some(Strategy::Block),
            "column" => Some(Strategy::Column),
            "row" => Some(Strategy::Row),
            "joint" | "joint-koenig" => Some(Strategy::Joint(Solver::Koenig)),
            "joint-weighted" | "joint-dinic" => Some(Strategy::Joint(Solver::Dinic)),
            "joint-greedy" => Some(Strategy::Joint(Solver::Greedy)),
            "adaptive" => Some(Strategy::Adaptive),
            _ => None,
        }
    }
}

/// Plan for the data flowing from source rank q to destination rank p.
///
/// Index spaces: `b_rows` are local to q's B block; `c_rows` are local to
/// p's C block; `a_row_part` / `a_col_part` keep the off-diagonal block's
/// local coordinates (rows local to p, cols local to q).
#[derive(Clone, Debug, Default)]
pub struct PairPlan {
    /// B rows (q-local) that q sends to p — column-based portion.
    pub b_rows: Vec<u32>,
    /// C rows (p-local) for which q computes and sends partial results —
    /// row-based portion.
    pub c_rows: Vec<u32>,
    /// Nonzeros of `A^(p,q)` served row-based. Shipped to q offline; at
    /// run time q computes `a_row_part · B^(q,:)` restricted to `c_rows`.
    pub a_row_part: Csr,
    /// Nonzeros served column-based; stays at p, multiplied against the
    /// received `b_rows`.
    pub a_col_part: Csr,
    /// Whether the whole remote block is sent (sparsity-oblivious mode);
    /// volume then follows Eq. 1 regardless of `b_rows`.
    pub full_block: bool,
    /// `a_col_part` with columns remapped to *positions in `b_rows`*:
    /// multiplies directly against the packed received B rows, avoiding a
    /// zero-buffer scatter on the hot path (§Perf opt-1).
    pub a_col_compact: Csr,
    /// `a_row_part` restricted to `c_rows` (rows reindexed to positions in
    /// `c_rows`): the exact operand of the remote partial SpMM, avoiding a
    /// per-call `select_rows` (§Perf opt-1).
    pub a_row_compact: Csr,
}

impl PairPlan {
    /// Build a pair plan from the split parts, deriving the packed compact
    /// operands used by the executor hot path.
    pub fn from_parts(a_row_part: Csr, a_col_part: Csr, full_block: bool) -> PairPlan {
        let c_rows = a_row_part.nonempty_rows();
        let b_rows = if full_block {
            (0..a_col_part.ncols as u32).collect::<Vec<u32>>()
        } else {
            a_col_part.nonempty_cols()
        };
        // Column remap: global col -> position in b_rows.
        let mut pos = vec![u32::MAX; a_col_part.ncols];
        for (k, &c) in b_rows.iter().enumerate() {
            pos[c as usize] = k as u32;
        }
        let a_col_compact = Csr {
            nrows: a_col_part.nrows,
            ncols: b_rows.len(),
            indptr: a_col_part.indptr.clone(),
            indices: a_col_part
                .indices
                .iter()
                .map(|&c| pos[c as usize])
                .collect(),
            data: a_col_part.data.clone(),
        };
        let a_row_compact = a_row_part.select_rows(&c_rows);
        PairPlan {
            b_rows,
            c_rows,
            a_row_part,
            a_col_part,
            full_block,
            a_col_compact,
            a_row_compact,
        }
    }
}

impl PairPlan {
    /// Mirror this pair plan for the transposed matrix: the (q→p) plan for
    /// A becomes the (p→q) plan for Aᵀ. Transposing an off-diagonal block
    /// swaps its row and column index spaces, so a cover of the block maps
    /// to a cover of the transposed block with the roles exchanged —
    /// `a_row_part ↔ a_col_partᵀ`, and therefore `c_rows ↔ b_rows`. The
    /// MWVC solution (and its optimality) carries over verbatim, and
    /// per-pair volume is preserved exactly. Sparsity-oblivious
    /// (`full_block`) pairs stay sparsity-oblivious — the whole transposed
    /// block ships column-based, matching Eq. 1 on the transposed operand;
    /// their volume swaps ends (`len(q) ↔ len(p)`), preserving the total.
    pub fn transpose(&self) -> PairPlan {
        if self.full_block {
            let t = self.a_col_part.transpose();
            return PairPlan::from_parts(Csr::zeros(t.nrows, t.ncols), t, true);
        }
        if self.a_row_part.nnz() == 0 && self.a_col_part.nnz() == 0 {
            // Empty pairs mirror to the canonical empty plan (the planner
            // emits `PairPlan::default()` for them, not shaped zeros).
            return PairPlan::default();
        }
        PairPlan::from_parts(
            self.a_col_part.transpose(),
            self.a_row_part.transpose(),
            false,
        )
    }

    /// Number of rows crossing the q→p link (B rows + C rows).
    pub fn rows_transferred(&self, k_src: usize) -> u64 {
        if self.full_block {
            k_src as u64
        } else {
            (self.b_rows.len() + self.c_rows.len()) as u64
        }
    }

    /// Volume in bytes for N dense columns (Eqs. 1–3, 9).
    pub fn volume_bytes(&self, k_src: usize, n_dense: usize) -> u64 {
        self.rows_transferred(k_src) * n_dense as u64 * SZ_DT
    }
}

/// The complete communication plan for one distributed SpMM.
#[derive(Clone, Debug)]
pub struct CommPlan {
    pub nranks: usize,
    pub strategy: Strategy,
    /// `pairs[p][q]` describes flow q → p. Diagonal entries are empty.
    pub pairs: Vec<Vec<PairPlan>>,
    /// Rows owned by each rank (B/C block heights), for Eq. 1 volumes.
    pub block_rows: Vec<usize>,
}

impl CommPlan {
    /// Volume in bytes crossing q→p for N dense columns.
    pub fn volume(&self, p: usize, q: usize, n_dense: usize) -> u64 {
        self.pairs[p][q].volume_bytes(self.block_rows[q], n_dense)
    }

    /// Total communication volume across all pairs (Fig. 8a metric).
    pub fn total_volume(&self, n_dense: usize) -> u64 {
        let mut v = 0;
        for p in 0..self.nranks {
            for q in 0..self.nranks {
                if p != q {
                    v += self.volume(p, q, n_dense);
                }
            }
        }
        v
    }

    /// Mirror the whole plan for Aᵀ: `pairs_t[p][q] = pairs[q][p].transpose()`
    /// ([`PairPlan::transpose`]). No cover is re-solved and no cost model is
    /// re-evaluated — the mirrored plan inherits the forward plan's covers
    /// with row/column roles exchanged, and its total volume is identical.
    /// Only meaningful in the 1D square-SpMM setting, where one partition
    /// serves both the rows and the columns (enforced by `split_1d`), so
    /// `block_rows` carries over unchanged.
    pub fn transpose(&self) -> CommPlan {
        let pairs = (0..self.nranks)
            .map(|p| {
                (0..self.nranks)
                    .map(|q| {
                        if p == q {
                            PairPlan::default()
                        } else {
                            self.pairs[q][p].transpose()
                        }
                    })
                    .collect()
            })
            .collect();
        CommPlan {
            nranks: self.nranks,
            strategy: self.strategy,
            pairs,
            block_rows: self.block_rows.clone(),
        }
    }

    /// Per-pair volume matrix `[dst][src]` (Fig. 9 heatmaps).
    pub fn volume_matrix(&self, n_dense: usize) -> crate::metrics::VolumeMatrix {
        let mut m = crate::metrics::VolumeMatrix::zeros(self.nranks);
        for p in 0..self.nranks {
            for q in 0..self.nranks {
                if p != q {
                    m.set(q, p, self.volume(p, q, n_dense));
                }
            }
        }
        m
    }
}

/// Optional per-rank-pair weight model for the weighted (Dinic) solver:
/// returns (row_weight, col_weight) unit costs for flow q→p.
pub type PairWeightFn<'a> = dyn Fn(usize, usize) -> (u64, u64) + 'a;

/// Build the communication plan for all pairs from each rank's local blocks.
///
/// `blocks[p].off_diag[q]` must be `A^(p,q)` with q-local column indices
/// (as produced by [`crate::partition::split_1d`]).
pub fn plan(
    blocks: &[LocalBlocks],
    part: &RowPartition,
    strategy: Strategy,
    pair_weights: Option<&PairWeightFn>,
) -> CommPlan {
    let nranks = part.nparts;
    if strategy == Strategy::Adaptive {
        // Without an explicit topology the adaptive compiler assumes a flat
        // network (uniform link costs) and the default planning width.
        // Callers that know the real topology or N should use
        // `plan::compile` (or `PlanSpec` with explicit params) instead; custom
        // pair weights only apply to the weighted Dinic solver.
        assert!(
            pair_weights.is_none(),
            "pair_weights are not consumed by Strategy::Adaptive — use plan::compile"
        );
        let topo = crate::topology::Topology::flat(nranks, 25e9);
        return crate::plan::compile(blocks, part, &topo, &crate::plan::PlanParams::default())
            .plan;
    }
    let mut pairs: Vec<Vec<PairPlan>> = Vec::with_capacity(nranks);
    for p in 0..nranks {
        let mut row = Vec::with_capacity(nranks);
        for q in 0..nranks {
            if p == q {
                row.push(PairPlan::default());
                continue;
            }
            let block = &blocks[p].off_diag[q];
            row.push(plan_pair(block, strategy, p, q, pair_weights));
        }
        pairs.push(row);
    }
    CommPlan {
        nranks,
        strategy,
        pairs,
        block_rows: (0..nranks).map(|p| part.len(p)).collect(),
    }
}

/// Build the plan for one (q→p) off-diagonal block under a fixed strategy.
/// Public so the adaptive compiler ([`crate::plan`]) evaluates candidates
/// through the exact same construction path as the fixed-strategy planner.
pub fn plan_pair(
    block: &Csr,
    strategy: Strategy,
    p: usize,
    q: usize,
    pair_weights: Option<&PairWeightFn>,
) -> PairPlan {
    if block.nnz() == 0 && strategy != Strategy::Block {
        return PairPlan::default();
    }
    match strategy {
        Strategy::Block => PairPlan::from_parts(
            Csr::zeros(block.nrows, block.ncols),
            block.clone(),
            true,
        ),
        Strategy::Column => {
            let sol = CoverSolution {
                rows: Vec::new(),
                cols: block.nonempty_cols(),
                cost: 0,
            };
            from_solution(block, sol)
        }
        Strategy::Row => {
            let sol = CoverSolution {
                rows: block.nonempty_rows(),
                cols: Vec::new(),
                cost: 0,
            };
            from_solution(block, sol)
        }
        Strategy::Joint(solver) => {
            let weights = match (solver, pair_weights) {
                (Solver::Dinic, Some(wf)) => {
                    let (rw, cw) = wf(p, q);
                    Weights {
                        row: Some(vec![rw; block.nrows]),
                        col: Some(vec![cw; block.ncols]),
                    }
                }
                _ => Weights::default(),
            };
            let sol = cover::solve(block, solver, &weights);
            from_solution(block, sol)
        }
        Strategy::Adaptive => unreachable!("Adaptive is expanded in plan()/plan::compile"),
    }
}

fn from_solution(block: &Csr, sol: CoverSolution) -> PairPlan {
    let (a_row_part, a_col_part) = cover::split_by_cover(block, &sol);
    // from_parts prunes selected vertices that ended up with no assigned
    // nonzeros (possible when both endpoints of an edge were selected) by
    // recomputing the used rows/cols from the split parts.
    PairPlan::from_parts(a_row_part, a_col_part, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::split_1d;
    use crate::sparse::gen;

    fn setup(n: usize, ranks: usize, seed: u64) -> (Csr, RowPartition, Vec<LocalBlocks>) {
        let a = gen::rmat(n, n * 8, (0.55, 0.2, 0.19), false, seed);
        let part = RowPartition::balanced(n, ranks);
        let blocks = split_1d(&a, &part);
        (a, part, blocks)
    }

    /// Every nonzero of every off-diagonal block must be covered: either its
    /// row is in c_rows (row-based) or its column is in b_rows (col-based).
    fn assert_plan_covers(plan: &CommPlan, blocks: &[LocalBlocks]) {
        for p in 0..plan.nranks {
            for q in 0..plan.nranks {
                if p == q {
                    continue;
                }
                let block = &blocks[p].off_diag[q];
                let pair = &plan.pairs[p][q];
                assert_eq!(
                    pair.a_row_part.nnz() + pair.a_col_part.nnz(),
                    block.nnz(),
                    "({p},{q}) nnz split"
                );
                if pair.full_block {
                    continue;
                }
                let crows: std::collections::HashSet<u32> =
                    pair.c_rows.iter().copied().collect();
                let brows: std::collections::HashSet<u32> =
                    pair.b_rows.iter().copied().collect();
                for r in 0..block.nrows {
                    for &c in block.row_indices(r) {
                        assert!(
                            crows.contains(&(r as u32)) || brows.contains(&c),
                            "({p},{q}) nnz ({r},{c}) uncovered"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn block_strategy_volume_is_eq1() {
        let (_, part, blocks) = setup(64, 4, 1);
        let plan = plan(&blocks, &part, Strategy::Block, None);
        // V = K · N · sz for every pair.
        let n_dense = 8;
        for p in 0..4 {
            for q in 0..4 {
                if p != q {
                    assert_eq!(
                        plan.volume(p, q, n_dense),
                        part.len(q) as u64 * n_dense as u64 * SZ_DT
                    );
                }
            }
        }
    }

    #[test]
    fn column_strategy_matches_eq2() {
        let (_, part, blocks) = setup(64, 4, 2);
        let cp = plan(&blocks, &part, Strategy::Column, None);
        for p in 0..4 {
            for q in 0..4 {
                if p == q {
                    continue;
                }
                let cols = blocks[p].off_diag[q].nonempty_cols();
                assert_eq!(cp.pairs[p][q].b_rows, cols);
                assert!(cp.pairs[p][q].c_rows.is_empty());
            }
        }
        assert_plan_covers(&cp, &blocks);
    }

    #[test]
    fn row_strategy_matches_eq3() {
        let (_, part, blocks) = setup(64, 4, 3);
        let rp = plan(&blocks, &part, Strategy::Row, None);
        for p in 0..4 {
            for q in 0..4 {
                if p == q {
                    continue;
                }
                let rows = blocks[p].off_diag[q].nonempty_rows();
                assert_eq!(rp.pairs[p][q].c_rows, rows);
                assert!(rp.pairs[p][q].b_rows.is_empty());
            }
        }
        assert_plan_covers(&rp, &blocks);
    }

    #[test]
    fn joint_dominates_both_single_strategies() {
        // Dominance (§5.4.1): joint volume ≤ min(column, row) per pair and
        // in total.
        let (_, part, blocks) = setup(128, 8, 4);
        let jp = plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let cp = plan(&blocks, &part, Strategy::Column, None);
        let rp = plan(&blocks, &part, Strategy::Row, None);
        assert_plan_covers(&jp, &blocks);
        let n = 32;
        for p in 0..8 {
            for q in 0..8 {
                if p != q {
                    assert!(jp.volume(p, q, n) <= cp.volume(p, q, n));
                    assert!(jp.volume(p, q, n) <= rp.volume(p, q, n));
                }
            }
        }
        assert!(jp.total_volume(n) <= cp.total_volume(n).min(rp.total_volume(n)));
        assert!(cp.total_volume(n) <= {
            let bp = plan(&blocks, &part, Strategy::Block, None);
            bp.total_volume(n)
        });
    }

    #[test]
    fn joint_strictly_better_on_web_pattern() {
        // Power-law with hubs on both sides: joint must beat column-only
        // (paper's high-reduction scenario, Fig. 5 Pattern 4).
        let a = gen::powerlaw(256, 4000, 1.4, 5);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let jp = plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let cp = plan(&blocks, &part, Strategy::Column, None);
        let n = 32;
        assert!(
            jp.total_volume(n) < cp.total_volume(n),
            "joint {} !< column {}",
            jp.total_volume(n),
            cp.total_volume(n)
        );
    }

    #[test]
    fn volume_matrix_diag_zero() {
        let (_, part, blocks) = setup(64, 4, 6);
        let p = plan(&blocks, &part, Strategy::Column, None);
        let m = p.volume_matrix(8);
        for i in 0..4 {
            assert_eq!(m.get(i, i), 0);
        }
        assert_eq!(m.total(), p.total_volume(8));
    }

    #[test]
    fn weighted_plan_shifts_to_cheaper_side() {
        let (_, part, blocks) = setup(64, 4, 7);
        // Make rows (C transfers) free-ish and columns expensive: plan
        // should use row-based almost everywhere.
        let wf = |_p: usize, _q: usize| (1u64, 1000u64);
        let jp = plan(&blocks, &part, Strategy::Joint(Solver::Dinic), Some(&wf));
        assert_plan_covers(&jp, &blocks);
        let total_b: usize = jp
            .pairs
            .iter()
            .flatten()
            .map(|pp| pp.b_rows.len())
            .sum();
        let total_c: usize = jp
            .pairs
            .iter()
            .flatten()
            .map(|pp| pp.c_rows.len())
            .sum();
        assert!(total_c > total_b * 5, "c={total_c} b={total_b}");
    }

    #[test]
    fn transposed_plan_covers_transposed_blocks_and_preserves_volume() {
        // The mirror must be a *valid* plan for Aᵀ under the same partition
        // — every strategy, including the sparsity-oblivious one — and the
        // per-pair volume must carry over exactly (the cover is reused, not
        // re-solved).
        let a = gen::rmat(96, 1100, (0.6, 0.18, 0.18), false, 11);
        let part = RowPartition::balanced(96, 6);
        let blocks = split_1d(&a, &part);
        let at = a.transpose();
        let blocks_t = split_1d(&at, &part);
        let n = 16;
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            let fwd = plan(&blocks, &part, strategy, None);
            let bwd = fwd.transpose();
            assert_eq!(
                crate::comm::validate::validate(&bwd, &blocks_t),
                Ok(()),
                "{strategy:?}: mirrored plan invalid for Aᵀ"
            );
            assert_plan_covers(&bwd, &blocks_t);
            assert_eq!(
                fwd.total_volume(n),
                bwd.total_volume(n),
                "{strategy:?}: mirroring changed the volume"
            );
            for p in 0..6 {
                for q in 0..6 {
                    if p == q || fwd.pairs[q][p].full_block {
                        // Sparsity-oblivious pairs stay column-based
                        // whole-block sends in both directions — no role
                        // exchange to assert.
                        continue;
                    }
                    // Roles swap: the mirrored pair serves row-based what
                    // the forward pair served column-based, and vice versa.
                    assert_eq!(bwd.pairs[p][q].c_rows, fwd.pairs[q][p].b_rows);
                    assert_eq!(bwd.pairs[p][q].b_rows, fwd.pairs[q][p].c_rows);
                }
            }
        }
    }

    #[test]
    fn double_transpose_is_identity_on_roles() {
        let (_, part, blocks) = setup(64, 4, 9);
        let fwd = plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let back = fwd.transpose().transpose();
        for p in 0..4 {
            for q in 0..4 {
                assert_eq!(back.pairs[p][q].b_rows, fwd.pairs[p][q].b_rows);
                assert_eq!(back.pairs[p][q].c_rows, fwd.pairs[p][q].c_rows);
                assert_eq!(back.pairs[p][q].a_row_part, fwd.pairs[p][q].a_row_part);
                assert_eq!(back.pairs[p][q].a_col_part, fwd.pairs[p][q].a_col_part);
            }
        }
    }

    #[test]
    fn empty_offdiag_pairs_empty_plan() {
        // Block-diagonal matrix → zero communication for sparsity-aware.
        let a = Csr::eye(32);
        let part = RowPartition::balanced(32, 4);
        let blocks = split_1d(&a, &part);
        let jp = plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        assert_eq!(jp.total_volume(16), 0);
        let cp = plan(&blocks, &part, Strategy::Column, None);
        assert_eq!(cp.total_volume(16), 0);
        // Block strategy still ships everything (sparsity-oblivious).
        let bp = plan(&blocks, &part, Strategy::Block, None);
        assert!(bp.total_volume(16) > 0);
    }
}
