//! Communication-plan validation: structural invariants checked before a
//! plan is trusted by the executor. Used by tests (failure injection) and
//! by `PlanSpec::plan` in debug builds.

use crate::comm::CommPlan;
use crate::partition::LocalBlocks;
use std::fmt;

#[derive(Debug, PartialEq)]
pub enum PlanError {
    NnzMismatch { p: usize, q: usize, got: usize, want: usize },
    UncoveredColumn { p: usize, q: usize, c: u32 },
    UncoveredRow { p: usize, q: usize, r: u32 },
    UnsortedBRows { p: usize, q: usize },
    UnsortedCRows { p: usize, q: usize },
    BRowOutOfRange { p: usize, q: usize, row: u32, len: usize },
    CRowOutOfRange { p: usize, q: usize, row: u32, len: usize },
    RankMismatch { got: usize, want: usize },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::NnzMismatch { p, q, got, want } => {
                write!(f, "pair ({p},{q}): nnz split {got} != block nnz {want}")
            }
            PlanError::UncoveredColumn { p, q, c } => {
                write!(f, "pair ({p},{q}): column {c} used by a_col_part but missing from b_rows")
            }
            PlanError::UncoveredRow { p, q, r } => {
                write!(f, "pair ({p},{q}): row {r} used by a_row_part but missing from c_rows")
            }
            PlanError::UnsortedBRows { p, q } => {
                write!(f, "pair ({p},{q}): b_rows not sorted/unique")
            }
            PlanError::UnsortedCRows { p, q } => {
                write!(f, "pair ({p},{q}): c_rows not sorted/unique")
            }
            PlanError::BRowOutOfRange { p, q, row, len } => {
                write!(f, "pair ({p},{q}): b_row {row} out of range {len}")
            }
            PlanError::CRowOutOfRange { p, q, row, len } => {
                write!(f, "pair ({p},{q}): c_row {row} out of range {len}")
            }
            PlanError::RankMismatch { got, want } => {
                write!(f, "plan has {got} ranks, blocks have {want}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

fn sorted_unique(v: &[u32]) -> bool {
    v.windows(2).all(|w| w[0] < w[1])
}

/// Validate a plan against the blocks it was derived from.
pub fn validate(plan: &CommPlan, blocks: &[LocalBlocks]) -> Result<(), PlanError> {
    if plan.nranks != blocks.len() {
        return Err(PlanError::RankMismatch { got: plan.nranks, want: blocks.len() });
    }
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p == q {
                continue;
            }
            let pair = &plan.pairs[p][q];
            let block = &blocks[p].off_diag[q];
            if !sorted_unique(&pair.b_rows) {
                return Err(PlanError::UnsortedBRows { p, q });
            }
            if !sorted_unique(&pair.c_rows) {
                return Err(PlanError::UnsortedCRows { p, q });
            }
            let k_src = plan.block_rows[q];
            if let Some(&row) = pair.b_rows.iter().find(|&&r| r as usize >= k_src) {
                return Err(PlanError::BRowOutOfRange { p, q, row, len: k_src });
            }
            let m_dst = plan.block_rows[p];
            if let Some(&row) = pair.c_rows.iter().find(|&&r| r as usize >= m_dst) {
                return Err(PlanError::CRowOutOfRange { p, q, row, len: m_dst });
            }
            let got = pair.a_row_part.nnz() + pair.a_col_part.nnz();
            if got != block.nnz() {
                return Err(PlanError::NnzMismatch { p, q, got, want: block.nnz() });
            }
            if !pair.full_block {
                for r in 0..pair.a_col_part.nrows {
                    for &c in pair.a_col_part.row_indices(r) {
                        if pair.b_rows.binary_search(&c).is_err() {
                            return Err(PlanError::UncoveredColumn { p, q, c });
                        }
                    }
                }
                for &r in pair.a_row_part.nonempty_rows().iter() {
                    if pair.c_rows.binary_search(&r).is_err() {
                        return Err(PlanError::UncoveredRow { p, q, r });
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;

    fn setup() -> (CommPlan, Vec<LocalBlocks>) {
        let a = gen::rmat(128, 1500, (0.5, 0.2, 0.2), false, 1);
        let part = RowPartition::balanced(128, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        (plan, blocks)
    }

    #[test]
    fn valid_plan_passes() {
        let (plan, blocks) = setup();
        assert_eq!(validate(&plan, &blocks), Ok(()));
    }

    #[test]
    fn injected_missing_b_row_detected() {
        let (mut plan, blocks) = setup();
        // Find a pair with b_rows and drop one (failure injection).
        'outer: for p in 0..8 {
            for q in 0..8 {
                if p != q && plan.pairs[p][q].b_rows.len() > 1 {
                    plan.pairs[p][q].b_rows.remove(0);
                    break 'outer;
                }
            }
        }
        assert!(matches!(
            validate(&plan, &blocks),
            Err(PlanError::UncoveredColumn { .. })
        ));
    }

    #[test]
    fn injected_unsorted_rows_detected() {
        let (mut plan, blocks) = setup();
        'outer: for p in 0..8 {
            for q in 0..8 {
                if p != q && plan.pairs[p][q].c_rows.len() > 1 {
                    plan.pairs[p][q].c_rows.swap(0, 1);
                    break 'outer;
                }
            }
        }
        assert!(matches!(
            validate(&plan, &blocks),
            Err(PlanError::UnsortedCRows { .. }) | Err(PlanError::UncoveredRow { .. })
        ));
    }

    #[test]
    fn injected_out_of_range_detected() {
        let (mut plan, blocks) = setup();
        'outer: for p in 0..8 {
            for q in 0..8 {
                if p != q && !plan.pairs[p][q].b_rows.is_empty() {
                    plan.pairs[p][q].b_rows.push(10_000);
                    break 'outer;
                }
            }
        }
        assert!(matches!(
            validate(&plan, &blocks),
            Err(PlanError::BRowOutOfRange { .. })
        ));
    }

    #[test]
    fn injected_dropped_nnz_detected() {
        let (mut plan, blocks) = setup();
        'outer: for p in 0..8 {
            for q in 0..8 {
                if p != q && plan.pairs[p][q].a_col_part.nnz() > 0 {
                    let pair = &mut plan.pairs[p][q];
                    pair.a_col_part = crate::sparse::Csr::zeros(
                        pair.a_col_part.nrows,
                        pair.a_col_part.ncols,
                    );
                    break 'outer;
                }
            }
        }
        assert!(matches!(
            validate(&plan, &blocks),
            Err(PlanError::NnzMismatch { .. })
        ));
    }

    #[test]
    fn rank_mismatch_detected() {
        let (plan, blocks) = setup();
        assert!(matches!(
            validate(&plan, &blocks[..4]),
            Err(PlanError::RankMismatch { .. })
        ));
    }
}
