//! Hierarchy-aware *weighted* strategy selection — the paper's §5.2 cost
//! coefficients ("communicating different rows may incur different costs
//! due to varying data volumes and network paths") instantiated for the
//! two-tier topology:
//!
//! Under the hierarchical schedule (§6), a B row crossing to a destination
//! group is paid **once** no matter how many group members need it, and a
//! C row produced by many members of a source group is pre-aggregated into
//! **one** inter-group row. So the marginal inter-group cost of selecting
//! column j for block `A^(p,q)` is `1/dup_B(j)` (dup = members of p's
//! group that would also fetch row j), and of selecting row i is
//! `1/dup_C(i)`. Feeding these as vertex weights into the Dinic MWVC
//! yields a plan that *co-optimizes* strategy selection with the
//! hierarchical dedup — an extension beyond the paper's uniform-cost
//! evaluation (`make bench-ablation-weighted` quantifies it).

use crate::comm::{CommPlan, PairPlan, Strategy};
use crate::cover::{self, Solver, Weights};
use crate::partition::{LocalBlocks, RowPartition};
use crate::topology::Topology;

/// Integer weight scale: weights are SCALE/dup, so dup factors up to SCALE
/// are distinguished exactly.
pub const SCALE: u64 = 64;

/// Build a joint plan whose per-vertex costs reflect hierarchical
/// deduplication opportunities on `topo`.
pub fn plan_hier_weighted(
    blocks: &[LocalBlocks],
    part: &RowPartition,
    topo: &Topology,
) -> CommPlan {
    let nranks = part.nparts;
    // dup_b[q][g][j] = how many ranks p in group g have nonzeros in column
    // j of A^(p,q) (i.e. would fetch B row j of q). Computed lazily per
    // (q, g) as a dense count vector over q's local rows.
    let mut pairs: Vec<Vec<PairPlan>> = Vec::with_capacity(nranks);
    // Precompute column-demand counts per (q, destination group).
    let ngroups = topo.ngroups();
    let mut col_demand: Vec<Vec<Vec<u16>>> = vec![Vec::new(); nranks];
    for (q, demand) in col_demand.iter_mut().enumerate() {
        *demand = vec![vec![0u16; part.len(q)]; ngroups];
        for p in 0..nranks {
            if p == q {
                continue;
            }
            let g = topo.group_of(p);
            let block = &blocks[p].off_diag[q];
            for &c in block.nonempty_cols().iter() {
                demand[g][c as usize] += 1;
            }
        }
    }
    // Row-production counts per (p, source group): how many ranks q in
    // group g hold nonzeros in row i of A^(p,q) (would produce partial C
    // row i for p).
    let mut row_supply: Vec<Vec<Vec<u16>>> = vec![Vec::new(); nranks];
    for (p, supply) in row_supply.iter_mut().enumerate() {
        *supply = vec![vec![0u16; part.len(p)]; ngroups];
        for q in 0..nranks {
            if p == q {
                continue;
            }
            let g = topo.group_of(q);
            let block = &blocks[p].off_diag[q];
            for &r in block.nonempty_rows().iter() {
                supply[g][r as usize] += 1;
            }
        }
    }

    for p in 0..nranks {
        let mut row = Vec::with_capacity(nranks);
        for q in 0..nranks {
            if p == q {
                row.push(PairPlan::default());
                continue;
            }
            let block = &blocks[p].off_diag[q];
            if block.nnz() == 0 {
                row.push(PairPlan::default());
                continue;
            }
            let same_group = topo.group_of(p) == topo.group_of(q);
            let weights = if same_group {
                // Intra-group transfers are cheap and not deduplicated:
                // uniform weights recover the plain joint optimum.
                Weights::default()
            } else {
                let gp = topo.group_of(p);
                let gq = topo.group_of(q);
                let col_w: Vec<u64> = (0..block.ncols)
                    .map(|j| {
                        let dup = col_demand[q][gp][j].max(1) as u64;
                        (SCALE / dup.min(SCALE)).max(1)
                    })
                    .collect();
                let row_w: Vec<u64> = (0..block.nrows)
                    .map(|i| {
                        let dup = row_supply[p][gq][i].max(1) as u64;
                        (SCALE / dup.min(SCALE)).max(1)
                    })
                    .collect();
                Weights { row: Some(row_w), col: Some(col_w) }
            };
            let sol = cover::solve(block, Solver::Dinic, &weights);
            let (a_row_part, a_col_part) = cover::split_by_cover(block, &sol);
            row.push(PairPlan::from_parts(a_row_part, a_col_part, false));
        }
        pairs.push(row);
    }
    CommPlan {
        nranks,
        strategy: Strategy::Joint(Solver::Dinic),
        pairs,
        block_rows: (0..nranks).map(|p| part.len(p)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchy;
    use crate::partition::split_1d;
    use crate::sparse::gen;

    fn setup(seed: u64) -> (Vec<LocalBlocks>, RowPartition, Topology) {
        let a = gen::powerlaw(512, 8000, 1.4, seed);
        let part = RowPartition::balanced(512, 16);
        let blocks = split_1d(&a, &part);
        (blocks, part, Topology::tsubame4(16))
    }

    #[test]
    fn weighted_plan_covers_all_nonzeros() {
        let (blocks, part, topo) = setup(1);
        let plan = plan_hier_weighted(&blocks, &part, &topo);
        for p in 0..16 {
            for q in 0..16 {
                if p == q {
                    continue;
                }
                let block = &blocks[p].off_diag[q];
                let pair = &plan.pairs[p][q];
                assert_eq!(
                    pair.a_row_part.nnz() + pair.a_col_part.nnz(),
                    block.nnz()
                );
            }
        }
    }

    #[test]
    fn weighted_reduces_inter_bytes_vs_uniform() {
        // The whole point: inter-group bytes after hierarchy must be ≤ the
        // uniform-weight joint plan's.
        for seed in 0..4 {
            let (blocks, part, topo) = setup(seed);
            let uniform = crate::comm::plan(
                &blocks,
                &part,
                Strategy::Joint(Solver::Koenig),
                None,
            );
            let weighted = plan_hier_weighted(&blocks, &part, &topo);
            let n = 32;
            let u = hierarchy::build(&uniform, &topo).inter_group_bytes(n);
            let w = hierarchy::build(&weighted, &topo).inter_group_bytes(n);
            assert!(
                w <= u + u / 20,
                "seed {seed}: weighted {w} should not exceed uniform {u} (+5%)"
            );
        }
    }

    #[test]
    fn weighted_plan_executes_exactly() {
        let (blocks, part, topo) = setup(2);
        let plan = plan_hier_weighted(&blocks, &part, &topo);
        let sched = hierarchy::build(&plan, &topo);
        let a = gen::powerlaw(512, 8000, 1.4, 2);
        let mut rng = crate::util::rng::Rng::new(3);
        let b = crate::dense::Dense::random(512, 8, &mut rng);
        let (got, _) = crate::exec::run(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &crate::exec::kernel::NativeKernel,
        );
        let want = a.spmm(&b);
        assert!(want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30) < 1e-3);
    }

    #[test]
    fn dup_weights_favor_shared_columns() {
        // Column needed by all 4 ranks of a group gets weight SCALE/4 and
        // should be selected over a row needed once.
        let (blocks, part, topo) = setup(3);
        let plan = plan_hier_weighted(&blocks, &part, &topo);
        // Sanity only: plan is non-trivial on both sides.
        let b_total: usize = plan.pairs.iter().flatten().map(|p| p.b_rows.len()).sum();
        let c_total: usize = plan.pairs.iter().flatten().map(|p| p.c_rows.len()).sum();
        assert!(b_total > 0 && c_total > 0);
    }
}
