//! Experiment configuration: mini-TOML file + CLI overrides, shared by the
//! `shiro` binary and the bench harness.

use crate::comm::Strategy;
use crate::partition::{split_1d, LocalBlocks, RowPartition};
use crate::sparse::{dataset_by_name, Csr};
use crate::topology::Topology;
use crate::util::cli::Args;
use crate::util::toml_mini::Config;

/// Resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub ranks: usize,
    pub n_dense: usize,
    pub scale: f64,
    pub topo: String,
    pub epochs: usize,
    /// Communication strategy name (see [`Strategy::by_name`]):
    /// block | column | row | joint | joint-weighted | joint-greedy | adaptive.
    pub strategy: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "Pokec".into(),
            ranks: 8,
            n_dense: 32,
            scale: 0.05,
            topo: "tsubame4".into(),
            epochs: 50,
            strategy: "joint".into(),
        }
    }
}

impl RunConfig {
    /// Load from `--config <file>` (if given) then apply CLI overrides.
    pub fn from_args(args: &Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            match Config::load(std::path::Path::new(path)) {
                Ok(file) => cfg.apply_file(&file),
                Err(e) => {
                    eprintln!("config {path}: {e:#}");
                    std::process::exit(2);
                }
            }
        }
        if let Some(d) = args.get("dataset") {
            cfg.dataset = d.to_string();
        }
        cfg.ranks = args.get_usize("ranks", cfg.ranks);
        cfg.n_dense = args.get_usize("n", cfg.n_dense);
        cfg.scale = args.get_f64("scale", cfg.scale);
        if let Some(t) = args.get("topo") {
            cfg.topo = t.to_string();
        }
        cfg.epochs = args.get_usize("epochs", cfg.epochs);
        if let Some(s) = args.get("strategy") {
            cfg.strategy = s.to_string();
        }
        cfg
    }

    fn apply_file(&mut self, file: &Config) {
        self.dataset = file.str_or("run.dataset", &self.dataset);
        self.ranks = file.int_or("run.ranks", self.ranks as i64) as usize;
        self.n_dense = file.int_or("run.n", self.n_dense as i64) as usize;
        self.scale = file.float_or("run.scale", self.scale);
        self.topo = file.str_or("run.topo", &self.topo);
        self.epochs = file.int_or("run.epochs", self.epochs as i64) as usize;
        self.strategy = file.str_or("run.strategy", &self.strategy);
    }

    /// Resolve the configured strategy name.
    pub fn strategy(&self) -> Strategy {
        Strategy::by_name(&self.strategy).unwrap_or_else(|| {
            eprintln!(
                "unknown strategy {:?} (block | column | row | joint | joint-weighted | \
                 joint-greedy | adaptive)",
                self.strategy
            );
            std::process::exit(2);
        })
    }

    /// Generate the configured dataset matrix.
    pub fn matrix(&self) -> Csr {
        match dataset_by_name(&self.dataset) {
            Some(spec) => spec.generate(self.scale),
            None => {
                eprintln!("unknown dataset {:?} — see `shiro datasets`", self.dataset);
                std::process::exit(2);
            }
        }
    }

    pub fn topology(&self) -> Topology {
        Topology::by_name(&self.topo, self.ranks).unwrap_or_else(|| {
            eprintln!("unknown topology {:?} (tsubame4 | aurora | flat)", self.topo);
            std::process::exit(2);
        })
    }

    pub fn split(&self, a: &Csr) -> (RowPartition, Vec<LocalBlocks>) {
        let part = RowPartition::balanced(a.nrows, self.ranks);
        let blocks = split_1d(a, &part);
        (part, blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let cfg = RunConfig::from_args(&args(&["plan", "--ranks", "16", "--n", "64"]));
        assert_eq!(cfg.ranks, 16);
        assert_eq!(cfg.n_dense, 64);
        assert_eq!(cfg.dataset, "Pokec");
    }

    #[test]
    fn config_file_then_cli_override() {
        let dir = std::env::temp_dir().join("shiro_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\ndataset = \"mawi\"\nranks = 32\nn = 128\n").unwrap();
        let cfg = RunConfig::from_args(&args(&[
            "plan",
            "--config",
            p.to_str().unwrap(),
            "--ranks",
            "8",
        ]));
        assert_eq!(cfg.dataset, "mawi");
        assert_eq!(cfg.ranks, 8); // CLI wins
        assert_eq!(cfg.n_dense, 128); // file value survives
    }

    #[test]
    fn topology_resolution() {
        let cfg = RunConfig { topo: "aurora".into(), ranks: 24, ..Default::default() };
        assert_eq!(cfg.topology().name, "aurora");
    }

    #[test]
    fn strategy_resolution() {
        use crate::comm::Strategy;
        use crate::cover::Solver;
        let cfg = RunConfig::from_args(&args(&["run", "--strategy", "adaptive"]));
        assert_eq!(cfg.strategy(), Strategy::Adaptive);
        let cfg = RunConfig::default();
        assert_eq!(cfg.strategy(), Strategy::Joint(Solver::Koenig));
        assert_eq!(Strategy::by_name("nope"), None);
        assert_eq!(Strategy::by_name("row"), Some(Strategy::Row));
    }
}
