//! Experiment configuration: mini-TOML file + CLI overrides, shared by the
//! `shiro` binary and the bench harness.

use crate::comm::Strategy;
use crate::partition::{split_1d, LocalBlocks, Partitioner, RowPartition};
use crate::sparse::{dataset_by_name, Csr};
use crate::topology::Topology;
use crate::util::cli::Args;
use crate::util::toml_mini::Config;

/// Resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub ranks: usize,
    pub n_dense: usize,
    pub scale: f64,
    pub topo: String,
    pub epochs: usize,
    /// Communication strategy name (see [`Strategy::by_name`]):
    /// block | column | row | joint | joint-weighted | joint-greedy | adaptive.
    pub strategy: String,
    /// Row-partitioner name (see [`Partitioner::by_name`]):
    /// balanced | nnz-balanced | cost-refined.
    pub partitioner: String,
    /// Executor scheduling: `true` = overlapped pipeline (Alg. 1, the
    /// default), `false` = strictly phase-ordered (`--overlap off`).
    pub overlap: bool,
    /// Executor backend: "thread" (in-process ranks, the default and the
    /// differential oracle) or "proc" (one OS process per rank over the
    /// socket control plane, [`crate::runtime::multiproc`]). Proc rank
    /// processes are pooled: spawned and handshaken once, then reused
    /// across requests ([`crate::runtime::multiproc::WorkerPool`]).
    pub backend: String,
    /// Proc-backend crash handling (see
    /// [`crate::runtime::multiproc::FaultPolicy`]): "fail" surfaces a
    /// structured failure (the default); "recover" or "recover:N" replans
    /// over the survivors, tolerating up to N lost workers (bare
    /// "recover" = 1).
    pub fault_policy: String,
    /// 1.5D replication factor (see [`crate::spmm::Replicate`], DESIGN.md
    /// §13): "1" is the flat engine (the default), a larger integer must
    /// divide the rank count, and "auto" searches the candidate factors
    /// with the α-β cost model.
    pub replicate: String,
    /// `shiro serve` worker threads.
    pub serve_workers: usize,
    /// `shiro serve` admission queue bound (back-pressure beyond this).
    pub serve_queue_cap: usize,
    /// `shiro serve` session-registry capacity (LRU beyond this).
    pub serve_registry_cap: usize,
    /// `shiro serve` micro-batch bound (1 disables coalescing).
    pub serve_max_batch: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "Pokec".into(),
            ranks: 8,
            n_dense: 32,
            scale: 0.05,
            topo: "tsubame4".into(),
            epochs: 50,
            strategy: "joint".into(),
            partitioner: "balanced".into(),
            overlap: true,
            backend: "thread".into(),
            fault_policy: "fail".into(),
            replicate: "1".into(),
            serve_workers: 2,
            serve_queue_cap: 64,
            serve_registry_cap: 4,
            serve_max_batch: 8,
        }
    }
}

/// Parse an `--overlap` value: on|off (plus true/false, 1/0).
fn parse_overlap(v: &str) -> bool {
    match v {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => {
            eprintln!("--overlap expects on|off, got {other:?}");
            std::process::exit(2);
        }
    }
}

/// Parse a `--backend` value: thread|proc.
fn parse_backend(v: &str) -> String {
    match v {
        "thread" | "proc" => v.to_string(),
        other => {
            eprintln!("--backend expects thread|proc, got {other:?}");
            std::process::exit(2);
        }
    }
}

/// Parse a `--replicate` value: auto|c (a positive integer).
fn parse_replicate(v: &str) -> String {
    let valid = v == "auto" || v.parse::<usize>().is_ok_and(|c| c > 0);
    if !valid {
        eprintln!("--replicate expects auto or a positive integer factor, got {v:?}");
        std::process::exit(2);
    }
    v.to_string()
}

/// Parse a `--fault-policy` value: fail|recover|recover:N.
fn parse_fault_policy(v: &str) -> String {
    let valid = v == "fail"
        || v == "recover"
        || v.strip_prefix("recover:").is_some_and(|n| n.parse::<usize>().is_ok());
    if !valid {
        eprintln!("--fault-policy expects fail|recover|recover:N, got {v:?}");
        std::process::exit(2);
    }
    v.to_string()
}

impl RunConfig {
    /// Load from `--config <file>` (if given) then apply CLI overrides.
    pub fn from_args(args: &Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            match Config::load(std::path::Path::new(path)) {
                Ok(file) => cfg.apply_file(&file),
                Err(e) => {
                    eprintln!("config {path}: {e:#}");
                    std::process::exit(2);
                }
            }
        }
        if let Some(d) = args.get("dataset") {
            cfg.dataset = d.to_string();
        }
        cfg.ranks = args.get_usize("ranks", cfg.ranks);
        cfg.n_dense = args.get_usize("n", cfg.n_dense);
        cfg.scale = args.get_f64("scale", cfg.scale);
        if let Some(t) = args.get("topo") {
            cfg.topo = t.to_string();
        }
        cfg.epochs = args.get_usize("epochs", cfg.epochs);
        if let Some(s) = args.get("strategy") {
            cfg.strategy = s.to_string();
        }
        if let Some(p) = args.get("partitioner") {
            cfg.partitioner = p.to_string();
        }
        if let Some(o) = args.get("overlap") {
            cfg.overlap = parse_overlap(o);
        }
        if let Some(b) = args.get("backend") {
            cfg.backend = parse_backend(b);
        }
        if let Some(fp) = args.get("fault-policy") {
            cfg.fault_policy = parse_fault_policy(fp);
        }
        if let Some(r) = args.get("replicate") {
            cfg.replicate = parse_replicate(r);
        }
        cfg.serve_workers = args.get_usize("serve-workers", cfg.serve_workers);
        cfg.serve_queue_cap = args.get_usize("serve-queue", cfg.serve_queue_cap);
        cfg.serve_registry_cap = args.get_usize("serve-registry", cfg.serve_registry_cap);
        cfg.serve_max_batch = args.get_usize("serve-batch", cfg.serve_max_batch);
        cfg
    }

    fn apply_file(&mut self, file: &Config) {
        self.dataset = file.str_or("run.dataset", &self.dataset);
        self.ranks = file.int_or("run.ranks", self.ranks as i64) as usize;
        self.n_dense = file.int_or("run.n", self.n_dense as i64) as usize;
        self.scale = file.float_or("run.scale", self.scale);
        self.topo = file.str_or("run.topo", &self.topo);
        self.epochs = file.int_or("run.epochs", self.epochs as i64) as usize;
        self.strategy = file.str_or("run.strategy", &self.strategy);
        self.partitioner = file.str_or("run.partitioner", &self.partitioner);
        // `run.overlap` accepts both the idiomatic TOML bool and the CLI's
        // "on"/"off" string form.
        if let Some(v) = file.get("run.overlap") {
            self.overlap = match (v.as_bool(), v.as_str()) {
                (Some(b), _) => b,
                (None, Some(s)) => parse_overlap(s),
                (None, None) => {
                    eprintln!("run.overlap expects a bool or \"on\"/\"off\"");
                    std::process::exit(2);
                }
            };
        }
        if let Some(v) = file.get("run.backend") {
            self.backend = match v.as_str() {
                Some(s) => parse_backend(s),
                None => {
                    eprintln!("run.backend expects \"thread\" or \"proc\"");
                    std::process::exit(2);
                }
            };
        }
        // `run.replicate` accepts both a TOML integer and the CLI's
        // "auto"/"c" string form.
        if let Some(v) = file.get("run.replicate") {
            self.replicate = match (v.as_int(), v.as_str()) {
                (Some(c), _) => parse_replicate(&c.to_string()),
                (None, Some(s)) => parse_replicate(s),
                (None, None) => {
                    eprintln!("run.replicate expects an integer or \"auto\"");
                    std::process::exit(2);
                }
            };
        }
        if let Some(v) = file.get("run.fault_policy") {
            self.fault_policy = match v.as_str() {
                Some(s) => parse_fault_policy(s),
                None => {
                    eprintln!("run.fault_policy expects \"fail\", \"recover\", or \"recover:N\"");
                    std::process::exit(2);
                }
            };
        }
        self.serve_workers = file.int_or("serve.workers", self.serve_workers as i64) as usize;
        self.serve_queue_cap = file.int_or("serve.queue", self.serve_queue_cap as i64) as usize;
        self.serve_registry_cap =
            file.int_or("serve.registry", self.serve_registry_cap as i64) as usize;
        self.serve_max_batch = file.int_or("serve.batch", self.serve_max_batch as i64) as usize;
    }

    /// Resolve the configured strategy name.
    pub fn strategy(&self) -> Strategy {
        Strategy::by_name(&self.strategy).unwrap_or_else(|| {
            eprintln!(
                "unknown strategy {:?} (block | column | row | joint | joint-weighted | \
                 joint-greedy | adaptive)",
                self.strategy
            );
            std::process::exit(2);
        })
    }

    /// Resolve the configured fault-policy string (validated at parse
    /// time; bare "recover" tolerates one lost worker).
    pub fn fault_policy(&self) -> crate::spmm::FaultPolicy {
        use crate::spmm::FaultPolicy;
        match self.fault_policy.as_str() {
            "fail" => FaultPolicy::Fail,
            "recover" => FaultPolicy::Recover { max_retries: 1 },
            other => match other.strip_prefix("recover:").and_then(|n| n.parse().ok()) {
                Some(max_retries) => FaultPolicy::Recover { max_retries },
                None => {
                    eprintln!(
                        "unknown fault policy {:?} (fail | recover | recover:N)",
                        self.fault_policy
                    );
                    std::process::exit(2);
                }
            },
        }
    }

    /// Resolve the configured replication factor (validated at parse
    /// time; "auto" defers to the planner's cost-model search).
    pub fn replicate(&self) -> crate::spmm::Replicate {
        use crate::spmm::Replicate;
        match self.replicate.as_str() {
            "auto" => Replicate::Auto,
            c => match c.parse::<usize>() {
                Ok(c) if c > 0 => Replicate::Factor(c),
                _ => {
                    eprintln!("unknown replication factor {:?} (auto | c)", self.replicate);
                    std::process::exit(2);
                }
            },
        }
    }

    /// Resolve the configured partitioner name.
    pub fn partitioner(&self) -> Partitioner {
        Partitioner::by_name(&self.partitioner).unwrap_or_else(|| {
            eprintln!(
                "unknown partitioner {:?} (balanced | nnz-balanced | cost-refined)",
                self.partitioner
            );
            std::process::exit(2);
        })
    }

    /// Generate the configured dataset matrix.
    pub fn matrix(&self) -> Csr {
        match dataset_by_name(&self.dataset) {
            Some(spec) => spec.generate(self.scale),
            None => {
                eprintln!("unknown dataset {:?} — see `shiro datasets`", self.dataset);
                std::process::exit(2);
            }
        }
    }

    pub fn topology(&self) -> Topology {
        Topology::by_name(&self.topo, self.ranks).unwrap_or_else(|| {
            eprintln!("unknown topology {:?} (tsubame4 | aurora | flat)", self.topo);
            std::process::exit(2);
        })
    }

    /// Partition `a` with the configured [`Partitioner`] and split it into
    /// per-rank blocks.
    pub fn split(&self, a: &Csr) -> (RowPartition, Vec<LocalBlocks>) {
        let part = self
            .partitioner()
            .partition(a, self.ranks, &self.topology(), self.n_dense);
        let blocks = split_1d(a, &part);
        (part, blocks)
    }

    /// Executor options implied by this configuration.
    pub fn exec_opts(&self) -> crate::exec::ExecOpts {
        if self.overlap {
            crate::exec::ExecOpts::default()
        } else {
            crate::exec::ExecOpts::sequential()
        }
    }

    /// The [`crate::spmm::PlanSpec`] implied by this configuration
    /// (strategy, topology, partitioner, dense width).
    pub fn plan_spec(&self) -> crate::spmm::PlanSpec {
        crate::spmm::PlanSpec::new(self.topology())
            .strategy(self.strategy())
            .partitioner(self.partitioner())
            .n_dense(self.n_dense)
            .replicate(self.replicate())
    }

    /// The [`crate::serve::ServeConfig`] implied by this configuration.
    pub fn serve_config(&self) -> crate::serve::ServeConfig {
        let mut sc = crate::serve::ServeConfig::new(self.topology());
        sc.workers = self.serve_workers;
        sc.queue_cap = self.serve_queue_cap;
        sc.registry_cap = self.serve_registry_cap;
        sc.max_batch = self.serve_max_batch;
        sc.spec = self.plan_spec();
        sc.opts = self.exec_opts();
        sc.fault_policy = self.fault_policy();
        sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let cfg = RunConfig::from_args(&args(&["plan", "--ranks", "16", "--n", "64"]));
        assert_eq!(cfg.ranks, 16);
        assert_eq!(cfg.n_dense, 64);
        assert_eq!(cfg.dataset, "Pokec");
        assert!(cfg.overlap, "overlapped pipeline is the default");
    }

    #[test]
    fn overlap_flag_parses() {
        let cfg = RunConfig::from_args(&args(&["run", "--overlap", "off"]));
        assert!(!cfg.overlap);
        assert!(!cfg.exec_opts().overlap);
        let cfg = RunConfig::from_args(&args(&["run", "--overlap", "on"]));
        assert!(cfg.overlap);
        assert!(cfg.exec_opts().overlap);
    }

    #[test]
    fn overlap_from_config_file_bool_and_string() {
        let dir = std::env::temp_dir().join("shiro_cfg_overlap_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (contents, want) in [
            ("[run]\noverlap = false\n", false),
            ("[run]\noverlap = true\n", true),
            ("[run]\noverlap = \"off\"\n", false),
        ] {
            let p = dir.join("run.toml");
            std::fs::write(&p, contents).unwrap();
            let cfg = RunConfig::from_args(&args(&["run", "--config", p.to_str().unwrap()]));
            assert_eq!(cfg.overlap, want, "{contents:?}");
        }
        // CLI still wins over the file.
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\noverlap = false\n").unwrap();
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--config",
            p.to_str().unwrap(),
            "--overlap",
            "on",
        ]));
        assert!(cfg.overlap);
    }

    #[test]
    fn config_file_then_cli_override() {
        let dir = std::env::temp_dir().join("shiro_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\ndataset = \"mawi\"\nranks = 32\nn = 128\n").unwrap();
        let cfg = RunConfig::from_args(&args(&[
            "plan",
            "--config",
            p.to_str().unwrap(),
            "--ranks",
            "8",
        ]));
        assert_eq!(cfg.dataset, "mawi");
        assert_eq!(cfg.ranks, 8); // CLI wins
        assert_eq!(cfg.n_dense, 128); // file value survives
    }

    #[test]
    fn backend_flag_and_file() {
        let cfg = RunConfig::from_args(&args(&["run"]));
        assert_eq!(cfg.backend, "thread", "thread backend is the default");
        let cfg = RunConfig::from_args(&args(&["run", "--backend", "proc"]));
        assert_eq!(cfg.backend, "proc");

        let dir = std::env::temp_dir().join("shiro_cfg_backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\nbackend = \"proc\"\n").unwrap();
        let cfg = RunConfig::from_args(&args(&["run", "--config", p.to_str().unwrap()]));
        assert_eq!(cfg.backend, "proc");
        // CLI wins over the file.
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--config",
            p.to_str().unwrap(),
            "--backend",
            "thread",
        ]));
        assert_eq!(cfg.backend, "thread");
    }

    #[test]
    fn fault_policy_flag_and_file() {
        use crate::spmm::FaultPolicy;
        let cfg = RunConfig::from_args(&args(&["run"]));
        assert_eq!(cfg.fault_policy, "fail", "fail is the default");
        assert_eq!(cfg.fault_policy(), FaultPolicy::Fail);
        let cfg = RunConfig::from_args(&args(&["run", "--fault-policy", "recover"]));
        assert_eq!(cfg.fault_policy(), FaultPolicy::Recover { max_retries: 1 });
        let cfg = RunConfig::from_args(&args(&["run", "--fault-policy", "recover:3"]));
        assert_eq!(cfg.fault_policy(), FaultPolicy::Recover { max_retries: 3 });

        let dir = std::env::temp_dir().join("shiro_cfg_fault_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\nfault_policy = \"recover:2\"\n").unwrap();
        let cfg = RunConfig::from_args(&args(&["run", "--config", p.to_str().unwrap()]));
        assert_eq!(cfg.fault_policy(), FaultPolicy::Recover { max_retries: 2 });
        assert_eq!(cfg.serve_config().fault_policy, FaultPolicy::Recover { max_retries: 2 });
        // CLI wins over the file.
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--config",
            p.to_str().unwrap(),
            "--fault-policy",
            "fail",
        ]));
        assert_eq!(cfg.fault_policy(), FaultPolicy::Fail);
    }

    #[test]
    fn replicate_flag_and_file() {
        use crate::spmm::Replicate;
        let cfg = RunConfig::from_args(&args(&["run"]));
        assert_eq!(cfg.replicate, "1", "flat engine is the default");
        assert_eq!(cfg.replicate(), Replicate::Factor(1));
        assert_eq!(cfg.plan_spec().replicate, Replicate::Factor(1));
        let cfg = RunConfig::from_args(&args(&["run", "--replicate", "2"]));
        assert_eq!(cfg.replicate(), Replicate::Factor(2));
        assert_eq!(cfg.plan_spec().replicate, Replicate::Factor(2));
        let cfg = RunConfig::from_args(&args(&["run", "--replicate", "auto"]));
        assert_eq!(cfg.replicate(), Replicate::Auto);

        let dir = std::env::temp_dir().join("shiro_cfg_replicate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        // Both the idiomatic TOML integer and the string form parse.
        for (contents, want) in [
            ("[run]\nreplicate = 4\n", Replicate::Factor(4)),
            ("[run]\nreplicate = \"auto\"\n", Replicate::Auto),
        ] {
            std::fs::write(&p, contents).unwrap();
            let cfg = RunConfig::from_args(&args(&["run", "--config", p.to_str().unwrap()]));
            assert_eq!(cfg.replicate(), want, "{contents:?}");
        }
        // CLI wins over the file.
        std::fs::write(&p, "[run]\nreplicate = 4\n").unwrap();
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--config",
            p.to_str().unwrap(),
            "--replicate",
            "1",
        ]));
        assert_eq!(cfg.replicate(), Replicate::Factor(1));
    }

    #[test]
    fn serve_knobs_flag_and_file() {
        let cfg = RunConfig::from_args(&args(&["serve"]));
        assert_eq!(
            (cfg.serve_workers, cfg.serve_queue_cap, cfg.serve_registry_cap, cfg.serve_max_batch),
            (2, 64, 4, 8),
            "serve defaults"
        );
        let cfg = RunConfig::from_args(&args(&[
            "serve",
            "--serve-workers",
            "3",
            "--serve-queue",
            "16",
            "--serve-registry",
            "2",
            "--serve-batch",
            "4",
        ]));
        let sc = cfg.serve_config();
        assert_eq!((sc.workers, sc.queue_cap, sc.registry_cap, sc.max_batch), (3, 16, 2, 4));

        let dir = std::env::temp_dir().join("shiro_cfg_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[serve]\nworkers = 1\nqueue = 8\nregistry = 3\nbatch = 2\n").unwrap();
        let cfg = RunConfig::from_args(&args(&["serve", "--config", p.to_str().unwrap()]));
        assert_eq!(
            (cfg.serve_workers, cfg.serve_queue_cap, cfg.serve_registry_cap, cfg.serve_max_batch),
            (1, 8, 3, 2)
        );
    }

    #[test]
    fn plan_spec_reflects_the_config() {
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--strategy",
            "adaptive",
            "--partitioner",
            "nnz-balanced",
            "--n",
            "48",
            "--ranks",
            "4",
        ]));
        let spec = cfg.plan_spec();
        assert_eq!(spec.strategy, Strategy::Adaptive);
        assert_eq!(spec.partitioner, Partitioner::NnzBalanced);
        assert_eq!(spec.params.n_dense, 48);
        assert_eq!(spec.topo.nranks, 4);
    }

    #[test]
    fn topology_resolution() {
        let cfg = RunConfig { topo: "aurora".into(), ranks: 24, ..Default::default() };
        assert_eq!(cfg.topology().name, "aurora");
    }

    #[test]
    fn partitioner_flag_and_file() {
        let cfg = RunConfig::from_args(&args(&["run", "--partitioner", "nnz-balanced"]));
        assert_eq!(cfg.partitioner(), Partitioner::NnzBalanced);
        let cfg = RunConfig::default();
        assert_eq!(cfg.partitioner(), Partitioner::Balanced);

        let dir = std::env::temp_dir().join("shiro_cfg_partitioner_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(&p, "[run]\npartitioner = \"cost-refined\"\n").unwrap();
        let cfg = RunConfig::from_args(&args(&["run", "--config", p.to_str().unwrap()]));
        assert_eq!(cfg.partitioner(), Partitioner::CostRefined);
        // CLI wins over the file.
        let cfg = RunConfig::from_args(&args(&[
            "run",
            "--config",
            p.to_str().unwrap(),
            "--partitioner",
            "balanced",
        ]));
        assert_eq!(cfg.partitioner(), Partitioner::Balanced);
    }

    #[test]
    fn split_respects_partitioner() {
        use crate::sparse::gen;
        let a = gen::rmat(256, 4000, (0.6, 0.18, 0.18), false, 5);
        let mut bal_cfg = RunConfig { ranks: 8, scale: 0.01, ..Default::default() };
        let (bal, blocks) = bal_cfg.split(&a);
        assert_eq!(blocks.len(), 8);
        assert_eq!(bal.starts, RowPartition::balanced(256, 8).starts);
        bal_cfg.partitioner = "nnz-balanced".into();
        let (nnz, blocks) = bal_cfg.split(&a);
        assert_eq!(blocks.len(), 8);
        assert_ne!(nnz.starts, bal.starts);
        assert!(
            crate::partition::max_rank_nnz(&a, &nnz)
                <= crate::partition::max_rank_nnz(&a, &bal)
        );
    }

    #[test]
    fn strategy_resolution() {
        use crate::comm::Strategy;
        use crate::cover::Solver;
        let cfg = RunConfig::from_args(&args(&["run", "--strategy", "adaptive"]));
        assert_eq!(cfg.strategy(), Strategy::Adaptive);
        let cfg = RunConfig::default();
        assert_eq!(cfg.strategy(), Strategy::Joint(Solver::Koenig));
        assert_eq!(Strategy::by_name("nope"), None);
        assert_eq!(Strategy::by_name("row"), Some(Strategy::Row));
    }
}
