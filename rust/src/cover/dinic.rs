//! Dinic's max-flow algorithm on integer capacities, used to solve the
//! minimum *weighted* vertex cover via the min-cut reduction (paper §5.3.2).

/// Sentinel "infinite" capacity for bipartite edges (never cut).
pub const INF: u64 = u64::MAX / 4;

#[derive(Clone, Debug)]
struct Edge {
    to: u32,
    cap: u64,
    /// Index of the reverse edge in `graph[to]`.
    rev: u32,
}

/// Flow network with Dinic's blocking-flow max-flow.
pub struct Dinic {
    graph: Vec<Vec<Edge>>,
    level: Vec<i32>,
    iter: Vec<usize>,
}

impl Dinic {
    pub fn new(n: usize) -> Dinic {
        Dinic {
            graph: vec![Vec::new(); n],
            level: vec![0; n],
            iter: vec![0; n],
        }
    }

    pub fn node_count(&self) -> usize {
        self.graph.len()
    }

    /// Add a directed edge u→v with capacity `cap`.
    pub fn add_edge(&mut self, u: usize, v: usize, cap: u64) {
        let rev_u = self.graph[v].len() as u32;
        let rev_v = self.graph[u].len() as u32;
        self.graph[u].push(Edge { to: v as u32, cap, rev: rev_u });
        self.graph[v].push(Edge { to: u as u32, cap: 0, rev: rev_v });
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.iter_mut().for_each(|l| *l = -1);
        let mut queue = std::collections::VecDeque::new();
        self.level[s] = 0;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for e in &self.graph[u] {
                if e.cap > 0 && self.level[e.to as usize] < 0 {
                    self.level[e.to as usize] = self.level[u] + 1;
                    queue.push_back(e.to as usize);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, u: usize, t: usize, f: u64) -> u64 {
        if u == t {
            return f;
        }
        while self.iter[u] < self.graph[u].len() {
            let i = self.iter[u];
            let (to, cap, rev) = {
                let e = &self.graph[u][i];
                (e.to as usize, e.cap, e.rev as usize)
            };
            if cap > 0 && self.level[to] == self.level[u] + 1 {
                let d = self.dfs(to, t, f.min(cap));
                if d > 0 {
                    self.graph[u][i].cap -= d;
                    self.graph[to][rev].cap += d;
                    return d;
                }
            }
            self.iter[u] += 1;
        }
        0
    }

    /// Compute max flow s→t. Safe to call once per network.
    pub fn max_flow(&mut self, s: usize, t: usize) -> u64 {
        let mut flow = 0u64;
        while self.bfs(s, t) {
            self.iter.iter_mut().for_each(|i| *i = 0);
            loop {
                let f = self.dfs(s, t, INF);
                if f == 0 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }

    /// After max_flow: the set of nodes reachable from `s` in the residual
    /// graph (the s-side of the min cut).
    pub fn min_cut_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.graph.len()];
        let mut stack = vec![s];
        seen[s] = true;
        while let Some(u) = stack.pop() {
            for e in &self.graph[u] {
                if e.cap > 0 && !seen[e.to as usize] {
                    seen[e.to as usize] = true;
                    stack.push(e.to as usize);
                }
            }
        }
        seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_path() {
        // s -3-> a -2-> t : flow 2.
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 3);
        d.add_edge(1, 2, 2);
        assert_eq!(d.max_flow(0, 2), 2);
    }

    #[test]
    fn parallel_paths() {
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 5);
        d.add_edge(0, 2, 3);
        d.add_edge(1, 3, 4);
        d.add_edge(2, 3, 4);
        assert_eq!(d.max_flow(0, 3), 7);
    }

    #[test]
    fn classic_textbook() {
        // CLRS-style example with cross edge.
        let mut d = Dinic::new(6);
        d.add_edge(0, 1, 16);
        d.add_edge(0, 2, 13);
        d.add_edge(1, 3, 12);
        d.add_edge(2, 1, 4);
        d.add_edge(2, 4, 14);
        d.add_edge(3, 2, 9);
        d.add_edge(3, 5, 20);
        d.add_edge(4, 3, 7);
        d.add_edge(4, 5, 4);
        assert_eq!(d.max_flow(0, 5), 23);
    }

    #[test]
    fn min_cut_side_separates() {
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 1);
        d.add_edge(1, 2, 100);
        d.add_edge(2, 3, 100);
        assert_eq!(d.max_flow(0, 3), 1);
        let side = d.min_cut_side(0);
        assert!(side[0]);
        assert!(!side[1] && !side[2] && !side[3]);
    }

    #[test]
    fn disconnected_zero_flow() {
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 10);
        assert_eq!(d.max_flow(0, 2), 0);
        let side = d.min_cut_side(0);
        assert!(side[0] && side[1] && !side[2]);
    }
}
