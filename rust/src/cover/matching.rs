//! Hopcroft–Karp maximum bipartite matching + König's theorem extraction of
//! the minimum vertex cover — the fast path for uniform weights
//! (paper §7.1.4: "a faster C++ implementation based on maximum bipartite
//! matching and König's theorem").

/// Bipartite graph in adjacency form: `adj[l]` = right-neighbour list of
/// left vertex `l`. Right vertices are 0..n_right.
pub struct Bipartite {
    pub n_left: usize,
    pub n_right: usize,
    pub adj: Vec<Vec<u32>>,
}

const NIL: u32 = u32::MAX;

pub struct MatchResult {
    /// match_l[l] = matched right vertex or NIL.
    pub match_l: Vec<u32>,
    /// match_r[r] = matched left vertex or NIL.
    pub match_r: Vec<u32>,
    pub size: usize,
}

/// Hopcroft–Karp maximum matching, O(E√V).
pub fn hopcroft_karp(g: &Bipartite) -> MatchResult {
    let mut match_l = vec![NIL; g.n_left];
    let mut match_r = vec![NIL; g.n_right];
    let mut dist = vec![u32::MAX; g.n_left];
    let mut size = 0usize;

    loop {
        // BFS from free left vertices; layers alternate non-matching /
        // matching edges.
        let mut queue = std::collections::VecDeque::new();
        for l in 0..g.n_left {
            if match_l[l] == NIL {
                dist[l] = 0;
                queue.push_back(l as u32);
            } else {
                dist[l] = u32::MAX;
            }
        }
        let mut found = false;
        while let Some(l) = queue.pop_front() {
            for &r in &g.adj[l as usize] {
                let l2 = match_r[r as usize];
                if l2 == NIL {
                    found = true;
                } else if dist[l2 as usize] == u32::MAX {
                    dist[l2 as usize] = dist[l as usize] + 1;
                    queue.push_back(l2);
                }
            }
        }
        if !found {
            break;
        }
        // DFS augmentation along shortest alternating paths.
        fn dfs(
            l: usize,
            g: &Bipartite,
            dist: &mut [u32],
            match_l: &mut [u32],
            match_r: &mut [u32],
        ) -> bool {
            for i in 0..g.adj[l].len() {
                let r = g.adj[l][i] as usize;
                let l2 = match_r[r];
                if l2 == NIL
                    || (dist[l2 as usize] == dist[l] + 1
                        && dfs(l2 as usize, g, dist, match_l, match_r))
                {
                    match_l[l] = r as u32;
                    match_r[r] = l as u32;
                    return true;
                }
            }
            dist[l] = u32::MAX;
            false
        }
        for l in 0..g.n_left {
            if match_l[l] == NIL && dfs(l, g, &mut dist, &mut match_l, &mut match_r) {
                size += 1;
            }
        }
    }
    MatchResult { match_l, match_r, size }
}

/// König's theorem: from a maximum matching, extract a minimum vertex cover.
/// Returns (left_in_cover, right_in_cover) boolean masks.
///
/// Z = vertices reachable from unmatched left vertices via alternating paths
/// (non-matching left→right, matching right→left). Cover = (L \ Z) ∪ (R ∩ Z).
pub fn koenig_cover(g: &Bipartite, m: &MatchResult) -> (Vec<bool>, Vec<bool>) {
    let mut z_left = vec![false; g.n_left];
    let mut z_right = vec![false; g.n_right];
    let mut stack: Vec<u32> = (0..g.n_left as u32)
        .filter(|&l| m.match_l[l as usize] == NIL)
        .collect();
    for &l in &stack {
        z_left[l as usize] = true;
    }
    while let Some(l) = stack.pop() {
        for &r in &g.adj[l as usize] {
            if !z_right[r as usize] {
                z_right[r as usize] = true;
                let l2 = m.match_r[r as usize];
                if l2 != NIL && !z_left[l2 as usize] {
                    z_left[l2 as usize] = true;
                    stack.push(l2);
                }
            }
        }
    }
    let left_cover: Vec<bool> = z_left.iter().map(|&z| !z).collect();
    // Only left vertices that have edges can be in a *minimum* cover;
    // isolated left vertices are never reachable and never needed.
    let left_cover = left_cover
        .iter()
        .enumerate()
        .map(|(l, &c)| c && !g.adj[l].is_empty())
        .collect();
    (left_cover, z_right)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(n_left: usize, n_right: usize, edges: &[(u32, u32)]) -> Bipartite {
        let mut adj = vec![Vec::new(); n_left];
        for &(l, r) in edges {
            adj[l as usize].push(r);
        }
        Bipartite { n_left, n_right, adj }
    }

    fn cover_is_valid(g: &Bipartite, lc: &[bool], rc: &[bool]) -> bool {
        for l in 0..g.n_left {
            for &r in &g.adj[l] {
                if !lc[l] && !rc[r as usize] {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn perfect_matching() {
        let g = graph(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let m = hopcroft_karp(&g);
        assert_eq!(m.size, 3);
    }

    #[test]
    fn augmenting_path_needed() {
        // l0-r0, l0-r1, l1-r0: matching size 2 requires augmentation.
        let g = graph(2, 2, &[(0, 0), (0, 1), (1, 0)]);
        let m = hopcroft_karp(&g);
        assert_eq!(m.size, 2);
    }

    #[test]
    fn star_graph_cover_is_center() {
        // One left hub connected to 4 right vertices.
        let g = graph(1, 4, &[(0, 0), (0, 1), (0, 2), (0, 3)]);
        let m = hopcroft_karp(&g);
        assert_eq!(m.size, 1);
        let (lc, rc) = koenig_cover(&g, &m);
        assert!(cover_is_valid(&g, &lc, &rc));
        let total = lc.iter().filter(|&&x| x).count() + rc.iter().filter(|&&x| x).count();
        assert_eq!(total, 1);
        assert!(lc[0]);
    }

    #[test]
    fn koenig_equals_matching_size() {
        // König: |min cover| == |max matching| in bipartite graphs.
        let g = graph(
            4,
            4,
            &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 2), (3, 3), (3, 0)],
        );
        let m = hopcroft_karp(&g);
        let (lc, rc) = koenig_cover(&g, &m);
        assert!(cover_is_valid(&g, &lc, &rc));
        let total = lc.iter().filter(|&&x| x).count() + rc.iter().filter(|&&x| x).count();
        assert_eq!(total, m.size);
    }

    #[test]
    fn isolated_vertices_excluded() {
        let g = graph(3, 3, &[(0, 0)]);
        let m = hopcroft_karp(&g);
        assert_eq!(m.size, 1);
        let (lc, rc) = koenig_cover(&g, &m);
        let total = lc.iter().filter(|&&x| x).count() + rc.iter().filter(|&&x| x).count();
        assert_eq!(total, 1);
        assert!(!lc[1] && !lc[2], "isolated left vertices must not be covered");
    }

    #[test]
    fn empty_graph() {
        let g = graph(2, 2, &[]);
        let m = hopcroft_karp(&g);
        assert_eq!(m.size, 0);
        let (lc, rc) = koenig_cover(&g, &m);
        assert!(lc.iter().all(|&x| !x));
        assert!(rc.iter().all(|&x| !x));
    }
}
