//! Joint row-column strategy selection (paper §5): for each off-diagonal
//! block `A^(p,q)`, decide per nonzero whether it is served by row-based
//! communication (send the corresponding partial C row) or column-based
//! communication (fetch the corresponding B row), minimizing total
//! communication cost.
//!
//! The optimal assignment is a minimum weighted vertex cover on the
//! bipartite graph (rows ∪ cols, edge per nonzero) — solved by
//! Hopcroft–Karp + König for uniform weights and Dinic max-flow min-cut for
//! weighted costs. A greedy cover is included as the paper's strawman.

pub mod dinic;
pub mod matching;

use crate::sparse::Csr;
use dinic::{Dinic, INF};
use matching::{hopcroft_karp, koenig_cover, Bipartite};

/// Which cover algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Hopcroft–Karp + König (uniform weights, optimal, O(E√V)).
    Koenig,
    /// Dinic max-flow min-cut (weighted, optimal, O(V²E) bound).
    Dinic,
    /// Degree-descending greedy set cover (suboptimal strawman, §5.2).
    Greedy,
    /// Pure column-based strategy (SPA/CoLa baseline, Eq. 2).
    ColumnOnly,
    /// Pure row-based strategy (Eq. 3).
    RowOnly,
}

/// Per-vertex communication costs. `None` means uniform weight 1 per row.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    /// Cost of selecting row i (sending C row i). Length = block nrows.
    pub row: Option<Vec<u64>>,
    /// Cost of selecting column j (fetching B row j). Length = block ncols.
    pub col: Option<Vec<u64>>,
}

/// Solution to the covering problem for one off-diagonal block.
#[derive(Clone, Debug, Default)]
pub struct CoverSolution {
    /// Sorted local row indices chosen for row-based communication
    /// (partial C rows computed at q and sent to p).
    pub rows: Vec<u32>,
    /// Sorted local column indices chosen for column-based communication
    /// (B rows fetched from q).
    pub cols: Vec<u32>,
    /// Total weighted cost (== μ for uniform weights).
    pub cost: u64,
}

impl CoverSolution {
    /// μ — total number of selected vertices (Eq. 9).
    pub fn mu(&self) -> usize {
        self.rows.len() + self.cols.len()
    }

    /// Check the covering constraint x_j + y_i ≥ a_ij for every nonzero.
    pub fn is_valid_for(&self, block: &Csr) -> bool {
        let rset: Vec<bool> = mask(block.nrows, &self.rows);
        let cset: Vec<bool> = mask(block.ncols, &self.cols);
        for r in 0..block.nrows {
            if rset[r] {
                continue;
            }
            for &c in block.row_indices(r) {
                if !cset[c as usize] {
                    return false;
                }
            }
        }
        true
    }
}

fn mask(n: usize, idx: &[u32]) -> Vec<bool> {
    let mut m = vec![false; n];
    for &i in idx {
        m[i as usize] = true;
    }
    m
}

/// Solve the strategy-selection problem for one off-diagonal block.
pub fn solve(block: &Csr, solver: Solver, weights: &Weights) -> CoverSolution {
    if block.nnz() == 0 {
        return CoverSolution::default();
    }
    match solver {
        Solver::ColumnOnly => {
            let cols = block.nonempty_cols();
            let cost = weight_sum(weights.col.as_deref(), &cols);
            CoverSolution { rows: Vec::new(), cols, cost }
        }
        Solver::RowOnly => {
            let rows = block.nonempty_rows();
            let cost = weight_sum(weights.row.as_deref(), &rows);
            CoverSolution { rows, cols: Vec::new(), cost }
        }
        Solver::Koenig => solve_koenig(block),
        Solver::Dinic => solve_dinic(block, weights),
        Solver::Greedy => solve_greedy(block, weights),
    }
}

fn weight_sum(w: Option<&[u64]>, idx: &[u32]) -> u64 {
    match w {
        None => idx.len() as u64,
        Some(w) => idx.iter().map(|&i| w[i as usize]).sum(),
    }
}

/// Compressed bipartite graph over the block's nonempty rows/cols.
struct Compressed {
    row_ids: Vec<u32>,
    col_ids: Vec<u32>,
    /// Map global col → compressed id.
    col_of: Vec<u32>,
}

fn compress(block: &Csr) -> (Compressed, Bipartite) {
    let row_ids = block.nonempty_rows();
    let col_ids = block.nonempty_cols();
    let mut col_of = vec![u32::MAX; block.ncols];
    for (k, &c) in col_ids.iter().enumerate() {
        col_of[c as usize] = k as u32;
    }
    let adj = row_ids
        .iter()
        .map(|&r| {
            block
                .row_indices(r as usize)
                .iter()
                .map(|&c| col_of[c as usize])
                .collect()
        })
        .collect();
    let g = Bipartite {
        n_left: row_ids.len(),
        n_right: col_ids.len(),
        adj,
    };
    (Compressed { row_ids, col_ids, col_of }, g)
}

fn solve_koenig(block: &Csr) -> CoverSolution {
    let (cmp, g) = compress(block);
    let m = hopcroft_karp(&g);
    let (lc, rc) = koenig_cover(&g, &m);
    let rows: Vec<u32> = lc
        .iter()
        .enumerate()
        .filter(|(_, &c)| c)
        .map(|(l, _)| cmp.row_ids[l])
        .collect();
    let cols: Vec<u32> = rc
        .iter()
        .enumerate()
        .filter(|(_, &c)| c)
        .map(|(r, _)| cmp.col_ids[r])
        .collect();
    let cost = (rows.len() + cols.len()) as u64;
    CoverSolution { rows, cols, cost }
}

fn solve_dinic(block: &Csr, weights: &Weights) -> CoverSolution {
    let (cmp, g) = compress(block);
    let (nl, nr) = (g.n_left, g.n_right);
    // Node ids: s = 0, rows 1..=nl, cols nl+1..=nl+nr, t = nl+nr+1.
    let s = 0usize;
    let t = nl + nr + 1;
    let mut net = Dinic::new(t + 1);
    for l in 0..nl {
        let w = weights
            .row
            .as_ref()
            .map(|w| w[cmp.row_ids[l] as usize])
            .unwrap_or(1);
        net.add_edge(s, 1 + l, w);
    }
    for r in 0..nr {
        let w = weights
            .col
            .as_ref()
            .map(|w| w[cmp.col_ids[r] as usize])
            .unwrap_or(1);
        net.add_edge(1 + nl + r, t, w);
    }
    for l in 0..nl {
        for &r in &g.adj[l] {
            net.add_edge(1 + l, 1 + nl + r as usize, INF);
        }
    }
    let cost = net.max_flow(s, t);
    let reach = net.min_cut_side(s);
    // Cut s→row edges (row NOT reachable) ⇒ row selected.
    let rows: Vec<u32> = (0..nl)
        .filter(|&l| !reach[1 + l])
        .map(|l| cmp.row_ids[l])
        .collect();
    // Cut col→t edges (col reachable) ⇒ col selected.
    let cols: Vec<u32> = (0..nr)
        .filter(|&r| reach[1 + nl + r])
        .map(|r| cmp.col_ids[r])
        .collect();
    CoverSolution { rows, cols, cost }
}

/// Greedy weighted set cover: repeatedly select the vertex with the best
/// uncovered-edges-per-cost ratio. The paper's §5.2 strawman — kept for the
/// ablation benches.
fn solve_greedy(block: &Csr, weights: &Weights) -> CoverSolution {
    let (cmp, g) = compress(block);
    let (nl, nr) = (g.n_left, g.n_right);
    let mut covered = vec![false; block.nnz()];
    // Edge lists per compressed vertex, as indices into `covered`.
    let mut row_edges: Vec<Vec<u32>> = vec![Vec::new(); nl];
    let mut col_edges: Vec<Vec<u32>> = vec![Vec::new(); nr];
    {
        let mut eid = 0u32;
        let mut row_of_gid = vec![u32::MAX; block.nrows];
        for (k, &r) in cmp.row_ids.iter().enumerate() {
            row_of_gid[r as usize] = k as u32;
        }
        for gr in 0..block.nrows {
            for &gc in block.row_indices(gr) {
                let l = row_of_gid[gr] as usize;
                let r = cmp.col_of[gc as usize] as usize;
                row_edges[l].push(eid);
                col_edges[r].push(eid);
                eid += 1;
            }
        }
    }
    let row_w = |l: usize| -> u64 {
        weights
            .row
            .as_ref()
            .map(|w| w[cmp.row_ids[l] as usize])
            .unwrap_or(1)
    };
    let col_w = |r: usize| -> u64 {
        weights
            .col
            .as_ref()
            .map(|w| w[cmp.col_ids[r] as usize])
            .unwrap_or(1)
    };
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut cost = 0u64;
    let mut remaining = block.nnz();
    while remaining > 0 {
        // Pick best ratio uncovered/weight across all vertices.
        let mut best: (f64, bool, usize, usize) = (-1.0, false, 0, 0); // (ratio, is_col, idx, gain)
        for l in 0..nl {
            let gain = row_edges[l].iter().filter(|&&e| !covered[e as usize]).count();
            if gain == 0 {
                continue;
            }
            let ratio = gain as f64 / row_w(l) as f64;
            if ratio > best.0 {
                best = (ratio, false, l, gain);
            }
        }
        for r in 0..nr {
            let gain = col_edges[r].iter().filter(|&&e| !covered[e as usize]).count();
            if gain == 0 {
                continue;
            }
            let ratio = gain as f64 / col_w(r) as f64;
            if ratio > best.0 {
                best = (ratio, true, r, gain);
            }
        }
        let (_, is_col, idx, gain) = best;
        debug_assert!(gain > 0);
        if is_col {
            for &e in &col_edges[idx] {
                covered[e as usize] = true;
            }
            cols.push(cmp.col_ids[idx]);
            cost += col_w(idx);
        } else {
            for &e in &row_edges[idx] {
                covered[e as usize] = true;
            }
            rows.push(cmp.row_ids[idx]);
            cost += row_w(idx);
        }
        remaining -= gain;
    }
    rows.sort_unstable();
    cols.sort_unstable();
    CoverSolution { rows, cols, cost }
}

/// Split a block's nonzeros by the cover decision (workflow step 2):
/// `a_row` holds nonzeros served row-based (their row is in the cover;
/// this portion is *shipped to the owner q* at plan time), `a_col` the
/// rest (their column is guaranteed covered; stays at p).
pub fn split_by_cover(block: &Csr, sol: &CoverSolution) -> (Csr, Csr) {
    let rsel = mask(block.nrows, &sol.rows);
    let mut row_coo = crate::sparse::Coo::new(block.nrows, block.ncols);
    let mut col_coo = crate::sparse::Coo::new(block.nrows, block.ncols);
    for r in 0..block.nrows {
        let vals = block.row_values(r);
        for (k, &c) in block.row_indices(r).iter().enumerate() {
            if rsel[r] {
                row_coo.push(r, c as usize, vals[k]);
            } else {
                col_coo.push(r, c as usize, vals[k]);
            }
        }
    }
    (row_coo.to_csr(), col_coo.to_csr())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn all_solvers() -> [Solver; 3] {
        [Solver::Koenig, Solver::Dinic, Solver::Greedy]
    }

    #[test]
    fn fig5_pattern_mu_values() {
        // Paper Fig. 5: |Rows|, |Cols|, μ, reduction% table.
        let expect = [
            ("row-skewed", 2usize, 4usize, 2usize, 0.0),
            ("col-skewed", 4, 2, 2, 0.0),
            ("uniform", 4, 4, 4, 0.0),
            ("mixed", 4, 4, 2, 50.0),
        ];
        for ((name, m), (ename, rows, cols, mu, red)) in
            gen::fig5_patterns().iter().zip(expect)
        {
            assert_eq!(*name, ename);
            assert_eq!(m.nonempty_rows().len(), rows, "{name} Rows");
            assert_eq!(m.nonempty_cols().len(), cols, "{name} Cols");
            let sol = solve(m, Solver::Koenig, &Weights::default());
            assert!(sol.is_valid_for(m), "{name} invalid cover");
            assert_eq!(sol.mu(), mu, "{name} μ");
            let single_best = rows.min(cols) as f64;
            let reduction = 100.0 * (1.0 - sol.mu() as f64 / single_best);
            assert!((reduction - red).abs() < 1e-9, "{name} reduction {reduction}");
        }
    }

    #[test]
    fn fig4_example_matrix() {
        // Paper Fig. 4: nonzeros {b,c,d,f,h}; optimal cover = {row 1, col 7},
        // μ = 2. Entries (from Fig. 1(d)): row 0: cols 5,6,7; row 1: col 6;
        // row 2: col 6. Rebased to a 3x3 block with cols {5,6,7}→{0,1,2}:
        let mut coo = crate::sparse::Coo::new(3, 8);
        coo.push(0, 5, 1.0);
        coo.push(0, 6, 1.0);
        coo.push(0, 7, 1.0);
        coo.push(1, 6, 1.0);
        coo.push(2, 6, 1.0);
        let m = coo.to_csr();
        let sol = solve(&m, Solver::Koenig, &Weights::default());
        assert!(sol.is_valid_for(&m));
        assert_eq!(sol.mu(), 2);
        // Column-based would need 3 (cols 5,6,7); row-based 3 (rows 0,1,2).
        assert_eq!(m.nonempty_cols().len(), 3);
        assert_eq!(m.nonempty_rows().len(), 3);
    }

    #[test]
    fn koenig_matches_dinic_uniform() {
        for seed in 0..10 {
            let m = gen::erdos_renyi(40, 40, 120, seed);
            let k = solve(&m, Solver::Koenig, &Weights::default());
            let d = solve(&m, Solver::Dinic, &Weights::default());
            assert!(k.is_valid_for(&m));
            assert!(d.is_valid_for(&m));
            assert_eq!(k.cost, d.cost, "seed {seed}: König {} vs Dinic {}", k.cost, d.cost);
        }
    }

    #[test]
    fn optimal_never_worse_than_single_strategies() {
        for seed in 0..8 {
            let m = gen::powerlaw(64, 400, 1.4, seed);
            let sol = solve(&m, Solver::Koenig, &Weights::default());
            assert!(sol.mu() <= m.nonempty_cols().len());
            assert!(sol.mu() <= m.nonempty_rows().len());
        }
    }

    #[test]
    fn greedy_valid_but_maybe_suboptimal() {
        for seed in 0..8 {
            let m = gen::rmat(64, 300, (0.5, 0.2, 0.2), false, seed);
            let g = solve(&m, Solver::Greedy, &Weights::default());
            let opt = solve(&m, Solver::Koenig, &Weights::default());
            assert!(g.is_valid_for(&m), "seed {seed}");
            assert!(g.cost >= opt.cost, "greedy beat optimal?!");
        }
    }

    #[test]
    fn weighted_dinic_respects_weights() {
        // Cross pattern: row 0 covers {(0,0),(0,1)}, cols {0,1} also cover
        // them. With row weight 10 and col weight 1, cols win even though
        // the uniform optimum would pick the row.
        let mut coo = crate::sparse::Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        let m = coo.to_csr();
        let w = Weights {
            row: Some(vec![10, 10]),
            col: Some(vec![1, 1]),
        };
        let sol = solve(&m, Solver::Dinic, &w);
        assert!(sol.is_valid_for(&m));
        assert_eq!(sol.cost, 2);
        assert_eq!(sol.rows.len(), 0);
        assert_eq!(sol.cols.len(), 2);
    }

    #[test]
    fn column_only_and_row_only() {
        let m = gen::erdos_renyi(30, 30, 90, 3);
        let c = solve(&m, Solver::ColumnOnly, &Weights::default());
        assert!(c.is_valid_for(&m));
        assert_eq!(c.cols, m.nonempty_cols());
        let r = solve(&m, Solver::RowOnly, &Weights::default());
        assert!(r.is_valid_for(&m));
        assert_eq!(r.rows, m.nonempty_rows());
    }

    #[test]
    fn empty_block() {
        let m = Csr::zeros(5, 5);
        for s in all_solvers() {
            let sol = solve(&m, s, &Weights::default());
            assert_eq!(sol.mu(), 0);
            assert!(sol.is_valid_for(&m));
        }
    }

    #[test]
    fn split_by_cover_partitions_nnz() {
        let m = gen::powerlaw(64, 500, 1.5, 4);
        let sol = solve(&m, Solver::Koenig, &Weights::default());
        let (a_row, a_col) = split_by_cover(&m, &sol);
        assert_eq!(a_row.nnz() + a_col.nnz(), m.nnz());
        // a_row's rows ⊆ selected rows.
        assert!(a_row.nonempty_rows().iter().all(|r| sol.rows.contains(r)));
        // a_col's cols ⊆ selected cols.
        assert!(a_col.nonempty_cols().iter().all(|c| sol.cols.contains(c)));
        // Values preserved: sum check.
        let total: f32 = m.data.iter().sum();
        let split: f32 = a_row.data.iter().sum::<f32>() + a_col.data.iter().sum::<f32>();
        assert!((total - split).abs() < 1e-3);
    }

    #[test]
    fn dense_block_cover_small() {
        // Fully dense k×k block: μ = k (cover one full side).
        let mut coo = crate::sparse::Coo::new(3, 3);
        for r in 0..3 {
            for c in 0..3 {
                coo.push(r, c, 1.0);
            }
        }
        let m = coo.to_csr();
        let sol = solve(&m, Solver::Koenig, &Weights::default());
        assert_eq!(sol.mu(), 3);
    }
}
