//! Row-major dense matrix substrate used for B/C blocks and GNN features.

/// Row-major f32 dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<f32>,
}

impl Dense {
    pub fn zeros(nrows: usize, ncols: usize) -> Dense {
        Dense {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    pub fn from_elem(nrows: usize, ncols: usize, v: f32) -> Dense {
        Dense {
            nrows,
            ncols,
            data: vec![v; nrows * ncols],
        }
    }

    pub fn from_fn(nrows: usize, ncols: usize, f: impl Fn(usize, usize) -> f32) -> Dense {
        let mut data = Vec::with_capacity(nrows * ncols);
        for i in 0..nrows {
            for j in 0..ncols {
                data.push(f(i, j));
            }
        }
        Dense { nrows, ncols, data }
    }

    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f32>) -> Dense {
        assert_eq!(data.len(), nrows * ncols);
        Dense { nrows, ncols, data }
    }

    /// Deterministic random matrix (for workloads / GNN features).
    pub fn random(nrows: usize, ncols: usize, rng: &mut crate::util::rng::Rng) -> Dense {
        let data = (0..nrows * ncols).map(|_| rng.f32() * 2.0 - 1.0).collect();
        Dense { nrows, ncols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.ncols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.ncols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    /// Copy the rows at `rows` (in order) into a new matrix — the "pack B
    /// rows for sending" primitive of sparsity-aware communication.
    pub fn gather_rows(&self, rows: &[u32]) -> Dense {
        let mut out = Dense::zeros(rows.len(), self.ncols);
        self.gather_rows_into(rows, &mut out);
        out
    }

    /// [`Dense::gather_rows`] into a caller-provided (pooled) buffer of
    /// shape `rows.len() × self.ncols` — the executor pipeline's
    /// allocation-free pack primitive.
    pub fn gather_rows_into(&self, rows: &[u32], out: &mut Dense) {
        assert_eq!(out.nrows, rows.len());
        assert_eq!(out.ncols, self.ncols);
        for (i, &r) in rows.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r as usize));
        }
    }

    /// C[rows[i], :] += src[i, :] — the "unpack received C partials"
    /// primitive (result aggregation).
    pub fn scatter_add_rows(&mut self, rows: &[u32], src: &Dense) {
        assert_eq!(rows.len(), src.nrows);
        assert_eq!(self.ncols, src.ncols);
        for (i, &r) in rows.iter().enumerate() {
            let dst = self.row_mut(r as usize);
            for (d, s) in dst.iter_mut().zip(src.row(i)) {
                *d += s;
            }
        }
    }

    /// Elementwise addition: self += other.
    pub fn add_assign(&mut self, other: &Dense) {
        assert_eq!(self.nrows, other.nrows);
        assert_eq!(self.ncols, other.ncols);
        for (d, s) in self.data.iter_mut().zip(&other.data) {
            *d += s;
        }
    }

    /// Dense GEMM: self (m×k) · other (k×n). Reference implementation.
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.ncols, other.nrows);
        let (m, k, n) = (self.nrows, self.ncols, other.ncols);
        let mut out = Dense::zeros(m, n);
        for i in 0..m {
            for l in 0..k {
                let a = self.get(i, l);
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(l);
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Transposed GEMM: selfᵀ (k×m becomes m-inner) · other — used in GNN
    /// backward for weight gradients without materializing the transpose.
    pub fn t_matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.nrows, other.nrows);
        let (k, m, n) = (self.nrows, self.ncols, other.ncols);
        let mut out = Dense::zeros(m, n);
        for l in 0..k {
            let arow = self.row(l);
            let brow = other.row(l);
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Frobenius-norm of the difference, for test tolerances.
    pub fn diff_norm(&self, other: &Dense) -> f64 {
        assert_eq!(self.nrows, other.nrows);
        assert_eq!(self.ncols, other.ncols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn construction() {
        let d = Dense::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(d.get(1, 2), 5.0);
        assert_eq!(d.row(0), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let d = Dense::from_fn(5, 2, |i, _| i as f32);
        let g = d.gather_rows(&[4, 0, 2]);
        assert_eq!(g.row(0), &[4.0, 4.0]);
        assert_eq!(g.row(2), &[2.0, 2.0]);
        let mut acc = Dense::zeros(5, 2);
        acc.scatter_add_rows(&[4, 0, 2], &g);
        assert_eq!(acc.get(4, 0), 4.0);
        assert_eq!(acc.get(0, 1), 0.0);
        assert_eq!(acc.get(2, 0), 2.0);
        assert_eq!(acc.get(1, 0), 0.0);
    }

    #[test]
    fn scatter_add_accumulates() {
        let src = Dense::from_elem(2, 1, 1.0);
        let mut dst = Dense::zeros(3, 1);
        dst.scatter_add_rows(&[1, 1], &src);
        assert_eq!(dst.get(1, 0), 2.0);
    }

    #[test]
    fn matmul_reference() {
        let a = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Dense::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Dense::random(4, 3, &mut rng);
        let b = Dense::random(4, 5, &mut rng);
        let at = Dense::from_fn(3, 4, |i, j| a.get(j, i));
        let want = at.matmul(&b);
        let got = a.t_matmul(&b);
        assert!(want.diff_norm(&got) < 1e-5);
    }

    #[test]
    fn diff_norm_zero_for_same() {
        let d = Dense::from_elem(3, 3, 2.0);
        assert_eq!(d.diff_norm(&d), 0.0);
    }

    #[test]
    fn add_assign_works() {
        let mut a = Dense::from_elem(2, 2, 1.0);
        a.add_assign(&Dense::from_elem(2, 2, 2.0));
        assert_eq!(a.data, vec![3.0; 4]);
    }
}
