//! Kernel abstraction for the distributed executor: the *distributed* op a
//! plan executes ([`KernelOp`]) and the *local* compute backend that op
//! dispatches to ([`SpmmKernel`] — native Rust here, the AOT-compiled
//! Pallas/XLA kernel via [`crate::runtime`]).
//!
//! One communication plan serves all three distributed kernels (DESIGN.md
//! §9): SpMM moves B rows in and partial C rows out; SDDMM moves dense
//! rows *to the sparse pattern's owners* (the plan's B covers as-is plus
//! its C covers reversed) and computes each edge value exactly once; the
//! fused SDDMM→SpMM kernel computes edge values and immediately consumes
//! them as the SpMM operand — no second exchange. The local trait below
//! therefore covers all three: plain SpMM, SDDMM value computation, and
//! SpMM with an override values buffer (the fused primitive). Every new
//! method has a native default, so whole-matrix backends (PJRT) keep
//! working unchanged and fall back to the native loops for the new ops.

use crate::dense::Dense;
use crate::sparse::Csr;

/// Which distributed kernel a plan executes — the kernel parameter on
/// sessions ([`crate::exec::SpmmSession`]) and the one-shot entry points.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelOp {
    /// C = A·B: B rows in (column-based), partial C rows out (row-based).
    #[default]
    Spmm,
    /// E = A ⊙ (X·Yᵀ) on A's pattern: dense rows ship to wherever the
    /// plan placed each nonzero (B covers forward, C covers reversed);
    /// the output stays plan-distributed and is assembled outside the
    /// exchange. Stage-I-only dataflow — no aggregation.
    Sddmm,
    /// C = (A ⊙ (X·Yᵀ))·Y: SDDMM whose edge values feed the SpMM in
    /// place (GAT-style attention). One exchange of X and Y rows in,
    /// aggregated partial C rows out.
    FusedSddmmSpmm,
}

impl KernelOp {
    pub fn name(&self) -> &'static str {
        match self {
            KernelOp::Spmm => "spmm",
            KernelOp::Sddmm => "sddmm",
            KernelOp::FusedSddmmSpmm => "fused-sddmm-spmm",
        }
    }
}

/// A local compute kernel: SpMM (C = A·B and variants), SDDMM value
/// computation, and values-override SpMM — everything the distributed
/// executor dispatches per rank.
pub trait SpmmKernel: Sync {
    fn spmm(&self, a: &Csr, b: &Dense) -> Dense;

    fn spmm_acc(&self, a: &Csr, b: &Dense, c: &mut Dense) {
        let partial = self.spmm(a, b);
        c.add_assign(&partial);
    }

    /// Row-tile SpMM for the overlapped executor pipeline: accumulate rows
    /// `r0..r1` of A·B into the same rows of `c`. The default runs the
    /// native CSR row loop, which is bitwise-identical to `Csr::spmm_acc`
    /// restricted to those rows — backends whose full-matrix path differs
    /// numerically from the native loop should return `false` from
    /// [`SpmmKernel::prefers_tiles`] so the executor hands them whole
    /// blocks through `spmm_acc` instead.
    fn spmm_rows(&self, a: &Csr, b: &Dense, c: &mut Dense, r0: usize, r1: usize) {
        a.spmm_rows_acc(b, c, r0, r1);
    }

    /// Row-tile SDDMM: write `vals[k] = a.data[k]·⟨x_row, y_col⟩` for
    /// every stored entry of rows `r0..r1` (entry-order buffer). Entries
    /// are independent, so tiling cannot change the bits; any backend
    /// override must keep the ascending-feature dot order to stay
    /// bitwise-compatible with the serial [`Csr::sddmm`] oracle.
    fn sddmm_rows(&self, a: &Csr, x: &Dense, y: &Dense, vals: &mut [f32], r0: usize, r1: usize) {
        a.sddmm_rows_into(x, y, vals, r0, r1);
    }

    /// Whole-pattern SDDMM (the non-tiled entry point).
    fn sddmm_vals(&self, a: &Csr, x: &Dense, y: &Dense, vals: &mut [f32]) {
        self.sddmm_rows(a, x, y, vals, 0, a.nrows);
    }

    /// Row-tile SpMM with an override values buffer (fused SDDMM→SpMM
    /// consumption: the freshly computed edge values multiply B without
    /// materializing a value-swapped matrix).
    fn spmm_vals_rows(
        &self,
        a: &Csr,
        vals: &[f32],
        b: &Dense,
        c: &mut Dense,
        r0: usize,
        r1: usize,
    ) {
        a.spmm_vals_rows_acc(vals, b, c, r0, r1);
    }

    /// Whole-pattern values-override SpMM accumulation.
    fn spmm_vals_acc(&self, a: &Csr, vals: &[f32], b: &Dense, c: &mut Dense) {
        self.spmm_vals_rows(a, vals, b, c, 0, a.nrows);
    }

    /// Whether the executor may split this kernel's diagonal SpMM into row
    /// tiles. Backends with whole-matrix entry points (AOT/XLA artifacts
    /// compiled for fixed shapes) return `false`; the pipeline then runs
    /// the diagonal as one `spmm_acc` call so every local SpMM still goes
    /// through the backend.
    fn prefers_tiles(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str;
}

/// Pure-Rust CSR kernels (the serial reference path for every op).
pub struct NativeKernel;

impl SpmmKernel for NativeKernel {
    fn spmm(&self, a: &Csr, b: &Dense) -> Dense {
        a.spmm(b)
    }

    fn spmm_acc(&self, a: &Csr, b: &Dense, c: &mut Dense) {
        a.spmm_acc(b, c);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn native_matches_reference() {
        let a = gen::rmat(64, 400, (0.5, 0.2, 0.2), false, 1);
        let mut rng = Rng::new(2);
        let b = Dense::random(64, 8, &mut rng);
        let k = NativeKernel;
        assert_eq!(k.spmm(&a, &b), a.spmm(&b));
        assert_eq!(k.name(), "native");
    }

    #[test]
    fn default_acc_matches_specialized() {
        let a = gen::erdos_renyi(32, 32, 100, 3);
        let mut rng = Rng::new(4);
        let b = Dense::random(32, 4, &mut rng);
        let mut c1 = Dense::from_elem(32, 4, 0.5);
        let mut c2 = c1.clone();
        NativeKernel.spmm_acc(&a, &b, &mut c1);
        let partial = NativeKernel.spmm(&a, &b);
        c2.add_assign(&partial);
        assert!(c1.diff_norm(&c2) < 1e-5);
    }

    #[test]
    fn sddmm_defaults_match_oracle_bitwise() {
        let a = gen::powerlaw(64, 500, 1.4, 5);
        let mut rng = Rng::new(8);
        let x = Dense::random(64, 6, &mut rng);
        let y = Dense::random(64, 6, &mut rng);
        let want = a.sddmm(&x, &y);
        let mut vals = vec![0.0f32; a.nnz()];
        NativeKernel.sddmm_vals(&a, &x, &y, &mut vals);
        assert_eq!(vals, want.data);
        // Tiled path, adversarial order.
        let mut vals2 = vec![0.0f32; a.nnz()];
        for r0 in (0..64).rev().step_by(5) {
            let lo = r0.saturating_sub(4);
            NativeKernel.sddmm_rows(&a, &x, &y, &mut vals2, lo, r0 + 1);
        }
        NativeKernel.sddmm_rows(&a, &x, &y, &mut vals2, 0, 64);
        assert_eq!(vals2, want.data);
    }

    #[test]
    fn fused_vals_spmm_matches_materialized() {
        let a = gen::rmat(48, 400, (0.5, 0.2, 0.2), false, 9);
        let mut rng = Rng::new(10);
        let x = Dense::random(48, 4, &mut rng);
        let y = Dense::random(48, 4, &mut rng);
        let e = a.sddmm(&x, &y);
        let want = e.spmm(&y);
        let mut got = Dense::zeros(48, 4);
        NativeKernel.spmm_vals_acc(&a, &e.data, &y, &mut got);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn kernel_op_names() {
        assert_eq!(KernelOp::Spmm.name(), "spmm");
        assert_eq!(KernelOp::Sddmm.name(), "sddmm");
        assert_eq!(KernelOp::FusedSddmmSpmm.name(), "fused-sddmm-spmm");
        assert_eq!(KernelOp::default(), KernelOp::Spmm);
    }
}
