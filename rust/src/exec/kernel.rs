//! Local SpMM compute backends for the executor: a native Rust kernel and
//! (via [`crate::runtime`]) the AOT-compiled Pallas/XLA kernel.

use crate::dense::Dense;
use crate::sparse::Csr;

/// A local SpMM kernel: computes C = A·B (and the accumulating variant).
pub trait SpmmKernel: Sync {
    fn spmm(&self, a: &Csr, b: &Dense) -> Dense;

    fn spmm_acc(&self, a: &Csr, b: &Dense, c: &mut Dense) {
        let partial = self.spmm(a, b);
        c.add_assign(&partial);
    }

    /// Row-tile SpMM for the overlapped executor pipeline: accumulate rows
    /// `r0..r1` of A·B into the same rows of `c`. The default runs the
    /// native CSR row loop, which is bitwise-identical to `Csr::spmm_acc`
    /// restricted to those rows — backends whose full-matrix path differs
    /// numerically from the native loop should return `false` from
    /// [`SpmmKernel::prefers_tiles`] so the executor hands them whole
    /// blocks through `spmm_acc` instead.
    fn spmm_rows(&self, a: &Csr, b: &Dense, c: &mut Dense, r0: usize, r1: usize) {
        a.spmm_rows_acc(b, c, r0, r1);
    }

    /// Whether the executor may split this kernel's diagonal SpMM into row
    /// tiles. Backends with whole-matrix entry points (AOT/XLA artifacts
    /// compiled for fixed shapes) return `false`; the pipeline then runs
    /// the diagonal as one `spmm_acc` call so every local SpMM still goes
    /// through the backend.
    fn prefers_tiles(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str;
}

/// Pure-Rust CSR SpMM (the serial reference path).
pub struct NativeKernel;

impl SpmmKernel for NativeKernel {
    fn spmm(&self, a: &Csr, b: &Dense) -> Dense {
        a.spmm(b)
    }

    fn spmm_acc(&self, a: &Csr, b: &Dense, c: &mut Dense) {
        a.spmm_acc(b, c);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn native_matches_reference() {
        let a = gen::rmat(64, 400, (0.5, 0.2, 0.2), false, 1);
        let mut rng = Rng::new(2);
        let b = Dense::random(64, 8, &mut rng);
        let k = NativeKernel;
        assert_eq!(k.spmm(&a, &b), a.spmm(&b));
        assert_eq!(k.name(), "native");
    }

    #[test]
    fn default_acc_matches_specialized() {
        let a = gen::erdos_renyi(32, 32, 100, 3);
        let mut rng = Rng::new(4);
        let b = Dense::random(32, 4, &mut rng);
        let mut c1 = Dense::from_elem(32, 4, 0.5);
        let mut c2 = c1.clone();
        NativeKernel.spmm_acc(&a, &b, &mut c1);
        let partial = NativeKernel.spmm(&a, &b);
        c2.add_assign(&partial);
        assert!(c1.diff_norm(&c2) < 1e-5);
    }
}
