//! In-process multi-rank executor: every "GPU" is a thread exchanging real
//! messages over channels, running the five-stage SHIRO workflow (§5.1) as
//! an overlapped, double-buffered pipeline — exactly the data movement the
//! plan prescribes, so the numerics of every strategy can be verified
//! bit-for-bit against the serial reference.
//!
//! The pipeline (Alg. 1 §6.2, [`pipeline`]): each rank posts its outgoing
//! B payloads eagerly (before local diagonal compute), interleaves local
//! SpMM tiles with draining the incoming channel, and — under hierarchical
//! routing — overlaps stage-I inter-group sends with stage-II intra-group
//! scatter of previously completed flows, the group representative folding
//! pre-aggregation incrementally as partials arrive instead of after a
//! barrier. `ExecOpts { overlap: false }` is the phase-ordered ablation
//! control; both modes apply every scatter-add in canonical (origin, row)
//! order at the fold point, so their results are bit-identical for any
//! thread interleaving.
//!
//! Flat mode delivers the [`crate::comm::CommPlan`] directly; hierarchical
//! mode routes through the [`crate::hierarchy::HierSchedule`]'s per-rank
//! step programs ([`crate::hierarchy::HierSchedule::rank_steps`]) — the
//! same object the simulator lowers, so simulated and executed orderings
//! cannot drift apart.

pub mod kernel;
pub mod pipeline;
pub mod session;

pub use pipeline::ExecOpts;
pub use session::SpmmSession;

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::hierarchy::{phase, HierSchedule, Step};
use crate::metrics::{OverlapWindow, VolumeMatrix};
use crate::partition::{LocalBlocks, RowPartition};
use crate::topology::{Tier, Topology};
use kernel::SpmmKernel;
use pipeline::{
    ckey, gated, BufferPool, ComputeGate, OrderedFold, PoolRef, DIAG_KEY, KIND_B, KIND_C,
};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

/// A message between ranks. `from` is the link-level sender (used for
/// receiver-side tier accounting); `origin` on B payloads is the rank that
/// owns the rows (differs from `from` when a representative forwards).
/// Row index spaces: `B.rows` are origin-local B rows; `C.rows` /
/// `CAgg.rows` are destination-local C rows.
enum Msg {
    /// B rows owned by `origin` (column-based payload).
    B {
        from: usize,
        origin: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Partial C rows, ready to scatter-add at the destination.
    C {
        from: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Producer → representative partial C rows destined for `final_dst`
    /// (hierarchical row-based stage I).
    CAgg {
        from: usize,
        final_dst: usize,
        rows: Vec<u32>,
        data: Dense,
    },
}

impl Msg {
    fn bytes(&self) -> u64 {
        let (rows, data) = match self {
            Msg::B { rows, data, .. } => (rows, data),
            Msg::C { rows, data, .. } => (rows, data),
            Msg::CAgg { rows, data, .. } => (rows, data),
        };
        (rows.len() * 4 + data.size_bytes()) as u64
    }

    fn from_rank(&self) -> usize {
        match self {
            Msg::B { from, .. } | Msg::C { from, .. } | Msg::CAgg { from, .. } => *from,
        }
    }
}

/// One labeled interval of a rank's timeline (seconds since run start);
/// names come from [`crate::hierarchy::phase`] so executor chrome traces
/// line up with the simulator's stage names.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSpan {
    pub name: &'static str,
    pub start: f64,
    pub end: f64,
}

/// Per-rank execution statistics. Bytes are counted on **both** sides of
/// every link (sender totals must equal receiver totals per tier — the
/// accounting agreement the tests assert).
#[derive(Clone, Debug, Default)]
pub struct RankStats {
    pub intra_bytes_sent: u64,
    pub inter_bytes_sent: u64,
    pub intra_bytes_recv: u64,
    pub inter_bytes_recv: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    /// Measured bytes sent to each destination rank (volume-matrix row).
    pub sent_to: Vec<u64>,
    pub compute_secs: f64,
    /// Seconds blocked in `recv` with no compute left to hide it behind.
    pub idle_secs: f64,
    /// Bytes drained from the inbox while compute items remained (traffic
    /// the pipeline overlapped with useful work).
    pub overlapped_recv_bytes: u64,
    /// Bytes received in the idle drain tail.
    pub idle_recv_bytes: u64,
    /// Timeline of this rank's pipeline phases (chrome-trace export:
    /// [`crate::sim::trace::exec_to_chrome_json`]).
    pub phases: Vec<PhaseSpan>,
}

/// Aggregated executor output.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub per_rank: Vec<RankStats>,
    pub wall_secs: f64,
}

impl ExecStats {
    pub fn total_inter_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.inter_bytes_sent).sum()
    }
    pub fn total_intra_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.intra_bytes_sent).sum()
    }
    pub fn total_inter_recv_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.inter_bytes_recv).sum()
    }
    pub fn total_intra_recv_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.intra_bytes_recv).sum()
    }

    /// Measured per-pair traffic (bytes actually sent src→dst), in the
    /// same shape as the planner's volume accounting so the two can be
    /// cross-checked.
    pub fn measured_volume(&self) -> VolumeMatrix {
        let n = self.per_rank.len();
        let mut m = VolumeMatrix::zeros(n);
        for (src, r) in self.per_rank.iter().enumerate() {
            for (dst, &b) in r.sent_to.iter().enumerate() {
                m.add(src, dst, b);
            }
        }
        m
    }

    /// Overlap-window accounting across all ranks.
    pub fn overlap_window(&self) -> OverlapWindow {
        let mut w = OverlapWindow::default();
        for r in &self.per_rank {
            w.overlapped_bytes += r.overlapped_recv_bytes;
            w.idle_bytes += r.idle_recv_bytes;
            w.idle_secs += r.idle_secs;
            w.compute_secs += r.compute_secs;
        }
        w
    }
}

/// How messages are routed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Flat,
    Hierarchical,
}

struct Ctx<'a> {
    rank: usize,
    part: &'a RowPartition,
    plan: &'a CommPlan,
    sched: Option<&'a HierSchedule>,
    topo: &'a Topology,
    kernel: &'a dyn SpmmKernel,
    senders: &'a [Sender<Msg>],
    inbox: Receiver<Msg>,
    stats: RankStats,
    opts: ExecOpts,
    gate: Option<&'a ComputeGate>,
    t0: Instant,
    pool: PoolRef<'a>,
}

impl<'a> Ctx<'a> {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Record `[start, now]` under `name`, merging contiguous same-name
    /// spans so tight tile loops stay one slice in the trace.
    fn span(&mut self, name: &'static str, start: f64) {
        let end = self.now();
        if let Some(last) = self.stats.phases.last_mut() {
            if last.name == name && start - last.end < 1e-7 {
                last.end = end;
                return;
            }
        }
        self.stats.phases.push(PhaseSpan { name, start, end });
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        let bytes = msg.bytes();
        match self.topo.tier(self.rank, dst) {
            Tier::Intra => self.stats.intra_bytes_sent += bytes,
            Tier::Inter => self.stats.inter_bytes_sent += bytes,
        }
        self.stats.msgs_sent += 1;
        self.stats.sent_to[dst] += bytes;
        self.senders[dst]
            .send(msg)
            .expect("receiver hung up — peer rank panicked");
    }

    /// Receiver-side accounting: the mirror of [`Ctx::send`], keyed by the
    /// link-level sender so per-tier totals agree between both sides.
    fn recv_account(&mut self, msg: &Msg, overlapped: bool) {
        let bytes = msg.bytes();
        match self.topo.tier(msg.from_rank(), self.rank) {
            Tier::Intra => self.stats.intra_bytes_recv += bytes,
            Tier::Inter => self.stats.inter_bytes_recv += bytes,
        }
        self.stats.msgs_recv += 1;
        if overlapped {
            self.stats.overlapped_recv_bytes += bytes;
        } else {
            self.stats.idle_recv_bytes += bytes;
        }
    }
}

/// Execute distributed SpMM with default options (overlapped pipeline):
/// C = A·B where A was split by `part` into `plan` (and optionally `sched`
/// for hierarchical routing). `b` is the full dense input (each rank only
/// reads its own row block, mirroring the distributed layout); returns the
/// assembled global C.
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
) -> (Dense, ExecStats) {
    run_with(part, plan, blocks, sched, topo, b, kernel, &ExecOpts::default())
}

/// [`run`] with explicit [`ExecOpts`] (overlap on/off, tile height, worker
/// cap).
#[allow(clippy::too_many_arguments)]
pub fn run_with(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Dense, ExecStats) {
    assert_eq!(part.n, b.nrows);
    let nranks = part.nparts;
    assert_eq!(plan.nranks, nranks);
    let n_dense = b.ncols;

    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    let gate = (opts.workers > 0).then(|| ComputeGate::new(opts.workers));

    let t0 = Instant::now();
    let mut results: Vec<Option<(Dense, RankStats)>> = (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = &senders;
            let gate = gate.as_ref();
            let inbox = inbox.take().unwrap();
            let (r0, r1) = part.range(rank);
            let b_local = Dense::from_vec(
                r1 - r0,
                n_dense,
                b.data[r0 * n_dense..r1 * n_dense].to_vec(),
            );
            handles.push(scope.spawn(move || {
                let mut ctx = Ctx {
                    rank,
                    part,
                    plan,
                    sched,
                    topo,
                    kernel,
                    senders,
                    inbox,
                    stats: RankStats { sent_to: vec![0; nranks], ..RankStats::default() },
                    opts: *opts,
                    gate,
                    t0,
                    pool: PoolRef::Own(BufferPool::new()),
                };
                let prog =
                    build_program(rank, part, plan, sched, opts, kernel.prefers_tiles());
                let mut c_local = Dense::zeros(part.len(rank), n_dense);
                rank_main(&mut ctx, &blocks[rank], &b_local, &mut c_local, &prog);
                (rank, c_local, ctx.stats)
            }));
        }
        for h in handles {
            let (rank, c, stats) = h.join().expect("rank thread panicked");
            results[rank] = Some((c, stats));
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut c_global = Dense::zeros(part.n, n_dense);
    let mut per_rank = Vec::with_capacity(nranks);
    for (rank, slot) in results.into_iter().enumerate() {
        let (c_local, stats) = slot.unwrap();
        let (r0, r1) = part.range(rank);
        assert_eq!(c_local.nrows, r1 - r0);
        c_global.data[r0 * n_dense..r1 * n_dense].copy_from_slice(&c_local.data);
        per_rank.push(stats);
    }
    (c_global, ExecStats { per_rank, wall_secs: wall })
}

// ------------------------------------------------------- rank program ----

/// An eager outgoing B payload (gather + send; no SpMM on this side).
struct BPost {
    dst: usize,
    rows: Vec<u32>,
    phase: &'static str,
}

/// One unit of local compute, interleaved with inbox drains in overlap
/// mode.
enum Item {
    /// Row-based partial production for a direct destination (flat pairs
    /// and same-group hierarchical transfers): SpMM then `Msg::C`.
    ProduceDirectC { dst: usize },
    /// Hierarchical partial production for `c_flows[flow]`: SpMM then
    /// route to the flow's rep (or fold locally when rep == self).
    ProduceFlowC { flow: usize },
    /// One diagonal-block SpMM tile.
    DiagTile { r0: usize, r1: usize },
}

/// The fully derived per-rank program: what to send, what to compute, what
/// to expect, and in which canonical order contributions fold.
#[derive(Default)]
struct Program {
    b_posts: Vec<BPost>,
    items: Vec<Item>,
    /// Total incoming messages (of any kind) this rank must consume.
    expect_msgs: usize,
    /// Canonical contribution keys for the local C fold.
    fold_keys: Vec<u64>,
    /// Flow indices for which this rank is the pre-aggregation rep.
    agg_flows: Vec<usize>,
    /// origin → b_flow index for flows this rank redistributes as rep.
    rep_b: BTreeMap<usize, usize>,
}

/// Sends deferred by the phase-ordered (`overlap: false`) schedule.
#[derive(Default)]
struct Deferred {
    msgs: Vec<(usize, Msg)>,
    /// (final_dst, c_rows, partial) this rank both produced and reps.
    self_aggs: Vec<(usize, Vec<u32>, Dense)>,
}

/// Derive rank `rank`'s full program from the plan/schedule. A pure
/// function of (plan, schedule, options, kernel tiling preference) — the
/// session layer precomputes these once and replays them every epoch.
fn build_program(
    rank: usize,
    part: &RowPartition,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    opts: &ExecOpts,
    prefers_tiles: bool,
) -> Program {
    let mut p = match sched {
        None => flat_program(rank, part, plan),
        Some(s) => hier_program(rank, plan, s),
    };
    p.fold_keys.push(DIAG_KEY);
    // Diagonal tiles go last: partial production unblocks other ranks, the
    // diagonal only feeds this one. Kernels with whole-matrix entry points
    // (PJRT) get a single full-range tile, dispatched via `spmm_acc`.
    let my_rows = part.len(rank);
    let tile = if prefers_tiles { opts.tile() } else { usize::MAX };
    let mut r0 = 0;
    while r0 < my_rows {
        let r1 = r0.saturating_add(tile).min(my_rows);
        p.items.push(Item::DiagTile { r0, r1 });
        r0 = r1;
    }
    p
}

/// Flat all-to-all program: the [`CommPlan`] pairs, mirrored for the
/// expected-receive side. (A pair is expected iff its sender would emit it
/// — in particular a `full_block` pair over an empty source block sends
/// nothing and must not be awaited.)
fn flat_program(r: usize, part: &RowPartition, plan: &CommPlan) -> Program {
    let mut p = Program::default();
    for q in 0..plan.nranks {
        if q == r {
            continue;
        }
        // Column-based: B rows of ours that q needs.
        let pair = &plan.pairs[q][r];
        let rows: Vec<u32> = if pair.full_block {
            (0..part.len(r) as u32).collect()
        } else {
            pair.b_rows.clone()
        };
        if !rows.is_empty() {
            p.b_posts.push(BPost { dst: q, rows, phase: crate::sim::FLAT_STAGE });
        }
        // Row-based: partial C rows we compute for q.
        if !pair.c_rows.is_empty() {
            p.items.push(Item::ProduceDirectC { dst: q });
        }
        // Mirror of the above at peer q: what we expect to receive.
        let my = &plan.pairs[r][q];
        let in_rows = if my.full_block { part.len(q) } else { my.b_rows.len() };
        if in_rows > 0 {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_B, q));
        }
        if !my.c_rows.is_empty() {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, q));
        }
    }
    p
}

/// Hierarchical program: this rank's slice of the schedule's step stream
/// ([`HierSchedule::rank_steps`]) plus the mirrored receive expectations.
fn hier_program(r: usize, plan: &CommPlan, sched: &HierSchedule) -> Program {
    let mut p = Program::default();
    for step in sched.rank_steps(r) {
        match step {
            Step::InterB(i) => {
                let f = &sched.b_flows[i];
                p.b_posts.push(BPost {
                    dst: f.rep,
                    rows: f.rows.clone(),
                    phase: phase::S1_INTER_B,
                });
            }
            Step::ProduceC(i) => p.items.push(Item::ProduceFlowC { flow: i }),
            Step::DirectC(i) => {
                let (_, dst, rows) = &sched.direct_c[i];
                debug_assert_eq!(&plan.pairs[*dst][r].c_rows, rows);
                p.items.push(Item::ProduceDirectC { dst: *dst });
            }
            Step::DirectB(i) => {
                let (_, dst, rows) = &sched.direct_b[i];
                p.b_posts.push(BPost {
                    dst: *dst,
                    rows: rows.clone(),
                    phase: phase::S2_INTRA_B,
                });
            }
        }
    }
    // Expected receives + canonical fold keys, mirrored from the schedule.
    for (i, f) in sched.b_flows.iter().enumerate() {
        if f.rep == r {
            p.expect_msgs += 1; // the stage-I inter-group arrival
            p.rep_b.insert(f.src, i);
        }
        if let Some((_, rows)) = f.consumers.iter().find(|(c, _)| *c == r) {
            if !rows.is_empty() {
                p.fold_keys.push(ckey(KIND_B, f.src));
                if f.rep != r {
                    p.expect_msgs += 1; // forwarded to us as Msg::B
                }
            }
        }
    }
    for (src, dst, rows) in &sched.direct_b {
        if *dst == r && !rows.is_empty() {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_B, *src));
        }
    }
    for (i, f) in sched.c_flows.iter().enumerate() {
        if f.rep == r {
            p.agg_flows.push(i);
            p.expect_msgs += f.producers.iter().filter(|(q, _)| *q != r).count();
        }
        if f.dst == r {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, f.rep));
        }
    }
    for (src, dst, rows) in &sched.direct_c {
        if *dst == r && !rows.is_empty() {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, *src));
        }
    }
    p
}

// -------------------------------------------------- aggregation state ----

/// Rep-side pre-aggregation for one C flow: producer partials fold into the
/// union-row accumulator **in canonical producer order** (incrementally as
/// they arrive — out-of-order arrivals park in the [`OrderedFold`]).
struct AggFlow {
    dst: usize,
    rows: Vec<u32>,
    acc: Dense,
    fold: OrderedFold<(Vec<u32>, Dense)>,
}

impl AggFlow {
    fn new(f: &crate::hierarchy::CFlow, n_dense: usize, pool: &mut PoolRef) -> AggFlow {
        AggFlow {
            dst: f.dst,
            rows: f.rows.clone(),
            acc: pool.acquire(f.rows.len(), n_dense),
            fold: OrderedFold::new(
                f.producers.iter().map(|(q, _)| ckey(KIND_C, *q)).collect(),
            ),
        }
    }

    /// Offer one producer's partial; returns true when every producer has
    /// been folded (the aggregate is ready to ship).
    fn offer(
        &mut self,
        producer: usize,
        prows: Vec<u32>,
        data: Dense,
        pool: &mut PoolRef,
    ) -> bool {
        let AggFlow { rows, acc, fold, .. } = self;
        fold.offer(ckey(KIND_C, producer), (prows, data), |(pr, d)| {
            fold_rows(rows, acc, &pr, &d);
            pool.release(d);
        });
        fold.is_done()
    }
}

/// Scatter-add a producer's partial rows into the union-row accumulator
/// (rows sorted; indices resolved by binary search).
fn fold_rows(union_rows: &[u32], acc: &mut Dense, rows: &[u32], data: &Dense) {
    for (i, row) in rows.iter().enumerate() {
        let k = union_rows.binary_search(row).expect("row not in union");
        for (d, s) in acc.row_mut(k).iter_mut().zip(data.row(i)) {
            *d += s;
        }
    }
}

/// Ship a completed aggregate across the inter-group link (stage II ②).
fn complete_agg(ctx: &mut Ctx, aggs: &mut BTreeMap<usize, AggFlow>, final_dst: usize) {
    let t = ctx.now();
    let a = aggs.remove(&final_dst).expect("unknown agg flow");
    ctx.send(a.dst, Msg::C { from: ctx.rank, rows: a.rows, data: a.acc });
    ctx.span(phase::S2_INTER_C, t);
}

// ---------------------------------------------------- contribution fold ----

/// A locally-applied contribution to this rank's C block. Application
/// order is canonical — [`pipeline::OrderedFold`] — never arrival order.
enum Contribution {
    /// The diagonal block finished accumulating (every element's base).
    DiagDone,
    /// Column-based remote partial spanning the whole local block.
    AddFull(Dense),
    /// Row-based partial rows to scatter-add.
    AddRows(Vec<u32>, Dense),
    /// Structurally empty (e.g. a full-block pair with no column-served
    /// nonzeros): participates in the ordering only.
    Empty,
}

fn apply_contribution(c_local: &mut Dense, pool: &mut PoolRef, contrib: Contribution) {
    match contrib {
        Contribution::DiagDone | Contribution::Empty => {}
        Contribution::AddFull(d) => {
            c_local.add_assign(&d);
            pool.release(d);
        }
        Contribution::AddRows(rows, d) => {
            c_local.scatter_add_rows(&rows, &d);
            pool.release(d);
        }
    }
}

/// Whether a column-based remote partial applies as a compact row set
/// (sparse: few touched output rows) or as a full-block add. Shared by the
/// executor hot path and the session payload layout
/// ([`session`]) — the two must branch identically or the session pool
/// under-seeds and the zero-alloc guarantee silently breaks.
pub(crate) fn col_contribution_is_compact(touched: usize, block_rows: usize) -> bool {
    touched * 2 < block_rows.max(1)
}

/// Remote column-based computation for B rows arriving from `origin`: the
/// received rows are packed in `pair.b_rows` order, the column space of
/// the precomputed `a_col_compact` operand — multiply directly, then fold
/// the partial in canonical order (§Perf opt-1 + determinism contract).
/// Sparse partials (few touched output rows) park and apply as compact
/// row sets so neither the parked memory nor the apply-time add pays for
/// the whole block; dense partials add the full block in one pass.
fn offer_col_contribution(
    ctx: &mut Ctx,
    fold: &mut OrderedFold<Contribution>,
    c_local: &mut Dense,
    origin: usize,
    rows: &[u32],
    data: Dense,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let pair = &plan.pairs[ctx.rank][origin];
    let contrib = if pair.a_col_compact.nnz() == 0 {
        ctx.pool.release(data);
        Contribution::Empty
    } else {
        debug_assert_eq!(rows.len(), pair.a_col_compact.ncols);
        if !pair.full_block {
            debug_assert_eq!(rows, &pair.b_rows[..]);
        }
        let t = ctx.now();
        let mut partial = ctx.pool.acquire(c_local.nrows, data.ncols);
        let dt = gated(gate, || {
            let t0 = Instant::now();
            kernel.spmm_acc(&pair.a_col_compact, &data, &mut partial);
            t0.elapsed().as_secs_f64()
        });
        ctx.stats.compute_secs += dt;
        ctx.span(phase::COMPUTE_REMOTE, t);
        ctx.pool.release(data);
        // The branch is a pure function of the pair's structure, so it is
        // identical across modes/runs and determinism is preserved.
        let touched = pair.a_col_compact.nonempty_rows();
        if col_contribution_is_compact(touched.len(), c_local.nrows) {
            let mut compact = ctx.pool.acquire(touched.len(), partial.ncols);
            partial.gather_rows_into(&touched, &mut compact);
            ctx.pool.release(partial);
            Contribution::AddRows(touched, compact)
        } else {
            Contribution::AddFull(partial)
        }
    };
    fold.offer(ckey(KIND_B, origin), contrib, |c| {
        apply_contribution(c_local, &mut ctx.pool, c)
    });
}

/// Extract `want` rows (a subset of the sorted `have` rows) from `data`
/// into a pooled buffer.
fn gather_subset(pool: &mut PoolRef, have: &[u32], data: &Dense, want: &[u32]) -> Dense {
    let mut out = pool.acquire(want.len(), data.ncols);
    for (i, w) in want.iter().enumerate() {
        let k = have.binary_search(w).expect("subset violation");
        out.row_mut(i).copy_from_slice(data.row(k));
    }
    out
}

// ------------------------------------------------------------ driver ----

/// The per-rank program: workflow steps 3–5 of §5.1 (steps 1–2 are the
/// offline planning already captured in `plan`/`sched`, and the program
/// derivation in `prog`), scheduled either as the overlapped pipeline or
/// strictly phase-ordered. `c_local` must arrive zeroed and shaped to this
/// rank's block; sessions pass persistent buffers here.
fn rank_main(
    ctx: &mut Ctx,
    blocks: &LocalBlocks,
    b_local: &Dense,
    c_local: &mut Dense,
    prog: &Program,
) {
    let n_dense = b_local.ncols;
    debug_assert_eq!(blocks.diag.nrows, ctx.part.len(ctx.rank));
    debug_assert_eq!(c_local.nrows, ctx.part.len(ctx.rank));
    let c_local = &mut *c_local;

    let mut fold = OrderedFold::new(prog.fold_keys.clone());
    let mut aggs: BTreeMap<usize, AggFlow> = prog
        .agg_flows
        .iter()
        .map(|&i| {
            let f = &ctx.sched.expect("agg flows imply a schedule").c_flows[i];
            (f.dst, AggFlow::new(f, n_dense, &mut ctx.pool))
        })
        .collect();
    let mut diag_left = prog
        .items
        .iter()
        .filter(|i| matches!(i, Item::DiagTile { .. }))
        .count();
    if diag_left == 0 {
        // Zero-row block: the base "contribution" is trivially complete.
        fold.offer(DIAG_KEY, Contribution::DiagDone, |c| {
            apply_contribution(c_local, &mut ctx.pool, c)
        });
    }
    let mut got = 0usize;

    if ctx.opts.overlap {
        // Overlapped pipeline: eager posts, then compute interleaved with
        // non-blocking drains of whatever has already arrived.
        post_b(ctx, prog, b_local);
        for item in &prog.items {
            while let Ok(msg) = ctx.inbox.try_recv() {
                got += 1;
                on_msg(ctx, prog, msg, c_local, &mut fold, &mut aggs, true);
            }
            run_item(
                ctx,
                item,
                blocks,
                b_local,
                c_local,
                &mut fold,
                &mut aggs,
                &mut diag_left,
                None,
            );
        }
    } else {
        // Phase-ordered control: all local compute with sends deferred,
        // then one blocking exchange + aggregation.
        let mut deferred = Deferred::default();
        for item in &prog.items {
            run_item(
                ctx,
                item,
                blocks,
                b_local,
                c_local,
                &mut fold,
                &mut aggs,
                &mut diag_left,
                Some(&mut deferred),
            );
        }
        post_b(ctx, prog, b_local);
        for (dst, msg) in deferred.msgs.drain(..) {
            ctx.send(dst, msg);
        }
        for (final_dst, rows, data) in deferred.self_aggs.drain(..) {
            let rank = ctx.rank;
            let agg = aggs.get_mut(&final_dst).expect("unknown agg flow");
            if agg.offer(rank, rows, data, &mut ctx.pool) {
                complete_agg(ctx, &mut aggs, final_dst);
            }
        }
    }

    // Idle drain: block for whatever is still in flight.
    while got < prog.expect_msgs {
        let t_idle = ctx.now();
        let msg = ctx.inbox.recv().expect("inbox closed — peer rank panicked");
        ctx.stats.idle_secs += ctx.now() - t_idle;
        ctx.span(phase::IDLE, t_idle);
        got += 1;
        on_msg(ctx, prog, msg, c_local, &mut fold, &mut aggs, false);
    }
    debug_assert!(fold.is_done(), "rank {}: fold incomplete", ctx.rank);
    debug_assert!(aggs.is_empty(), "rank {}: unshipped aggregates", ctx.rank);
}

/// Gather and send every outgoing B payload (cheap packs — no SpMM), in
/// program order: inter-group flows first, then same-group directs.
fn post_b(ctx: &mut Ctx, prog: &Program, b_local: &Dense) {
    for post in &prog.b_posts {
        let t = ctx.now();
        let mut data = ctx.pool.acquire(post.rows.len(), b_local.ncols);
        b_local.gather_rows_into(&post.rows, &mut data);
        ctx.send(
            post.dst,
            Msg::B { from: ctx.rank, origin: ctx.rank, rows: post.rows.clone(), data },
        );
        ctx.span(post.phase, t);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_item(
    ctx: &mut Ctx,
    item: &Item,
    blocks: &LocalBlocks,
    b_local: &Dense,
    c_local: &mut Dense,
    fold: &mut OrderedFold<Contribution>,
    aggs: &mut BTreeMap<usize, AggFlow>,
    diag_left: &mut usize,
    mut defer: Option<&mut Deferred>,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let rank = ctx.rank;
    match item {
        Item::DiagTile { r0, r1 } => {
            let t = ctx.now();
            let dt = gated(gate, || {
                let t0 = Instant::now();
                if *r0 == 0 && *r1 == c_local.nrows {
                    // Whole block: dispatch through the backend's full
                    // spmm_acc (bitwise-identical for the native kernel;
                    // the AOT path for PJRT). Partial tiles use the native
                    // row loop.
                    kernel.spmm_acc(&blocks.diag, b_local, c_local);
                } else {
                    kernel.spmm_rows(&blocks.diag, b_local, c_local, *r0, *r1);
                }
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::COMPUTE_LOCAL, t);
            *diag_left -= 1;
            if *diag_left == 0 {
                fold.offer(DIAG_KEY, Contribution::DiagDone, |c| {
                    apply_contribution(c_local, &mut ctx.pool, c)
                });
            }
        }
        Item::ProduceDirectC { dst } => {
            let pair = &plan.pairs[*dst][rank];
            let ph = if ctx.sched.is_some() {
                phase::S1_INTRA_C
            } else {
                phase::COMPUTE_LOCAL
            };
            let t = ctx.now();
            let mut data = ctx.pool.acquire(pair.a_row_compact.nrows, b_local.ncols);
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.spmm_acc(&pair.a_row_compact, b_local, &mut data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(ph, t);
            let msg = Msg::C { from: rank, rows: pair.c_rows.clone(), data };
            match defer.as_deref_mut() {
                None => ctx.send(*dst, msg),
                Some(d) => d.msgs.push((*dst, msg)),
            }
        }
        Item::ProduceFlowC { flow } => {
            let sched = ctx.sched.expect("flow item implies a schedule");
            let f = &sched.c_flows[*flow];
            let pair = &plan.pairs[f.dst][rank];
            let t = ctx.now();
            let mut data = ctx.pool.acquire(pair.a_row_compact.nrows, b_local.ncols);
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.spmm_acc(&pair.a_row_compact, b_local, &mut data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::S1_INTRA_C, t);
            if f.rep == rank {
                match defer.as_deref_mut() {
                    None => {
                        let agg = aggs.get_mut(&f.dst).expect("unknown agg flow");
                        if agg.offer(rank, pair.c_rows.clone(), data, &mut ctx.pool) {
                            complete_agg(ctx, aggs, f.dst);
                        }
                    }
                    Some(d) => d.self_aggs.push((f.dst, pair.c_rows.clone(), data)),
                }
            } else {
                let msg =
                    Msg::CAgg { from: rank, final_dst: f.dst, rows: pair.c_rows.clone(), data };
                match defer.as_deref_mut() {
                    None => ctx.send(f.rep, msg),
                    Some(d) => d.msgs.push((f.rep, msg)),
                }
            }
        }
    }
}

/// Handle one arrived message: account it, route it (rep redistribution /
/// pre-aggregation), and fold its contribution in canonical order.
fn on_msg(
    ctx: &mut Ctx,
    prog: &Program,
    msg: Msg,
    c_local: &mut Dense,
    fold: &mut OrderedFold<Contribution>,
    aggs: &mut BTreeMap<usize, AggFlow>,
    overlapped: bool,
) {
    ctx.recv_account(&msg, overlapped);
    match msg {
        Msg::B { from, origin, rows, data } => {
            if let Some(&fi) = prog.rep_b.get(&origin) {
                // Stage-I inter-group flow arrival: we are the rep.
                debug_assert_eq!(from, origin);
                let sched = ctx.sched.expect("rep_b implies a schedule");
                let f = &sched.b_flows[fi];
                debug_assert_ne!(
                    ctx.topo.group_of(origin),
                    ctx.topo.group_of(ctx.rank),
                    "B flows cross groups by construction"
                );
                // Stage II ②: redistribute to in-group consumers...
                let t = ctx.now();
                let mut own: Option<(&[u32], Dense)> = None;
                for (consumer, crows) in &f.consumers {
                    let sub = gather_subset(&mut ctx.pool, &rows, &data, crows);
                    if *consumer == ctx.rank {
                        own = Some((crows.as_slice(), sub));
                    } else {
                        ctx.send(
                            *consumer,
                            Msg::B { from: ctx.rank, origin, rows: crows.clone(), data: sub },
                        );
                    }
                }
                ctx.span(phase::S2_INTRA_B, t);
                ctx.pool.release(data);
                // ...then compute and fold our own subset.
                if let Some((crows, sub)) = own {
                    offer_col_contribution(ctx, fold, c_local, origin, crows, sub);
                }
            } else {
                // Direct in-group B or rep→consumer distribution.
                offer_col_contribution(ctx, fold, c_local, origin, &rows, data);
            }
        }
        Msg::C { from, rows, data } => {
            fold.offer(ckey(KIND_C, from), Contribution::AddRows(rows, data), |c| {
                apply_contribution(c_local, &mut ctx.pool, c)
            });
        }
        Msg::CAgg { from, final_dst, rows, data } => {
            let agg = aggs.get_mut(&final_dst).expect("unknown agg flow");
            if agg.offer(from, rows, data, &mut ctx.pool) {
                complete_agg(ctx, aggs, final_dst);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::hierarchy;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;
    use crate::util::rng::Rng;
    use kernel::NativeKernel;

    fn verify(
        a: &crate::sparse::Csr,
        ranks: usize,
        strategy: Strategy,
        mode: Mode,
    ) -> ExecStats {
        verify_with(a, ranks, strategy, mode, &ExecOpts::default())
    }

    fn verify_with(
        a: &crate::sparse::Csr,
        ranks: usize,
        strategy: Strategy,
        mode: Mode,
        opts: &ExecOpts,
    ) -> ExecStats {
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(a, &part);
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let sched = match mode {
            Mode::Flat => None,
            Mode::Hierarchical => Some(hierarchy::build(&plan, &topo)),
        };
        let mut rng = Rng::new(42);
        let b = Dense::random(a.nrows, 16, &mut rng);
        let want = a.spmm(&b);
        let (got, stats) = run_with(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &b,
            &NativeKernel,
            opts,
        );
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(err < 1e-3, "{:?}/{mode:?}: rel err {err}", strategy);
        stats
    }

    #[test]
    fn flat_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 1);
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
            Strategy::Joint(Solver::Greedy),
        ] {
            verify(&a, 8, strategy, Mode::Flat);
        }
    }

    #[test]
    fn hier_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 2);
        for strategy in [
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            verify(&a, 8, strategy, Mode::Hierarchical);
        }
    }

    #[test]
    fn hier_across_datasets() {
        for (gen_fn, name) in [
            (gen::mesh2d(12, 3), "mesh"),
            (gen::powerlaw(128, 1200, 1.4, 3), "web"),
            (gen::banded_hub(128, 3, 4, 40, 3), "traffic"),
        ] {
            let _ = name;
            verify(&gen_fn, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn phase_ordered_mode_exact_everywhere() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 8);
        for mode in [Mode::Flat, Mode::Hierarchical] {
            verify_with(
                &a,
                8,
                Strategy::Joint(Solver::Koenig),
                mode,
                &ExecOpts::sequential(),
            );
        }
    }

    #[test]
    fn hier_reduces_inter_bytes_vs_flat() {
        // Web pattern with hubs: hierarchical dedup must cut inter-group
        // bytes actually sent (measured, not planned).
        let a = gen::powerlaw(256, 4000, 1.3, 4);
        let flat = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Flat);
        let hier = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        assert!(
            hier.total_inter_bytes() < flat.total_inter_bytes(),
            "hier {} !< flat {}",
            hier.total_inter_bytes(),
            flat.total_inter_bytes()
        );
    }

    #[test]
    fn various_rank_counts() {
        let a = gen::rmat(128, 2000, (0.5, 0.25, 0.15), false, 5);
        for ranks in [2, 3, 5, 8, 16] {
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Flat);
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn single_rank_degenerate() {
        let a = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 6);
        verify(&a, 1, Strategy::Joint(Solver::Koenig), Mode::Flat);
    }

    #[test]
    fn empty_matrix() {
        let a = crate::sparse::Csr::zeros(32, 32);
        let part = RowPartition::balanced(32, 4);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(4);
        let b = Dense::from_elem(32, 4, 1.0);
        let (got, _) = run(&part, &plan, &blocks, None, &topo, &b, &NativeKernel);
        assert_eq!(got, Dense::zeros(32, 4));
    }

    #[test]
    fn symmetric_matrix_symmetric_traffic() {
        // Joint strategy on a symmetric matrix should produce symmetric
        // measured traffic (Fig. 9's observation), unlike column-based.
        let a = gen::banded_hub(256, 3, 6, 60, 7);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let topo = Topology::tsubame4(8);
        let b = Dense::from_elem(256, 8, 1.0);

        let jplan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let jm = jplan.volume_matrix(8);
        let cplan = comm::plan(&blocks, &part, Strategy::Column, None);
        let cm = cplan.volume_matrix(8);
        assert!(
            jm.asymmetry() <= cm.asymmetry() + 1e-9,
            "joint {} vs column {}",
            jm.asymmetry(),
            cm.asymmetry()
        );
        // And both still compute the right answer.
        let want = a.spmm(&b);
        let (got, _) = run(&part, &jplan, &blocks, None, &topo, &b, &NativeKernel);
        assert!(want.diff_norm(&got) < 1e-3);
    }

    #[test]
    fn send_and_recv_byte_accounting_agree() {
        // Satellite fix: sender-side and receiver-side per-tier totals must
        // match exactly, including representative forwarding, and the
        // measured volume matrix must tell the same story.
        let a = gen::powerlaw(256, 4000, 1.35, 9);
        for mode in [Mode::Flat, Mode::Hierarchical] {
            for opts in [ExecOpts::default(), ExecOpts::sequential()] {
                let stats = verify_with(&a, 16, Strategy::Joint(Solver::Koenig), mode, &opts);
                assert_eq!(
                    stats.total_inter_bytes(),
                    stats.total_inter_recv_bytes(),
                    "{mode:?}/{opts:?}: inter sent != recv"
                );
                assert_eq!(
                    stats.total_intra_bytes(),
                    stats.total_intra_recv_bytes(),
                    "{mode:?}/{opts:?}: intra sent != recv"
                );
                let sent_msgs: u64 = stats.per_rank.iter().map(|r| r.msgs_sent).sum();
                let recv_msgs: u64 = stats.per_rank.iter().map(|r| r.msgs_recv).sum();
                assert_eq!(sent_msgs, recv_msgs);
                let mv = stats.measured_volume();
                assert_eq!(
                    mv.total(),
                    stats.total_inter_bytes() + stats.total_intra_bytes()
                );
                let topo = Topology::tsubame4(16);
                assert_eq!(
                    mv.inter_group_total(&topo.group_vec()),
                    stats.total_inter_bytes()
                );
            }
        }
    }

    #[test]
    fn overlap_and_phase_ordered_bit_identical() {
        // The determinism contract: canonical fold order makes overlap
        // on/off produce the same bits even on arbitrary float inputs.
        let a = gen::powerlaw(256, 4000, 1.4, 10);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let sched = hierarchy::build(&plan, &topo);
        let mut rng = Rng::new(3);
        let b = Dense::random(256, 16, &mut rng);
        let (c_on, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts::default(),
        );
        let (c_off, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts::sequential(),
        );
        assert_eq!(c_on.data, c_off.data, "overlap on/off must be bit-identical");
        // Tile height must not change bits either.
        let (c_tile, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts { tile_rows: 7, ..ExecOpts::default() },
        );
        assert_eq!(c_on.data, c_tile.data, "tile height changed the bits");
    }

    #[test]
    fn overlap_window_accounting_consistent() {
        let a = gen::rmat(256, 4000, (0.55, 0.2, 0.19), false, 11);
        let stats = verify(&a, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        let w = stats.overlap_window();
        let recv_total = stats.total_inter_recv_bytes() + stats.total_intra_recv_bytes();
        assert_eq!(w.overlapped_bytes + w.idle_bytes, recv_total);
        assert!(w.compute_secs > 0.0);
        // Phase-ordered mode overlaps nothing by definition.
        let seq = verify_with(
            &a,
            8,
            Strategy::Joint(Solver::Koenig),
            Mode::Hierarchical,
            &ExecOpts::sequential(),
        );
        assert_eq!(seq.overlap_window().overlapped_bytes, 0);
    }

    #[test]
    fn phase_log_uses_schedule_names() {
        let a = gen::rmat(128, 2000, (0.55, 0.2, 0.19), false, 12);
        let stats = verify(&a, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        let names: std::collections::BTreeSet<&str> = stats
            .per_rank
            .iter()
            .flat_map(|r| r.phases.iter().map(|p| p.name))
            .collect();
        assert!(names.contains(phase::COMPUTE_LOCAL), "{names:?}");
        let sched_phases = [
            phase::S1_INTER_B,
            phase::S1_INTRA_C,
            phase::S2_INTER_C,
            phase::S2_INTRA_B,
        ];
        assert!(
            sched_phases.iter().any(|p| names.contains(p)),
            "no Alg. 1 phase in executor log: {names:?}"
        );
        for r in &stats.per_rank {
            for p in &r.phases {
                assert!(p.end >= p.start);
            }
        }
    }

    #[test]
    fn worker_cap_changes_nothing() {
        let a = gen::rmat(192, 2500, (0.5, 0.22, 0.18), false, 13);
        let part = RowPartition::balanced(192, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let sched = hierarchy::build(&plan, &topo);
        let mut rng = Rng::new(17);
        let b = Dense::random(192, 8, &mut rng);
        let mut reference: Option<Dense> = None;
        for workers in [1usize, 2, 4, 8, 0] {
            let (c, _) = run_with(
                &part,
                &plan,
                &blocks,
                Some(&sched),
                &topo,
                &b,
                &NativeKernel,
                &ExecOpts { workers, ..ExecOpts::default() },
            );
            match &reference {
                None => reference = Some(c),
                Some(want) => assert_eq!(want.data, c.data, "workers={workers}"),
            }
        }
    }
}
