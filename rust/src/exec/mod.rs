//! In-process multi-rank executor: every "GPU" is a thread exchanging real
//! messages over channels, running the five-stage SHIRO workflow (§5.1) —
//! exactly the data movement the plan prescribes, so the numerics of every
//! strategy can be verified bit-for-bit against the serial reference.
//!
//! Flat mode delivers the [`crate::comm::CommPlan`] directly; hierarchical
//! mode routes through the [`crate::hierarchy::HierSchedule`] with
//! representative forwarding and in-group pre-aggregation (Alg. 1).

pub mod kernel;

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::hierarchy::HierSchedule;
use crate::partition::RowPartition;
use crate::topology::{Tier, Topology};
use kernel::SpmmKernel;
use std::sync::mpsc::{channel, Receiver, Sender};

/// A message between ranks. Row index spaces: `B.rows` are origin-local B
/// rows; `C.rows` / `CAgg.rows` are destination-local C rows.
enum Msg {
    /// B rows owned by `origin` (column-based payload).
    B {
        origin: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Partial C rows, ready to scatter-add at the destination.
    C { rows: Vec<u32>, data: Dense },
    /// Producer → representative partial C rows destined for `final_dst`
    /// (hierarchical row-based stage I).
    CAgg {
        final_dst: usize,
        rows: Vec<u32>,
        data: Dense,
    },
}

impl Msg {
    fn bytes(&self) -> u64 {
        let (rows, data) = match self {
            Msg::B { rows, data, .. } => (rows, data),
            Msg::C { rows, data } => (rows, data),
            Msg::CAgg { rows, data, .. } => (rows, data),
        };
        (rows.len() * 4 + data.size_bytes()) as u64
    }
}

/// Per-rank execution statistics.
#[derive(Clone, Debug, Default)]
pub struct RankStats {
    pub intra_bytes_sent: u64,
    pub inter_bytes_sent: u64,
    pub msgs_sent: u64,
    pub compute_secs: f64,
}

/// Aggregated executor output.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub per_rank: Vec<RankStats>,
    pub wall_secs: f64,
}

impl ExecStats {
    pub fn total_inter_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.inter_bytes_sent).sum()
    }
    pub fn total_intra_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.intra_bytes_sent).sum()
    }
}

/// How messages are routed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Flat,
    Hierarchical,
}

struct Ctx<'a> {
    rank: usize,
    part: &'a RowPartition,
    plan: &'a CommPlan,
    sched: Option<&'a HierSchedule>,
    topo: &'a Topology,
    kernel: &'a dyn SpmmKernel,
    senders: &'a [Sender<Msg>],
    inbox: Receiver<Msg>,
    stats: RankStats,
}

impl<'a> Ctx<'a> {
    fn send(&mut self, dst: usize, msg: Msg) {
        let bytes = msg.bytes();
        match self.topo.tier(self.rank, dst) {
            Tier::Intra => self.stats.intra_bytes_sent += bytes,
            Tier::Inter => self.stats.inter_bytes_sent += bytes,
        }
        self.stats.msgs_sent += 1;
        self.senders[dst]
            .send(msg)
            .expect("receiver hung up — peer rank panicked");
    }

    fn spmm(&mut self, a: &crate::sparse::Csr, b: &Dense) -> Dense {
        let t0 = std::time::Instant::now();
        let c = self.kernel.spmm(a, b);
        self.stats.compute_secs += t0.elapsed().as_secs_f64();
        c
    }

}

/// Execute distributed SpMM: C = A·B where A was split by `part` into
/// `plan` (and optionally `sched` for hierarchical routing). `b` is the
/// full dense input (each rank only reads its own row block, mirroring the
/// distributed layout); returns the assembled global C.
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[crate::partition::LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
) -> (Dense, ExecStats) {
    assert_eq!(part.n, b.nrows);
    let nranks = part.nparts;
    assert_eq!(plan.nranks, nranks);
    let n_dense = b.ncols;

    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(Some(rx));
    }

    let t0 = std::time::Instant::now();
    let mut results: Vec<Option<(Dense, RankStats)>> = (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = &senders;
            let inbox = inbox.take().unwrap();
            let (r0, r1) = part.range(rank);
            let b_local = Dense::from_vec(
                r1 - r0,
                n_dense,
                b.data[r0 * n_dense..r1 * n_dense].to_vec(),
            );
            handles.push(scope.spawn(move || {
                let mut ctx = Ctx {
                    rank,
                    part,
                    plan,
                    sched,
                    topo,
                    kernel,
                    senders,
                    inbox,
                    stats: RankStats::default(),
                };
                let c = rank_main(&mut ctx, &blocks[rank], &b_local);
                (rank, c, ctx.stats)
            }));
        }
        for h in handles {
            let (rank, c, stats) = h.join().expect("rank thread panicked");
            results[rank] = Some((c, stats));
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut c_global = Dense::zeros(part.n, n_dense);
    let mut per_rank = Vec::with_capacity(nranks);
    for (rank, slot) in results.into_iter().enumerate() {
        let (c_local, stats) = slot.unwrap();
        let (r0, r1) = part.range(rank);
        assert_eq!(c_local.nrows, r1 - r0);
        c_global.data[r0 * n_dense..r1 * n_dense].copy_from_slice(&c_local.data);
        per_rank.push(stats);
    }
    (c_global, ExecStats { per_rank, wall_secs: wall })
}

/// The per-rank program: workflow steps 3–5 of §5.1 (steps 1–2 are the
/// offline planning already captured in `plan`/`sched`).
fn rank_main(ctx: &mut Ctx, blocks: &crate::partition::LocalBlocks, b_local: &Dense) -> Dense {
    // Stage: local computation with the diagonal block.
    let mut c_local = ctx.spmm(&blocks.diag, b_local);

    match ctx.sched {
        None => flat_exchange(ctx, b_local, &mut c_local),
        Some(_) => hier_exchange(ctx, b_local, &mut c_local),
    }
    c_local
}

// ---------------------------------------------------------------- flat ----

fn flat_exchange(ctx: &mut Ctx, b_local: &Dense, c_local: &mut Dense) {
    let r = ctx.rank;
    let nranks = ctx.plan.nranks;

    // Remote computation (row-based portions shipped to us offline) + sends.
    let mut expected_b = 0usize;
    let mut expected_c = 0usize;
    for p in 0..nranks {
        if p == r {
            continue;
        }
        // Column-based: send our B rows that p needs.
        let pair = &ctx.plan.pairs[p][r];
        let b_rows: Vec<u32> = if pair.full_block {
            (0..ctx.part.len(r) as u32).collect()
        } else {
            pair.b_rows.clone()
        };
        if !b_rows.is_empty() {
            let data = b_local.gather_rows(&b_rows);
            ctx.send(p, Msg::B { origin: r, rows: b_rows, data });
        }
        // Row-based: compute partial C rows for p and send (operand is the
        // precomputed row-compact block — §Perf opt-1).
        if !pair.c_rows.is_empty() {
            let data = ctx.spmm(&pair.a_row_compact, b_local);
            ctx.send(p, Msg::C { rows: pair.c_rows.clone(), data });
        }
        // What we expect to receive (mirror of the above at peer q=p).
        let my_pair = &ctx.plan.pairs[r][p];
        if my_pair.full_block || !my_pair.b_rows.is_empty() {
            expected_b += 1;
        }
        if !my_pair.c_rows.is_empty() {
            expected_c += 1;
        }
    }

    // Receive loop: B rows → remote column-based compute; C partials →
    // scatter-add (result aggregation).
    let mut got_b = 0;
    let mut got_c = 0;
    while got_b < expected_b || got_c < expected_c {
        match ctx.inbox.recv().expect("inbox closed") {
            Msg::B { origin, rows, data } => {
                apply_b_rows(ctx, origin, &rows, &data, c_local);
                got_b += 1;
            }
            Msg::C { rows, data } => {
                c_local.scatter_add_rows(&rows, &data);
                got_c += 1;
            }
            Msg::CAgg { .. } => unreachable!("CAgg in flat mode"),
        }
    }
}

/// Remote column-based computation: the received B rows arrive packed in
/// `b_rows` order, which is exactly the column space of the precomputed
/// `a_col_compact` operand — multiply directly, no scatter (§Perf opt-1).
fn apply_b_rows(ctx: &mut Ctx, origin: usize, rows: &[u32], data: &Dense, c_local: &mut Dense) {
    let pair = &ctx.plan.pairs[ctx.rank][origin];
    if pair.a_col_compact.nnz() == 0 {
        return;
    }
    debug_assert_eq!(rows.len(), pair.a_col_compact.ncols);
    debug_assert_eq!(rows, &pair.b_rows[..]);
    let t0 = std::time::Instant::now();
    let a_col = &ctx.plan.pairs[ctx.rank][origin].a_col_compact;
    a_col.spmm_acc(data, c_local);
    ctx.stats.compute_secs += t0.elapsed().as_secs_f64();
}

// ---------------------------------------------------------- hierarchical ----

fn hier_exchange(ctx: &mut Ctx, b_local: &Dense, c_local: &mut Dense) {
    let r = ctx.rank;
    let sched = ctx.sched.unwrap();

    // ---- Expected-receive bookkeeping (derived from the schedule). ----
    // Stage I as rep: inter-B flows addressed to us; CAgg from producers.
    let mut expect_flow_b = 0usize; // Msg::B with origin in another group
    let mut expect_direct_b = 0usize; // Msg::B same group
    let mut expect_cagg = 0usize; // Msg::CAgg (we are rep)
    let mut expect_c = 0usize; // Msg::C (direct row-based or rep→us aggregated)
    for f in &sched.b_flows {
        if f.rep == r {
            expect_flow_b += 1;
        }
        for (consumer, rows) in &f.consumers {
            if *consumer == r && f.rep != r && !rows.is_empty() {
                expect_direct_b += 1; // arrives as Msg::B from rep
            }
        }
    }
    for (_, dst, _) in &sched.direct_b {
        if *dst == r {
            expect_direct_b += 1;
        }
    }
    for f in &sched.c_flows {
        if f.rep == r {
            expect_cagg += f.producers.iter().filter(|(p, _)| *p != r).count();
        }
        if f.dst == r {
            expect_c += 1;
        }
    }
    for (_, dst, _) in &sched.direct_c {
        if *dst == r {
            expect_c += 1;
        }
    }

    // ---- Stage I sends ----
    // Column-based ①: inter-group deduplicated B fetch (flows we source).
    for f in sched.b_flows.iter().filter(|f| f.src == r) {
        let data = b_local.gather_rows(&f.rows);
        ctx.send(f.rep, Msg::B { origin: r, rows: f.rows.clone(), data });
    }
    // Row-based ①: compute partials; route via rep or direct.
    // (a) partials destined outside our group → rep (CAgg) or self-keep.
    let mut self_agg: Vec<(usize, Vec<u32>, Dense)> = Vec::new(); // (final_dst, rows, data) kept at rep == us
    for f in &sched.c_flows {
        for (producer, _) in &f.producers {
            if *producer != r {
                continue;
            }
            let pair = &ctx.plan.pairs[f.dst][r];
            let data = ctx.spmm(&pair.a_row_compact, b_local);
            if f.rep == r {
                self_agg.push((f.dst, pair.c_rows.clone(), data));
            } else {
                ctx.send(
                    f.rep,
                    Msg::CAgg { final_dst: f.dst, rows: pair.c_rows.clone(), data },
                );
            }
        }
    }
    // (b) same-group direct row-based.
    for (src, dst, rows) in &sched.direct_c {
        if *src != r {
            continue;
        }
        let pair = &ctx.plan.pairs[*dst][r];
        debug_assert_eq!(&pair.c_rows, rows);
        let data = ctx.spmm(&pair.a_row_compact, b_local);
        ctx.send(*dst, Msg::C { rows: rows.clone(), data });
    }
    // Same-group direct column-based (scheduled stage II in the paper, but
    // independent — send now, receiver applies on arrival).
    for (src, dst, rows) in &sched.direct_b {
        if *src != r {
            continue;
        }
        let data = b_local.gather_rows(rows);
        ctx.send(*dst, Msg::B { origin: r, rows: rows.clone(), data });
    }

    // ---- Aggregation state for flows where we are rep ----
    // (final_dst → accumulated rows/data over the union row set).
    let mut agg: std::collections::BTreeMap<usize, (Vec<u32>, Dense)> =
        std::collections::BTreeMap::new();
    for f in sched.c_flows.iter().filter(|f| f.rep == r) {
        agg.insert(
            f.dst,
            (f.rows.clone(), Dense::zeros(f.rows.len(), b_local.ncols)),
        );
    }
    let mut agg_pending: std::collections::BTreeMap<usize, usize> = sched
        .c_flows
        .iter()
        .filter(|f| f.rep == r)
        .map(|f| (f.dst, f.producers.len()))
        .collect();
    // Fold in our own partials (if we are both producer and rep).
    for (final_dst, rows, data) in self_agg {
        fold_agg(&mut agg, final_dst, &rows, &data);
        complete_agg(ctx, &mut agg, &mut agg_pending, final_dst);
    }

    // ---- Receive loop ----
    let mut got_flow_b = 0;
    let mut got_direct_b = 0;
    let mut got_cagg = 0;
    let mut got_c = 0;
    while got_flow_b < expect_flow_b
        || got_direct_b < expect_direct_b
        || got_cagg < expect_cagg
        || got_c < expect_c
    {
        match ctx.inbox.recv().expect("inbox closed") {
            Msg::B { origin, rows, data } => {
                let flow = sched
                    .b_flows
                    .iter()
                    .find(|f| f.src == origin && f.rep == r)
                    .filter(|_| ctx.topo.group_of(origin) != ctx.topo.group_of(r));
                if let Some(f) = flow {
                    // Stage II ②: distribute to in-group consumers; keep ours.
                    for (consumer, crows) in &f.consumers {
                        let sub = gather_subset(&rows, &data, crows);
                        if *consumer == r {
                            apply_b_rows(ctx, origin, crows, &sub, c_local);
                        } else {
                            ctx.send(
                                *consumer,
                                Msg::B { origin, rows: crows.clone(), data: sub },
                            );
                        }
                    }
                    got_flow_b += 1;
                } else {
                    // Direct in-group B or rep→consumer distribution.
                    apply_b_rows(ctx, origin, &rows, &data, c_local);
                    got_direct_b += 1;
                }
            }
            Msg::CAgg { final_dst, rows, data } => {
                fold_agg(&mut agg, final_dst, &rows, &data);
                got_cagg += 1;
                complete_agg(ctx, &mut agg, &mut agg_pending, final_dst);
            }
            Msg::C { rows, data } => {
                c_local.scatter_add_rows(&rows, &data);
                got_c += 1;
            }
        }
    }
}

/// Add a producer's partial rows into the rep's union-row accumulator.
fn fold_agg(
    agg: &mut std::collections::BTreeMap<usize, (Vec<u32>, Dense)>,
    final_dst: usize,
    rows: &[u32],
    data: &Dense,
) {
    let (union_rows, acc) = agg.get_mut(&final_dst).expect("unknown agg flow");
    for (i, row) in rows.iter().enumerate() {
        let k = union_rows.binary_search(row).expect("row not in union");
        for (d, s) in acc.row_mut(k).iter_mut().zip(data.row(i)) {
            *d += s;
        }
    }
}

/// If all producers for `final_dst` have contributed, ship the aggregate
/// (Stage II ②: inter-group C transmission).
fn complete_agg(
    ctx: &mut Ctx,
    agg: &mut std::collections::BTreeMap<usize, (Vec<u32>, Dense)>,
    pending: &mut std::collections::BTreeMap<usize, usize>,
    final_dst: usize,
) {
    let left = pending.get_mut(&final_dst).expect("unknown pending flow");
    *left -= 1;
    if *left == 0 {
        let (rows, data) = agg.remove(&final_dst).unwrap();
        ctx.send(final_dst, Msg::C { rows, data });
        pending.remove(&final_dst);
    }
}

/// Extract `want` rows (a subset of the sorted `have` rows) from `data`.
fn gather_subset(have: &[u32], data: &Dense, want: &[u32]) -> Dense {
    let mut out = Dense::zeros(want.len(), data.ncols);
    for (i, w) in want.iter().enumerate() {
        let k = have.binary_search(w).expect("subset violation");
        out.row_mut(i).copy_from_slice(data.row(k));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::hierarchy;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;
    use crate::util::rng::Rng;
    use kernel::NativeKernel;

    fn verify(
        a: &crate::sparse::Csr,
        ranks: usize,
        strategy: Strategy,
        mode: Mode,
    ) -> ExecStats {
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(a, &part);
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let sched = match mode {
            Mode::Flat => None,
            Mode::Hierarchical => Some(hierarchy::build(&plan, &topo)),
        };
        let mut rng = Rng::new(42);
        let b = Dense::random(a.nrows, 16, &mut rng);
        let want = a.spmm(&b);
        let (got, stats) = run(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &b,
            &NativeKernel,
        );
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(err < 1e-3, "{:?}/{mode:?}: rel err {err}", strategy);
        stats
    }

    #[test]
    fn flat_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 1);
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
            Strategy::Joint(Solver::Greedy),
        ] {
            verify(&a, 8, strategy, Mode::Flat);
        }
    }

    #[test]
    fn hier_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 2);
        for strategy in [
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            verify(&a, 8, strategy, Mode::Hierarchical);
        }
    }

    #[test]
    fn hier_across_datasets() {
        for (gen_fn, name) in [
            (gen::mesh2d(12, 3), "mesh"),
            (gen::powerlaw(128, 1200, 1.4, 3), "web"),
            (gen::banded_hub(128, 3, 4, 40, 3), "traffic"),
        ] {
            let _ = name;
            verify(&gen_fn, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn hier_reduces_inter_bytes_vs_flat() {
        // Web pattern with hubs: hierarchical dedup must cut inter-group
        // bytes actually sent (measured, not planned).
        let a = gen::powerlaw(256, 4000, 1.3, 4);
        let flat = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Flat);
        let hier = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        assert!(
            hier.total_inter_bytes() < flat.total_inter_bytes(),
            "hier {} !< flat {}",
            hier.total_inter_bytes(),
            flat.total_inter_bytes()
        );
    }

    #[test]
    fn various_rank_counts() {
        let a = gen::rmat(128, 2000, (0.5, 0.25, 0.15), false, 5);
        for ranks in [2, 3, 5, 8, 16] {
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Flat);
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn single_rank_degenerate() {
        let a = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 6);
        verify(&a, 1, Strategy::Joint(Solver::Koenig), Mode::Flat);
    }

    #[test]
    fn empty_matrix() {
        let a = crate::sparse::Csr::zeros(32, 32);
        let part = RowPartition::balanced(32, 4);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(4);
        let b = Dense::from_elem(32, 4, 1.0);
        let (got, _) = run(&part, &plan, &blocks, None, &topo, &b, &NativeKernel);
        assert_eq!(got, Dense::zeros(32, 4));
    }

    #[test]
    fn symmetric_matrix_symmetric_traffic() {
        // Joint strategy on a symmetric matrix should produce symmetric
        // measured traffic (Fig. 9's observation), unlike column-based.
        let a = gen::banded_hub(256, 3, 6, 60, 7);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let topo = Topology::tsubame4(8);
        let b = Dense::from_elem(256, 8, 1.0);

        let jplan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let jm = jplan.volume_matrix(8);
        let cplan = comm::plan(&blocks, &part, Strategy::Column, None);
        let cm = cplan.volume_matrix(8);
        assert!(
            jm.asymmetry() <= cm.asymmetry() + 1e-9,
            "joint {} vs column {}",
            jm.asymmetry(),
            cm.asymmetry()
        );
        // And both still compute the right answer.
        let want = a.spmm(&b);
        let (got, _) = run(&part, &jplan, &blocks, None, &topo, &b, &NativeKernel);
        assert!(want.diff_norm(&got) < 1e-3);
    }
}
