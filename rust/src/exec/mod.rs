//! In-process multi-rank executor: every "GPU" is a thread exchanging real
//! messages over channels, running the five-stage SHIRO workflow (§5.1) as
//! an overlapped, double-buffered pipeline — exactly the data movement the
//! plan prescribes, so the numerics of every strategy can be verified
//! bit-for-bit against the serial reference.
//!
//! The pipeline (Alg. 1 §6.2, [`pipeline`]): each rank posts its outgoing
//! B payloads eagerly (before local diagonal compute), interleaves local
//! SpMM tiles with draining the incoming channel, and — under hierarchical
//! routing — overlaps stage-I inter-group sends with stage-II intra-group
//! scatter of previously completed flows, the group representative folding
//! pre-aggregation incrementally as partials arrive instead of after a
//! barrier. `ExecOpts { overlap: false }` is the phase-ordered ablation
//! control; both modes apply every scatter-add in canonical (origin, row)
//! order at the fold point, so their results are bit-identical for any
//! thread interleaving.
//!
//! Flat mode delivers the [`crate::comm::CommPlan`] directly; hierarchical
//! mode routes through the [`crate::hierarchy::HierSchedule`]'s per-rank
//! step programs ([`crate::hierarchy::HierSchedule::rank_steps`]) — the
//! same object the simulator lowers, so simulated and executed orderings
//! cannot drift apart.
//!
//! The executor is **kernel-generic** (DESIGN.md §9): one plan executes
//! any [`kernel::KernelOp`]. SpMM runs the full B-in / partial-C-out
//! dataflow; SDDMM reuses the same B covers and *reverses* the C covers
//! into X-row fetches ([`crate::hierarchy::sddmm_fetch`] — stage-I-only,
//! no aggregation), computing each edge value exactly once at the rank the
//! plan assigned its nonzero to; the fused SDDMM→SpMM kernel consumes the
//! freshly computed edge values as the SpMM operand in place, so the only
//! addition over SDDMM is the plan's ordinary aggregated C flow back.

pub mod kernel;
pub mod pipeline;
pub(crate) mod replicate;
pub mod session;
pub(crate) mod wire;

pub use kernel::KernelOp;
pub use pipeline::ExecOpts;
pub use session::SpmmSession;

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::hierarchy::{self, phase, HierSchedule, Step};
use crate::metrics::{OverlapWindow, VolumeMatrix};
use crate::partition::{LocalBlocks, RowPartition};
use crate::sparse::Csr;
use crate::topology::{Tier, Topology};
use kernel::SpmmKernel;
use pipeline::{
    ckey, gated, BufferPool, ComputeGate, OrderedFold, PoolRef, DIAG_KEY, KIND_B, KIND_C,
};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

/// A message between ranks. `from` is the link-level sender (used for
/// receiver-side tier accounting); `origin` on B/X payloads is the rank
/// that owns the rows (differs from `from` when a representative
/// forwards). Row index spaces: `B.rows` are origin-local B rows; `X.rows`
/// are origin-local X rows (the origin's C rows of the reversed flow);
/// `C.rows` / `CAgg.rows` are destination-local C rows.
pub(crate) enum Msg {
    /// B rows owned by `origin` (column-based payload).
    B {
        from: usize,
        origin: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// X rows owned by `origin`, fetched by a row-serving rank so it can
    /// compute SDDMM edge values for `origin`'s pattern rows (the plan's
    /// C covers reversed — SDDMM/fused kernels only).
    X {
        from: usize,
        origin: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Partial C rows, ready to scatter-add at the destination.
    C {
        from: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Producer → representative partial C rows destined for `final_dst`
    /// (hierarchical row-based stage I).
    CAgg {
        from: usize,
        final_dst: usize,
        rows: Vec<u32>,
        data: Dense,
    },
    /// Replica member → group home: the member accumulator's touched rows,
    /// the reduce-scatter leg of the 1.5D decomposition ([`replicate`]).
    /// `rows` are group-local C rows.
    CRed {
        from: usize,
        rows: Vec<u32>,
        data: Dense,
    },
}

impl Msg {
    fn bytes(&self) -> u64 {
        let (rows, data) = match self {
            Msg::B { rows, data, .. } => (rows, data),
            Msg::X { rows, data, .. } => (rows, data),
            Msg::C { rows, data, .. } => (rows, data),
            Msg::CAgg { rows, data, .. } => (rows, data),
            Msg::CRed { rows, data, .. } => (rows, data),
        };
        (rows.len() * 4 + data.size_bytes()) as u64
    }

    fn from_rank(&self) -> usize {
        match self {
            Msg::B { from, .. }
            | Msg::X { from, .. }
            | Msg::C { from, .. }
            | Msg::CAgg { from, .. }
            | Msg::CRed { from, .. } => *from,
        }
    }
}

/// One labeled interval of a rank's timeline (seconds since run start);
/// names come from [`crate::hierarchy::phase`] so executor chrome traces
/// line up with the simulator's stage names.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSpan {
    pub name: &'static str,
    pub start: f64,
    pub end: f64,
}

/// Per-rank execution statistics. Bytes are counted on **both** sides of
/// every link (sender totals must equal receiver totals per tier — the
/// accounting agreement the tests assert).
#[derive(Clone, Debug, Default)]
pub struct RankStats {
    pub intra_bytes_sent: u64,
    pub inter_bytes_sent: u64,
    pub intra_bytes_recv: u64,
    pub inter_bytes_recv: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    /// Measured bytes sent to each destination rank (volume-matrix row).
    pub sent_to: Vec<u64>,
    /// The B-side subset of `sent_to`: bytes of B-row payloads only
    /// (column-based covers, including representative forwarding). The
    /// plan-sharing contract is that this matrix is *identical* between
    /// SpMM and SDDMM executions of one frozen plan — the same dense rows
    /// move on the same links either way.
    pub sent_b_to: Vec<u64>,
    pub compute_secs: f64,
    /// Seconds blocked in `recv` with no compute left to hide it behind.
    pub idle_secs: f64,
    /// Bytes drained from the inbox while compute items remained (traffic
    /// the pipeline overlapped with useful work).
    pub overlapped_recv_bytes: u64,
    /// Bytes received in the idle drain tail.
    pub idle_recv_bytes: u64,
    /// Timeline of this rank's pipeline phases (chrome-trace export:
    /// [`crate::sim::trace::exec_to_chrome_json`]).
    pub phases: Vec<PhaseSpan>,
}

/// Aggregated executor output.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub per_rank: Vec<RankStats>,
    pub wall_secs: f64,
}

impl ExecStats {
    pub fn total_inter_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.inter_bytes_sent).sum()
    }
    pub fn total_intra_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.intra_bytes_sent).sum()
    }
    pub fn total_inter_recv_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.inter_bytes_recv).sum()
    }
    pub fn total_intra_recv_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.intra_bytes_recv).sum()
    }

    /// Measured per-pair traffic (bytes actually sent src→dst), in the
    /// same shape as the planner's volume accounting so the two can be
    /// cross-checked.
    pub fn measured_volume(&self) -> VolumeMatrix {
        let n = self.per_rank.len();
        let mut m = VolumeMatrix::zeros(n);
        for (src, r) in self.per_rank.iter().enumerate() {
            for (dst, &b) in r.sent_to.iter().enumerate() {
                m.add(src, dst, b);
            }
        }
        m
    }

    /// Measured per-pair B-row traffic only (the column-based covers):
    /// the shared-plan invariant is `spmm.measured_b_volume() ==
    /// sddmm.measured_b_volume()` for any two kernels run off one plan.
    pub fn measured_b_volume(&self) -> VolumeMatrix {
        let n = self.per_rank.len();
        let mut m = VolumeMatrix::zeros(n);
        for (src, r) in self.per_rank.iter().enumerate() {
            for (dst, &b) in r.sent_b_to.iter().enumerate() {
                m.add(src, dst, b);
            }
        }
        m
    }

    /// Overlap-window accounting across all ranks.
    pub fn overlap_window(&self) -> OverlapWindow {
        let mut w = OverlapWindow::default();
        for r in &self.per_rank {
            w.overlapped_bytes += r.overlapped_recv_bytes;
            w.idle_bytes += r.idle_recv_bytes;
            w.idle_secs += r.idle_secs;
            w.compute_secs += r.compute_secs;
        }
        w
    }
}

/// How messages are routed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Flat,
    Hierarchical,
}

/// Where a rank's outgoing messages go: in-process channels (the thread
/// backend) or the parent control plane's socket (the multi-process
/// backend, [`wire`]). `rank_main` and everything below it is transport-
/// agnostic — the same program drives both, which is what makes the thread
/// executor a bitwise differential oracle for the proc backend.
pub(crate) enum Outbox<'a> {
    Local(&'a [Sender<Msg>]),
    /// Epoch-stamped socket sender, so an aborted step's in-flight frames
    /// are distinguishable from the replanned epoch's (wire v3).
    Socket(&'a wire::EpochTx),
}

impl Outbox<'_> {
    fn send(&self, dst: usize, msg: Msg) {
        match self {
            Outbox::Local(senders) => senders[dst]
                .send(msg)
                .expect("receiver hung up — peer rank panicked"),
            Outbox::Socket(tx) => tx.send(dst, &msg),
        }
    }
}

struct Ctx<'a> {
    rank: usize,
    part: &'a RowPartition,
    plan: &'a CommPlan,
    sched: Option<&'a HierSchedule>,
    /// Stage-I-only X fetch schedule ([`crate::hierarchy::sddmm_fetch`]);
    /// present only for hierarchical SDDMM/fused execution.
    xsched: Option<&'a HierSchedule>,
    topo: &'a Topology,
    kernel: &'a dyn SpmmKernel,
    outbox: Outbox<'a>,
    inbox: Receiver<Msg>,
    stats: RankStats,
    opts: ExecOpts,
    gate: Option<&'a ComputeGate>,
    t0: Instant,
    pool: PoolRef<'a>,
}

impl<'a> Ctx<'a> {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Record `[start, now]` under `name`, merging contiguous same-name
    /// spans so tight tile loops stay one slice in the trace.
    fn span(&mut self, name: &'static str, start: f64) {
        let end = self.now();
        if let Some(last) = self.stats.phases.last_mut() {
            if last.name == name && start - last.end < 1e-7 {
                last.end = end;
                return;
            }
        }
        self.stats.phases.push(PhaseSpan { name, start, end });
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        let bytes = msg.bytes();
        match self.topo.tier(self.rank, dst) {
            Tier::Intra => self.stats.intra_bytes_sent += bytes,
            Tier::Inter => self.stats.inter_bytes_sent += bytes,
        }
        self.stats.msgs_sent += 1;
        self.stats.sent_to[dst] += bytes;
        if matches!(msg, Msg::B { .. }) {
            self.stats.sent_b_to[dst] += bytes;
        }
        self.outbox.send(dst, msg);
    }

    /// Receiver-side accounting: the mirror of [`Ctx::send`], keyed by the
    /// link-level sender so per-tier totals agree between both sides.
    fn recv_account(&mut self, msg: &Msg, overlapped: bool) {
        let bytes = msg.bytes();
        match self.topo.tier(msg.from_rank(), self.rank) {
            Tier::Intra => self.stats.intra_bytes_recv += bytes,
            Tier::Inter => self.stats.inter_bytes_recv += bytes,
        }
        self.stats.msgs_recv += 1;
        if overlapped {
            self.stats.overlapped_recv_bytes += bytes;
        } else {
            self.stats.idle_recv_bytes += bytes;
        }
    }
}

/// Execute distributed SpMM with default options (overlapped pipeline):
/// C = A·B where A was split by `part` into `plan` (and optionally `sched`
/// for hierarchical routing). `b` is the full dense input (each rank only
/// reads its own row block, mirroring the distributed layout); returns the
/// assembled global C.
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
) -> (Dense, ExecStats) {
    run_with(part, plan, blocks, sched, topo, b, kernel, &ExecOpts::default())
}

/// [`run`] with explicit [`ExecOpts`] (overlap on/off, tile height, worker
/// cap).
#[allow(clippy::too_many_arguments)]
pub fn run_with(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Dense, ExecStats) {
    let (c, _, stats) =
        run_kernel_with(KernelOp::Spmm, part, plan, blocks, sched, topo, None, b, kernel, opts);
    (c, stats)
}

/// Execute distributed SDDMM on the *same* plan the SpMM engine uses:
/// E = A ⊙ (X·Yᵀ) over A's pattern. Y rows move along the plan's B covers
/// unchanged; X rows move along the plan's C covers reversed
/// ([`crate::hierarchy::sddmm_fetch`]) so every rank can compute exactly
/// the edge values of the nonzeros the plan assigned to it. The output is
/// assembled from the plan-distributed per-rank values — each entry has
/// exactly one producer, so the result is bitwise-identical to the serial
/// [`Csr::sddmm`] oracle on any input.
#[allow(clippy::too_many_arguments)]
pub fn run_sddmm_with(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Csr, ExecStats) {
    let (_, vals, stats) = run_kernel_with(
        KernelOp::Sddmm,
        part,
        plan,
        blocks,
        sched,
        topo,
        Some(x),
        y,
        kernel,
        opts,
    );
    (assemble_sddmm(part, blocks, plan, &vals), stats)
}

/// Execute the fused SDDMM→SpMM kernel: C = (A ⊙ (X·Yᵀ))·Y in one
/// exchange. The SDDMM stage runs exactly as [`run_sddmm_with`]; the edge
/// values are then consumed in place — column-served values multiply the
/// already-received Y rows, row-served values multiply the server's local
/// Y block — so the only traffic beyond SDDMM's is the plan's ordinary
/// aggregated partial-C flow. No second B exchange, no edge-value gather:
/// that is the fused kernel's strict byte saving over running SDDMM and
/// SpMM as two passes (`ablation_fused`).
#[allow(clippy::too_many_arguments)]
pub fn run_fused_with(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Dense, ExecStats) {
    let (c, _, stats) = run_kernel_with(
        KernelOp::FusedSddmmSpmm,
        part,
        plan,
        blocks,
        sched,
        topo,
        Some(x),
        y,
        kernel,
        opts,
    );
    (c, stats)
}

/// The kernel-generic driver behind every one-shot entry point: spawn one
/// thread per rank, derive the per-rank program for `op`, run the
/// overlapped (or phase-ordered) pipeline, and return the assembled dense
/// output plus the per-rank SDDMM values (empty for SpMM).
#[allow(clippy::too_many_arguments)]
fn run_kernel_with(
    op: KernelOp,
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: Option<&Dense>,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Dense, Vec<SddmmVals>, ExecStats) {
    assert_eq!(part.n, b.nrows);
    let nranks = part.nparts;
    assert_eq!(plan.nranks, nranks);
    let n_dense = b.ncols;
    if op != KernelOp::Spmm {
        let x = x.expect("SDDMM kernels require an X operand");
        assert_eq!(x.nrows, part.n, "X height != planned matrix");
        assert_eq!(x.ncols, n_dense, "SDDMM requires matching X/Y widths");
    }
    // The X fetch schedule is derived from the plan's schedule, not stored
    // in it: the same frozen `sched` serves every kernel.
    let xsched_owned = (op != KernelOp::Spmm)
        .then(|| sched.map(hierarchy::sddmm_fetch))
        .flatten();
    let xsched = xsched_owned.as_ref();

    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    let gate = (opts.workers > 0).then(|| ComputeGate::new(opts.workers));

    let t0 = Instant::now();
    let mut results: Vec<Option<(Dense, SddmmVals, RankStats)>> =
        (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = &senders;
            let gate = gate.as_ref();
            let inbox = inbox.take().unwrap();
            let (r0, r1) = part.range(rank);
            let b_local = Dense::from_vec(
                r1 - r0,
                n_dense,
                b.data[r0 * n_dense..r1 * n_dense].to_vec(),
            );
            let x_local = x.map(|x| {
                Dense::from_vec(r1 - r0, n_dense, x.data[r0 * n_dense..r1 * n_dense].to_vec())
            });
            handles.push(scope.spawn(move || {
                let mut ctx = Ctx {
                    rank,
                    part,
                    plan,
                    sched,
                    xsched,
                    topo,
                    kernel,
                    outbox: Outbox::Local(senders),
                    inbox,
                    stats: RankStats {
                        sent_to: vec![0; nranks],
                        sent_b_to: vec![0; nranks],
                        ..RankStats::default()
                    },
                    opts: *opts,
                    gate,
                    t0,
                    pool: PoolRef::Own(BufferPool::new()),
                };
                let prog = build_program(
                    rank,
                    part,
                    plan,
                    sched,
                    xsched,
                    opts,
                    kernel.prefers_tiles(),
                    op,
                );
                // SDDMM has no dense output block; a zero-width C keeps the
                // driver uniform without allocating.
                let c_width = if op == KernelOp::Sddmm { 0 } else { n_dense };
                let mut c_local = Dense::zeros(part.len(rank), c_width);
                let mut vals = SddmmVals::default();
                rank_main(
                    &mut ctx,
                    &blocks[rank],
                    x_local.as_ref(),
                    &b_local,
                    &mut c_local,
                    &mut vals,
                    &prog,
                );
                (rank, c_local, vals, ctx.stats)
            }));
        }
        for h in handles {
            let (rank, c, vals, stats) = h.join().expect("rank thread panicked");
            results[rank] = Some((c, vals, stats));
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let c_width = if op == KernelOp::Sddmm { 0 } else { n_dense };
    let mut c_global = Dense::zeros(part.n, c_width);
    let mut per_rank = Vec::with_capacity(nranks);
    let mut all_vals = Vec::with_capacity(nranks);
    for (rank, slot) in results.into_iter().enumerate() {
        let (c_local, vals, stats) = slot.unwrap();
        let (r0, r1) = part.range(rank);
        assert_eq!(c_local.nrows, r1 - r0);
        c_global.data[r0 * c_width..r1 * c_width].copy_from_slice(&c_local.data);
        per_rank.push(stats);
        all_vals.push(vals);
    }
    (c_global, all_vals, ExecStats { per_rank, wall_secs: wall })
}

/// Plan-distributed SDDMM output of one rank: the edge values it computed,
/// laid out in entry order of the pattern operand that produced them.
/// Buffers come from the executor's pool so sessions stay allocation-free
/// in steady state; [`assemble_sddmm`] copies them into the global result.
#[derive(Default)]
pub(crate) struct SddmmVals {
    /// Diagonal-block values (1 × nnz, entry order).
    pub diag: Dense,
    /// origin rank q → values for `pairs[self][q].a_col_compact`
    /// (column-served entries, computed here from received Y rows).
    pub col: BTreeMap<usize, Dense>,
    /// destination rank p → values for `pairs[p][self].a_row_compact`
    /// (row-served entries this rank computed for p from received X rows).
    pub row: BTreeMap<usize, Dense>,
}

impl SddmmVals {
    /// Release every buffer back into `pool` (the session steady-state
    /// path: values are copied out by assembly, buffers recycle).
    pub(crate) fn release_into(self, pool: &mut PoolRef) {
        pool.release(self.diag);
        for (_, d) in self.col {
            pool.release(d);
        }
        for (_, d) in self.row {
            pool.release(d);
        }
    }
}

/// Assemble the plan-distributed SDDMM values into the global sparse
/// result. Each stored entry of A was computed by exactly one rank — the
/// diagonal and column-served entries at the pattern owner p, the
/// row-served entries at their server q — so assembly is a deterministic
/// merge: per global row, the per-block runs are concatenated in block
/// order and the column/row-served runs inside one block are interleaved
/// by column index. The result's structure equals A's exactly.
pub(crate) fn assemble_sddmm(
    part: &RowPartition,
    blocks: &[LocalBlocks],
    plan: &CommPlan,
    vals: &[SddmmVals],
) -> Csr {
    let n = part.n;
    let nranks = part.nparts;
    let total: usize = blocks
        .iter()
        .map(|b| b.diag.nnz() + b.off_diag.iter().map(Csr::nnz).sum::<usize>())
        .sum();
    let mut indptr = vec![0u64; n + 1];
    let mut indices = Vec::with_capacity(total);
    let mut data = Vec::with_capacity(total);
    for p in 0..nranks {
        let (r0, r1) = part.range(p);
        for r in 0..(r1 - r0) {
            for q in 0..nranks {
                let c0 = part.range(q).0 as u32;
                if q == p {
                    let diag = &blocks[p].diag;
                    let (lo, hi) = (diag.indptr[r] as usize, diag.indptr[r + 1] as usize);
                    for k in lo..hi {
                        indices.push(diag.indices[k] + c0);
                        data.push(vals[p].diag.data[k]);
                    }
                } else {
                    // `a_col_part` and `a_row_part` split this block's
                    // entries disjointly, and each keeps entry order, so
                    // the two per-row runs merge by (strictly distinct)
                    // column index.
                    let pair = &plan.pairs[p][q];
                    let cp = &pair.a_col_part;
                    let rp = &pair.a_row_part;
                    let cvals = vals[p].col.get(&q);
                    let rvals = vals[q].row.get(&p);
                    let (mut ci, chi) = (cp.indptr[r] as usize, cp.indptr[r + 1] as usize);
                    let (mut ri, rhi) = (rp.indptr[r] as usize, rp.indptr[r + 1] as usize);
                    while ci < chi || ri < rhi {
                        let take_col = if ri >= rhi {
                            true
                        } else if ci >= chi {
                            false
                        } else {
                            cp.indices[ci] < rp.indices[ri]
                        };
                        if take_col {
                            indices.push(cp.indices[ci] + c0);
                            data.push(cvals.expect("missing column-served values").data[ci]);
                            ci += 1;
                        } else {
                            indices.push(rp.indices[ri] + c0);
                            data.push(rvals.expect("missing row-served values").data[ri]);
                            ri += 1;
                        }
                    }
                }
            }
            indptr[r0 + r + 1] = indices.len() as u64;
        }
    }
    Csr { nrows: n, ncols: n, indptr, indices, data }
}

// ------------------------------------------------------- rank program ----

/// An eager outgoing dense-row payload (gather + send; no compute on this
/// side). Used for both B posts and — in SDDMM/fused programs — X posts.
struct BPost {
    dst: usize,
    rows: Vec<u32>,
    phase: &'static str,
}

/// One unit of local compute, interleaved with inbox drains in overlap
/// mode.
enum Item {
    /// Row-based partial production for a direct destination (flat pairs
    /// and same-group hierarchical transfers): SpMM then `Msg::C`.
    ProduceDirectC { dst: usize },
    /// Hierarchical partial production for `c_flows[flow]`: SpMM then
    /// route to the flow's rep (or fold locally when rep == self).
    ProduceFlowC { flow: usize },
    /// One diagonal-block tile: SpMM, SDDMM values, or both (fused),
    /// depending on the program's kernel op.
    DiagTile { r0: usize, r1: usize },
}

/// How a fused row-served partial reaches its destination once the X rows
/// that unlock it have arrived: the same two routes SpMM's proactive
/// `Produce*` items use, looked up reactively by origin.
#[derive(Clone, Copy)]
enum RowRoute {
    /// Send `Msg::C` straight to the destination (flat pair or same-group
    /// direct transfer).
    Direct,
    /// Route through `c_flows[i]`'s representative (or fold locally when
    /// this rank is the rep).
    Flow(usize),
}

/// The fully derived per-rank program: which kernel op, what to send, what
/// to compute, what to expect, and in which canonical order contributions
/// fold.
#[derive(Default)]
struct Program {
    /// The distributed kernel this program executes.
    op: KernelOp,
    b_posts: Vec<BPost>,
    /// X-row posts (SDDMM/fused): the plan's C covers reversed.
    x_posts: Vec<BPost>,
    items: Vec<Item>,
    /// Total incoming messages (of any kind) this rank must consume.
    expect_msgs: usize,
    /// Canonical contribution keys for the local C fold (empty for SDDMM,
    /// which accumulates nothing — every edge value has one producer).
    fold_keys: Vec<u64>,
    /// Flow indices for which this rank is the pre-aggregation rep.
    agg_flows: Vec<usize>,
    /// origin → b_flow index for flows this rank redistributes as rep.
    rep_b: BTreeMap<usize, usize>,
    /// origin → X-schedule b_flow index for X flows this rank reps.
    rep_x: BTreeMap<usize, usize>,
    /// Fused only: destination → route for the row-served partial this
    /// rank produces when that destination's X rows arrive.
    row_route: BTreeMap<usize, RowRoute>,
}

/// Sends deferred by the phase-ordered (`overlap: false`) schedule.
#[derive(Default)]
struct Deferred {
    msgs: Vec<(usize, Msg)>,
    /// (final_dst, c_rows, partial) this rank both produced and reps.
    self_aggs: Vec<(usize, Vec<u32>, Dense)>,
}

/// Derive rank `rank`'s full program for kernel `op` from the plan and
/// schedules. A pure function of (plan, schedules, options, kernel tiling
/// preference, op) — the session layer precomputes these once per op and
/// replays them every call. `xsched` must be
/// [`crate::hierarchy::sddmm_fetch`] of `sched` (present iff `sched` is
/// and `op` is not SpMM).
#[allow(clippy::too_many_arguments)]
fn build_program(
    rank: usize,
    part: &RowPartition,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    xsched: Option<&HierSchedule>,
    opts: &ExecOpts,
    prefers_tiles: bool,
    op: KernelOp,
) -> Program {
    let mut p = Program { op, ..Program::default() };
    // SDDMM folds nothing: each edge value has exactly one producer, so B
    // arrivals fill disjoint value buffers instead of accumulating.
    let with_fold = op != KernelOp::Sddmm;
    match sched {
        None => flat_b_side(&mut p, rank, part, plan, with_fold),
        Some(s) => hier_b_side(&mut p, rank, s, with_fold),
    }
    match op {
        KernelOp::Spmm => match sched {
            None => flat_c_side(&mut p, rank, plan, true),
            Some(s) => hier_c_side(&mut p, rank, plan, s, true),
        },
        KernelOp::Sddmm => match xsched {
            None => flat_x_side(&mut p, rank, plan),
            Some(xs) => hier_x_side(&mut p, rank, xs),
        },
        KernelOp::FusedSddmmSpmm => {
            match xsched {
                None => flat_x_side(&mut p, rank, plan),
                Some(xs) => hier_x_side(&mut p, rank, xs),
            }
            // The C flow back is the plan's ordinary one — produced
            // reactively (on X arrival) instead of as local items.
            match sched {
                None => flat_c_side(&mut p, rank, plan, false),
                Some(s) => hier_c_side(&mut p, rank, plan, s, false),
            }
        }
    }
    if with_fold {
        p.fold_keys.push(DIAG_KEY);
    }
    // Diagonal tiles go last: partial production unblocks other ranks, the
    // diagonal only feeds this one. Kernels with whole-matrix entry points
    // (PJRT) get a single full-range tile, dispatched via `spmm_acc`.
    let my_rows = part.len(rank);
    let tile = if prefers_tiles { opts.tile() } else { usize::MAX };
    let mut r0 = 0;
    while r0 < my_rows {
        let r1 = r0.saturating_add(tile).min(my_rows);
        p.items.push(Item::DiagTile { r0, r1 });
        r0 = r1;
    }
    p
}

/// Flat B side: outgoing B posts plus the mirrored receive expectations.
/// (A pair is expected iff its sender would emit it — in particular a
/// `full_block` pair over an empty source block sends nothing and must not
/// be awaited.)
fn flat_b_side(p: &mut Program, r: usize, part: &RowPartition, plan: &CommPlan, with_fold: bool) {
    for q in 0..plan.nranks {
        if q == r {
            continue;
        }
        // Column-based: B rows of ours that q needs.
        let pair = &plan.pairs[q][r];
        let rows: Vec<u32> = if pair.full_block {
            (0..part.len(r) as u32).collect()
        } else {
            pair.b_rows.clone()
        };
        if !rows.is_empty() {
            p.b_posts.push(BPost { dst: q, rows, phase: crate::sim::FLAT_STAGE });
        }
        // Mirror at peer q: what we expect to receive.
        let my = &plan.pairs[r][q];
        let in_rows = if my.full_block { part.len(q) } else { my.b_rows.len() };
        if in_rows > 0 {
            p.expect_msgs += 1;
            if with_fold {
                p.fold_keys.push(ckey(KIND_B, q));
            }
        }
    }
}

/// Flat C side: partial-production duties (as proactive items for SpMM,
/// as reactive row routes for the fused kernel) plus the mirrored receive
/// expectations and fold keys.
fn flat_c_side(p: &mut Program, r: usize, plan: &CommPlan, produce: bool) {
    for q in 0..plan.nranks {
        if q == r {
            continue;
        }
        // Row-based: partial C rows we compute for q.
        if !plan.pairs[q][r].c_rows.is_empty() {
            if produce {
                p.items.push(Item::ProduceDirectC { dst: q });
            } else {
                p.row_route.insert(q, RowRoute::Direct);
            }
        }
        if !plan.pairs[r][q].c_rows.is_empty() {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, q));
        }
    }
}

/// Flat X side (SDDMM/fused): the plan's C covers reversed — we post our X
/// rows to every rank that row-serves us, and expect X rows from every
/// rank we row-serve.
fn flat_x_side(p: &mut Program, r: usize, plan: &CommPlan) {
    for q in 0..plan.nranks {
        if q == r {
            continue;
        }
        // q computes edge values for our pattern rows c_rows[r][q]; it
        // needs exactly those X rows of ours.
        let pair = &plan.pairs[r][q];
        if !pair.c_rows.is_empty() {
            p.x_posts.push(BPost {
                dst: q,
                rows: pair.c_rows.clone(),
                phase: phase::S1_FETCH_X,
            });
        }
        // Mirror: the X rows we need from q to serve its pattern rows.
        if !plan.pairs[q][r].c_rows.is_empty() {
            p.expect_msgs += 1;
        }
    }
}

/// Hierarchical B side of `sched` (its stage-I fetch pattern): posts in
/// [`HierSchedule::rank_steps`] order plus mirrored expectations.
fn hier_b_side(p: &mut Program, r: usize, sched: &HierSchedule, with_fold: bool) {
    for step in sched.rank_steps(r) {
        match step {
            Step::InterB(i) => {
                let f = &sched.b_flows[i];
                p.b_posts.push(BPost {
                    dst: f.rep,
                    rows: f.rows.clone(),
                    phase: phase::S1_INTER_B,
                });
            }
            Step::DirectB(i) => {
                let (_, dst, rows) = &sched.direct_b[i];
                p.b_posts.push(BPost {
                    dst: *dst,
                    rows: rows.clone(),
                    phase: phase::S2_INTRA_B,
                });
            }
            Step::ProduceC(_) | Step::DirectC(_) => {}
        }
    }
    for (i, f) in sched.b_flows.iter().enumerate() {
        if f.rep == r {
            p.expect_msgs += 1; // the stage-I inter-group arrival
            p.rep_b.insert(f.src, i);
        }
        if let Some((_, rows)) = f.consumers.iter().find(|(c, _)| *c == r) {
            if !rows.is_empty() {
                if with_fold {
                    p.fold_keys.push(ckey(KIND_B, f.src));
                }
                if f.rep != r {
                    p.expect_msgs += 1; // forwarded to us as Msg::B
                }
            }
        }
    }
    for (src, dst, rows) in &sched.direct_b {
        if *dst == r && !rows.is_empty() {
            p.expect_msgs += 1;
            if with_fold {
                p.fold_keys.push(ckey(KIND_B, *src));
            }
        }
    }
}

/// Hierarchical C side of `sched`: production duties (items or reactive
/// routes) plus rep/aggregation and receive expectations.
fn hier_c_side(p: &mut Program, r: usize, plan: &CommPlan, sched: &HierSchedule, produce: bool) {
    for step in sched.rank_steps(r) {
        match step {
            Step::ProduceC(i) => {
                if produce {
                    p.items.push(Item::ProduceFlowC { flow: i });
                } else {
                    p.row_route.insert(sched.c_flows[i].dst, RowRoute::Flow(i));
                }
            }
            Step::DirectC(i) => {
                let (_, dst, rows) = &sched.direct_c[i];
                debug_assert_eq!(&plan.pairs[*dst][r].c_rows, rows);
                if produce {
                    p.items.push(Item::ProduceDirectC { dst: *dst });
                } else {
                    p.row_route.insert(*dst, RowRoute::Direct);
                }
            }
            Step::InterB(_) | Step::DirectB(_) => {}
        }
    }
    for (i, f) in sched.c_flows.iter().enumerate() {
        if f.rep == r {
            p.agg_flows.push(i);
            p.expect_msgs += f.producers.iter().filter(|(q, _)| *q != r).count();
        }
        if f.dst == r {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, f.rep));
        }
    }
    for (src, dst, rows) in &sched.direct_c {
        if *dst == r && !rows.is_empty() {
            p.expect_msgs += 1;
            p.fold_keys.push(ckey(KIND_C, *src));
        }
    }
}

/// Hierarchical X side (SDDMM/fused): the stage-I-only fetch schedule
/// produced by [`crate::hierarchy::sddmm_fetch`], consumed with exactly
/// the B-side mechanics — union posts to reps, rep redistribution, direct
/// same-group transfers — but tracked separately (`x_posts`/`rep_x`) so
/// arrivals dispatch to the row-serving compute path.
fn hier_x_side(p: &mut Program, r: usize, xsched: &HierSchedule) {
    debug_assert!(
        xsched.c_flows.is_empty() && xsched.direct_c.is_empty(),
        "X schedule must be stage-I-only (hierarchy::sddmm_fetch)"
    );
    for step in xsched.rank_steps(r) {
        match step {
            Step::InterB(i) => {
                let f = &xsched.b_flows[i];
                p.x_posts.push(BPost {
                    dst: f.rep,
                    rows: f.rows.clone(),
                    phase: phase::S1_FETCH_X,
                });
            }
            Step::DirectB(i) => {
                let (_, dst, rows) = &xsched.direct_b[i];
                p.x_posts.push(BPost {
                    dst: *dst,
                    rows: rows.clone(),
                    phase: phase::S1_FETCH_X,
                });
            }
            Step::ProduceC(_) | Step::DirectC(_) => {
                unreachable!("stage-I-only schedule has no C steps")
            }
        }
    }
    for (i, f) in xsched.b_flows.iter().enumerate() {
        if f.rep == r {
            p.expect_msgs += 1;
            p.rep_x.insert(f.src, i);
        }
        if let Some((_, rows)) = f.consumers.iter().find(|(c, _)| *c == r) {
            if !rows.is_empty() && f.rep != r {
                p.expect_msgs += 1; // forwarded to us as Msg::X
            }
        }
    }
    for (_, dst, rows) in &xsched.direct_b {
        if *dst == r && !rows.is_empty() {
            p.expect_msgs += 1;
        }
    }
}

// -------------------------------------------------- aggregation state ----

/// Rep-side pre-aggregation for one C flow: producer partials fold into the
/// union-row accumulator **in canonical producer order** (incrementally as
/// they arrive — out-of-order arrivals park in the [`OrderedFold`]).
struct AggFlow {
    dst: usize,
    rows: Vec<u32>,
    acc: Dense,
    fold: OrderedFold<(Vec<u32>, Dense)>,
}

impl AggFlow {
    fn new(f: &crate::hierarchy::CFlow, n_dense: usize, pool: &mut PoolRef) -> AggFlow {
        AggFlow {
            dst: f.dst,
            rows: f.rows.clone(),
            acc: pool.acquire(f.rows.len(), n_dense),
            fold: OrderedFold::new(
                f.producers.iter().map(|(q, _)| ckey(KIND_C, *q)).collect(),
            ),
        }
    }

    /// Offer one producer's partial; returns true when every producer has
    /// been folded (the aggregate is ready to ship).
    fn offer(
        &mut self,
        producer: usize,
        prows: Vec<u32>,
        data: Dense,
        pool: &mut PoolRef,
    ) -> bool {
        let AggFlow { rows, acc, fold, .. } = self;
        fold.offer(ckey(KIND_C, producer), (prows, data), |(pr, d)| {
            fold_rows(rows, acc, &pr, &d);
            pool.release(d);
        });
        fold.is_done()
    }
}

/// Scatter-add a producer's partial rows into the union-row accumulator
/// (rows sorted; indices resolved by binary search).
fn fold_rows(union_rows: &[u32], acc: &mut Dense, rows: &[u32], data: &Dense) {
    for (i, row) in rows.iter().enumerate() {
        let k = union_rows.binary_search(row).expect("row not in union");
        for (d, s) in acc.row_mut(k).iter_mut().zip(data.row(i)) {
            *d += s;
        }
    }
}

/// Ship a completed aggregate across the inter-group link (stage II ②).
fn complete_agg(ctx: &mut Ctx, aggs: &mut BTreeMap<usize, AggFlow>, final_dst: usize) {
    let t = ctx.now();
    let a = aggs.remove(&final_dst).expect("unknown agg flow");
    ctx.send(a.dst, Msg::C { from: ctx.rank, rows: a.rows, data: a.acc });
    ctx.span(phase::S2_INTER_C, t);
}

// ---------------------------------------------------- contribution fold ----

/// A locally-applied contribution to this rank's C block. Application
/// order is canonical — [`pipeline::OrderedFold`] — never arrival order.
enum Contribution {
    /// The diagonal block finished accumulating (every element's base).
    DiagDone,
    /// Column-based remote partial spanning the whole local block.
    AddFull(Dense),
    /// Row-based partial rows to scatter-add.
    AddRows(Vec<u32>, Dense),
    /// Structurally empty (e.g. a full-block pair with no column-served
    /// nonzeros): participates in the ordering only.
    Empty,
}

fn apply_contribution(c_local: &mut Dense, pool: &mut PoolRef, contrib: Contribution) {
    match contrib {
        Contribution::DiagDone | Contribution::Empty => {}
        Contribution::AddFull(d) => {
            c_local.add_assign(&d);
            pool.release(d);
        }
        Contribution::AddRows(rows, d) => {
            c_local.scatter_add_rows(&rows, &d);
            pool.release(d);
        }
    }
}

/// Whether a column-based remote partial applies as a compact row set
/// (sparse: few touched output rows) or as a full-block add. Shared by the
/// executor hot path and the session payload layout
/// ([`session`]) — the two must branch identically or the session pool
/// under-seeds and the zero-alloc guarantee silently breaks.
pub(crate) fn col_contribution_is_compact(touched: usize, block_rows: usize) -> bool {
    touched * 2 < block_rows.max(1)
}

/// Consume B rows arriving from `origin` (packed in `pair.b_rows` order,
/// the column space of the precomputed `a_col_compact` operand), per
/// kernel op:
///
/// - **SpMM**: multiply directly, then fold the partial in canonical order
///   (§Perf opt-1 + determinism contract). Sparse partials (few touched
///   output rows) park and apply as compact row sets so neither the parked
///   memory nor the apply-time add pays for the whole block; dense
///   partials add the full block in one pass.
/// - **SDDMM**: the received rows are the Y operand of the column-served
///   entries — compute their edge values into this rank's value buffer.
///   Nothing folds: each entry has exactly one producer.
/// - **Fused**: SDDMM as above, then the fresh values immediately multiply
///   the *same received Y rows* ([`SpmmKernel::spmm_vals_acc`]) and the
///   partial folds exactly like SpMM's — no second exchange.
#[allow(clippy::too_many_arguments)]
fn consume_b(
    ctx: &mut Ctx,
    op: KernelOp,
    fold: &mut OrderedFold<Contribution>,
    c_local: &mut Dense,
    x_local: Option<&Dense>,
    vals_out: &mut SddmmVals,
    origin: usize,
    rows: &[u32],
    data: Dense,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let pair = &plan.pairs[ctx.rank][origin];
    if op == KernelOp::Sddmm {
        let mut v = ctx.pool.acquire(1, pair.a_col_compact.nnz());
        if pair.a_col_compact.nnz() > 0 {
            debug_assert_eq!(rows.len(), pair.a_col_compact.ncols);
            if !pair.full_block {
                debug_assert_eq!(rows, &pair.b_rows[..]);
            }
            let x = x_local.expect("SDDMM consumes B with an X operand");
            let t = ctx.now();
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.sddmm_vals(&pair.a_col_compact, x, &data, &mut v.data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::COMPUTE_REMOTE, t);
        }
        ctx.pool.release(data);
        vals_out.col.insert(origin, v);
        return;
    }
    let contrib = if pair.a_col_compact.nnz() == 0 {
        ctx.pool.release(data);
        Contribution::Empty
    } else {
        debug_assert_eq!(rows.len(), pair.a_col_compact.ncols);
        if !pair.full_block {
            debug_assert_eq!(rows, &pair.b_rows[..]);
        }
        let t = ctx.now();
        let mut partial = ctx.pool.acquire(c_local.nrows, data.ncols);
        let dt = gated(gate, || {
            let t0 = Instant::now();
            match op {
                KernelOp::Spmm => kernel.spmm_acc(&pair.a_col_compact, &data, &mut partial),
                KernelOp::FusedSddmmSpmm => {
                    let x = x_local.expect("fused kernel consumes B with an X operand");
                    let mut v = ctx.pool.acquire(1, pair.a_col_compact.nnz());
                    kernel.sddmm_vals(&pair.a_col_compact, x, &data, &mut v.data);
                    kernel.spmm_vals_acc(&pair.a_col_compact, &v.data, &data, &mut partial);
                    ctx.pool.release(v);
                }
                KernelOp::Sddmm => unreachable!("handled above"),
            }
            t0.elapsed().as_secs_f64()
        });
        ctx.stats.compute_secs += dt;
        ctx.span(phase::COMPUTE_REMOTE, t);
        ctx.pool.release(data);
        // The branch is a pure function of the pair's structure, so it is
        // identical across modes/runs and determinism is preserved.
        let touched = pair.a_col_compact.nonempty_rows();
        if col_contribution_is_compact(touched.len(), c_local.nrows) {
            let mut compact = ctx.pool.acquire(touched.len(), partial.ncols);
            partial.gather_rows_into(&touched, &mut compact);
            ctx.pool.release(partial);
            Contribution::AddRows(touched, compact)
        } else {
            Contribution::AddFull(partial)
        }
    };
    fold.offer(ckey(KIND_B, origin), contrib, |c| {
        apply_contribution(c_local, &mut ctx.pool, c)
    });
}

/// Consume X rows arriving from `origin` (packed in `pair.c_rows` order —
/// the row space of the precomputed `a_row_compact` operand): compute the
/// row-served edge values this rank owes `origin`. For standalone SDDMM
/// the values stay here, plan-distributed, for assembly. For the fused
/// kernel they immediately multiply the local Y block and the partial C
/// rows take the plan's ordinary row-based route back ([`RowRoute`]) —
/// direct, via the flow rep, or folded locally when this rank is the rep.
#[allow(clippy::too_many_arguments)]
fn consume_x(
    ctx: &mut Ctx,
    prog: &Program,
    aggs: &mut BTreeMap<usize, AggFlow>,
    b_local: &Dense,
    vals_out: &mut SddmmVals,
    origin: usize,
    rows: &[u32],
    data: Dense,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let pair = &plan.pairs[origin][ctx.rank];
    debug_assert_eq!(rows, &pair.c_rows[..]);
    debug_assert_eq!(pair.a_row_compact.nrows, rows.len());
    let mut v = ctx.pool.acquire(1, pair.a_row_compact.nnz());
    match prog.op {
        KernelOp::Sddmm => {
            let t = ctx.now();
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.sddmm_vals(&pair.a_row_compact, &data, b_local, &mut v.data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::COMPUTE_REMOTE, t);
            ctx.pool.release(data);
            vals_out.row.insert(origin, v);
        }
        KernelOp::FusedSddmmSpmm => {
            let t = ctx.now();
            let mut partial = ctx.pool.acquire(pair.a_row_compact.nrows, b_local.ncols);
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.sddmm_vals(&pair.a_row_compact, &data, b_local, &mut v.data);
                kernel.spmm_vals_acc(&pair.a_row_compact, &v.data, b_local, &mut partial);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::S1_INTRA_C, t);
            ctx.pool.release(data);
            ctx.pool.release(v);
            let route = prog
                .row_route
                .get(&origin)
                .copied()
                .expect("X arrival without a row route");
            match route {
                RowRoute::Direct => ctx.send(
                    origin,
                    Msg::C { from: ctx.rank, rows: pair.c_rows.clone(), data: partial },
                ),
                RowRoute::Flow(i) => {
                    let f = &ctx.sched.expect("flow route implies a schedule").c_flows[i];
                    debug_assert_eq!(f.dst, origin);
                    if f.rep == ctx.rank {
                        let rank = ctx.rank;
                        let agg = aggs.get_mut(&origin).expect("unknown agg flow");
                        if agg.offer(rank, pair.c_rows.clone(), partial, &mut ctx.pool) {
                            complete_agg(ctx, aggs, origin);
                        }
                    } else {
                        ctx.send(
                            f.rep,
                            Msg::CAgg {
                                from: ctx.rank,
                                final_dst: origin,
                                rows: pair.c_rows.clone(),
                                data: partial,
                            },
                        );
                    }
                }
            }
        }
        KernelOp::Spmm => unreachable!("SpMM programs expect no X messages"),
    }
}

/// Extract `want` rows (a subset of the sorted `have` rows) from `data`
/// into a pooled buffer.
fn gather_subset(pool: &mut PoolRef, have: &[u32], data: &Dense, want: &[u32]) -> Dense {
    let mut out = pool.acquire(want.len(), data.ncols);
    for (i, w) in want.iter().enumerate() {
        let k = have.binary_search(w).expect("subset violation");
        out.row_mut(i).copy_from_slice(data.row(k));
    }
    out
}

// ------------------------------------------------------------ driver ----

/// The per-rank program: workflow steps 3–5 of §5.1 (steps 1–2 are the
/// offline planning already captured in `plan`/`sched`, and the program
/// derivation in `prog`), scheduled either as the overlapped pipeline or
/// strictly phase-ordered. `c_local` must arrive zeroed and shaped to this
/// rank's block (zero-width for SDDMM); sessions pass persistent buffers
/// here. `x_local` is the X operand block (SDDMM/fused only); `vals`
/// collects this rank's plan-distributed edge values.
fn rank_main(
    ctx: &mut Ctx,
    blocks: &LocalBlocks,
    x_local: Option<&Dense>,
    b_local: &Dense,
    c_local: &mut Dense,
    vals: &mut SddmmVals,
    prog: &Program,
) {
    let n_dense = b_local.ncols;
    debug_assert_eq!(blocks.diag.nrows, ctx.part.len(ctx.rank));
    debug_assert_eq!(c_local.nrows, ctx.part.len(ctx.rank));
    let c_local = &mut *c_local;
    if prog.op != KernelOp::Spmm {
        // One entry-order buffer for the whole diagonal pattern, filled
        // tile by tile.
        vals.diag = ctx.pool.acquire(1, blocks.diag.nnz());
    }

    let mut fold = OrderedFold::new(prog.fold_keys.clone());
    let mut aggs: BTreeMap<usize, AggFlow> = prog
        .agg_flows
        .iter()
        .map(|&i| {
            let f = &ctx.sched.expect("agg flows imply a schedule").c_flows[i];
            (f.dst, AggFlow::new(f, n_dense, &mut ctx.pool))
        })
        .collect();
    let mut diag_left = prog
        .items
        .iter()
        .filter(|i| matches!(i, Item::DiagTile { .. }))
        .count();
    if diag_left == 0 && prog.op != KernelOp::Sddmm {
        // Zero-row block: the base "contribution" is trivially complete.
        fold.offer(DIAG_KEY, Contribution::DiagDone, |c| {
            apply_contribution(c_local, &mut ctx.pool, c)
        });
    }
    let mut got = 0usize;

    if ctx.opts.overlap {
        // Overlapped pipeline: eager posts, then compute interleaved with
        // non-blocking drains of whatever has already arrived.
        post_b(ctx, prog, b_local, x_local);
        for item in &prog.items {
            while let Ok(msg) = ctx.inbox.try_recv() {
                got += 1;
                on_msg(ctx, prog, msg, x_local, b_local, c_local, vals, &mut fold, &mut aggs, true);
            }
            run_item(
                ctx,
                item,
                blocks,
                x_local,
                b_local,
                c_local,
                vals,
                &mut fold,
                &mut aggs,
                &mut diag_left,
                None,
                prog.op,
            );
        }
    } else {
        // Phase-ordered control: all local compute with sends deferred,
        // then one blocking exchange + aggregation. (For SDDMM/fused the
        // local phase is the diagonal only; remote compute is reactive and
        // happens in the drain below, after every post is out.)
        let mut deferred = Deferred::default();
        for item in &prog.items {
            run_item(
                ctx,
                item,
                blocks,
                x_local,
                b_local,
                c_local,
                vals,
                &mut fold,
                &mut aggs,
                &mut diag_left,
                Some(&mut deferred),
                prog.op,
            );
        }
        post_b(ctx, prog, b_local, x_local);
        for (dst, msg) in deferred.msgs.drain(..) {
            ctx.send(dst, msg);
        }
        for (final_dst, rows, data) in deferred.self_aggs.drain(..) {
            let rank = ctx.rank;
            let agg = aggs.get_mut(&final_dst).expect("unknown agg flow");
            if agg.offer(rank, rows, data, &mut ctx.pool) {
                complete_agg(ctx, &mut aggs, final_dst);
            }
        }
    }

    // Idle drain: block for whatever is still in flight.
    while got < prog.expect_msgs {
        let t_idle = ctx.now();
        let msg = ctx.inbox.recv().expect("inbox closed — peer rank panicked");
        ctx.stats.idle_secs += ctx.now() - t_idle;
        ctx.span(phase::IDLE, t_idle);
        got += 1;
        on_msg(ctx, prog, msg, x_local, b_local, c_local, vals, &mut fold, &mut aggs, false);
    }
    debug_assert!(fold.is_done(), "rank {}: fold incomplete", ctx.rank);
    debug_assert!(aggs.is_empty(), "rank {}: unshipped aggregates", ctx.rank);
}

/// Gather and send every outgoing dense-row payload (cheap packs — no
/// compute), in program order: B posts (inter-group flows first, then
/// same-group directs), then X posts for the SDDMM-family kernels.
fn post_b(ctx: &mut Ctx, prog: &Program, b_local: &Dense, x_local: Option<&Dense>) {
    for post in &prog.b_posts {
        let t = ctx.now();
        let mut data = ctx.pool.acquire(post.rows.len(), b_local.ncols);
        b_local.gather_rows_into(&post.rows, &mut data);
        ctx.send(
            post.dst,
            Msg::B { from: ctx.rank, origin: ctx.rank, rows: post.rows.clone(), data },
        );
        ctx.span(post.phase, t);
    }
    for post in &prog.x_posts {
        let x = x_local.expect("X posts require an X operand");
        let t = ctx.now();
        let mut data = ctx.pool.acquire(post.rows.len(), x.ncols);
        x.gather_rows_into(&post.rows, &mut data);
        ctx.send(
            post.dst,
            Msg::X { from: ctx.rank, origin: ctx.rank, rows: post.rows.clone(), data },
        );
        ctx.span(post.phase, t);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_item(
    ctx: &mut Ctx,
    item: &Item,
    blocks: &LocalBlocks,
    x_local: Option<&Dense>,
    b_local: &Dense,
    c_local: &mut Dense,
    vals: &mut SddmmVals,
    fold: &mut OrderedFold<Contribution>,
    aggs: &mut BTreeMap<usize, AggFlow>,
    diag_left: &mut usize,
    mut defer: Option<&mut Deferred>,
    op: KernelOp,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let rank = ctx.rank;
    match item {
        Item::DiagTile { r0, r1 } => {
            let t = ctx.now();
            let dt = gated(gate, || {
                let t0 = Instant::now();
                match op {
                    KernelOp::Spmm => {
                        if *r0 == 0 && *r1 == c_local.nrows {
                            // Whole block: dispatch through the backend's
                            // full spmm_acc (bitwise-identical for the
                            // native kernel; the AOT path for PJRT).
                            // Partial tiles use the native row loop.
                            kernel.spmm_acc(&blocks.diag, b_local, c_local);
                        } else {
                            kernel.spmm_rows(&blocks.diag, b_local, c_local, *r0, *r1);
                        }
                    }
                    KernelOp::Sddmm => {
                        let x = x_local.expect("SDDMM diagonal needs an X operand");
                        let vd = &mut vals.diag.data;
                        kernel.sddmm_rows(&blocks.diag, x, b_local, vd, *r0, *r1);
                    }
                    KernelOp::FusedSddmmSpmm => {
                        // Edge values for this tile, then immediately
                        // consumed as the tile's SpMM operand.
                        let x = x_local.expect("fused diagonal needs an X operand");
                        let vd = &mut vals.diag.data;
                        kernel.sddmm_rows(&blocks.diag, x, b_local, vd, *r0, *r1);
                        kernel.spmm_vals_rows(
                            &blocks.diag,
                            &vals.diag.data,
                            b_local,
                            c_local,
                            *r0,
                            *r1,
                        );
                    }
                }
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::COMPUTE_LOCAL, t);
            *diag_left -= 1;
            if *diag_left == 0 && op != KernelOp::Sddmm {
                fold.offer(DIAG_KEY, Contribution::DiagDone, |c| {
                    apply_contribution(c_local, &mut ctx.pool, c)
                });
            }
        }
        Item::ProduceDirectC { dst } => {
            let pair = &plan.pairs[*dst][rank];
            let ph = if ctx.sched.is_some() {
                phase::S1_INTRA_C
            } else {
                phase::COMPUTE_LOCAL
            };
            let t = ctx.now();
            let mut data = ctx.pool.acquire(pair.a_row_compact.nrows, b_local.ncols);
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.spmm_acc(&pair.a_row_compact, b_local, &mut data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(ph, t);
            let msg = Msg::C { from: rank, rows: pair.c_rows.clone(), data };
            match defer.as_deref_mut() {
                None => ctx.send(*dst, msg),
                Some(d) => d.msgs.push((*dst, msg)),
            }
        }
        Item::ProduceFlowC { flow } => {
            let sched = ctx.sched.expect("flow item implies a schedule");
            let f = &sched.c_flows[*flow];
            let pair = &plan.pairs[f.dst][rank];
            let t = ctx.now();
            let mut data = ctx.pool.acquire(pair.a_row_compact.nrows, b_local.ncols);
            let dt = gated(gate, || {
                let t0 = Instant::now();
                kernel.spmm_acc(&pair.a_row_compact, b_local, &mut data);
                t0.elapsed().as_secs_f64()
            });
            ctx.stats.compute_secs += dt;
            ctx.span(phase::S1_INTRA_C, t);
            if f.rep == rank {
                match defer.as_deref_mut() {
                    None => {
                        let agg = aggs.get_mut(&f.dst).expect("unknown agg flow");
                        if agg.offer(rank, pair.c_rows.clone(), data, &mut ctx.pool) {
                            complete_agg(ctx, aggs, f.dst);
                        }
                    }
                    Some(d) => d.self_aggs.push((f.dst, pair.c_rows.clone(), data)),
                }
            } else {
                let msg =
                    Msg::CAgg { from: rank, final_dst: f.dst, rows: pair.c_rows.clone(), data };
                match defer.as_deref_mut() {
                    None => ctx.send(f.rep, msg),
                    Some(d) => d.msgs.push((f.rep, msg)),
                }
            }
        }
    }
}

/// Handle one arrived message: account it, route it (rep redistribution /
/// pre-aggregation), and consume it per the program's kernel op — folding
/// in canonical order where the op accumulates.
#[allow(clippy::too_many_arguments)]
fn on_msg(
    ctx: &mut Ctx,
    prog: &Program,
    msg: Msg,
    x_local: Option<&Dense>,
    b_local: &Dense,
    c_local: &mut Dense,
    vals: &mut SddmmVals,
    fold: &mut OrderedFold<Contribution>,
    aggs: &mut BTreeMap<usize, AggFlow>,
    overlapped: bool,
) {
    ctx.recv_account(&msg, overlapped);
    match msg {
        Msg::B { from, origin, rows, data } => {
            if let Some(&fi) = prog.rep_b.get(&origin) {
                // Stage-I inter-group flow arrival: we are the rep.
                debug_assert_eq!(from, origin);
                let sched = ctx.sched.expect("rep_b implies a schedule");
                let f = &sched.b_flows[fi];
                debug_assert_ne!(
                    ctx.topo.group_of(origin),
                    ctx.topo.group_of(ctx.rank),
                    "B flows cross groups by construction"
                );
                // Stage II ②: redistribute to in-group consumers...
                let t = ctx.now();
                let mut own: Option<(&[u32], Dense)> = None;
                for (consumer, crows) in &f.consumers {
                    let sub = gather_subset(&mut ctx.pool, &rows, &data, crows);
                    if *consumer == ctx.rank {
                        own = Some((crows.as_slice(), sub));
                    } else {
                        ctx.send(
                            *consumer,
                            Msg::B { from: ctx.rank, origin, rows: crows.clone(), data: sub },
                        );
                    }
                }
                ctx.span(phase::S2_INTRA_B, t);
                ctx.pool.release(data);
                // ...then compute and consume our own subset.
                if let Some((crows, sub)) = own {
                    consume_b(ctx, prog.op, fold, c_local, x_local, vals, origin, crows, sub);
                }
            } else {
                // Direct in-group B or rep→consumer distribution.
                consume_b(ctx, prog.op, fold, c_local, x_local, vals, origin, &rows, data);
            }
        }
        Msg::X { from, origin, rows, data } => {
            if let Some(&fi) = prog.rep_x.get(&origin) {
                // Stage-I X flow arrival: we rep the reversed fetch —
                // identical mechanics to the B rep above, dispatching to
                // the row-serving compute path instead of the fold.
                debug_assert_eq!(from, origin);
                let xsched = ctx.xsched.expect("rep_x implies an X schedule");
                let f = &xsched.b_flows[fi];
                let t = ctx.now();
                let mut own: Option<(&[u32], Dense)> = None;
                for (consumer, crows) in &f.consumers {
                    let sub = gather_subset(&mut ctx.pool, &rows, &data, crows);
                    if *consumer == ctx.rank {
                        own = Some((crows.as_slice(), sub));
                    } else {
                        ctx.send(
                            *consumer,
                            Msg::X { from: ctx.rank, origin, rows: crows.clone(), data: sub },
                        );
                    }
                }
                ctx.span(phase::S2_INTRA_X, t);
                ctx.pool.release(data);
                if let Some((crows, sub)) = own {
                    consume_x(ctx, prog, aggs, b_local, vals, origin, crows, sub);
                }
            } else {
                consume_x(ctx, prog, aggs, b_local, vals, origin, &rows, data);
            }
        }
        Msg::C { from, rows, data } => {
            fold.offer(ckey(KIND_C, from), Contribution::AddRows(rows, data), |c| {
                apply_contribution(c_local, &mut ctx.pool, c)
            });
        }
        Msg::CAgg { from, final_dst, rows, data } => {
            let agg = aggs.get_mut(&final_dst).expect("unknown agg flow");
            if agg.offer(from, rows, data, &mut ctx.pool) {
                complete_agg(ctx, aggs, final_dst);
            }
        }
        Msg::CRed { .. } => {
            unreachable!("reduce-scatter messages only occur in replicated runs")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::hierarchy;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;
    use crate::util::rng::Rng;
    use kernel::NativeKernel;

    fn verify(
        a: &crate::sparse::Csr,
        ranks: usize,
        strategy: Strategy,
        mode: Mode,
    ) -> ExecStats {
        verify_with(a, ranks, strategy, mode, &ExecOpts::default())
    }

    fn verify_with(
        a: &crate::sparse::Csr,
        ranks: usize,
        strategy: Strategy,
        mode: Mode,
        opts: &ExecOpts,
    ) -> ExecStats {
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(a, &part);
        let plan = comm::plan(&blocks, &part, strategy, None);
        let topo = Topology::tsubame4(ranks);
        let sched = match mode {
            Mode::Flat => None,
            Mode::Hierarchical => Some(hierarchy::build(&plan, &topo)),
        };
        let mut rng = Rng::new(42);
        let b = Dense::random(a.nrows, 16, &mut rng);
        let want = a.spmm(&b);
        let (got, stats) = run_with(
            &part,
            &plan,
            &blocks,
            sched.as_ref(),
            &topo,
            &b,
            &NativeKernel,
            opts,
        );
        let err = want.diff_norm(&got) / (want.max_abs() as f64 + 1e-30);
        assert!(err < 1e-3, "{:?}/{mode:?}: rel err {err}", strategy);
        stats
    }

    #[test]
    fn flat_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 1);
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
            Strategy::Joint(Solver::Greedy),
        ] {
            verify(&a, 8, strategy, Mode::Flat);
        }
    }

    #[test]
    fn hier_all_strategies_exact() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 2);
        for strategy in [
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            verify(&a, 8, strategy, Mode::Hierarchical);
        }
    }

    #[test]
    fn hier_across_datasets() {
        for (gen_fn, name) in [
            (gen::mesh2d(12, 3), "mesh"),
            (gen::powerlaw(128, 1200, 1.4, 3), "web"),
            (gen::banded_hub(128, 3, 4, 40, 3), "traffic"),
        ] {
            let _ = name;
            verify(&gen_fn, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn phase_ordered_mode_exact_everywhere() {
        let a = gen::rmat(128, 1500, (0.55, 0.2, 0.19), false, 8);
        for mode in [Mode::Flat, Mode::Hierarchical] {
            verify_with(
                &a,
                8,
                Strategy::Joint(Solver::Koenig),
                mode,
                &ExecOpts::sequential(),
            );
        }
    }

    #[test]
    fn hier_reduces_inter_bytes_vs_flat() {
        // Web pattern with hubs: hierarchical dedup must cut inter-group
        // bytes actually sent (measured, not planned).
        let a = gen::powerlaw(256, 4000, 1.3, 4);
        let flat = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Flat);
        let hier = verify(&a, 16, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        assert!(
            hier.total_inter_bytes() < flat.total_inter_bytes(),
            "hier {} !< flat {}",
            hier.total_inter_bytes(),
            flat.total_inter_bytes()
        );
    }

    #[test]
    fn various_rank_counts() {
        let a = gen::rmat(128, 2000, (0.5, 0.25, 0.15), false, 5);
        for ranks in [2, 3, 5, 8, 16] {
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Flat);
            verify(&a, ranks, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        }
    }

    #[test]
    fn single_rank_degenerate() {
        let a = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 6);
        verify(&a, 1, Strategy::Joint(Solver::Koenig), Mode::Flat);
    }

    #[test]
    fn empty_matrix() {
        let a = crate::sparse::Csr::zeros(32, 32);
        let part = RowPartition::balanced(32, 4);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(4);
        let b = Dense::from_elem(32, 4, 1.0);
        let (got, _) = run(&part, &plan, &blocks, None, &topo, &b, &NativeKernel);
        assert_eq!(got, Dense::zeros(32, 4));
    }

    #[test]
    fn symmetric_matrix_symmetric_traffic() {
        // Joint strategy on a symmetric matrix should produce symmetric
        // measured traffic (Fig. 9's observation), unlike column-based.
        let a = gen::banded_hub(256, 3, 6, 60, 7);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let topo = Topology::tsubame4(8);
        let b = Dense::from_elem(256, 8, 1.0);

        let jplan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let jm = jplan.volume_matrix(8);
        let cplan = comm::plan(&blocks, &part, Strategy::Column, None);
        let cm = cplan.volume_matrix(8);
        assert!(
            jm.asymmetry() <= cm.asymmetry() + 1e-9,
            "joint {} vs column {}",
            jm.asymmetry(),
            cm.asymmetry()
        );
        // And both still compute the right answer.
        let want = a.spmm(&b);
        let (got, _) = run(&part, &jplan, &blocks, None, &topo, &b, &NativeKernel);
        assert!(want.diff_norm(&got) < 1e-3);
    }

    #[test]
    fn send_and_recv_byte_accounting_agree() {
        // Satellite fix: sender-side and receiver-side per-tier totals must
        // match exactly, including representative forwarding, and the
        // measured volume matrix must tell the same story.
        let a = gen::powerlaw(256, 4000, 1.35, 9);
        for mode in [Mode::Flat, Mode::Hierarchical] {
            for opts in [ExecOpts::default(), ExecOpts::sequential()] {
                let stats = verify_with(&a, 16, Strategy::Joint(Solver::Koenig), mode, &opts);
                assert_eq!(
                    stats.total_inter_bytes(),
                    stats.total_inter_recv_bytes(),
                    "{mode:?}/{opts:?}: inter sent != recv"
                );
                assert_eq!(
                    stats.total_intra_bytes(),
                    stats.total_intra_recv_bytes(),
                    "{mode:?}/{opts:?}: intra sent != recv"
                );
                let sent_msgs: u64 = stats.per_rank.iter().map(|r| r.msgs_sent).sum();
                let recv_msgs: u64 = stats.per_rank.iter().map(|r| r.msgs_recv).sum();
                assert_eq!(sent_msgs, recv_msgs);
                let mv = stats.measured_volume();
                assert_eq!(
                    mv.total(),
                    stats.total_inter_bytes() + stats.total_intra_bytes()
                );
                let topo = Topology::tsubame4(16);
                assert_eq!(
                    mv.inter_group_total(&topo.group_vec()),
                    stats.total_inter_bytes()
                );
            }
        }
    }

    #[test]
    fn overlap_and_phase_ordered_bit_identical() {
        // The determinism contract: canonical fold order makes overlap
        // on/off produce the same bits even on arbitrary float inputs.
        let a = gen::powerlaw(256, 4000, 1.4, 10);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let sched = hierarchy::build(&plan, &topo);
        let mut rng = Rng::new(3);
        let b = Dense::random(256, 16, &mut rng);
        let (c_on, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts::default(),
        );
        let (c_off, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts::sequential(),
        );
        assert_eq!(c_on.data, c_off.data, "overlap on/off must be bit-identical");
        // Tile height must not change bits either.
        let (c_tile, _) = run_with(
            &part,
            &plan,
            &blocks,
            Some(&sched),
            &topo,
            &b,
            &NativeKernel,
            &ExecOpts { tile_rows: 7, ..ExecOpts::default() },
        );
        assert_eq!(c_on.data, c_tile.data, "tile height changed the bits");
    }

    #[test]
    fn overlap_window_accounting_consistent() {
        let a = gen::rmat(256, 4000, (0.55, 0.2, 0.19), false, 11);
        let stats = verify(&a, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        let w = stats.overlap_window();
        let recv_total = stats.total_inter_recv_bytes() + stats.total_intra_recv_bytes();
        assert_eq!(w.overlapped_bytes + w.idle_bytes, recv_total);
        assert!(w.compute_secs > 0.0);
        // Phase-ordered mode overlaps nothing by definition.
        let seq = verify_with(
            &a,
            8,
            Strategy::Joint(Solver::Koenig),
            Mode::Hierarchical,
            &ExecOpts::sequential(),
        );
        assert_eq!(seq.overlap_window().overlapped_bytes, 0);
    }

    #[test]
    fn phase_log_uses_schedule_names() {
        let a = gen::rmat(128, 2000, (0.55, 0.2, 0.19), false, 12);
        let stats = verify(&a, 8, Strategy::Joint(Solver::Koenig), Mode::Hierarchical);
        let names: std::collections::BTreeSet<&str> = stats
            .per_rank
            .iter()
            .flat_map(|r| r.phases.iter().map(|p| p.name))
            .collect();
        assert!(names.contains(phase::COMPUTE_LOCAL), "{names:?}");
        let sched_phases = [
            phase::S1_INTER_B,
            phase::S1_INTRA_C,
            phase::S2_INTER_C,
            phase::S2_INTRA_B,
        ];
        assert!(
            sched_phases.iter().any(|p| names.contains(p)),
            "no Alg. 1 phase in executor log: {names:?}"
        );
        for r in &stats.per_rank {
            for p in &r.phases {
                assert!(p.end >= p.start);
            }
        }
    }

    #[test]
    fn sddmm_matches_oracle_bitwise_every_mode() {
        // Distributed SDDMM is bitwise-identical to the serial oracle on
        // *arbitrary float* inputs: each edge value has exactly one
        // producer and the dot order is fixed, so no accumulation-order
        // freedom exists anywhere.
        let a = gen::powerlaw(192, 2600, 1.4, 31);
        let part = RowPartition::balanced(192, 8);
        let blocks = split_1d(&a, &part);
        let topo = Topology::tsubame4(8);
        let mut rng = Rng::new(41);
        let x = Dense::random(192, 8, &mut rng);
        let y = Dense::random(192, 8, &mut rng);
        let want = a.sddmm(&x, &y);
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            let plan = comm::plan(&blocks, &part, strategy, None);
            for hier in [false, true] {
                if hier && strategy == Strategy::Block {
                    continue; // block mode is defined flat-only
                }
                let sched = hier.then(|| hierarchy::build(&plan, &topo));
                for opts in [ExecOpts::default(), ExecOpts::sequential()] {
                    let (got, stats) = run_sddmm_with(
                        &part,
                        &plan,
                        &blocks,
                        sched.as_ref(),
                        &topo,
                        &x,
                        &y,
                        &NativeKernel,
                        &opts,
                    );
                    assert_eq!(got, want, "{strategy:?} hier={hier} {opts:?}");
                    // Both sides of every link agree on the new message
                    // kinds too.
                    assert_eq!(stats.total_inter_bytes(), stats.total_inter_recv_bytes());
                    assert_eq!(stats.total_intra_bytes(), stats.total_intra_recv_bytes());
                }
            }
        }
    }

    #[test]
    fn sddmm_b_side_volume_identical_to_spmm() {
        // The plan-sharing contract: the same B rows cross the same links
        // whichever kernel consumes them.
        let a = gen::powerlaw(256, 4000, 1.35, 33);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let mut rng = Rng::new(42);
        let x = Dense::random(256, 8, &mut rng);
        let y = Dense::random(256, 8, &mut rng);
        for hier in [false, true] {
            let sched = hier.then(|| hierarchy::build(&plan, &topo));
            let (_, spmm_stats) = run_with(
                &part,
                &plan,
                &blocks,
                sched.as_ref(),
                &topo,
                &y,
                &NativeKernel,
                &ExecOpts::default(),
            );
            let (_, sddmm_stats) = run_sddmm_with(
                &part,
                &plan,
                &blocks,
                sched.as_ref(),
                &topo,
                &x,
                &y,
                &NativeKernel,
                &ExecOpts::default(),
            );
            assert!(spmm_stats.measured_b_volume().total() > 0, "hier={hier}");
            assert_eq!(
                spmm_stats.measured_b_volume(),
                sddmm_stats.measured_b_volume(),
                "hier={hier}: B-side volume differs between kernels"
            );
        }
    }

    #[test]
    fn fused_matches_two_pass_bitwise_on_exact_inputs() {
        // Fused SDDMM→SpMM must equal SDDMM-then-SpMM bit for bit on
        // integer-exact inputs (float addition is associative there), for
        // every routing mode and schedule knob.
        let a = crate::bench::int_matrix(192, 1800, 51);
        let part = RowPartition::balanced(192, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let x = Dense::from_fn(192, 4, |i, j| ((i * 3 + j) % 5) as f32 - 2.0);
        let y = Dense::from_fn(192, 4, |i, j| ((i * 7 + j * 2) % 5) as f32 - 2.0);
        let want = a.sddmm(&x, &y).spmm(&y);
        for hier in [false, true] {
            let sched = hier.then(|| hierarchy::build(&plan, &topo));
            for opts in [
                ExecOpts::default(),
                ExecOpts::sequential(),
                ExecOpts { workers: 2, tile_rows: 7, ..ExecOpts::default() },
            ] {
                let (got, _) = run_fused_with(
                    &part,
                    &plan,
                    &blocks,
                    sched.as_ref(),
                    &topo,
                    &x,
                    &y,
                    &NativeKernel,
                    &opts,
                );
                assert_eq!(got.data, want.data, "hier={hier} {opts:?}");
            }
        }
    }

    #[test]
    fn fused_cuts_bytes_vs_two_pass() {
        // The fused kernel ships X+Y once and the partials back; two-pass
        // re-ships the B side for the SpMM pass. Measured, not modeled —
        // and not even counting the edge-value gather two-pass would need.
        let a = gen::powerlaw(256, 4000, 1.4, 61);
        let part = RowPartition::balanced(256, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let mut rng = Rng::new(62);
        let x = Dense::random(256, 8, &mut rng);
        let y = Dense::random(256, 8, &mut rng);
        for hier in [false, true] {
            let sched = hier.then(|| hierarchy::build(&plan, &topo));
            let total = |s: &ExecStats| s.total_inter_bytes() + s.total_intra_bytes();
            let (_, fused) = run_fused_with(
                &part, &plan, &blocks, sched.as_ref(), &topo, &x, &y, &NativeKernel,
                &ExecOpts::default(),
            );
            let (_, sd) = run_sddmm_with(
                &part, &plan, &blocks, sched.as_ref(), &topo, &x, &y, &NativeKernel,
                &ExecOpts::default(),
            );
            let (_, sp) = run_with(
                &part, &plan, &blocks, sched.as_ref(), &topo, &y, &NativeKernel,
                &ExecOpts::default(),
            );
            assert!(
                total(&fused) < total(&sd) + total(&sp),
                "hier={hier}: fused {} !< two-pass {}",
                total(&fused),
                total(&sd) + total(&sp)
            );
        }
    }

    #[test]
    fn worker_cap_changes_nothing() {
        let a = gen::rmat(192, 2500, (0.5, 0.22, 0.18), false, 13);
        let part = RowPartition::balanced(192, 8);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(8);
        let sched = hierarchy::build(&plan, &topo);
        let mut rng = Rng::new(17);
        let b = Dense::random(192, 8, &mut rng);
        let mut reference: Option<Dense> = None;
        for workers in [1usize, 2, 4, 8, 0] {
            let (c, _) = run_with(
                &part,
                &plan,
                &blocks,
                Some(&sched),
                &topo,
                &b,
                &NativeKernel,
                &ExecOpts { workers, ..ExecOpts::default() },
            );
            match &reference {
                None => reference = Some(c),
                Some(want) => assert_eq!(want.data, c.data, "workers={workers}"),
            }
        }
    }
}
