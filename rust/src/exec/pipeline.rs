//! Building blocks of the overlapped executor pipeline (Alg. 1 §6.2 made
//! real): execution options, a reusable buffer pool (generalized double
//! buffering — gathers, partials, and message payloads recycle instead of
//! allocating per transfer), a canonical-order fold that makes the result
//! independent of message arrival order, and a worker gate that caps how
//! many ranks compute concurrently (the determinism suite's lever for
//! forcing adversarial interleavings).

use crate::dense::Dense;
use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

/// Decode a contribution key built by [`ckey`] back into (kind, peer);
/// `None` for [`DIAG_KEY`]. Used by the session layer to enumerate the
/// posted-payload layout from a program's fold keys.
pub(crate) fn ckey_decode(key: u64) -> Option<(u8, usize)> {
    if key == DIAG_KEY {
        None
    } else {
        Some((((key >> 32) - 1) as u8, (key & 0xffff_ffff) as usize))
    }
}

/// Default diagonal-SpMM tile height between inbox drains.
pub const DEFAULT_TILE_ROWS: usize = 256;

/// Executor options: how the per-rank program is scheduled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecOpts {
    /// `true` (default): the overlapped pipeline — outgoing B posts before
    /// local compute, SpMM tiles interleaved with draining the inbox,
    /// representatives folding pre-aggregation incrementally as partials
    /// arrive. `false`: strictly phase-ordered execution (all local
    /// compute, then a blocking exchange, then aggregation) — the ablation
    /// control. Results are bit-identical either way: every scatter-add is
    /// applied in canonical (origin, row) order, not arrival order.
    pub overlap: bool,
    /// Diagonal-block SpMM tile height (rows) between inbox drains;
    /// 0 = [`DEFAULT_TILE_ROWS`].
    pub tile_rows: usize,
    /// Maximum number of ranks computing concurrently (worker-thread cap);
    /// 0 = one worker per rank (no cap). Any value must produce
    /// bit-identical results — the determinism tests sweep 1/2/4/8.
    pub workers: usize,
}

impl Default for ExecOpts {
    fn default() -> ExecOpts {
        ExecOpts { overlap: true, tile_rows: 0, workers: 0 }
    }
}

impl ExecOpts {
    /// The phase-ordered ablation control (`--overlap off`).
    pub fn sequential() -> ExecOpts {
        ExecOpts { overlap: false, ..ExecOpts::default() }
    }

    pub(crate) fn tile(&self) -> usize {
        if self.tile_rows == 0 {
            DEFAULT_TILE_ROWS
        } else {
            self.tile_rows
        }
    }
}

/// Pool of reusable f32 buffers. Outgoing payloads are acquired here and
/// released into the receiving side's pool on arrival, so steady state runs
/// allocation-free regardless of which rank produced a buffer.
///
/// Reuse is **best-fit**: the free list is kept sorted by capacity and
/// `acquire` takes the smallest buffer that already fits (a miss allocates
/// fresh and bumps [`BufferPool::allocs`] — the amortization metric the
/// session layer asserts on). Best-fit matters for the session guarantee:
/// once the pool holds one buffer per payload-layout slot, *no* later
/// acquire sequence over those slots can miss, whatever the arrival order.
pub(crate) struct BufferPool {
    /// Free buffers sorted by capacity (ascending).
    free: Vec<Vec<f32>>,
    /// Bound on retained buffers.
    cap: usize,
    /// Fresh-allocation events (pool misses and explicit seeds).
    pub allocs: u64,
}

/// Default bound on retained buffers — enough for deep pipelines, small
/// enough not to hoard a whole matrix per rank.
const POOL_CAP: usize = 64;

impl Default for BufferPool {
    fn default() -> BufferPool {
        BufferPool::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::with_cap(POOL_CAP)
    }

    /// A pool retaining up to `cap` buffers (sessions size this to their
    /// full payload layout so nothing is ever dropped).
    pub fn with_cap(cap: usize) -> BufferPool {
        BufferPool { free: Vec::new(), cap, allocs: 0 }
    }

    /// A zeroed `nrows × ncols` matrix, recycling the smallest retained
    /// allocation that fits; allocates (and counts) on a miss. Zero-sized
    /// requests (empty ranks / zero-width operands) bypass the pool
    /// entirely — they need no storage, must not steal a slot from a real
    /// payload, and must not count as allocation events.
    pub fn acquire(&mut self, nrows: usize, ncols: usize) -> Dense {
        let n = nrows * ncols;
        if n == 0 {
            return Dense { nrows, ncols, data: Vec::new() };
        }
        let i = self.free.partition_point(|v| v.capacity() < n);
        let mut data = if i < self.free.len() {
            self.free.remove(i)
        } else {
            self.allocs += 1;
            Vec::with_capacity(n)
        };
        data.clear();
        data.resize(n, 0.0);
        Dense { nrows, ncols, data }
    }

    /// Return a buffer to the pool. The cap is enforced with
    /// **largest-first eviction**: at capacity, whichever of {incoming,
    /// largest retained} has the bigger footprint is dropped, so a session
    /// serving varied widths converges on the smallest working set instead
    /// of hoarding every historical size forever. Seeded layouts are never
    /// evicted: [`BufferPool::seed`] grows the cap to cover every slot it
    /// plants, and a session holds at most its seeded count in the free
    /// list, so releases under a seeded layout always retain — preserving
    /// the steady-state zero-miss guarantee at the default cap.
    pub fn release(&mut self, d: Dense) {
        if d.data.capacity() == 0 {
            return;
        }
        if self.free.len() >= self.cap {
            match self.free.last() {
                // The free list is sorted ascending, so the last entry is
                // the largest retained buffer; evict it only if the
                // incoming one is smaller.
                Some(big) if big.capacity() > d.data.capacity() => {
                    self.free.pop();
                }
                _ => return,
            }
        }
        let i = self
            .free
            .partition_point(|v| v.capacity() <= d.data.capacity());
        self.free.insert(i, d.data);
    }

    /// Pre-seed one free buffer of `n` floats (a posted-payload slot).
    /// Counted in [`BufferPool::allocs`] like any other fresh allocation.
    /// Seeding grows the cap when the seeded layout outgrows it, so a
    /// session's full payload layout always fits and is never evicted.
    pub fn seed(&mut self, n: usize) {
        if n == 0 {
            return;
        }
        self.allocs += 1;
        let v: Vec<f32> = Vec::with_capacity(n);
        let i = self.free.partition_point(|b| b.capacity() <= v.capacity());
        self.free.insert(i, v);
        self.cap = self.cap.max(self.free.len());
    }
}

/// How a rank reaches its buffer pool: one-shot executions own a private
/// per-rank pool (the seed behavior); sessions share a single pool across
/// ranks behind a mutex so payloads released at the receiver are available
/// to their producer again next epoch.
pub(crate) enum PoolRef<'a> {
    Own(BufferPool),
    Shared(&'a Mutex<BufferPool>),
}

impl PoolRef<'_> {
    pub fn acquire(&mut self, nrows: usize, ncols: usize) -> Dense {
        match self {
            PoolRef::Own(p) => p.acquire(nrows, ncols),
            PoolRef::Shared(m) => m.lock().unwrap().acquire(nrows, ncols),
        }
    }

    pub fn release(&mut self, d: Dense) {
        match self {
            PoolRef::Own(p) => p.release(d),
            PoolRef::Shared(m) => m.lock().unwrap().release(d),
        }
    }
}

/// Canonical contribution key: `DIAG_KEY` sorts first (the diagonal block
/// is every element's base value), then column-based (B) contributions by
/// origin, then row-based (C) contributions by sending peer, then — in
/// replicated (1.5D) runs — member-accumulator reductions by member rank.
pub(crate) const DIAG_KEY: u64 = 0;
pub(crate) const KIND_B: u8 = 0;
pub(crate) const KIND_C: u8 = 1;
pub(crate) const KIND_RED: u8 = 2;

pub(crate) fn ckey(kind: u8, peer: usize) -> u64 {
    ((kind as u64 + 1) << 32) | peer as u64
}

/// Applies contributions in a fixed canonical key order regardless of
/// arrival order: an out-of-order contribution is parked until every
/// earlier key has been applied. This is the determinism contract of the
/// pipeline — float addition is not associative, so the *sequence* of
/// scatter-adds into any accumulator must not depend on thread timing.
pub(crate) struct OrderedFold<T> {
    keys: Vec<u64>,
    next: usize,
    parked: BTreeMap<u64, T>,
}

impl<T> OrderedFold<T> {
    pub fn new(mut keys: Vec<u64>) -> OrderedFold<T> {
        keys.sort_unstable();
        debug_assert!(keys.windows(2).all(|w| w[0] < w[1]), "duplicate fold key");
        OrderedFold { keys, next: 0, parked: BTreeMap::new() }
    }

    /// Park `item` under `key`, then apply every contribution that is now
    /// at the head of the canonical order (possibly including this one).
    pub fn offer(&mut self, key: u64, item: T, mut apply: impl FnMut(T)) {
        debug_assert!(self.keys.binary_search(&key).is_ok(), "unknown fold key {key:#x}");
        let prev = self.parked.insert(key, item);
        debug_assert!(prev.is_none(), "duplicate contribution for key {key:#x}");
        while self.next < self.keys.len() {
            match self.parked.remove(&self.keys[self.next]) {
                Some(ready) => {
                    apply(ready);
                    self.next += 1;
                }
                None => break,
            }
        }
    }

    pub fn is_done(&self) -> bool {
        self.next == self.keys.len()
    }
}

/// Counting gate bounding how many ranks run compute simultaneously. Only
/// compute sections acquire a permit — never a blocking receive — so the
/// gate can not deadlock the exchange: every rank holding a permit is
/// making progress and releases it before waiting on the network.
pub(crate) struct ComputeGate {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl ComputeGate {
    pub fn new(workers: usize) -> ComputeGate {
        assert!(workers > 0);
        ComputeGate { permits: Mutex::new(workers), cv: Condvar::new() }
    }

    /// Run `f` while holding one permit. The permit is restored by a drop
    /// guard, so a panicking kernel unwinds the rank thread (and cascades
    /// through the channel expects) instead of starving the other ranks
    /// into a hang.
    pub fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        let mut n = self.permits.lock().unwrap();
        while *n == 0 {
            n = self.cv.wait(n).unwrap();
        }
        *n -= 1;
        drop(n);
        struct Release<'a>(&'a ComputeGate);
        impl Drop for Release<'_> {
            fn drop(&mut self) {
                *self.0.permits.lock().unwrap() += 1;
                self.0.cv.notify_one();
            }
        }
        let _permit = Release(self);
        f()
    }
}

/// Run `f` under the gate when one is configured.
pub(crate) fn gated<R>(gate: Option<&ComputeGate>, f: impl FnOnce() -> R) -> R {
    match gate {
        Some(g) => g.run(f),
        None => f(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_allocations() {
        let mut pool = BufferPool::new();
        let a = pool.acquire(4, 8);
        assert_eq!(pool.allocs, 1);
        let ptr = a.data.as_ptr();
        pool.release(a);
        let b = pool.acquire(2, 8); // smaller fits the same allocation
        assert_eq!(b.data.as_ptr(), ptr);
        assert_eq!(b.nrows, 2);
        assert_eq!(pool.allocs, 1, "reuse must not count as an allocation");
        assert!(b.data.iter().all(|&x| x == 0.0), "acquire must zero");
        // A request that fits no retained buffer allocates fresh (and
        // counts) instead of growing a smaller one.
        pool.release(b);
        let c = pool.acquire(16, 16);
        assert_eq!(c.data.len(), 256);
        assert_eq!(pool.allocs, 2);
        assert!(c.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pool_best_fit_prefers_smallest_sufficient() {
        let mut pool = BufferPool::new();
        let big = pool.acquire(10, 10);
        let small = pool.acquire(2, 2);
        let (big_ptr, small_ptr) = (big.data.as_ptr(), small.data.as_ptr());
        pool.release(big);
        pool.release(small);
        // A 4-float request must take the 4-capacity buffer, keeping the
        // 100-capacity one free for a large request.
        let got = pool.acquire(2, 2);
        assert_eq!(got.data.as_ptr(), small_ptr);
        let got_big = pool.acquire(5, 10);
        assert_eq!(got_big.data.as_ptr(), big_ptr);
        assert_eq!(pool.allocs, 2, "both requests were served from the pool");
    }

    #[test]
    fn pool_seed_covers_later_acquires() {
        let mut pool = BufferPool::new();
        for n in [32, 8, 64] {
            pool.seed(n);
        }
        assert_eq!(pool.allocs, 3);
        // Any acquire sequence over the seeded sizes hits the pool.
        let a = pool.acquire(2, 4);
        let b = pool.acquire(4, 8);
        let c = pool.acquire(8, 8);
        assert_eq!(pool.allocs, 3, "seeded slots must absorb every acquire");
        pool.release(a);
        pool.release(b);
        pool.release(c);
        pool.seed(0); // no-op
        assert_eq!(pool.allocs, 3);
    }

    #[test]
    fn pool_cap_evicts_largest_first() {
        // Satellite regression (PR 6): pools were built with
        // `with_cap(usize::MAX)`, retaining every historical buffer size
        // forever. The cap is real now, and eviction drops the largest
        // footprint first.
        let mut pool = BufferPool::with_cap(2);
        pool.release(Dense::zeros(2, 8)); // 16 floats
        pool.release(Dense::zeros(8, 8)); // 64 floats — pool full
        // Releasing a smaller buffer evicts the 64-float one.
        pool.release(Dense::zeros(1, 4)); // 4 floats
        let before = pool.allocs;
        let big = pool.acquire(8, 8);
        assert_eq!(pool.allocs, before + 1, "largest buffer must be gone");
        // Releasing a larger buffer while full drops the incoming one.
        pool.release(big); // free = [4, 16] → 64 is the largest, dropped
        let before = pool.allocs;
        let small = pool.acquire(1, 4);
        let mid = pool.acquire(2, 8);
        assert_eq!(pool.allocs, before, "small buffers were retained");
        drop((small, mid));
    }

    #[test]
    fn pool_seed_grows_cap_beyond_default() {
        // A session layout larger than the configured cap must still be
        // fully retained: seed() grows the cap to cover every slot it
        // plants, keeping the zero-miss guarantee.
        let mut pool = BufferPool::with_cap(2);
        for n in [8, 16, 32, 64] {
            pool.seed(n);
        }
        assert_eq!(pool.allocs, 4);
        let bufs: Vec<Dense> =
            [(1, 8), (2, 8), (4, 8), (8, 8)].map(|(r, c)| pool.acquire(r, c)).into();
        assert_eq!(pool.allocs, 4, "seeded slots absorb every acquire");
        for b in bufs {
            pool.release(b);
        }
        // Every release was retained (cap grew to the seeded count), so a
        // second pass over the same sizes is still allocation-free.
        for (r, c) in [(1, 8), (2, 8), (4, 8), (8, 8)] {
            let b = pool.acquire(r, c);
            pool.release(b);
        }
        assert_eq!(pool.allocs, 4, "steady state stays zero-miss");
    }

    #[test]
    fn ckey_roundtrip() {
        assert_eq!(ckey_decode(DIAG_KEY), None);
        assert_eq!(ckey_decode(ckey(KIND_B, 7)), Some((KIND_B, 7)));
        assert_eq!(ckey_decode(ckey(KIND_C, 0)), Some((KIND_C, 0)));
    }

    #[test]
    fn ordered_fold_applies_in_key_order() {
        let keys = vec![DIAG_KEY, ckey(KIND_B, 3), ckey(KIND_B, 1), ckey(KIND_C, 0)];
        let mut fold = OrderedFold::new(keys);
        let mut applied = Vec::new();
        // Arrivals in adversarial order: everything parks until DIAG_KEY.
        fold.offer(ckey(KIND_C, 0), "c0", |x| applied.push(x));
        fold.offer(ckey(KIND_B, 3), "b3", |x| applied.push(x));
        assert!(applied.is_empty());
        fold.offer(DIAG_KEY, "diag", |x| applied.push(x));
        assert_eq!(applied, vec!["diag"]);
        fold.offer(ckey(KIND_B, 1), "b1", |x| applied.push(x));
        assert_eq!(applied, vec!["diag", "b1", "b3", "c0"]);
        assert!(fold.is_done());
    }

    #[test]
    fn ordered_fold_empty_is_done() {
        let fold: OrderedFold<()> = OrderedFold::new(Vec::new());
        assert!(fold.is_done());
    }

    #[test]
    fn diag_key_sorts_before_contributions() {
        assert!(DIAG_KEY < ckey(KIND_B, 0));
        assert!(ckey(KIND_B, usize::MAX as u32 as usize) < ckey(KIND_C, 0));
        assert!(ckey(KIND_C, usize::MAX as u32 as usize) < ckey(KIND_RED, 0));
        assert!(ckey(KIND_B, 3) < ckey(KIND_B, 4));
    }

    #[test]
    fn gate_bounds_concurrency() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let gate = ComputeGate::new(2);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    gate.run(|| {
                        let n = live.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(n, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        live.fetch_sub(1, Ordering::SeqCst);
                    });
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }
}
