//! 1.5D replicated executor (ROADMAP item 3, SpComm3D's replication
//! axis): `nranks` physical ranks form `nranks/c` replication groups of
//! `c` consecutive ranks each. The group's **home** (rank `g·c`) owns the
//! group's A blocks, B rows, and final C rows; the other members hold
//! replicas of the group A block and serve a round-robin share of the
//! group's inter-group flows, so a group's inbound traffic lands on `c`
//! NICs instead of one.
//!
//! Traffic shape per dealt group-pair flow `(g, h)`:
//!
//! - **Sparsity-aware allgather**: `h`'s home ships only the cover-named B
//!   rows (`pair.b_rows`) of the *group plan* — a [`CommPlan`] over the
//!   coarsened `nranks/c`-way partition — to the member of `g` dealt the
//!   pair, which multiplies them against the replicated `a_col_compact`.
//! - **Row-based leg**: `h`'s home computes `a_row_compact · B_home` and
//!   ships exactly the partial `c_rows` to the same member.
//! - **Sparsity-aware reduce-scatter**: each member folds its dealt flows
//!   into a private group-height accumulator in canonical order
//!   ([`OrderedFold`]), then ships only the accumulator's `touched` rows
//!   home ([`Msg::CRed`]); the home folds member reductions — its own
//!   accumulator included — after the diagonal block, again in canonical
//!   order, so results are bit-stable across thread interleavings.
//!
//! The deal-out and reduce wiring live in
//! [`crate::hierarchy::RepSchedule`]; this module only executes it. On
//! integer-exact inputs the result is bitwise-identical to the serial
//! reference and to every other replication factor — the property suite's
//! equivalence gate for `--replicate`.

use super::kernel::SpmmKernel;
use super::pipeline::{
    ckey, gated, BufferPool, ComputeGate, ExecOpts, OrderedFold, PoolRef, DIAG_KEY, KIND_B,
    KIND_C, KIND_RED,
};
use super::{
    apply_contribution, col_contribution_is_compact, Contribution, Ctx, ExecStats, Msg, Outbox,
    RankStats,
};
use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::hierarchy::{phase, RepAssign, RepSchedule};
use crate::partition::{LocalBlocks, RowPartition};
use crate::topology::Topology;
use std::sync::mpsc::channel;
use std::time::Instant;

/// Execute distributed SpMM under a 1.5D replicated decomposition:
/// `gpart`/`gplan`/`gblocks` describe the *group-level* problem (one part
/// per replication group), `rsched` deals its inter-group flows out to the
/// `rsched.map.nranks` physical ranks. Returns the assembled global C and
/// per-physical-rank stats (tier accounting against `topo`, which spans
/// the physical ranks).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_replicated(
    gpart: &RowPartition,
    gplan: &CommPlan,
    gblocks: &[LocalBlocks],
    rsched: &RepSchedule,
    topo: &Topology,
    b: &Dense,
    kernel: &(dyn SpmmKernel + Sync),
    opts: &ExecOpts,
) -> (Dense, ExecStats) {
    let map = rsched.map;
    assert_eq!(gpart.n, b.nrows);
    assert_eq!(gplan.nranks, map.ngroups(), "group plan / replica map mismatch");
    assert_eq!(gpart.nparts, map.ngroups(), "group partition / replica map mismatch");
    assert_eq!(gblocks.len(), map.ngroups());
    assert_eq!(rsched.assigns.len(), map.nranks);
    assert_eq!(
        topo.nranks, map.nranks,
        "replica map spans {} ranks but topology has {}",
        map.nranks, topo.nranks
    );
    let nranks = map.nranks;
    let n_dense = b.ncols;

    let mut senders = Vec::with_capacity(nranks);
    let mut inboxes = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    let gate = (opts.workers > 0).then(|| ComputeGate::new(opts.workers));

    let t0 = Instant::now();
    let mut results: Vec<Option<(Dense, RankStats)>> = (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = &senders;
            let gate = gate.as_ref();
            let inbox = inbox.take().unwrap();
            let g = map.group_of(rank);
            let (r0, r1) = gpart.range(g);
            let is_home = map.member_of(rank) == 0;
            // Only homes hold B (and C) rows; replica members operate
            // purely on fetched payloads and their private accumulator.
            let b_local = if is_home {
                Dense::from_vec(r1 - r0, n_dense, b.data[r0 * n_dense..r1 * n_dense].to_vec())
            } else {
                Dense::zeros(0, n_dense)
            };
            handles.push(scope.spawn(move || {
                let mut ctx = Ctx {
                    rank,
                    part: gpart,
                    plan: gplan,
                    sched: None,
                    xsched: None,
                    topo,
                    kernel,
                    outbox: Outbox::Local(senders),
                    inbox,
                    stats: RankStats {
                        sent_to: vec![0; nranks],
                        sent_b_to: vec![0; nranks],
                        ..RankStats::default()
                    },
                    opts: *opts,
                    gate,
                    t0,
                    pool: PoolRef::Own(BufferPool::new()),
                };
                let mut c_local = Dense::zeros(if is_home { r1 - r0 } else { 0 }, n_dense);
                rank_main_rep(&mut ctx, rsched, &gblocks[g], &b_local, &mut c_local);
                (rank, c_local, ctx.stats)
            }));
        }
        for h in handles {
            let (rank, c, stats) = h.join().expect("rank thread panicked");
            results[rank] = Some((c, stats));
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut c_global = Dense::zeros(gpart.n, n_dense);
    let mut per_rank = Vec::with_capacity(nranks);
    for (rank, slot) in results.into_iter().enumerate() {
        let (c_local, stats) = slot.unwrap();
        if map.member_of(rank) == 0 {
            let (r0, r1) = gpart.range(map.group_of(rank));
            assert_eq!(c_local.nrows, r1 - r0);
            c_global.data[r0 * n_dense..r1 * n_dense].copy_from_slice(&c_local.data);
        }
        per_rank.push(stats);
    }
    (c_global, ExecStats { per_rank, wall_secs: wall })
}

/// One physical rank's replicated program. Homes additionally run the
/// diagonal block, ship the group's outgoing payloads (both legs are pure
/// functions of `b_local`, so every send precedes every receive — no
/// cyclic waits), and fold member reductions; members only consume dealt
/// flows and reduce-scatter the result home.
pub(crate) fn rank_main_rep(
    ctx: &mut Ctx,
    rsched: &RepSchedule,
    blocks: &LocalBlocks,
    b_local: &Dense,
    c_local: &mut Dense,
) {
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let rank = ctx.rank;
    let map = &rsched.map;
    let g = map.group_of(rank);
    let asg = &rsched.assigns[rank];
    let is_home = asg.member == 0;
    let n_dense = b_local.ncols;
    let glen = ctx.part.len(g);
    debug_assert_eq!(blocks.diag.nrows, glen);
    debug_assert_eq!(c_local.nrows, if is_home { glen } else { 0 });

    // Inner fold: the flows dealt to this member, keyed by source group,
    // applied to a private group-height accumulator in canonical order.
    let inner_keys: Vec<u64> = asg
        .col_fetch
        .iter()
        .map(|&h| ckey(KIND_B, h))
        .chain(asg.row_recv.iter().map(|&h| ckey(KIND_C, h)))
        .collect();
    let mut acc = (!inner_keys.is_empty()).then(|| ctx.pool.acquire(glen, n_dense));
    let mut inner: OrderedFold<Contribution> = OrderedFold::new(inner_keys);
    let mut shipped = false;

    // Top fold (home only): the diagonal base, then each contributing
    // member's reduction by ascending rank — the home's own accumulator
    // (smallest rank in the group) folds first, locally, without a
    // message.
    let mut top_keys = Vec::new();
    if is_home {
        top_keys.push(DIAG_KEY);
        for m in map.members(g) {
            if !rsched.assigns[m].touched.is_empty() {
                top_keys.push(ckey(KIND_RED, m));
            }
        }
    }
    let mut top: OrderedFold<Contribution> = OrderedFold::new(top_keys);

    let expect = asg.col_fetch.len() + asg.row_recv.len() + asg.red_from.len();

    // Sparsity-aware allgather sends: only the cover-named B rows cross
    // the inter-group link. (`b_rows` is populated for full-block pairs
    // too — it spans the whole source block there.)
    for &(dst, dg) in &asg.b_sends {
        let pair = &plan.pairs[dg][g];
        let t = ctx.now();
        let mut data = ctx.pool.acquire(pair.b_rows.len(), n_dense);
        b_local.gather_rows_into(&pair.b_rows, &mut data);
        ctx.send(dst, Msg::B { from: rank, origin: g, rows: pair.b_rows.clone(), data });
        ctx.span(phase::S1_INTER_B, t);
    }
    // Row-based leg: partials this home computes for other groups' dealt
    // members.
    for &(dst, dg) in &asg.c_sends {
        let pair = &plan.pairs[dg][g];
        let t = ctx.now();
        let mut data = ctx.pool.acquire(pair.a_row_compact.nrows, n_dense);
        let dt = gated(gate, || {
            let t0 = Instant::now();
            kernel.spmm_acc(&pair.a_row_compact, b_local, &mut data);
            t0.elapsed().as_secs_f64()
        });
        ctx.stats.compute_secs += dt;
        ctx.span(phase::S1_INTRA_C, t);
        let t = ctx.now();
        ctx.send(dst, Msg::C { from: rank, rows: pair.c_rows.clone(), data });
        ctx.span(phase::S2_INTER_C, t);
    }

    // Diagonal tiles (home only), interleaved with inbox drains when
    // overlapping.
    let mut got = 0usize;
    let tile = if kernel.prefers_tiles() { ctx.opts.tile() } else { usize::MAX };
    let mut tiles = Vec::new();
    if is_home {
        let mut r0 = 0;
        while r0 < glen {
            let r1 = r0.saturating_add(tile).min(glen);
            tiles.push((r0, r1));
            r0 = r1;
        }
    }
    if is_home && tiles.is_empty() {
        top.offer(DIAG_KEY, Contribution::DiagDone, |c| {
            apply_contribution(c_local, &mut ctx.pool, c)
        });
    }
    let mut diag_left = tiles.len();
    for &(r0, r1) in &tiles {
        if ctx.opts.overlap {
            while let Ok(msg) = ctx.inbox.try_recv() {
                got += 1;
                on_msg_rep(ctx, rsched, &mut inner, &mut acc, &mut top, c_local, msg, true);
                finish_inner(ctx, asg, &inner, &mut acc, &mut top, c_local, &mut shipped);
            }
        }
        let t = ctx.now();
        let dt = gated(gate, || {
            let t0 = Instant::now();
            if r0 == 0 && r1 == glen {
                kernel.spmm_acc(&blocks.diag, b_local, c_local);
            } else {
                kernel.spmm_rows(&blocks.diag, b_local, c_local, r0, r1);
            }
            t0.elapsed().as_secs_f64()
        });
        ctx.stats.compute_secs += dt;
        ctx.span(phase::COMPUTE_LOCAL, t);
        diag_left -= 1;
        if diag_left == 0 {
            top.offer(DIAG_KEY, Contribution::DiagDone, |c| {
                apply_contribution(c_local, &mut ctx.pool, c)
            });
        }
    }

    // A member dealt nothing (or a home with no inbound flows) completes
    // its inner fold without receiving.
    finish_inner(ctx, asg, &inner, &mut acc, &mut top, c_local, &mut shipped);

    // Idle drain: block for whatever is still in flight.
    while got < expect {
        let t_idle = ctx.now();
        let msg = ctx.inbox.recv().expect("inbox closed — peer rank panicked");
        ctx.stats.idle_secs += ctx.now() - t_idle;
        ctx.span(phase::IDLE, t_idle);
        got += 1;
        on_msg_rep(ctx, rsched, &mut inner, &mut acc, &mut top, c_local, msg, false);
        finish_inner(ctx, asg, &inner, &mut acc, &mut top, c_local, &mut shipped);
    }
    debug_assert!(inner.is_done(), "rank {rank}: inner fold incomplete");
    debug_assert!(top.is_done(), "rank {rank}: reduce fold incomplete");
    debug_assert!(shipped, "rank {rank}: accumulator never reduced");
}

/// Handle one arrived message: account it, then fold it into the member
/// accumulator (B/C payloads of dealt flows) or the home's C block (member
/// reductions) in canonical order.
#[allow(clippy::too_many_arguments)]
fn on_msg_rep(
    ctx: &mut Ctx,
    rsched: &RepSchedule,
    inner: &mut OrderedFold<Contribution>,
    acc: &mut Option<Dense>,
    top: &mut OrderedFold<Contribution>,
    c_local: &mut Dense,
    msg: Msg,
    overlapped: bool,
) {
    ctx.recv_account(&msg, overlapped);
    let plan = ctx.plan;
    let kernel = ctx.kernel;
    let gate = ctx.gate;
    let g = rsched.map.group_of(ctx.rank);
    let glen = ctx.part.len(g);
    match msg {
        Msg::B { origin: h, rows, data, .. } => {
            // Column-shaped payload of dealt flow (g, h): multiply the
            // packed rows against the replicated compact operand.
            let pair = &plan.pairs[g][h];
            let contrib = if pair.a_col_compact.nnz() == 0 {
                ctx.pool.release(data);
                Contribution::Empty
            } else {
                debug_assert_eq!(rows.len(), pair.a_col_compact.ncols);
                let t = ctx.now();
                let mut partial = ctx.pool.acquire(glen, data.ncols);
                let dt = gated(gate, || {
                    let t0 = Instant::now();
                    kernel.spmm_acc(&pair.a_col_compact, &data, &mut partial);
                    t0.elapsed().as_secs_f64()
                });
                ctx.stats.compute_secs += dt;
                ctx.span(phase::COMPUTE_REMOTE, t);
                ctx.pool.release(data);
                let touched = pair.a_col_compact.nonempty_rows();
                if col_contribution_is_compact(touched.len(), glen) {
                    let mut compact = ctx.pool.acquire(touched.len(), partial.ncols);
                    partial.gather_rows_into(&touched, &mut compact);
                    ctx.pool.release(partial);
                    Contribution::AddRows(touched, compact)
                } else {
                    Contribution::AddFull(partial)
                }
            };
            let acc = acc.as_mut().expect("B arrival without an accumulator");
            inner.offer(ckey(KIND_B, h), contrib, |c| {
                apply_contribution(acc, &mut ctx.pool, c)
            });
        }
        Msg::C { from, rows, data } => {
            // Row-shaped payload: partial C rows computed at the source
            // group's home, keyed by that group.
            let h = rsched.map.group_of(from);
            let acc = acc.as_mut().expect("C arrival without an accumulator");
            inner.offer(ckey(KIND_C, h), Contribution::AddRows(rows, data), |c| {
                apply_contribution(acc, &mut ctx.pool, c)
            });
        }
        Msg::CRed { from, rows, data } => {
            top.offer(ckey(KIND_RED, from), Contribution::AddRows(rows, data), |c| {
                apply_contribution(c_local, &mut ctx.pool, c)
            });
        }
        Msg::X { .. } | Msg::CAgg { .. } => {
            unreachable!("replicated SpMM exchanges no X/CAgg messages")
        }
    }
}

/// Once the inner fold completes, reduce-scatter the accumulator's touched
/// rows: members ship them home ([`Msg::CRed`]); the home offers its own
/// accumulator into the top fold locally.
fn finish_inner(
    ctx: &mut Ctx,
    asg: &RepAssign,
    inner: &OrderedFold<Contribution>,
    acc: &mut Option<Dense>,
    top: &mut OrderedFold<Contribution>,
    c_local: &mut Dense,
    shipped: &mut bool,
) {
    if *shipped || !inner.is_done() {
        return;
    }
    *shipped = true;
    let Some(a) = acc.take() else { return };
    if asg.touched.is_empty() {
        ctx.pool.release(a);
        return;
    }
    let t = ctx.now();
    let mut compact = ctx.pool.acquire(asg.touched.len(), a.ncols);
    a.gather_rows_into(&asg.touched, &mut compact);
    ctx.pool.release(a);
    match asg.red_to {
        Some(home) => {
            ctx.send(home, Msg::CRed { from: ctx.rank, rows: asg.touched.clone(), data: compact });
        }
        None => {
            let rank = ctx.rank;
            top.offer(
                ckey(KIND_RED, rank),
                Contribution::AddRows(asg.touched.clone(), compact),
                |c| apply_contribution(c_local, &mut ctx.pool, c),
            );
        }
    }
    ctx.span(phase::RED_INTRA, t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::exec::kernel::NativeKernel;
    use crate::hierarchy::build_replicated;
    use crate::partition::split_1d;
    use crate::sparse::gen;
    use crate::topology::ReplicaMap;
    use crate::util::rng::Rng;

    /// Integer-exact inputs: small-integer values keep every intermediate
    /// sum exactly representable in f32, so any fold order yields the same
    /// bits and replicated results must equal the serial reference
    /// *bitwise*.
    fn int_inputs(n: usize, nnz: usize, seed: u64) -> (crate::sparse::Csr, Dense) {
        let mut a = gen::rmat(n, nnz, (0.55, 0.2, 0.19), false, seed);
        for (i, v) in a.data.iter_mut().enumerate() {
            *v = 1.0 + (i % 3) as f32;
        }
        let mut rng = Rng::new(seed ^ 0x5eed);
        let data: Vec<f32> = (0..n * 16).map(|_| (rng.next_u64() % 5) as f32).collect();
        (a, Dense::from_vec(n, 16, data))
    }

    fn run_factor(
        a: &crate::sparse::Csr,
        b: &Dense,
        nranks: usize,
        c: usize,
        strategy: Strategy,
        opts: &ExecOpts,
    ) -> (Dense, ExecStats, u64, u64) {
        let part = crate::partition::RowPartition::balanced(a.nrows, nranks);
        let gpart = part.coarsen(c);
        let gblocks = split_1d(a, &gpart);
        let gplan = comm::plan(&gblocks, &gpart, strategy, None);
        let map = ReplicaMap::new(nranks, c);
        let rsched = build_replicated(&gplan, &map);
        rsched.validate(&gplan).expect("schedule must validate");
        // A topology whose physical groups *are* the replication groups
        // makes the executor's tier accounting line up exactly with the
        // schedule's modeled wire bytes.
        let mut topo = Topology::tsubame4(nranks);
        topo.group_size = c;
        let (got, stats) =
            run_replicated(&gpart, &gplan, &gblocks, &rsched, &topo, b, &NativeKernel, opts);
        let n_dense = b.ncols;
        (got, stats, rsched.inter_wire_bytes(&gplan, n_dense), rsched.intra_wire_bytes(n_dense))
    }

    #[test]
    fn replicated_bitwise_matches_serial_across_factors() {
        let (a, b) = int_inputs(128, 1300, 7);
        let want = a.spmm(&b);
        for strategy in [
            Strategy::Block,
            Strategy::Column,
            Strategy::Row,
            Strategy::Joint(Solver::Koenig),
        ] {
            for c in [1, 2, 4, 8] {
                let (got, _, _, _) =
                    run_factor(&a, &b, 8, c, strategy, &ExecOpts::default());
                assert_eq!(got.data, want.data, "{strategy:?} c={c} not bitwise-exact");
            }
        }
    }

    #[test]
    fn replicated_modes_and_worker_caps_agree() {
        let (a, b) = int_inputs(96, 900, 11);
        let want = a.spmm(&b);
        for opts in [
            ExecOpts::default(),
            ExecOpts::sequential(),
            ExecOpts { workers: 2, ..ExecOpts::default() },
            ExecOpts { tile_rows: 8, ..ExecOpts::default() },
        ] {
            let (got, _, _, _) =
                run_factor(&a, &b, 8, 4, Strategy::Joint(Solver::Koenig), &opts);
            assert_eq!(got.data, want.data, "{opts:?} diverged");
        }
    }

    #[test]
    fn measured_traffic_matches_schedule_model_exactly() {
        let (a, b) = int_inputs(160, 2200, 3);
        for c in [1, 2, 4] {
            let (_, stats, inter_model, intra_model) =
                run_factor(&a, &b, 8, c, Strategy::Joint(Solver::Koenig), &ExecOpts::default());
            assert_eq!(
                stats.total_inter_bytes(),
                inter_model,
                "c={c}: measured inter-group bytes drifted from the model"
            );
            assert_eq!(
                stats.total_intra_bytes(),
                intra_model,
                "c={c}: measured reduce-scatter bytes drifted from the model"
            );
            assert_eq!(stats.total_inter_bytes(), stats.total_inter_recv_bytes());
            assert_eq!(stats.total_intra_bytes(), stats.total_intra_recv_bytes());
            if c == 1 {
                assert_eq!(intra_model, 0, "c=1 has no reduce-scatter leg");
            }
        }
    }
}
