//! Epoch-persistent execution sessions (DESIGN.md §8): the GNN training
//! loop multiplies the same Â every layer of every epoch, so everything
//! that is a pure function of the *plan* — per-rank step programs, fold
//! orders, posted-send payload layouts, exchange buffers — is derived once
//! and replayed across `execute` calls instead of being rebuilt per call.
//!
//! The session owns one shared [`BufferPool`] for all ranks (payloads are
//! released at the *receiver*, so per-rank pools would drain toward the
//! receive-heavy ranks and re-allocate at the send-heavy ones every epoch)
//! and pre-seeds it with the **payload layout**: one slot per buffer role
//! the programs can ever hold live at once — every outgoing message, every
//! remote partial, every pre-aggregation accumulator. Because reuse is
//! best-fit and the layout is a strict upper bound on concurrent liveness,
//! *no* execute call after warm-up can miss the pool, whatever the thread
//! interleaving. That is the amortization contract asserted through
//! [`crate::metrics::Amortization`]: plan time and fresh-allocation counts
//! are exactly zero from the second epoch onward, and results stay
//! bit-identical to cold per-epoch execution (same programs, same
//! canonical fold order).

use super::kernel::SpmmKernel;
use super::pipeline::{ckey_decode, BufferPool, ExecOpts, PoolRef, KIND_B};
use super::{build_program, rank_main, Ctx, ExecStats, Item, Msg, Program, RankStats};
use crate::dense::Dense;
use crate::metrics::Amortization;
use crate::spmm::DistSpmm;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::Instant;

/// A frozen plan + partition with persistent executor state, reusable
/// across arbitrarily many `execute` calls. Build one with
/// [`SpmmSession::new`] (or [`DistSpmm::into_session`]), optionally
/// [`SpmmSession::warm`] it for a dense width, then call
/// [`SpmmSession::execute`] once per product.
pub struct SpmmSession {
    dist: DistSpmm,
    opts: ExecOpts,
    prefers_tiles: bool,
    /// Per-rank step programs, derived once from (plan, sched, opts).
    programs: Vec<Program>,
    /// Shared exchange-buffer pool (see module docs for why it is shared).
    pool: Mutex<BufferPool>,
    /// Persistent per-rank input blocks, refilled (not reallocated) per call.
    b_locals: Vec<Dense>,
    /// Persistent per-rank output blocks, zeroed (not reallocated) per call.
    c_locals: Vec<Dense>,
    /// Largest dense width the payload layout has been seeded for.
    seeded_n: usize,
    amort: Amortization,
}

impl SpmmSession {
    /// Freeze `dist` into a session. `prefers_tiles` must match the kernel
    /// the session will execute with ([`SpmmKernel::prefers_tiles`]) — a
    /// mismatched kernel at execute time retargets the programs and the
    /// retargeting cost shows up in that call's amortization record.
    pub fn new(dist: DistSpmm, opts: ExecOpts, prefers_tiles: bool) -> SpmmSession {
        let t0 = Instant::now();
        let programs = build_all(&dist, &opts, prefers_tiles);
        let nranks = dist.part.nparts;
        let mut s = SpmmSession {
            programs,
            pool: Mutex::new(BufferPool::with_cap(usize::MAX)),
            b_locals: (0..nranks).map(|_| Dense::zeros(0, 0)).collect(),
            c_locals: (0..nranks).map(|_| Dense::zeros(0, 0)).collect(),
            seeded_n: 0,
            amort: Amortization::default(),
            dist,
            opts,
            prefers_tiles,
        };
        s.amort.build_secs = t0.elapsed().as_secs_f64();
        s
    }

    /// The frozen plan this session executes.
    pub fn dist(&self) -> &DistSpmm {
        &self.dist
    }

    pub fn opts(&self) -> ExecOpts {
        self.opts
    }

    /// Change scheduling options. Only the diagonal tile height affects the
    /// derived programs; overlap/worker changes are free.
    pub fn set_opts(&mut self, opts: ExecOpts) {
        let rebuild = opts.tile() != self.opts.tile();
        self.opts = opts;
        if rebuild {
            let t0 = Instant::now();
            self.programs = build_all(&self.dist, &self.opts, self.prefers_tiles);
            self.amort.build_secs += t0.elapsed().as_secs_f64();
        }
    }

    /// Amortization record: build cost plus per-call plan seconds and
    /// fresh-allocation events. [`Amortization::steady_state`] is the
    /// epoch-reuse guarantee.
    pub fn amortization(&self) -> &Amortization {
        &self.amort
    }

    /// Rebuild the programs for a kernel with a different tiling
    /// preference, counted as build time. Calling this before the first
    /// `execute` (as [`crate::gnn::Gcn::train`] does) keeps execute-time
    /// plan seconds at zero even when the kernel changes; an unretargeted
    /// mismatch is healed inside `execute` instead, at that call's cost.
    pub fn retarget(&mut self, prefers_tiles: bool) {
        if prefers_tiles == self.prefers_tiles {
            return;
        }
        let t0 = Instant::now();
        self.prefers_tiles = prefers_tiles;
        self.programs = build_all(&self.dist, &self.opts, prefers_tiles);
        self.amort.build_secs += t0.elapsed().as_secs_f64();
    }

    /// Eagerly seed the payload layout and persistent blocks for dense
    /// width `n_dense` (counted as build time, not per-call plan time).
    /// Calls with `b.ncols <= n_dense` then do zero planning work and zero
    /// allocations from the very first epoch.
    pub fn warm(&mut self, n_dense: usize) {
        let t0 = Instant::now();
        if self.seed_layout(n_dense) {
            self.amort.build_secs += t0.elapsed().as_secs_f64();
        }
    }

    /// Execute C = A·B, allocating the assembled global output. The
    /// exchange path is fully persistent; only the returned matrix is
    /// fresh. Use [`SpmmSession::execute_into`] to reuse an output buffer.
    pub fn execute(
        &mut self,
        b: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        let mut out = Dense::zeros(0, 0);
        let stats = self.execute_into(b, kernel, &mut out);
        (out, stats)
    }

    /// Execute C = A·B into `out` (reshaped as needed; a caller-held
    /// buffer of the right capacity makes the whole call allocation-free).
    /// Bit-identical to [`DistSpmm::execute_with`] on the same plan and
    /// options — the session changes *when* state is built, never what the
    /// ranks compute.
    pub fn execute_into(
        &mut self,
        b: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        out: &mut Dense,
    ) -> ExecStats {
        let nranks = self.dist.part.nparts;
        let n_dense = b.ncols;
        assert_eq!(self.dist.part.n, b.nrows, "B height != planned matrix");

        // Per-call baseline for the allocation record: lazy work below is
        // attributed to *this* call (the steady-state assertion is on
        // later calls, which must find everything already in place).
        let allocs_before = self.pool.lock().unwrap().allocs;
        let t_plan = Instant::now();
        let mut planned = false;
        if kernel.prefers_tiles() != self.prefers_tiles {
            self.prefers_tiles = kernel.prefers_tiles();
            self.programs = build_all(&self.dist, &self.opts, self.prefers_tiles);
            planned = true;
        }
        planned |= self.seed_layout(n_dense);
        // Exact zero when nothing was (re)planned — the steady-state gate.
        let plan_secs = if planned { t_plan.elapsed().as_secs_f64() } else { 0.0 };

        // Refill the persistent per-rank blocks (copies, no allocation:
        // capacities were sized by seed_layout).
        for p in 0..nranks {
            let (r0, r1) = self.dist.part.range(p);
            let bl = &mut self.b_locals[p];
            bl.nrows = r1 - r0;
            bl.ncols = n_dense;
            bl.data.clear();
            bl.data
                .extend_from_slice(&b.data[r0 * n_dense..r1 * n_dense]);
            let cl = &mut self.c_locals[p];
            cl.nrows = r1 - r0;
            cl.ncols = n_dense;
            cl.data.clear();
            cl.data.resize((r1 - r0) * n_dense, 0.0);
        }

        let dist = &self.dist;
        let programs = &self.programs;
        let pool = &self.pool;
        let opts = self.opts;
        let c_locals = &mut self.c_locals;
        let b_locals = &self.b_locals;

        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nranks);
        let mut inboxes: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nranks);
        for _ in 0..nranks {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(Some(rx));
        }
        let gate = (opts.workers > 0).then(|| super::ComputeGate::new(opts.workers));

        let t0 = Instant::now();
        let mut per_rank: Vec<Option<RankStats>> = (0..nranks).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            let rank_iter = inboxes
                .iter_mut()
                .zip(b_locals.iter())
                .zip(c_locals.iter_mut())
                .enumerate();
            for (rank, ((inbox, b_local), c_local)) in rank_iter {
                let senders = &senders;
                let gate = gate.as_ref();
                let inbox = inbox.take().unwrap();
                handles.push(scope.spawn(move || {
                    let mut ctx = Ctx {
                        rank,
                        part: &dist.part,
                        plan: &dist.plan,
                        sched: dist.sched.as_ref(),
                        topo: &dist.topo,
                        kernel,
                        senders,
                        inbox,
                        stats: RankStats {
                            sent_to: vec![0; nranks],
                            ..RankStats::default()
                        },
                        opts,
                        gate,
                        t0,
                        pool: PoolRef::Shared(pool),
                    };
                    rank_main(&mut ctx, &dist.blocks[rank], b_local, c_local, &programs[rank]);
                    (rank, ctx.stats)
                }));
            }
            for h in handles {
                let (rank, stats) = h.join().expect("rank thread panicked");
                per_rank[rank] = Some(stats);
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        // Assemble: the contiguous ascending row ranges cover 0..n, so the
        // global C is the concatenation of the per-rank blocks.
        out.nrows = self.dist.part.n;
        out.ncols = n_dense;
        out.data.clear();
        for cl in self.c_locals.iter() {
            out.data.extend_from_slice(&cl.data);
        }

        let allocs = self.pool.lock().unwrap().allocs - allocs_before;
        self.amort.record(plan_secs, allocs);
        ExecStats {
            per_rank: per_rank.into_iter().map(Option::unwrap).collect(),
            wall_secs: wall,
        }
    }

    /// Seed the pool with the payload layout at width `n` and size the
    /// persistent blocks; no-op when already seeded at least this wide.
    fn seed_layout(&mut self, n: usize) -> bool {
        if n <= self.seeded_n {
            return false;
        }
        let layout = payload_layout(&self.dist, &self.programs);
        {
            let mut pool = self.pool.lock().unwrap();
            for rows in layout {
                pool.seed(rows * n);
            }
        }
        for p in 0..self.dist.part.nparts {
            let len = self.dist.part.len(p);
            self.b_locals[p] = Dense::zeros(len, n);
            self.c_locals[p] = Dense::zeros(len, n);
        }
        self.seeded_n = n;
        true
    }
}

fn build_all(dist: &DistSpmm, opts: &ExecOpts, prefers_tiles: bool) -> Vec<Program> {
    (0..dist.part.nparts)
        .map(|rank| {
            build_program(
                rank,
                &dist.part,
                &dist.plan,
                dist.sched.as_ref(),
                opts,
                prefers_tiles,
            )
        })
        .collect()
}

/// Enumerate the posted-payload layout: the dense-row height of every
/// buffer role the programs can hold live simultaneously — outgoing B
/// posts, produced C partials, representative redistribution subsets,
/// pre-aggregation accumulators, and the remote-partial scratch acquired
/// while folding each incoming column-based contribution. One pool slot
/// per role is a strict upper bound on concurrent liveness: each role
/// acquires at most once per call and everything is back in the pool by
/// the end of the call.
fn payload_layout(dist: &DistSpmm, programs: &[Program]) -> Vec<usize> {
    let part = &dist.part;
    let plan = &dist.plan;
    let sched = dist.sched.as_ref();
    let mut rows = Vec::new();
    for (r, prog) in programs.iter().enumerate() {
        for post in &prog.b_posts {
            rows.push(post.rows.len());
        }
        for item in &prog.items {
            match item {
                Item::ProduceDirectC { dst } => {
                    rows.push(plan.pairs[*dst][r].a_row_compact.nrows);
                }
                Item::ProduceFlowC { flow } => {
                    let f = &sched.expect("flow item implies a schedule").c_flows[*flow];
                    rows.push(plan.pairs[f.dst][r].a_row_compact.nrows);
                }
                Item::DiagTile { .. } => {}
            }
        }
        for &fi in prog.rep_b.values() {
            let f = &sched.expect("rep duty implies a schedule").b_flows[fi];
            for (_, crows) in &f.consumers {
                rows.push(crows.len());
            }
        }
        for &i in &prog.agg_flows {
            rows.push(sched.expect("agg flow implies a schedule").c_flows[i].rows.len());
        }
        for &key in &prog.fold_keys {
            if let Some((KIND_B, origin)) = ckey_decode(key) {
                let pair = &plan.pairs[r][origin];
                if pair.a_col_compact.nnz() > 0 {
                    // The full-height partial, plus the compact row set the
                    // sparse apply path gathers into — the branch predicate
                    // is shared with `offer_col_contribution` so the two
                    // cannot drift apart.
                    rows.push(part.len(r));
                    let touched = pair.a_col_compact.nonempty_rows().len();
                    if super::col_contribution_is_compact(touched, part.len(r)) {
                        rows.push(touched);
                    }
                }
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;
    use crate::cover::Solver;
    use crate::exec::kernel::NativeKernel;
    use crate::sparse::gen;
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    fn planned(seed: u64, hier: bool) -> DistSpmm {
        let a = gen::rmat(192, 2500, (0.55, 0.2, 0.19), false, seed);
        DistSpmm::plan(&a, Strategy::Joint(Solver::Koenig), Topology::tsubame4(8), hier)
    }

    #[test]
    fn session_matches_cold_execution_bitwise() {
        for hier in [false, true] {
            let d_cold = planned(21, hier);
            let d_sess = planned(21, hier);
            let mut rng = Rng::new(5);
            let b = Dense::random(192, 16, &mut rng);
            let (want, _) = d_cold.execute(&b, &NativeKernel);
            let mut s = SpmmSession::new(d_sess, ExecOpts::default(), true);
            for _ in 0..3 {
                let (got, _) = s.execute(&b, &NativeKernel);
                assert_eq!(got.data, want.data, "hier={hier}");
            }
        }
    }

    #[test]
    fn session_steady_state_after_first_call() {
        let mut s = SpmmSession::new(planned(22, true), ExecOpts::default(), true);
        let mut rng = Rng::new(6);
        let b = Dense::random(192, 8, &mut rng);
        let mut out = Dense::zeros(0, 0);
        for _ in 0..4 {
            s.execute_into(&b, &NativeKernel, &mut out);
        }
        let a = s.amortization();
        assert_eq!(a.calls(), 4);
        assert!(a.alloc_events[0] > 0, "first call seeds the layout");
        assert!(a.plan_secs[0] > 0.0);
        for i in 1..4 {
            assert_eq!(a.alloc_events[i], 0, "call {i} allocated");
            assert_eq!(a.plan_secs[i], 0.0, "call {i} planned");
        }
        assert!(a.steady_state());
    }

    #[test]
    fn warm_session_is_clean_from_the_first_call() {
        let mut s = SpmmSession::new(planned(23, true), ExecOpts::default(), true);
        s.warm(16);
        assert!(s.amortization().build_secs > 0.0);
        let mut rng = Rng::new(7);
        // Narrower widths than the warmed one stay allocation-free too.
        for n in [16usize, 4] {
            let b = Dense::random(192, n, &mut rng);
            let (_, _) = s.execute(&b, &NativeKernel);
        }
        let a = s.amortization();
        assert_eq!(a.total_allocs(), 0, "warmed session must never allocate");
        assert!(a.plan_secs.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn session_handles_width_growth_then_stabilizes() {
        let mut s = SpmmSession::new(planned(24, false), ExecOpts::default(), true);
        let mut rng = Rng::new(8);
        let small = Dense::random(192, 4, &mut rng);
        let big = Dense::random(192, 12, &mut rng);
        s.execute(&small, &NativeKernel);
        s.execute(&big, &NativeKernel); // grows: re-seeds at the new width
        let a = s.amortization();
        assert!(a.alloc_events[1] > 0, "growth call must re-seed");
        assert!(a.plan_secs[1] > 0.0, "growth is planning work");
        for _ in 0..3 {
            s.execute(&big, &NativeKernel);
            s.execute(&small, &NativeKernel);
        }
        // Every call after the growth one is clean, whatever the width mix.
        let a = s.amortization();
        assert_eq!(a.calls(), 8);
        assert!(a.alloc_events[2..].iter().all(|&x| x == 0), "{:?}", a.alloc_events);
        assert!(a.plan_secs[2..].iter().all(|&t| t == 0.0));
    }

    #[test]
    fn session_opts_variants_bit_identical() {
        let mut rng = Rng::new(9);
        let b = Dense::random(192, 8, &mut rng);
        let (want, _) = planned(25, true).execute(&b, &NativeKernel);
        for opts in [
            ExecOpts::sequential(),
            ExecOpts { workers: 2, ..ExecOpts::default() },
            ExecOpts { tile_rows: 7, ..ExecOpts::default() },
        ] {
            let mut s = SpmmSession::new(planned(25, true), ExecOpts::default(), true);
            s.set_opts(opts);
            let (got, _) = s.execute(&b, &NativeKernel);
            assert_eq!(got.data, want.data, "{opts:?}");
        }
    }
}
