//! Epoch-persistent execution sessions (DESIGN.md §8, kernel-generic per
//! §9): iterative workloads multiply (or SDDMM) against the same sparsity
//! pattern every layer of every epoch, so everything that is a pure
//! function of the *plan* — per-rank step programs, fold orders,
//! posted-send payload layouts, exchange buffers — is derived once and
//! replayed across `execute` calls instead of being rebuilt per call.
//!
//! One session now serves **all three kernels** off one frozen plan
//! through the same entry point the one-shot engine uses:
//! [`SpmmSession::execute`] takes an [`ExecRequest`] (SpMM / SDDMM /
//! fused). Each kernel op owns its program set and
//! its [`Amortization`] record, lazily built on first use (or eagerly via
//! [`SpmmSession::warm_kernel`]); the exchange-buffer pool, the X fetch
//! schedule, and the persistent dense blocks are shared. The plan-sharing
//! contract (asserted in `property_suite`): a session executing SpMM then
//! SDDMM reports *identical* B-side measured volume — the same dense rows
//! move on the same links — and each kernel reaches its zero-plan,
//! zero-allocation steady state from its second call.
//!
//! The session owns one shared [`BufferPool`] for all ranks (payloads are
//! released at the *receiver*, so per-rank pools would drain toward the
//! receive-heavy ranks and re-allocate at the send-heavy ones every epoch)
//! and pre-seeds it with the **payload layout**: one slot per buffer role
//! the programs can ever hold live at once — every outgoing message, every
//! remote partial, every pre-aggregation accumulator, every SDDMM value
//! buffer. Because reuse is best-fit and the layout is a strict upper
//! bound on concurrent liveness, *no* execute call after warm-up can miss
//! the pool, whatever the thread interleaving. That is the amortization
//! contract asserted through [`crate::metrics::Amortization`]: plan time
//! and fresh-allocation counts are exactly zero from the second call
//! onward (per kernel op), and results stay bit-identical to cold
//! execution (same programs, same canonical fold order).

use super::kernel::{KernelOp, SpmmKernel};
use super::pipeline::{ckey_decode, BufferPool, ExecOpts, PoolRef, KIND_B};
use super::{
    assemble_sddmm, build_program, col_contribution_is_compact, rank_main, Ctx, ExecStats, Item,
    Msg, Outbox, Program, RankStats, SddmmVals,
};
use crate::dense::Dense;
use crate::hierarchy::{self, HierSchedule};
use crate::metrics::Amortization;
use crate::sparse::Csr;
use crate::spmm::{Backend, DistSpmm, ExecError, ExecRequest, ExecResult};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::Instant;

/// Program set + seeding state for one of the SDDMM-family kernel ops.
struct KernelPrograms {
    programs: Vec<Program>,
    /// Largest dense width this op's payload layout has been seeded for.
    seeded_n: usize,
}

/// A frozen plan + partition with persistent executor state, reusable
/// across arbitrarily many `execute` calls and across kernel ops. Build
/// one with [`SpmmSession::new`] (or [`DistSpmm::into_session`]),
/// optionally [`SpmmSession::warm`] / [`SpmmSession::warm_kernel`] it for
/// a dense width, then call the per-op execute methods once per product.
pub struct SpmmSession {
    dist: DistSpmm,
    opts: ExecOpts,
    prefers_tiles: bool,
    /// Per-rank SpMM step programs, derived once from (plan, sched, opts).
    programs: Vec<Program>,
    /// Lazily built SDDMM / fused program sets (kernel parameter).
    sddmm: Option<KernelPrograms>,
    fused: Option<KernelPrograms>,
    /// X fetch schedule shared by the SDDMM-family programs
    /// ([`hierarchy::sddmm_fetch`] of the frozen schedule); built with the
    /// first non-SpMM program set, `None` for flat plans.
    xsched: Option<HierSchedule>,
    xsched_built: bool,
    /// Shared exchange-buffer pool (see module docs for why it is shared).
    pool: Mutex<BufferPool>,
    /// Persistent per-rank input blocks, refilled (not reallocated) per call.
    b_locals: Vec<Dense>,
    /// Persistent per-rank X blocks (SDDMM-family calls only).
    x_locals: Vec<Dense>,
    /// Persistent per-rank output blocks, zeroed (not reallocated) per call.
    c_locals: Vec<Dense>,
    /// Largest dense width the SpMM payload layout has been seeded for.
    seeded_n: usize,
    /// Element sizes of every slot ever seeded into the pool, descending —
    /// the dominance ledger [`SpmmSession::seed_missing`] matches new
    /// layouts against so roles shared across kernel ops (and across
    /// width growth) are seeded once, not once per op.
    seeded_slots: Vec<usize>,
    amort: Amortization,
    amort_sddmm: Amortization,
    amort_fused: Amortization,
}

impl SpmmSession {
    /// Freeze `dist` into a session. `prefers_tiles` must match the kernel
    /// the session will execute with ([`SpmmKernel::prefers_tiles`]) — a
    /// mismatched kernel at execute time retargets the programs and the
    /// retargeting cost shows up in that call's amortization record.
    pub fn new(dist: DistSpmm, opts: ExecOpts, prefers_tiles: bool) -> SpmmSession {
        assert!(
            dist.rep.is_none(),
            "replicated (c>1) plans are not session-capable; \
             execute them directly via DistSpmm::execute or replan at c=1"
        );
        let t0 = Instant::now();
        let programs = build_all(&dist, &opts, prefers_tiles);
        let nranks = dist.part.nparts;
        let mut s = SpmmSession {
            programs,
            sddmm: None,
            fused: None,
            xsched: None,
            xsched_built: false,
            // Default cap: seed_layout grows it to cover every seeded slot,
            // so the session's zero-miss layout is never evicted while
            // buffers outside the layout (stale widths) stay bounded.
            pool: Mutex::new(BufferPool::new()),
            b_locals: (0..nranks).map(|_| Dense::zeros(0, 0)).collect(),
            x_locals: (0..nranks).map(|_| Dense::zeros(0, 0)).collect(),
            c_locals: (0..nranks).map(|_| Dense::zeros(0, 0)).collect(),
            seeded_n: 0,
            seeded_slots: Vec::new(),
            amort: Amortization::default(),
            amort_sddmm: Amortization::default(),
            amort_fused: Amortization::default(),
            dist,
            opts,
            prefers_tiles,
        };
        s.amort.build_secs = t0.elapsed().as_secs_f64();
        s
    }

    /// The frozen plan this session executes.
    pub fn dist(&self) -> &DistSpmm {
        &self.dist
    }

    pub fn opts(&self) -> ExecOpts {
        self.opts
    }

    /// Change scheduling options. Only the diagonal tile height affects the
    /// derived programs; overlap/worker changes are free.
    pub fn set_opts(&mut self, opts: ExecOpts) {
        let rebuild = opts.tile() != self.opts.tile();
        self.opts = opts;
        if rebuild {
            let t0 = Instant::now();
            self.rebuild_programs();
            self.amort.build_secs += t0.elapsed().as_secs_f64();
        }
    }

    /// Amortization record of the SpMM kernel: build cost plus per-call
    /// plan seconds and fresh-allocation events.
    /// [`Amortization::steady_state`] is the epoch-reuse guarantee.
    pub fn amortization(&self) -> &Amortization {
        &self.amort
    }

    /// Amortization record of one kernel op (the SDDMM-family ops record
    /// separately so each op's own steady state is observable even when
    /// calls interleave across ops).
    pub fn amortization_for(&self, op: KernelOp) -> &Amortization {
        match op {
            KernelOp::Spmm => &self.amort,
            KernelOp::Sddmm => &self.amort_sddmm,
            KernelOp::FusedSddmmSpmm => &self.amort_fused,
        }
    }

    /// Rebuild the programs for a kernel with a different tiling
    /// preference, counted as build time. Calling this before the first
    /// `execute` (as [`crate::gnn::Gcn::train`] does) keeps execute-time
    /// plan seconds at zero even when the kernel changes; an unretargeted
    /// mismatch is healed inside `execute` instead, at that call's cost.
    pub fn retarget(&mut self, prefers_tiles: bool) {
        if prefers_tiles == self.prefers_tiles {
            return;
        }
        let t0 = Instant::now();
        self.prefers_tiles = prefers_tiles;
        self.rebuild_programs();
        self.amort.build_secs += t0.elapsed().as_secs_f64();
    }

    /// The lazily-built program-set slot for one SDDMM-family op.
    fn kernel_slot(&mut self, op: KernelOp) -> &mut Option<KernelPrograms> {
        match op {
            KernelOp::Sddmm => &mut self.sddmm,
            KernelOp::FusedSddmmSpmm => &mut self.fused,
            KernelOp::Spmm => unreachable!("SpMM programs are built eagerly"),
        }
    }

    /// Rebuild every program set that exists for the current
    /// (opts, prefers_tiles) — the SpMM set always, the SDDMM-family sets
    /// only if already built.
    fn rebuild_programs(&mut self) {
        self.programs = build_all(&self.dist, &self.opts, self.prefers_tiles);
        for op in [KernelOp::Sddmm, KernelOp::FusedSddmmSpmm] {
            if self.kernel_slot(op).is_some() {
                let programs = build_all_op(
                    &self.dist,
                    self.xsched.as_ref(),
                    &self.opts,
                    self.prefers_tiles,
                    op,
                );
                self.kernel_slot(op).as_mut().unwrap().programs = programs;
            }
        }
    }

    /// Eagerly seed the SpMM payload layout and persistent blocks for
    /// dense width `n_dense` (counted as build time, not per-call plan
    /// time). Calls with `b.ncols <= n_dense` then do zero planning work
    /// and zero allocations from the very first epoch.
    pub fn warm(&mut self, n_dense: usize) {
        let t0 = Instant::now();
        if self.seed_layout(n_dense) {
            self.amort.build_secs += t0.elapsed().as_secs_f64();
        }
    }

    /// [`SpmmSession::warm`] for a specific kernel op: build its program
    /// set (and the shared X fetch schedule) and seed its payload layout
    /// at width `n_dense`, all counted as that op's build time.
    pub fn warm_kernel(&mut self, op: KernelOp, n_dense: usize) {
        if op == KernelOp::Spmm {
            self.warm(n_dense);
            return;
        }
        let t0 = Instant::now();
        let mut did = self.ensure_kernel_state(op);
        did |= self.seed_kernel_layout(op, n_dense);
        if did {
            let dt = t0.elapsed().as_secs_f64();
            match op {
                KernelOp::Sddmm => self.amort_sddmm.build_secs += dt,
                KernelOp::FusedSddmmSpmm => self.amort_fused.build_secs += dt,
                KernelOp::Spmm => unreachable!(),
            }
        }
    }

    /// Execute one [`ExecRequest`] against the frozen plan — the same
    /// entry point as [`DistSpmm::execute`], with the same result
    /// semantics (`dense` for SpMM/fused, `sparse` for SDDMM).
    ///
    /// Two session-specific rules: on the thread backend the session's
    /// *own* options win over `req.opts` (frozen programs depend on them
    /// — change via [`SpmmSession::set_opts`]), and [`Backend::Proc`]
    /// requests delegate to [`DistSpmm::execute`] over the frozen plan —
    /// per-rank state lives in the worker processes (warm across requests
    /// when [`crate::runtime::multiproc::ProcOpts::pool`] is set), so the
    /// request's own options and fault policy apply.
    pub fn execute(&mut self, req: &ExecRequest) -> Result<ExecResult, ExecError> {
        if matches!(req.backend, Backend::Proc(_)) {
            return self.dist.execute(req);
        }
        match req.op {
            KernelOp::Spmm => {
                let mut out = Dense::zeros(0, 0);
                let stats = self.run_spmm_into(req.b, req.kernel, &mut out);
                Ok(ExecResult::from_dense(out, stats))
            }
            KernelOp::Sddmm => {
                let x = req.x_operand()?;
                let (e, stats) = self.run_sddmm(x, req.b, req.kernel);
                Ok(ExecResult::from_sparse(e, stats))
            }
            KernelOp::FusedSddmmSpmm => {
                let x = req.x_operand()?;
                let mut out = Dense::zeros(0, 0);
                let stats = self.run_fused_into(x, req.b, req.kernel, &mut out);
                Ok(ExecResult::from_dense(out, stats))
            }
        }
    }

    /// [`SpmmSession::execute`] into a caller-held output buffer
    /// (reshaped as needed; a buffer of the right capacity makes the whole
    /// call allocation-free). Dense-output requests only — SDDMM produces
    /// a sparse matrix and returns [`ExecError::Unsupported`] here.
    pub fn execute_into(
        &mut self,
        req: &ExecRequest,
        out: &mut Dense,
    ) -> Result<ExecStats, ExecError> {
        if matches!(req.backend, Backend::Proc(_)) {
            if req.op == KernelOp::Sddmm {
                return Err(ExecError::Unsupported(
                    "SDDMM produces a sparse matrix; use SpmmSession::execute".into(),
                ));
            }
            // Delegate to the one-shot proc path over the frozen plan; the
            // parent assembles a fresh C, which replaces the caller's
            // buffer wholesale.
            let res = self.dist.execute(req)?;
            *out = res.dense.expect("dense-output op");
            return Ok(res.stats);
        }
        match req.op {
            KernelOp::Spmm => Ok(self.run_spmm_into(req.b, req.kernel, out)),
            KernelOp::Sddmm => Err(ExecError::Unsupported(
                "SDDMM produces a sparse matrix; use SpmmSession::execute".into(),
            )),
            KernelOp::FusedSddmmSpmm => {
                let x = req.x_operand()?;
                Ok(self.run_fused_into(x, req.b, req.kernel, out))
            }
        }
    }

    /// Execute C = A·B into `out`. Bit-identical to the one-shot path on
    /// the same plan and options — the session changes *when* state is
    /// built, never what the ranks compute.
    fn run_spmm_into(
        &mut self,
        b: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        out: &mut Dense,
    ) -> ExecStats {
        let nranks = self.dist.part.nparts;
        let n_dense = b.ncols;
        assert_eq!(self.dist.part.n, b.nrows, "B height != planned matrix");

        // Per-call baseline for the allocation record: lazy work below is
        // attributed to *this* call (the steady-state assertion is on
        // later calls, which must find everything already in place).
        let allocs_before = self.pool.lock().unwrap().allocs;
        let t_plan = Instant::now();
        let mut planned = false;
        if kernel.prefers_tiles() != self.prefers_tiles {
            self.prefers_tiles = kernel.prefers_tiles();
            self.rebuild_programs();
            planned = true;
        }
        planned |= self.seed_layout(n_dense);
        // Exact zero when nothing was (re)planned — the steady-state gate.
        let plan_secs = if planned { t_plan.elapsed().as_secs_f64() } else { 0.0 };

        // Refill the persistent per-rank blocks (copies, no allocation:
        // capacities were sized by seed_layout).
        for p in 0..nranks {
            let (r0, r1) = self.dist.part.range(p);
            let bl = &mut self.b_locals[p];
            bl.nrows = r1 - r0;
            bl.ncols = n_dense;
            bl.data.clear();
            bl.data
                .extend_from_slice(&b.data[r0 * n_dense..r1 * n_dense]);
            let cl = &mut self.c_locals[p];
            cl.nrows = r1 - r0;
            cl.ncols = n_dense;
            cl.data.clear();
            cl.data.resize((r1 - r0) * n_dense, 0.0);
        }

        let dist = &self.dist;
        let programs = &self.programs;
        let pool = &self.pool;
        let opts = self.opts;
        let c_locals = &mut self.c_locals;
        let b_locals = &self.b_locals;

        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nranks);
        let mut inboxes: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nranks);
        for _ in 0..nranks {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(Some(rx));
        }
        let gate = (opts.workers > 0).then(|| super::ComputeGate::new(opts.workers));

        let t0 = Instant::now();
        let mut per_rank: Vec<Option<RankStats>> = (0..nranks).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            let rank_iter = inboxes
                .iter_mut()
                .zip(b_locals.iter())
                .zip(c_locals.iter_mut())
                .enumerate();
            for (rank, ((inbox, b_local), c_local)) in rank_iter {
                let senders = &senders;
                let gate = gate.as_ref();
                let inbox = inbox.take().unwrap();
                handles.push(scope.spawn(move || {
                    let mut ctx = Ctx {
                        rank,
                        part: &dist.part,
                        plan: &dist.plan,
                        sched: dist.sched.as_ref(),
                        xsched: None,
                        topo: &dist.topo,
                        kernel,
                        outbox: Outbox::Local(senders),
                        inbox,
                        stats: RankStats {
                            sent_to: vec![0; nranks],
                            sent_b_to: vec![0; nranks],
                            ..RankStats::default()
                        },
                        opts,
                        gate,
                        t0,
                        pool: PoolRef::Shared(pool),
                    };
                    let mut vals = SddmmVals::default();
                    rank_main(
                        &mut ctx,
                        &dist.blocks[rank],
                        None,
                        b_local,
                        c_local,
                        &mut vals,
                        &programs[rank],
                    );
                    (rank, ctx.stats)
                }));
            }
            for h in handles {
                let (rank, stats) = h.join().expect("rank thread panicked");
                per_rank[rank] = Some(stats);
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        // Assemble: the contiguous ascending row ranges cover 0..n, so the
        // global C is the concatenation of the per-rank blocks.
        out.nrows = self.dist.part.n;
        out.ncols = n_dense;
        out.data.clear();
        for cl in self.c_locals.iter() {
            out.data.extend_from_slice(&cl.data);
        }

        let allocs = self.pool.lock().unwrap().allocs - allocs_before;
        self.amort.record(plan_secs, allocs);
        ExecStats {
            per_rank: per_rank.into_iter().map(Option::unwrap).collect(),
            wall_secs: wall,
        }
    }

    /// Execute distributed SDDMM E = A ⊙ (X·Yᵀ) off this session's frozen
    /// plan: Y rows move along the very B covers the SpMM path uses
    /// (identical B-side measured volume), X rows along the C covers
    /// reversed. Bitwise-identical to the serial [`Csr::sddmm`] oracle on
    /// any input. The first call builds this op's programs and seeds its
    /// slice of the shared pool (that call's plan time / alloc events);
    /// later calls keep the *exchange path* plan-free and allocation-free
    /// ([`SpmmSession::amortization_for`]) — only the returned sparse
    /// matrix is fresh: assembly copies the pool-held value buffers into a
    /// newly allocated O(nnz) [`Csr`] each call.
    fn run_sddmm(
        &mut self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Csr, ExecStats) {
        let (vals, stats) = self.execute_kernel(KernelOp::Sddmm, x, y, kernel);
        let out = assemble_sddmm(&self.dist.part, &self.dist.blocks, &self.dist.plan, &vals);
        let mut pref = PoolRef::Shared(&self.pool);
        for v in vals {
            v.release_into(&mut pref);
        }
        (out, stats)
    }

    /// Execute the fused SDDMM→SpMM kernel C = (A ⊙ (X·Yᵀ))·Y into `out` —
    /// one exchange, no edge-value materialization (GAT-style attention
    /// propagation).
    fn run_fused_into(
        &mut self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        out: &mut Dense,
    ) -> ExecStats {
        let n_dense = y.ncols;
        let (vals, stats) = self.execute_kernel(KernelOp::FusedSddmmSpmm, x, y, kernel);
        let mut pref = PoolRef::Shared(&self.pool);
        for v in vals {
            v.release_into(&mut pref);
        }
        out.nrows = self.dist.part.n;
        out.ncols = n_dense;
        out.data.clear();
        for cl in self.c_locals.iter() {
            out.data.extend_from_slice(&cl.data);
        }
        stats
    }

    /// E = A ⊙ (X·Yᵀ) off the frozen plan.
    #[deprecated(note = "use SpmmSession::execute(&ExecRequest::sddmm(x, y).kernel(k))")]
    pub fn execute_sddmm(
        &mut self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Csr, ExecStats) {
        self.run_sddmm(x, y, kernel)
    }

    /// Fused SDDMM→SpMM off the frozen plan.
    #[deprecated(note = "use SpmmSession::execute(&ExecRequest::fused(x, y).kernel(k))")]
    pub fn execute_fused(
        &mut self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        let mut out = Dense::zeros(0, 0);
        let stats = self.run_fused_into(x, y, kernel, &mut out);
        (out, stats)
    }

    /// Fused SDDMM→SpMM into a caller-held output buffer.
    #[deprecated(note = "use SpmmSession::execute_into(&ExecRequest::fused(x, y).kernel(k), out)")]
    pub fn execute_fused_into(
        &mut self,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
        out: &mut Dense,
    ) -> ExecStats {
        self.run_fused_into(x, y, kernel, out)
    }

    /// The shared driver for the SDDMM-family ops: heal/plan lazily,
    /// refill the persistent blocks, run the rank threads against this
    /// op's programs, and record amortization. Returns the per-rank value
    /// buffers (still pool-owned — callers release or assemble them).
    fn execute_kernel(
        &mut self,
        op: KernelOp,
        x: &Dense,
        y: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Vec<SddmmVals>, ExecStats) {
        debug_assert_ne!(op, KernelOp::Spmm);
        let nranks = self.dist.part.nparts;
        let n_dense = y.ncols;
        assert_eq!(self.dist.part.n, y.nrows, "Y height != planned matrix");
        assert_eq!(self.dist.part.n, x.nrows, "X height != planned matrix");
        assert_eq!(x.ncols, n_dense, "SDDMM requires matching X/Y widths");

        let allocs_before = self.pool.lock().unwrap().allocs;
        let t_plan = Instant::now();
        let mut planned = false;
        if kernel.prefers_tiles() != self.prefers_tiles {
            self.prefers_tiles = kernel.prefers_tiles();
            self.rebuild_programs();
            planned = true;
        }
        planned |= self.ensure_kernel_state(op);
        planned |= self.seed_kernel_layout(op, n_dense);
        let plan_secs = if planned { t_plan.elapsed().as_secs_f64() } else { 0.0 };

        let is_fused = op == KernelOp::FusedSddmmSpmm;
        for p in 0..nranks {
            let (r0, r1) = self.dist.part.range(p);
            let bl = &mut self.b_locals[p];
            bl.nrows = r1 - r0;
            bl.ncols = n_dense;
            bl.data.clear();
            bl.data.extend_from_slice(&y.data[r0 * n_dense..r1 * n_dense]);
            let xl = &mut self.x_locals[p];
            xl.nrows = r1 - r0;
            xl.ncols = n_dense;
            xl.data.clear();
            xl.data.extend_from_slice(&x.data[r0 * n_dense..r1 * n_dense]);
            let cl = &mut self.c_locals[p];
            cl.nrows = r1 - r0;
            cl.ncols = if is_fused { n_dense } else { 0 };
            cl.data.clear();
            if is_fused {
                cl.data.resize((r1 - r0) * n_dense, 0.0);
            }
        }

        let dist = &self.dist;
        let programs: &Vec<Program> = match op {
            KernelOp::Sddmm => &self.sddmm.as_ref().unwrap().programs,
            KernelOp::FusedSddmmSpmm => &self.fused.as_ref().unwrap().programs,
            KernelOp::Spmm => unreachable!(),
        };
        let xsched = self.xsched.as_ref();
        let pool = &self.pool;
        let opts = self.opts;
        let c_locals = &mut self.c_locals;
        let b_locals = &self.b_locals;
        let x_locals = &self.x_locals;

        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(nranks);
        let mut inboxes: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(nranks);
        for _ in 0..nranks {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(Some(rx));
        }
        let gate = (opts.workers > 0).then(|| super::ComputeGate::new(opts.workers));

        let t0 = Instant::now();
        let mut per_rank: Vec<Option<(SddmmVals, RankStats)>> =
            (0..nranks).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            let rank_iter = inboxes
                .iter_mut()
                .zip(b_locals.iter())
                .zip(x_locals.iter())
                .zip(c_locals.iter_mut())
                .enumerate();
            for (rank, (((inbox, b_local), x_local), c_local)) in rank_iter {
                let senders = &senders;
                let gate = gate.as_ref();
                let inbox = inbox.take().unwrap();
                handles.push(scope.spawn(move || {
                    let mut ctx = Ctx {
                        rank,
                        part: &dist.part,
                        plan: &dist.plan,
                        sched: dist.sched.as_ref(),
                        xsched,
                        topo: &dist.topo,
                        kernel,
                        outbox: Outbox::Local(senders),
                        inbox,
                        stats: RankStats {
                            sent_to: vec![0; nranks],
                            sent_b_to: vec![0; nranks],
                            ..RankStats::default()
                        },
                        opts,
                        gate,
                        t0,
                        pool: PoolRef::Shared(pool),
                    };
                    let mut vals = SddmmVals::default();
                    rank_main(
                        &mut ctx,
                        &dist.blocks[rank],
                        Some(x_local),
                        b_local,
                        c_local,
                        &mut vals,
                        &programs[rank],
                    );
                    (rank, vals, ctx.stats)
                }));
            }
            for h in handles {
                let (rank, vals, stats) = h.join().expect("rank thread panicked");
                per_rank[rank] = Some((vals, stats));
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        let allocs = self.pool.lock().unwrap().allocs - allocs_before;
        match op {
            KernelOp::Sddmm => self.amort_sddmm.record(plan_secs, allocs),
            KernelOp::FusedSddmmSpmm => self.amort_fused.record(plan_secs, allocs),
            KernelOp::Spmm => unreachable!(),
        }
        let mut all_vals = Vec::with_capacity(nranks);
        let mut stats = Vec::with_capacity(nranks);
        for slot in per_rank {
            let (vals, s) = slot.unwrap();
            all_vals.push(vals);
            stats.push(s);
        }
        (all_vals, ExecStats { per_rank: stats, wall_secs: wall })
    }

    /// Build the X fetch schedule and `op`'s program set if missing.
    /// Returns true when anything was built (planning work).
    fn ensure_kernel_state(&mut self, op: KernelOp) -> bool {
        let mut did = false;
        if !self.xsched_built {
            self.xsched = self.dist.sched.as_ref().map(hierarchy::sddmm_fetch);
            self.xsched_built = true;
            did = true;
        }
        if self.kernel_slot(op).is_none() {
            let programs = build_all_op(
                &self.dist,
                self.xsched.as_ref(),
                &self.opts,
                self.prefers_tiles,
                op,
            );
            *self.kernel_slot(op) = Some(KernelPrograms { programs, seeded_n: 0 });
            did = true;
        }
        did
    }

    /// Seed the pool with the SpMM payload layout at width `n` and size
    /// the persistent blocks; no-op when already seeded at least this wide.
    fn seed_layout(&mut self, n: usize) -> bool {
        if n <= self.seeded_n {
            return false;
        }
        let elems = payload_elems(&self.dist, &self.programs, None, n);
        self.seed_missing(elems);
        for p in 0..self.dist.part.nparts {
            let len = self.dist.part.len(p);
            ensure_capacity(&mut self.b_locals[p], len, n);
            ensure_capacity(&mut self.c_locals[p], len, n);
        }
        self.seeded_n = n;
        true
    }

    /// Seed the pool with `op`'s payload layout at width `n` and size the
    /// persistent blocks (including X); no-op when already seeded.
    fn seed_kernel_layout(&mut self, op: KernelOp, n: usize) -> bool {
        let state = self.kernel_slot(op).as_mut().expect("state built before seeding");
        if n <= state.seeded_n {
            return false;
        }
        state.seeded_n = n;
        // Field-precise re-borrow: payload_elems needs the programs (held
        // in self.sddmm/self.fused) together with &self.dist/&self.xsched.
        let programs = match op {
            KernelOp::Sddmm => &self.sddmm.as_ref().unwrap().programs,
            KernelOp::FusedSddmmSpmm => &self.fused.as_ref().unwrap().programs,
            KernelOp::Spmm => unreachable!(),
        };
        let elems = payload_elems(&self.dist, programs, self.xsched.as_ref(), n);
        self.seed_missing(elems);
        for p in 0..self.dist.part.nparts {
            let len = self.dist.part.len(p);
            ensure_capacity(&mut self.b_locals[p], len, n);
            ensure_capacity(&mut self.x_locals[p], len, n);
            if op == KernelOp::FusedSddmmSpmm {
                ensure_capacity(&mut self.c_locals[p], len, n);
            }
        }
        true
    }

    /// Seed only the slots of `layout` not already dominated by the
    /// session's seeded multiset. Kernel ops share most buffer roles (the
    /// B posts, rep subsets, fold partials), and only one op executes at a
    /// time, so one pool slot can serve a role in every op's layout — the
    /// per-call zero-miss argument only needs, per op, an injective
    /// mapping from that op's roles onto free slots of at least the same
    /// size, which dominance of the union-max multiset provides. Greedy
    /// largest-first matching is exact here (exchange argument), so no
    /// duplicate slots are ever seeded — across ops or across width
    /// growth.
    fn seed_missing(&mut self, mut layout: Vec<usize>) {
        layout.retain(|&e| e > 0);
        layout.sort_unstable_by(|a, b| b.cmp(a)); // descending
        let mut avail = 0usize; // cursor into seeded_slots (descending)
        let mut added = Vec::new();
        for &need in &layout {
            if avail < self.seeded_slots.len() && self.seeded_slots[avail] >= need {
                avail += 1;
            } else {
                added.push(need);
            }
        }
        if added.is_empty() {
            return;
        }
        {
            let mut pool = self.pool.lock().unwrap();
            for &e in &added {
                pool.seed(e);
            }
        }
        self.seeded_slots.extend(added);
        self.seeded_slots.sort_unstable_by(|a, b| b.cmp(a));
    }
}

/// Grow a persistent block's backing storage to hold `len × n` floats
/// without ever shrinking it (other kernel ops may have seeded wider).
fn ensure_capacity(d: &mut Dense, len: usize, n: usize) {
    if d.data.capacity() < len * n {
        *d = Dense::zeros(len, n);
    }
}

fn build_all(dist: &DistSpmm, opts: &ExecOpts, prefers_tiles: bool) -> Vec<Program> {
    build_all_op(dist, None, opts, prefers_tiles, KernelOp::Spmm)
}

fn build_all_op(
    dist: &DistSpmm,
    xsched: Option<&HierSchedule>,
    opts: &ExecOpts,
    prefers_tiles: bool,
    op: KernelOp,
) -> Vec<Program> {
    (0..dist.part.nparts)
        .map(|rank| {
            build_program(
                rank,
                &dist.part,
                &dist.plan,
                dist.sched.as_ref(),
                xsched,
                opts,
                prefers_tiles,
                op,
            )
        })
        .collect()
}

/// Enumerate the posted-payload layout as element counts at dense width
/// `n`: one pool slot per buffer role the programs can ever hold live at
/// once — every outgoing B/X message, every produced C partial,
/// representative redistribution subsets, pre-aggregation accumulators,
/// the remote-partial scratch acquired while folding each incoming
/// column-based contribution, and (SDDMM-family) every edge-value buffer.
/// One slot per role is a strict upper bound on concurrent liveness: each
/// role acquires at most once per call and everything is back in the pool
/// by the end of the call.
fn payload_elems(
    dist: &DistSpmm,
    programs: &[Program],
    xsched: Option<&HierSchedule>,
    n: usize,
) -> Vec<usize> {
    let part = &dist.part;
    let plan = &dist.plan;
    let sched = dist.sched.as_ref();
    let mut elems = Vec::new();
    for (r, prog) in programs.iter().enumerate() {
        for post in &prog.b_posts {
            elems.push(post.rows.len() * n);
        }
        for post in &prog.x_posts {
            elems.push(post.rows.len() * n);
        }
        for item in &prog.items {
            match item {
                Item::ProduceDirectC { dst } => {
                    elems.push(plan.pairs[*dst][r].a_row_compact.nrows * n);
                }
                Item::ProduceFlowC { flow } => {
                    let f = &sched.expect("flow item implies a schedule").c_flows[*flow];
                    elems.push(plan.pairs[f.dst][r].a_row_compact.nrows * n);
                }
                Item::DiagTile { .. } => {}
            }
        }
        for &fi in prog.rep_b.values() {
            let f = &sched.expect("rep duty implies a schedule").b_flows[fi];
            for (_, crows) in &f.consumers {
                elems.push(crows.len() * n);
            }
        }
        for &fi in prog.rep_x.values() {
            let f = &xsched.expect("X rep duty implies an X schedule").b_flows[fi];
            for (_, crows) in &f.consumers {
                elems.push(crows.len() * n);
            }
        }
        for &i in &prog.agg_flows {
            elems.push(sched.expect("agg flow implies a schedule").c_flows[i].rows.len() * n);
        }
        for &key in &prog.fold_keys {
            if let Some((KIND_B, origin)) = ckey_decode(key) {
                let pair = &plan.pairs[r][origin];
                if pair.a_col_compact.nnz() > 0 {
                    // The full-height partial, plus the compact row set the
                    // sparse apply path gathers into — the branch predicate
                    // is shared with `consume_b` so the two cannot drift
                    // apart.
                    elems.push(part.len(r) * n);
                    let touched = pair.a_col_compact.nonempty_rows().len();
                    if col_contribution_is_compact(touched, part.len(r)) {
                        elems.push(touched * n);
                    }
                }
            }
        }
        if prog.op != super::KernelOp::Spmm {
            // Edge-value buffers (width-independent): the diagonal block's,
            // one per incoming column-served origin, one per row-served
            // destination — plus, for the fused kernel, the reactive row
            // partials its X arrivals produce.
            elems.push(dist.blocks[r].diag.nnz());
            for q in 0..part.nparts {
                if q == r {
                    continue;
                }
                elems.push(plan.pairs[r][q].a_col_compact.nnz());
                elems.push(plan.pairs[q][r].a_row_compact.nnz());
            }
            if prog.op == super::KernelOp::FusedSddmmSpmm {
                for dst in prog.row_route.keys() {
                    elems.push(plan.pairs[*dst][r].a_row_compact.nrows * n);
                }
            }
        }
    }
    elems
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Strategy;
    use crate::cover::Solver;
    use crate::sparse::gen;
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    use crate::spmm::PlanSpec;

    fn planned(seed: u64, hier: bool) -> DistSpmm {
        let a = gen::rmat(192, 2500, (0.55, 0.2, 0.19), false, seed);
        PlanSpec::new(Topology::tsubame4(8))
            .strategy(Strategy::Joint(Solver::Koenig))
            .hierarchical(hier)
            .plan(&a)
    }

    fn run_spmm(s: &mut SpmmSession, b: &Dense) -> (Dense, ExecStats) {
        s.execute(&ExecRequest::spmm(b)).unwrap().into_dense()
    }

    #[test]
    fn session_matches_cold_execution_bitwise() {
        for hier in [false, true] {
            let d_cold = planned(21, hier);
            let d_sess = planned(21, hier);
            let mut rng = Rng::new(5);
            let b = Dense::random(192, 16, &mut rng);
            let (want, _) = d_cold.execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
            let mut s = SpmmSession::new(d_sess, ExecOpts::default(), true);
            for _ in 0..3 {
                let (got, _) = run_spmm(&mut s, &b);
                assert_eq!(got.data, want.data, "hier={hier}");
            }
        }
    }

    #[test]
    fn session_steady_state_after_first_call() {
        let mut s = SpmmSession::new(planned(22, true), ExecOpts::default(), true);
        let mut rng = Rng::new(6);
        let b = Dense::random(192, 8, &mut rng);
        let mut out = Dense::zeros(0, 0);
        for _ in 0..4 {
            s.execute_into(&ExecRequest::spmm(&b), &mut out).unwrap();
        }
        let a = s.amortization();
        assert_eq!(a.calls(), 4);
        assert!(a.alloc_events[0] > 0, "first call seeds the layout");
        assert!(a.plan_secs[0] > 0.0);
        for i in 1..4 {
            assert_eq!(a.alloc_events[i], 0, "call {i} allocated");
            assert_eq!(a.plan_secs[i], 0.0, "call {i} planned");
        }
        assert!(a.steady_state());
    }

    #[test]
    fn warm_session_is_clean_from_the_first_call() {
        let mut s = SpmmSession::new(planned(23, true), ExecOpts::default(), true);
        s.warm(16);
        assert!(s.amortization().build_secs > 0.0);
        let mut rng = Rng::new(7);
        // Narrower widths than the warmed one stay allocation-free too.
        for n in [16usize, 4] {
            let b = Dense::random(192, n, &mut rng);
            let _ = run_spmm(&mut s, &b);
        }
        let a = s.amortization();
        assert_eq!(a.total_allocs(), 0, "warmed session must never allocate");
        assert!(a.plan_secs.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn session_handles_width_growth_then_stabilizes() {
        let mut s = SpmmSession::new(planned(24, false), ExecOpts::default(), true);
        let mut rng = Rng::new(8);
        let small = Dense::random(192, 4, &mut rng);
        let big = Dense::random(192, 12, &mut rng);
        run_spmm(&mut s, &small);
        run_spmm(&mut s, &big); // grows: re-seeds at the new width
        let a = s.amortization();
        assert!(a.alloc_events[1] > 0, "growth call must re-seed");
        assert!(a.plan_secs[1] > 0.0, "growth is planning work");
        for _ in 0..3 {
            run_spmm(&mut s, &big);
            run_spmm(&mut s, &small);
        }
        // Every call after the growth one is clean, whatever the width mix.
        let a = s.amortization();
        assert_eq!(a.calls(), 8);
        assert!(a.alloc_events[2..].iter().all(|&x| x == 0), "{:?}", a.alloc_events);
        assert!(a.plan_secs[2..].iter().all(|&t| t == 0.0));
    }

    #[test]
    fn session_opts_variants_bit_identical() {
        let mut rng = Rng::new(9);
        let b = Dense::random(192, 8, &mut rng);
        let (want, _) = planned(25, true).execute(&ExecRequest::spmm(&b)).unwrap().into_dense();
        for opts in [
            ExecOpts::sequential(),
            ExecOpts { workers: 2, ..ExecOpts::default() },
            ExecOpts { tile_rows: 7, ..ExecOpts::default() },
        ] {
            let mut s = SpmmSession::new(planned(25, true), ExecOpts::default(), true);
            s.set_opts(opts);
            let (got, _) = run_spmm(&mut s, &b);
            assert_eq!(got.data, want.data, "{opts:?}");
        }
    }

    #[test]
    fn session_sddmm_matches_oracle_and_reaches_steady_state() {
        for hier in [false, true] {
            let mut s = SpmmSession::new(planned(26, hier), ExecOpts::default(), true);
            let a_hat = {
                // Rebuild the same matrix the plan froze (planned() is
                // deterministic) to get the oracle.
                gen::rmat(192, 2500, (0.55, 0.2, 0.19), false, 26)
            };
            let mut rng = Rng::new(10);
            let x = Dense::random(192, 8, &mut rng);
            let y = Dense::random(192, 8, &mut rng);
            let want = a_hat.sddmm(&x, &y);
            for _ in 0..3 {
                let (got, _) = s.execute(&ExecRequest::sddmm(&x, &y)).unwrap().into_sparse();
                assert_eq!(got, want, "hier={hier}");
            }
            let am = s.amortization_for(KernelOp::Sddmm);
            assert_eq!(am.calls(), 3);
            assert!(am.alloc_events[0] > 0 && am.plan_secs[0] > 0.0);
            assert!(am.steady_state(), "hier={hier}: {:?}", am.alloc_events);
        }
    }

    #[test]
    fn session_shared_plan_spmm_then_sddmm_identical_b_side() {
        // The plan-sharing session contract: SpMM then SDDMM off one
        // frozen plan move identical B-side bytes, and the second call of
        // each kernel does zero planning and zero fresh allocations.
        let mut s = SpmmSession::new(planned(27, true), ExecOpts::default(), true);
        let mut rng = Rng::new(11);
        let x = Dense::random(192, 8, &mut rng);
        let y = Dense::random(192, 8, &mut rng);
        let (_, spmm_stats) = run_spmm(&mut s, &y);
        let (_, sddmm_stats) = s.execute(&ExecRequest::sddmm(&x, &y)).unwrap().into_sparse();
        assert!(spmm_stats.measured_b_volume().total() > 0);
        assert_eq!(
            spmm_stats.measured_b_volume(),
            sddmm_stats.measured_b_volume(),
            "kernels moved different B-side bytes off one plan"
        );
        // Second calls of both kernels are clean.
        let _ = run_spmm(&mut s, &y);
        let _ = s.execute(&ExecRequest::sddmm(&x, &y)).unwrap().into_sparse();
        assert_eq!(s.amortization().alloc_events[1], 0);
        assert_eq!(s.amortization().plan_secs[1], 0.0);
        assert_eq!(s.amortization_for(KernelOp::Sddmm).alloc_events[1], 0);
        assert_eq!(s.amortization_for(KernelOp::Sddmm).plan_secs[1], 0.0);
    }

    #[test]
    fn session_fused_matches_one_shot_and_steady_state() {
        let a = crate::bench::int_matrix(192, 1800, 28);
        let x = Dense::from_fn(192, 4, |i, j| ((i * 3 + j) % 5) as f32 - 2.0);
        let y = Dense::from_fn(192, 4, |i, j| ((i + j * 5) % 5) as f32 - 2.0);
        let want = a.sddmm(&x, &y).spmm(&y);
        for hier in [false, true] {
            let d = PlanSpec::new(Topology::tsubame4(8))
                .strategy(Strategy::Joint(Solver::Koenig))
                .hierarchical(hier)
                .plan(&a);
            let mut s = d.into_session(ExecOpts::default(), true);
            s.warm_kernel(KernelOp::FusedSddmmSpmm, 4);
            for _ in 0..3 {
                let (got, _) = s.execute(&ExecRequest::fused(&x, &y)).unwrap().into_dense();
                assert_eq!(got.data, want.data, "hier={hier}");
            }
            let am = s.amortization_for(KernelOp::FusedSddmmSpmm);
            assert!(am.steady_state(), "hier={hier}");
            assert_eq!(am.total_allocs(), 0, "hier={hier}: warmed fused allocated");
            assert!(am.plan_secs.iter().all(|&t| t == 0.0), "hier={hier}");
        }
    }
}
