//! Wire format and worker half of the multi-process backend
//! ([`crate::runtime::multiproc`]).
//!
//! The control plane serializes each rank's *entire* job — partition,
//! topology, plan, schedule, the frozen [`Program`] that
//! [`super::build_program`] derived, local A blocks and dense operands —
//! into one versioned blob, and every runtime `Msg` into a framed DATA
//! payload. Workers run the exact same `rank_main` as the thread
//! executor, with [`super::Outbox::Socket`] swapped in for the channel
//! senders; since every scatter-add folds in canonical (origin, row)
//! order regardless of arrival order, the proc backend's C is
//! bitwise-identical to the thread backend's — the property
//! `tests/multiproc_suite.rs` pins.
//!
//! Framing: `len: u32 LE | kind: u8 | payload`, where `len` counts the
//! kind byte plus payload. All integers little-endian, floats as raw
//! IEEE-754 bits ([`crate::util::bin`]), every length field bounded by
//! the enclosing buffer so corrupt input fails cleanly.

use super::kernel::{KernelOp, NativeKernel};
use super::pipeline::{BufferPool, ExecOpts, PoolRef};
use super::{
    rank_main, BPost, Ctx, Item, Msg, Outbox, Program, RankStats, RowRoute, SddmmVals,
};
use crate::comm::{CommPlan, PairPlan};
use crate::dense::Dense;
use crate::hierarchy::{self, phase, BFlow, CFlow, HierSchedule, RepAssign, RepSchedule};
use crate::partition::{LocalBlocks, RowPartition};
use crate::plan::cache::{decode_strategy, encode_strategy};
use crate::runtime::multiproc::CrashPhase;
use crate::topology::{ReplicaMap, Topology};
use crate::util::bin::{
    r_csr, r_dense, r_f64, r_str, r_u32, r_u32s, r_u64, r_u64s, r_u8, w_csr, w_dense, w_f64,
    w_str, w_u32, w_u32s, w_u64, w_u64s, w_u8,
};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Magic + version prefix of every JOB blob. Bump [`WIRE_VERSION`] on any
/// layout change: parent and workers are always the same binary, so a
/// mismatch means a stale `--worker-exe` override, not rolling upgrade.
pub(crate) const WIRE_MAGIC: &[u8; 8] = b"SHIROWIR";
/// v5: the job blob carries an optional 1.5D replication schedule
/// ([`RepSchedule`], DESIGN.md §13) — for replicated jobs the partition /
/// plan / blocks describe the *group-level* problem while `nranks` stays
/// physical, the shipped program is an unused placeholder, and workers
/// run `rank_main_rep` instead of `rank_main`; the partial-C
/// reduce-scatter rides DATA frames as `Msg::CRed`. v4 was the
/// multi-*job* pool protocol (DESIGN.md §10/§12): every JOB frame carries
/// a fixed `generation | epoch | mode | crash | fingerprint` header so
/// one live worker serves many requests — `mode` distinguishes a full job
/// blob from a delta (operands only, against the plan body the worker
/// cached under its fingerprint), and deterministic fault injection rides
/// the per-JOB crash byte instead of a spawn-time env var. v3
/// epoch-tagged JOB/DATA/DONE/ERROR and added ABORT — the crash-recovery
/// protocol. v2 added the op-gated SDDMM edge-value DONE payload.
pub(crate) const WIRE_VERSION: u32 = 5;

/// Hard ceiling on one frame (1 GiB): no legitimate payload approaches
/// this; a larger claim means a corrupt or hostile length field.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Worker heartbeat interval. The control plane declares a rank dead when
/// nothing (BEAT or otherwise) arrives within its failure timeout — many
/// intervals, so scheduler jitter can't false-positive.
pub(crate) const BEAT_MILLIS: u64 = 100;

/// Env vars the parent sets when spawning a worker; their presence is what
/// [`crate::runtime::multiproc::maybe_run_worker`] keys on.
pub(crate) const ENV_PORT: &str = "SHIRO_WORKER_PORT";
pub(crate) const ENV_RANK: &str = "SHIRO_WORKER_RANK";

/// Frame kinds. Namespaced so they cannot be confused with the fold-key
/// kinds in [`super::pipeline`].
pub(crate) mod kind {
    /// Worker → parent, first frame: `version u32 | rank u64`.
    pub const HELLO: u8 = 1;
    /// Parent → worker: a [`super::JobHeader`] (`generation u64 | epoch
    /// u64 | mode u8 | crash u8 | fingerprint u64`) followed by a full
    /// job blob or an operand-only delta. Re-sent with a fresh epoch
    /// after every recovery replan and with a fresh generation for every
    /// pooled request; the job's own `rank` field (not the worker's
    /// spawn-time identity) is authoritative for that epoch.
    pub const JOB: u8 = 2;
    /// Either direction: `dst u64 | epoch u64 | encoded Msg` — routed by
    /// the parent to `dst`'s stream for the *current* epoch; stale-epoch
    /// frames are dropped by both parent and workers.
    pub const DATA: u8 = 3;
    /// Worker → parent on success:
    /// `epoch u64 | rank u64 | C block | RankStats | flag u8 [| SddmmVals]`
    /// — the edge-value payload ships only for SDDMM jobs (flag 1), whose
    /// output *is* the per-rank sparse values.
    pub const DONE: u8 = 4;
    /// Worker → parent liveness: `rank u64`, every [`super::BEAT_MILLIS`].
    pub const BEAT: u8 = 5;
    /// Worker → parent on failure: `epoch u64 | rank u64 | message`. An
    /// aborted job's "inbox closed" panic also lands here, tagged with
    /// its stale epoch, which the parent discards.
    pub const ERROR: u8 = 6;
    /// Parent → worker: `epoch u64` — cancel the in-flight job for that
    /// epoch (a peer died; a replanned JOB follows under a new epoch).
    pub const ABORT: u8 = 7;
}

// ------------------------------------------------------------- framing ----

/// Length prefix for a frame with `payload_len` payload bytes, rejecting
/// anything the `u32` word could misrepresent. `MAX_FRAME < u32::MAX`, so
/// a payload that passes here can never wrap the prefix and desync the
/// stream; one that doesn't gets a structured error instead of a silent
/// truncation. Factored out of [`write_frame`] so the boundary is unit
/// testable without allocating gigabyte payloads.
pub(crate) fn frame_len(payload_len: usize) -> Result<u32> {
    // len counts the kind byte too: len = payload_len + 1 > MAX_FRAME,
    // phrased without the `+ 1` so `usize::MAX` cannot overflow.
    if payload_len >= MAX_FRAME {
        bail!(
            "frame payload of {payload_len} bytes exceeds MAX_FRAME \
             ({MAX_FRAME} bytes incl. kind byte): refusing to emit a frame \
             the length prefix cannot represent"
        );
    }
    Ok((payload_len + 1) as u32)
}

pub(crate) fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> Result<()> {
    let len = frame_len(payload.len())?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

pub(crate) fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>)> {
    let mut lenb = [0u8; 4];
    r.read_exact(&mut lenb)?;
    let len = u32::from_le_bytes(lenb) as usize;
    if len == 0 || len > MAX_FRAME {
        bail!("frame length {len} out of range");
    }
    let mut kb = [0u8; 1];
    r.read_exact(&mut kb)?;
    let mut payload = vec![0u8; len - 1];
    r.read_exact(&mut payload)?;
    Ok((kb[0], payload))
}

/// Shared write half of a worker's control-plane socket: the pipeline
/// ([`Outbox::Socket`]) and the heartbeat thread interleave whole frames
/// under one lock.
pub(crate) struct SocketTx {
    stream: Mutex<TcpStream>,
}

impl SocketTx {
    pub(crate) fn new(stream: TcpStream) -> SocketTx {
        SocketTx { stream: Mutex::new(stream) }
    }

    pub(crate) fn frame(&self, kind: u8, payload: &[u8]) -> Result<()> {
        let mut s = self.stream.lock().unwrap();
        write_frame(&mut *s, kind, payload)
    }
}

/// Per-epoch send handle the pipeline writes through
/// ([`Outbox::Socket`]): every outgoing DATA frame is stamped with the
/// epoch it belongs to, so after a recovery replan the control plane and
/// surviving workers can discard traffic from the aborted step. Wraps the
/// process-wide [`SocketTx`] — one write lock per frame, shared with the
/// heartbeat thread and any not-yet-dead previous job thread.
pub(crate) struct EpochTx {
    tx: Arc<SocketTx>,
    epoch: u64,
    /// [`CrashPhase::MidExchange`] fault injection: abort the process
    /// right after the first DATA frame hits the socket.
    crash_mid: bool,
}

impl EpochTx {
    pub(crate) fn new(tx: Arc<SocketTx>, epoch: u64, crash_mid: bool) -> EpochTx {
        EpochTx { tx, epoch, crash_mid }
    }

    /// Encode and send one rank→rank message. Panics on socket failure:
    /// the parent is gone, no progress is possible, and the pipeline's
    /// send path is infallible by contract (mirroring the thread
    /// backend's channel `send().expect(..)`).
    pub(crate) fn send(&self, dst: usize, msg: &Msg) {
        let mut payload = Vec::new();
        w_u64(&mut payload, dst as u64).expect("vec write");
        w_u64(&mut payload, self.epoch).expect("vec write");
        encode_msg(&mut payload, msg).expect("vec write");
        self.tx
            .frame(kind::DATA, &payload)
            .expect("control-plane socket write failed — parent gone");
        if self.crash_mid {
            std::process::abort();
        }
    }
}

/// Routing header of a v3 DATA payload: `dst u64 | epoch u64 | Msg`. The
/// parent reads only this much to route; workers read it to drop frames
/// from an aborted epoch before decoding the message body.
pub(crate) const DATA_HEADER: usize = 16;

pub(crate) fn decode_data_header(payload: &[u8]) -> Result<(usize, u64)> {
    let r = &mut &payload[..];
    let dst = r_u64(r)? as usize;
    let epoch = r_u64(r)?;
    Ok((dst, epoch))
}

/// Payload of ABORT frames: one `epoch u64`.
pub(crate) fn epoch_payload(epoch: u64) -> Vec<u8> {
    epoch.to_le_bytes().to_vec()
}

pub(crate) fn decode_epoch(buf: &[u8]) -> Result<u64> {
    r_u64(&mut &buf[..])
}

// -------------------------------------------------------- job header ----

/// JOB payload mode: the body is a complete job blob ([`encode_job`]).
pub(crate) const JOB_MODE_FULL: u8 = 1;
/// JOB payload mode: the body is an operand-only delta
/// ([`encode_job_delta`]) against the plan body the worker cached under
/// the header's fingerprint.
pub(crate) const JOB_MODE_DELTA: u8 = 2;
/// Bytes of the fixed v4 JOB header:
/// `generation u64 | epoch u64 | mode u8 | crash u8 | fingerprint u64`.
pub(crate) const JOB_HEADER: usize = 26;

/// Fixed header of every v4 JOB payload.
pub(crate) struct JobHeader {
    /// Pool generation: bumped once per request a
    /// [`crate::runtime::multiproc::WorkerPool`] serves, monotone over a
    /// connection's lifetime. A regression means a corrupt or replayed
    /// frame.
    pub generation: u64,
    /// Exchange epoch. Bumped by recovery replans *within* a request and
    /// kept monotone across pooled requests, so stale DATA from any
    /// earlier step can never alias a live one.
    pub epoch: u64,
    /// [`JOB_MODE_FULL`] or [`JOB_MODE_DELTA`].
    pub mode: u8,
    /// Deterministic fault injection
    /// ([`crate::runtime::multiproc::FaultPlan`]): the phase at which the
    /// receiving worker abort()s. Rides the JOB frame rather than the
    /// spawn environment so a pooled worker can be crash-armed per
    /// request — and disarmed on the next one.
    pub crash: Option<CrashPhase>,
    /// [`job_fingerprint`] of the job's plan body. A delta body is valid
    /// only against a cached full body with this fingerprint.
    pub fp: u64,
}

fn crash_byte(crash: Option<CrashPhase>) -> u8 {
    match crash {
        None => 0,
        Some(p) => {
            let i = CrashPhase::ALL.iter().position(|&q| q == p).expect("ALL is total");
            i as u8 + 1
        }
    }
}

fn crash_from_byte(b: u8) -> Result<Option<CrashPhase>> {
    if b == 0 {
        return Ok(None);
    }
    CrashPhase::ALL
        .get(b as usize - 1)
        .copied()
        .map(Some)
        .ok_or_else(|| anyhow!("unknown crash-phase byte {b}"))
}

pub(crate) fn encode_job_header(h: &JobHeader) -> Vec<u8> {
    let mut out = Vec::with_capacity(JOB_HEADER);
    w_u64(&mut out, h.generation).expect("vec write");
    w_u64(&mut out, h.epoch).expect("vec write");
    w_u8(&mut out, h.mode).expect("vec write");
    w_u8(&mut out, crash_byte(h.crash)).expect("vec write");
    w_u64(&mut out, h.fp).expect("vec write");
    out
}

pub(crate) fn decode_job_header(buf: &[u8]) -> Result<JobHeader> {
    if buf.len() < JOB_HEADER {
        bail!("JOB frame too short for v4 header ({} < {JOB_HEADER} bytes)", buf.len());
    }
    let r = &mut &buf[..];
    let generation = r_u64(r)?;
    let epoch = r_u64(r)?;
    let mode = r_u8(r)?;
    if mode != JOB_MODE_FULL && mode != JOB_MODE_DELTA {
        bail!("unknown JOB mode {mode}");
    }
    let crash = crash_from_byte(r_u8(r)?)?;
    let fp = r_u64(r)?;
    Ok(JobHeader { generation, epoch, mode, crash, fp })
}

// ------------------------------------------------------ message codec ----

fn encode_msg(out: &mut Vec<u8>, msg: &Msg) -> Result<()> {
    match msg {
        Msg::B { from, origin, rows, data } => {
            w_u8(out, 0)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *origin as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::X { from, origin, rows, data } => {
            w_u8(out, 1)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *origin as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::C { from, rows, data } => {
            w_u8(out, 2)?;
            w_u64(out, *from as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::CAgg { from, final_dst, rows, data } => {
            w_u8(out, 3)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *final_dst as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::CRed { from, rows, data } => {
            w_u8(out, 4)?;
            w_u64(out, *from as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
    }
    Ok(())
}

fn decode_msg<R: Read>(r: &mut R, max: usize) -> Result<Msg> {
    let tag = r_u8(r)?;
    let from = r_u64(r)? as usize;
    Ok(match tag {
        0 | 1 => {
            let origin = r_u64(r)? as usize;
            let rows = r_u32s(r, max)?;
            let data = r_dense(r, max)?;
            if tag == 0 {
                Msg::B { from, origin, rows, data }
            } else {
                Msg::X { from, origin, rows, data }
            }
        }
        2 => Msg::C { from, rows: r_u32s(r, max)?, data: r_dense(r, max)? },
        3 => {
            let final_dst = r_u64(r)? as usize;
            Msg::CAgg { from, final_dst, rows: r_u32s(r, max)?, data: r_dense(r, max)? }
        }
        4 => Msg::CRed { from, rows: r_u32s(r, max)?, data: r_dense(r, max)? },
        t => bail!("unknown message tag {t}"),
    })
}

// ------------------------------------------------------ program codec ----

/// Every `&'static str` phase label a [`BPost`] can carry; the wire tag is
/// the table index. Unknown labels are an encode-time error, so adding a
/// phase without extending this table fails loudly in tests, not silently
/// on a worker.
const PHASES: [&str; 11] = [
    crate::sim::FLAT_STAGE,
    phase::S1_INTER_B,
    phase::S1_INTRA_C,
    phase::S2_INTER_C,
    phase::S2_INTRA_B,
    phase::COMPUTE_LOCAL,
    phase::COMPUTE_REMOTE,
    phase::IDLE,
    phase::S1_FETCH_X,
    phase::S2_INTRA_X,
    phase::RED_INTRA,
];

fn phase_tag(name: &str) -> Result<u8> {
    PHASES
        .iter()
        .position(|&p| p == name)
        .map(|i| i as u8)
        .ok_or_else(|| anyhow!("phase label {name:?} missing from wire table"))
}

fn phase_name(tag: u8) -> Result<&'static str> {
    PHASES
        .get(tag as usize)
        .copied()
        .ok_or_else(|| anyhow!("unknown phase tag {tag}"))
}

fn op_tag(op: KernelOp) -> u8 {
    match op {
        KernelOp::Spmm => 0,
        KernelOp::Sddmm => 1,
        KernelOp::FusedSddmmSpmm => 2,
    }
}

fn op_from_tag(tag: u8) -> Result<KernelOp> {
    Ok(match tag {
        0 => KernelOp::Spmm,
        1 => KernelOp::Sddmm,
        2 => KernelOp::FusedSddmmSpmm,
        t => bail!("unknown kernel-op tag {t}"),
    })
}

fn w_usizes<W: Write>(w: &mut W, xs: &[usize]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for &x in xs {
        w_u64(w, x as u64)?;
    }
    Ok(())
}

fn r_usizes<R: Read>(r: &mut R, max: usize) -> Result<Vec<usize>> {
    Ok(r_u64s(r, max)?.into_iter().map(|x| x as usize).collect())
}

/// Preallocation guard for count-prefixed containers: the element-count
/// bound (`max = buf.len()/4 + 1`) caps how many items a frame can claim,
/// but `Vec::with_capacity(n)` of a multi-word element type can still
/// demand many times the frame's size up front. Cap the *reserved*
/// capacity by the bytes actually remaining in the frame — a corrupt
/// count then costs at most amortized regrowth before the decode errors
/// out, never an outsized allocation. (Honest inputs whose wire encoding
/// is smaller than the in-memory element just regrow a few times.)
fn bounded_vec<T>(n: usize, remaining_bytes: usize) -> Vec<T> {
    let elem = std::mem::size_of::<T>().max(1);
    Vec::with_capacity(n.min(remaining_bytes / elem + 1))
}

fn encode_posts(out: &mut Vec<u8>, posts: &[BPost]) -> Result<()> {
    w_u64(out, posts.len() as u64)?;
    for p in posts {
        w_u64(out, p.dst as u64)?;
        w_u8(out, phase_tag(p.phase)?)?;
        w_u32s(out, &p.rows)?;
    }
    Ok(())
}

fn decode_posts(r: &mut &[u8], max: usize) -> Result<Vec<BPost>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt program: {n} posts exceed available bytes");
    }
    let mut posts = bounded_vec::<BPost>(n, r.len());
    for _ in 0..n {
        let dst = r_u64(r)? as usize;
        let phase = phase_name(r_u8(r)?)?;
        posts.push(BPost { dst, rows: r_u32s(r, max)?, phase });
    }
    Ok(posts)
}

fn encode_map(out: &mut Vec<u8>, m: &std::collections::BTreeMap<usize, usize>) -> Result<()> {
    w_u64(out, m.len() as u64)?;
    for (&k, &v) in m {
        w_u64(out, k as u64)?;
        w_u64(out, v as u64)?;
    }
    Ok(())
}

fn decode_map<R: Read>(
    r: &mut R,
    max: usize,
) -> Result<std::collections::BTreeMap<usize, usize>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt program: map of {n} entries exceeds available bytes");
    }
    let mut m = std::collections::BTreeMap::new();
    for _ in 0..n {
        let k = r_u64(r)? as usize;
        m.insert(k, r_u64(r)? as usize);
    }
    Ok(m)
}

fn encode_program(out: &mut Vec<u8>, p: &Program) -> Result<()> {
    w_u8(out, op_tag(p.op))?;
    encode_posts(out, &p.b_posts)?;
    encode_posts(out, &p.x_posts)?;
    w_u64(out, p.items.len() as u64)?;
    for it in &p.items {
        match it {
            Item::ProduceDirectC { dst } => {
                w_u8(out, 0)?;
                w_u64(out, *dst as u64)?;
            }
            Item::ProduceFlowC { flow } => {
                w_u8(out, 1)?;
                w_u64(out, *flow as u64)?;
            }
            Item::DiagTile { r0, r1 } => {
                w_u8(out, 2)?;
                w_u64(out, *r0 as u64)?;
                w_u64(out, *r1 as u64)?;
            }
        }
    }
    w_u64(out, p.expect_msgs as u64)?;
    w_u64s(out, &p.fold_keys)?;
    w_usizes(out, &p.agg_flows)?;
    encode_map(out, &p.rep_b)?;
    encode_map(out, &p.rep_x)?;
    w_u64(out, p.row_route.len() as u64)?;
    for (&dst, route) in &p.row_route {
        w_u64(out, dst as u64)?;
        match route {
            RowRoute::Direct => w_u8(out, 0)?,
            RowRoute::Flow(i) => {
                w_u8(out, 1)?;
                w_u64(out, *i as u64)?;
            }
        }
    }
    Ok(())
}

fn decode_program(r: &mut &[u8], max: usize) -> Result<Program> {
    let op = op_from_tag(r_u8(r)?)?;
    let b_posts = decode_posts(r, max)?;
    let x_posts = decode_posts(r, max)?;
    let n_items = r_u64(r)? as usize;
    if n_items > max {
        bail!("corrupt program: {n_items} items exceed available bytes");
    }
    let mut items = bounded_vec::<Item>(n_items, r.len());
    for _ in 0..n_items {
        items.push(match r_u8(r)? {
            0 => Item::ProduceDirectC { dst: r_u64(r)? as usize },
            1 => Item::ProduceFlowC { flow: r_u64(r)? as usize },
            2 => Item::DiagTile { r0: r_u64(r)? as usize, r1: r_u64(r)? as usize },
            t => bail!("unknown program item tag {t}"),
        });
    }
    let expect_msgs = r_u64(r)? as usize;
    let fold_keys = r_u64s(r, max)?;
    let agg_flows = r_usizes(r, max)?;
    let rep_b = decode_map(r, max)?;
    let rep_x = decode_map(r, max)?;
    let n_routes = r_u64(r)? as usize;
    if n_routes > max {
        bail!("corrupt program: {n_routes} row routes exceed available bytes");
    }
    let mut row_route = std::collections::BTreeMap::new();
    for _ in 0..n_routes {
        let dst = r_u64(r)? as usize;
        let route = match r_u8(r)? {
            0 => RowRoute::Direct,
            1 => RowRoute::Flow(r_u64(r)? as usize),
            t => bail!("unknown row-route tag {t}"),
        };
        row_route.insert(dst, route);
    }
    Ok(Program {
        op,
        b_posts,
        x_posts,
        items,
        expect_msgs,
        fold_keys,
        agg_flows,
        rep_b,
        rep_x,
        row_route,
    })
}

// ------------------------------------------- plan / schedule / operand ----

fn encode_topo(out: &mut Vec<u8>, t: &Topology) -> Result<()> {
    w_str(out, &t.name)?;
    w_u64(out, t.nranks as u64)?;
    w_u64(out, t.group_size as u64)?;
    for v in [t.intra_bw, t.inter_bw, t.intra_lat, t.inter_lat, t.compute_rate, t.kernel_launch]
    {
        w_f64(out, v)?;
    }
    Ok(())
}

fn decode_topo<R: Read>(r: &mut R, max: usize) -> Result<Topology> {
    Ok(Topology {
        name: r_str(r, max)?,
        nranks: r_u64(r)? as usize,
        group_size: r_u64(r)? as usize,
        intra_bw: r_f64(r)?,
        inter_bw: r_f64(r)?,
        intra_lat: r_f64(r)?,
        inter_lat: r_f64(r)?,
        compute_rate: r_f64(r)?,
        kernel_launch: r_f64(r)?,
    })
}

/// Same layout as the plan cache's body ([`crate::plan::cache`]): split
/// parts + flags only, compact operands re-derived via
/// [`PairPlan::from_parts`] — the reconstruction the cache's roundtrip
/// test proves exact.
fn encode_plan(out: &mut Vec<u8>, plan: &CommPlan) -> Result<()> {
    w_u64(out, plan.nranks as u64)?;
    w_u8(out, encode_strategy(plan.strategy))?;
    w_usizes(out, &plan.block_rows)?;
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p == q {
                continue;
            }
            let pair = &plan.pairs[p][q];
            w_u8(out, u8::from(pair.full_block))?;
            w_csr(out, &pair.a_row_part)?;
            w_csr(out, &pair.a_col_part)?;
        }
    }
    Ok(())
}

fn decode_plan(r: &mut &[u8], max: usize) -> Result<CommPlan> {
    let nranks = r_u64(r)? as usize;
    if nranks > max {
        bail!("corrupt plan: nranks {nranks} exceeds available bytes");
    }
    let strategy = decode_strategy(r_u8(r)?)?;
    let block_rows = r_usizes(r, max)?;
    if block_rows.len() != nranks {
        bail!("corrupt plan: {} block heights for {nranks} ranks", block_rows.len());
    }
    let mut pairs = bounded_vec::<Vec<PairPlan>>(nranks, r.len());
    for p in 0..nranks {
        let mut row = bounded_vec::<PairPlan>(nranks, r.len());
        for q in 0..nranks {
            if p == q {
                row.push(PairPlan::default());
                continue;
            }
            let full_block = r_u8(r)? != 0;
            let a_row_part = r_csr(r, max)?;
            let a_col_part = r_csr(r, max)?;
            row.push(PairPlan::from_parts(a_row_part, a_col_part, full_block));
        }
        pairs.push(row);
    }
    Ok(CommPlan { nranks, strategy, pairs, block_rows })
}

fn encode_rowsets(out: &mut Vec<u8>, sets: &[(usize, Vec<u32>)]) -> Result<()> {
    w_u64(out, sets.len() as u64)?;
    for (rank, rows) in sets {
        w_u64(out, *rank as u64)?;
        w_u32s(out, rows)?;
    }
    Ok(())
}

fn decode_rowsets(r: &mut &[u8], max: usize) -> Result<Vec<(usize, Vec<u32>)>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt schedule: {n} row sets exceed available bytes");
    }
    let mut sets = bounded_vec::<(usize, Vec<u32>)>(n, r.len());
    for _ in 0..n {
        let rank = r_u64(r)? as usize;
        sets.push((rank, r_u32s(r, max)?));
    }
    Ok(sets)
}

fn encode_directs(out: &mut Vec<u8>, ds: &[(usize, usize, Vec<u32>)]) -> Result<()> {
    w_u64(out, ds.len() as u64)?;
    for (a, b, rows) in ds {
        w_u64(out, *a as u64)?;
        w_u64(out, *b as u64)?;
        w_u32s(out, rows)?;
    }
    Ok(())
}

fn decode_directs(r: &mut &[u8], max: usize) -> Result<Vec<(usize, usize, Vec<u32>)>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt schedule: {n} direct transfers exceed available bytes");
    }
    let mut ds = bounded_vec::<(usize, usize, Vec<u32>)>(n, r.len());
    for _ in 0..n {
        let a = r_u64(r)? as usize;
        let b = r_u64(r)? as usize;
        ds.push((a, b, r_u32s(r, max)?));
    }
    Ok(ds)
}

fn encode_sched(out: &mut Vec<u8>, s: &HierSchedule) -> Result<()> {
    w_u64(out, s.nranks as u64)?;
    w_u64(out, s.b_flows.len() as u64)?;
    for f in &s.b_flows {
        w_u64(out, f.src as u64)?;
        w_u64(out, f.dst_group as u64)?;
        w_u64(out, f.rep as u64)?;
        w_u32s(out, &f.rows)?;
        encode_rowsets(out, &f.consumers)?;
    }
    w_u64(out, s.c_flows.len() as u64)?;
    for f in &s.c_flows {
        w_u64(out, f.dst as u64)?;
        w_u64(out, f.src_group as u64)?;
        w_u64(out, f.rep as u64)?;
        w_u32s(out, &f.rows)?;
        encode_rowsets(out, &f.producers)?;
    }
    encode_directs(out, &s.direct_b)?;
    encode_directs(out, &s.direct_c)?;
    Ok(())
}

fn decode_sched(r: &mut &[u8], max: usize) -> Result<HierSchedule> {
    let nranks = r_u64(r)? as usize;
    let nb = r_u64(r)? as usize;
    if nb > max {
        bail!("corrupt schedule: {nb} B flows exceed available bytes");
    }
    let mut b_flows = bounded_vec::<BFlow>(nb, r.len());
    for _ in 0..nb {
        b_flows.push(BFlow {
            src: r_u64(r)? as usize,
            dst_group: r_u64(r)? as usize,
            rep: r_u64(r)? as usize,
            rows: r_u32s(r, max)?,
            consumers: decode_rowsets(r, max)?,
        });
    }
    let nc = r_u64(r)? as usize;
    if nc > max {
        bail!("corrupt schedule: {nc} C flows exceed available bytes");
    }
    let mut c_flows = bounded_vec::<CFlow>(nc, r.len());
    for _ in 0..nc {
        c_flows.push(CFlow {
            dst: r_u64(r)? as usize,
            src_group: r_u64(r)? as usize,
            rep: r_u64(r)? as usize,
            rows: r_u32s(r, max)?,
            producers: decode_rowsets(r, max)?,
        });
    }
    let direct_b = decode_directs(r, max)?;
    let direct_c = decode_directs(r, max)?;
    Ok(HierSchedule { nranks, b_flows, c_flows, direct_b, direct_c })
}

fn encode_rank_pairs(out: &mut Vec<u8>, ps: &[(usize, usize)]) -> Result<()> {
    w_u64(out, ps.len() as u64)?;
    for &(a, b) in ps {
        w_u64(out, a as u64)?;
        w_u64(out, b as u64)?;
    }
    Ok(())
}

fn decode_rank_pairs(r: &mut &[u8], max: usize) -> Result<Vec<(usize, usize)>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt replicated schedule: {n} sends exceed available bytes");
    }
    let mut ps = bounded_vec::<(usize, usize)>(n, r.len());
    for _ in 0..n {
        let a = r_u64(r)? as usize;
        let b = r_u64(r)? as usize;
        ps.push((a, b));
    }
    Ok(ps)
}

/// Wire form of the 1.5D replication schedule (v5): the replica map as two
/// words, then one [`RepAssign`] per physical rank in rank order.
fn encode_rep(out: &mut Vec<u8>, rs: &RepSchedule) -> Result<()> {
    w_u64(out, rs.map.nranks as u64)?;
    w_u64(out, rs.map.c as u64)?;
    w_u64(out, rs.assigns.len() as u64)?;
    for a in &rs.assigns {
        w_u64(out, a.group as u64)?;
        w_u64(out, a.member as u64)?;
        w_usizes(out, &a.col_fetch)?;
        w_usizes(out, &a.row_recv)?;
        w_u32s(out, &a.touched)?;
        encode_rank_pairs(out, &a.b_sends)?;
        encode_rank_pairs(out, &a.c_sends)?;
        w_usizes(out, &a.red_from)?;
        match a.red_to {
            None => w_u8(out, 0)?,
            Some(home) => {
                w_u8(out, 1)?;
                w_u64(out, home as u64)?;
            }
        }
    }
    Ok(())
}

fn decode_rep(r: &mut &[u8], max: usize) -> Result<RepSchedule> {
    let nranks = r_u64(r)? as usize;
    let c = r_u64(r)? as usize;
    if nranks == 0 || c == 0 || nranks % c != 0 || nranks > max {
        bail!("corrupt replica map: {nranks} ranks with factor {c}");
    }
    let map = ReplicaMap::new(nranks, c);
    let n = r_u64(r)? as usize;
    if n != nranks {
        bail!("corrupt replicated schedule: {n} assigns for {nranks} ranks");
    }
    let mut assigns = bounded_vec::<RepAssign>(n, r.len());
    for _ in 0..n {
        let group = r_u64(r)? as usize;
        let member = r_u64(r)? as usize;
        let col_fetch = r_usizes(r, max)?;
        let row_recv = r_usizes(r, max)?;
        let touched = r_u32s(r, max)?;
        let b_sends = decode_rank_pairs(r, max)?;
        let c_sends = decode_rank_pairs(r, max)?;
        let red_from = r_usizes(r, max)?;
        let red_to = match r_u8(r)? {
            0 => None,
            1 => Some(r_u64(r)? as usize),
            t => bail!("bad red_to option tag {t}"),
        };
        assigns.push(RepAssign {
            group,
            member,
            col_fetch,
            row_recv,
            touched,
            b_sends,
            c_sends,
            red_from,
            red_to,
        });
    }
    Ok(RepSchedule { map, assigns })
}

// ----------------------------------------------------------- job codec ----

/// The request-invariant part of a worker's assignment: everything a
/// pooled worker caches between requests so that a repeat request against
/// the same planned `DistSpmm` ships only a [`JOB_MODE_DELTA`] payload.
/// Shared via `Arc` between the worker's cache slot and the in-flight
/// job.
struct JobBody {
    /// Physical rank count: for a replicated job this is `rep.map.nranks`
    /// while `part`/`plan`/`blocks` describe the group-level problem.
    nranks: usize,
    part: RowPartition,
    topo: Topology,
    plan: CommPlan,
    sched: Option<HierSchedule>,
    /// 1.5D replication schedule (v5). When present the worker runs
    /// `rank_main_rep` and the shipped [`Program`] is an unused
    /// placeholder — the replicated executor derives its steps from this
    /// schedule directly.
    rep: Option<RepSchedule>,
    blocks: LocalBlocks,
}

/// One worker's fully decoded assignment.
struct Job {
    rank: usize,
    op: KernelOp,
    opts: ExecOpts,
    body: Arc<JobBody>,
    prog: Program,
    b_local: Dense,
    x_local: Option<Dense>,
}

/// Placeholder program for replicated jobs: `rank_main_rep` takes its
/// step list from the [`RepSchedule`], never from a [`Program`], and
/// `build_program` cannot even be called there (the physical rank indexes
/// past the group-level plan). Shipping an empty one keeps the blob
/// layout uniform across flat and replicated jobs.
fn empty_program(op: KernelOp) -> Program {
    Program {
        op,
        b_posts: Vec::new(),
        x_posts: Vec::new(),
        items: Vec::new(),
        expect_msgs: 0,
        fold_keys: Vec::new(),
        agg_flows: Vec::new(),
        rep_b: Default::default(),
        rep_x: Default::default(),
        row_route: Default::default(),
    }
}

/// Serialize rank `rank`'s job. The program is derived here with the
/// *same* `build_program` call the thread executor makes (NativeKernel
/// prefers tiles), so both backends run literally the same step list.
/// `xsched` must be [`hierarchy::sddmm_fetch`] of `sched` exactly as in
/// [`super::run_kernel_with`] — present iff `sched` is and `op` needs X.
/// For a replicated job (`rep` present) `part`/`plan`/`blocks` are the
/// group-level problem, the blob's `nranks` is the physical count, and
/// the program is the unused [`empty_program`] placeholder.
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_job(
    rank: usize,
    op: KernelOp,
    opts: &ExecOpts,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    xsched: Option<&HierSchedule>,
    rep: Option<&RepSchedule>,
    blocks: &LocalBlocks,
    b_local: &Dense,
    x_local: Option<&Dense>,
) -> Result<Vec<u8>> {
    let nranks = rep.map_or(plan.nranks, |rs| rs.map.nranks);
    let prog = match rep {
        Some(_) => empty_program(op),
        None => super::build_program(rank, part, plan, sched, xsched, opts, true, op),
    };
    encode_job_parts(
        rank, nranks, op, opts, part, topo, plan, sched, rep, &prog, blocks, b_local, x_local,
    )
}

#[allow(clippy::too_many_arguments)]
fn encode_job_parts(
    rank: usize,
    nranks: usize,
    op: KernelOp,
    opts: &ExecOpts,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    rep: Option<&RepSchedule>,
    prog: &Program,
    blocks: &LocalBlocks,
    b_local: &Dense,
    x_local: Option<&Dense>,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(WIRE_MAGIC);
    w_u32(&mut out, WIRE_VERSION)?;
    w_u64(&mut out, rank as u64)?;
    w_u64(&mut out, nranks as u64)?;
    w_u8(&mut out, op_tag(op))?;
    w_u8(&mut out, u8::from(opts.overlap))?;
    w_u64(&mut out, opts.tile_rows as u64)?;
    w_u64(&mut out, opts.workers as u64)?;
    w_usizes(&mut out, &part.starts)?;
    encode_topo(&mut out, topo)?;
    encode_plan(&mut out, plan)?;
    match sched {
        None => w_u8(&mut out, 0)?,
        Some(s) => {
            w_u8(&mut out, 1)?;
            encode_sched(&mut out, s)?;
        }
    }
    match rep {
        None => w_u8(&mut out, 0)?,
        Some(rs) => {
            w_u8(&mut out, 1)?;
            encode_rep(&mut out, rs)?;
        }
    }
    encode_program(&mut out, prog)?;
    w_u64(&mut out, blocks.rank as u64)?;
    w_csr(&mut out, &blocks.diag)?;
    w_u64(&mut out, blocks.off_diag.len() as u64)?;
    for m in &blocks.off_diag {
        w_csr(&mut out, m)?;
    }
    w_dense(&mut out, b_local)?;
    match x_local {
        None => w_u8(&mut out, 0)?,
        Some(x) => {
            w_u8(&mut out, 1)?;
            w_dense(&mut out, x)?;
        }
    }
    Ok(out)
}

fn decode_job(buf: &[u8]) -> Result<Job> {
    // Every serialized element occupies ≥ 4 bytes, so no honest length
    // field can exceed this bound (the +1 admits empty lists in a tiny
    // buffer).
    let max = buf.len() / 4 + 1;
    let r = &mut &buf[..];
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != WIRE_MAGIC {
        bail!("bad job magic");
    }
    let version = r_u32(r)?;
    if version != WIRE_VERSION {
        bail!("wire version {version} != {WIRE_VERSION} (mismatched worker binary?)");
    }
    let rank = r_u64(r)? as usize;
    let nranks = r_u64(r)? as usize;
    let op = op_from_tag(r_u8(r)?)?;
    let opts = ExecOpts {
        overlap: r_u8(r)? != 0,
        tile_rows: r_u64(r)? as usize,
        workers: r_u64(r)? as usize,
    };
    let starts = r_usizes(r, max)?;
    if starts.len() < 2 || starts[0] != 0 || starts.windows(2).any(|w| w[0] > w[1]) {
        bail!("corrupt job: bad partition starts {starts:?}");
    }
    let part = RowPartition::from_starts(starts);
    let topo = decode_topo(r, max)?;
    let plan = decode_plan(r, max)?;
    let sched = match r_u8(r)? {
        0 => None,
        1 => Some(decode_sched(r, max)?),
        t => bail!("bad schedule option tag {t}"),
    };
    let rep = match r_u8(r)? {
        0 => None,
        1 => Some(decode_rep(r, max)?),
        t => bail!("bad replication option tag {t}"),
    };
    let prog = decode_program(r, max)?;
    let blocks_rank = r_u64(r)? as usize;
    let diag = r_csr(r, max)?;
    let n_off = r_u64(r)? as usize;
    if n_off > max {
        bail!("corrupt job: {n_off} off-diagonal blocks exceed available bytes");
    }
    let mut off_diag = bounded_vec::<crate::sparse::Csr>(n_off, r.len());
    for _ in 0..n_off {
        off_diag.push(r_csr(r, max)?);
    }
    let blocks = LocalBlocks { rank: blocks_rank, diag, off_diag };
    let b_local = r_dense(r, max)?;
    let x_local = match r_u8(r)? {
        0 => None,
        1 => Some(r_dense(r, max)?),
        t => bail!("bad X option tag {t}"),
    };
    match &rep {
        None => {
            if rank >= nranks
                || part.nparts != nranks
                || plan.nranks != nranks
                || blocks_rank != rank
            {
                bail!("inconsistent job: rank {rank}, nranks {nranks}, part {}", part.nparts);
            }
        }
        Some(rs) => {
            // Replicated job: the partition / plan / blocks are
            // group-level, the rank and nranks physical.
            if op != KernelOp::Spmm {
                bail!("replicated jobs are SpMM-only (got {op:?})");
            }
            if rank >= nranks
                || nranks != rs.map.nranks
                || part.nparts != rs.map.ngroups()
                || plan.nranks != rs.map.ngroups()
                || blocks_rank != rs.map.group_of(rank)
            {
                bail!(
                    "inconsistent replicated job: rank {rank}, nranks {nranks}, \
                     part {}, c {}",
                    part.nparts,
                    rs.map.c
                );
            }
        }
    }
    Ok(Job {
        rank,
        op,
        opts,
        body: Arc::new(JobBody { nranks, part, topo, plan, sched, rep, blocks }),
        prog,
        b_local,
        x_local,
    })
}

// ---------------------------------------------- delta JOBs (wire v4) ----

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical encoding of the request-invariant job core — hashed, never
/// shipped. Must cover everything a [`JobBody`] caches (the A blocks
/// included: two graphs can share partition starts), and nothing the
/// delta re-ships.
fn encode_job_core(
    rank: usize,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    rep: Option<&RepSchedule>,
    blocks: &LocalBlocks,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u64(&mut out, rank as u64)?;
    w_usizes(&mut out, &part.starts)?;
    encode_topo(&mut out, topo)?;
    encode_plan(&mut out, plan)?;
    match sched {
        None => w_u8(&mut out, 0)?,
        Some(s) => {
            w_u8(&mut out, 1)?;
            encode_sched(&mut out, s)?;
        }
    }
    match rep {
        None => w_u8(&mut out, 0)?,
        Some(rs) => {
            w_u8(&mut out, 1)?;
            encode_rep(&mut out, rs)?;
        }
    }
    w_u64(&mut out, blocks.rank as u64)?;
    w_csr(&mut out, &blocks.diag)?;
    w_u64(&mut out, blocks.off_diag.len() as u64)?;
    for m in &blocks.off_diag {
        w_csr(&mut out, m)?;
    }
    Ok(out)
}

/// Fingerprint of rank `rank`'s plan body: what the pool compares to
/// decide full-ship vs delta, and what a worker validates a delta
/// against. Includes the rank, so one fingerprint names exactly one
/// worker's body.
pub(crate) fn job_fingerprint(
    rank: usize,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    rep: Option<&RepSchedule>,
    blocks: &LocalBlocks,
) -> u64 {
    fnv1a(&encode_job_core(rank, part, topo, plan, sched, rep, blocks).expect("vec write"))
}

/// Serialize the per-request part of rank `rank`'s job: kernel op,
/// scheduling options, operands. Everything else is the cached body the
/// header's fingerprint names; the worker re-derives the frozen program
/// with the same pure `build_program` call the parent's full-ship path
/// makes, so a delta-shipped job runs a bitwise-identical step list.
pub(crate) fn encode_job_delta(
    rank: usize,
    op: KernelOp,
    opts: &ExecOpts,
    b_local: &Dense,
    x_local: Option<&Dense>,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(WIRE_MAGIC);
    w_u32(&mut out, WIRE_VERSION)?;
    w_u64(&mut out, rank as u64)?;
    w_u8(&mut out, op_tag(op))?;
    w_u8(&mut out, u8::from(opts.overlap))?;
    w_u64(&mut out, opts.tile_rows as u64)?;
    w_u64(&mut out, opts.workers as u64)?;
    w_dense(&mut out, b_local)?;
    match x_local {
        None => w_u8(&mut out, 0)?,
        Some(x) => {
            w_u8(&mut out, 1)?;
            w_dense(&mut out, x)?;
        }
    }
    Ok(out)
}

fn decode_job_delta(buf: &[u8]) -> Result<(usize, KernelOp, ExecOpts, Dense, Option<Dense>)> {
    let max = buf.len() / 4 + 1;
    let r = &mut &buf[..];
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != WIRE_MAGIC {
        bail!("bad job magic");
    }
    let version = r_u32(r)?;
    if version != WIRE_VERSION {
        bail!("wire version {version} != {WIRE_VERSION} (mismatched worker binary?)");
    }
    let rank = r_u64(r)? as usize;
    let op = op_from_tag(r_u8(r)?)?;
    let opts = ExecOpts {
        overlap: r_u8(r)? != 0,
        tile_rows: r_u64(r)? as usize,
        workers: r_u64(r)? as usize,
    };
    let b_local = r_dense(r, max)?;
    let x_local = match r_u8(r)? {
        0 => None,
        1 => Some(r_dense(r, max)?),
        t => bail!("bad X option tag {t}"),
    };
    Ok((rank, op, opts, b_local, x_local))
}

/// Materialize a delta JOB against the cached body. The X fetch schedule
/// and the frozen program are re-derived exactly as [`encode_job`] does
/// for a full ship — both are pure functions of the body and the delta's
/// (op, opts) — so parent-shipped and worker-rebuilt programs are
/// identical.
fn apply_job_delta(body: &Arc<JobBody>, buf: &[u8]) -> Result<Job> {
    let (rank, op, opts, b_local, x_local) = decode_job_delta(buf)?;
    if let Some(rs) = &body.rep {
        // Replicated body: the cached blocks belong to the whole group, so
        // the identity check is group membership, not blocks.rank.
        if op != KernelOp::Spmm {
            bail!("replicated jobs are SpMM-only (got {op:?})");
        }
        if rank >= rs.map.nranks || rs.map.group_of(rank) != body.blocks.rank {
            bail!(
                "delta JOB for rank {rank} against a cached replicated body for group {}",
                body.blocks.rank
            );
        }
        let prog = empty_program(op);
        return Ok(Job { rank, op, opts, body: Arc::clone(body), prog, b_local, x_local });
    }
    if rank != body.blocks.rank {
        bail!("delta JOB for rank {rank} against a cached body for rank {}", body.blocks.rank);
    }
    let xsched = (op != KernelOp::Spmm)
        .then(|| body.sched.as_ref().map(hierarchy::sddmm_fetch))
        .flatten();
    let prog = super::build_program(
        rank,
        &body.part,
        &body.plan,
        body.sched.as_ref(),
        xsched.as_ref(),
        &opts,
        true,
        op,
    );
    Ok(Job { rank, op, opts, body: Arc::clone(body), prog, b_local, x_local })
}

// --------------------------------------------------- control messages ----

fn rank_payload(rank: usize) -> Vec<u8> {
    let mut out = Vec::new();
    w_u64(&mut out, rank as u64).expect("vec write");
    out
}

fn encode_hello(rank: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u32(&mut out, WIRE_VERSION)?;
    w_u64(&mut out, rank as u64)?;
    Ok(out)
}

pub(crate) fn decode_hello(buf: &[u8]) -> Result<(u32, usize)> {
    let r = &mut &buf[..];
    Ok((r_u32(r)?, r_u64(r)? as usize))
}

fn encode_done(
    epoch: u64,
    rank: usize,
    c: &Dense,
    vals: Option<&SddmmVals>,
    st: &RankStats,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u64(&mut out, epoch)?;
    w_u64(&mut out, rank as u64)?;
    w_dense(&mut out, c)?;
    for v in [
        st.intra_bytes_sent,
        st.inter_bytes_sent,
        st.intra_bytes_recv,
        st.inter_bytes_recv,
        st.msgs_sent,
        st.msgs_recv,
    ] {
        w_u64(&mut out, v)?;
    }
    w_u64s(&mut out, &st.sent_to)?;
    w_u64s(&mut out, &st.sent_b_to)?;
    w_f64(&mut out, st.compute_secs)?;
    w_f64(&mut out, st.idle_secs)?;
    w_u64(&mut out, st.overlapped_recv_bytes)?;
    w_u64(&mut out, st.idle_recv_bytes)?;
    // Phase spans stay worker-local: their labels are `&'static str`s and
    // the chrome-trace export is a thread-backend diagnostic.
    match vals {
        None => w_u8(&mut out, 0)?,
        Some(v) => {
            w_u8(&mut out, 1)?;
            w_dense(&mut out, &v.diag)?;
            for map in [&v.col, &v.row] {
                w_u64(&mut out, map.len() as u64)?;
                for (&peer, d) in map {
                    w_u64(&mut out, peer as u64)?;
                    w_dense(&mut out, d)?;
                }
            }
        }
    }
    Ok(out)
}

pub(crate) fn decode_done(buf: &[u8]) -> Result<(u64, usize, Dense, SddmmVals, RankStats)> {
    let max = buf.len() / 4 + 1;
    let r = &mut &buf[..];
    let epoch = r_u64(r)?;
    let rank = r_u64(r)? as usize;
    let c = r_dense(r, max)?;
    let st = RankStats {
        intra_bytes_sent: r_u64(r)?,
        inter_bytes_sent: r_u64(r)?,
        intra_bytes_recv: r_u64(r)?,
        inter_bytes_recv: r_u64(r)?,
        msgs_sent: r_u64(r)?,
        msgs_recv: r_u64(r)?,
        sent_to: r_u64s(r, max)?,
        sent_b_to: r_u64s(r, max)?,
        compute_secs: r_f64(r)?,
        idle_secs: r_f64(r)?,
        overlapped_recv_bytes: r_u64(r)?,
        idle_recv_bytes: r_u64(r)?,
        phases: Vec::new(),
    };
    let mut vals = SddmmVals::default();
    if r_u8(r)? == 1 {
        vals.diag = r_dense(r, max)?;
        for map_is_col in [true, false] {
            let len = r_u64(r)? as usize;
            if len > max {
                bail!("SDDMM value map claims {len} entries");
            }
            for _ in 0..len {
                let peer = r_u64(r)? as usize;
                let d = r_dense(r, max)?;
                if map_is_col {
                    vals.col.insert(peer, d);
                } else {
                    vals.row.insert(peer, d);
                }
            }
        }
    }
    Ok((epoch, rank, c, vals, st))
}

fn encode_error(epoch: u64, rank: usize, msg: &str) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u64(&mut out, epoch)?;
    w_u64(&mut out, rank as u64)?;
    w_str(&mut out, msg)?;
    Ok(out)
}

pub(crate) fn decode_error(buf: &[u8]) -> Result<(u64, usize, String)> {
    let r = &mut &buf[..];
    let epoch = r_u64(r)?;
    let rank = r_u64(r)? as usize;
    let msg = r_str(r, buf.len())?;
    Ok((epoch, rank, msg))
}

// --------------------------------------------------------- worker side ----

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "rank panicked (non-string payload)".to_string()
    }
}

/// Worker-process entry point: connect, HELLO, then serve epoch-tagged
/// JOB frames until the control plane closes the socket. Never returns.
pub(crate) fn worker_main(port: u16, rank: usize) -> ! {
    let code = match worker_run(port, rank) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("shiro worker rank {rank}: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// The worker's main loop owns the socket's read half and multiplexes
/// three frame kinds across jobs (and, pooled, across whole requests):
///
/// - JOB: the v4 header names a pool generation, an epoch, a full or
///   delta body, the plan-body fingerprint, and an optional crash phase.
///   A full body replaces the worker's cached [`JobBody`]; a delta is
///   applied against the cache iff the fingerprints match (else the
///   worker answers with an ERROR and stays alive — the parent falls
///   back to a full ship). Each accepted JOB spawns a job thread running
///   the shared `rank_main` with a fresh inbox; the job's own `rank`
///   field is authoritative (after a recovery replan the parent
///   renumbers survivors, and a re-admitted pool slot may serve a
///   different rank than it was spawned with).
/// - DATA: forwarded into the inbox iff its epoch matches the in-flight
///   job; stale frames from an aborted step are dropped.
/// - ABORT(epoch): drop the matching job's inbox sender — a `recv`
///   blocked in `rank_main` panics ("inbox closed"), the job thread
///   catches it and reports an ERROR tagged with its stale epoch, which
///   the parent discards.
///
/// Socket EOF is the clean shutdown signal. One buffered reader serves
/// every frame — a second reader over the raw stream would lose whatever
/// bytes this BufReader has already pulled past a frame boundary.
fn worker_run(port: u16, rank: usize) -> Result<()> {
    let stream =
        TcpStream::connect(("127.0.0.1", port)).context("connect to control plane")?;
    stream.set_nodelay(true).ok();
    let tx = Arc::new(SocketTx::new(stream.try_clone().context("clone control socket")?));
    tx.frame(kind::HELLO, &encode_hello(rank)?)?;

    // Liveness is a property of the worker process, not of any one
    // epoch's job: one heartbeat thread spans the whole lifetime.
    let stop = Arc::new(AtomicBool::new(false));
    let beat = {
        let tx = Arc::clone(&tx);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let payload = rank_payload(rank);
            while !stop.load(Ordering::Relaxed) {
                if tx.frame(kind::BEAT, &payload).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(BEAT_MILLIS));
            }
        })
    };

    let mut reader = BufReader::new(stream);
    // The in-flight job: its epoch and the sender feeding its inbox.
    let mut current: Option<(u64, mpsc::Sender<Msg>)> = None;
    // Pool protocol state: the highest generation seen, and the cached
    // request-invariant body (with its fingerprint) a delta JOB can be
    // applied to.
    let mut generation: u64 = 0;
    let mut cached: Option<(u64, Arc<JobBody>)> = None;
    loop {
        let (k, payload) = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => break, // parent closed the socket: clean shutdown
        };
        match k {
            kind::JOB => {
                let h = decode_job_header(&payload)?;
                if h.generation < generation {
                    // Cannot happen over one ordered stream; treat as
                    // corruption, report, and stay alive.
                    let msg = format!(
                        "JOB generation {} regressed below {generation}",
                        h.generation
                    );
                    let _ = tx.frame(kind::ERROR, &encode_error(h.epoch, rank, &msg)?);
                    continue;
                }
                generation = h.generation;
                let body_buf = &payload[JOB_HEADER..];
                let decoded = if h.mode == JOB_MODE_FULL {
                    decode_job(body_buf).map(|job| {
                        cached = Some((h.fp, Arc::clone(&job.body)));
                        job
                    })
                } else {
                    match &cached {
                        Some((fp, body)) if *fp == h.fp => apply_job_delta(body, body_buf),
                        _ => Err(anyhow!(
                            "delta JOB against unknown plan fingerprint {:#018x}",
                            h.fp
                        )),
                    }
                };
                let job = match decoded {
                    Ok(j) => j,
                    Err(e) => {
                        let msg = format!("bad job: {e:#}");
                        let _ = tx.frame(kind::ERROR, &encode_error(h.epoch, rank, &msg)?);
                        continue;
                    }
                };
                // Per-JOB fault injection: arm (or disarm) the crash for
                // exactly this job — a pooled worker must not stay armed
                // into the next request.
                let crash = h.crash;
                if crash == Some(CrashPhase::PostDecode) {
                    std::process::abort();
                }
                // A JOB while one is in flight shouldn't happen (the
                // parent aborts first), but dropping the old sender makes
                // it converge to the same aborted state either way.
                drop(current.take());
                let (msg_tx, msg_rx) = mpsc::channel::<Msg>();
                current = Some((h.epoch, msg_tx));
                let jtx = Arc::clone(&tx);
                std::thread::spawn(move || run_job(h.epoch, job, jtx, msg_rx, crash));
            }
            kind::DATA => {
                if payload.len() < DATA_HEADER {
                    bail!("DATA frame too short for routing header");
                }
                let (_dst, epoch) = decode_data_header(&payload)?;
                let intact = match &current {
                    // Stale frames from an aborted step are dropped; a
                    // send error just means the job thread already
                    // finished.
                    Some((cur, msg_tx)) if *cur == epoch => {
                        let r = &mut &payload[DATA_HEADER..];
                        match decode_msg(r, payload.len() / 4 + 1) {
                            Ok(m) => {
                                let _ = msg_tx.send(m);
                                true
                            }
                            Err(_) => false,
                        }
                    }
                    _ => true,
                };
                if !intact {
                    // Corrupt message: poison the in-flight job so its
                    // blocked recv panics and surfaces a current-epoch
                    // ERROR instead of hanging on a frame that never
                    // arrives.
                    drop(current.take());
                }
            }
            kind::ABORT => {
                let epoch = decode_epoch(&payload)?;
                if matches!(&current, Some((cur, _)) if *cur == epoch) {
                    drop(current.take());
                }
            }
            _ => {} // unknown kinds are ignored (same binary: can't happen)
        }
    }
    stop.store(true, Ordering::Relaxed);
    let _ = beat.join();
    // Job threads are detached; they die with the process.
    Ok(())
}

/// One epoch's job, on its own thread so the main loop keeps draining
/// frames (DATA for this job, ABORT against it, the next epoch's JOB).
fn run_job(
    epoch: u64,
    job: Job,
    tx: Arc<SocketTx>,
    inbox: mpsc::Receiver<Msg>,
    crash: Option<CrashPhase>,
) {
    let rank = job.rank;
    let nranks = job.body.nranks;
    let etx = EpochTx::new(Arc::clone(&tx), epoch, crash == Some(CrashPhase::MidExchange));
    let result = catch_unwind(AssertUnwindSafe(|| {
        // Re-derive the X fetch schedule exactly as `run_kernel_with`
        // does — it is a pure function of the shipped schedule.
        let xsched = (job.op != KernelOp::Spmm)
            .then(|| job.body.sched.as_ref().map(hierarchy::sddmm_fetch))
            .flatten();
        let kernel = NativeKernel;
        let mut ctx = Ctx {
            rank,
            part: &job.body.part,
            plan: &job.body.plan,
            sched: job.body.sched.as_ref(),
            xsched: xsched.as_ref(),
            topo: &job.body.topo,
            kernel: &kernel,
            outbox: Outbox::Socket(&etx),
            inbox,
            stats: RankStats {
                sent_to: vec![0; nranks],
                sent_b_to: vec![0; nranks],
                ..RankStats::default()
            },
            opts: job.opts,
            gate: None,
            t0: Instant::now(),
            pool: PoolRef::Own(BufferPool::new()),
        };
        if let Some(rsched) = &job.body.rep {
            // Replicated job (v5): the schedule drives the step list, the
            // shipped program is a placeholder. decode_job already pinned
            // op == Spmm and blocks.rank == this rank's group.
            let map = rsched.map;
            let is_home = map.member_of(rank) == 0;
            let glen = job.body.part.len(map.group_of(rank));
            let mut c_local =
                Dense::zeros(if is_home { glen } else { 0 }, job.b_local.ncols);
            super::replicate::rank_main_rep(
                &mut ctx,
                rsched,
                &job.body.blocks,
                &job.b_local,
                &mut c_local,
            );
            return (c_local, SddmmVals::default(), ctx.stats);
        }
        let c_width = if job.op == KernelOp::Sddmm { 0 } else { job.b_local.ncols };
        let mut c_local = Dense::zeros(job.body.part.len(rank), c_width);
        let mut vals = SddmmVals::default();
        rank_main(
            &mut ctx,
            &job.body.blocks,
            job.x_local.as_ref(),
            &job.b_local,
            &mut c_local,
            &mut vals,
            &job.prog,
        );
        (c_local, vals, ctx.stats)
    }));

    match result {
        Ok((c_local, vals, stats)) => {
            // A still-armed crash fires here: PreDone by definition, or
            // MidExchange when the program had nothing to send.
            if crash.is_some() {
                std::process::abort();
            }
            // The fused kernel also leaves edge values in `vals`, but its
            // output is the dense C — only SDDMM ships them back.
            let vals = (job.op == KernelOp::Sddmm).then_some(&vals);
            let payload = encode_done(epoch, rank, &c_local, vals, &stats)
                .expect("vec write");
            // Write failure means the parent is gone; the main loop's EOF
            // will end the process.
            let _ = tx.frame(kind::DONE, &payload);
        }
        Err(p) => {
            let msg = panic_message(p.as_ref());
            if let Ok(payload) = encode_error(epoch, rank, &msg) {
                let _ = tx.frame(kind::ERROR, &payload);
            }
        }
    }
}

// --------------------------------------------------------------- tests ----

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::partition::split_1d;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind::DATA, &[1, 2, 3]).unwrap();
        write_frame(&mut buf, kind::BEAT, &[]).unwrap();
        let r = &mut &buf[..];
        assert_eq!(read_frame(r).unwrap(), (kind::DATA, vec![1, 2, 3]));
        assert_eq!(read_frame(r).unwrap(), (kind::BEAT, vec![]));
        assert!(r.is_empty());
        // A zero length word is rejected (kind byte is always counted).
        let bad = 0u32.to_le_bytes();
        assert!(read_frame(&mut &bad[..]).is_err());
    }

    /// Decode-then-re-encode must reproduce the exact bytes; avoids
    /// needing PartialEq on the executor's private message type.
    fn msg_roundtrips(m: &Msg) {
        let mut buf = Vec::new();
        encode_msg(&mut buf, m).unwrap();
        let r = &mut &buf[..];
        let back = decode_msg(r, buf.len() / 4 + 1).unwrap();
        assert!(r.is_empty());
        let mut buf2 = Vec::new();
        encode_msg(&mut buf2, &back).unwrap();
        assert_eq!(buf, buf2);
    }

    #[test]
    fn msg_roundtrip_all_variants() {
        // NaN and -0.0 payloads must survive bitwise (float bits travel
        // raw), or the proc backend could not be a bitwise oracle match.
        let d = Dense::from_vec(2, 2, vec![1.5, f32::NAN, -0.0, 7.25]);
        msg_roundtrips(&Msg::B { from: 3, origin: 1, rows: vec![0, 5], data: d.clone() });
        msg_roundtrips(&Msg::X { from: 0, origin: 2, rows: vec![9], data: d.clone() });
        msg_roundtrips(&Msg::C { from: 7, rows: vec![], data: Dense::zeros(0, 4) });
        msg_roundtrips(&Msg::CAgg { from: 2, final_dst: 6, rows: vec![1, 2, 3], data: d.clone() });
        msg_roundtrips(&Msg::CRed { from: 5, rows: vec![0, 2, 7], data: d });
    }

    #[test]
    fn phase_table_roundtrips() {
        for (i, &name) in PHASES.iter().enumerate() {
            assert_eq!(phase_tag(name).unwrap(), i as u8);
            assert_eq!(phase_name(i as u8).unwrap(), name);
        }
        assert!(phase_name(PHASES.len() as u8).is_err());
        assert!(phase_tag("no such phase").is_err());
    }

    #[test]
    fn done_roundtrip() {
        let c = Dense::from_fn(3, 2, |i, j| (i + j) as f32 - 1.5);
        let st = RankStats {
            intra_bytes_sent: 10,
            inter_bytes_sent: 20,
            intra_bytes_recv: 30,
            inter_bytes_recv: 40,
            msgs_sent: 5,
            msgs_recv: 6,
            sent_to: vec![1, 2, 3],
            sent_b_to: vec![1, 0, 3],
            compute_secs: 0.25,
            idle_secs: 0.125,
            overlapped_recv_bytes: 7,
            idle_recv_bytes: 8,
            phases: Vec::new(),
        };
        let buf = encode_done(9, 2, &c, None, &st).unwrap();
        let (epoch, rank, c2, vals2, st2) = decode_done(&buf).unwrap();
        assert_eq!((epoch, rank), (9, 2));
        assert_eq!(c2, c);
        assert_eq!(vals2.diag.data, Vec::<f32>::new());
        assert!(vals2.col.is_empty() && vals2.row.is_empty());
        assert_eq!(st2.sent_to, st.sent_to);
        assert_eq!(st2.msgs_recv, 6);
        assert_eq!(st2.compute_secs, 0.25);

        // SDDMM DONE frames carry the edge values bitwise (NaN included).
        let mut vals = SddmmVals::default();
        vals.diag = Dense::from_vec(1, 3, vec![1.0, f32::NAN, -0.0]);
        vals.col.insert(3, Dense::from_vec(1, 2, vec![2.5, -7.0]));
        vals.row.insert(0, Dense::from_vec(1, 1, vec![0.125]));
        vals.row.insert(5, Dense::zeros(0, 0));
        let buf = encode_done(0, 1, &Dense::zeros(2, 0), Some(&vals), &st).unwrap();
        let (epoch, rank, c2, vals2, _) = decode_done(&buf).unwrap();
        assert_eq!((epoch, rank, c2.nrows, c2.ncols), (0, 1, 2, 0));
        assert_eq!(vals2.diag.data.len(), 3);
        assert_eq!(vals2.diag.data[0].to_bits(), 1.0f32.to_bits());
        assert!(vals2.diag.data[1].is_nan());
        assert_eq!(vals2.diag.data[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(vals2.col[&3].data, vec![2.5, -7.0]);
        assert_eq!(vals2.row[&0].data, vec![0.125]);
        assert_eq!(vals2.row[&5], Dense::zeros(0, 0));
    }

    #[test]
    fn hello_and_error_roundtrip() {
        let (v, rank) = decode_hello(&encode_hello(11).unwrap()).unwrap();
        assert_eq!((v, rank), (WIRE_VERSION, 11));
        let (epoch, rank, msg) =
            decode_error(&encode_error(4, 3, "inbox closed").unwrap()).unwrap();
        assert_eq!((epoch, rank, msg.as_str()), (4, 3, "inbox closed"));
    }

    #[test]
    fn epoch_and_data_header_roundtrip() {
        // ABORT / JOB-prefix payloads.
        assert_eq!(decode_epoch(&epoch_payload(0)).unwrap(), 0);
        assert_eq!(decode_epoch(&epoch_payload(u64::MAX)).unwrap(), u64::MAX);
        assert!(decode_epoch(&[1, 2, 3]).is_err());
        // DATA routing headers: what EpochTx::send writes is what
        // decode_data_header reads, and the Msg body follows intact.
        let tx_payload = {
            let mut p = Vec::new();
            w_u64(&mut p, 5).unwrap();
            w_u64(&mut p, 7).unwrap();
            encode_msg(
                &mut p,
                &Msg::C { from: 2, rows: vec![4], data: Dense::from_vec(1, 1, vec![2.5]) },
            )
            .unwrap();
            p
        };
        let (dst, epoch) = decode_data_header(&tx_payload).unwrap();
        assert_eq!((dst, epoch), (5, 7));
        let body = &mut &tx_payload[DATA_HEADER..];
        let m = decode_msg(body, tx_payload.len() / 4 + 1).unwrap();
        assert!(matches!(m, Msg::C { from: 2, .. }));
        assert!(decode_data_header(&tx_payload[..12]).is_err());
    }

    /// Full job blobs over real plans re-encode byte-identically after a
    /// decode, for every kernel op and both flat and hierarchical routing
    /// — the program, plan, schedule, and operand codecs are all exact.
    #[test]
    fn job_roundtrips_byte_identical() {
        let a = gen::rmat(96, 900, (0.55, 0.2, 0.19), false, 11);
        let ranks = 4;
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        let sched = hierarchy::build(&plan, &topo);
        let xsched = hierarchy::sddmm_fetch(&sched);
        let mut rng = Rng::new(7);
        let b_full = Dense::random(a.nrows, 8, &mut rng);
        let x_full = Dense::random(a.nrows, 8, &mut rng);
        for op in [KernelOp::Spmm, KernelOp::FusedSddmmSpmm] {
            for use_sched in [false, true] {
                for rank in 0..ranks {
                    let (r0, r1) = part.range(rank);
                    let n = b_full.ncols;
                    let b_local =
                        Dense::from_vec(r1 - r0, n, b_full.data[r0 * n..r1 * n].to_vec());
                    let x_local = (op != KernelOp::Spmm).then(|| {
                        Dense::from_vec(r1 - r0, n, x_full.data[r0 * n..r1 * n].to_vec())
                    });
                    let (s, xs) = if use_sched {
                        (
                            Some(&sched),
                            (op != KernelOp::Spmm).then_some(&xsched),
                        )
                    } else {
                        (None, None)
                    };
                    let bytes = encode_job(
                        rank,
                        op,
                        &ExecOpts::default(),
                        &part,
                        &topo,
                        &plan,
                        s,
                        xs,
                        None,
                        &blocks[rank],
                        &b_local,
                        x_local.as_ref(),
                    )
                    .unwrap();
                    let job = decode_job(&bytes).unwrap();
                    let again = encode_job_parts(
                        job.rank,
                        job.body.nranks,
                        job.op,
                        &job.opts,
                        &job.body.part,
                        &job.body.topo,
                        &job.body.plan,
                        job.body.sched.as_ref(),
                        job.body.rep.as_ref(),
                        &job.prog,
                        &job.body.blocks,
                        &job.b_local,
                        job.x_local.as_ref(),
                    )
                    .unwrap();
                    assert_eq!(bytes, again, "op {op:?} sched {use_sched} rank {rank}");
                }
            }
        }
    }

    /// Satellite of the pool protocol: the frame-length prefix is checked
    /// structurally at the boundary, without allocating gigabyte buffers.
    #[test]
    fn frame_length_boundary() {
        // Largest representable payload: len = payload + kind byte hits
        // MAX_FRAME exactly.
        assert_eq!(frame_len(0).unwrap(), 1);
        assert_eq!(frame_len(MAX_FRAME - 1).unwrap(), MAX_FRAME as u32);
        // One byte over (and the usize extremes) are structured errors,
        // not wrapped prefixes.
        for n in [MAX_FRAME, MAX_FRAME + 1, u32::MAX as usize, usize::MAX] {
            let err = frame_len(n).unwrap_err().to_string();
            assert!(err.contains("exceeds MAX_FRAME"), "{err}");
        }
    }

    /// A corrupt count can pass the element-count bound yet demand a
    /// multi-word allocation far beyond the frame; the reserved capacity
    /// is clamped by the bytes that are actually left.
    #[test]
    fn decode_preallocation_is_clamped() {
        let v = bounded_vec::<u64>(1 << 30, 64);
        assert!(v.capacity() <= 9, "capacity {} not clamped", v.capacity());
        let v = bounded_vec::<[u8; 64]>(1000, 128);
        assert!(v.capacity() <= 3, "capacity {} not clamped", v.capacity());
        // Zero-remaining still admits a probe element, never panics.
        assert!(bounded_vec::<u64>(5, 0).capacity() <= 1);

        // End-to-end: a posts buffer claiming a huge-but-in-bound count
        // over a tiny body fails cleanly in decode.
        let mut buf = Vec::new();
        w_u64(&mut buf, 40).unwrap(); // claims 40 posts...
        w_u64(&mut buf, 0).unwrap(); // ...but bytes for ~one
        w_u8(&mut buf, 0).unwrap();
        let max = buf.len() / 4 + 1;
        assert!(decode_posts(&mut &buf[..], max).is_err());
    }

    #[test]
    fn job_header_roundtrip() {
        let mut crashes = vec![None];
        crashes.extend(CrashPhase::ALL.map(Some));
        for (i, crash) in crashes.into_iter().enumerate() {
            let h = JobHeader {
                generation: 7 + i as u64,
                epoch: 40 + i as u64,
                mode: if i % 2 == 0 { JOB_MODE_FULL } else { JOB_MODE_DELTA },
                crash,
                fp: 0xdead_beef_0bad_f00d ^ i as u64,
            };
            let buf = encode_job_header(&h);
            assert_eq!(buf.len(), JOB_HEADER);
            let back = decode_job_header(&buf).unwrap();
            assert_eq!(back.generation, h.generation);
            assert_eq!(back.epoch, h.epoch);
            assert_eq!(back.mode, h.mode);
            assert_eq!(back.crash, h.crash);
            assert_eq!(back.fp, h.fp);
        }
        // Truncated header / unknown mode / unknown crash byte all fail
        // structurally.
        let good = encode_job_header(&JobHeader {
            generation: 1,
            epoch: 2,
            mode: JOB_MODE_FULL,
            crash: None,
            fp: 3,
        });
        assert!(decode_job_header(&good[..JOB_HEADER - 1]).is_err());
        let mut bad = good.clone();
        bad[16] = 9; // mode byte
        assert!(decode_job_header(&bad).is_err());
        let mut bad = good.clone();
        bad[17] = CrashPhase::ALL.len() as u8 + 1; // crash byte
        assert!(decode_job_header(&bad).is_err());
    }

    /// The pool's delta path must reconstruct byte-for-byte what a full
    /// ship would have sent: same decoded body, and a worker-rebuilt
    /// program identical to the parent-shipped one.
    #[test]
    fn delta_job_rebuilds_the_full_program() {
        let a = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 21);
        let ranks = 4;
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        let sched = hierarchy::build(&plan, &topo);
        let xsched = hierarchy::sddmm_fetch(&sched);
        let mut rng = Rng::new(17);
        let b_full = Dense::random(a.nrows, 6, &mut rng);
        let x_full = Dense::random(a.nrows, 6, &mut rng);
        for op in [KernelOp::Spmm, KernelOp::Sddmm, KernelOp::FusedSddmmSpmm] {
            for rank in 0..ranks {
                let (r0, r1) = part.range(rank);
                let n = b_full.ncols;
                let b_local =
                    Dense::from_vec(r1 - r0, n, b_full.data[r0 * n..r1 * n].to_vec());
                let x_local = (op != KernelOp::Spmm).then(|| {
                    Dense::from_vec(r1 - r0, n, x_full.data[r0 * n..r1 * n].to_vec())
                });
                let xs = (op != KernelOp::Spmm).then_some(&xsched);
                // Full ship establishes the cached body.
                let full = encode_job(
                    rank,
                    op,
                    &ExecOpts::default(),
                    &part,
                    &topo,
                    &plan,
                    Some(&sched),
                    xs,
                    None,
                    &blocks[rank],
                    &b_local,
                    x_local.as_ref(),
                )
                .unwrap();
                let full_job = decode_job(&full).unwrap();
                // Delta against it, as a warm pool would send.
                let delta = encode_job_delta(
                    rank,
                    op,
                    &ExecOpts::default(),
                    &b_local,
                    x_local.as_ref(),
                )
                .unwrap();
                let delta_job = apply_job_delta(&full_job.body, &delta).unwrap();
                let enc = |p: &Program| {
                    let mut out = Vec::new();
                    encode_program(&mut out, p).unwrap();
                    out
                };
                assert_eq!(
                    enc(&full_job.prog),
                    enc(&delta_job.prog),
                    "op {op:?} rank {rank}: delta-rebuilt program differs"
                );
                assert_eq!(delta_job.b_local, full_job.b_local);
                assert_eq!(delta_job.x_local, full_job.x_local);
                // Wrong rank against the cached body is rejected.
                let other = encode_job_delta(
                    (rank + 1) % ranks,
                    op,
                    &ExecOpts::default(),
                    &b_local,
                    x_local.as_ref(),
                )
                .unwrap();
                assert!(apply_job_delta(&full_job.body, &other).is_err());
            }
        }
    }

    /// The fingerprint keys the delta decision: stable for an identical
    /// body, different per rank and per graph (the A blocks are hashed,
    /// not just the partition shape).
    #[test]
    fn job_fingerprint_separates_bodies() {
        let a = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 21);
        let a2 = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 22);
        let ranks = 4;
        let part = RowPartition::balanced(a.nrows, ranks);
        let (blocks, blocks2) = (split_1d(&a, &part), split_1d(&a2, &part));
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let plan2 = comm::plan(&blocks2, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        let fp = |r: usize| job_fingerprint(r, &part, &topo, &plan, None, None, &blocks[r]);
        assert_eq!(fp(0), fp(0), "fingerprint must be deterministic");
        assert_ne!(fp(0), fp(1), "distinct ranks must fingerprint apart");
        // Same partition starts, different graph content.
        assert_ne!(
            fp(0),
            job_fingerprint(0, &part, &topo, &plan2, None, None, &blocks2[0]),
            "different A under identical starts must fingerprint apart"
        );
    }

    /// Replicated (v5) jobs roundtrip byte-identically: the rep section,
    /// the group-level plan body, and the physical rank/nranks split all
    /// survive a decode; deltas apply against the cached replicated body;
    /// and the fingerprint separates replicated from flat bodies.
    #[test]
    fn replicated_job_roundtrips_byte_identical() {
        let a = gen::rmat(64, 500, (0.55, 0.2, 0.19), false, 9);
        let (nranks, c) = (4, 2);
        let part = RowPartition::balanced(a.nrows, nranks);
        let gpart = part.coarsen(c);
        let gblocks = split_1d(&a, &gpart);
        let gplan = comm::plan(&gblocks, &gpart, Strategy::Joint(Solver::Koenig), None);
        let map = crate::topology::ReplicaMap::new(nranks, c);
        let rsched = hierarchy::build_replicated(&gplan, &map);
        let topo = Topology::tsubame4(nranks);
        let mut rng = Rng::new(13);
        let b_full = Dense::random(a.nrows, 8, &mut rng);
        let n = b_full.ncols;
        for rank in 0..nranks {
            let g = map.group_of(rank);
            let (r0, r1) = gpart.range(g);
            // Only homes carry B rows, exactly as the thread path slices.
            let b_local = if map.member_of(rank) == 0 {
                Dense::from_vec(r1 - r0, n, b_full.data[r0 * n..r1 * n].to_vec())
            } else {
                Dense::zeros(0, n)
            };
            let bytes = encode_job(
                rank,
                KernelOp::Spmm,
                &ExecOpts::default(),
                &gpart,
                &topo,
                &gplan,
                None,
                None,
                Some(&rsched),
                &gblocks[g],
                &b_local,
                None,
            )
            .unwrap();
            let job = decode_job(&bytes).unwrap();
            assert_eq!(job.body.nranks, nranks, "nranks must stay physical");
            assert_eq!(job.body.rep.as_ref(), Some(&rsched));
            assert_eq!(job.body.blocks.rank, g);
            let again = encode_job_parts(
                job.rank,
                job.body.nranks,
                job.op,
                &job.opts,
                &job.body.part,
                &job.body.topo,
                &job.body.plan,
                job.body.sched.as_ref(),
                job.body.rep.as_ref(),
                &job.prog,
                &job.body.blocks,
                &job.b_local,
                job.x_local.as_ref(),
            )
            .unwrap();
            assert_eq!(bytes, again, "rank {rank}");

            // Deltas apply against the cached replicated body and keep
            // the placeholder program empty.
            let delta =
                encode_job_delta(rank, KernelOp::Spmm, &ExecOpts::default(), &b_local, None)
                    .unwrap();
            let dj = apply_job_delta(&job.body, &delta).unwrap();
            assert!(dj.prog.items.is_empty() && dj.prog.expect_msgs == 0);
            assert_eq!(dj.b_local, job.b_local);
            // An SDDMM delta against a replicated body is rejected.
            let bad =
                encode_job_delta(rank, KernelOp::Sddmm, &ExecOpts::default(), &b_local, None)
                    .unwrap();
            assert!(apply_job_delta(&job.body, &bad).is_err());

            // The schedule is part of the fingerprinted core.
            assert_ne!(
                job_fingerprint(rank, &gpart, &topo, &gplan, None, Some(&rsched), &gblocks[g]),
                job_fingerprint(rank, &gpart, &topo, &gplan, None, None, &gblocks[g]),
                "replicated and flat bodies must fingerprint apart"
            );
        }
    }

    #[test]
    fn job_rejects_corruption() {
        let a = gen::rmat(32, 200, (0.55, 0.2, 0.19), false, 5);
        let part = RowPartition::balanced(a.nrows, 2);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Column, None);
        let topo = Topology::tsubame4(2);
        let b = Dense::zeros(part.len(0), 4);
        let bytes = encode_job(
            0,
            KernelOp::Spmm,
            &ExecOpts::default(),
            &part,
            &topo,
            &plan,
            None,
            None,
            None,
            &blocks[0],
            &b,
            None,
        )
        .unwrap();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode_job(&bad).is_err());
        // Bad version.
        let mut bad = bytes.clone();
        bad[8] ^= 0xff;
        assert!(decode_job(&bad).is_err());
        // Truncation anywhere fails cleanly rather than panicking.
        for cut in [bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_job(&bytes[..cut]).is_err());
        }
    }
}
