//! Wire format and worker half of the multi-process backend
//! ([`crate::runtime::multiproc`]).
//!
//! The control plane serializes each rank's *entire* job — partition,
//! topology, plan, schedule, the frozen [`Program`] that
//! [`super::build_program`] derived, local A blocks and dense operands —
//! into one versioned blob, and every runtime `Msg` into a framed DATA
//! payload. Workers run the exact same `rank_main` as the thread
//! executor, with [`super::Outbox::Socket`] swapped in for the channel
//! senders; since every scatter-add folds in canonical (origin, row)
//! order regardless of arrival order, the proc backend's C is
//! bitwise-identical to the thread backend's — the property
//! `tests/multiproc_suite.rs` pins.
//!
//! Framing: `len: u32 LE | kind: u8 | payload`, where `len` counts the
//! kind byte plus payload. All integers little-endian, floats as raw
//! IEEE-754 bits ([`crate::util::bin`]), every length field bounded by
//! the enclosing buffer so corrupt input fails cleanly.

use super::kernel::{KernelOp, NativeKernel};
use super::pipeline::{BufferPool, ExecOpts, PoolRef};
use super::{
    rank_main, BPost, Ctx, Item, Msg, Outbox, Program, RankStats, RowRoute, SddmmVals,
};
use crate::comm::{CommPlan, PairPlan};
use crate::dense::Dense;
use crate::hierarchy::{self, phase, BFlow, CFlow, HierSchedule};
use crate::partition::{LocalBlocks, RowPartition};
use crate::plan::cache::{decode_strategy, encode_strategy};
use crate::runtime::multiproc::CrashPhase;
use crate::topology::Topology;
use crate::util::bin::{
    r_csr, r_dense, r_f64, r_str, r_u32, r_u32s, r_u64, r_u64s, r_u8, w_csr, w_dense, w_f64,
    w_str, w_u32, w_u32s, w_u64, w_u64s, w_u8,
};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Magic + version prefix of every JOB blob. Bump [`WIRE_VERSION`] on any
/// layout change: parent and workers are always the same binary, so a
/// mismatch means a stale `--worker-exe` override, not rolling upgrade.
pub(crate) const WIRE_MAGIC: &[u8; 8] = b"SHIROWIR";
/// v3: JOB/DATA/DONE/ERROR frames are epoch-tagged and ABORT lets the
/// control plane cancel an in-flight step on surviving workers — the
/// crash-recovery protocol (DESIGN.md §12). v2 added the op-gated SDDMM
/// edge-value DONE payload.
pub(crate) const WIRE_VERSION: u32 = 3;

/// Hard ceiling on one frame (1 GiB): no legitimate payload approaches
/// this; a larger claim means a corrupt or hostile length field.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Worker heartbeat interval. The control plane declares a rank dead when
/// nothing (BEAT or otherwise) arrives within its failure timeout — many
/// intervals, so scheduler jitter can't false-positive.
pub(crate) const BEAT_MILLIS: u64 = 100;

/// Env vars the parent sets when spawning a worker; their presence is what
/// [`crate::runtime::multiproc::maybe_run_worker`] keys on.
pub(crate) const ENV_PORT: &str = "SHIRO_WORKER_PORT";
pub(crate) const ENV_RANK: &str = "SHIRO_WORKER_RANK";
/// Fault-injection hook ([`crate::runtime::multiproc::FaultPlan`]): the
/// value names the [`CrashPhase`] at which the worker aborts, standing in
/// for a segfaulted or OOM-killed rank at that point in the step.
pub(crate) const ENV_CRASH: &str = "SHIRO_WORKER_CRASH";

/// Frame kinds. Namespaced so they cannot be confused with the fold-key
/// kinds in [`super::pipeline`].
pub(crate) mod kind {
    /// Worker → parent, first frame: `version u32 | rank u64`.
    pub const HELLO: u8 = 1;
    /// Parent → worker: `epoch u64 | serialized job blob`. Re-sent with a
    /// fresh epoch after every recovery replan; the job's own `rank`
    /// field (not the worker's spawn-time identity) is authoritative for
    /// that epoch.
    pub const JOB: u8 = 2;
    /// Either direction: `dst u64 | epoch u64 | encoded Msg` — routed by
    /// the parent to `dst`'s stream for the *current* epoch; stale-epoch
    /// frames are dropped by both parent and workers.
    pub const DATA: u8 = 3;
    /// Worker → parent on success:
    /// `epoch u64 | rank u64 | C block | RankStats | flag u8 [| SddmmVals]`
    /// — the edge-value payload ships only for SDDMM jobs (flag 1), whose
    /// output *is* the per-rank sparse values.
    pub const DONE: u8 = 4;
    /// Worker → parent liveness: `rank u64`, every [`super::BEAT_MILLIS`].
    pub const BEAT: u8 = 5;
    /// Worker → parent on failure: `epoch u64 | rank u64 | message`. An
    /// aborted job's "inbox closed" panic also lands here, tagged with
    /// its stale epoch, which the parent discards.
    pub const ERROR: u8 = 6;
    /// Parent → worker: `epoch u64` — cancel the in-flight job for that
    /// epoch (a peer died; a replanned JOB follows under a new epoch).
    pub const ABORT: u8 = 7;
}

// ------------------------------------------------------------- framing ----

pub(crate) fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> Result<()> {
    let len = payload.len() + 1;
    if len > MAX_FRAME {
        bail!("frame payload of {} bytes exceeds MAX_FRAME", payload.len());
    }
    w.write_all(&(len as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

pub(crate) fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>)> {
    let mut lenb = [0u8; 4];
    r.read_exact(&mut lenb)?;
    let len = u32::from_le_bytes(lenb) as usize;
    if len == 0 || len > MAX_FRAME {
        bail!("frame length {len} out of range");
    }
    let mut kb = [0u8; 1];
    r.read_exact(&mut kb)?;
    let mut payload = vec![0u8; len - 1];
    r.read_exact(&mut payload)?;
    Ok((kb[0], payload))
}

/// Shared write half of a worker's control-plane socket: the pipeline
/// ([`Outbox::Socket`]) and the heartbeat thread interleave whole frames
/// under one lock.
pub(crate) struct SocketTx {
    stream: Mutex<TcpStream>,
}

impl SocketTx {
    pub(crate) fn new(stream: TcpStream) -> SocketTx {
        SocketTx { stream: Mutex::new(stream) }
    }

    pub(crate) fn frame(&self, kind: u8, payload: &[u8]) -> Result<()> {
        let mut s = self.stream.lock().unwrap();
        write_frame(&mut *s, kind, payload)
    }
}

/// Per-epoch send handle the pipeline writes through
/// ([`Outbox::Socket`]): every outgoing DATA frame is stamped with the
/// epoch it belongs to, so after a recovery replan the control plane and
/// surviving workers can discard traffic from the aborted step. Wraps the
/// process-wide [`SocketTx`] — one write lock per frame, shared with the
/// heartbeat thread and any not-yet-dead previous job thread.
pub(crate) struct EpochTx {
    tx: Arc<SocketTx>,
    epoch: u64,
    /// [`CrashPhase::MidExchange`] fault injection: abort the process
    /// right after the first DATA frame hits the socket.
    crash_mid: bool,
}

impl EpochTx {
    pub(crate) fn new(tx: Arc<SocketTx>, epoch: u64, crash_mid: bool) -> EpochTx {
        EpochTx { tx, epoch, crash_mid }
    }

    /// Encode and send one rank→rank message. Panics on socket failure:
    /// the parent is gone, no progress is possible, and the pipeline's
    /// send path is infallible by contract (mirroring the thread
    /// backend's channel `send().expect(..)`).
    pub(crate) fn send(&self, dst: usize, msg: &Msg) {
        let mut payload = Vec::new();
        w_u64(&mut payload, dst as u64).expect("vec write");
        w_u64(&mut payload, self.epoch).expect("vec write");
        encode_msg(&mut payload, msg).expect("vec write");
        self.tx
            .frame(kind::DATA, &payload)
            .expect("control-plane socket write failed — parent gone");
        if self.crash_mid {
            std::process::abort();
        }
    }
}

/// Routing header of a v3 DATA payload: `dst u64 | epoch u64 | Msg`. The
/// parent reads only this much to route; workers read it to drop frames
/// from an aborted epoch before decoding the message body.
pub(crate) const DATA_HEADER: usize = 16;

pub(crate) fn decode_data_header(payload: &[u8]) -> Result<(usize, u64)> {
    let r = &mut &payload[..];
    let dst = r_u64(r)? as usize;
    let epoch = r_u64(r)?;
    Ok((dst, epoch))
}

/// Payload of ABORT frames and the prefix of JOB frames: one `epoch u64`.
pub(crate) fn epoch_payload(epoch: u64) -> Vec<u8> {
    epoch.to_le_bytes().to_vec()
}

pub(crate) fn decode_epoch(buf: &[u8]) -> Result<u64> {
    r_u64(&mut &buf[..])
}

// ------------------------------------------------------ message codec ----

fn encode_msg(out: &mut Vec<u8>, msg: &Msg) -> Result<()> {
    match msg {
        Msg::B { from, origin, rows, data } => {
            w_u8(out, 0)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *origin as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::X { from, origin, rows, data } => {
            w_u8(out, 1)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *origin as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::C { from, rows, data } => {
            w_u8(out, 2)?;
            w_u64(out, *from as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
        Msg::CAgg { from, final_dst, rows, data } => {
            w_u8(out, 3)?;
            w_u64(out, *from as u64)?;
            w_u64(out, *final_dst as u64)?;
            w_u32s(out, rows)?;
            w_dense(out, data)?;
        }
    }
    Ok(())
}

fn decode_msg<R: Read>(r: &mut R, max: usize) -> Result<Msg> {
    let tag = r_u8(r)?;
    let from = r_u64(r)? as usize;
    Ok(match tag {
        0 | 1 => {
            let origin = r_u64(r)? as usize;
            let rows = r_u32s(r, max)?;
            let data = r_dense(r, max)?;
            if tag == 0 {
                Msg::B { from, origin, rows, data }
            } else {
                Msg::X { from, origin, rows, data }
            }
        }
        2 => Msg::C { from, rows: r_u32s(r, max)?, data: r_dense(r, max)? },
        3 => {
            let final_dst = r_u64(r)? as usize;
            Msg::CAgg { from, final_dst, rows: r_u32s(r, max)?, data: r_dense(r, max)? }
        }
        t => bail!("unknown message tag {t}"),
    })
}

// ------------------------------------------------------ program codec ----

/// Every `&'static str` phase label a [`BPost`] can carry; the wire tag is
/// the table index. Unknown labels are an encode-time error, so adding a
/// phase without extending this table fails loudly in tests, not silently
/// on a worker.
const PHASES: [&str; 10] = [
    crate::sim::FLAT_STAGE,
    phase::S1_INTER_B,
    phase::S1_INTRA_C,
    phase::S2_INTER_C,
    phase::S2_INTRA_B,
    phase::COMPUTE_LOCAL,
    phase::COMPUTE_REMOTE,
    phase::IDLE,
    phase::S1_FETCH_X,
    phase::S2_INTRA_X,
];

fn phase_tag(name: &str) -> Result<u8> {
    PHASES
        .iter()
        .position(|&p| p == name)
        .map(|i| i as u8)
        .ok_or_else(|| anyhow!("phase label {name:?} missing from wire table"))
}

fn phase_name(tag: u8) -> Result<&'static str> {
    PHASES
        .get(tag as usize)
        .copied()
        .ok_or_else(|| anyhow!("unknown phase tag {tag}"))
}

fn op_tag(op: KernelOp) -> u8 {
    match op {
        KernelOp::Spmm => 0,
        KernelOp::Sddmm => 1,
        KernelOp::FusedSddmmSpmm => 2,
    }
}

fn op_from_tag(tag: u8) -> Result<KernelOp> {
    Ok(match tag {
        0 => KernelOp::Spmm,
        1 => KernelOp::Sddmm,
        2 => KernelOp::FusedSddmmSpmm,
        t => bail!("unknown kernel-op tag {t}"),
    })
}

fn w_usizes<W: Write>(w: &mut W, xs: &[usize]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for &x in xs {
        w_u64(w, x as u64)?;
    }
    Ok(())
}

fn r_usizes<R: Read>(r: &mut R, max: usize) -> Result<Vec<usize>> {
    Ok(r_u64s(r, max)?.into_iter().map(|x| x as usize).collect())
}

fn encode_posts(out: &mut Vec<u8>, posts: &[BPost]) -> Result<()> {
    w_u64(out, posts.len() as u64)?;
    for p in posts {
        w_u64(out, p.dst as u64)?;
        w_u8(out, phase_tag(p.phase)?)?;
        w_u32s(out, &p.rows)?;
    }
    Ok(())
}

fn decode_posts<R: Read>(r: &mut R, max: usize) -> Result<Vec<BPost>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt program: {n} posts exceed available bytes");
    }
    let mut posts = Vec::with_capacity(n);
    for _ in 0..n {
        let dst = r_u64(r)? as usize;
        let phase = phase_name(r_u8(r)?)?;
        posts.push(BPost { dst, rows: r_u32s(r, max)?, phase });
    }
    Ok(posts)
}

fn encode_map(out: &mut Vec<u8>, m: &std::collections::BTreeMap<usize, usize>) -> Result<()> {
    w_u64(out, m.len() as u64)?;
    for (&k, &v) in m {
        w_u64(out, k as u64)?;
        w_u64(out, v as u64)?;
    }
    Ok(())
}

fn decode_map<R: Read>(
    r: &mut R,
    max: usize,
) -> Result<std::collections::BTreeMap<usize, usize>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt program: map of {n} entries exceeds available bytes");
    }
    let mut m = std::collections::BTreeMap::new();
    for _ in 0..n {
        let k = r_u64(r)? as usize;
        m.insert(k, r_u64(r)? as usize);
    }
    Ok(m)
}

fn encode_program(out: &mut Vec<u8>, p: &Program) -> Result<()> {
    w_u8(out, op_tag(p.op))?;
    encode_posts(out, &p.b_posts)?;
    encode_posts(out, &p.x_posts)?;
    w_u64(out, p.items.len() as u64)?;
    for it in &p.items {
        match it {
            Item::ProduceDirectC { dst } => {
                w_u8(out, 0)?;
                w_u64(out, *dst as u64)?;
            }
            Item::ProduceFlowC { flow } => {
                w_u8(out, 1)?;
                w_u64(out, *flow as u64)?;
            }
            Item::DiagTile { r0, r1 } => {
                w_u8(out, 2)?;
                w_u64(out, *r0 as u64)?;
                w_u64(out, *r1 as u64)?;
            }
        }
    }
    w_u64(out, p.expect_msgs as u64)?;
    w_u64s(out, &p.fold_keys)?;
    w_usizes(out, &p.agg_flows)?;
    encode_map(out, &p.rep_b)?;
    encode_map(out, &p.rep_x)?;
    w_u64(out, p.row_route.len() as u64)?;
    for (&dst, route) in &p.row_route {
        w_u64(out, dst as u64)?;
        match route {
            RowRoute::Direct => w_u8(out, 0)?,
            RowRoute::Flow(i) => {
                w_u8(out, 1)?;
                w_u64(out, *i as u64)?;
            }
        }
    }
    Ok(())
}

fn decode_program<R: Read>(r: &mut R, max: usize) -> Result<Program> {
    let op = op_from_tag(r_u8(r)?)?;
    let b_posts = decode_posts(r, max)?;
    let x_posts = decode_posts(r, max)?;
    let n_items = r_u64(r)? as usize;
    if n_items > max {
        bail!("corrupt program: {n_items} items exceed available bytes");
    }
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        items.push(match r_u8(r)? {
            0 => Item::ProduceDirectC { dst: r_u64(r)? as usize },
            1 => Item::ProduceFlowC { flow: r_u64(r)? as usize },
            2 => Item::DiagTile { r0: r_u64(r)? as usize, r1: r_u64(r)? as usize },
            t => bail!("unknown program item tag {t}"),
        });
    }
    let expect_msgs = r_u64(r)? as usize;
    let fold_keys = r_u64s(r, max)?;
    let agg_flows = r_usizes(r, max)?;
    let rep_b = decode_map(r, max)?;
    let rep_x = decode_map(r, max)?;
    let n_routes = r_u64(r)? as usize;
    if n_routes > max {
        bail!("corrupt program: {n_routes} row routes exceed available bytes");
    }
    let mut row_route = std::collections::BTreeMap::new();
    for _ in 0..n_routes {
        let dst = r_u64(r)? as usize;
        let route = match r_u8(r)? {
            0 => RowRoute::Direct,
            1 => RowRoute::Flow(r_u64(r)? as usize),
            t => bail!("unknown row-route tag {t}"),
        };
        row_route.insert(dst, route);
    }
    Ok(Program {
        op,
        b_posts,
        x_posts,
        items,
        expect_msgs,
        fold_keys,
        agg_flows,
        rep_b,
        rep_x,
        row_route,
    })
}

// ------------------------------------------- plan / schedule / operand ----

fn encode_topo(out: &mut Vec<u8>, t: &Topology) -> Result<()> {
    w_str(out, &t.name)?;
    w_u64(out, t.nranks as u64)?;
    w_u64(out, t.group_size as u64)?;
    for v in [t.intra_bw, t.inter_bw, t.intra_lat, t.inter_lat, t.compute_rate, t.kernel_launch]
    {
        w_f64(out, v)?;
    }
    Ok(())
}

fn decode_topo<R: Read>(r: &mut R, max: usize) -> Result<Topology> {
    Ok(Topology {
        name: r_str(r, max)?,
        nranks: r_u64(r)? as usize,
        group_size: r_u64(r)? as usize,
        intra_bw: r_f64(r)?,
        inter_bw: r_f64(r)?,
        intra_lat: r_f64(r)?,
        inter_lat: r_f64(r)?,
        compute_rate: r_f64(r)?,
        kernel_launch: r_f64(r)?,
    })
}

/// Same layout as the plan cache's body ([`crate::plan::cache`]): split
/// parts + flags only, compact operands re-derived via
/// [`PairPlan::from_parts`] — the reconstruction the cache's roundtrip
/// test proves exact.
fn encode_plan(out: &mut Vec<u8>, plan: &CommPlan) -> Result<()> {
    w_u64(out, plan.nranks as u64)?;
    w_u8(out, encode_strategy(plan.strategy))?;
    w_usizes(out, &plan.block_rows)?;
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p == q {
                continue;
            }
            let pair = &plan.pairs[p][q];
            w_u8(out, u8::from(pair.full_block))?;
            w_csr(out, &pair.a_row_part)?;
            w_csr(out, &pair.a_col_part)?;
        }
    }
    Ok(())
}

fn decode_plan<R: Read>(r: &mut R, max: usize) -> Result<CommPlan> {
    let nranks = r_u64(r)? as usize;
    if nranks > max {
        bail!("corrupt plan: nranks {nranks} exceeds available bytes");
    }
    let strategy = decode_strategy(r_u8(r)?)?;
    let block_rows = r_usizes(r, max)?;
    if block_rows.len() != nranks {
        bail!("corrupt plan: {} block heights for {nranks} ranks", block_rows.len());
    }
    let mut pairs = Vec::with_capacity(nranks);
    for p in 0..nranks {
        let mut row = Vec::with_capacity(nranks);
        for q in 0..nranks {
            if p == q {
                row.push(PairPlan::default());
                continue;
            }
            let full_block = r_u8(r)? != 0;
            let a_row_part = r_csr(r, max)?;
            let a_col_part = r_csr(r, max)?;
            row.push(PairPlan::from_parts(a_row_part, a_col_part, full_block));
        }
        pairs.push(row);
    }
    Ok(CommPlan { nranks, strategy, pairs, block_rows })
}

fn encode_rowsets(out: &mut Vec<u8>, sets: &[(usize, Vec<u32>)]) -> Result<()> {
    w_u64(out, sets.len() as u64)?;
    for (rank, rows) in sets {
        w_u64(out, *rank as u64)?;
        w_u32s(out, rows)?;
    }
    Ok(())
}

fn decode_rowsets<R: Read>(r: &mut R, max: usize) -> Result<Vec<(usize, Vec<u32>)>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt schedule: {n} row sets exceed available bytes");
    }
    let mut sets = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = r_u64(r)? as usize;
        sets.push((rank, r_u32s(r, max)?));
    }
    Ok(sets)
}

fn encode_directs(out: &mut Vec<u8>, ds: &[(usize, usize, Vec<u32>)]) -> Result<()> {
    w_u64(out, ds.len() as u64)?;
    for (a, b, rows) in ds {
        w_u64(out, *a as u64)?;
        w_u64(out, *b as u64)?;
        w_u32s(out, rows)?;
    }
    Ok(())
}

fn decode_directs<R: Read>(r: &mut R, max: usize) -> Result<Vec<(usize, usize, Vec<u32>)>> {
    let n = r_u64(r)? as usize;
    if n > max {
        bail!("corrupt schedule: {n} direct transfers exceed available bytes");
    }
    let mut ds = Vec::with_capacity(n);
    for _ in 0..n {
        let a = r_u64(r)? as usize;
        let b = r_u64(r)? as usize;
        ds.push((a, b, r_u32s(r, max)?));
    }
    Ok(ds)
}

fn encode_sched(out: &mut Vec<u8>, s: &HierSchedule) -> Result<()> {
    w_u64(out, s.nranks as u64)?;
    w_u64(out, s.b_flows.len() as u64)?;
    for f in &s.b_flows {
        w_u64(out, f.src as u64)?;
        w_u64(out, f.dst_group as u64)?;
        w_u64(out, f.rep as u64)?;
        w_u32s(out, &f.rows)?;
        encode_rowsets(out, &f.consumers)?;
    }
    w_u64(out, s.c_flows.len() as u64)?;
    for f in &s.c_flows {
        w_u64(out, f.dst as u64)?;
        w_u64(out, f.src_group as u64)?;
        w_u64(out, f.rep as u64)?;
        w_u32s(out, &f.rows)?;
        encode_rowsets(out, &f.producers)?;
    }
    encode_directs(out, &s.direct_b)?;
    encode_directs(out, &s.direct_c)?;
    Ok(())
}

fn decode_sched<R: Read>(r: &mut R, max: usize) -> Result<HierSchedule> {
    let nranks = r_u64(r)? as usize;
    let nb = r_u64(r)? as usize;
    if nb > max {
        bail!("corrupt schedule: {nb} B flows exceed available bytes");
    }
    let mut b_flows = Vec::with_capacity(nb);
    for _ in 0..nb {
        b_flows.push(BFlow {
            src: r_u64(r)? as usize,
            dst_group: r_u64(r)? as usize,
            rep: r_u64(r)? as usize,
            rows: r_u32s(r, max)?,
            consumers: decode_rowsets(r, max)?,
        });
    }
    let nc = r_u64(r)? as usize;
    if nc > max {
        bail!("corrupt schedule: {nc} C flows exceed available bytes");
    }
    let mut c_flows = Vec::with_capacity(nc);
    for _ in 0..nc {
        c_flows.push(CFlow {
            dst: r_u64(r)? as usize,
            src_group: r_u64(r)? as usize,
            rep: r_u64(r)? as usize,
            rows: r_u32s(r, max)?,
            producers: decode_rowsets(r, max)?,
        });
    }
    let direct_b = decode_directs(r, max)?;
    let direct_c = decode_directs(r, max)?;
    Ok(HierSchedule { nranks, b_flows, c_flows, direct_b, direct_c })
}

// ----------------------------------------------------------- job codec ----

/// One worker's fully decoded assignment.
struct Job {
    rank: usize,
    nranks: usize,
    op: KernelOp,
    opts: ExecOpts,
    part: RowPartition,
    topo: Topology,
    plan: CommPlan,
    sched: Option<HierSchedule>,
    prog: Program,
    blocks: LocalBlocks,
    b_local: Dense,
    x_local: Option<Dense>,
}

/// Serialize rank `rank`'s job. The program is derived here with the
/// *same* `build_program` call the thread executor makes (NativeKernel
/// prefers tiles), so both backends run literally the same step list.
/// `xsched` must be [`hierarchy::sddmm_fetch`] of `sched` exactly as in
/// [`super::run_kernel_with`] — present iff `sched` is and `op` needs X.
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_job(
    rank: usize,
    op: KernelOp,
    opts: &ExecOpts,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    xsched: Option<&HierSchedule>,
    blocks: &LocalBlocks,
    b_local: &Dense,
    x_local: Option<&Dense>,
) -> Result<Vec<u8>> {
    let prog = super::build_program(rank, part, plan, sched, xsched, opts, true, op);
    encode_job_parts(
        rank, plan.nranks, op, opts, part, topo, plan, sched, &prog, blocks, b_local, x_local,
    )
}

#[allow(clippy::too_many_arguments)]
fn encode_job_parts(
    rank: usize,
    nranks: usize,
    op: KernelOp,
    opts: &ExecOpts,
    part: &RowPartition,
    topo: &Topology,
    plan: &CommPlan,
    sched: Option<&HierSchedule>,
    prog: &Program,
    blocks: &LocalBlocks,
    b_local: &Dense,
    x_local: Option<&Dense>,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(WIRE_MAGIC);
    w_u32(&mut out, WIRE_VERSION)?;
    w_u64(&mut out, rank as u64)?;
    w_u64(&mut out, nranks as u64)?;
    w_u8(&mut out, op_tag(op))?;
    w_u8(&mut out, u8::from(opts.overlap))?;
    w_u64(&mut out, opts.tile_rows as u64)?;
    w_u64(&mut out, opts.workers as u64)?;
    w_usizes(&mut out, &part.starts)?;
    encode_topo(&mut out, topo)?;
    encode_plan(&mut out, plan)?;
    match sched {
        None => w_u8(&mut out, 0)?,
        Some(s) => {
            w_u8(&mut out, 1)?;
            encode_sched(&mut out, s)?;
        }
    }
    encode_program(&mut out, prog)?;
    w_u64(&mut out, blocks.rank as u64)?;
    w_csr(&mut out, &blocks.diag)?;
    w_u64(&mut out, blocks.off_diag.len() as u64)?;
    for m in &blocks.off_diag {
        w_csr(&mut out, m)?;
    }
    w_dense(&mut out, b_local)?;
    match x_local {
        None => w_u8(&mut out, 0)?,
        Some(x) => {
            w_u8(&mut out, 1)?;
            w_dense(&mut out, x)?;
        }
    }
    Ok(out)
}

fn decode_job(buf: &[u8]) -> Result<Job> {
    // Every serialized element occupies ≥ 4 bytes, so no honest length
    // field can exceed this bound (the +1 admits empty lists in a tiny
    // buffer).
    let max = buf.len() / 4 + 1;
    let r = &mut &buf[..];
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != WIRE_MAGIC {
        bail!("bad job magic");
    }
    let version = r_u32(r)?;
    if version != WIRE_VERSION {
        bail!("wire version {version} != {WIRE_VERSION} (mismatched worker binary?)");
    }
    let rank = r_u64(r)? as usize;
    let nranks = r_u64(r)? as usize;
    let op = op_from_tag(r_u8(r)?)?;
    let opts = ExecOpts {
        overlap: r_u8(r)? != 0,
        tile_rows: r_u64(r)? as usize,
        workers: r_u64(r)? as usize,
    };
    let starts = r_usizes(r, max)?;
    if starts.len() < 2 || starts[0] != 0 || starts.windows(2).any(|w| w[0] > w[1]) {
        bail!("corrupt job: bad partition starts {starts:?}");
    }
    let part = RowPartition::from_starts(starts);
    let topo = decode_topo(r, max)?;
    let plan = decode_plan(r, max)?;
    let sched = match r_u8(r)? {
        0 => None,
        1 => Some(decode_sched(r, max)?),
        t => bail!("bad schedule option tag {t}"),
    };
    let prog = decode_program(r, max)?;
    let blocks_rank = r_u64(r)? as usize;
    let diag = r_csr(r, max)?;
    let n_off = r_u64(r)? as usize;
    if n_off > max {
        bail!("corrupt job: {n_off} off-diagonal blocks exceed available bytes");
    }
    let mut off_diag = Vec::with_capacity(n_off);
    for _ in 0..n_off {
        off_diag.push(r_csr(r, max)?);
    }
    let blocks = LocalBlocks { rank: blocks_rank, diag, off_diag };
    let b_local = r_dense(r, max)?;
    let x_local = match r_u8(r)? {
        0 => None,
        1 => Some(r_dense(r, max)?),
        t => bail!("bad X option tag {t}"),
    };
    if rank >= nranks || part.nparts != nranks || plan.nranks != nranks || blocks_rank != rank {
        bail!("inconsistent job: rank {rank}, nranks {nranks}, part {}", part.nparts);
    }
    Ok(Job { rank, nranks, op, opts, part, topo, plan, sched, prog, blocks, b_local, x_local })
}

// --------------------------------------------------- control messages ----

fn rank_payload(rank: usize) -> Vec<u8> {
    let mut out = Vec::new();
    w_u64(&mut out, rank as u64).expect("vec write");
    out
}

fn encode_hello(rank: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u32(&mut out, WIRE_VERSION)?;
    w_u64(&mut out, rank as u64)?;
    Ok(out)
}

pub(crate) fn decode_hello(buf: &[u8]) -> Result<(u32, usize)> {
    let r = &mut &buf[..];
    Ok((r_u32(r)?, r_u64(r)? as usize))
}

fn encode_done(
    epoch: u64,
    rank: usize,
    c: &Dense,
    vals: Option<&SddmmVals>,
    st: &RankStats,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u64(&mut out, epoch)?;
    w_u64(&mut out, rank as u64)?;
    w_dense(&mut out, c)?;
    for v in [
        st.intra_bytes_sent,
        st.inter_bytes_sent,
        st.intra_bytes_recv,
        st.inter_bytes_recv,
        st.msgs_sent,
        st.msgs_recv,
    ] {
        w_u64(&mut out, v)?;
    }
    w_u64s(&mut out, &st.sent_to)?;
    w_u64s(&mut out, &st.sent_b_to)?;
    w_f64(&mut out, st.compute_secs)?;
    w_f64(&mut out, st.idle_secs)?;
    w_u64(&mut out, st.overlapped_recv_bytes)?;
    w_u64(&mut out, st.idle_recv_bytes)?;
    // Phase spans stay worker-local: their labels are `&'static str`s and
    // the chrome-trace export is a thread-backend diagnostic.
    match vals {
        None => w_u8(&mut out, 0)?,
        Some(v) => {
            w_u8(&mut out, 1)?;
            w_dense(&mut out, &v.diag)?;
            for map in [&v.col, &v.row] {
                w_u64(&mut out, map.len() as u64)?;
                for (&peer, d) in map {
                    w_u64(&mut out, peer as u64)?;
                    w_dense(&mut out, d)?;
                }
            }
        }
    }
    Ok(out)
}

pub(crate) fn decode_done(buf: &[u8]) -> Result<(u64, usize, Dense, SddmmVals, RankStats)> {
    let max = buf.len() / 4 + 1;
    let r = &mut &buf[..];
    let epoch = r_u64(r)?;
    let rank = r_u64(r)? as usize;
    let c = r_dense(r, max)?;
    let st = RankStats {
        intra_bytes_sent: r_u64(r)?,
        inter_bytes_sent: r_u64(r)?,
        intra_bytes_recv: r_u64(r)?,
        inter_bytes_recv: r_u64(r)?,
        msgs_sent: r_u64(r)?,
        msgs_recv: r_u64(r)?,
        sent_to: r_u64s(r, max)?,
        sent_b_to: r_u64s(r, max)?,
        compute_secs: r_f64(r)?,
        idle_secs: r_f64(r)?,
        overlapped_recv_bytes: r_u64(r)?,
        idle_recv_bytes: r_u64(r)?,
        phases: Vec::new(),
    };
    let mut vals = SddmmVals::default();
    if r_u8(r)? == 1 {
        vals.diag = r_dense(r, max)?;
        for map_is_col in [true, false] {
            let len = r_u64(r)? as usize;
            if len > max {
                bail!("SDDMM value map claims {len} entries");
            }
            for _ in 0..len {
                let peer = r_u64(r)? as usize;
                let d = r_dense(r, max)?;
                if map_is_col {
                    vals.col.insert(peer, d);
                } else {
                    vals.row.insert(peer, d);
                }
            }
        }
    }
    Ok((epoch, rank, c, vals, st))
}

fn encode_error(epoch: u64, rank: usize, msg: &str) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    w_u64(&mut out, epoch)?;
    w_u64(&mut out, rank as u64)?;
    w_str(&mut out, msg)?;
    Ok(out)
}

pub(crate) fn decode_error(buf: &[u8]) -> Result<(u64, usize, String)> {
    let r = &mut &buf[..];
    let epoch = r_u64(r)?;
    let rank = r_u64(r)? as usize;
    let msg = r_str(r, buf.len())?;
    Ok((epoch, rank, msg))
}

// --------------------------------------------------------- worker side ----

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "rank panicked (non-string payload)".to_string()
    }
}

/// Worker-process entry point: connect, HELLO, then serve epoch-tagged
/// JOB frames until the control plane closes the socket. Never returns.
pub(crate) fn worker_main(port: u16, rank: usize) -> ! {
    let code = match worker_run(port, rank) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("shiro worker rank {rank}: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// The worker's main loop owns the socket's read half and multiplexes
/// three frame kinds across epochs:
///
/// - JOB(epoch): spawn a job thread running the shared `rank_main` with a
///   fresh inbox; the job's own `rank` field is authoritative (after a
///   recovery replan the parent renumbers survivors).
/// - DATA: forwarded into the inbox iff its epoch matches the in-flight
///   job; stale frames from an aborted step are dropped.
/// - ABORT(epoch): drop the matching job's inbox sender — a `recv`
///   blocked in `rank_main` panics ("inbox closed"), the job thread
///   catches it and reports an ERROR tagged with its stale epoch, which
///   the parent discards.
///
/// Socket EOF is the clean shutdown signal. One buffered reader serves
/// every frame — a second reader over the raw stream would lose whatever
/// bytes this BufReader has already pulled past a frame boundary.
fn worker_run(port: u16, rank: usize) -> Result<()> {
    let stream =
        TcpStream::connect(("127.0.0.1", port)).context("connect to control plane")?;
    stream.set_nodelay(true).ok();
    let tx = Arc::new(SocketTx::new(stream.try_clone().context("clone control socket")?));
    tx.frame(kind::HELLO, &encode_hello(rank)?)?;

    // Fault injection (`ProcOpts::fault`): the env value names the phase
    // at which this worker abort()s, standing in for a segfaulted or
    // OOM-killed rank at that point in the step.
    let crash = std::env::var(ENV_CRASH).ok().and_then(|v| CrashPhase::by_name(&v));

    // Liveness is a property of the worker process, not of any one
    // epoch's job: one heartbeat thread spans the whole lifetime.
    let stop = Arc::new(AtomicBool::new(false));
    let beat = {
        let tx = Arc::clone(&tx);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let payload = rank_payload(rank);
            while !stop.load(Ordering::Relaxed) {
                if tx.frame(kind::BEAT, &payload).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(BEAT_MILLIS));
            }
        })
    };

    let mut reader = BufReader::new(stream);
    // The in-flight job: its epoch and the sender feeding its inbox.
    let mut current: Option<(u64, mpsc::Sender<Msg>)> = None;
    loop {
        let (k, payload) = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => break, // parent closed the socket: clean shutdown
        };
        match k {
            kind::JOB => {
                if payload.len() < 8 {
                    bail!("JOB frame too short for epoch prefix");
                }
                let epoch = decode_epoch(&payload)?;
                let job = match decode_job(&payload[8..]) {
                    Ok(j) => j,
                    Err(e) => {
                        let msg = format!("bad job: {e:#}");
                        let _ = tx.frame(kind::ERROR, &encode_error(epoch, rank, &msg)?);
                        continue;
                    }
                };
                if crash == Some(CrashPhase::PostDecode) {
                    std::process::abort();
                }
                // A JOB while one is in flight shouldn't happen (the
                // parent aborts first), but dropping the old sender makes
                // it converge to the same aborted state either way.
                drop(current.take());
                let (msg_tx, msg_rx) = mpsc::channel::<Msg>();
                current = Some((epoch, msg_tx));
                let jtx = Arc::clone(&tx);
                std::thread::spawn(move || run_job(epoch, job, jtx, msg_rx, crash));
            }
            kind::DATA => {
                if payload.len() < DATA_HEADER {
                    bail!("DATA frame too short for routing header");
                }
                let (_dst, epoch) = decode_data_header(&payload)?;
                let intact = match &current {
                    // Stale frames from an aborted step are dropped; a
                    // send error just means the job thread already
                    // finished.
                    Some((cur, msg_tx)) if *cur == epoch => {
                        let r = &mut &payload[DATA_HEADER..];
                        match decode_msg(r, payload.len() / 4 + 1) {
                            Ok(m) => {
                                let _ = msg_tx.send(m);
                                true
                            }
                            Err(_) => false,
                        }
                    }
                    _ => true,
                };
                if !intact {
                    // Corrupt message: poison the in-flight job so its
                    // blocked recv panics and surfaces a current-epoch
                    // ERROR instead of hanging on a frame that never
                    // arrives.
                    drop(current.take());
                }
            }
            kind::ABORT => {
                let epoch = decode_epoch(&payload)?;
                if matches!(&current, Some((cur, _)) if *cur == epoch) {
                    drop(current.take());
                }
            }
            _ => {} // unknown kinds are ignored (same binary: can't happen)
        }
    }
    stop.store(true, Ordering::Relaxed);
    let _ = beat.join();
    // Job threads are detached; they die with the process.
    Ok(())
}

/// One epoch's job, on its own thread so the main loop keeps draining
/// frames (DATA for this job, ABORT against it, the next epoch's JOB).
fn run_job(
    epoch: u64,
    job: Job,
    tx: Arc<SocketTx>,
    inbox: mpsc::Receiver<Msg>,
    crash: Option<CrashPhase>,
) {
    let rank = job.rank;
    let nranks = job.nranks;
    let etx = EpochTx::new(Arc::clone(&tx), epoch, crash == Some(CrashPhase::MidExchange));
    let result = catch_unwind(AssertUnwindSafe(|| {
        // Re-derive the X fetch schedule exactly as `run_kernel_with`
        // does — it is a pure function of the shipped schedule.
        let xsched = (job.op != KernelOp::Spmm)
            .then(|| job.sched.as_ref().map(hierarchy::sddmm_fetch))
            .flatten();
        let kernel = NativeKernel;
        let mut ctx = Ctx {
            rank,
            part: &job.part,
            plan: &job.plan,
            sched: job.sched.as_ref(),
            xsched: xsched.as_ref(),
            topo: &job.topo,
            kernel: &kernel,
            outbox: Outbox::Socket(&etx),
            inbox,
            stats: RankStats {
                sent_to: vec![0; nranks],
                sent_b_to: vec![0; nranks],
                ..RankStats::default()
            },
            opts: job.opts,
            gate: None,
            t0: Instant::now(),
            pool: PoolRef::Own(BufferPool::new()),
        };
        let c_width = if job.op == KernelOp::Sddmm { 0 } else { job.b_local.ncols };
        let mut c_local = Dense::zeros(job.part.len(rank), c_width);
        let mut vals = SddmmVals::default();
        rank_main(
            &mut ctx,
            &job.blocks,
            job.x_local.as_ref(),
            &job.b_local,
            &mut c_local,
            &mut vals,
            &job.prog,
        );
        (c_local, vals, ctx.stats)
    }));

    match result {
        Ok((c_local, vals, stats)) => {
            // A still-armed crash fires here: PreDone by definition, or
            // MidExchange when the program had nothing to send.
            if crash.is_some() {
                std::process::abort();
            }
            // The fused kernel also leaves edge values in `vals`, but its
            // output is the dense C — only SDDMM ships them back.
            let vals = (job.op == KernelOp::Sddmm).then_some(&vals);
            let payload = encode_done(epoch, rank, &c_local, vals, &stats)
                .expect("vec write");
            // Write failure means the parent is gone; the main loop's EOF
            // will end the process.
            let _ = tx.frame(kind::DONE, &payload);
        }
        Err(p) => {
            let msg = panic_message(p.as_ref());
            if let Ok(payload) = encode_error(epoch, rank, &msg) {
                let _ = tx.frame(kind::ERROR, &payload);
            }
        }
    }
}

// --------------------------------------------------------------- tests ----

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::partition::split_1d;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind::DATA, &[1, 2, 3]).unwrap();
        write_frame(&mut buf, kind::BEAT, &[]).unwrap();
        let r = &mut &buf[..];
        assert_eq!(read_frame(r).unwrap(), (kind::DATA, vec![1, 2, 3]));
        assert_eq!(read_frame(r).unwrap(), (kind::BEAT, vec![]));
        assert!(r.is_empty());
        // A zero length word is rejected (kind byte is always counted).
        let bad = 0u32.to_le_bytes();
        assert!(read_frame(&mut &bad[..]).is_err());
    }

    /// Decode-then-re-encode must reproduce the exact bytes; avoids
    /// needing PartialEq on the executor's private message type.
    fn msg_roundtrips(m: &Msg) {
        let mut buf = Vec::new();
        encode_msg(&mut buf, m).unwrap();
        let r = &mut &buf[..];
        let back = decode_msg(r, buf.len() / 4 + 1).unwrap();
        assert!(r.is_empty());
        let mut buf2 = Vec::new();
        encode_msg(&mut buf2, &back).unwrap();
        assert_eq!(buf, buf2);
    }

    #[test]
    fn msg_roundtrip_all_variants() {
        // NaN and -0.0 payloads must survive bitwise (float bits travel
        // raw), or the proc backend could not be a bitwise oracle match.
        let d = Dense::from_vec(2, 2, vec![1.5, f32::NAN, -0.0, 7.25]);
        msg_roundtrips(&Msg::B { from: 3, origin: 1, rows: vec![0, 5], data: d.clone() });
        msg_roundtrips(&Msg::X { from: 0, origin: 2, rows: vec![9], data: d.clone() });
        msg_roundtrips(&Msg::C { from: 7, rows: vec![], data: Dense::zeros(0, 4) });
        msg_roundtrips(&Msg::CAgg { from: 2, final_dst: 6, rows: vec![1, 2, 3], data: d });
    }

    #[test]
    fn phase_table_roundtrips() {
        for (i, &name) in PHASES.iter().enumerate() {
            assert_eq!(phase_tag(name).unwrap(), i as u8);
            assert_eq!(phase_name(i as u8).unwrap(), name);
        }
        assert!(phase_name(PHASES.len() as u8).is_err());
        assert!(phase_tag("no such phase").is_err());
    }

    #[test]
    fn done_roundtrip() {
        let c = Dense::from_fn(3, 2, |i, j| (i + j) as f32 - 1.5);
        let st = RankStats {
            intra_bytes_sent: 10,
            inter_bytes_sent: 20,
            intra_bytes_recv: 30,
            inter_bytes_recv: 40,
            msgs_sent: 5,
            msgs_recv: 6,
            sent_to: vec![1, 2, 3],
            sent_b_to: vec![1, 0, 3],
            compute_secs: 0.25,
            idle_secs: 0.125,
            overlapped_recv_bytes: 7,
            idle_recv_bytes: 8,
            phases: Vec::new(),
        };
        let buf = encode_done(9, 2, &c, None, &st).unwrap();
        let (epoch, rank, c2, vals2, st2) = decode_done(&buf).unwrap();
        assert_eq!((epoch, rank), (9, 2));
        assert_eq!(c2, c);
        assert_eq!(vals2.diag.data, Vec::<f32>::new());
        assert!(vals2.col.is_empty() && vals2.row.is_empty());
        assert_eq!(st2.sent_to, st.sent_to);
        assert_eq!(st2.msgs_recv, 6);
        assert_eq!(st2.compute_secs, 0.25);

        // SDDMM DONE frames carry the edge values bitwise (NaN included).
        let mut vals = SddmmVals::default();
        vals.diag = Dense::from_vec(1, 3, vec![1.0, f32::NAN, -0.0]);
        vals.col.insert(3, Dense::from_vec(1, 2, vec![2.5, -7.0]));
        vals.row.insert(0, Dense::from_vec(1, 1, vec![0.125]));
        vals.row.insert(5, Dense::zeros(0, 0));
        let buf = encode_done(0, 1, &Dense::zeros(2, 0), Some(&vals), &st).unwrap();
        let (epoch, rank, c2, vals2, _) = decode_done(&buf).unwrap();
        assert_eq!((epoch, rank, c2.nrows, c2.ncols), (0, 1, 2, 0));
        assert_eq!(vals2.diag.data.len(), 3);
        assert_eq!(vals2.diag.data[0].to_bits(), 1.0f32.to_bits());
        assert!(vals2.diag.data[1].is_nan());
        assert_eq!(vals2.diag.data[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(vals2.col[&3].data, vec![2.5, -7.0]);
        assert_eq!(vals2.row[&0].data, vec![0.125]);
        assert_eq!(vals2.row[&5], Dense::zeros(0, 0));
    }

    #[test]
    fn hello_and_error_roundtrip() {
        let (v, rank) = decode_hello(&encode_hello(11).unwrap()).unwrap();
        assert_eq!((v, rank), (WIRE_VERSION, 11));
        let (epoch, rank, msg) =
            decode_error(&encode_error(4, 3, "inbox closed").unwrap()).unwrap();
        assert_eq!((epoch, rank, msg.as_str()), (4, 3, "inbox closed"));
    }

    #[test]
    fn epoch_and_data_header_roundtrip() {
        // ABORT / JOB-prefix payloads.
        assert_eq!(decode_epoch(&epoch_payload(0)).unwrap(), 0);
        assert_eq!(decode_epoch(&epoch_payload(u64::MAX)).unwrap(), u64::MAX);
        assert!(decode_epoch(&[1, 2, 3]).is_err());
        // DATA routing headers: what EpochTx::send writes is what
        // decode_data_header reads, and the Msg body follows intact.
        let tx_payload = {
            let mut p = Vec::new();
            w_u64(&mut p, 5).unwrap();
            w_u64(&mut p, 7).unwrap();
            encode_msg(
                &mut p,
                &Msg::C { from: 2, rows: vec![4], data: Dense::from_vec(1, 1, vec![2.5]) },
            )
            .unwrap();
            p
        };
        let (dst, epoch) = decode_data_header(&tx_payload).unwrap();
        assert_eq!((dst, epoch), (5, 7));
        let body = &mut &tx_payload[DATA_HEADER..];
        let m = decode_msg(body, tx_payload.len() / 4 + 1).unwrap();
        assert!(matches!(m, Msg::C { from: 2, .. }));
        assert!(decode_data_header(&tx_payload[..12]).is_err());
    }

    /// Full job blobs over real plans re-encode byte-identically after a
    /// decode, for every kernel op and both flat and hierarchical routing
    /// — the program, plan, schedule, and operand codecs are all exact.
    #[test]
    fn job_roundtrips_byte_identical() {
        let a = gen::rmat(96, 900, (0.55, 0.2, 0.19), false, 11);
        let ranks = 4;
        let part = RowPartition::balanced(a.nrows, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        let sched = hierarchy::build(&plan, &topo);
        let xsched = hierarchy::sddmm_fetch(&sched);
        let mut rng = Rng::new(7);
        let b_full = Dense::random(a.nrows, 8, &mut rng);
        let x_full = Dense::random(a.nrows, 8, &mut rng);
        for op in [KernelOp::Spmm, KernelOp::FusedSddmmSpmm] {
            for use_sched in [false, true] {
                for rank in 0..ranks {
                    let (r0, r1) = part.range(rank);
                    let n = b_full.ncols;
                    let b_local =
                        Dense::from_vec(r1 - r0, n, b_full.data[r0 * n..r1 * n].to_vec());
                    let x_local = (op != KernelOp::Spmm).then(|| {
                        Dense::from_vec(r1 - r0, n, x_full.data[r0 * n..r1 * n].to_vec())
                    });
                    let (s, xs) = if use_sched {
                        (
                            Some(&sched),
                            (op != KernelOp::Spmm).then_some(&xsched),
                        )
                    } else {
                        (None, None)
                    };
                    let bytes = encode_job(
                        rank,
                        op,
                        &ExecOpts::default(),
                        &part,
                        &topo,
                        &plan,
                        s,
                        xs,
                        &blocks[rank],
                        &b_local,
                        x_local.as_ref(),
                    )
                    .unwrap();
                    let job = decode_job(&bytes).unwrap();
                    let again = encode_job_parts(
                        job.rank,
                        job.nranks,
                        job.op,
                        &job.opts,
                        &job.part,
                        &job.topo,
                        &job.plan,
                        job.sched.as_ref(),
                        &job.prog,
                        &job.blocks,
                        &job.b_local,
                        job.x_local.as_ref(),
                    )
                    .unwrap();
                    assert_eq!(bytes, again, "op {op:?} sched {use_sched} rank {rank}");
                }
            }
        }
    }

    #[test]
    fn job_rejects_corruption() {
        let a = gen::rmat(32, 200, (0.55, 0.2, 0.19), false, 5);
        let part = RowPartition::balanced(a.nrows, 2);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Column, None);
        let topo = Topology::tsubame4(2);
        let b = Dense::zeros(part.len(0), 4);
        let bytes = encode_job(
            0,
            KernelOp::Spmm,
            &ExecOpts::default(),
            &part,
            &topo,
            &plan,
            None,
            None,
            &blocks[0],
            &b,
            None,
        )
        .unwrap();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode_job(&bad).is_err());
        // Bad version.
        let mut bad = bytes.clone();
        bad[8] ^= 0xff;
        assert!(decode_job(&bad).is_err());
        // Truncation anywhere fails cleanly rather than panicking.
        for cut in [bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_job(&bytes[..cut]).is_err());
        }
    }
}
