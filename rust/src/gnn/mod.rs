//! GNN case study (paper §7.6): full-batch 2-layer GCN training where the
//! message-passing aggregation is the distributed SpMM under test.
//!
//! Forward:  H1 = relu(Â X W0),  H2 = relu(Â H1 W1),  loss = MSE(H2, Y)
//! Backward: dW1 = P1ᵀ dZ1, dH1 = Âᵀ (dZ1 W1ᵀ), dW0 = P0ᵀ dZ0
//!
//! The three Â·(dense) products per epoch run through **epoch-persistent
//! [`SpmmSession`]s** (DESIGN.md §8): the forward session freezes the Â
//! plan once, the backward session is derived from it by
//! [`crate::spmm::DistSpmm::transposed`] — a pure mirror of the forward cover, so
//! Âᵀ products cost zero extra preprocessing and *asymmetric* adjacencies
//! (directed graphs) are first-class. From the second epoch onward the
//! sessions do zero planning work and zero fresh exchange-buffer
//! allocations ([`crate::metrics::Amortization`], asserted in
//! `ablation_epoch_reuse --check` and `tests/gnn_suite.rs`). The dense
//! halves run through the L2 GCN artifacts when available.

use crate::comm::Strategy;
use crate::dense::Dense;
use crate::exec::kernel::{KernelOp, SpmmKernel};
use crate::exec::{ExecOpts, ExecStats};
use crate::sparse::{Coo, Csr};
use crate::spmm::{ExecRequest, PlanSpec, SpmmSession};
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Symmetric GCN normalization: Â = D^{-1/2} (|A| + I) D^{-1/2}.
///
/// Pinned edge-case behavior (regression-tested in `tests/gnn_suite.rs`):
///
/// - Entry **magnitudes** are used, so Â is entrywise non-negative, with
///   zeros only where the input stored explicit zeros.
/// - A unit self-loop is added to every vertex; a pre-existing diagonal
///   entry is *summed* with it (duplicate diagonal mass is kept), giving
///   unscaled Â_rr = 1 + |a_rr|.
/// - deg_r = Σ_c unscaled Â_rc ≥ 1 always — the self-loop guarantees it —
///   so the normalization never divides by ≈0. In particular an isolated
///   (zero-degree) vertex gets exactly Â_rr = 1 and cannot produce huge
///   weights. (The seed's `1e-12` clamp implied such rows could blow up;
///   it was unreachable and is replaced by this structural guarantee.)
/// - Every output entry lies in [0, 1]: |â_rc| ≤ min(deg_r, deg_c) ≤
///   √(deg_r·deg_c).
///
/// For a directed (asymmetric) graph the row sums are out-degrees, Âᵀ ≠ Â,
/// and backward products must use the mirrored transpose plan — which
/// [`Gcn`] does for every graph.
pub fn normalize_adj(a: &Csr) -> Csr {
    assert_eq!(a.nrows, a.ncols);
    let n = a.nrows;
    // |A| + I (duplicate coordinates, including any existing diagonal,
    // are summed by to_csr).
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for (k, &c) in a.row_indices(r).iter().enumerate() {
            coo.push(r, c as usize, a.row_values(r)[k].abs());
        }
        coo.push(r, r, 1.0);
    }
    let a_hat = coo.to_csr();
    let deg: Vec<f32> = (0..n)
        .map(|r| a_hat.row_values(r).iter().sum::<f32>())
        .collect();
    let mut out = a_hat;
    for r in 0..n {
        debug_assert!(deg[r] >= 1.0, "self-loop must guarantee deg ≥ 1");
        let (lo, hi) = (out.indptr[r] as usize, out.indptr[r + 1] as usize);
        for k in lo..hi {
            let c = out.indices[k] as usize;
            out.data[k] /= (deg[r] * deg[c]).sqrt();
        }
    }
    out
}

/// Dense-half compute backend: native Rust or the AOT L2 artifacts.
pub trait DenseOps: Sync {
    /// (z, h) = (h_agg·w, relu(z)).
    fn fwd(&self, h_agg: &Dense, w: &Dense) -> (Dense, Dense);
    /// (d_h_agg, d_w) given cached z and upstream dh.
    fn bwd(&self, h_agg: &Dense, w: &Dense, z: &Dense, dh: &Dense) -> (Dense, Dense);
    /// (loss, d_pred).
    fn mse(&self, pred: &Dense, target: &Dense) -> (f32, Dense);
    fn name(&self) -> &'static str;
}

/// Pure-Rust dense ops.
pub struct NativeDense;

impl DenseOps for NativeDense {
    fn fwd(&self, h_agg: &Dense, w: &Dense) -> (Dense, Dense) {
        let z = h_agg.matmul(w);
        let mut h = z.clone();
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        (z, h)
    }

    fn bwd(&self, h_agg: &Dense, w: &Dense, z: &Dense, dh: &Dense) -> (Dense, Dense) {
        let mut dz = dh.clone();
        for (d, zz) in dz.data.iter_mut().zip(&z.data) {
            if *zz <= 0.0 {
                *d = 0.0;
            }
        }
        let wt = Dense::from_fn(w.ncols, w.nrows, |i, j| w.get(j, i));
        let d_h_agg = dz.matmul(&wt);
        let d_w = h_agg.t_matmul(&dz);
        (d_h_agg, d_w)
    }

    fn mse(&self, pred: &Dense, target: &Dense) -> (f32, Dense) {
        let n = pred.data.len() as f32;
        let mut grad = Dense::zeros(pred.nrows, pred.ncols);
        // f64 loss accumulation: the loss value feeds finite-difference
        // gradient checks, where f32 summation noise would swamp the
        // ±ε differences. Gradients stay f32 (they are what training uses).
        let mut loss = 0.0f64;
        for i in 0..pred.data.len() {
            let d = pred.data[i] - target.data[i];
            loss += (d as f64) * (d as f64);
            grad.data[i] = 2.0 * d / n;
        }
        ((loss / n as f64) as f32, grad)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// L2-artifact dense ops: chunks global matrices into the artifact's row
/// block (the per-rank layout — dense halves are embarrassingly parallel in
/// a real deployment, so chunking loses nothing). Falls back to native if a
/// shape has no artifact.
pub struct PjrtDense<'a> {
    pub kernel: &'a crate::runtime::PjrtKernel,
    /// Artifact row-block size (matches aot.py GCN_VARIANTS, e.g. 512).
    pub chunk: usize,
}

impl<'a> PjrtDense<'a> {
    fn chunks(&self, m: usize) -> Option<Vec<(usize, usize)>> {
        if m % self.chunk != 0 {
            return None;
        }
        Some((0..m / self.chunk).map(|i| (i * self.chunk, (i + 1) * self.chunk)).collect())
    }

    fn slice(d: &Dense, r0: usize, r1: usize) -> Dense {
        Dense::from_vec(r1 - r0, d.ncols, d.data[r0 * d.ncols..r1 * d.ncols].to_vec())
    }
}

impl<'a> DenseOps for PjrtDense<'a> {
    fn fwd(&self, h_agg: &Dense, w: &Dense) -> (Dense, Dense) {
        let Some(chunks) = self.chunks(h_agg.nrows) else {
            return NativeDense.fwd(h_agg, w);
        };
        let mut z = Dense::zeros(h_agg.nrows, w.ncols);
        let mut h = Dense::zeros(h_agg.nrows, w.ncols);
        for (r0, r1) in chunks {
            let part = Self::slice(h_agg, r0, r1);
            match self.kernel.with_runtime(|rt| rt.gcn_fwd(&part, w)) {
                Ok((zc, hc)) => {
                    z.data[r0 * w.ncols..r1 * w.ncols].copy_from_slice(&zc.data);
                    h.data[r0 * w.ncols..r1 * w.ncols].copy_from_slice(&hc.data);
                }
                Err(_) => return NativeDense.fwd(h_agg, w),
            }
        }
        (z, h)
    }

    fn bwd(&self, h_agg: &Dense, w: &Dense, z: &Dense, dh: &Dense) -> (Dense, Dense) {
        let Some(chunks) = self.chunks(h_agg.nrows) else {
            return NativeDense.bwd(h_agg, w, z, dh);
        };
        let mut d_h_agg = Dense::zeros(h_agg.nrows, w.ncols);
        let mut d_w = Dense::zeros(w.nrows, w.ncols);
        for (r0, r1) in chunks {
            let ha = Self::slice(h_agg, r0, r1);
            let zc = Self::slice(z, r0, r1);
            let dhc = Self::slice(dh, r0, r1);
            match self
                .kernel
                .with_runtime(|rt| rt.gcn_bwd(&ha, w, &zc, &dhc))
            {
                Ok((dhac, dwc)) => {
                    d_h_agg.data[r0 * w.ncols..r1 * w.ncols].copy_from_slice(&dhac.data);
                    d_w.add_assign(&dwc);
                }
                Err(_) => return NativeDense.bwd(h_agg, w, z, dh),
            }
        }
        (d_h_agg, d_w)
    }

    fn mse(&self, pred: &Dense, target: &Dense) -> (f32, Dense) {
        let Some(chunks) = self.chunks(pred.nrows) else {
            return NativeDense.mse(pred, target);
        };
        let nchunks = chunks.len() as f32;
        let mut loss = 0.0f32;
        let mut grad = Dense::zeros(pred.nrows, pred.ncols);
        for (r0, r1) in chunks {
            let p = Self::slice(pred, r0, r1);
            let t = Self::slice(target, r0, r1);
            match self.kernel.with_runtime(|rt| rt.mse(&p, &t)) {
                Ok((l, g)) => {
                    loss += l / nchunks;
                    // Chunk grads are scaled by chunk size; rescale to global.
                    for (dst, src) in grad.data[r0 * pred.ncols..r1 * pred.ncols]
                        .iter_mut()
                        .zip(&g.data)
                    {
                        *dst = src / nchunks;
                    }
                }
                Err(_) => return NativeDense.mse(pred, target),
            }
        }
        (loss, grad)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct GcnConfig {
    pub feature_dim: usize,
    pub hidden_dim: usize,
    pub epochs: usize,
    pub lr: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for GcnConfig {
    fn default() -> Self {
        GcnConfig {
            feature_dim: 32,
            hidden_dim: 32,
            epochs: 50,
            lr: 1.0,
            log_every: 10,
            seed: 42,
        }
    }
}

/// Training output report (Tab. 3's measurements).
#[derive(Clone, Debug)]
pub struct GnnReport {
    /// (epoch, loss) samples.
    pub losses: Vec<(usize, f32)>,
    /// One-time preprocessing seconds: MWVC plan + transpose mirror +
    /// session build/warm. For [`Gcn::train_cold`] this instead accumulates
    /// the *per-epoch* re-planning the sessions amortize away.
    pub prep_secs: f64,
    pub train_secs: f64,
    /// Wall seconds inside distributed SpMM calls.
    pub spmm_secs: f64,
    pub spmm_calls: usize,
    pub inter_bytes: u64,
    pub intra_bytes: u64,
}

/// Accumulated per-product executor stats.
#[derive(Default)]
struct SpmmTally {
    secs: f64,
    calls: usize,
    inter: u64,
    intra: u64,
}

impl SpmmTally {
    fn add(&mut self, stats: &ExecStats) {
        self.secs += stats.wall_secs;
        self.calls += 1;
        self.inter += stats.total_inter_bytes();
        self.intra += stats.total_intra_bytes();
    }

    fn merge(&mut self, other: SpmmTally) {
        self.secs += other.secs;
        self.calls += other.calls;
        self.inter += other.inter;
        self.intra += other.intra;
    }

    fn merge_into(self, report: &mut GnnReport) {
        report.spmm_secs += self.secs;
        report.spmm_calls += self.calls;
        report.inter_bytes += self.inter;
        report.intra_bytes += self.intra;
    }
}

/// One epoch's products and gradients, generic over how the two sparse
/// operators are applied (persistent sessions in [`Gcn::train`], cold
/// per-epoch plans in [`Gcn::train_cold`] — bit-identical either way).
#[allow(clippy::too_many_arguments)]
fn epoch_products(
    x: &Dense,
    y: &Dense,
    w0: &Dense,
    w1: &Dense,
    dense: &dyn DenseOps,
    p0: &mut Dense,
    p1: &mut Dense,
    dh1: &mut Dense,
    spmm_fwd: &mut dyn FnMut(&Dense, &mut Dense),
    spmm_bwd: &mut dyn FnMut(&Dense, &mut Dense),
) -> (f32, Dense, Dense) {
    // Forward.
    spmm_fwd(x, p0); // Â X
    let (z0, h1) = dense.fwd(p0, w0);
    spmm_fwd(&h1, p1); // Â H1
    let (z1, h2) = dense.fwd(p1, w1);
    let (loss, dh2) = dense.mse(&h2, y);
    // Backward.
    let (dp1, dw1) = dense.bwd(p1, w1, &z1, &dh2);
    spmm_bwd(&dp1, dh1); // Âᵀ (dZ1 W1ᵀ) — the mirrored transpose plan
    let (_, dw0) = dense.bwd(p0, w0, &z0, dh1);
    (loss, dw0, dw1)
}

/// A planned 2-layer GCN over a (possibly asymmetric) graph.
pub struct Gcn {
    /// Epoch-persistent Â session (two products per epoch).
    pub fwd: SpmmSession,
    /// Epoch-persistent Âᵀ session, mirrored via
    /// [`crate::spmm::DistSpmm::transposed`].
    pub bwd: SpmmSession,
    /// The normalized adjacency (kept for the cold-execution ablation and
    /// reference checks).
    pub a_hat: Csr,
    pub x: Dense,
    pub y: Dense,
    pub w0: Dense,
    pub w1: Dense,
    // Persistent aggregation outputs — the exchange path allocates nothing
    // per epoch.
    p0: Dense,
    p1: Dense,
    dh1: Dense,
    cfg: GcnConfig,
    strategy: Strategy,
    hierarchical: bool,
    opts: ExecOpts,
}

impl Gcn {
    /// Plan the GCN: normalize the adjacency, build the SHIRO plan
    /// (strategy + hierarchy) once, mirror it for Âᵀ, freeze both into
    /// sessions warmed for the training widths, and synthesize
    /// features/targets/weights.
    pub fn new(
        adj: &Csr,
        strategy: Strategy,
        topo: Topology,
        hierarchical: bool,
        cfg: GcnConfig,
    ) -> Gcn {
        let a_hat = normalize_adj(adj);
        let dist =
            PlanSpec::new(topo).strategy(strategy).hierarchical(hierarchical).plan(&a_hat);
        // Backward products mirror the forward plan — no re-cover, no
        // re-cost, and correct even when Âᵀ ≠ Â (directed graphs).
        let dist_t = dist.transposed();
        let opts = ExecOpts::default();
        let mut fwd = dist.into_session(opts, true);
        let mut bwd = dist_t.into_session(opts, true);
        fwd.warm(cfg.feature_dim.max(cfg.hidden_dim));
        bwd.warm(cfg.hidden_dim);
        let n = adj.nrows;
        let mut rng = Rng::new(cfg.seed);
        let x = Dense::random(n, cfg.feature_dim, &mut rng);
        // Smooth synthetic target: one round of propagation of a random
        // signal (gives the GCN something learnable).
        let y_raw = Dense::random(n, cfg.hidden_dim, &mut rng);
        let mut y = a_hat.spmm(&y_raw);
        for v in y.data.iter_mut() {
            *v = v.max(0.0);
        }
        let scale = (1.0 / cfg.feature_dim as f32).sqrt();
        let mut w_rng = Rng::new(cfg.seed ^ xw0w1());
        let mut wdata = |rows: usize, cols: usize| -> Dense {
            let data = (0..rows * cols)
                .map(|_| (w_rng.f32() * 2.0 - 1.0) * scale)
                .collect();
            Dense::from_vec(rows, cols, data)
        };
        let w0 = wdata(cfg.feature_dim, cfg.hidden_dim);
        let w1 = wdata(cfg.hidden_dim, cfg.hidden_dim);
        Gcn {
            fwd,
            bwd,
            a_hat,
            x,
            y,
            w0,
            w1,
            p0: Dense::zeros(0, 0),
            p1: Dense::zeros(0, 0),
            dh1: Dense::zeros(0, 0),
            cfg,
            strategy,
            hierarchical,
            opts,
        }
    }

    /// One-time preprocessing seconds: MWVC plan, transpose mirror, and
    /// session build/warm (the Tab. 3 prep column).
    pub fn prep_secs(&self) -> f64 {
        self.fwd.dist().prep_secs
            + self.bwd.dist().prep_secs
            + self.fwd.amortization().build_secs
            + self.bwd.amortization().build_secs
    }

    /// Change executor scheduling for both sessions (and the cold path).
    pub fn set_exec_opts(&mut self, opts: ExecOpts) {
        self.opts = opts;
        self.fwd.set_opts(opts);
        self.bwd.set_opts(opts);
    }

    /// Loss and weight gradients at the current parameters, **without**
    /// updating them — the entry point for finite-difference gradient
    /// checks (`tests/gnn_suite.rs`). Exactly one epoch's forward+backward
    /// through the persistent sessions.
    pub fn loss_and_grads(
        &mut self,
        kernel: &(dyn SpmmKernel + Sync),
        dense: &dyn DenseOps,
    ) -> (f32, Dense, Dense) {
        let (loss, dw0, dw1, _) = self.session_epoch(kernel, dense);
        (loss, dw0, dw1)
    }

    fn session_epoch(
        &mut self,
        kernel: &(dyn SpmmKernel + Sync),
        dense: &dyn DenseOps,
    ) -> (f32, Dense, Dense, SpmmTally) {
        let Gcn { fwd, bwd, x, y, w0, w1, p0, p1, dh1, .. } = self;
        let mut tally_f = SpmmTally::default();
        let mut tally_b = SpmmTally::default();
        let mut spmm_fwd = |m: &Dense, out: &mut Dense| {
            let stats = fwd
                .execute_into(&ExecRequest::spmm(m).kernel(kernel), out)
                .expect("thread-backend SpMM");
            tally_f.add(&stats);
        };
        let mut spmm_bwd = |m: &Dense, out: &mut Dense| {
            let stats = bwd
                .execute_into(&ExecRequest::spmm(m).kernel(kernel), out)
                .expect("thread-backend SpMM");
            tally_b.add(&stats);
        };
        let (loss, dw0, dw1) =
            epoch_products(x, y, w0, w1, dense, p0, p1, dh1, &mut spmm_fwd, &mut spmm_bwd);
        tally_f.merge(tally_b);
        (loss, dw0, dw1, tally_f)
    }

    fn sgd(&mut self, dw0: &Dense, dw1: &Dense) {
        for (w, g) in self.w0.data.iter_mut().zip(&dw0.data) {
            *w -= self.cfg.lr * g;
        }
        for (w, g) in self.w1.data.iter_mut().zip(&dw1.data) {
            *w -= self.cfg.lr * g;
        }
    }

    fn log_loss(&self, report: &mut GnnReport, epoch: usize, loss: f32) {
        if epoch % self.cfg.log_every == 0 || epoch + 1 == self.cfg.epochs {
            report.losses.push((epoch, loss));
        }
    }

    /// Full-batch training loop. Every Â·M product is a distributed SpMM
    /// through the persistent sessions; from epoch 2 onward the sessions
    /// are provably plan-free and allocation-free
    /// ([`SpmmSession::amortization`]).
    pub fn train(
        &mut self,
        kernel: &(dyn SpmmKernel + Sync),
        dense: &dyn DenseOps,
    ) -> GnnReport {
        // Align the sessions with this kernel's tiling preference up front
        // (PJRT kernels take whole blocks) so the rebuild is counted as
        // prep, not as the first epoch's plan time.
        self.fwd.retarget(kernel.prefers_tiles());
        self.bwd.retarget(kernel.prefers_tiles());
        let mut report = GnnReport {
            losses: Vec::new(),
            prep_secs: self.prep_secs(),
            train_secs: 0.0,
            spmm_secs: 0.0,
            spmm_calls: 0,
            inter_bytes: 0,
            intra_bytes: 0,
        };
        let t_train = std::time::Instant::now();
        for epoch in 0..self.cfg.epochs {
            let (loss, dw0, dw1, tally) = self.session_epoch(kernel, dense);
            tally.merge_into(&mut report);
            self.sgd(&dw0, &dw1);
            self.log_loss(&mut report, epoch, loss);
        }
        report.train_secs = t_train.elapsed().as_secs_f64();
        report
    }

    /// The ablation control for `ablation_epoch_reuse`: every epoch
    /// re-enters [`crate::spmm::DistSpmm`] cold — fresh plan, fresh transpose mirror,
    /// fresh executor state — and `report.prep_secs` accumulates the
    /// repeated planning the sessions amortize away. Results are
    /// bit-identical to [`Gcn::train`]: the executor applies every
    /// scatter-add in canonical order whichever way its state was built.
    pub fn train_cold(
        &mut self,
        kernel: &(dyn SpmmKernel + Sync),
        dense: &dyn DenseOps,
    ) -> GnnReport {
        let mut report = GnnReport {
            losses: Vec::new(),
            prep_secs: 0.0,
            train_secs: 0.0,
            spmm_secs: 0.0,
            spmm_calls: 0,
            inter_bytes: 0,
            intra_bytes: 0,
        };
        let t_train = std::time::Instant::now();
        for epoch in 0..self.cfg.epochs {
            let t_plan = std::time::Instant::now();
            let fdist = PlanSpec::new(self.fwd.dist().topo.clone())
                .strategy(self.strategy)
                .hierarchical(self.hierarchical)
                .plan(&self.a_hat);
            let bdist = fdist.transposed();
            report.prep_secs += t_plan.elapsed().as_secs_f64();
            let opts = self.opts;
            let Gcn { x, y, w0, w1, p0, p1, dh1, .. } = &mut *self;
            let mut tally = SpmmTally::default();
            let mut tally_b = SpmmTally::default();
            let mut spmm_fwd = |m: &Dense, out: &mut Dense| {
                let (c, stats) = fdist
                    .execute(&ExecRequest::spmm(m).kernel(kernel).opts(opts))
                    .expect("thread-backend SpMM")
                    .into_dense();
                *out = c;
                tally.add(&stats);
            };
            let mut spmm_bwd = |m: &Dense, out: &mut Dense| {
                let (c, stats) = bdist
                    .execute(&ExecRequest::spmm(m).kernel(kernel).opts(opts))
                    .expect("thread-backend SpMM")
                    .into_dense();
                *out = c;
                tally_b.add(&stats);
            };
            let (loss, dw0, dw1) =
                epoch_products(x, y, w0, w1, dense, p0, p1, dh1, &mut spmm_fwd, &mut spmm_bwd);
            tally.merge(tally_b);
            tally.merge_into(&mut report);
            self.sgd(&dw0, &dw1);
            self.log_loss(&mut report, epoch, loss);
        }
        report.train_secs = t_train.elapsed().as_secs_f64();
        report
    }
}

/// GAT-style attention propagation layer (softmax-free linear attention):
/// one round of Z = X·W, E = Â ⊙ (Z·Zᵀ) (edge scores on the adjacency
/// pattern), H = relu(E·Z) — the SDDMM→SpMM composition attention GNN
/// message passing reduces to. Both sparse kernels run through **one
/// kernel-generic [`SpmmSession`]** frozen from the Â plan, exactly
/// [`Gcn`]'s session machinery: the plan is built once, the fused forward
/// ([`Gat::forward`]) computes scores and aggregates them in a single
/// exchange, and [`Gat::forward_two_pass`] is the ablation control that
/// materializes E first (the path `ablation_fused` charges for the extra
/// B-side re-shipment plus the edge-value gather).
pub struct Gat {
    /// Kernel-generic session over the frozen Â plan (serves SDDMM and
    /// fused [`ExecRequest`]s through [`SpmmSession::execute`]).
    pub session: SpmmSession,
    /// Normalized adjacency, kept for oracle checks and the two-pass
    /// control's SpMM half.
    pub a_hat: Csr,
    /// Projection weights: scores and aggregation both use Z = X·W (the
    /// single-operand form that makes the fused kernel exchange-free
    /// beyond SDDMM's own traffic).
    pub w: Dense,
}

impl Gat {
    /// Plan the layer: normalize the adjacency, freeze one SHIRO plan into
    /// a session, warm it for the fused kernel at `out_dim`, and
    /// initialize the projection.
    pub fn new(
        adj: &Csr,
        strategy: Strategy,
        topo: Topology,
        hierarchical: bool,
        feature_dim: usize,
        out_dim: usize,
        seed: u64,
    ) -> Gat {
        let a_hat = normalize_adj(adj);
        let dist =
            PlanSpec::new(topo).strategy(strategy).hierarchical(hierarchical).plan(&a_hat);
        let mut session = dist.into_session(ExecOpts::default(), true);
        session.warm_kernel(KernelOp::FusedSddmmSpmm, out_dim);
        let scale = (1.0 / feature_dim as f32).sqrt();
        let mut rng = Rng::new(seed ^ xw0w1());
        let data = (0..feature_dim * out_dim)
            .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
            .collect();
        Gat { session, a_hat, w: Dense::from_vec(feature_dim, out_dim, data) }
    }

    fn project(&self, x: &Dense) -> Dense {
        assert_eq!(x.ncols, self.w.nrows, "feature dim mismatch");
        x.matmul(&self.w)
    }

    fn relu(mut h: Dense) -> Dense {
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        h
    }

    /// Fused forward pass: one distributed exchange computes the edge
    /// scores *and* aggregates with them.
    pub fn forward(
        &mut self,
        x: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        let z = self.project(x);
        let (h, stats) = self
            .session
            .execute(&ExecRequest::fused(&z, &z).kernel(kernel))
            .expect("thread-backend fused kernel")
            .into_dense();
        (Self::relu(h), stats)
    }

    /// Two-pass ablation control: distributed SDDMM materializes E through
    /// the same session, then the aggregation E·Z runs serially here. The
    /// returned stats cover the SDDMM exchange only — in a distributed
    /// two-pass deployment the SpMM pass would additionally re-ship the
    /// plan's whole B side and gather the row-served edge values home,
    /// which is exactly the traffic `ablation_fused` charges against it.
    pub fn forward_two_pass(
        &mut self,
        x: &Dense,
        kernel: &(dyn SpmmKernel + Sync),
    ) -> (Dense, ExecStats) {
        let z = self.project(x);
        let (e, stats) = self
            .session
            .execute(&ExecRequest::sddmm(&z, &z).kernel(kernel))
            .expect("thread-backend SDDMM")
            .into_sparse();
        (Self::relu(e.spmm(&z)), stats)
    }

    /// Serial oracle for the whole layer.
    pub fn forward_serial(&self, x: &Dense) -> Dense {
        let z = self.project(x);
        Self::relu(self.a_hat.sddmm(&z, &z).spmm(&z))
    }
}

// Small seed-mixing helper (avoids a magic literal at the use site).
#[allow(non_snake_case)]
fn xw0w1() -> u64 {
    0x57_1A_C0_DE
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cover::Solver;
    use crate::exec::kernel::NativeKernel;
    use crate::sparse::gen;

    #[test]
    fn normalize_adj_row_sums_bounded() {
        let a = gen::rmat(64, 600, (0.5, 0.2, 0.2), true, 1);
        let n = normalize_adj(&a);
        n.validate().unwrap();
        // Symmetric in, symmetric out.
        let t = n.transpose();
        assert_eq!(n.indices, t.indices);
        for r in 0..n.nrows {
            let s: f32 = n.row_values(r).iter().sum();
            // Symmetric normalization bounds row sums by sqrt(deg) ratios;
            // they stay O(1) rather than exactly 1.
            assert!(s <= 3.0, "row {r} sum {s}");
            assert!(n.row_values(r).iter().all(|&v| v <= 1.0 + 1e-5));
            assert!(n.row_nnz(r) >= 1, "diagonal must exist");
        }
    }

    #[test]
    fn gcn_loss_decreases() {
        let adj = gen::rmat(128, 1000, (0.5, 0.2, 0.2), true, 2);
        let cfg = GcnConfig {
            epochs: 40,
            log_every: 39,
            lr: 3.0,
            ..Default::default()
        };
        let mut gcn = Gcn::new(
            &adj,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(4),
            true,
            cfg,
        );
        let report = gcn.train(&NativeKernel, &NativeDense);
        assert!(report.losses.len() >= 2);
        let first = report.losses.first().unwrap().1;
        let last = report.losses.last().unwrap().1;
        assert!(
            last < first * 0.9,
            "loss did not decrease: {first} → {last}"
        );
        assert_eq!(report.spmm_calls, 40 * 3);
        assert!(report.spmm_secs > 0.0);
        // The session contract held throughout training.
        assert!(gcn.fwd.amortization().steady_state());
        assert!(gcn.bwd.amortization().steady_state());
        assert_eq!(gcn.fwd.amortization().total_allocs(), 0, "warmed at plan time");
        assert_eq!(gcn.bwd.amortization().total_allocs(), 0);
    }

    #[test]
    fn gcn_same_result_all_strategies() {
        // The communication strategy must not change the numerics.
        let adj = gen::rmat(64, 500, (0.5, 0.2, 0.2), true, 3);
        let cfg = GcnConfig { epochs: 3, log_every: 1, ..Default::default() };
        let mut reports = Vec::new();
        for (strategy, hier) in [
            (Strategy::Column, false),
            (Strategy::Joint(Solver::Koenig), false),
            (Strategy::Joint(Solver::Koenig), true),
            (Strategy::Adaptive, true),
        ] {
            let mut gcn = Gcn::new(&adj, strategy, Topology::tsubame4(4), hier, cfg.clone());
            let r = gcn.train(&NativeKernel, &NativeDense);
            reports.push(r.losses.last().unwrap().1);
        }
        for w in reports.windows(2) {
            assert!(
                (w[0] - w[1]).abs() < 1e-4 * w[0].abs().max(1.0),
                "strategies disagree: {reports:?}"
            );
        }
    }

    #[test]
    fn gat_fused_matches_serial_and_two_pass() {
        let adj = gen::rmat(128, 1200, (0.5, 0.2, 0.2), true, 9);
        let mut rng = Rng::new(15);
        let x = Dense::random(128, 16, &mut rng);
        for hier in [false, true] {
            let mut gat = Gat::new(
                &adj,
                Strategy::Joint(Solver::Koenig),
                Topology::tsubame4(4),
                hier,
                16,
                8,
                7,
            );
            let want = gat.forward_serial(&x);
            // The two-pass control is bitwise-serial: distributed SDDMM is
            // bitwise-exact and its SpMM half runs serially here.
            let (two_pass, _) = gat.forward_two_pass(&x, &NativeKernel);
            assert_eq!(two_pass.data, want.data, "hier={hier}");
            // Fused agrees numerically (distributed fold order differs).
            let (fused, _) = gat.forward(&x, &NativeKernel);
            let err = want.diff_norm(&fused) / (want.max_abs() as f64 + 1e-30);
            assert!(err < 1e-3, "hier={hier}: fused rel err {err}");
        }
    }

    #[test]
    fn gat_fused_deterministic_and_steady_state() {
        use crate::exec::ExecOpts;
        let adj = gen::rmat(128, 1100, (0.55, 0.2, 0.19), false, 11);
        let mut gat = Gat::new(
            &adj,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(4),
            true,
            8,
            8,
            3,
        );
        let mut rng = Rng::new(16);
        let x = Dense::random(128, 8, &mut rng);
        let (h0, _) = gat.forward(&x, &NativeKernel);
        // Overlap off and worker caps must not change the bits.
        for opts in [ExecOpts::sequential(), ExecOpts { workers: 2, ..ExecOpts::default() }] {
            gat.session.set_opts(opts);
            let (h, _) = gat.forward(&x, &NativeKernel);
            assert_eq!(h.data, h0.data, "{opts:?}");
        }
        gat.session.set_opts(ExecOpts::default());
        for _ in 0..2 {
            gat.forward(&x, &NativeKernel);
        }
        // Warmed at construction: the fused kernel never allocates and
        // never plans inside forward.
        let am = gat.session.amortization_for(KernelOp::FusedSddmmSpmm);
        assert!(am.steady_state());
        assert_eq!(am.total_allocs(), 0, "warmed GAT session allocated");
        assert!(am.plan_secs.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn asymmetric_adjacency_trains_through_transpose_plan() {
        // A directed graph: Âᵀ ≠ Â, so backward products *must* route
        // through the mirrored transpose plan to be correct. Training
        // still reduces the loss.
        let adj = gen::rmat(128, 1200, (0.6, 0.25, 0.1), false, 7);
        assert_ne!(
            normalize_adj(&adj).transpose().indices,
            normalize_adj(&adj).indices,
            "test graph must actually be asymmetric"
        );
        let cfg = GcnConfig { epochs: 30, log_every: 29, lr: 2.0, ..Default::default() };
        let mut gcn = Gcn::new(
            &adj,
            Strategy::Joint(Solver::Koenig),
            Topology::tsubame4(4),
            true,
            cfg,
        );
        let report = gcn.train(&NativeKernel, &NativeDense);
        let first = report.losses.first().unwrap().1;
        let last = report.losses.last().unwrap().1;
        assert!(last < first, "directed training diverged: {first} → {last}");
        assert!(gcn.fwd.amortization().steady_state());
        assert!(gcn.bwd.amortization().steady_state());
    }
}
