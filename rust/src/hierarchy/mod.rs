//! Hierarchical communication strategy (paper §6): map a [`CommPlan`] onto a
//! two-tier [`Topology`] by deduplicating inter-group B transfers
//! (3-step column-based scheme, §6.1.2) and pre-aggregating partial C rows
//! inside source groups (2-stage row-based scheme), then schedule the two
//! patterns in complementary overlapped stages (§6.2, Alg. 1):
//!
//! - **Stage I**: inter-group B fetch (column-based ①) ∥ intra-group C
//!   pre-aggregation (row-based ①).
//! - **Stage II**: inter-group aggregated-C transmission (row-based ②) ∥
//!   intra-group B distribution (column-based ②).

use crate::comm::CommPlan;
use crate::topology::Topology;
use std::collections::BTreeMap;

/// Canonical Alg. 1 phase labels, shared by the simulator's stage names,
/// the executor's per-rank phase log, and both chrome-trace exporters —
/// traces from `sim::trace` and from the executed pipeline line up by name.
pub mod phase {
    /// Stage I, inter-group: deduplicated B fetch (col ①).
    pub const S1_INTER_B: &str = "stageI: interB";
    /// Stage I, intra-group: C pre-aggregation + same-group row-based (row ①).
    pub const S1_INTRA_C: &str = "stageI: intraC";
    /// Stage II, inter-group: aggregated C transmission (row ②).
    pub const S2_INTER_C: &str = "stageII: interC";
    /// Stage II, intra-group: B distribution + same-group column-based (col ②).
    pub const S2_INTRA_B: &str = "stageII: intraB";
    /// Local diagonal-block SpMM (workflow step 3, overlappable compute).
    pub const COMPUTE_LOCAL: &str = "compute: local";
    /// Remote column-based SpMM + result aggregation (workflow step 5).
    pub const COMPUTE_REMOTE: &str = "compute: remote";
    /// Executor only: blocked in `recv` with no compute left to overlap.
    pub const IDLE: &str = "idle: waiting";
    /// SDDMM/fused: dense X rows fetched by the row-serving side (the
    /// plan's C covers reversed into stage-I fetches — DESIGN.md §9).
    pub const S1_FETCH_X: &str = "stageI: fetchX";
    /// SDDMM/fused: representative redistribution of a fetched X union to
    /// its in-group row-servers (mirror of stage-II B distribution).
    pub const S2_INTRA_X: &str = "stageII: intraX";
    /// 1.5D replication: sparsity-aware partial-C reduce-scatter, member
    /// accumulator → group home (intra-group).
    pub const RED_INTRA: &str = "reduce: intraC";
}

/// Hierarchical column-based flow: source rank `src` serves destination
/// group `dst_group` through one deduplicated inter-group transfer to `rep`,
/// which redistributes intra-group.
#[derive(Clone, Debug, PartialEq)]
pub struct BFlow {
    pub src: usize,
    pub dst_group: usize,
    /// Representative (first hop) inside `dst_group`.
    pub rep: usize,
    /// Deduplicated union of B-row indices (src-local), sorted. This is
    /// what crosses the inter-group link exactly once.
    pub rows: Vec<u32>,
    /// (consumer rank, its required subset of `rows`).
    pub consumers: Vec<(usize, Vec<u32>)>,
}

/// Hierarchical row-based flow: the members of `src_group` produce partial C
/// rows for destination `dst`; `rep` pre-aggregates rows with equal index
/// and sends the aggregate across the inter-group link once.
#[derive(Clone, Debug, PartialEq)]
pub struct CFlow {
    pub dst: usize,
    pub src_group: usize,
    pub rep: usize,
    /// Union of C-row indices (dst-local), sorted — the aggregated payload.
    pub rows: Vec<u32>,
    /// (producer rank, its produced C-row subset).
    pub producers: Vec<(usize, Vec<u32>)>,
}

/// The two-stage overlapped hierarchical schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HierSchedule {
    pub nranks: usize,
    pub b_flows: Vec<BFlow>,
    pub c_flows: Vec<CFlow>,
    /// Same-group column-based transfers (no hierarchy needed): (src, dst,
    /// src-local B rows). Scheduled in Stage II with B distribution.
    pub direct_b: Vec<(usize, usize, Vec<u32>)>,
    /// Same-group row-based transfers: (src, dst, dst-local C rows).
    /// Scheduled in Stage I with the C-aggregation alltoall.
    pub direct_c: Vec<(usize, usize, Vec<u32>)>,
}

fn union_sorted(sets: &[&[u32]]) -> Vec<u32> {
    let mut all: Vec<u32> = sets.iter().flat_map(|s| s.iter().copied()).collect();
    all.sort_unstable();
    all.dedup();
    all
}

/// Build the hierarchical schedule from a flat communication plan.
pub fn build(plan: &CommPlan, topo: &Topology) -> HierSchedule {
    assert_eq!(plan.nranks, topo.nranks);
    let n = plan.nranks;
    let mut b_groups: BTreeMap<(usize, usize), Vec<(usize, Vec<u32>)>> = BTreeMap::new();
    let mut c_groups: BTreeMap<(usize, usize), Vec<(usize, Vec<u32>)>> = BTreeMap::new();
    let mut direct_b = Vec::new();
    let mut direct_c = Vec::new();

    for p in 0..n {
        for q in 0..n {
            if p == q {
                continue;
            }
            let pair = &plan.pairs[p][q];
            // Column-based rows: q → p. Sparsity-oblivious pairs transfer
            // the whole block.
            let b_rows: Vec<u32> = if pair.full_block {
                (0..plan.block_rows[q] as u32).collect()
            } else {
                pair.b_rows.clone()
            };
            if !b_rows.is_empty() {
                if topo.group_of(p) == topo.group_of(q) {
                    direct_b.push((q, p, b_rows));
                } else {
                    b_groups
                        .entry((q, topo.group_of(p)))
                        .or_default()
                        .push((p, b_rows));
                }
            }
            // Row-based rows: q computes partials for p.
            if !pair.c_rows.is_empty() {
                if topo.group_of(p) == topo.group_of(q) {
                    direct_c.push((q, p, pair.c_rows.clone()));
                } else {
                    c_groups
                        .entry((p, topo.group_of(q)))
                        .or_default()
                        .push((q, pair.c_rows.clone()));
                }
            }
        }
    }

    let b_flows = b_groups
        .into_iter()
        .map(|((src, dst_group), consumers)| {
            let rows = union_sorted(
                &consumers.iter().map(|(_, r)| r.as_slice()).collect::<Vec<_>>(),
            );
            // Single consumer: skip the extra hop, deliver directly.
            let rep = if consumers.len() == 1 {
                consumers[0].0
            } else {
                topo.representative(dst_group, src)
            };
            BFlow { src, dst_group, rep, rows, consumers }
        })
        .collect();

    let c_flows = c_groups
        .into_iter()
        .map(|((dst, src_group), producers)| {
            let rows = union_sorted(
                &producers.iter().map(|(_, r)| r.as_slice()).collect::<Vec<_>>(),
            );
            let rep = if producers.len() == 1 {
                producers[0].0
            } else {
                topo.representative(src_group, dst)
            };
            CFlow { dst, src_group, rep, rows, producers }
        })
        .collect();

    HierSchedule { nranks: n, b_flows, c_flows, direct_b, direct_c }
}

/// Mirror a schedule for the transposed plan ([`crate::comm::CommPlan::
/// transpose`]): transposing the matrix exchanges the two hierarchical
/// patterns wholesale. A deduplicated inter-group B fetch (src → group)
/// becomes a pre-aggregated C transmission (group → dst) with the *same*
/// union rows, representative, and per-rank subsets — and vice versa;
/// same-group direct transfers swap kind with src/dst reversed. No plan
/// re-scan, no union recomputation: `mirror(build(P)) == build(Pᵀ)`
/// (pinned by test), so the backward schedule is derived in O(schedule).
pub fn mirror(sched: &HierSchedule) -> HierSchedule {
    let b_flows = sched
        .c_flows
        .iter()
        .map(|f| BFlow {
            src: f.dst,
            dst_group: f.src_group,
            rep: f.rep,
            rows: f.rows.clone(),
            consumers: f.producers.clone(),
        })
        .collect();
    let c_flows = sched
        .b_flows
        .iter()
        .map(|f| CFlow {
            dst: f.src,
            src_group: f.dst_group,
            rep: f.rep,
            rows: f.rows.clone(),
            producers: f.consumers.clone(),
        })
        .collect();
    // Direct transfers swap kind and direction. `build` emits them in
    // (dst, src) scan order; restore it after the swap.
    let mut direct_b: Vec<(usize, usize, Vec<u32>)> = sched
        .direct_c
        .iter()
        .map(|(src, dst, rows)| (*dst, *src, rows.clone()))
        .collect();
    direct_b.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
    let mut direct_c: Vec<(usize, usize, Vec<u32>)> = sched
        .direct_b
        .iter()
        .map(|(src, dst, rows)| (*dst, *src, rows.clone()))
        .collect();
    direct_c.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
    HierSchedule { nranks: sched.nranks, b_flows, c_flows, direct_b, direct_c }
}

impl HierSchedule {
    /// Stage-I-only degeneration of this schedule: the B-side flows
    /// (deduplicated inter-group fetches plus same-group direct transfers)
    /// with the row-based C side dropped entirely. This is the schedule a
    /// pure dense-row-fetch kernel consumes: SDDMM's output is sparse at
    /// A's pattern, so there is no partial-C aggregation and no stage-II
    /// inter-group transmission — the hierarchy *itself* degenerates,
    /// rather than the executor special-casing empty aggregation
    /// (DESIGN.md §9). The kept flows still perform their stage-II
    /// intra-group rep redistribution — that second hop is part of the
    /// fetch pattern, not of the dropped C side.
    pub fn stage1_fetch(&self) -> HierSchedule {
        HierSchedule {
            nranks: self.nranks,
            b_flows: self.b_flows.clone(),
            c_flows: Vec::new(),
            direct_b: self.direct_b.clone(),
            direct_c: Vec::new(),
        }
    }
}

/// The X-side fetch schedule for SDDMM and the fused kernel: every
/// row-based C flow of `sched` reversed into a dense-row fetch. In SpMM,
/// `sched`'s C flows carry *computed partials* q→p with in-group
/// pre-aggregation; in SDDMM those same covers describe which X rows of p
/// the row-serving ranks q need — the identical union crosses the
/// inter-group link once (p → rep of q's group), and the rep redistributes
/// per-consumer subsets, exactly a B flow in the reverse direction. That
/// is [`mirror`]'s B side, so the X schedule is
/// `mirror(sched).stage1_fetch()`: volume-preserving (same unions, same
/// subsets, direction reversed) and aggregation-free.
pub fn sddmm_fetch(sched: &HierSchedule) -> HierSchedule {
    mirror(sched).stage1_fetch()
}

/// A point-to-point message with a tier-stage label, consumed by the
/// simulator and (with payload attached) by the executor.
#[derive(Clone, Debug, PartialEq)]
pub struct StageMsg {
    pub src: usize,
    pub dst: usize,
    /// Number of dense rows carried.
    pub rows: u64,
}

/// The four message sets of the overlapped schedule (Fig. 6f).
#[derive(Clone, Debug, Default)]
pub struct StagedMessages {
    /// Stage I, inter-group: deduplicated B fetch (col ①).
    pub s1_inter_b: Vec<StageMsg>,
    /// Stage I, intra-group: C pre-aggregation + same-group row-based (row ①).
    pub s1_intra_c: Vec<StageMsg>,
    /// Stage II, inter-group: aggregated C transmission (row ②).
    pub s2_inter_c: Vec<StageMsg>,
    /// Stage II, intra-group: B distribution + same-group column-based (col ②).
    pub s2_intra_b: Vec<StageMsg>,
}

/// One send-side step of a rank's overlapped program (Alg. 1). Indices
/// point into the owning [`HierSchedule`]'s vectors, so both the executor
/// (which needs the payload row lists) and the simulator lowering (which
/// only needs sizes) resolve the *same* schedule entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Stage I ①: ship the deduplicated B union to `b_flows[i].rep` (inter).
    InterB(usize),
    /// Stage I ① row-based: compute this rank's partial C rows for
    /// `c_flows[i]` and route them to the rep (or keep, when rep == self).
    ProduceC(usize),
    /// Stage I intra: same-group direct row-based transfer `direct_c[i]`.
    DirectC(usize),
    /// Stage II intra: same-group direct column-based transfer `direct_b[i]`.
    DirectB(usize),
}

impl HierSchedule {
    /// The ordered send program of `rank` under Alg. 1: inter-group B flows
    /// first (they unblock remote groups), then row-based partial
    /// production, then the same-group direct transfers. The executor's
    /// pipeline runs exactly this sequence, and [`HierSchedule::messages`]
    /// is folded from the union of all ranks' programs — the simulated and
    /// executed orderings are provably the same object.
    pub fn rank_steps(&self, rank: usize) -> Vec<Step> {
        let mut steps = Vec::new();
        for (i, f) in self.b_flows.iter().enumerate() {
            if f.src == rank {
                steps.push(Step::InterB(i));
            }
        }
        for (i, f) in self.c_flows.iter().enumerate() {
            if f.producers.iter().any(|(p, _)| *p == rank) {
                steps.push(Step::ProduceC(i));
            }
        }
        for (i, (src, _, _)) in self.direct_c.iter().enumerate() {
            if *src == rank {
                steps.push(Step::DirectC(i));
            }
        }
        for (i, (src, _, _)) in self.direct_b.iter().enumerate() {
            if *src == rank {
                steps.push(Step::DirectB(i));
            }
        }
        steps
    }

    /// Canonical (phase, message) stream: every rank's [`Step`] program in
    /// rank order, followed by the reactive second hops that the reps emit
    /// on arrival (stage-II B distribution and aggregated-C transmission).
    /// Both the sim lowering ([`HierSchedule::messages`]) and the executor
    /// consume this stream — one through byte counts, one with payloads.
    pub fn phase_messages(&self) -> Vec<(&'static str, StageMsg)> {
        let mut out = Vec::new();
        for rank in 0..self.nranks {
            for step in self.rank_steps(rank) {
                match step {
                    Step::InterB(i) => {
                        let f = &self.b_flows[i];
                        out.push((
                            phase::S1_INTER_B,
                            StageMsg { src: f.src, dst: f.rep, rows: f.rows.len() as u64 },
                        ));
                    }
                    Step::ProduceC(i) => {
                        let f = &self.c_flows[i];
                        // Only the rep→self keep is silent; producers that
                        // are not the rep send their partials intra-group.
                        if f.rep != rank {
                            let rows = f
                                .producers
                                .iter()
                                .find(|(p, _)| *p == rank)
                                .map(|(_, r)| r.len() as u64)
                                .unwrap_or(0);
                            out.push((
                                phase::S1_INTRA_C,
                                StageMsg { src: rank, dst: f.rep, rows },
                            ));
                        }
                    }
                    Step::DirectC(i) => {
                        let (src, dst, rows) = &self.direct_c[i];
                        out.push((
                            phase::S1_INTRA_C,
                            StageMsg { src: *src, dst: *dst, rows: rows.len() as u64 },
                        ));
                    }
                    Step::DirectB(i) => {
                        let (src, dst, rows) = &self.direct_b[i];
                        out.push((
                            phase::S2_INTRA_B,
                            StageMsg { src: *src, dst: *dst, rows: rows.len() as u64 },
                        ));
                    }
                }
            }
        }
        // Reactive hops, in schedule order (deterministic): the rep
        // redistributes each arrived B flow to its in-group consumers, and
        // ships each completed C aggregate across the inter-group link.
        for f in &self.b_flows {
            for (consumer, rows) in &f.consumers {
                if *consumer != f.rep {
                    out.push((
                        phase::S2_INTRA_B,
                        StageMsg { src: f.rep, dst: *consumer, rows: rows.len() as u64 },
                    ));
                }
            }
        }
        for f in &self.c_flows {
            out.push((
                phase::S2_INTER_C,
                StageMsg { src: f.rep, dst: f.dst, rows: f.rows.len() as u64 },
            ));
        }
        out
    }

    /// Lower the schedule to per-stage message lists — a fold of
    /// [`HierSchedule::phase_messages`] by phase, so the simulator sees
    /// exactly the messages the executor's rank programs emit.
    pub fn messages(&self) -> StagedMessages {
        let mut m = StagedMessages::default();
        for (ph, msg) in self.phase_messages() {
            match ph {
                phase::S1_INTER_B => m.s1_inter_b.push(msg),
                phase::S1_INTRA_C => m.s1_intra_c.push(msg),
                phase::S2_INTER_C => m.s2_inter_c.push(msg),
                phase::S2_INTRA_B => m.s2_intra_b.push(msg),
                _ => unreachable!("non-message phase {ph}"),
            }
        }
        m
    }

    /// Total bytes crossing inter-group links (Fig. 8b metric).
    pub fn inter_group_bytes(&self, n_dense: usize) -> u64 {
        let m = self.messages();
        let rows: u64 = m.s1_inter_b.iter().map(|x| x.rows).sum::<u64>()
            + m.s2_inter_c.iter().map(|x| x.rows).sum::<u64>();
        rows * n_dense as u64 * crate::comm::SZ_DT
    }

    /// Total bytes on intra-group links.
    pub fn intra_group_bytes(&self, n_dense: usize) -> u64 {
        let m = self.messages();
        let rows: u64 = m.s1_intra_c.iter().map(|x| x.rows).sum::<u64>()
            + m.s2_intra_b.iter().map(|x| x.rows).sum::<u64>();
        rows * n_dense as u64 * crate::comm::SZ_DT
    }
}

// ------------------------------------------------------- 1.5D replication ----

use crate::topology::ReplicaMap;

/// One rank's role in a replicated (1.5D) run — the "group" tier of the
/// schedule. Ranks are addressed through a [`ReplicaMap`]: `nranks/c`
/// groups of `c` consecutive ranks, rank `g·c` the group's **home**.
///
/// The home of group `g` owns the group's A blocks, its B rows, and the
/// final C rows. Inter-group flows of the *group plan* (a [`CommPlan`]
/// over `nranks/c` coarsened parts) are dealt out round-robin to the
/// group's members: the member assigned pair `(g, h)` receives the
/// sparsity-aware payload from `h`'s home (packed cover B rows for
/// column-shaped portions, precomputed partial C rows for row-shaped
/// portions), folds it into a private group-height accumulator, and
/// finally reduce-scatters the accumulator's touched rows back to its own
/// home — the partial-C reduce-scatter leg.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RepAssign {
    /// Replication group this rank belongs to.
    pub group: usize,
    /// Member index inside the group (0 = home).
    pub member: usize,
    /// Source groups whose column-shaped payload (packed cover B rows)
    /// this rank fetches and multiplies against the replicated
    /// `a_col_compact`. Ascending.
    pub col_fetch: Vec<usize>,
    /// Source groups whose row-shaped payload (partial C rows computed at
    /// the source home) this rank receives and scatter-adds. Ascending.
    pub row_recv: Vec<usize>,
    /// Group-local C rows this rank's accumulator can touch: the union of
    /// its col-portions' `a_col_compact` nonempty rows and its
    /// row-portions' `c_rows`. Sorted; exactly the rows the reduce leg
    /// ships.
    pub touched: Vec<u32>,
    /// Home only: `(dst rank, dst group)` for every column-shaped payload
    /// this home ships (the sparsity-aware allgather sends).
    pub b_sends: Vec<(usize, usize)>,
    /// Home only: `(dst rank, dst group)` for every row-shaped partial-C
    /// payload this home computes (`a_row_compact · B_home`) and ships.
    pub c_sends: Vec<(usize, usize)>,
    /// Home only: non-home member ranks whose accumulators reduce into
    /// this home, ascending. The home's *own* accumulator (when it was
    /// dealt pairs) folds locally and is not listed.
    pub red_from: Vec<usize>,
    /// Non-home members only: the home rank this rank's accumulator
    /// reduce-scatters to (`None` when the member was dealt no pairs, or
    /// for homes).
    pub red_to: Option<usize>,
}

/// The full 1.5D schedule: one [`RepAssign`] per physical rank, built from
/// the group plan by [`build_replicated`]. The group plan itself stays in
/// [`crate::comm::CommPlan`] form (over `nranks/c` parts) — this structure
/// only adds the member deal-out and the reduce-scatter wiring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RepSchedule {
    pub map: ReplicaMap,
    /// `assigns[r]` is rank r's role. Length `map.nranks`.
    pub assigns: Vec<RepAssign>,
}

/// Deal the group plan's inter-group flows out to replica members and wire
/// the partial-C reduce-scatter. Deterministic: flows into group `g` are
/// enumerated by ascending source group and dealt round-robin over the
/// `c` members, and both portions of one `(g, h)` pair land on the same
/// member (they fold into one accumulator slot).
pub fn build_replicated(plan: &CommPlan, map: &ReplicaMap) -> RepSchedule {
    assert_eq!(
        plan.nranks,
        map.ngroups(),
        "group plan spans {} parts but map has {} groups",
        plan.nranks,
        map.ngroups()
    );
    let c = map.c;
    let mut assigns: Vec<RepAssign> = (0..map.nranks)
        .map(|r| RepAssign {
            group: map.group_of(r),
            member: map.member_of(r),
            ..RepAssign::default()
        })
        .collect();
    for g in 0..map.ngroups() {
        let mut dealt = 0usize;
        for h in 0..map.ngroups() {
            if h == g {
                continue;
            }
            let pair = &plan.pairs[g][h];
            let has_col = !pair.b_rows.is_empty();
            let has_row = !pair.c_rows.is_empty();
            if !has_col && !has_row {
                continue;
            }
            let m = map.rank(g, dealt % c);
            dealt += 1;
            let mut touched: Vec<u32> = Vec::new();
            if has_col {
                assigns[m].col_fetch.push(h);
                assigns[map.home(h)].b_sends.push((m, g));
                touched.extend(pair.a_col_compact.nonempty_rows());
            }
            if has_row {
                assigns[m].row_recv.push(h);
                assigns[map.home(h)].c_sends.push((m, g));
                touched.extend(pair.c_rows.iter().copied());
            }
            assigns[m].touched.extend(touched);
        }
    }
    for r in 0..map.nranks {
        assigns[r].touched.sort_unstable();
        assigns[r].touched.dedup();
        let g = map.group_of(r);
        if map.member_of(r) != 0 && !assigns[r].touched.is_empty() {
            assigns[r].red_to = Some(map.home(g));
        }
    }
    for g in 0..map.ngroups() {
        let home = map.home(g);
        let red_from: Vec<usize> = map
            .members(g)
            .filter(|&r| r != home && assigns[r].red_to == Some(home))
            .collect();
        assigns[home].red_from = red_from;
    }
    RepSchedule { map, assigns }
}

impl RepSchedule {
    /// Modeled cover volume crossing group boundaries (bytes of dense
    /// payload, the Fig. 8-style metric): every group-pair flow of the
    /// group plan is inter-group by construction, so this is the plan's
    /// total volume. Strictly decreasing in `c` on nested partitions is
    /// the tentpole's acceptance gate.
    pub fn inter_group_bytes(&self, plan: &CommPlan, n_dense: usize) -> u64 {
        plan.total_volume(n_dense)
    }

    /// Exact wire bytes the inter-group payloads occupy in the executor's
    /// message format: each shipped row carries its u32 index plus
    /// `n_dense` f32 values ([`crate::exec::ExecStats`] measures exactly
    /// this, which is what the predicted-vs-measured bench gate compares).
    pub fn inter_wire_bytes(&self, plan: &CommPlan, n_dense: usize) -> u64 {
        let per_row = 4 + n_dense as u64 * crate::comm::SZ_DT;
        let mut rows = 0u64;
        for g in 0..plan.nranks {
            for h in 0..plan.nranks {
                if g != h {
                    let pair = &plan.pairs[g][h];
                    rows += (pair.b_rows.len() + pair.c_rows.len()) as u64;
                }
            }
        }
        rows * per_row
    }

    /// Exact wire bytes of the intra-group reduce-scatter legs (touched
    /// rows, each with its u32 index).
    pub fn intra_wire_bytes(&self, n_dense: usize) -> u64 {
        let per_row = 4 + n_dense as u64 * crate::comm::SZ_DT;
        self.assigns
            .iter()
            .filter(|a| a.red_to.is_some())
            .map(|a| a.touched.len() as u64 * per_row)
            .sum()
    }

    /// Structural validation, used by the property suite: every nonempty
    /// group-pair flow dealt to exactly one member of the destination
    /// group, send lists mirroring fetch lists, reduce wiring consistent,
    /// and `touched` exactly the union the executor folds.
    pub fn validate(&self, plan: &CommPlan) -> Result<(), String> {
        let map = &self.map;
        if self.assigns.len() != map.nranks {
            return Err(format!("{} assigns for {} ranks", self.assigns.len(), map.nranks));
        }
        if plan.nranks != map.ngroups() {
            return Err(format!(
                "plan spans {} parts, map has {} groups",
                plan.nranks,
                map.ngroups()
            ));
        }
        for (r, asg) in self.assigns.iter().enumerate() {
            if asg.group != map.group_of(r) || asg.member != map.member_of(r) {
                return Err(format!("rank {r}: bad group/member"));
            }
            if asg.member == 0 && asg.red_to.is_some() {
                return Err(format!("home {r} must not reduce outward"));
            }
            if asg.member != 0 && (!asg.b_sends.is_empty() || !asg.c_sends.is_empty()) {
                return Err(format!("non-home {r} must not own send lists"));
            }
            if asg.red_to.is_some() && asg.touched.is_empty() {
                return Err(format!("rank {r}: reduces with empty accumulator"));
            }
            if !asg.touched.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("rank {r}: touched not sorted/deduped"));
            }
        }
        // Every nonempty flow (g, h) appears on exactly one member of g,
        // mirrored by one send at home(h); touched is the exact union.
        for g in 0..map.ngroups() {
            for h in 0..map.ngroups() {
                if g == h {
                    continue;
                }
                let pair = &plan.pairs[g][h];
                let col_owners: Vec<usize> = map
                    .members(g)
                    .filter(|&r| self.assigns[r].col_fetch.contains(&h))
                    .collect();
                let row_owners: Vec<usize> = map
                    .members(g)
                    .filter(|&r| self.assigns[r].row_recv.contains(&h))
                    .collect();
                let want_col = usize::from(!pair.b_rows.is_empty());
                let want_row = usize::from(!pair.c_rows.is_empty());
                if col_owners.len() != want_col {
                    return Err(format!("flow ({g},{h}) col dealt {}×", col_owners.len()));
                }
                if row_owners.len() != want_row {
                    return Err(format!("flow ({g},{h}) row dealt {}×", row_owners.len()));
                }
                if want_col == 1 && want_row == 1 && col_owners != row_owners {
                    return Err(format!("flow ({g},{h}) split across members"));
                }
                let home_h = &self.assigns[map.home(h)];
                let b_cnt =
                    home_h.b_sends.iter().filter(|(_, dg)| *dg == g).count();
                let c_cnt =
                    home_h.c_sends.iter().filter(|(_, dg)| *dg == g).count();
                if b_cnt != want_col || c_cnt != want_row {
                    return Err(format!("flow ({g},{h}) send lists mismatch"));
                }
                if want_col == 1 && !home_h.b_sends.contains(&(col_owners[0], g)) {
                    return Err(format!("flow ({g},{h}) b_send targets wrong rank"));
                }
                if want_row == 1 && !home_h.c_sends.contains(&(row_owners[0], g)) {
                    return Err(format!("flow ({g},{h}) c_send targets wrong rank"));
                }
            }
        }
        for (r, asg) in self.assigns.iter().enumerate() {
            let mut want: Vec<u32> = Vec::new();
            for &h in &asg.col_fetch {
                want.extend(plan.pairs[asg.group][h].a_col_compact.nonempty_rows());
            }
            for &h in &asg.row_recv {
                want.extend(plan.pairs[asg.group][h].c_rows.iter().copied());
            }
            want.sort_unstable();
            want.dedup();
            if want != asg.touched {
                return Err(format!("rank {r}: touched != fold union"));
            }
            if asg.member != 0 {
                let home = map.home(asg.group);
                let listed = self.assigns[home].red_from.contains(&r);
                if listed != asg.red_to.is_some() {
                    return Err(format!("rank {r}: red_from/red_to inconsistent"));
                }
            }
        }
        Ok(())
    }
}

/// Inter-group bytes of the *flat* plan on the same topology (the baseline
/// Fig. 8b compares against): every q→p pair crossing a group boundary pays
/// its own transfer.
pub fn flat_inter_group_bytes(plan: &CommPlan, topo: &Topology, n_dense: usize) -> u64 {
    let mut v = 0;
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p != q && topo.group_of(p) != topo.group_of(q) {
                v += plan.volume(p, q, n_dense);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{self, Strategy};
    use crate::cover::Solver;
    use crate::partition::{split_1d, RowPartition};
    use crate::sparse::gen;

    fn setup(n: usize, ranks: usize, seed: u64) -> (CommPlan, Topology) {
        let a = gen::rmat(n, n * 10, (0.55, 0.2, 0.19), false, seed);
        let part = RowPartition::balanced(n, ranks);
        let blocks = split_1d(&a, &part);
        let plan = comm::plan(&blocks, &part, Strategy::Joint(Solver::Koenig), None);
        let topo = Topology::tsubame4(ranks);
        (plan, topo)
    }

    #[test]
    fn hier_never_increases_inter_traffic() {
        for seed in 0..5 {
            let (plan, topo) = setup(128, 8, seed);
            let sched = build(&plan, &topo);
            let n = 32;
            assert!(
                sched.inter_group_bytes(n) <= flat_inter_group_bytes(&plan, &topo, n),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn dedup_counts_fig2_example() {
        // Paper Fig. 2/6: 2 groups of 4; ranks 4..8 each need the same B rows
        // {0,1,2} from rank 0 ⇒ flat sends 12 rows inter-group, hier sends 3.
        let mut plan = CommPlan {
            nranks: 8,
            strategy: Strategy::Column,
            pairs: vec![vec![Default::default(); 8]; 8],
            block_rows: vec![16; 8],
        };
        for p in 4..8 {
            plan.pairs[p][0].b_rows = vec![0, 1, 2];
        }
        let topo = Topology::tsubame4(8);
        let flat = flat_inter_group_bytes(&plan, &topo, 1) / crate::comm::SZ_DT;
        assert_eq!(flat, 12);
        let sched = build(&plan, &topo);
        let hier = sched.inter_group_bytes(1) / crate::comm::SZ_DT;
        assert_eq!(hier, 3);
        // And the intra-group distribution delivers each consumer its rows
        // (3 consumers that are not the rep × 3 rows).
        assert_eq!(sched.intra_group_bytes(1) / crate::comm::SZ_DT, 9);
    }

    #[test]
    fn c_preaggregation_fig6e_example() {
        // Ranks 0..4 (group 0) each produce partials for the same C rows
        // {0,1} of rank 5 (group 1): flat = 8 rows inter; hier = 2 rows
        // inter + intra aggregation traffic (3 producers → rep).
        let mut plan = CommPlan {
            nranks: 8,
            strategy: Strategy::Row,
            pairs: vec![vec![Default::default(); 8]; 8],
            block_rows: vec![16; 8],
        };
        for q in 0..4 {
            plan.pairs[5][q].c_rows = vec![0, 1];
        }
        let topo = Topology::tsubame4(8);
        assert_eq!(flat_inter_group_bytes(&plan, &topo, 1) / crate::comm::SZ_DT, 8);
        let sched = build(&plan, &topo);
        assert_eq!(sched.inter_group_bytes(1) / crate::comm::SZ_DT, 2);
        assert_eq!(sched.intra_group_bytes(1) / crate::comm::SZ_DT, 6);
    }

    #[test]
    fn consumers_rows_subset_of_union() {
        let (plan, topo) = setup(128, 8, 3);
        let sched = build(&plan, &topo);
        for f in &sched.b_flows {
            for (_, rows) in &f.consumers {
                for r in rows {
                    assert!(f.rows.binary_search(r).is_ok());
                }
            }
            assert!(topo.group_members(f.dst_group).contains(&f.rep));
            assert_ne!(topo.group_of(f.src), f.dst_group);
        }
        for f in &sched.c_flows {
            for (_, rows) in &f.producers {
                for r in rows {
                    assert!(f.rows.binary_search(r).is_ok());
                }
            }
            assert!(topo.group_members(f.src_group).contains(&f.rep));
            assert_ne!(topo.group_of(f.dst), f.src_group);
        }
    }

    #[test]
    fn direct_transfers_stay_intra() {
        let (plan, topo) = setup(128, 8, 4);
        let sched = build(&plan, &topo);
        for (s, d, _) in &sched.direct_b {
            assert_eq!(topo.group_of(*s), topo.group_of(*d));
        }
        for (s, d, _) in &sched.direct_c {
            assert_eq!(topo.group_of(*s), topo.group_of(*d));
        }
    }

    #[test]
    fn stage_messages_tier_consistent() {
        let (plan, topo) = setup(128, 8, 5);
        let sched = build(&plan, &topo);
        let m = sched.messages();
        use crate::topology::Tier;
        for msg in m.s1_inter_b.iter().chain(&m.s2_inter_c) {
            assert_eq!(topo.tier(msg.src, msg.dst), Tier::Inter, "{msg:?}");
        }
        for msg in m.s1_intra_c.iter().chain(&m.s2_intra_b) {
            assert_eq!(topo.tier(msg.src, msg.dst), Tier::Intra, "{msg:?}");
        }
    }

    #[test]
    fn single_consumer_skips_rep_hop() {
        let mut plan = CommPlan {
            nranks: 8,
            strategy: Strategy::Column,
            pairs: vec![vec![Default::default(); 8]; 8],
            block_rows: vec![16; 8],
        };
        plan.pairs[6][1].b_rows = vec![3, 4];
        let topo = Topology::tsubame4(8);
        let sched = build(&plan, &topo);
        assert_eq!(sched.b_flows.len(), 1);
        assert_eq!(sched.b_flows[0].rep, 6);
        let m = sched.messages();
        assert_eq!(m.s2_intra_b.len(), 0, "no second hop for single consumer");
    }

    #[test]
    fn rank_programs_and_sim_lowering_are_one_object() {
        let (plan, topo) = setup(128, 8, 7);
        let sched = build(&plan, &topo);
        // Every schedule entry appears in exactly one rank's send program.
        let (mut inter_b, mut produce_c, mut direct_b, mut direct_c) = (0, 0, 0, 0);
        for r in 0..sched.nranks {
            for s in sched.rank_steps(r) {
                match s {
                    Step::InterB(i) => {
                        assert_eq!(sched.b_flows[i].src, r);
                        inter_b += 1;
                    }
                    Step::ProduceC(i) => {
                        assert!(sched.c_flows[i].producers.iter().any(|(p, _)| *p == r));
                        produce_c += 1;
                    }
                    Step::DirectC(i) => {
                        assert_eq!(sched.direct_c[i].0, r);
                        direct_c += 1;
                    }
                    Step::DirectB(i) => {
                        assert_eq!(sched.direct_b[i].0, r);
                        direct_b += 1;
                    }
                }
            }
        }
        assert_eq!(inter_b, sched.b_flows.len());
        assert_eq!(
            produce_c,
            sched.c_flows.iter().map(|f| f.producers.len()).sum::<usize>()
        );
        assert_eq!(direct_b, sched.direct_b.len());
        assert_eq!(direct_c, sched.direct_c.len());
        // The sim lowering is a fold of the same canonical stream.
        let m = sched.messages();
        let stream = sched.phase_messages();
        let count = |ph: &str| stream.iter().filter(|(p, _)| *p == ph).count();
        assert_eq!(count(phase::S1_INTER_B), m.s1_inter_b.len());
        assert_eq!(count(phase::S1_INTRA_C), m.s1_intra_c.len());
        assert_eq!(count(phase::S2_INTER_C), m.s2_inter_c.len());
        assert_eq!(count(phase::S2_INTRA_B), m.s2_intra_b.len());
        assert!(!stream.is_empty());
    }

    #[test]
    fn mirror_equals_build_on_transposed_plan() {
        // The O(schedule) mirror must produce exactly the schedule a full
        // rebuild on the mirrored plan would: same flows, same reps, same
        // unions, same ordering. Exercise several seeds and both a plan
        // with and without row-based flows.
        for (seed, strategy) in [
            (3u64, Strategy::Joint(Solver::Koenig)),
            (8, Strategy::Joint(Solver::Koenig)),
            (5, Strategy::Column),
            (6, Strategy::Row),
        ] {
            let a = gen::rmat(128, 1300, (0.55, 0.2, 0.19), false, seed);
            let part = RowPartition::balanced(128, 8);
            let blocks = split_1d(&a, &part);
            let plan = comm::plan(&blocks, &part, strategy, None);
            let topo = Topology::tsubame4(8);
            let sched = build(&plan, &topo);
            let mirrored = mirror(&sched);
            let rebuilt = build(&plan.transpose(), &topo);
            assert_eq!(mirrored, rebuilt, "seed {seed} {strategy:?}");
            // Mirroring twice is the identity.
            assert_eq!(mirror(&mirrored), sched, "seed {seed} double mirror");
        }
    }

    #[test]
    fn stage1_fetch_drops_exactly_the_c_side() {
        let (plan, topo) = setup(128, 8, 9);
        let sched = build(&plan, &topo);
        assert!(!sched.c_flows.is_empty(), "test needs a real C side");
        let fetch = sched.stage1_fetch();
        assert_eq!(fetch.b_flows, sched.b_flows);
        assert_eq!(fetch.direct_b, sched.direct_b);
        assert!(fetch.c_flows.is_empty());
        assert!(fetch.direct_c.is_empty());
        // No stage-II inter-group transmissions remain; the B fetch volume
        // is untouched.
        let m = fetch.messages();
        assert!(m.s2_inter_c.is_empty());
        assert!(m.s1_intra_c.is_empty());
        assert_eq!(m.s1_inter_b, sched.messages().s1_inter_b);
    }

    #[test]
    fn sddmm_fetch_is_the_reversed_c_side() {
        let (plan, topo) = setup(128, 8, 10);
        let sched = build(&plan, &topo);
        let xs = sddmm_fetch(&sched);
        assert!(xs.c_flows.is_empty() && xs.direct_c.is_empty());
        assert_eq!(xs.b_flows.len(), sched.c_flows.len());
        for (xf, cf) in xs.b_flows.iter().zip(&sched.c_flows) {
            // Same union rows, same rep, direction reversed: the X fetch
            // is volume-identical to the SpMM C flow it replaces.
            assert_eq!(xf.src, cf.dst);
            assert_eq!(xf.dst_group, cf.src_group);
            assert_eq!(xf.rep, cf.rep);
            assert_eq!(xf.rows, cf.rows);
            assert_eq!(xf.consumers, cf.producers);
        }
        // Reversed direct transfers carry the same rows (order follows
        // mirror's canonical (dst, src) re-sort, so compare as sets).
        assert_eq!(xs.direct_b.len(), sched.direct_c.len());
        let mut want: Vec<(usize, usize, Vec<u32>)> = sched
            .direct_c
            .iter()
            .map(|(s, d, rows)| (*d, *s, rows.clone()))
            .collect();
        want.sort();
        let mut got = xs.direct_b.clone();
        got.sort();
        assert_eq!(got, want);
        // Reversal preserves total fetch volume: X inter bytes equal the
        // C flows' aggregated inter transmissions.
        let n = 16;
        assert_eq!(
            xs.inter_group_bytes(n),
            sched.messages().s2_inter_c.iter().map(|m| m.rows).sum::<u64>()
                * n as u64
                * crate::comm::SZ_DT
        );
    }

    #[test]
    fn flat_topology_all_direct() {
        let (plan, _) = setup(64, 8, 6);
        let topo = Topology::flat(8, 25e9);
        let sched = build(&plan, &topo);
        assert!(sched.b_flows.is_empty());
        assert!(sched.c_flows.is_empty());
        assert_eq!(sched.inter_group_bytes(32), 0);
    }

    #[test]
    fn replicated_schedule_validates_across_factors() {
        let a = gen::rmat(128, 1300, (0.55, 0.2, 0.19), false, 11);
        let rank_part = RowPartition::balanced(128, 8);
        for strategy in [Strategy::Joint(Solver::Koenig), Strategy::Column, Strategy::Row] {
            for c in [1usize, 2, 4, 8] {
                let map = ReplicaMap::new(8, c);
                let gpart = rank_part.coarsen(c);
                let gblocks = split_1d(&a, &gpart);
                let plan = comm::plan(&gblocks, &gpart, strategy, None);
                let sched = build_replicated(&plan, &map);
                sched.validate(&plan).unwrap_or_else(|e| {
                    panic!("c={c} {strategy:?}: {e}");
                });
                // Homes own sends, never reduce outward; every dealt
                // member reduces to its own home.
                for (r, asg) in sched.assigns.iter().enumerate() {
                    if map.member_of(r) == 0 {
                        assert_eq!(asg.red_to, None);
                    } else {
                        assert!(asg.b_sends.is_empty() && asg.c_sends.is_empty());
                        if let Some(home) = asg.red_to {
                            assert_eq!(home, map.home(map.group_of(r)));
                        }
                    }
                }
                // At c=1 every rank is its own home: no reduce legs at all.
                if c == 1 {
                    assert_eq!(sched.intra_wire_bytes(32), 0);
                }
            }
        }
    }

    #[test]
    fn replicated_inter_volume_non_increasing_in_c() {
        // Nested coarsening (group boundaries ⊂ rank boundaries) makes a
        // merged pair's cover no larger than the union of its fine pairs'
        // covers, so modeled inter-group volume is monotone in c for the
        // fixed sparsity-aware strategies — the tentpole's volume gate.
        let a = gen::rmat(256, 4000, (0.57, 0.19, 0.19), false, 12);
        let rank_part = RowPartition::balanced(256, 8);
        for strategy in [Strategy::Joint(Solver::Koenig), Strategy::Column] {
            let mut prev = u64::MAX;
            for c in [1usize, 2, 4, 8] {
                let map = ReplicaMap::new(8, c);
                let gpart = rank_part.coarsen(c);
                let gblocks = split_1d(&a, &gpart);
                let plan = comm::plan(&gblocks, &gpart, strategy, None);
                let sched = build_replicated(&plan, &map);
                let v = sched.inter_group_bytes(&plan, 32);
                assert!(
                    v <= prev,
                    "{strategy:?}: c={c} volume {v} > previous {prev}"
                );
                prev = v;
            }
        }
    }

    #[test]
    fn replicated_round_robin_spreads_flows() {
        // A dense-ish pattern gives every group multiple incoming flows;
        // the deal-out must hit more than one member at c=4.
        let a = gen::rmat(128, 4000, (0.4, 0.3, 0.2), false, 13);
        let rank_part = RowPartition::balanced(128, 8);
        let map = ReplicaMap::new(8, 4);
        let gpart = rank_part.coarsen(4);
        let gblocks = split_1d(&a, &gpart);
        let plan = comm::plan(&gblocks, &gpart, Strategy::Joint(Solver::Koenig), None);
        let sched = build_replicated(&plan, &map);
        sched.validate(&plan).unwrap();
        let busy = |g: usize| {
            map.members(g)
                .filter(|&r| {
                    !sched.assigns[r].col_fetch.is_empty()
                        || !sched.assigns[r].row_recv.is_empty()
                })
                .count()
        };
        // 2 groups, each with 1 possible source group → 1 flow each; use
        // the flow count to scale the expectation.
        for g in 0..map.ngroups() {
            let flows: usize = (0..map.ngroups())
                .filter(|&h| {
                    h != g && {
                        let p = &plan.pairs[g][h];
                        !p.b_rows.is_empty() || !p.c_rows.is_empty()
                    }
                })
                .count();
            assert_eq!(busy(g), flows.min(map.c), "group {g}");
        }
    }
}
