//! # SHIRO
//!
//! Reproduction of *"SHIRO: Near-Optimal Communication Strategies for
//! Distributed Sparse Matrix Multiplication"* (ICS '26) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! - **L3 (this crate)** — the paper's contribution: sparsity-aware joint
//!   row-column communication planning ([`cover`], [`comm`]), the adaptive
//!   per-pair plan compiler ([`plan`]), and hierarchical scheduling
//!   ([`hierarchy`]) over a simulated two-tier GPU cluster ([`topology`],
//!   [`sim`]) with a real multi-rank executor ([`exec`]) and distributed
//!   SpMM engine ([`spmm`]).
//! - **L2/L1 (python/compile)** — JAX GCN model + Pallas SpMM kernels,
//!   AOT-lowered to HLO text, loaded at runtime via [`runtime`] (PJRT;
//!   stubbed unless the `pjrt` feature is enabled).
//!
//! See `DESIGN.md` at the repository root for the system inventory, the
//! five-stage workflow, and the experiment → bench mapping.

pub mod baselines;
pub mod bench;
pub mod comm;
pub mod config;
pub mod cover;
pub mod dense;
pub mod exec;
pub mod gnn;
pub mod metrics;
pub mod partition;
pub mod hierarchy;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod topology;
pub mod sparse;
pub mod spmm;
pub mod util;
