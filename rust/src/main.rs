//! `shiro` — the framework launcher.
//!
//! Subcommands:
//!   datasets                         Tab. 2 registry and generated stats
//!   plan     --dataset D --ranks R   plan + volume report per strategy
//!   run      --dataset D --ranks R   execute distributed SpMM, verify
//!   sddmm    --dataset D --ranks R   SDDMM + fused SDDMM→SpMM on the
//!                                    shared SpMM plan, verify + byte report
//!   sim      --dataset D --ranks R   simulate all systems at scale
//!   gnn      --epochs E --ranks R    GCN training case study
//!   serve    [--bench --preset P]    multi-tenant serving layer (closed-
//!                                    loop demo, or the saturation bench)
//!   info                             runtime/artifact status
//!
//! Global flags: --n <dense cols> --scale <dataset scale> --topo <name>
//! --strategy <block|column|row|joint|joint-weighted|joint-greedy|adaptive>
//! --partitioner <balanced|nnz-balanced|cost-refined> (row-boundary choice)
//! --overlap <on|off> (overlapped executor pipeline vs phase-ordered)
//! --backend <thread|proc> (in-process ranks vs one OS process per rank)
//! --replicate <c|auto> (1.5D replication factor: ranks in groups of c
//! replicate A and split the group's inter-group traffic; "auto" picks by
//! modeled cost; 1 = the flat engine, the default)
//! --fault-policy <fail|recover|recover:N> (proc-backend crash handling:
//! surface a structured failure, or replan over the survivors and replay)
//! --config <file.toml> (CLI overrides config values).
//! `trace` accepts --exec to emit the executed pipeline's chrome trace
//! alongside the simulated one (same phase names, comparable in Perfetto).
//! `serve` adds --serve-workers/--serve-queue/--serve-registry/--serve-batch;
//! with --bench it runs the closed-loop saturation driver (--preset ci|full,
//! --out <json path>) and prints the latency/throughput curve; add
//! --backend proc to run the sweep over the server's persistent worker
//! pools (the run fails if pool reuse never engages).

use shiro::comm::Strategy;
use shiro::config::RunConfig;
use shiro::cover::Solver;
use shiro::util::cli::Args;

fn main() {
    // If this process was spawned as a multiproc worker, this runs the
    // worker loop and never returns; a no-op for ordinary invocations.
    shiro::runtime::multiproc::maybe_run_worker();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let cfg = RunConfig::from_args(&args);
    match cmd {
        "datasets" => cmd_datasets(&cfg),
        "plan" => cmd_plan(&cfg),
        "run" => cmd_run(&cfg),
        "sddmm" => cmd_sddmm(&cfg),
        "sim" => cmd_sim(&cfg),
        "gnn" => cmd_gnn(&cfg),
        "serve" => cmd_serve(&cfg, &args),
        "trace" => cmd_trace(&cfg, &args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: shiro <datasets|plan|run|sddmm|sim|gnn|serve|trace|info> \
                 [--dataset D] [--ranks R] [--n N] [--scale S] [--topo T] \
                 [--strategy S] [--partitioner P] [--overlap on|off] \
                 [--backend thread|proc] [--replicate c|auto] \
                 [--fault-policy fail|recover|recover:N] \
                 [--config F] \
                 [serve: --bench --preset ci|full --out J --serve-workers W \
                 --serve-queue Q --serve-registry C --serve-batch K]"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn cmd_datasets(cfg: &RunConfig) {
    use shiro::metrics::Table;
    use shiro::sparse::{stats::stats, DATASETS};
    let mut t = Table::new(&[
        "name", "paper size", "domain", "rows", "nnz", "density", "row-gini", "sym",
    ]);
    for d in DATASETS {
        let m = d.generate(cfg.scale);
        let s = stats(&m);
        t.row(vec![
            d.name.into(),
            format!("{} / {}", d.paper_rows, d.paper_nnz),
            d.domain.into(),
            s.nrows.to_string(),
            s.nnz.to_string(),
            format!("{:.1e}", s.density),
            format!("{:.2}", s.row_gini),
            if s.structurally_symmetric { "yes" } else { "no" }.into(),
        ]);
    }
    println!("{}", t.render());
}

fn cmd_plan(cfg: &RunConfig) {
    use shiro::metrics::{reduction_pct, Table};
    let a = cfg.matrix();
    let (part, blocks) = cfg.split(&a);
    println!(
        "{}: {}x{} nnz={} on {} ranks, N={}",
        cfg.dataset, a.nrows, a.ncols, a.nnz(), cfg.ranks, cfg.n_dense
    );
    let loads = shiro::partition::rank_nnz(&a, &part);
    println!(
        "partition [{}]: max-rank nnz {}, load imbalance {:.2}x",
        cfg.partitioner().name(),
        loads.iter().copied().max().unwrap_or(0),
        shiro::metrics::load_imbalance(&loads)
    );
    let mut t = Table::new(&["strategy", "total bytes", "vs column %", "prep ms"]);
    let mut col = 0u64;
    for s in [
        Strategy::Block,
        Strategy::Column,
        Strategy::Row,
        Strategy::Joint(Solver::Greedy),
        Strategy::Joint(Solver::Koenig),
    ] {
        let t0 = std::time::Instant::now();
        let plan = shiro::comm::plan(&blocks, &part, s, None);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let v = plan.total_volume(cfg.n_dense);
        if s == Strategy::Column {
            col = v;
        }
        t.row(vec![
            s.name().into(),
            v.to_string(),
            if col > 0 { format!("{:.1}", reduction_pct(col, v)) } else { "-".into() },
            format!("{ms:.1}"),
        ]);
    }
    // Adaptive uses the actual topology's cost model (the fixed strategies
    // above are topology-oblivious volume counts).
    {
        let topo = cfg.topology();
        let params = shiro::plan::PlanParams { n_dense: cfg.n_dense, ..Default::default() };
        let t0 = std::time::Instant::now();
        let compiled = shiro::plan::compile(&blocks, &part, &topo, &params);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let v = compiled.plan.total_volume(cfg.n_dense);
        t.row(vec![
            format!("adaptive ({})", cfg.topo),
            v.to_string(),
            if col > 0 { format!("{:.1}", reduction_pct(col, v)) } else { "-".into() },
            format!("{ms:.1}"),
        ]);
        println!("{}", t.render());
        let counts = compiled.shape_counts();
        println!(
            "adaptive per-pair choices: block={} column={} row={} joint={} (modeled cost {:.3} ms)",
            counts[0],
            counts[1],
            counts[2],
            counts[3],
            compiled.modeled_cost * 1e3
        );
    }
}

/// The [`shiro::spmm::Backend`] named by `--backend`.
fn backend_of(cfg: &RunConfig) -> shiro::spmm::Backend {
    if cfg.backend == "proc" {
        shiro::spmm::Backend::proc()
    } else {
        shiro::spmm::Backend::Thread
    }
}

fn cmd_run(cfg: &RunConfig) {
    use shiro::dense::Dense;
    use shiro::spmm::ExecRequest;
    use shiro::util::rng::Rng;
    let a = cfg.matrix();
    let d = cfg.plan_spec().plan(&a);
    let loads = shiro::partition::rank_nnz(&a, &d.part);
    println!(
        "partition [{}]: max-rank nnz {}, load imbalance {:.2}x",
        cfg.partitioner().name(),
        loads.iter().copied().max().unwrap_or(0),
        shiro::metrics::load_imbalance(&loads)
    );
    if let Some(rep) = &d.rep {
        println!(
            "replication: c={} ({} groups of {}), modeled inter-group wire {} B \
             (intra-group {} B)",
            rep.map.c,
            rep.map.ngroups(),
            rep.map.c,
            rep.inter_wire_bytes(&d.plan, cfg.n_dense),
            rep.intra_wire_bytes(cfg.n_dense)
        );
    }
    let mut rng = Rng::new(1);
    let b = Dense::random(a.nrows, cfg.n_dense, &mut rng);
    // Attach a pool handle so a proc run reports worker-pool stats (and
    // any future request on the same handle reuses the warm fleet).
    let pool = shiro::runtime::multiproc::PoolHandle::new();
    let mut backend = backend_of(cfg);
    if let shiro::spmm::Backend::Proc(popts) = &mut backend {
        popts.pool = Some(pool.clone());
    }
    let req = ExecRequest::spmm(&b)
        .opts(cfg.exec_opts())
        .backend(backend)
        .fault_policy(cfg.fault_policy());
    let (recovery, c, stats) = match d.execute(&req) {
        Ok(r) => {
            let rec = r.recovery.clone();
            let (c, stats) = r.into_dense();
            (rec, c, stats)
        }
        Err(e) => {
            eprintln!("{} backend failed: {e}", cfg.backend);
            std::process::exit(1);
        }
    };
    if let Some(rec) = &recovery {
        let (lat, total) = rec.latency();
        println!(
            "recovered from {} lost rank(s) {:?} in {} replan(s): {:.1} ms total replan \
             (max {:.1} ms), final partition {} ranks",
            rec.lost_ranks.len(),
            rec.lost_ranks,
            rec.replans,
            total * 1e3,
            lat.max * 1e3,
            rec.final_starts.len() - 1
        );
    }
    let want = a.spmm(&b);
    let err = want.diff_norm(&c) / (want.max_abs() as f64 + 1e-30);
    let w = stats.overlap_window();
    println!(
        "executed {} ranks [{}] backend={} overlap={}: rel err {err:.2e}, wall {:.1} ms, \
         intra {} B, inter {} B",
        cfg.ranks,
        d.plan.strategy.name(),
        cfg.backend,
        if cfg.overlap { "on" } else { "off" },
        stats.wall_secs * 1e3,
        stats.total_intra_bytes(),
        stats.total_inter_bytes()
    );
    println!(
        "overlap window: {:.1}% of received bytes in flight during compute \
         ({} of {} B), idle {:.2} ms, compute {:.2} ms",
        100.0 * w.overlapped_fraction(),
        w.overlapped_bytes,
        w.total_bytes(),
        w.idle_secs * 1e3,
        w.compute_secs * 1e3
    );
    if cfg.backend == "proc" {
        let ps = pool.stats();
        println!(
            "proc pool: {} spawns, {} reuses, {} readmissions",
            ps.spawns, ps.reuses, ps.readmissions
        );
    }
    assert!(err < 1e-3, "verification failed");
}

fn cmd_sddmm(cfg: &RunConfig) {
    use shiro::dense::Dense;
    use shiro::spmm::ExecRequest;
    use shiro::util::rng::Rng;
    let a = cfg.matrix();
    let d = cfg.plan_spec().plan(&a);
    let mut rng = Rng::new(1);
    let x = Dense::random(a.nrows, cfg.n_dense, &mut rng);
    let y = Dense::random(a.nrows, cfg.n_dense, &mut rng);
    let opts = cfg.exec_opts();
    let backend = backend_of(cfg);
    let fail = |e: shiro::spmm::ExecError| -> ! {
        eprintln!("{} backend failed: {e}", cfg.backend);
        std::process::exit(1);
    };

    // Standalone SDDMM: bitwise-exact vs the serial oracle (each edge
    // value has one producer and a fixed dot order — no tolerance needed),
    // on either backend (--backend proc routes it over the socket control
    // plane through the same plan).
    let req = ExecRequest::sddmm(&x, &y).opts(opts).backend(backend.clone());
    let (e, sddmm_stats) = d.execute(&req).unwrap_or_else(|e| fail(e)).into_sparse();
    let want = a.sddmm(&x, &y);
    assert_eq!(e, want, "distributed SDDMM != serial oracle");
    println!(
        "sddmm on {} ranks [{}] backend={} overlap={}: {} edge values bitwise-exact, \
         wall {:.1} ms, intra {} B, inter {} B",
        cfg.ranks,
        d.plan.strategy.name(),
        cfg.backend,
        if cfg.overlap { "on" } else { "off" },
        e.nnz(),
        sddmm_stats.wall_secs * 1e3,
        sddmm_stats.total_intra_bytes(),
        sddmm_stats.total_inter_bytes()
    );

    // Plan sharing: the same frozen plan serves SpMM with identical B-side
    // traffic.
    let req = ExecRequest::spmm(&y).opts(opts).backend(backend.clone());
    let (_, spmm_stats) = d.execute(&req).unwrap_or_else(|e| fail(e)).into_dense();
    let (bs, bd) = (
        spmm_stats.measured_b_volume().total(),
        sddmm_stats.measured_b_volume().total(),
    );
    println!("plan sharing: B-side bytes spmm={bs} sddmm={bd} (identical: {})", bs == bd);
    assert_eq!(bs, bd, "B-side volume differs between kernels on one plan");

    // Fused SDDMM→SpMM vs the two-pass alternative, byte-for-byte.
    let req = ExecRequest::fused(&x, &y).opts(opts).backend(backend);
    let (c, fused_stats) = d.execute(&req).unwrap_or_else(|e| fail(e)).into_dense();
    let want_c = want.spmm(&y);
    let err = want_c.diff_norm(&c) / (want_c.max_abs() as f64 + 1e-30);
    assert!(err < 1e-3, "fused verification failed: rel err {err}");
    let total = |s: &shiro::exec::ExecStats| s.total_intra_bytes() + s.total_inter_bytes();
    let two_pass = total(&sddmm_stats) + total(&spmm_stats);
    println!(
        "fused sddmm→spmm: rel err {err:.2e}, {} B exchanged vs {} B two-pass \
         ({:.1}% saved, not counting the edge-value gather two-pass also needs)",
        total(&fused_stats),
        two_pass,
        shiro::metrics::reduction_pct(two_pass, total(&fused_stats))
    );
}

fn cmd_sim(cfg: &RunConfig) {
    use shiro::baselines::{simulate, System};
    use shiro::metrics::Table;
    let a = cfg.matrix();
    let topo = cfg.topology();
    let mut t = Table::new(&["system", "time/SpMM (ms)", "inter MiB", "intra MiB"]);
    for sys in System::all() {
        let r = simulate(sys, &a, cfg.n_dense, &topo);
        t.row(vec![
            sys.name().into(),
            format!("{:.3}", r.total * 1e3),
            format!("{:.2}", r.inter_bytes as f64 / (1 << 20) as f64),
            format!("{:.2}", r.intra_bytes as f64 / (1 << 20) as f64),
        ]);
    }
    println!(
        "{} @ {} ranks on {} (N={}):\n{}",
        cfg.dataset, cfg.ranks, cfg.topo, cfg.n_dense, t.render()
    );
}

fn cmd_gnn(cfg: &RunConfig) {
    use shiro::exec::kernel::NativeKernel;
    use shiro::gnn::{Gcn, GcnConfig, NativeDense};
    use shiro::sparse::gen;
    let n = (512 * cfg.ranks).next_power_of_two();
    let adj = gen::rmat(n, n * 10, (0.55, 0.2, 0.19), true, 42);
    let gcn_cfg = GcnConfig {
        epochs: cfg.epochs,
        log_every: (cfg.epochs / 10).max(1),
        lr: 2.0,
        ..Default::default()
    };
    let mut gcn = Gcn::new(&adj, cfg.strategy(), cfg.topology(), true, gcn_cfg);
    gcn.set_exec_opts(cfg.exec_opts());
    let report = gcn.train(&NativeKernel, &NativeDense);
    for (e, l) in &report.losses {
        println!("epoch {e:>4} loss {l:.6}");
    }
    println!(
        "train {:.2}s, spmm {:.2}s ({} calls), prep {:.3}s ({:.1}%)",
        report.train_secs,
        report.spmm_secs,
        report.spmm_calls,
        report.prep_secs,
        100.0 * report.prep_secs / (report.prep_secs + report.train_secs)
    );
    // The epoch-reuse contract, live: both sessions planned once and
    // allocated nothing per epoch after warm-up.
    let (fa, ba) = (gcn.fwd.amortization(), gcn.bwd.amortization());
    println!(
        "sessions: fwd build {:.1} ms / {} calls, bwd (mirrored Âᵀ) build {:.1} ms / {} calls",
        fa.build_secs * 1e3,
        fa.calls(),
        ba.build_secs * 1e3,
        ba.calls()
    );
    println!(
        "epoch reuse: plan time per call after warm-up 0 ms, fresh allocs {} (steady state: {})",
        fa.total_allocs() + ba.total_allocs(),
        fa.steady_state() && ba.steady_state()
    );
}

fn cmd_serve(cfg: &RunConfig, args: &Args) {
    use shiro::serve::{bench, ServeError, ServeRequest, Server};
    if args.has_flag("bench") {
        let name = args.get("preset").unwrap_or("ci");
        let Some(p) = bench::preset(name) else {
            eprintln!("unknown preset {name:?} (ci | full)");
            std::process::exit(2);
        };
        let out = std::path::PathBuf::from(
            args.get("out").unwrap_or("bench_results/serve_bench.json"),
        );
        match bench::run(&p, &out, cfg.backend == "proc") {
            Ok(report) => print!("{report}"),
            Err(e) => {
                eprintln!("serve bench failed: {e:#}");
                std::process::exit(1);
            }
        }
        return;
    }
    // Closed-loop demo: serve the configured dataset, 2 clients per
    // worker, `epochs` requests total, then report the latency breakdown.
    use shiro::dense::Dense;
    use shiro::util::rng::Rng;
    let a = cfg.matrix();
    let mut srv = Server::new(cfg.serve_config());
    srv.register_graph(&cfg.dataset, a.clone());
    let clients = cfg.serve_workers.max(1) * 2;
    let reqs = (cfg.epochs / clients).max(1);
    std::thread::scope(|s| {
        for c in 0..clients {
            let (srv, a) = (&srv, &a);
            s.spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for _ in 0..reqs {
                    let b = Dense::random(a.nrows, cfg.n_dense, &mut rng);
                    loop {
                        match srv.submit_wait(ServeRequest::spmm(&cfg.dataset, b.clone())) {
                            Ok(_) => break,
                            Err(ServeError::Saturated { .. }) => {
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(e) => {
                                eprintln!("serve request failed: {e}");
                                std::process::exit(1);
                            }
                        }
                    }
                }
            });
        }
    });
    let stats = srv.shutdown();
    let lat = stats.latency();
    println!(
        "served {} requests ({} clients x {}) on {} workers: p50 {:.2} ms, p99 {:.2} ms, \
         max {:.2} ms",
        stats.completed,
        clients,
        reqs,
        cfg.serve_workers,
        lat.p50 * 1e3,
        lat.p99 * 1e3,
        lat.max * 1e3
    );
    println!(
        "batching: {} coalesced executes covering {} requests (mean batch {:.2}, max {}); \
         registry: {} hits / {} misses / {} evictions (hit rate {:.2})",
        stats.batches,
        stats.batched_requests,
        stats.mean_batch(),
        stats.max_batch_seen,
        stats.registry_hits,
        stats.registry_misses,
        stats.registry_evictions,
        stats.hit_rate()
    );
}

fn cmd_trace(cfg: &RunConfig, args: &Args) {
    use shiro::sim::trace::{exec_to_chrome_json, to_chrome_json, trace};
    use shiro::spmm::PlanSpec;
    let a = cfg.matrix();
    // Same partitioner as `shiro run` so the simulated/executed traces
    // describe the boundaries the configured run actually uses (strategy
    // pinned to the paper's joint default).
    let d = PlanSpec::new(cfg.topology())
        .strategy(Strategy::Joint(Solver::Koenig))
        .partitioner(cfg.partitioner())
        .n_dense(cfg.n_dense)
        .plan(&a);
    let job = d.sim_job(cfg.n_dense);
    let timings = trace(&job, &d.topo);
    let json = to_chrome_json(&timings, &job);
    let path = format!("trace_{}_{}r.json", cfg.dataset, cfg.ranks);
    std::fs::write(&path, json).expect("write trace");
    println!(
        "wrote {path} ({} messages) — load in chrome://tracing or Perfetto",
        timings.len()
    );
    if args.has_flag("exec") {
        // The executed pipeline's trace, with the same phase names as the
        // simulated stages, for side-by-side comparison.
        use shiro::dense::Dense;
        use shiro::spmm::ExecRequest;
        use shiro::util::rng::Rng;
        let mut rng = Rng::new(1);
        let b = Dense::random(a.nrows, cfg.n_dense, &mut rng);
        let req = ExecRequest::spmm(&b).opts(cfg.exec_opts());
        let (_, stats) = d.execute(&req).expect("thread-backend SpMM").into_dense();
        let path = format!("trace_{}_{}r_exec.json", cfg.dataset, cfg.ranks);
        std::fs::write(&path, exec_to_chrome_json(&stats)).expect("write exec trace");
        println!("wrote {path} (executed pipeline, same phase names)");
    }
}

fn cmd_info() {
    use shiro::runtime::Runtime;
    println!("shiro {}", env!("CARGO_PKG_VERSION"));
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => {
            println!("artifacts: {} loaded from {}", rt.artifact_names().len(), rt.dir().display());
            println!("platform: {}", rt.platform());
            let mut names = rt.artifact_names().into_iter().map(String::from).collect::<Vec<_>>();
            names.sort();
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("artifacts: not available ({e:#}) — run `make artifacts`"),
    }
}
