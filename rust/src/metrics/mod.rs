//! Communication-volume accounting and reporting: per-pair volume matrices
//! (Fig. 9 heatmaps), totals/reductions (Fig. 8), imbalance and symmetry
//! measures, and simple table/CSV emitters shared by the benches.

use std::fmt::Write as _;

/// nranks × nranks matrix of bytes sent from src (row) to dst (col).
#[derive(Clone, Debug, PartialEq)]
pub struct VolumeMatrix {
    pub n: usize,
    pub data: Vec<u64>,
}

impl VolumeMatrix {
    pub fn zeros(n: usize) -> VolumeMatrix {
        VolumeMatrix { n, data: vec![0; n * n] }
    }

    #[inline]
    pub fn get(&self, src: usize, dst: usize) -> u64 {
        self.data[src * self.n + dst]
    }

    #[inline]
    pub fn set(&mut self, src: usize, dst: usize, v: u64) {
        self.data[src * self.n + dst] = v;
    }

    #[inline]
    pub fn add(&mut self, src: usize, dst: usize, v: u64) {
        self.data[src * self.n + dst] += v;
    }

    pub fn total(&self) -> u64 {
        self.data.iter().sum()
    }

    pub fn max(&self) -> u64 {
        self.data.iter().copied().max().unwrap_or(0)
    }

    /// Sum of volume crossing group boundaries, given each rank's group id
    /// (Fig. 8b's inter-node volume metric).
    pub fn inter_group_total(&self, group_of: &[usize]) -> u64 {
        assert_eq!(group_of.len(), self.n);
        let mut v = 0;
        for s in 0..self.n {
            for d in 0..self.n {
                if group_of[s] != group_of[d] {
                    v += self.get(s, d);
                }
            }
        }
        v
    }

    /// Load imbalance: max over ranks of (sent+received) divided by mean.
    /// Empty or all-zero traffic reports 0.0 — "no load" must not be
    /// conflated with "perfectly balanced" (1.0), or a plan that moves
    /// nothing would score as ideally balanced in the ablation tables.
    pub fn imbalance(&self) -> f64 {
        let mut per_rank = vec![0u64; self.n];
        for s in 0..self.n {
            for d in 0..self.n {
                per_rank[s] += self.get(s, d);
                per_rank[d] += self.get(s, d);
            }
        }
        let max = per_rank.iter().copied().max().unwrap_or(0) as f64;
        let mean = per_rank.iter().sum::<u64>() as f64 / self.n.max(1) as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean
        }
    }

    /// Relative asymmetry: ‖V - Vᵀ‖₁ / ‖V‖₁ (0 = perfectly symmetric).
    /// Fig. 9's observation: the joint strategy restores symmetry on
    /// symmetric matrices.
    pub fn asymmetry(&self) -> f64 {
        let total = self.total() as f64;
        if total == 0.0 {
            return 0.0;
        }
        let mut diff = 0u64;
        for s in 0..self.n {
            for d in 0..self.n {
                diff += self.get(s, d).abs_diff(self.get(d, s));
            }
        }
        diff as f64 / total
    }

    /// CSV export (one row per source rank), volumes normalized by the
    /// matrix max when `normalize` (the Fig. 9 convention). A zero-max
    /// (all-zero traffic) matrix normalizes to all zeros rather than
    /// dividing by a fabricated max of 1 — same digits, but the guard is
    /// explicit instead of hiding behind `max(1)` on a u64.
    pub fn to_csv(&self, normalize: bool) -> String {
        let max = self.max();
        let mut out = String::new();
        for s in 0..self.n {
            for d in 0..self.n {
                if d > 0 {
                    out.push(',');
                }
                if normalize {
                    let frac =
                        if max == 0 { 0.0 } else { self.get(s, d) as f64 / max as f64 };
                    let _ = write!(out, "{:.4}", frac);
                } else {
                    let _ = write!(out, "{}", self.get(s, d));
                }
            }
            out.push('\n');
        }
        out
    }

    /// ASCII heatmap (for terminal inspection of Fig. 9). A zero-max
    /// matrix renders as all-blank shades; the shade index is computed
    /// against the true max, never a fabricated `max(1)` floor.
    pub fn to_ascii(&self) -> String {
        const SHADES: &[u8] = b" .:-=+*#%@";
        let max = self.max();
        let mut out = String::new();
        for s in 0..self.n {
            for d in 0..self.n {
                let v = if max == 0 {
                    0.0
                } else {
                    self.get(s, d) as f64 / max as f64
                };
                let idx = ((v * (SHADES.len() - 1) as f64).round() as usize)
                    .min(SHADES.len() - 1);
                out.push(SHADES[idx] as char);
            }
            out.push('\n');
        }
        out
    }
}

/// Overlap-window accounting for an executed pipeline run (§6.2): how much
/// of the received traffic landed while the rank still had compute to hide
/// it behind, versus while idling in the drain tail. Filled per rank by the
/// executor ([`crate::exec::ExecStats::overlap_window`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverlapWindow {
    /// Bytes drained from inboxes while compute items remained (in flight
    /// during compute — hidden communication).
    pub overlapped_bytes: u64,
    /// Bytes received in the idle drain tail (exposed communication).
    pub idle_bytes: u64,
    /// Seconds blocked in `recv` with nothing left to compute (over ranks).
    pub idle_secs: f64,
    /// Seconds of local SpMM compute (over ranks).
    pub compute_secs: f64,
}

impl OverlapWindow {
    pub fn total_bytes(&self) -> u64 {
        self.overlapped_bytes + self.idle_bytes
    }

    /// Fraction of received bytes that arrived inside the overlap window
    /// (1.0 = all communication hidden behind compute).
    pub fn overlapped_fraction(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            0.0
        } else {
            self.overlapped_bytes as f64 / total as f64
        }
    }
}

/// Amortization accounting for an epoch-persistent execution session
/// ([`crate::exec::SpmmSession`]): how much planning work and how many
/// fresh buffer allocations each `execute` call paid. The session contract
/// is that everything is front-loaded — from the second call onward both
/// series must be exactly zero ([`Amortization::steady_state`], the CI
/// gate in `ablation_epoch_reuse --check`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Amortization {
    /// One-time session construction seconds (program derivation, payload
    /// layout, pool seeding done eagerly at build/warm time).
    pub build_secs: f64,
    /// Per-`execute`-call planning seconds (lazy program/layout work that
    /// had not been warmed before the call).
    pub plan_secs: Vec<f64>,
    /// Per-`execute`-call fresh exchange-buffer allocation events
    /// (pool misses + lazy seeds attributed to that call).
    pub alloc_events: Vec<u64>,
}

impl Amortization {
    /// Record one `execute` call's planning time and allocation events.
    pub fn record(&mut self, plan_secs: f64, alloc_events: u64) {
        self.plan_secs.push(plan_secs);
        self.alloc_events.push(alloc_events);
    }

    /// Number of `execute` calls recorded.
    pub fn calls(&self) -> usize {
        self.plan_secs.len()
    }

    /// True when every call after the first did zero planning work and
    /// zero fresh allocations (the epoch-reuse guarantee).
    pub fn steady_state(&self) -> bool {
        self.plan_secs.iter().skip(1).all(|&s| s == 0.0)
            && self.alloc_events.iter().skip(1).all(|&a| a == 0)
    }

    /// Total allocation events across all calls (excluding `build_secs`-era
    /// warm-up, which is not per-call).
    pub fn total_allocs(&self) -> u64 {
        self.alloc_events.iter().sum()
    }
}

/// Load-imbalance factor of a per-rank load vector: max/mean (1.0 =
/// perfectly balanced). Used with [`crate::partition::rank_nnz`] to score
/// partitioners — the overlapped executor's wall clock tracks the max,
/// throughput the mean, so this factor is the straggler overhead.
/// Empty or all-zero loads report 0.0: "nothing to balance" is not the
/// same as "perfectly balanced", and the old 1.0 answer let an all-empty
/// partition masquerade as ideal in the partitioner ablation.
pub fn load_imbalance(loads: &[u64]) -> f64 {
    if loads.is_empty() {
        return 0.0;
    }
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let mean = loads.iter().sum::<u64>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        0.0
    } else {
        max / mean
    }
}

/// Order statistics over a latency sample set (seconds), computed with the
/// nearest-rank method on a sorted copy. Used by the serve layer to report
/// per-request queue/plan/exec latencies and the `serve --bench` curve.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    pub mean: f64,
    /// Non-finite samples (NaN/±inf) excluded from the order statistics.
    /// A nonzero value flags a timing bug upstream without poisoning the
    /// percentiles or panicking the reporting path.
    pub dropped: usize,
}

/// Summarize a latency sample vector. Empty input yields all-zero stats.
/// Non-finite samples are dropped (and counted in [`LatencyStats::dropped`])
/// rather than panicking the sort or propagating NaN into every percentile.
pub fn latency_stats(samples: &[f64]) -> LatencyStats {
    let mut sorted: Vec<f64> = samples.iter().copied().filter(|s| s.is_finite()).collect();
    let dropped = samples.len() - sorted.len();
    if sorted.is_empty() {
        return LatencyStats { dropped, ..LatencyStats::default() };
    }
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    // Nearest-rank: the smallest sample with at least p% of the mass at or
    // below it, i.e. index ceil(p * n) - 1.
    let rank = |p: f64| -> f64 {
        let k = ((p * n as f64).ceil() as usize).clamp(1, n);
        sorted[k - 1]
    };
    LatencyStats {
        count: n,
        p50: rank(0.50),
        p90: rank(0.90),
        p99: rank(0.99),
        max: sorted[n - 1],
        mean: sorted.iter().sum::<f64>() / n as f64,
        dropped,
    }
}

/// Summarize crash-recovery replan latencies: order statistics plus the
/// *total* seconds spent replanning. Recovery rounds are few (bounded by
/// `max_retries`), so the aggregate downtime matters as much as the
/// percentiles — a serve operator budgets total stall, not p99.
pub fn recovery_latency(samples: &[f64]) -> (LatencyStats, f64) {
    (latency_stats(samples), samples.iter().sum())
}

/// Percent reduction from `base` to `opt` (Fig. 8 bars).
pub fn reduction_pct(base: u64, opt: u64) -> f64 {
    if base == 0 {
        return 0.0;
    }
    100.0 * (1.0 - opt as f64 / base as f64)
}

/// Fixed-width table printer used by all benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = write!(out, "{:<w$}", c, w = width[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &width, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &width, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_groups() {
        let mut m = VolumeMatrix::zeros(4);
        m.set(0, 1, 10);
        m.set(0, 2, 20);
        m.set(2, 3, 5);
        assert_eq!(m.total(), 35);
        // Groups {0,1}, {2,3}: only 0→2 crosses.
        assert_eq!(m.inter_group_total(&[0, 0, 1, 1]), 20);
    }

    #[test]
    fn asymmetry_zero_for_symmetric() {
        let mut m = VolumeMatrix::zeros(3);
        m.set(0, 1, 7);
        m.set(1, 0, 7);
        assert_eq!(m.asymmetry(), 0.0);
        m.set(2, 0, 4);
        assert!(m.asymmetry() > 0.0);
    }

    #[test]
    fn imbalance_one_when_uniform() {
        let mut m = VolumeMatrix::zeros(2);
        m.set(0, 1, 5);
        m.set(1, 0, 5);
        assert!((m.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_zero_when_no_traffic() {
        // All-zero traffic must report 0.0, not "perfectly balanced" 1.0.
        assert_eq!(VolumeMatrix::zeros(4).imbalance(), 0.0);
        assert_eq!(VolumeMatrix::zeros(0).imbalance(), 0.0);
    }

    #[test]
    fn zero_max_heatmap_renders_blank_without_fabricated_max() {
        let m = VolumeMatrix::zeros(3);
        let a = m.to_ascii();
        assert!(a.lines().all(|l| l == "   "), "all-zero matrix must be blank: {a:?}");
        let csv = m.to_csv(true);
        for line in csv.lines() {
            assert_eq!(line, "0.0000,0.0000,0.0000");
        }
        // Non-zero max still saturates to the darkest shade.
        let mut m = VolumeMatrix::zeros(2);
        m.set(0, 1, 8);
        assert!(m.to_ascii().contains('@'));
    }

    #[test]
    fn overlap_window_fraction() {
        let w = OverlapWindow { overlapped_bytes: 75, idle_bytes: 25, ..Default::default() };
        assert_eq!(w.total_bytes(), 100);
        assert!((w.overlapped_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(OverlapWindow::default().overlapped_fraction(), 0.0);
    }

    #[test]
    fn load_imbalance_factor() {
        // Degenerate inputs: no load is 0.0, not "balanced" 1.0.
        assert_eq!(load_imbalance(&[]), 0.0);
        assert_eq!(load_imbalance(&[0, 0, 0]), 0.0);
        assert!((load_imbalance(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
        // One rank with everything over 4 ranks: max/mean = 4.
        assert!((load_imbalance(&[12, 0, 0, 0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn amortization_steady_state() {
        let mut a = Amortization::default();
        assert!(a.steady_state(), "empty series is trivially steady");
        a.record(0.2, 17);
        assert!(a.steady_state(), "first call may plan and allocate");
        a.record(0.0, 0);
        a.record(0.0, 0);
        assert!(a.steady_state());
        assert_eq!(a.calls(), 3);
        assert_eq!(a.total_allocs(), 17);
        a.record(0.0, 1);
        assert!(!a.steady_state(), "late allocation must break steady state");
    }

    #[test]
    fn latency_stats_nearest_rank() {
        assert_eq!(latency_stats(&[]), LatencyStats::default());
        let one = latency_stats(&[3.0]);
        assert_eq!(one.count, 1);
        assert_eq!(one.p50, 3.0);
        assert_eq!(one.p99, 3.0);
        assert_eq!(one.max, 3.0);
        assert_eq!(one.mean, 3.0);
        // 1..=100 in shuffled order: nearest-rank pX is exactly X.
        let mut v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        v.reverse();
        let s = latency_stats(&v);
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-12);
    }

    #[test]
    fn latency_stats_drops_non_finite_samples() {
        // A NaN sample must not panic the sort or poison the percentiles.
        let s = latency_stats(&[1.0, f64::NAN, 3.0, f64::INFINITY, 2.0, f64::NEG_INFINITY]);
        assert_eq!(s.count, 3);
        assert_eq!(s.dropped, 3);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 2.0);
        // All-non-finite input degrades to empty stats with the drop count.
        let s = latency_stats(&[f64::NAN, f64::NAN]);
        assert_eq!(s, LatencyStats { dropped: 2, ..LatencyStats::default() });
        // Finite inputs are unaffected.
        assert_eq!(latency_stats(&[1.0, 2.0]).dropped, 0);
    }

    #[test]
    fn recovery_latency_totals_and_orders() {
        let (s, total) = recovery_latency(&[]);
        assert_eq!(s, LatencyStats::default());
        assert_eq!(total, 0.0);
        let (s, total) = recovery_latency(&[0.5, 0.25, 0.25]);
        assert_eq!(s.count, 3);
        assert_eq!(s.max, 0.5);
        assert_eq!(total, 1.0);
    }

    #[test]
    fn reduction_pct_basic() {
        assert!((reduction_pct(100, 4) - 96.0).abs() < 1e-12);
        assert_eq!(reduction_pct(0, 5), 0.0);
    }

    #[test]
    fn csv_shape() {
        let mut m = VolumeMatrix::zeros(2);
        m.set(0, 1, 10);
        let csv = m.to_csv(true);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("0.0000,1.0000"));
    }

    #[test]
    fn ascii_shape() {
        let m = VolumeMatrix::zeros(3);
        let a = m.to_ascii();
        assert_eq!(a.lines().count(), 3);
        assert!(a.lines().all(|l| l.len() == 3));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert_eq!(s.lines().count(), 4);
    }
}
