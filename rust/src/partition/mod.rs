//! Matrix partitioning: 1D row partition (SHIRO's setting, paper §2.2) plus
//! the 1.5D and 2D layouts needed by the CAGNET/SPA/BCL baselines, and the
//! load-aware [`Partitioner`] subsystem that chooses *where* the row
//! boundaries fall before the cover/plan machinery decides *how* the
//! resulting remote nonzeros are served (DESIGN.md §7).

use crate::sparse::Csr;
use crate::topology::Topology;

/// A 1D row partition of an n-row matrix over `nparts` processes:
/// contiguous row ranges. Ranges need **not** be uniform — every consumer
/// (`comm`, `plan`, `hierarchy`, `exec`, `sim`) indexes through
/// [`RowPartition::range`]/[`RowPartition::len`], so arbitrary boundaries
/// (including empty ranks) flow through the whole stack unchanged.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowPartition {
    pub n: usize,
    pub nparts: usize,
    /// `starts[p]..starts[p+1]` is process p's row range. Length nparts+1.
    pub starts: Vec<usize>,
}

impl RowPartition {
    /// Balanced contiguous partition (remainder spread over leading parts).
    pub fn balanced(n: usize, nparts: usize) -> RowPartition {
        assert!(nparts > 0);
        let base = n / nparts;
        let rem = n % nparts;
        let mut starts = Vec::with_capacity(nparts + 1);
        let mut acc = 0;
        starts.push(0);
        for p in 0..nparts {
            acc += base + usize::from(p < rem);
            starts.push(acc);
        }
        RowPartition { n, nparts, starts }
    }

    /// Arbitrary contiguous partition from explicit boundaries:
    /// `starts[p]..starts[p+1]` is rank p's row range, `starts[0]` must be
    /// 0 and the sequence non-decreasing (equal consecutive entries are
    /// zero-row ranks). The final entry defines `n`.
    pub fn from_starts(starts: Vec<usize>) -> RowPartition {
        assert!(starts.len() >= 2, "need at least one part");
        assert_eq!(starts[0], 0, "starts must begin at 0");
        assert!(
            starts.windows(2).all(|w| w[0] <= w[1]),
            "starts must be non-decreasing: {starts:?}"
        );
        let n = *starts.last().unwrap();
        RowPartition { n, nparts: starts.len() - 1, starts }
    }

    /// Load-aware contiguous partition: split on the prefix sum of row
    /// nonzero counts (`a.indptr`) so every rank owns ≈ nnz/nparts
    /// nonzeros, whatever the row-count skew. Each boundary is the row
    /// whose prefix is closest to the ideal target `p·nnz/nparts`,
    /// clamped so boundaries strictly advance while rows remain — a hub
    /// row whose prefix swallows several targets must not repeat a
    /// boundary and leave an *interior* rank empty (only tail ranks may
    /// be empty, once rows run out). Falls back to
    /// [`RowPartition::balanced`] on an all-zero matrix.
    pub fn nnz_balanced(a: &Csr, nparts: usize) -> RowPartition {
        assert!(nparts > 0);
        let n = a.nrows;
        let total = a.nnz() as u64;
        if total == 0 {
            return RowPartition::balanced(n, nparts);
        }
        let mut starts = Vec::with_capacity(nparts + 1);
        starts.push(0usize);
        for p in 1..nparts {
            let target = p as u64 * total / nparts as u64;
            // First row boundary whose prefix reaches the target…
            let hi = a.indptr.partition_point(|&x| x < target).min(n);
            // …or the one just before it, whichever lands closer.
            let prev = *starts.last().unwrap();
            let lo = hi.saturating_sub(1);
            let pick = if lo >= prev
                && target - a.indptr[lo].min(target) < a.indptr[hi] - target
            {
                lo
            } else {
                hi
            };
            let floor = if prev < n { prev + 1 } else { n };
            starts.push(pick.clamp(floor, n));
        }
        starts.push(n);
        RowPartition::from_starts(starts)
    }

    /// Coarsen a rank-level partition into a group-level one by merging
    /// every `c` consecutive parts (`nparts` must be divisible by `c`).
    /// The group boundaries are a **subset** of the rank boundaries —
    /// this nesting is what makes per-pair cover volume non-increasing
    /// in the replication factor (a merged pair's cover is contained in
    /// the union of the fine pairs' covers), so the 1.5D planner builds
    /// its group plan on `coarsen(c)` of the configured partitioner's
    /// rank split rather than re-partitioning at `nparts/c`.
    pub fn coarsen(&self, c: usize) -> RowPartition {
        assert!(c > 0, "replication factor must be positive");
        assert_eq!(
            self.nparts % c,
            0,
            "replication factor {c} must divide nparts {}",
            self.nparts
        );
        let ngroups = self.nparts / c;
        let starts = (0..=ngroups).map(|g| self.starts[g * c]).collect();
        RowPartition::from_starts(starts)
    }

    /// Expand a group-level partition (this) back to `ngroups·c` ranks:
    /// each group's home rank (`g·c`) owns the whole group range and the
    /// other `c-1` members own zero rows. Used when a replicated run must
    /// degrade to the flat c=1 machinery (e.g. proc crash recovery) —
    /// zero-row ranks flow through the whole stack since PR 3.
    pub fn expand_replicated(&self, c: usize) -> RowPartition {
        assert!(c > 0, "replication factor must be positive");
        let mut starts = Vec::with_capacity(self.nparts * c + 1);
        for g in 0..self.nparts {
            starts.push(self.starts[g]);
            // Members g·c+1 .. g·c+c start where the group ends: 0 rows.
            for _ in 1..c {
                starts.push(self.starts[g + 1]);
            }
        }
        starts.push(self.n);
        RowPartition::from_starts(starts)
    }

    #[inline]
    pub fn range(&self, p: usize) -> (usize, usize) {
        (self.starts[p], self.starts[p + 1])
    }

    #[inline]
    pub fn len(&self, p: usize) -> usize {
        self.starts[p + 1] - self.starts[p]
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Which process owns global row `r`.
    pub fn owner(&self, r: usize) -> usize {
        debug_assert!(r < self.n);
        // starts is sorted; partition_point gives the first start > r.
        self.starts.partition_point(|&s| s <= r) - 1
    }

    /// Convert a global row index to (owner, local index).
    pub fn to_local(&self, r: usize) -> (usize, usize) {
        let p = self.owner(r);
        (p, r - self.starts[p])
    }

    pub fn to_global(&self, p: usize, local: usize) -> usize {
        self.starts[p] + local
    }
}

/// Per-rank nonzero loads under a partition (straight off `a.indptr`).
/// The max/mean of this vector is the load-imbalance factor reported by
/// [`crate::metrics::load_imbalance`] and the `ablation_partition` bench.
pub fn rank_nnz(a: &Csr, part: &RowPartition) -> Vec<u64> {
    assert_eq!(a.nrows, part.n);
    (0..part.nparts)
        .map(|p| a.indptr[part.starts[p + 1]] - a.indptr[part.starts[p]])
        .collect()
}

/// Maximum nonzeros owned by any single rank — the straggler bound the
/// load-aware partitioners minimize (the overlapped executor finishes no
/// earlier than its heaviest rank's compute).
pub fn max_rank_nnz(a: &Csr, part: &RowPartition) -> u64 {
    rank_nnz(a, part).into_iter().max().unwrap_or(0)
}

/// How the 1D row boundaries are chosen. Partitioning decides *which*
/// nonzeros are remote; the cover/plan machinery then decides *how* the
/// remote ones are served — the two compose (§8.1's reordering argument).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// Equal row counts per rank (the seed behavior).
    Balanced,
    /// Prefix-sum splitting on row nnz: equal nonzeros per rank.
    NnzBalanced,
    /// Start from [`Partitioner::NnzBalanced`], then greedily shift
    /// boundaries to minimize the α-β cost of the resulting joint plan
    /// plus a max-rank compute term (see [`refine_objective`]).
    CostRefined,
}

impl Partitioner {
    pub const ALL: [Partitioner; 3] =
        [Partitioner::Balanced, Partitioner::NnzBalanced, Partitioner::CostRefined];

    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Balanced => "balanced",
            Partitioner::NnzBalanced => "nnz-balanced",
            Partitioner::CostRefined => "cost-refined",
        }
    }

    /// Inverse of [`Partitioner::name`] for config/CLI parsing.
    pub fn by_name(name: &str) -> Option<Partitioner> {
        match name {
            "balanced" => Some(Partitioner::Balanced),
            "nnz-balanced" | "nnz" => Some(Partitioner::NnzBalanced),
            "cost-refined" | "cost" => Some(Partitioner::CostRefined),
            _ => None,
        }
    }

    /// Compute the row partition of `a` over `nparts` ranks. `topo` and
    /// `n_dense` parameterize the cost model and are only read by
    /// [`Partitioner::CostRefined`].
    pub fn partition(
        &self,
        a: &Csr,
        nparts: usize,
        topo: &Topology,
        n_dense: usize,
    ) -> RowPartition {
        match self {
            Partitioner::Balanced => RowPartition::balanced(a.nrows, nparts),
            Partitioner::NnzBalanced => RowPartition::nnz_balanced(a, nparts),
            Partitioner::CostRefined => cost_refined(a, nparts, topo, n_dense),
        }
    }
}

/// The objective [`Partitioner::CostRefined`] minimizes: the modeled α-β
/// cost of the joint (König) plan induced by the partition, plus the
/// heaviest rank's local-SpMM compute time (`2·max_nnz·N / compute_rate`)
/// — the straggler term the pipeline stalls on.
pub fn refine_objective(
    a: &Csr,
    part: &RowPartition,
    topo: &Topology,
    n_dense: usize,
) -> f64 {
    let blocks = split_1d(a, part);
    let plan = crate::comm::plan(
        &blocks,
        part,
        crate::comm::Strategy::Joint(crate::cover::Solver::Koenig),
        None,
    );
    let comm = crate::plan::modeled_cost(&plan, topo, n_dense);
    let max_nnz = max_rank_nnz(a, part) as f64;
    comm + 2.0 * max_nnz * n_dense as f64 / topo.compute_rate
}

/// Greedy boundary refinement: starting from the nnz-balanced split, try
/// shifting each interior boundary by ±step rows (step halves every pass),
/// accepting a move only when [`refine_objective`] strictly decreases —
/// deterministic, and by construction never worse than nnz-balanced under
/// the objective.
fn cost_refined(a: &Csr, nparts: usize, topo: &Topology, n_dense: usize) -> RowPartition {
    let mut part = RowPartition::nnz_balanced(a, nparts);
    if nparts < 2 || a.nrows == 0 {
        return part;
    }
    let mut best = refine_objective(a, &part, topo, n_dense);
    let mut step = (a.nrows / (8 * nparts)).max(1);
    for _pass in 0..3 {
        for b in 1..nparts {
            for dir in [-1i64, 1] {
                let cur = part.starts[b] as i64;
                let lo = part.starts[b - 1] as i64;
                let hi = part.starts[b + 1] as i64;
                let cand = (cur + dir * step as i64).clamp(lo, hi);
                if cand == cur {
                    continue;
                }
                let mut starts = part.starts.clone();
                starts[b] = cand as usize;
                let cand_part = RowPartition::from_starts(starts);
                let obj = refine_objective(a, &cand_part, topo, n_dense);
                if obj < best {
                    best = obj;
                    part = cand_part;
                }
            }
        }
        if step == 1 {
            break;
        }
        step /= 2;
    }
    part
}

/// Process p's view of the 1D-partitioned sparse matrix: its diagonal block
/// and every off-diagonal block `A^(p,q)` (paper notation), with column
/// indices re-based to the owner q's local row space of B.
#[derive(Clone, Debug)]
pub struct LocalBlocks {
    pub rank: usize,
    /// `A^(p,p)` — needs only local `B^(p,:)`.
    pub diag: Csr,
    /// `blocks[q]` = `A^(p,q)` for q ≠ p (entry for q == p is an empty
    /// matrix); column indices are local to q's B rows.
    pub off_diag: Vec<Csr>,
}

/// Split the full matrix into per-process local blocks under a 1D row
/// partition. This is the offline "Matrix Sparsity Analysis" input
/// (workflow step 1, paper §5.1).
pub fn split_1d(a: &Csr, part: &RowPartition) -> Vec<LocalBlocks> {
    assert_eq!(a.nrows, part.n);
    assert_eq!(a.ncols, part.n, "1D SpMM expects square A");
    (0..part.nparts)
        .map(|p| {
            let (r0, r1) = part.range(p);
            let off_diag = (0..part.nparts)
                .map(|q| {
                    if q == p {
                        Csr::zeros(r1 - r0, part.len(q))
                    } else {
                        let (c0, c1) = part.range(q);
                        a.block(r0, r1, c0, c1)
                    }
                })
                .collect();
            let (c0, c1) = part.range(p);
            LocalBlocks {
                rank: p,
                diag: a.block(r0, r1, c0, c1),
                off_diag,
            }
        })
        .collect()
}

/// Reassemble the full matrix from per-process local blocks — the exact
/// inverse of [`split_1d`]. Within a row, the diag/off-diag blocks are
/// column-range slices in rank order, so concatenating each block's row
/// segment (column indices re-based from q-local back to global through
/// `starts[q]`) reproduces the original CSR byte for byte: same indptr,
/// same sorted indices, same value bits. Crash recovery leans on this:
/// the control plane reassembles A once and re-splits it under the
/// surviving-rank partition, so the recovered run is indistinguishable
/// from a cold start on that partition.
pub fn assemble_1d(blocks: &[LocalBlocks], part: &RowPartition) -> Csr {
    assert_eq!(blocks.len(), part.nparts);
    let n = part.n;
    let nnz: usize = blocks
        .iter()
        .map(|b| b.diag.nnz() + b.off_diag.iter().map(|m| m.nnz()).sum::<usize>())
        .sum();
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::with_capacity(nnz);
    let mut data = Vec::with_capacity(nnz);
    indptr.push(0u64);
    for (p, blk) in blocks.iter().enumerate() {
        assert_eq!(blk.rank, p, "blocks must be in rank order");
        for i in 0..part.len(p) {
            for q in 0..part.nparts {
                let m = if q == p { &blk.diag } else { &blk.off_diag[q] };
                let base = part.starts[q] as u32;
                indices.extend(m.row_indices(i).iter().map(|&c| c + base));
                data.extend_from_slice(m.row_values(i));
            }
            indptr.push(indices.len() as u64);
        }
    }
    Csr { nrows: n, ncols: n, indptr, indices, data }
}

/// Derive the (n−1)-rank partition after losing rank `lost`: every
/// surviving rank keeps its exact row range except the one adjacent
/// neighbor that absorbs the lost rows (the next rank down, or the
/// previous one when the last rank dies). Preserving the surviving
/// boundaries keeps the recovered split maximally local — only covers
/// touching the absorbed block change — and makes the result a pure
/// function of `(starts, lost)`, which is what lets a recovered run be
/// replayed bitwise as a cold start.
pub fn recover_partition(part: &RowPartition, lost: usize) -> RowPartition {
    assert!(lost < part.nparts, "lost rank {lost} out of range");
    assert!(part.nparts >= 2, "cannot recover a 1-rank partition");
    let mut starts = part.starts.clone();
    // Dropping boundary lost+1 merges `lost` into its successor; for the
    // last rank there is no successor, so drop boundary `lost` and let
    // the predecessor absorb it.
    let drop_at = if lost + 1 < part.nparts { lost + 1 } else { lost };
    starts.remove(drop_at);
    RowPartition::from_starts(starts)
}

/// 2D process grid used by the BCL baseline (stationary C): processes are
/// arranged pr × pc; A is tiled into pr × pc blocks.
#[derive(Clone, Copy, Debug)]
pub struct Grid2D {
    pub pr: usize,
    pub pc: usize,
}

impl Grid2D {
    /// Nearly-square grid for `nparts` processes.
    pub fn near_square(nparts: usize) -> Grid2D {
        let mut pr = (nparts as f64).sqrt() as usize;
        while pr > 1 && nparts % pr != 0 {
            pr -= 1;
        }
        Grid2D {
            pr: pr.max(1),
            pc: nparts / pr.max(1),
        }
    }

    pub fn coords(&self, rank: usize) -> (usize, usize) {
        (rank / self.pc, rank % self.pc)
    }

    pub fn rank(&self, r: usize, c: usize) -> usize {
        r * self.pc + c
    }

    pub fn size(&self) -> usize {
        self.pr * self.pc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::Dense;
    use crate::sparse::gen;

    #[test]
    fn balanced_partition_covers() {
        let p = RowPartition::balanced(10, 3);
        assert_eq!(p.starts, vec![0, 4, 7, 10]);
        assert_eq!(p.len(0), 4);
        assert_eq!(p.len(2), 3);
        for r in 0..10 {
            let (owner, local) = p.to_local(r);
            assert_eq!(p.to_global(owner, local), r);
        }
    }

    #[test]
    fn owner_boundaries() {
        let p = RowPartition::balanced(8, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 0);
        assert_eq!(p.owner(2), 1);
        assert_eq!(p.owner(7), 3);
    }

    #[test]
    fn partition_more_parts_than_rows() {
        let p = RowPartition::balanced(2, 4);
        assert_eq!(p.len(0), 1);
        assert_eq!(p.len(1), 1);
        assert_eq!(p.len(2), 0);
        assert_eq!(p.len(3), 0);
    }

    #[test]
    fn split_1d_blocks_reassemble() {
        let a = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 3);
        let part = RowPartition::balanced(64, 4);
        let blocks = split_1d(&a, &part);
        assert_eq!(blocks.len(), 4);
        // Total nnz across diag + off-diag equals original.
        let total: usize = blocks
            .iter()
            .map(|b| b.diag.nnz() + b.off_diag.iter().map(|m| m.nnz()).sum::<usize>())
            .sum();
        assert_eq!(total, a.nnz());
        // Distributed SpMM the dumb way (every process uses full B)
        // reproduces serial SpMM.
        let bmat = Dense::from_fn(64, 8, |i, j| ((i * 13 + j * 7) % 10) as f32);
        let want = a.spmm(&bmat);
        for (p, blk) in blocks.iter().enumerate() {
            let (r0, r1) = part.range(p);
            let (c0, c1) = part.range(p);
            let b_local = Dense::from_fn(c1 - c0, 8, |i, j| bmat.get(c0 + i, j));
            let mut c_local = blk.diag.spmm(&b_local);
            for (q, off) in blk.off_diag.iter().enumerate() {
                if q == p {
                    continue;
                }
                let (q0, q1) = part.range(q);
                let b_q = Dense::from_fn(q1 - q0, 8, |i, j| bmat.get(q0 + i, j));
                off.spmm_acc(&b_q, &mut c_local);
            }
            for i in r0..r1 {
                for j in 0..8 {
                    assert!(
                        (c_local.get(i - r0, j) - want.get(i, j)).abs() < 1e-4,
                        "mismatch at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn assemble_1d_is_exact_inverse_of_split_1d() {
        // Byte-exact roundtrip, including NaN/-0.0 value bits, on uneven
        // boundaries with an empty rank.
        let mut a = gen::rmat(64, 700, (0.5, 0.2, 0.2), false, 9);
        if a.nnz() >= 2 {
            a.data[0] = f32::NAN;
            a.data[1] = -0.0;
        }
        for starts in [vec![0usize, 16, 32, 48, 64], vec![0, 5, 5, 40, 64], vec![0, 64]] {
            let part = RowPartition::from_starts(starts);
            let blocks = split_1d(&a, &part);
            let back = assemble_1d(&blocks, &part);
            assert_eq!(back.indptr, a.indptr);
            assert_eq!(back.indices, a.indices);
            assert_eq!(
                back.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                a.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "value bits must survive the roundtrip"
            );
        }
    }

    #[test]
    fn recover_partition_preserves_surviving_boundaries() {
        let part = RowPartition::from_starts(vec![0, 10, 25, 40, 64]);
        // Interior loss: successor absorbs.
        let r1 = recover_partition(&part, 1);
        assert_eq!(r1.starts, vec![0, 10, 40, 64]);
        // First rank: successor absorbs.
        let r0 = recover_partition(&part, 0);
        assert_eq!(r0.starts, vec![0, 25, 40, 64]);
        // Last rank has no successor: predecessor absorbs.
        let r3 = recover_partition(&part, 3);
        assert_eq!(r3.starts, vec![0, 10, 25, 64]);
        for (lost, rec) in [(1, &r1), (0, &r0), (3, &r3)] {
            assert_eq!(rec.nparts, 3);
            assert_eq!(rec.n, part.n);
            assert!(
                rec.starts.iter().all(|s| part.starts.contains(s)),
                "lost={lost}: recovery must not invent boundaries"
            );
        }
        // Down to one rank: everything merges.
        let two = RowPartition::from_starts(vec![0, 3, 8]);
        assert_eq!(recover_partition(&two, 0).starts, vec![0, 8]);
        assert_eq!(recover_partition(&two, 1).starts, vec![0, 8]);
    }

    #[test]
    fn from_starts_roundtrip_with_empty_parts() {
        let p = RowPartition::from_starts(vec![0, 0, 4, 4, 8]);
        assert_eq!(p.n, 8);
        assert_eq!(p.nparts, 4);
        assert_eq!(p.len(0), 0);
        assert_eq!(p.len(1), 4);
        assert_eq!(p.len(2), 0);
        assert_eq!(p.len(3), 4);
        for r in 0..8 {
            let (owner, local) = p.to_local(r);
            assert!(p.len(owner) > 0, "row {r} assigned to empty part {owner}");
            assert_eq!(p.to_global(owner, local), r);
        }
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn from_starts_rejects_decreasing() {
        let _ = RowPartition::from_starts(vec![0, 5, 3, 8]);
    }

    #[test]
    fn nnz_balanced_conserves_and_reduces_straggler() {
        // rmat with a strong top-left bias concentrates nnz in low row
        // indices — equal row counts are maximally unfair here.
        let a = gen::rmat(256, 4000, (0.6, 0.18, 0.18), false, 11);
        for nparts in [2usize, 4, 8, 16] {
            let bal = RowPartition::balanced(a.nrows, nparts);
            let nnz = RowPartition::nnz_balanced(&a, nparts);
            assert_eq!(*nnz.starts.last().unwrap(), a.nrows);
            assert_eq!(rank_nnz(&a, &nnz).iter().sum::<u64>(), a.nnz() as u64);
            assert!(
                max_rank_nnz(&a, &nnz) <= max_rank_nnz(&a, &bal),
                "nparts={nparts}: nnz-balanced {} > balanced {}",
                max_rank_nnz(&a, &nnz),
                max_rank_nnz(&a, &bal)
            );
        }
        // And the skew is actually large enough for a strict win at 8.
        let bal = RowPartition::balanced(a.nrows, 8);
        let nnz = RowPartition::nnz_balanced(&a, 8);
        assert!(max_rank_nnz(&a, &nnz) < max_rank_nnz(&a, &bal));
    }

    #[test]
    fn nnz_balanced_handles_degenerate_inputs() {
        // All-zero matrix falls back to balanced.
        let z = Csr::zeros(16, 16);
        assert_eq!(
            RowPartition::nnz_balanced(&z, 4).starts,
            RowPartition::balanced(16, 4).starts
        );
        // One hot row owning every nonzero: some ranks must be empty and
        // nothing is lost.
        let mut coo = crate::sparse::Coo::new(32, 32);
        for c in 0..32 {
            coo.push(5, c, 1.0);
        }
        let a = coo.to_csr();
        let p = RowPartition::nnz_balanced(&a, 4);
        assert_eq!(rank_nnz(&a, &p).iter().sum::<u64>(), 32);
        assert_eq!(max_rank_nnz(&a, &p), 32, "one row cannot be split");
        // More parts than rows.
        let small = gen::erdos_renyi(4, 4, 8, 1);
        let p = RowPartition::nnz_balanced(&small, 9);
        assert_eq!(p.nparts, 9);
        assert_eq!(*p.starts.last().unwrap(), 4);
        assert_eq!(rank_nnz(&small, &p).iter().sum::<u64>(), small.nnz() as u64);
    }

    #[test]
    fn nnz_balanced_hub_row_keeps_interior_ranks_nonempty() {
        // A hub row whose nnz swallows several per-rank targets used to
        // make nearest-boundary rounding repeat a start, silently leaving
        // *interior* ranks with zero rows (and zero nnz), which skewed
        // CostRefined's straggler term. Boundaries must strictly advance
        // while rows remain; only tail ranks may be empty.
        let mut coo = crate::sparse::Coo::new(32, 32);
        for c in 0..32 {
            coo.push(5, c, 1.0); // hub: row 5 owns every nonzero
        }
        let a = coo.to_csr();
        for nparts in [2usize, 4, 8] {
            let p = RowPartition::nnz_balanced(&a, nparts);
            for q in 0..nparts {
                let tail_empty = (q + 1..nparts).all(|r| p.len(r) == 0);
                assert!(
                    p.len(q) > 0 || tail_empty,
                    "nparts={nparts}: interior rank {q} empty in {:?}",
                    p.starts
                );
            }
            assert_eq!(rank_nnz(&a, &p).iter().sum::<u64>(), 32);
        }
        // Hub off-center plus trailing light rows: every rank must still
        // get at least one row (32 rows ≥ 8 parts, so none may be empty).
        let mut coo = crate::sparse::Coo::new(32, 32);
        for c in 0..32 {
            coo.push(9, c, 1.0);
        }
        for r in 20..32 {
            coo.push(r, 0, 1.0);
        }
        let a = coo.to_csr();
        let p = RowPartition::nnz_balanced(&a, 8);
        for q in 0..8 {
            assert!(p.len(q) > 0, "rank {q} empty in {:?}", p.starts);
        }
        assert_eq!(rank_nnz(&a, &p).iter().sum::<u64>(), a.nnz() as u64);
    }

    #[test]
    fn coarsen_nests_and_expand_replicated_inverts() {
        let part = RowPartition::from_starts(vec![0, 10, 25, 40, 64]);
        let g = part.coarsen(2);
        assert_eq!(g.starts, vec![0, 25, 64]);
        // Group boundaries are a subset of rank boundaries (nesting).
        assert!(g.starts.iter().all(|s| part.starts.contains(s)));
        assert_eq!(part.coarsen(1).starts, part.starts);
        assert_eq!(part.coarsen(4).starts, vec![0, 64]);
        // Expansion puts each group's rows on its home rank and zero rows
        // on the members.
        let e = g.expand_replicated(2);
        assert_eq!(e.nparts, 4);
        assert_eq!(e.starts, vec![0, 25, 25, 64, 64]);
        assert_eq!(e.len(0), 25);
        assert_eq!(e.len(1), 0);
        assert_eq!(e.len(2), 39);
        assert_eq!(e.len(3), 0);
        assert_eq!(g.expand_replicated(1).starts, g.starts);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn coarsen_rejects_nondivisor() {
        let _ = RowPartition::from_starts(vec![0, 10, 25, 64]).coarsen(2);
    }

    #[test]
    fn cost_refined_never_worse_than_nnz_balanced_objective() {
        let a = gen::powerlaw(128, 1500, 1.4, 7);
        let topo = crate::topology::Topology::tsubame4(8);
        let nnz = RowPartition::nnz_balanced(&a, 8);
        let refined = Partitioner::CostRefined.partition(&a, 8, &topo, 32);
        assert_eq!(*refined.starts.last().unwrap(), a.nrows);
        assert!(
            refine_objective(&a, &refined, &topo, 32)
                <= refine_objective(&a, &nnz, &topo, 32) + 1e-15
        );
    }

    #[test]
    fn partitioner_names_roundtrip() {
        for p in Partitioner::ALL {
            assert_eq!(Partitioner::by_name(p.name()), Some(p));
        }
        assert_eq!(Partitioner::by_name("nnz"), Some(Partitioner::NnzBalanced));
        assert_eq!(Partitioner::by_name("cost"), Some(Partitioner::CostRefined));
        assert_eq!(Partitioner::by_name("nope"), None);
        // Balanced partitioner reproduces the seed constructor exactly.
        let a = gen::rmat(64, 600, (0.5, 0.2, 0.2), false, 2);
        let topo = crate::topology::Topology::tsubame4(4);
        assert_eq!(
            Partitioner::Balanced.partition(&a, 4, &topo, 32).starts,
            RowPartition::balanced(64, 4).starts
        );
    }

    #[test]
    fn grid2d_near_square() {
        let g = Grid2D::near_square(12);
        assert_eq!(g.size(), 12);
        assert!(g.pr >= 2 && g.pc >= 2, "{g:?}");
        let g1 = Grid2D::near_square(7);
        assert_eq!(g1.size(), 7);
        let (r, c) = g.coords(g.rank(2, 1));
        assert_eq!((r, c), (2, 1));
    }
}
