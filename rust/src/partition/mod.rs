//! Matrix partitioning: 1D row partition (SHIRO's setting, paper §2.2) plus
//! the 1.5D and 2D layouts needed by the CAGNET/SPA/BCL baselines.

use crate::sparse::Csr;

/// A 1D row partition of an n-row matrix over `nparts` processes:
/// contiguous, balanced row ranges.
#[derive(Clone, Debug)]
pub struct RowPartition {
    pub n: usize,
    pub nparts: usize,
    /// `starts[p]..starts[p+1]` is process p's row range. Length nparts+1.
    pub starts: Vec<usize>,
}

impl RowPartition {
    /// Balanced contiguous partition (remainder spread over leading parts).
    pub fn balanced(n: usize, nparts: usize) -> RowPartition {
        assert!(nparts > 0);
        let base = n / nparts;
        let rem = n % nparts;
        let mut starts = Vec::with_capacity(nparts + 1);
        let mut acc = 0;
        starts.push(0);
        for p in 0..nparts {
            acc += base + usize::from(p < rem);
            starts.push(acc);
        }
        RowPartition { n, nparts, starts }
    }

    #[inline]
    pub fn range(&self, p: usize) -> (usize, usize) {
        (self.starts[p], self.starts[p + 1])
    }

    #[inline]
    pub fn len(&self, p: usize) -> usize {
        self.starts[p + 1] - self.starts[p]
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Which process owns global row `r`.
    pub fn owner(&self, r: usize) -> usize {
        debug_assert!(r < self.n);
        // starts is sorted; partition_point gives the first start > r.
        self.starts.partition_point(|&s| s <= r) - 1
    }

    /// Convert a global row index to (owner, local index).
    pub fn to_local(&self, r: usize) -> (usize, usize) {
        let p = self.owner(r);
        (p, r - self.starts[p])
    }

    pub fn to_global(&self, p: usize, local: usize) -> usize {
        self.starts[p] + local
    }
}

/// Process p's view of the 1D-partitioned sparse matrix: its diagonal block
/// and every off-diagonal block `A^(p,q)` (paper notation), with column
/// indices re-based to the owner q's local row space of B.
#[derive(Clone, Debug)]
pub struct LocalBlocks {
    pub rank: usize,
    /// `A^(p,p)` — needs only local `B^(p,:)`.
    pub diag: Csr,
    /// `blocks[q]` = `A^(p,q)` for q ≠ p (entry for q == p is an empty
    /// matrix); column indices are local to q's B rows.
    pub off_diag: Vec<Csr>,
}

/// Split the full matrix into per-process local blocks under a 1D row
/// partition. This is the offline "Matrix Sparsity Analysis" input
/// (workflow step 1, paper §5.1).
pub fn split_1d(a: &Csr, part: &RowPartition) -> Vec<LocalBlocks> {
    assert_eq!(a.nrows, part.n);
    assert_eq!(a.ncols, part.n, "1D SpMM expects square A");
    (0..part.nparts)
        .map(|p| {
            let (r0, r1) = part.range(p);
            let off_diag = (0..part.nparts)
                .map(|q| {
                    if q == p {
                        Csr::zeros(r1 - r0, part.len(q))
                    } else {
                        let (c0, c1) = part.range(q);
                        a.block(r0, r1, c0, c1)
                    }
                })
                .collect();
            let (c0, c1) = part.range(p);
            LocalBlocks {
                rank: p,
                diag: a.block(r0, r1, c0, c1),
                off_diag,
            }
        })
        .collect()
}

/// 2D process grid used by the BCL baseline (stationary C): processes are
/// arranged pr × pc; A is tiled into pr × pc blocks.
#[derive(Clone, Copy, Debug)]
pub struct Grid2D {
    pub pr: usize,
    pub pc: usize,
}

impl Grid2D {
    /// Nearly-square grid for `nparts` processes.
    pub fn near_square(nparts: usize) -> Grid2D {
        let mut pr = (nparts as f64).sqrt() as usize;
        while pr > 1 && nparts % pr != 0 {
            pr -= 1;
        }
        Grid2D {
            pr: pr.max(1),
            pc: nparts / pr.max(1),
        }
    }

    pub fn coords(&self, rank: usize) -> (usize, usize) {
        (rank / self.pc, rank % self.pc)
    }

    pub fn rank(&self, r: usize, c: usize) -> usize {
        r * self.pc + c
    }

    pub fn size(&self) -> usize {
        self.pr * self.pc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::Dense;
    use crate::sparse::gen;

    #[test]
    fn balanced_partition_covers() {
        let p = RowPartition::balanced(10, 3);
        assert_eq!(p.starts, vec![0, 4, 7, 10]);
        assert_eq!(p.len(0), 4);
        assert_eq!(p.len(2), 3);
        for r in 0..10 {
            let (owner, local) = p.to_local(r);
            assert_eq!(p.to_global(owner, local), r);
        }
    }

    #[test]
    fn owner_boundaries() {
        let p = RowPartition::balanced(8, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 0);
        assert_eq!(p.owner(2), 1);
        assert_eq!(p.owner(7), 3);
    }

    #[test]
    fn partition_more_parts_than_rows() {
        let p = RowPartition::balanced(2, 4);
        assert_eq!(p.len(0), 1);
        assert_eq!(p.len(1), 1);
        assert_eq!(p.len(2), 0);
        assert_eq!(p.len(3), 0);
    }

    #[test]
    fn split_1d_blocks_reassemble() {
        let a = gen::rmat(64, 500, (0.5, 0.2, 0.2), false, 3);
        let part = RowPartition::balanced(64, 4);
        let blocks = split_1d(&a, &part);
        assert_eq!(blocks.len(), 4);
        // Total nnz across diag + off-diag equals original.
        let total: usize = blocks
            .iter()
            .map(|b| b.diag.nnz() + b.off_diag.iter().map(|m| m.nnz()).sum::<usize>())
            .sum();
        assert_eq!(total, a.nnz());
        // Distributed SpMM the dumb way (every process uses full B)
        // reproduces serial SpMM.
        let bmat = Dense::from_fn(64, 8, |i, j| ((i * 13 + j * 7) % 10) as f32);
        let want = a.spmm(&bmat);
        for (p, blk) in blocks.iter().enumerate() {
            let (r0, r1) = part.range(p);
            let (c0, c1) = part.range(p);
            let b_local = Dense::from_fn(c1 - c0, 8, |i, j| bmat.get(c0 + i, j));
            let mut c_local = blk.diag.spmm(&b_local);
            for (q, off) in blk.off_diag.iter().enumerate() {
                if q == p {
                    continue;
                }
                let (q0, q1) = part.range(q);
                let b_q = Dense::from_fn(q1 - q0, 8, |i, j| bmat.get(q0 + i, j));
                off.spmm_acc(&b_q, &mut c_local);
            }
            for i in r0..r1 {
                for j in 0..8 {
                    assert!(
                        (c_local.get(i - r0, j) - want.get(i, j)).abs() < 1e-4,
                        "mismatch at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn grid2d_near_square() {
        let g = Grid2D::near_square(12);
        assert_eq!(g.size(), 12);
        assert!(g.pr >= 2 && g.pc >= 2, "{g:?}");
        let g1 = Grid2D::near_square(7);
        assert_eq!(g1.size(), 7);
        let (r, c) = g.coords(g.rank(2, 1));
        assert_eq!((r, c), (2, 1));
    }
}
