//! Pattern-keyed plan cache with a compact binary on-disk form (same style
//! as the [`crate::sparse::io`] CSR cache).
//!
//! Planning is the expensive offline step (MWVC per pair); workloads that
//! re-plan the same operator — GNN layers sharing one Â, repeated epochs,
//! repeated benchmark runs — can key the compiled [`CommPlan`] by a
//! fingerprint of the partitioned blocks plus the planning inputs and skip
//! the solve entirely. The fingerprint covers the blocks' structure *and*
//! values because a plan embeds the numeric sub-blocks (`a_row_part` /
//! `a_col_part`) that the executor multiplies against.

use crate::comm::{CommPlan, PairPlan, Strategy};
use crate::cover::Solver;
use crate::partition::{LocalBlocks, RowPartition};
use crate::plan::{compile, CompiledPlan, PlanParams};
use crate::sparse::Csr;
use crate::topology::Topology;
use crate::util::bin::{r_csr, r_u64, w_csr, w_u64};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const PLAN_MAGIC: &[u8; 8] = b"SHIROPLN";
const PLAN_VERSION: u32 = 1;

// ---------------------------------------------------------------- keying ----

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }
    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
}

fn hash_csr(h: &mut Fnv, m: &Csr) {
    h.u64(m.nrows as u64);
    h.u64(m.ncols as u64);
    h.u64(m.nnz() as u64);
    for &v in &m.indptr {
        h.u64(v);
    }
    for &c in &m.indices {
        h.bytes(&c.to_le_bytes());
    }
    for &v in &m.data {
        h.bytes(&v.to_bits().to_le_bytes());
    }
}

/// Fingerprint of everything the adaptive compiler reads: the partitioned
/// off-diagonal blocks, the partition boundaries, the topology's cost
/// parameters (including its rank count and group size — for a replicated
/// lookup these are the *coarsened* group topology), the planning N, and
/// the replication factor. The boundaries (`part.starts`) are hashed
/// explicitly: two partitioners can induce structurally similar blocks
/// over different row ranges, and a plan compiled for one set of
/// boundaries embeds block heights the executor trusts — returning it for
/// another partition would be stale (regression-tested in
/// `partition_boundaries_key_the_cache`). The replication factor is
/// hashed for the same reason (same bug class): a `c=2` group plan and a
/// `c=1` flat plan can share boundaries on small inputs, but they embed
/// different flow structure — regression-tested in
/// `replication_factor_keys_the_cache`.
pub fn pattern_key(
    blocks: &[LocalBlocks],
    part: &RowPartition,
    topo: &Topology,
    params: &PlanParams,
) -> u64 {
    let mut h = Fnv::new();
    h.u64(part.nparts as u64);
    for &s in &part.starts {
        h.u64(s as u64);
    }
    h.u64(params.replicate as u64);
    h.u64(topo.nranks as u64);
    h.u64(topo.group_size as u64);
    h.u64(topo.intra_bw.to_bits());
    h.u64(topo.inter_bw.to_bits());
    h.u64(topo.intra_lat.to_bits());
    h.u64(topo.inter_lat.to_bits());
    h.u64(topo.compute_rate.to_bits());
    h.u64(topo.kernel_launch.to_bits());
    h.u64(params.n_dense as u64);
    for b in blocks {
        h.u64(b.rank as u64);
        for (q, blk) in b.off_diag.iter().enumerate() {
            if q != b.rank {
                hash_csr(&mut h, blk);
            }
        }
    }
    h.0
}

/// Fingerprint of one global sparse matrix (structure and values, FNV over
/// the CSR arrays). The serve layer's session-registry key: two registered
/// graphs with the same fingerprint can share every session and every
/// cached plan, whatever name the tenants registered them under.
pub fn csr_fingerprint(a: &Csr) -> u64 {
    let mut h = Fnv::new();
    hash_csr(&mut h, a);
    h.0
}

// --------------------------------------------------------- serialization ----
//
// The scalar/CSR primitives live in `util::bin` (shared with the multiproc
// wire format); this module only owns the plan-file layout around them.

pub(crate) fn encode_strategy(s: Strategy) -> u8 {
    match s {
        Strategy::Block => 0,
        Strategy::Column => 1,
        Strategy::Row => 2,
        Strategy::Joint(Solver::Koenig) => 3,
        Strategy::Joint(Solver::Dinic) => 4,
        Strategy::Joint(Solver::Greedy) => 5,
        Strategy::Joint(Solver::ColumnOnly) => 6,
        Strategy::Joint(Solver::RowOnly) => 7,
        Strategy::Adaptive => 8,
    }
}

pub(crate) fn decode_strategy(tag: u8) -> Result<Strategy> {
    Ok(match tag {
        0 => Strategy::Block,
        1 => Strategy::Column,
        2 => Strategy::Row,
        3 => Strategy::Joint(Solver::Koenig),
        4 => Strategy::Joint(Solver::Dinic),
        5 => Strategy::Joint(Solver::Greedy),
        6 => Strategy::Joint(Solver::ColumnOnly),
        7 => Strategy::Joint(Solver::RowOnly),
        8 => Strategy::Adaptive,
        _ => bail!("unknown strategy tag {tag}"),
    })
}

/// Serialize a plan (with its pattern key) to a compact binary file. Only
/// the split parts and flags are stored; the packed compact operands and
/// index lists are derived on load via [`PairPlan::from_parts`].
pub fn save_plan(plan: &CommPlan, key: u64, path: &Path) -> Result<()> {
    // Write to a temp file and rename so a killed process never leaves a
    // half-written entry at the final path. The suffix carries a
    // process-wide counter in addition to the pid: two PlanCache
    // instances (or concurrent sessions) in one process saving the same
    // key must not share a temp path, or one writer truncates the file
    // under the other and the rename publishes a torn entry.
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let f = std::fs::File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(PLAN_MAGIC)?;
    w_u64(&mut w, PLAN_VERSION as u64)?;
    w_u64(&mut w, key)?;
    w_u64(&mut w, plan.nranks as u64)?;
    w.write_all(&[encode_strategy(plan.strategy)])?;
    for &rows in &plan.block_rows {
        w_u64(&mut w, rows as u64)?;
    }
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p == q {
                continue;
            }
            let pair = &plan.pairs[p][q];
            w.write_all(&[u8::from(pair.full_block)])?;
            w_csr(&mut w, &pair.a_row_part)?;
            w_csr(&mut w, &pair.a_col_part)?;
        }
    }
    w.into_inner().map_err(|e| anyhow::anyhow!("flush {}: {}", tmp.display(), e))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Load a plan saved by [`save_plan`], verifying magic, version, and (when
/// `expect_key` is `Some`) the pattern key.
pub fn load_plan(path: &Path, expect_key: Option<u64>) -> Result<CommPlan> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    // Every serialized element occupies at least 4 bytes, so no valid
    // length field can exceed this bound; see r_csr.
    let max_elems = (f.metadata()?.len() / 4) as usize;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != PLAN_MAGIC {
        bail!("bad plan magic");
    }
    let version = r_u64(&mut r)?;
    if version != PLAN_VERSION as u64 {
        bail!("plan cache version {version} != {PLAN_VERSION}");
    }
    let key = r_u64(&mut r)?;
    if let Some(want) = expect_key {
        if key != want {
            bail!("plan cache key mismatch: file {key:#x}, expected {want:#x}");
        }
    }
    let nranks = r_u64(&mut r)? as usize;
    if nranks > max_elems {
        bail!("plan cache entry corrupt: nranks {nranks} exceeds file size");
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let strategy = decode_strategy(tag[0])?;
    let mut block_rows = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        block_rows.push(r_u64(&mut r)? as usize);
    }
    let mut pairs = Vec::with_capacity(nranks);
    for p in 0..nranks {
        let mut row = Vec::with_capacity(nranks);
        for q in 0..nranks {
            if p == q {
                row.push(PairPlan::default());
                continue;
            }
            let mut fb = [0u8; 1];
            r.read_exact(&mut fb)?;
            let a_row_part = r_csr(&mut r, max_elems)?;
            let a_col_part = r_csr(&mut r, max_elems)?;
            row.push(PairPlan::from_parts(a_row_part, a_col_part, fb[0] != 0));
        }
        pairs.push(row);
    }
    Ok(CommPlan { nranks, strategy, pairs, block_rows })
}

// ----------------------------------------------------------------- cache ----

/// In-memory (optionally disk-backed) cache of compiled adaptive plans.
pub struct PlanCache {
    dir: Option<PathBuf>,
    mem: HashMap<u64, CommPlan>,
    pub hits: u64,
    pub misses: u64,
}

impl PlanCache {
    /// Session-local cache (no persistence).
    pub fn in_memory() -> PlanCache {
        PlanCache { dir: None, mem: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Disk-backed cache: entries persist as `plan_<key>.bin` under `dir`
    /// (created on first save), surviving process restarts.
    pub fn with_dir(dir: &Path) -> PlanCache {
        PlanCache {
            dir: Some(dir.to_path_buf()),
            mem: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    fn entry_path(&self, key: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("plan_{key:016x}.bin")))
    }

    /// Return the cached plan for this (blocks, partition, topology, params)
    /// fingerprint, compiling on miss. The bool is `true` on a cache hit.
    pub fn get_or_compile(
        &mut self,
        blocks: &[LocalBlocks],
        part: &RowPartition,
        topo: &Topology,
        params: &PlanParams,
    ) -> (CommPlan, bool) {
        let key = pattern_key(blocks, part, topo, params);
        if let Some(plan) = self.mem.get(&key) {
            self.hits += 1;
            return (plan.clone(), true);
        }
        if let Some(path) = self.entry_path(key) {
            if path.exists() {
                if let Ok(plan) = load_plan(&path, Some(key)) {
                    self.hits += 1;
                    self.mem.insert(key, plan.clone());
                    return (plan, true);
                }
            }
        }
        self.misses += 1;
        let CompiledPlan { plan, .. } = compile(blocks, part, topo, params);
        if let Some(path) = self.entry_path(key) {
            if let Some(parent) = path.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            // Best-effort persistence: a failed write only costs re-planning.
            let _ = save_plan(&plan, key, &path);
        }
        self.mem.insert(key, plan.clone());
        (plan, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::split_1d;
    use crate::sparse::gen;

    fn setup(seed: u64) -> (RowPartition, Vec<LocalBlocks>, Topology) {
        let a = gen::rmat(128, 1200, (0.55, 0.2, 0.19), false, seed);
        let part = RowPartition::balanced(128, 8);
        let blocks = split_1d(&a, &part);
        (part, blocks, Topology::tsubame4(8))
    }

    fn assert_plans_equal(a: &CommPlan, b: &CommPlan) {
        assert_eq!(a.nranks, b.nranks);
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.block_rows, b.block_rows);
        for p in 0..a.nranks {
            for q in 0..a.nranks {
                let (x, y) = (&a.pairs[p][q], &b.pairs[p][q]);
                assert_eq!(x.full_block, y.full_block, "({p},{q})");
                assert_eq!(x.b_rows, y.b_rows, "({p},{q})");
                assert_eq!(x.c_rows, y.c_rows, "({p},{q})");
                assert_eq!(x.a_row_part, y.a_row_part, "({p},{q})");
                assert_eq!(x.a_col_part, y.a_col_part, "({p},{q})");
                assert_eq!(x.a_row_compact, y.a_row_compact, "({p},{q})");
                assert_eq!(x.a_col_compact, y.a_col_compact, "({p},{q})");
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let (part, blocks, topo) = setup(1);
        let compiled = compile(&blocks, &part, &topo, &PlanParams::default());
        let key = pattern_key(&blocks, &part, &topo, &PlanParams::default());
        let dir = std::env::temp_dir().join("shiro_plan_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.bin");
        save_plan(&compiled.plan, key, &path).unwrap();
        let back = load_plan(&path, Some(key)).unwrap();
        assert_plans_equal(&compiled.plan, &back);
        // Wrong key is rejected.
        assert!(load_plan(&path, Some(key ^ 1)).is_err());
    }

    #[test]
    fn concurrent_saves_of_one_key_never_corrupt() {
        // Satellite regression (PR 6): the temp-file suffix must be unique
        // per save, not just per process — with a pid-only suffix, two
        // in-process writers of the same key truncate each other's temp
        // file and can rename a torn entry into the cache.
        let (part, blocks, topo) = setup(7);
        let compiled = compile(&blocks, &part, &topo, &PlanParams::default());
        let key = pattern_key(&blocks, &part, &topo, &PlanParams::default());
        let dir = std::env::temp_dir().join("shiro_plan_cache_race_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("raced.bin");
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..4 {
                        save_plan(&compiled.plan, key, &path).unwrap();
                        // Rename is atomic, so every concurrent load must
                        // see a complete, valid entry.
                        let back = load_plan(&path, Some(key)).unwrap();
                        assert_plans_equal(&compiled.plan, &back);
                    }
                });
            }
        });
        // No temp files left behind.
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path() != path)
            .collect();
        assert!(stray.is_empty(), "leftover temp files: {stray:?}");
    }

    #[test]
    fn cache_hits_and_misses() {
        let (part, blocks, topo) = setup(2);
        let mut cache = PlanCache::in_memory();
        let params = PlanParams::default();
        let (first, hit1) = cache.get_or_compile(&blocks, &part, &topo, &params);
        assert!(!hit1);
        let (second, hit2) = cache.get_or_compile(&blocks, &part, &topo, &params);
        assert!(hit2);
        assert_plans_equal(&first, &second);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        // A different pattern misses.
        let (part3, blocks3, _) = setup(3);
        let (_, hit3) = cache.get_or_compile(&blocks3, &part3, &topo, &params);
        assert!(!hit3);
        assert_eq!(cache.misses, 2);
    }

    #[test]
    fn disk_cache_survives_new_instance() {
        let (part, blocks, topo) = setup(4);
        let dir = std::env::temp_dir().join("shiro_plan_cache_disk_test");
        let _ = std::fs::remove_dir_all(&dir);
        let params = PlanParams::default();
        let mut c1 = PlanCache::with_dir(&dir);
        let (plan1, hit) = c1.get_or_compile(&blocks, &part, &topo, &params);
        assert!(!hit);
        // Fresh instance (no shared memory): must hit from disk.
        let mut c2 = PlanCache::with_dir(&dir);
        let (plan2, hit) = c2.get_or_compile(&blocks, &part, &topo, &params);
        assert!(hit, "expected disk hit");
        assert_plans_equal(&plan1, &plan2);
    }

    #[test]
    fn corrupt_disk_entry_recompiles_and_heals() {
        let (part, blocks, topo) = setup(6);
        let dir = std::env::temp_dir().join("shiro_plan_cache_corrupt_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let params = PlanParams::default();
        let key = pattern_key(&blocks, &part, &topo, &params);
        let path = dir.join(format!("plan_{key:016x}.bin"));
        // Valid magic/version/key, then an absurd nranks: must error out
        // cleanly (no huge allocation attempt).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(PLAN_MAGIC);
        bytes.extend_from_slice(&(PLAN_VERSION as u64).to_le_bytes());
        bytes.extend_from_slice(&key.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_plan(&path, Some(key)).is_err());
        let mut cache = PlanCache::with_dir(&dir);
        let (_, hit) = cache.get_or_compile(&blocks, &part, &topo, &params);
        assert!(!hit, "corrupt entry must not count as a hit");
        // The recompiled plan atomically replaced the corrupt file.
        assert!(load_plan(&path, Some(key)).is_ok());
    }

    #[test]
    fn partition_boundaries_key_the_cache() {
        // Satellite regression (PR 3): switching partitioners on the same
        // matrix must miss the cache, never return the stale Balanced plan.
        let a = gen::rmat(256, 4000, (0.6, 0.18, 0.18), false, 9);
        let topo = Topology::tsubame4(8);
        let params = PlanParams::default();
        let bal = RowPartition::balanced(256, 8);
        let nnz = RowPartition::nnz_balanced(&a, 8);
        assert_ne!(bal.starts, nnz.starts, "partitions must differ for this test");
        let bal_blocks = split_1d(&a, &bal);
        let nnz_blocks = split_1d(&a, &nnz);
        assert_ne!(
            pattern_key(&bal_blocks, &bal, &topo, &params),
            pattern_key(&nnz_blocks, &nnz, &topo, &params),
            "boundary change must change the fingerprint"
        );
        let mut cache = PlanCache::in_memory();
        let (bal_plan, hit) = cache.get_or_compile(&bal_blocks, &bal, &topo, &params);
        assert!(!hit);
        let (nnz_plan, hit) = cache.get_or_compile(&nnz_blocks, &nnz, &topo, &params);
        assert!(!hit, "NnzBalanced lookup must miss a Balanced-keyed cache");
        assert_eq!((cache.hits, cache.misses), (0, 2));
        // Each cached plan carries its own partition's block heights.
        let rows = |p: &RowPartition| (0..p.nparts).map(|i| p.len(i)).collect::<Vec<_>>();
        assert_eq!(bal_plan.block_rows, rows(&bal));
        assert_eq!(nnz_plan.block_rows, rows(&nnz));
    }

    #[test]
    fn replication_factor_keys_the_cache() {
        // Satellite regression: a c=2 group plan must never be served for
        // a c=1 lookup. Degenerate worst case: 2 ranks at c=2 collapse to
        // one group whose "partition" has the same boundary set as a
        // 1-rank c=1 run — only the replication factor (and the coarsened
        // topology) distinguishes the lookups.
        let a = gen::rmat(128, 1200, (0.55, 0.2, 0.19), false, 8);
        let rank_part = RowPartition::balanced(128, 8);
        let topo = Topology::tsubame4(8);
        let flat = PlanParams::default();
        assert_eq!(flat.replicate, 1);
        let rep2 = PlanParams { replicate: 2, ..Default::default() };
        // Same blocks/partition/topology, different factor: keys differ.
        let blocks = split_1d(&a, &rank_part);
        assert_ne!(
            pattern_key(&blocks, &rank_part, &topo, &flat),
            pattern_key(&blocks, &rank_part, &topo, &rep2),
            "replication factor must change the fingerprint"
        );
        // The real replicated lookup shape: coarsened partition + topology.
        let gpart = rank_part.coarsen(2);
        let gblocks = split_1d(&a, &gpart);
        let gtopo = topo.coarsen(2);
        let mut cache = PlanCache::in_memory();
        let (_, hit) = cache.get_or_compile(&blocks, &rank_part, &topo, &flat);
        assert!(!hit);
        let (gplan, hit) = cache.get_or_compile(&gblocks, &gpart, &gtopo, &rep2);
        assert!(!hit, "c=2 lookup must miss a c=1-keyed cache");
        let (gplan2, hit) = cache.get_or_compile(&gblocks, &gpart, &gtopo, &rep2);
        assert!(hit, "repeat c=2 lookup must hit its own entry");
        assert_plans_equal(&gplan, &gplan2);
        assert_eq!(gplan.nranks, 4, "group plan spans nranks/c groups");
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn key_sensitive_to_inputs() {
        let (part, blocks, topo) = setup(5);
        let params = PlanParams::default();
        let k1 = pattern_key(&blocks, &part, &topo, &params);
        assert_eq!(k1, pattern_key(&blocks, &part, &topo, &params));
        let k2 = pattern_key(
            &blocks,
            &part,
            &topo,
            &PlanParams { n_dense: 64, ..Default::default() },
        );
        assert_ne!(k1, k2);
        let k3 = pattern_key(&blocks, &part, &Topology::aurora(8), &params);
        assert_ne!(k1, k3);
    }
}
