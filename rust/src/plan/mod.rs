//! Adaptive per-pair plan compiler (the `Strategy::Adaptive` backend).
//!
//! SHIRO's near-optimality argument is per process pair: the cheapest
//! communication *shape* for the flow q→p depends on the off-diagonal
//! block's sparsity pattern **and** on the link the pair crosses. The seed
//! planner applied one fixed [`Strategy`] globally; this module instead
//! evaluates all four candidate shapes — Block, Column, Row, Joint — for
//! every pair under the α-β(+compute) cost model already used by
//! [`crate::sim`] and [`crate::topology`], and emits a mixed-strategy
//! [`CommPlan`] that `exec`, `hierarchy`, and `spmm` consume unchanged.
//!
//! Cost model per pair (DESIGN.md §5): one aggregate message of
//! `volume_bytes` on the pair's tier costs `lat + bytes/bw`; candidates
//! with a row-based portion additionally pay the source-side partial-SpMM
//! compute `2·nnz_row·N / compute_rate` plus one kernel launch. Ties are
//! broken toward the hierarchy-friendlier shape when the pair crosses the
//! slow inter-group tier (row-based partials pre-aggregate inside the
//! source group, Joint first), and toward the sparsity-aware shapes intra
//! group.
//!
//! Planning is offline preprocessing (workflow steps 1–2), so candidate
//! evaluation is parallelized across pairs with scoped threads; the result
//! is deterministic regardless of thread count. A pattern-keyed
//! [`cache::PlanCache`] with a compact on-disk form lets repeated GNN
//! layers/epochs (and repeated runs) skip re-planning entirely.

pub mod cache;

use crate::comm::{self, CommPlan, PairPlan, Strategy};
use crate::cover::Solver;
use crate::partition::{LocalBlocks, RowPartition};
use crate::sparse::Csr;
use crate::topology::{Tier, Topology};

/// The four candidate communication shapes evaluated per (q→p) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    Block,
    Column,
    Row,
    Joint,
}

impl Shape {
    pub const ALL: [Shape; 4] = [Shape::Block, Shape::Column, Shape::Row, Shape::Joint];

    /// The fixed strategy this candidate is planned with.
    pub fn strategy(self) -> Strategy {
        match self {
            Shape::Block => Strategy::Block,
            Shape::Column => Strategy::Column,
            Shape::Row => Strategy::Row,
            Shape::Joint => Strategy::Joint(Solver::Koenig),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Shape::Block => "block",
            Shape::Column => "column",
            Shape::Row => "row",
            Shape::Joint => "joint",
        }
    }
}

/// Planner knobs.
#[derive(Clone, Debug)]
pub struct PlanParams {
    /// Dense column count N the α-β cost is evaluated at. Volume scales
    /// linearly in N, so N only shifts the balance between the latency and
    /// compute terms; 32 matches the paper's default SpMM width.
    pub n_dense: usize,
    /// Planner thread cap; 0 = one thread per available core.
    pub threads: usize,
    /// Replication factor the plan is compiled for (1 = flat 1D). For
    /// `c > 1` the plan is a *group* plan over `nranks/c` coarsened parts;
    /// the factor participates in the cache fingerprint so a `c=2` group
    /// plan can never be served for a `c=1` lookup (or vice versa).
    pub replicate: usize,
}

impl Default for PlanParams {
    fn default() -> Self {
        PlanParams { n_dense: 32, threads: 0, replicate: 1 }
    }
}

/// A compiled mixed-strategy plan plus the per-pair decisions that produced
/// it (for reporting and the ablation benches).
pub struct CompiledPlan {
    /// The mixed plan, tagged `Strategy::Adaptive`. Structurally a normal
    /// [`CommPlan`]: downstream consumers need no changes.
    pub plan: CommPlan,
    /// `choices[p][q]` = shape selected for flow q→p (`None` on the
    /// diagonal and for empty blocks).
    pub choices: Vec<Vec<Option<Shape>>>,
    /// Σ per-pair modeled cost of the selected candidates (seconds).
    pub modeled_cost: f64,
}

impl CompiledPlan {
    /// Count of non-empty pairs that selected each shape, in
    /// [`Shape::ALL`] order.
    pub fn shape_counts(&self) -> [usize; 4] {
        let mut counts = [0usize; 4];
        for row in &self.choices {
            for choice in row.iter().flatten() {
                let k = Shape::ALL.iter().position(|s| s == choice).unwrap();
                counts[k] += 1;
            }
        }
        counts
    }
}

/// Modeled α-β(+compute) cost of one pair plan on the given tier
/// (seconds). `k_src` is the source rank's B-block height (for Eq. 1
/// volumes of sparsity-oblivious pairs).
pub fn pair_cost(
    pair: &PairPlan,
    k_src: usize,
    tier: Tier,
    topo: &Topology,
    n_dense: usize,
) -> f64 {
    let bytes = pair.volume_bytes(k_src, n_dense);
    let mut cost = 0.0;
    if bytes > 0 {
        cost += topo.lat(tier) + bytes as f64 / topo.bw(tier);
    }
    let row_nnz = pair.a_row_part.nnz();
    if row_nnz > 0 {
        // Row-based portions are computed at the source before sending:
        // marginal flops plus one (batched) kernel launch.
        cost += 2.0 * row_nnz as f64 * n_dense as f64 / topo.compute_rate + topo.kernel_launch;
    }
    cost
}

/// Σ [`pair_cost`] over all off-diagonal pairs of a plan — the objective
/// the adaptive compiler minimizes (per-pair independently, so the
/// adaptive total is ≤ any fixed strategy's total by construction).
pub fn modeled_cost(plan: &CommPlan, topo: &Topology, n_dense: usize) -> f64 {
    let mut total = 0.0;
    for p in 0..plan.nranks {
        for q in 0..plan.nranks {
            if p != q {
                total += pair_cost(
                    &plan.pairs[p][q],
                    plan.block_rows[q],
                    topo.tier(p, q),
                    topo,
                    n_dense,
                );
            }
        }
    }
    total
}

/// Replication factors `--replicate auto` searches over (filtered to the
/// divisors of the rank count). Powers of two up to 8 cover the paper's
/// memory-rich regimes without an exhaustive divisor sweep.
pub const REPLICATION_CANDIDATES: [usize; 4] = [1, 2, 4, 8];

/// Modeled cost (seconds) of running the 1.5D decomposition at replication
/// factor `c` on the rank partition `part`: the group plan's α-β cost on
/// the coarsened topology (all group-pair traffic is inter-group by
/// construction), plus the intra-group partial-C reduce-scatter
/// (member → home, sparsity-aware: only touched rows move, each carrying
/// its u32 row index), plus the heaviest group home's diagonal-block
/// compute — the straggler term that keeps `auto` from collapsing to
/// `c = nranks` (zero communication, zero parallelism).
pub fn replicated_cost(
    a: &Csr,
    part: &RowPartition,
    c: usize,
    strategy: Strategy,
    topo: &Topology,
    params: &PlanParams,
) -> f64 {
    let gpart = part.coarsen(c);
    let gblocks = crate::partition::split_1d(a, &gpart);
    let gtopo = topo.coarsen(c);
    let gplan = match strategy {
        Strategy::Adaptive => compile(&gblocks, &gpart, &gtopo, params).plan,
        s => comm::plan(&gblocks, &gpart, s, None),
    };
    let map = crate::topology::ReplicaMap::new(part.nparts, c);
    let rsched = crate::hierarchy::build_replicated(&gplan, &map);
    let inter = modeled_cost(&gplan, &gtopo, params.n_dense);
    let mut intra = 0.0;
    for asg in &rsched.assigns {
        if asg.red_to.is_some() && !asg.touched.is_empty() {
            let bytes = asg.touched.len() * (params.n_dense * comm::SZ_DT as usize + 4);
            intra += topo.intra_lat + bytes as f64 / topo.intra_bw;
        }
    }
    let max_diag = gblocks.iter().map(|b| b.diag.nnz()).max().unwrap_or(0);
    let straggler = 2.0 * max_diag as f64 * params.n_dense as f64 / topo.compute_rate;
    inter + intra + straggler
}

/// Pick the replication factor with the lowest [`replicated_cost`] among
/// [`REPLICATION_CANDIDATES`] that divide the rank count. Deterministic;
/// ties break toward the smaller factor (less memory), so `auto` only
/// replicates when the model says it strictly pays.
pub fn choose_replication(
    a: &Csr,
    part: &RowPartition,
    strategy: Strategy,
    topo: &Topology,
    params: &PlanParams,
) -> usize {
    let mut best_c = 1;
    let mut best = f64::INFINITY;
    for c in REPLICATION_CANDIDATES {
        if c > part.nparts || part.nparts % c != 0 {
            continue;
        }
        let cost = replicated_cost(a, part, c, strategy, topo, params);
        if cost < best {
            best = cost;
            best_c = c;
        }
    }
    best_c
}

/// Candidate evaluation order; earlier entries win cost ties. Crossing the
/// slow tier, row-based shapes rank above column-based ones because the
/// hierarchical schedule pre-aggregates partial C rows inside the source
/// group (one inter-group transfer per group instead of one per producer);
/// intra group the classic sparsity-aware order applies. Block is last on
/// both tiers — it is never strictly cheaper than Column.
fn preference(tier: Tier) -> [Shape; 4] {
    match tier {
        Tier::Inter => [Shape::Joint, Shape::Row, Shape::Column, Shape::Block],
        Tier::Intra => [Shape::Joint, Shape::Column, Shape::Row, Shape::Block],
    }
}

/// Evaluate all candidates for one off-diagonal block and keep the
/// cheapest (ties resolved by [`preference`] order).
fn plan_one(
    block: &Csr,
    p: usize,
    q: usize,
    k_src: usize,
    topo: &Topology,
    params: &PlanParams,
) -> (PairPlan, Option<Shape>, f64) {
    if block.nnz() == 0 {
        return (PairPlan::default(), None, 0.0);
    }
    let tier = topo.tier(p, q);
    let mut best: Option<(PairPlan, Shape, f64)> = None;
    for shape in preference(tier) {
        let cand = comm::plan_pair(block, shape.strategy(), p, q, None);
        let cost = pair_cost(&cand, k_src, tier, topo, params.n_dense);
        let better = match &best {
            None => true,
            Some((_, _, best_cost)) => cost < *best_cost,
        };
        if better {
            best = Some((cand, shape, cost));
        }
    }
    let (pair, shape, cost) = best.expect("at least one candidate");
    (pair, Some(shape), cost)
}

/// Compile an adaptive mixed-strategy plan: per-pair minimum over the four
/// candidate shapes under `topo`'s cost model, parallelized across pairs
/// with scoped threads.
pub fn compile(
    blocks: &[LocalBlocks],
    part: &RowPartition,
    topo: &Topology,
    params: &PlanParams,
) -> CompiledPlan {
    let n = part.nparts;
    assert_eq!(blocks.len(), n, "blocks/partition rank mismatch");
    let mut slots: Vec<Option<(PairPlan, Option<Shape>, f64)>> =
        (0..n * n).map(|_| None).collect();
    let nthreads = if params.threads == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        params.threads
    };
    let chunk = (n * n).div_ceil(nthreads.max(1)).max(1);
    std::thread::scope(|scope| {
        for (ci, chunk_slots) in slots.chunks_mut(chunk).enumerate() {
            let base = ci * chunk;
            scope.spawn(move || {
                for (off, slot) in chunk_slots.iter_mut().enumerate() {
                    let idx = base + off;
                    let (p, q) = (idx / n, idx % n);
                    if p == q {
                        continue;
                    }
                    *slot = Some(plan_one(
                        &blocks[p].off_diag[q],
                        p,
                        q,
                        part.len(q),
                        topo,
                        params,
                    ));
                }
            });
        }
    });

    let mut pairs: Vec<Vec<PairPlan>> = Vec::with_capacity(n);
    let mut choices: Vec<Vec<Option<Shape>>> = Vec::with_capacity(n);
    let mut modeled = 0.0;
    let mut slot_iter = slots.into_iter();
    for _p in 0..n {
        let mut pair_row = Vec::with_capacity(n);
        let mut choice_row = Vec::with_capacity(n);
        for _q in 0..n {
            match slot_iter.next().expect("slot count") {
                None => {
                    pair_row.push(PairPlan::default());
                    choice_row.push(None);
                }
                Some((pair, shape, cost)) => {
                    modeled += cost;
                    pair_row.push(pair);
                    choice_row.push(shape);
                }
            }
        }
        pairs.push(pair_row);
        choices.push(choice_row);
    }
    CompiledPlan {
        plan: CommPlan {
            nranks: n,
            strategy: Strategy::Adaptive,
            pairs,
            block_rows: (0..n).map(|p| part.len(p)).collect(),
        },
        choices,
        modeled_cost: modeled,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::split_1d;
    use crate::sparse::gen;

    fn setup(n: usize, ranks: usize, seed: u64) -> (RowPartition, Vec<LocalBlocks>) {
        let a = gen::rmat(n, n * 8, (0.55, 0.2, 0.19), false, seed);
        let part = RowPartition::balanced(n, ranks);
        let blocks = split_1d(&a, &part);
        (part, blocks)
    }

    #[test]
    fn per_pair_never_worse_than_any_fixed_shape() {
        let (part, blocks) = setup(128, 8, 1);
        let topo = Topology::tsubame4(8);
        let params = PlanParams::default();
        let compiled = compile(&blocks, &part, &topo, &params);
        for p in 0..8 {
            for q in 0..8 {
                if p == q {
                    continue;
                }
                let tier = topo.tier(p, q);
                let k_src = part.len(q);
                let chosen = pair_cost(
                    &compiled.plan.pairs[p][q],
                    k_src,
                    tier,
                    &topo,
                    params.n_dense,
                );
                for shape in Shape::ALL {
                    let block = &blocks[p].off_diag[q];
                    if block.nnz() == 0 {
                        continue;
                    }
                    let cand = comm::plan_pair(block, shape.strategy(), p, q, None);
                    let c = pair_cost(&cand, k_src, tier, &topo, params.n_dense);
                    assert!(
                        chosen <= c,
                        "({p},{q}): adaptive {chosen} > {} {c}",
                        shape.name()
                    );
                }
            }
        }
    }

    #[test]
    fn total_cost_not_above_any_fixed_strategy() {
        for (ranks, seed) in [(4usize, 2u64), (8, 3), (12, 4)] {
            let (part, blocks) = setup(256, ranks, seed);
            for topo in [
                Topology::tsubame4(ranks),
                Topology::aurora(ranks),
                Topology::flat(ranks, 25e9),
            ] {
                let params = PlanParams::default();
                let compiled = compile(&blocks, &part, &topo, &params);
                assert!(
                    (compiled.modeled_cost
                        - modeled_cost(&compiled.plan, &topo, params.n_dense))
                    .abs()
                        < 1e-9
                );
                for shape in Shape::ALL {
                    let fixed = comm::plan(&blocks, &part, shape.strategy(), None);
                    let fc = modeled_cost(&fixed, &topo, params.n_dense);
                    assert!(
                        compiled.modeled_cost <= fc + 1e-12,
                        "{} on {}: adaptive {} > {} {}",
                        ranks,
                        topo.name,
                        compiled.modeled_cost,
                        shape.name(),
                        fc
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (part, blocks) = setup(128, 8, 5);
        let topo = Topology::tsubame4(8);
        let serial = compile(
            &blocks,
            &part,
            &topo,
            &PlanParams { threads: 1, ..Default::default() },
        );
        let parallel = compile(
            &blocks,
            &part,
            &topo,
            &PlanParams { threads: 0, ..Default::default() },
        );
        assert_eq!(serial.choices, parallel.choices);
        assert_eq!(serial.modeled_cost, parallel.modeled_cost);
        for p in 0..8 {
            for q in 0..8 {
                let a = &serial.plan.pairs[p][q];
                let b = &parallel.plan.pairs[p][q];
                assert_eq!(a.b_rows, b.b_rows);
                assert_eq!(a.c_rows, b.c_rows);
                assert_eq!(a.a_row_part, b.a_row_part);
                assert_eq!(a.a_col_part, b.a_col_part);
            }
        }
    }

    #[test]
    fn adaptive_plan_covers_all_nonzeros() {
        let (part, blocks) = setup(128, 8, 6);
        let topo = Topology::tsubame4(8);
        let compiled = compile(&blocks, &part, &topo, &PlanParams::default());
        assert_eq!(
            crate::comm::validate::validate(&compiled.plan, &blocks),
            Ok(())
        );
        assert_eq!(compiled.plan.strategy, Strategy::Adaptive);
    }

    #[test]
    fn block_shape_never_selected() {
        // Block is dominated by Column in bytes and compute, and Column
        // precedes it in both preference orders.
        let (part, blocks) = setup(256, 8, 7);
        for topo in [Topology::tsubame4(8), Topology::aurora(8)] {
            let compiled = compile(&blocks, &part, &topo, &PlanParams::default());
            assert_eq!(compiled.shape_counts()[0], 0, "block chosen on {}", topo.name);
        }
    }

    #[test]
    fn empty_matrix_compiles_to_empty_plan() {
        let a = Csr::eye(32);
        let part = RowPartition::balanced(32, 4);
        let blocks = split_1d(&a, &part);
        let topo = Topology::tsubame4(4);
        let compiled = compile(&blocks, &part, &topo, &PlanParams::default());
        assert_eq!(compiled.plan.total_volume(16), 0);
        assert_eq!(compiled.modeled_cost, 0.0);
        assert_eq!(compiled.shape_counts(), [0, 0, 0, 0]);
    }
}
