//! CSR → blocked-ELL packing for the AOT Pallas kernel (mirrors
//! `python/compile/kernels/spmm_ell.py::csr_to_ell`).

use crate::sparse::Csr;

/// One ELL slab: row-major (m_pad × kmax) index/value panes. Padded slots
/// have val = 0 (index value is then irrelevant; we use 0).
pub struct EllSlab {
    pub idx: Vec<i32>,
    pub val: Vec<f32>,
}

/// Pack a CSR block into one or more ELL slabs of width `kmax`, padded to
/// `m_pad` rows. Rows with more than `kmax` nonzeros spill into subsequent
/// slabs; the caller sums the slab SpMM outputs.
pub fn pack(a: &Csr, kmax: usize, m_pad: usize) -> Vec<EllSlab> {
    assert!(m_pad >= a.nrows);
    assert!(kmax > 0);
    let max_row = (0..a.nrows).map(|r| a.row_nnz(r)).max().unwrap_or(0);
    let nslabs = max_row.div_ceil(kmax).max(1);
    let mut slabs = Vec::with_capacity(nslabs);
    for s in 0..nslabs {
        let mut idx = vec![0i32; m_pad * kmax];
        let mut val = vec![0f32; m_pad * kmax];
        for r in 0..a.nrows {
            let cols = a.row_indices(r);
            let vals = a.row_values(r);
            let lo = s * kmax;
            let hi = ((s + 1) * kmax).min(cols.len());
            for (slot, k) in (lo..hi).enumerate() {
                idx[r * kmax + slot] = cols[k] as i32;
                val[r * kmax + slot] = vals[k];
            }
        }
        slabs.push(EllSlab { idx, val });
    }
    slabs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::Dense;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    /// Reference ELL SpMM over slabs (mirrors the Pallas kernel semantics).
    fn ell_spmm_ref(slabs: &[EllSlab], m_pad: usize, kmax: usize, b: &Dense) -> Dense {
        let mut out = Dense::zeros(m_pad, b.ncols);
        for slab in slabs {
            for m in 0..m_pad {
                for k in 0..kmax {
                    let v = slab.val[m * kmax + k];
                    if v != 0.0 {
                        let row = slab.idx[m * kmax + k] as usize;
                        for j in 0..b.ncols {
                            out.data[m * b.ncols + j] += v * b.get(row, j);
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn pack_roundtrip_matches_csr_spmm() {
        let a = gen::rmat(64, 800, (0.5, 0.2, 0.2), false, 1);
        let mut rng = Rng::new(2);
        let b = Dense::random(64, 8, &mut rng);
        let kmax = 4;
        let m_pad = 80;
        let slabs = pack(&a, kmax, m_pad);
        let got = ell_spmm_ref(&slabs, m_pad, kmax, &b);
        let want = a.spmm(&b);
        for r in 0..64 {
            for j in 0..8 {
                assert!((got.get(r, j) - want.get(r, j)).abs() < 1e-3);
            }
        }
        // Padding rows all zero.
        for r in 64..80 {
            assert!(got.row(r).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn dense_row_spills_to_slabs() {
        // One row with 10 nnz, kmax 4 → 3 slabs.
        let mut coo = crate::sparse::Coo::new(4, 16);
        for c in 0..10 {
            coo.push(0, c, 1.0);
        }
        let a = coo.to_csr();
        let slabs = pack(&a, 4, 4);
        assert_eq!(slabs.len(), 3);
        let total: f32 = slabs.iter().map(|s| s.val.iter().sum::<f32>()).sum();
        assert_eq!(total, 10.0);
    }

    #[test]
    fn empty_matrix_single_zero_slab() {
        let a = Csr::zeros(4, 4);
        let slabs = pack(&a, 4, 8);
        assert_eq!(slabs.len(), 1);
        assert!(slabs[0].val.iter().all(|&v| v == 0.0));
    }

    use crate::sparse::Csr;
}
