//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced by
//! `make artifacts`) and execute them from the L3 hot path.
//!
//! HLO **text** is the interchange format — `HloModuleProto::from_text_file`
//! reassigns instruction ids, avoiding the 64-bit-id protos that
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).
//!
//! The PJRT client needs the `xla` bindings crate, which the offline build
//! image does not ship (DESIGN.md §1). The real implementation is therefore
//! gated behind the `pjrt` cargo feature; without it this module compiles a
//! stub whose `load` fails cleanly and whose kernel falls back to the
//! native path, so every caller (`shiro info`, the GNN example, the
//! executor) keeps working.

pub mod ell;
pub mod multiproc;

/// Default artifact location (repo-root/artifacts), overridable with
/// SHIRO_ARTIFACTS. Shared by the real and stub runtimes.
fn artifacts_dir_from_env() -> std::path::PathBuf {
    std::env::var_os("SHIRO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod imp {
    use crate::dense::Dense;
    use crate::exec::kernel::SpmmKernel;
    use crate::sparse::Csr;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use super::ell;

    /// A loaded artifact set backed by a PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        dir: PathBuf,
        /// SpMM variants available: (m, kmax, k, n) → artifact name.
        spmm_variants: Vec<(usize, usize, usize, usize, String)>,
    }

    impl Runtime {
        /// Load every artifact listed in `<dir>/manifest.txt`.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu()?;
            let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
                .with_context(|| {
                    format!("read {}/manifest.txt — run `make artifacts`", dir.display())
                })?;
            let mut exes = HashMap::new();
            let mut spmm_variants = Vec::new();
            for line in manifest.lines() {
                let mut it = line.split_whitespace();
                let (Some(name), Some(_shapes)) = (it.next(), it.next()) else {
                    continue;
                };
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                if let Some(v) = parse_spmm_name(name) {
                    spmm_variants.push((v.0, v.1, v.2, v.3, name.to_string()));
                }
                exes.insert(name.to_string(), exe);
            }
            anyhow::ensure!(!exes.is_empty(), "no artifacts loaded from {}", dir.display());
            Ok(Runtime { client, exes, dir: dir.to_path_buf(), spmm_variants })
        }

        /// Default artifact location — see [`super::artifacts_dir_from_env`].
        pub fn default_dir() -> PathBuf {
            super::artifacts_dir_from_env()
        }

        pub fn artifact_names(&self) -> Vec<&str> {
            self.exes.keys().map(|s| s.as_str()).collect()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.exes
                .get(name)
                .with_context(|| format!("artifact {name} not loaded"))
        }

        /// Execute an artifact returning the tuple of output literals.
        pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self.exe(name)?;
            let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple()?)
        }

        /// Find an SpMM variant compatible with (rows ≤ m, k, n, any kmax).
        pub fn find_spmm_variant(
            &self,
            rows: usize,
            k: usize,
            n: usize,
        ) -> Option<(usize, usize, String)> {
            self.spmm_variants
                .iter()
                .filter(|(m, _kmax, vk, vn, _)| *vk == k && *vn == n && *m >= rows)
                .min_by_key(|(m, _, _, _, _)| *m)
                .map(|(m, kmax, _, _, name)| (*m, *kmax, name.clone()))
        }

        /// Run one padded ELL SpMM slab through the AOT kernel.
        fn run_spmm_slab(
            &self,
            name: &str,
            m: usize,
            kmax: usize,
            idx: &[i32],
            val: &[f32],
            b: &Dense,
        ) -> Result<Dense> {
            let idx_lit = xla::Literal::vec1(idx).reshape(&[m as i64, kmax as i64])?;
            let val_lit = xla::Literal::vec1(val).reshape(&[m as i64, kmax as i64])?;
            let b_lit = xla::Literal::vec1(&b.data)
                .reshape(&[b.nrows as i64, b.ncols as i64])?;
            let out = self.execute(name, &[idx_lit, val_lit, b_lit])?;
            let data = out[0].to_vec::<f32>()?;
            Ok(Dense::from_vec(m, b.ncols, data))
        }

        /// Full SpMM through the AOT Pallas kernel (pads rows, splits dense
        /// rows into KMAX slabs, sums). Errors if no matching variant exists.
        pub fn spmm(&self, a: &Csr, b: &Dense) -> Result<Dense> {
            let (m_pad, kmax, name) = self
                .find_spmm_variant(a.nrows, b.nrows, b.ncols)
                .with_context(|| {
                    format!(
                        "no spmm artifact for rows≤{} k={} n={} (have {:?})",
                        a.nrows,
                        b.nrows,
                        b.ncols,
                        self.spmm_variants
                    )
                })?;
            let slabs = ell::pack(a, kmax, m_pad);
            let mut acc = Dense::zeros(m_pad, b.ncols);
            for slab in &slabs {
                let out = self.run_spmm_slab(&name, m_pad, kmax, &slab.idx, &slab.val, b)?;
                acc.add_assign(&out);
            }
            // Truncate padding rows.
            if m_pad == a.nrows {
                Ok(acc)
            } else {
                Ok(Dense::from_vec(
                    a.nrows,
                    b.ncols,
                    acc.data[..a.nrows * b.ncols].to_vec(),
                ))
            }
        }

        /// GCN dense forward via artifact: (z, h) = gcn_fwd(h_agg, w).
        pub fn gcn_fwd(&self, h_agg: &Dense, w: &Dense) -> Result<(Dense, Dense)> {
            let name = format!("gcn_fwd_m{}_f{}_h{}", h_agg.nrows, h_agg.ncols, w.ncols);
            let ha = xla::Literal::vec1(&h_agg.data)
                .reshape(&[h_agg.nrows as i64, h_agg.ncols as i64])?;
            let wl = xla::Literal::vec1(&w.data).reshape(&[w.nrows as i64, w.ncols as i64])?;
            let out = self.execute(&name, &[ha, wl])?;
            let z = Dense::from_vec(h_agg.nrows, w.ncols, out[0].to_vec::<f32>()?);
            let h = Dense::from_vec(h_agg.nrows, w.ncols, out[1].to_vec::<f32>()?);
            Ok((z, h))
        }

        /// GCN dense backward via artifact: (d_h_agg, d_w).
        pub fn gcn_bwd(
            &self,
            h_agg: &Dense,
            w: &Dense,
            z: &Dense,
            dh: &Dense,
        ) -> Result<(Dense, Dense)> {
            let name = format!("gcn_bwd_m{}_f{}_h{}", h_agg.nrows, h_agg.ncols, w.ncols);
            let lit = |d: &Dense| -> Result<xla::Literal> {
                Ok(xla::Literal::vec1(&d.data).reshape(&[d.nrows as i64, d.ncols as i64])?)
            };
            let out = self.execute(&name, &[lit(h_agg)?, lit(w)?, lit(z)?, lit(dh)?])?;
            let d_h_agg = Dense::from_vec(h_agg.nrows, w.ncols, out[0].to_vec::<f32>()?);
            let d_w = Dense::from_vec(w.nrows, w.ncols, out[1].to_vec::<f32>()?);
            Ok((d_h_agg, d_w))
        }

        /// Fused GCN layer via artifact (L1 extension, kernels/gcn_fused.py):
        /// (z, h) = relu-split of (ELL(a)·b)·w in one kernel. `a` must fit one
        /// ELL slab of the variant's KMAX; returns None-equivalent error if no
        /// variant matches.
        pub fn gcn_fused(
            &self,
            a: &Csr,
            b: &Dense,
            w: &Dense,
        ) -> Result<(Dense, Dense)> {
            // Fixed variant naming: gcn_fused_m{M}_x{KMAX}_k{K}_n{N}_h{H}.
            let name = format!(
                "gcn_fused_m512_x16_k{}_n{}_h{}",
                b.nrows, b.ncols, w.ncols
            );
            anyhow::ensure!(self.exes.contains_key(&name), "no fused artifact {name}");
            anyhow::ensure!(a.nrows <= 512, "block too tall for fused variant");
            let slabs = ell::pack(a, 16, 512);
            anyhow::ensure!(
                slabs.len() == 1,
                "fused path requires rows with ≤16 nnz (got {} slabs)",
                slabs.len()
            );
            let slab = &slabs[0];
            let idx = xla::Literal::vec1(&slab.idx).reshape(&[512, 16])?;
            let val = xla::Literal::vec1(&slab.val).reshape(&[512, 16])?;
            let bl = xla::Literal::vec1(&b.data).reshape(&[b.nrows as i64, b.ncols as i64])?;
            let wl = xla::Literal::vec1(&w.data).reshape(&[w.nrows as i64, w.ncols as i64])?;
            let out = self.execute(&name, &[idx, val, bl, wl])?;
            let z = Dense::from_vec(512, w.ncols, out[0].to_vec::<f32>()?);
            let h = Dense::from_vec(512, w.ncols, out[1].to_vec::<f32>()?);
            Ok((z, h))
        }

        /// MSE loss + gradient via artifact.
        pub fn mse(&self, pred: &Dense, target: &Dense) -> Result<(f32, Dense)> {
            let name = format!("mse_m{}_h{}", pred.nrows, pred.ncols);
            let lit = |d: &Dense| -> Result<xla::Literal> {
                Ok(xla::Literal::vec1(&d.data).reshape(&[d.nrows as i64, d.ncols as i64])?)
            };
            let out = self.execute(&name, &[lit(pred)?, lit(target)?])?;
            let loss = out[0].to_vec::<f32>()?[0];
            let grad = Dense::from_vec(pred.nrows, pred.ncols, out[1].to_vec::<f32>()?);
            Ok((loss, grad))
        }
    }

    fn parse_spmm_name(name: &str) -> Option<(usize, usize, usize, usize)> {
        // spmm_ell_m{M}_x{KMAX}_k{K}_n{N}
        let rest = name.strip_prefix("spmm_ell_m")?;
        let (m, rest) = rest.split_once("_x")?;
        let (kmax, rest) = rest.split_once("_k")?;
        let (k, n) = rest.split_once("_n")?;
        Some((m.parse().ok()?, kmax.parse().ok()?, k.parse().ok()?, n.parse().ok()?))
    }

    /// Thread-shareable SpMM kernel backed by the PJRT runtime.
    ///
    /// PJRT's C API is documented thread-safe for execution; the raw pointers
    /// in the Rust wrapper types are what keep them from being auto-Send/Sync,
    /// so we serialize all access through a Mutex and assert Send+Sync
    /// manually.
    pub struct PjrtKernel {
        inner: Mutex<Runtime>,
        /// Count of calls that fell back to the native kernel (no matching
        /// artifact shape). Exposed for tests/metrics.
        pub fallbacks: std::sync::atomic::AtomicU64,
    }

    unsafe impl Send for PjrtKernel {}
    unsafe impl Sync for PjrtKernel {}

    impl PjrtKernel {
        pub fn load(dir: &Path) -> Result<PjrtKernel> {
            Ok(PjrtKernel {
                inner: Mutex::new(Runtime::load(dir)?),
                fallbacks: std::sync::atomic::AtomicU64::new(0),
            })
        }

        pub fn with_runtime<T>(&self, f: impl FnOnce(&Runtime) -> T) -> T {
            f(&self.inner.lock().unwrap())
        }
    }

    impl SpmmKernel for PjrtKernel {
        fn spmm(&self, a: &Csr, b: &Dense) -> Dense {
            let rt = self.inner.lock().unwrap();
            match rt.spmm(a, b) {
                Ok(c) => c,
                Err(_) => {
                    self.fallbacks
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    a.spmm(b)
                }
            }
        }

        /// AOT artifacts are compiled for whole-block shapes: tell the
        /// executor pipeline to hand us the full diagonal via `spmm_acc`
        /// instead of native row tiles.
        fn prefers_tiles(&self) -> bool {
            false
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parse_names() {
            assert_eq!(
                parse_spmm_name("spmm_ell_m512_x16_k512_n32"),
                Some((512, 16, 512, 32))
            );
            assert_eq!(parse_spmm_name("gcn_fwd_m512_f32_h32"), None);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::dense::Dense;
    use crate::exec::kernel::SpmmKernel;
    use crate::sparse::Csr;
    use anyhow::Result;
    use std::path::{Path, PathBuf};

    fn unavailable() -> anyhow::Error {
        anyhow::anyhow!(
            "PJRT runtime unavailable: this build has the `pjrt` feature disabled \
             (the offline image lacks the xla bindings)"
        )
    }

    /// Stub runtime: mirrors the PJRT-backed API so callers compile
    /// unchanged; every load/execute path reports the feature is off.
    pub struct Runtime {
        dir: PathBuf,
    }

    impl Runtime {
        pub fn load(dir: &Path) -> Result<Runtime> {
            let _ = dir;
            Err(unavailable())
        }

        /// Default artifact location — see [`super::artifacts_dir_from_env`].
        pub fn default_dir() -> PathBuf {
            super::artifacts_dir_from_env()
        }

        pub fn artifact_names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn spmm(&self, _a: &Csr, _b: &Dense) -> Result<Dense> {
            Err(unavailable())
        }

        pub fn gcn_fwd(&self, _h_agg: &Dense, _w: &Dense) -> Result<(Dense, Dense)> {
            Err(unavailable())
        }

        pub fn gcn_bwd(
            &self,
            _h_agg: &Dense,
            _w: &Dense,
            _z: &Dense,
            _dh: &Dense,
        ) -> Result<(Dense, Dense)> {
            Err(unavailable())
        }

        pub fn gcn_fused(&self, _a: &Csr, _b: &Dense, _w: &Dense) -> Result<(Dense, Dense)> {
            Err(unavailable())
        }

        pub fn mse(&self, _pred: &Dense, _target: &Dense) -> Result<(f32, Dense)> {
            Err(unavailable())
        }
    }

    /// Stub kernel: cannot be constructed (load always errors); the trait
    /// impl exists so shared call sites type-check and, defensively, routes
    /// to the native path.
    pub struct PjrtKernel {
        _inner: Runtime,
        /// Count of calls that fell back to the native kernel.
        pub fallbacks: std::sync::atomic::AtomicU64,
    }

    impl PjrtKernel {
        pub fn load(dir: &Path) -> Result<PjrtKernel> {
            Ok(PjrtKernel {
                _inner: Runtime::load(dir)?,
                fallbacks: std::sync::atomic::AtomicU64::new(0),
            })
        }

        pub fn with_runtime<T>(&self, f: impl FnOnce(&Runtime) -> T) -> T {
            f(&self._inner)
        }
    }

    impl SpmmKernel for PjrtKernel {
        fn spmm(&self, a: &Csr, b: &Dense) -> Dense {
            self.fallbacks
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            a.spmm(b)
        }

        /// Mirror the real backend's contract (whole blocks, no tiles) so
        /// executor behavior is identical with and without `--features
        /// pjrt`.
        fn prefers_tiles(&self) -> bool {
            false
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_load_fails_cleanly() {
            let err = Runtime::load(Path::new("artifacts")).unwrap_err();
            assert!(format!("{err}").contains("pjrt"));
            assert!(PjrtKernel::load(Path::new("artifacts")).is_err());
        }

        #[test]
        fn default_dir_env_override() {
            // No env set in tests: default is ./artifacts.
            if std::env::var_os("SHIRO_ARTIFACTS").is_none() {
                assert_eq!(Runtime::default_dir(), PathBuf::from("artifacts"));
            }
        }
    }
}

pub use imp::{PjrtKernel, Runtime};
