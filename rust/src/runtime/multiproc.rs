//! Multi-process executor backend: every rank is an OS process, driven by
//! a socket message queue (DESIGN.md §10), with crash recovery by
//! replanning over the survivors (DESIGN.md §12).
//!
//! The parent is a pure control plane — it never touches the numerics. It
//! spawns one worker per rank (re-executing its own binary;
//! [`maybe_run_worker`] intercepts the env-var handshake before CLI
//! dispatch), serializes each rank's job with [`crate::exec::wire`] — the
//! *same* frozen step program the thread executor runs — and then routes
//! DATA frames between workers verbatim. Workers run the identical
//! `rank_main`; since every scatter-add folds in canonical (origin, row)
//! order, the proc backend's C is bitwise-identical to the thread
//! backend's (`tests/multiproc_suite.rs`).
//!
//! Every request runs on a [`WorkerPool`]: spawn + HELLO handshake happen
//! once, then the live connections serve request after request (wire v4's
//! generation-stamped multi-job protocol), shipping operand-only delta
//! JOBs when the plan-body fingerprint is unchanged. Set
//! [`ProcOpts::pool`] to share one fleet across requests; leave it `None`
//! and the request gets an ephemeral pool torn down on return — the
//! classic spawn-per-request behavior, running the exact same code path,
//! which is why pooled and cold results are bitwise-identical by
//! construction. A worker lost mid-request is quarantined and the pool
//! *re-admits* a respawned replacement between requests, replanning back
//! to the original rank count.
//!
//! Failure semantics: workers heartbeat every
//! [`crate::exec::wire::BEAT_MILLIS`] ms; a worker that panics reports a
//! structured ERROR frame; one that dies silently is detected by its
//! socket closing or by heartbeat silence past [`ProcOpts::timeout`].
//! Under [`FaultPolicy::Fail`] (the default) every failure path kills and
//! reaps all children and surfaces a [`RankFailure`] instead of hanging.
//! Under [`FaultPolicy::Recover`] a mid-step failure triggers recovery
//! instead: the dead worker is quarantined, its row block is merged into
//! an adjacent survivor ([`crate::partition::recover_partition`]), the
//! comm plan and hierarchical schedule are recompiled for the shrunken
//! topology, survivors get an ABORT for the in-flight epoch followed by
//! replanned JOBs under a new epoch, and the step replays from scratch.
//! The parent holds the full `Csr` and dense operands, so no worker state
//! survives into the retry — which is exactly why the recovered C is
//! bitwise-identical to a cold run on the post-recovery partition
//! (`tests/fault_suite.rs`).

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::exec::wire::{self, kind};
use crate::exec::{assemble_sddmm, ExecOpts, ExecStats, KernelOp, RankStats, SddmmVals};
use crate::hierarchy::{self, HierSchedule, RepSchedule};
use crate::metrics::{recovery_latency, LatencyStats};
use crate::partition::{assemble_1d, recover_partition, split_1d, LocalBlocks, RowPartition};
use crate::sparse::Csr;
use crate::topology::Topology;
use crate::util::rng::Rng;
use std::collections::BTreeSet;
use std::fmt;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Where in the step a [`FaultPlan`] kills its worker. The three phases
/// cover the distinct in-flight states the recovery protocol must handle:
/// before any traffic, mid-exchange with partial data already folded into
/// peers, and after compute with the result one frame from home.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPhase {
    /// Right after the worker decodes its job — no traffic yet (the old
    /// `crash_rank` behavior).
    PostDecode,
    /// Right after the worker's first outgoing DATA frame hits the wire,
    /// so peers hold partial state from the dead rank. Degenerates to
    /// [`CrashPhase::PreDone`] when the program has nothing to send.
    MidExchange,
    /// After compute completes, right before the DONE frame — peers may
    /// have finished already.
    PreDone,
}

impl CrashPhase {
    pub const ALL: [CrashPhase; 3] =
        [CrashPhase::PostDecode, CrashPhase::MidExchange, CrashPhase::PreDone];

    pub fn name(&self) -> &'static str {
        match self {
            CrashPhase::PostDecode => "post-decode",
            CrashPhase::MidExchange => "mid-exchange",
            CrashPhase::PreDone => "pre-done",
        }
    }

    /// Inverse of [`CrashPhase::name`] — for parsing phase names from
    /// CLI/config surfaces.
    pub fn by_name(name: &str) -> Option<CrashPhase> {
        CrashPhase::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// Deterministic fault injection: kill rank `rank` at `phase`. Shipped in
/// the targeted rank's JOB header (the wire-v4 crash byte), so the crash
/// is reproducible run over run — the property the fault suite's
/// differential assertions stand on — and a pooled worker is armed for
/// exactly one request, then disarmed by the next JOB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Spawn-time identity (epoch-0 rank) of the worker to kill.
    pub rank: usize,
    pub phase: CrashPhase,
}

impl FaultPlan {
    pub fn new(rank: usize, phase: CrashPhase) -> FaultPlan {
        FaultPlan { rank, phase }
    }

    /// The old `crash_rank` behavior: abort right after decoding the job.
    pub fn post_decode(rank: usize) -> FaultPlan {
        FaultPlan { rank, phase: CrashPhase::PostDecode }
    }

    /// Seeded (rank, phase) choice over `nranks` workers — what the chaos
    /// soak uses to vary its kills reproducibly.
    pub fn seeded(seed: u64, nranks: usize) -> FaultPlan {
        assert!(nranks > 0);
        let mut rng = Rng::new(seed);
        FaultPlan {
            rank: rng.below(nranks),
            phase: CrashPhase::ALL[rng.below(CrashPhase::ALL.len())],
        }
    }
}

/// What the control plane does when a rank dies mid-step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Surface the structured [`RankFailure`] — bitwise the pre-recovery
    /// behavior, and the default.
    #[default]
    Fail,
    /// Repartition the lost rank's rows over the survivors, replan, and
    /// replay the step. At most `max_retries` workers may be lost across
    /// one run; the next failure (or losing the last worker) surfaces the
    /// [`RankFailure`] like [`FaultPolicy::Fail`] does.
    Recover {
        max_retries: usize,
    },
}

/// What recovery did, returned alongside the result when at least one
/// replan happened. `final_starts` pins the post-recovery partition, so a
/// differential test can replay the recovered run as a cold start on the
/// surviving ranks and demand bitwise equality.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryReport {
    /// Spawn-time identities (epoch-0 ranks) of the lost workers, in
    /// failure order.
    pub lost_ranks: Vec<usize>,
    /// Replan rounds performed (== `lost_ranks.len()`).
    pub replans: usize,
    /// The run completed after recovery. (Exhausted retries surface the
    /// final [`RankFailure`] as an error instead of a report.)
    pub recovered: bool,
    /// Row boundaries of the final partition.
    pub final_starts: Vec<usize>,
    /// Seconds per replan round: failure detected → survivor jobs
    /// re-shipped.
    pub replan_secs: Vec<f64>,
}

impl RecoveryReport {
    /// Order statistics plus total over the replan latency samples
    /// ([`crate::metrics::recovery_latency`]).
    pub fn latency(&self) -> (LatencyStats, f64) {
        recovery_latency(&self.replan_secs)
    }
}

/// Control-plane options for one multi-process run.
#[derive(Clone, Debug)]
pub struct ProcOpts {
    /// Declare a rank dead after this long without any frame from it
    /// (heartbeats arrive every [`wire::BEAT_MILLIS`] ms, so this allows
    /// hundreds of missed beats). Also bounds worker connect time.
    pub timeout: Duration,
    /// Worker binary; defaults to `std::env::current_exe()`. Tests pass
    /// `env!("CARGO_BIN_EXE_shiro")` because their own executable is the
    /// test harness, not the CLI.
    pub worker_exe: Option<PathBuf>,
    /// Deterministic fault injection: kill one rank at a chosen phase of
    /// its first step, standing in for a segfaulted or OOM-killed worker.
    pub fault: Option<FaultPlan>,
    /// Persistent worker pool: when set, the request reuses (lazily
    /// creating) the shared [`WorkerPool`] behind the handle instead of
    /// spawning rank processes per request. `None` keeps the classic
    /// spawn-per-request behavior — an ephemeral pool torn down with the
    /// request, on the very same code path.
    pub pool: Option<PoolHandle>,
}

impl Default for ProcOpts {
    fn default() -> ProcOpts {
        ProcOpts { timeout: Duration::from_secs(30), worker_exe: None, fault: None, pool: None }
    }
}

/// Structured report of the first unrecovered rank failure the control
/// plane saw.
#[derive(Debug)]
pub struct RankFailure {
    pub rank: usize,
    pub cause: FailureCause,
}

#[derive(Debug)]
pub enum FailureCause {
    /// The worker process could not be spawned (or the control socket
    /// could not be set up — reported as rank 0).
    Spawn(String),
    /// The worker's socket closed before it reported DONE (crash, abort,
    /// OOM kill — anything that dies without a word).
    Disconnected(String),
    /// No frame of any kind within the timeout: the worker is alive-ish
    /// but wedged, or the host lost it.
    HeartbeatTimeout(Duration),
    /// The worker itself reported an error (panic message or job
    /// rejection) via an ERROR frame.
    Worker(String),
    /// The worker sent something the protocol does not allow.
    Protocol(String),
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.cause {
            FailureCause::Spawn(e) => {
                write!(f, "rank {}: failed to spawn worker: {e}", self.rank)
            }
            FailureCause::Disconnected(e) => {
                write!(f, "rank {}: worker disconnected before finishing: {e}", self.rank)
            }
            FailureCause::HeartbeatTimeout(d) => write!(
                f,
                "rank {}: no heartbeat for {:.1}s — worker presumed dead",
                self.rank,
                d.as_secs_f64()
            ),
            FailureCause::Worker(m) => write!(f, "rank {}: worker error: {m}", self.rank),
            FailureCause::Protocol(m) => {
                write!(f, "rank {}: protocol violation: {m}", self.rank)
            }
        }
    }
}

impl std::error::Error for RankFailure {}

/// Call first thing in `main()`: if the worker env vars are set, this
/// process is a spawned rank — run the worker loop and never return.
/// A no-op in ordinary CLI invocations.
pub fn maybe_run_worker() {
    let (Some(port), Some(rank)) =
        (std::env::var(wire::ENV_PORT).ok(), std::env::var(wire::ENV_RANK).ok())
    else {
        return;
    };
    let (Ok(port), Ok(rank)) = (port.parse::<u16>(), rank.parse::<usize>()) else {
        eprintln!(
            "shiro worker: unparseable {}={port:?} / {}={rank:?}",
            wire::ENV_PORT,
            wire::ENV_RANK
        );
        std::process::exit(3);
    };
    wire::worker_main(port, rank)
}

/// Distributed SpMM across worker processes: the proc-backend counterpart
/// of [`crate::exec::run_with`], same plan inputs, same bitwise result.
#[allow(clippy::too_many_arguments)]
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, ExecStats, Option<RecoveryReport>), RankFailure> {
    run_op(KernelOp::Spmm, part, plan, blocks, sched, None, topo, None, b, opts, popts, policy)
        .map(|(c, _, st, rec)| (c, st, rec))
}

/// Distributed SpMM under a 1.5D replicated decomposition across worker
/// processes: the proc-backend counterpart of the thread executor's
/// replicated path — `part`/`plan`/`blocks` describe the *group-level*
/// problem, `rep` deals its flows out to the physical ranks, and the
/// result is bitwise-identical to the thread backend's by the same
/// canonical-fold argument. Crash recovery is not available on replicated
/// runs: any lost worker surfaces as a [`RankFailure`] (replan at c=1 for
/// recovery semantics).
#[allow(clippy::too_many_arguments)]
pub fn run_replicated(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    rep: &RepSchedule,
    topo: &Topology,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
) -> Result<(Dense, ExecStats), RankFailure> {
    run_op(
        KernelOp::Spmm,
        part,
        plan,
        blocks,
        None,
        Some(rep),
        topo,
        None,
        b,
        opts,
        popts,
        FaultPolicy::Fail,
    )
    .map(|(c, _, st, _)| (c, st))
}

/// Fused SDDMM→SpMM across worker processes: counterpart of
/// [`crate::exec::run_fused_with`]. Exercises `Msg::X` over the wire.
#[allow(clippy::too_many_arguments)]
pub fn run_fused(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, ExecStats, Option<RecoveryReport>), RankFailure> {
    run_op(
        KernelOp::FusedSddmmSpmm,
        part,
        plan,
        blocks,
        sched,
        None,
        topo,
        Some(x),
        y,
        opts,
        popts,
        policy,
    )
    .map(|(c, _, st, rec)| (c, st, rec))
}

/// Distributed SDDMM across worker processes: counterpart of
/// [`crate::exec::run_sddmm_with`]. Each worker's DONE frame carries its
/// pool of edge-value buffers (the v2 wire payload); the parent assembles
/// them into the global E — under the *final* (possibly post-recovery)
/// partition — exactly as the thread backend does, so the result is
/// bitwise-identical to [`Csr::sddmm`].
#[allow(clippy::too_many_arguments)]
pub fn run_sddmm(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Csr, ExecStats, Option<RecoveryReport>), RankFailure> {
    let (_, e, stats, rec) = run_op(
        KernelOp::Sddmm,
        part,
        plan,
        blocks,
        sched,
        None,
        topo,
        Some(x),
        y,
        opts,
        popts,
        policy,
    )?;
    Ok((e.expect("SDDMM always assembles E"), stats, rec))
}

/// One event from a worker's reader thread to the collector. Workers are
/// identified by their pool slot (spawn-time identity) plus the id of the
/// connection the event arrived on — a re-admitted slot's old reader can
/// race its replacement, and the collector tells their events apart by
/// the connection id, never by any epoch-relative rank a payload claims.
enum Event {
    /// DONE frame: (slot, conn, epoch, claimed rank, C block, vals, stats).
    Done(usize, u64, u64, usize, Dense, SddmmVals, RankStats),
    Beat(usize, u64),
    /// Unrecoverable protocol-level problem on this worker's stream.
    Fail(usize, u64, FailureCause),
    /// ERROR frame: (slot, conn, epoch, message). Stale epochs are the
    /// normal "inbox closed" wake-up of an aborted job and are discarded.
    WorkerErr(usize, u64, u64, String),
    /// Stream closed (or read error). Benign after DONE, fatal before —
    /// and between pooled requests, the death notice re-admission keys on.
    Eof(usize, u64, String),
}

/// Plan state for the current epoch, owned by the collector once the
/// first recovery replan replaces the caller's borrowed base-epoch state.
struct Live {
    part: RowPartition,
    plan: CommPlan,
    blocks: Vec<LocalBlocks>,
    sched: Option<HierSchedule>,
    topo: Topology,
}

/// Routing + liveness table shared with the detached per-connection
/// reader threads. DATA frames carry an epoch-relative `dst` rank, so the
/// rank→slot map must swap atomically with the epoch bump; `active` gates
/// event forwarding so the idle heartbeats workers keep sending between
/// pooled requests cannot grow the collector's queue without bound.
struct RouteState {
    epoch: u64,
    /// A request is in flight. Inactive readers still report EOF (worker
    /// death) and terminal protocol failures; routine traffic is dropped.
    active: bool,
    /// Slot serving each epoch-relative rank.
    worker_of_rank: Vec<usize>,
    /// Write half of each slot's control socket, shared between the
    /// parent (JOB/ABORT) and the readers (routed DATA).
    writers: Vec<Arc<Mutex<TcpStream>>>,
}

/// What one rank's DONE frame carries.
type RankResult = (Dense, SddmmVals, RankStats);

/// Counters a [`PoolHandle`] exposes. A warm pool serving N requests at a
/// fixed shape shows `spawns == nranks` and `reuses == N - 1` — the
/// "zero spawns after the first request" property the suites assert.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker processes spawned over the pool's lifetime (cold start plus
    /// re-admissions).
    pub spawns: u64,
    /// Requests served over already-established connections.
    pub reuses: u64,
    /// Workers respawned and re-admitted after being lost mid-request.
    pub readmissions: u64,
}

/// Shared, lazily filled slot for a [`WorkerPool`]: clone one handle into
/// [`ProcOpts::pool`] on every request and they all reuse the same
/// spawned workers. The pool is created on first use and rebuilt (counters
/// reset) if a request arrives for a different rank count or worker
/// binary, so key long-lived handles by (topology, nranks) as the serve
/// layer does. Dropping the last clone kills the workers.
#[derive(Clone, Default)]
pub struct PoolHandle(Arc<Mutex<Option<WorkerPool>>>);

impl PoolHandle {
    pub fn new() -> PoolHandle {
        PoolHandle::default()
    }

    /// Spawn/reuse counters; zeros before the first request.
    pub fn stats(&self) -> PoolStats {
        self.lock().as_ref().map(|p| p.stats).unwrap_or_default()
    }

    fn lock(&self) -> MutexGuard<'_, Option<WorkerPool>> {
        // A panicked request (a caller assertion in a serve worker) must
        // not wedge every later request on lock poisoning: the pool
        // revalidates its children on entry anyway, so recover the guard.
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.stats();
        write!(
            f,
            "PoolHandle(spawns {}, reuses {}, readmissions {})",
            st.spawns, st.reuses, st.readmissions
        )
    }
}

/// A persistent fleet of rank processes: spawned and HELLO-handshaked
/// once, then reused request after request over the same control-plane
/// connections (the wire-v4 multi-job protocol). The parent keeps its
/// listener open for the pool's whole lifetime so a worker lost
/// mid-request can be respawned and *re-admitted* between requests,
/// replanning back to the original rank count.
pub struct WorkerPool {
    nranks: usize,
    exe: PathBuf,
    listener: TcpListener,
    port: u16,
    children: Vec<Option<Child>>,
    /// Liveness per slot; a dead slot is respawned at next request start.
    alive: Vec<bool>,
    /// Monotone id of each slot's current connection: events from a
    /// replaced reader carry a stale id and are ignored.
    conn_id: Vec<u64>,
    /// Fingerprint of the last plan body shipped to each slot — the
    /// delta-vs-full JOB decision. Cleared on re-admission.
    last_fp: Vec<Option<u64>>,
    route: Arc<Mutex<RouteState>>,
    ev_tx: mpsc::Sender<Event>,
    ev_rx: mpsc::Receiver<Event>,
    /// Next request's base exchange epoch — strictly above every epoch
    /// any earlier request used, so stale frames can never alias.
    epoch: u64,
    /// Pool generation, bumped once per request (the JOB header field).
    generation: u64,
    /// Last request's failure-detection timeout; sizes the teardown grace.
    timeout: Duration,
    served: bool,
    stats: PoolStats,
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Killing the children closes their sockets, which unblocks every
        // detached reader thread; each exits on EOF.
        kill_all(&mut self.children);
        reap(&mut self.children, reap_grace(self.timeout));
    }
}

#[allow(clippy::too_many_arguments)]
fn run_op(
    op: KernelOp,
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    rep: Option<&RepSchedule>,
    topo: &Topology,
    x: Option<&Dense>,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, Option<Csr>, ExecStats, Option<RecoveryReport>), RankFailure> {
    // For a replicated run the partition / plan / blocks are group-level
    // while the fleet spans the physical ranks.
    let nranks = match rep {
        None => {
            assert_eq!(plan.nranks, part.nparts);
            part.nparts
        }
        Some(rs) => {
            assert_eq!(op, KernelOp::Spmm, "replicated proc runs are SpMM-only");
            assert_eq!(plan.nranks, rs.map.ngroups());
            assert_eq!(part.nparts, rs.map.ngroups());
            rs.map.nranks
        }
    };
    assert_eq!(part.n, b.nrows);
    let exe = match &popts.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| RankFailure {
                rank: 0,
                cause: FailureCause::Spawn(format!("current_exe: {e}")),
            })?,
    };
    match &popts.pool {
        Some(handle) => {
            let mut slot = handle.lock();
            // Rebuild on shape/binary mismatch. Handles are keyed by the
            // caller (one per (topology, nranks) in the serve layer), so
            // this is a cold-start path, not churn.
            let rebuild = !matches!(&*slot, Some(p) if p.nranks == nranks && p.exe == exe);
            if rebuild {
                *slot = None; // kill any stale fleet before spawning anew
                *slot = Some(WorkerPool::new(nranks, exe, popts.timeout)?);
            }
            let pool = slot.as_mut().expect("pool ensured above");
            pool.run_request(op, part, plan, blocks, sched, rep, topo, x, b, opts, popts, policy)
        }
        None => {
            // Ephemeral pool: spawn, serve one request, tear down — the
            // classic spawn-per-request behavior, routed through the very
            // same code as warm pools, which keeps the two bitwise
            // identical by construction.
            let mut pool = WorkerPool::new(nranks, exe, popts.timeout)?;
            pool.run_request(op, part, plan, blocks, sched, rep, topo, x, b, opts, popts, policy)
        }
    }
}

impl WorkerPool {
    /// Spawn `nranks` workers, handshake them all, and start their
    /// detached reader threads. Everything here happens exactly once per
    /// fleet — the per-request path only ships JOBs over these
    /// connections.
    fn new(nranks: usize, exe: PathBuf, timeout: Duration) -> Result<WorkerPool, RankFailure> {
        let fail = |rank: usize, cause: FailureCause| RankFailure { rank, cause };
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .map_err(|e| fail(0, FailureCause::Spawn(format!("bind control socket: {e}"))))?;
        let port = listener
            .local_addr()
            .map_err(|e| fail(0, FailureCause::Spawn(format!("control socket addr: {e}"))))?
            .port();
        listener.set_nonblocking(true).ok();

        let mut children: Vec<Option<Child>> = Vec::with_capacity(nranks);
        for rank in 0..nranks {
            match spawn_worker(&exe, port, rank) {
                Ok(c) => children.push(Some(c)),
                Err(e) => {
                    kill_all(&mut children);
                    reap(&mut children, reap_grace(timeout));
                    return Err(fail(rank, FailureCause::Spawn(e.to_string())));
                }
            }
        }
        let mut expect: BTreeSet<usize> = (0..nranks).collect();
        let streams = match accept_hellos(&listener, &mut expect, timeout) {
            Ok(s) => s,
            Err(f) => {
                kill_all(&mut children);
                reap(&mut children, reap_grace(timeout));
                return Err(f);
            }
        };

        // Split each stream: a cloned read half per reader thread, the
        // original write half behind a shared mutex for routed DATA and
        // control (JOB / ABORT) frames.
        let mut writers: Vec<Option<Arc<Mutex<TcpStream>>>> = (0..nranks).map(|_| None).collect();
        let mut read_halves: Vec<Option<TcpStream>> = (0..nranks).map(|_| None).collect();
        for (rank, stream) in streams {
            match stream.try_clone() {
                Ok(rd) => read_halves[rank] = Some(rd),
                Err(e) => {
                    kill_all(&mut children);
                    reap(&mut children, reap_grace(timeout));
                    return Err(fail(rank, FailureCause::Spawn(format!("clone stream: {e}"))));
                }
            }
            writers[rank] = Some(Arc::new(Mutex::new(stream)));
        }
        let writers: Vec<Arc<Mutex<TcpStream>>> =
            writers.into_iter().map(|w| w.expect("handshaked above")).collect();
        let route = Arc::new(Mutex::new(RouteState {
            epoch: 0,
            active: false,
            worker_of_rank: Vec::new(),
            writers,
        }));
        let (ev_tx, ev_rx) = mpsc::channel::<Event>();
        for (slot, rd) in read_halves.into_iter().enumerate() {
            let rd = rd.expect("handshaked above");
            let route = Arc::clone(&route);
            let tx = ev_tx.clone();
            std::thread::spawn(move || reader_loop(slot, 1, rd, route, tx));
        }
        Ok(WorkerPool {
            nranks,
            exe,
            listener,
            port,
            children,
            alive: vec![true; nranks],
            conn_id: vec![1; nranks],
            last_fp: vec![None; nranks],
            route,
            ev_tx,
            ev_rx,
            epoch: 0,
            generation: 0,
            timeout,
            served: false,
            stats: PoolStats { spawns: nranks as u64, ..PoolStats::default() },
        })
    }

    /// Between requests: collect queued death notices, reap dead workers,
    /// and re-admit respawned replacements so the next request replans
    /// back to the full rank count — the recovery-on-*growth* half of the
    /// protocol (a mid-request loss only ever shrinks the fleet).
    fn readmit(&mut self, timeout: Duration) -> Result<(), RankFailure> {
        // Death notices queued while no request was active. Everything
        // else in the queue is stale request traffic; epochs are globally
        // monotone, so none of it can alias later work.
        while let Ok(ev) = self.ev_rx.try_recv() {
            if let Event::Eof(slot, conn, _) = ev {
                if conn == self.conn_id[slot] {
                    self.alive[slot] = false;
                }
            }
        }
        // A worker can be dead without its EOF having surfaced yet (the
        // OS buffered the reset): ask the OS directly.
        for slot in 0..self.nranks {
            if self.alive[slot] {
                if let Some(c) = self.children[slot].as_mut() {
                    if matches!(c.try_wait(), Ok(Some(_))) {
                        self.alive[slot] = false;
                    }
                }
            }
        }
        let dead: Vec<usize> = (0..self.nranks).filter(|&s| !self.alive[s]).collect();
        if dead.is_empty() {
            return Ok(());
        }
        for &slot in &dead {
            // A quarantined worker may still be running (heartbeat
            // timeouts and reported panics leave the process up); kill it
            // before its replacement takes the slot.
            if let Some(c) = self.children[slot].take() {
                let mut one = [Some(c)];
                kill_all(&mut one);
                reap(&mut one, reap_grace(timeout));
            }
            self.last_fp[slot] = None;
        }
        for &slot in &dead {
            match spawn_worker(&self.exe, self.port, slot) {
                Ok(c) => {
                    self.children[slot] = Some(c);
                    self.stats.spawns += 1;
                    self.stats.readmissions += 1;
                }
                Err(e) => {
                    return Err(RankFailure {
                        rank: slot,
                        cause: FailureCause::Spawn(e.to_string()),
                    })
                }
            }
        }
        let mut expect: BTreeSet<usize> = dead.iter().copied().collect();
        let streams = accept_hellos(&self.listener, &mut expect, timeout)?;
        for (slot, stream) in streams {
            let rd = stream.try_clone().map_err(|e| RankFailure {
                rank: slot,
                cause: FailureCause::Spawn(format!("clone stream: {e}")),
            })?;
            self.conn_id[slot] += 1;
            self.route.lock().unwrap().writers[slot] = Arc::new(Mutex::new(stream));
            let route = Arc::clone(&self.route);
            let tx = self.ev_tx.clone();
            let conn = self.conn_id[slot];
            std::thread::spawn(move || reader_loop(slot, conn, rd, route, tx));
            self.alive[slot] = true;
        }
        Ok(())
    }

    /// Serve one request over the pool: re-admit dead workers, ship JOBs
    /// (operand-only deltas when a slot's plan-body fingerprint is
    /// unchanged), collect DONEs with the same quarantine-and-replan
    /// recovery the spawn-per-request path always had, and leave the
    /// fleet idle for the next request. The workers decode into the same
    /// frozen step programs either way, which is what keeps warm-pool
    /// results bitwise-identical to a cold run.
    #[allow(clippy::too_many_arguments)]
    fn run_request(
        &mut self,
        op: KernelOp,
        part: &RowPartition,
        plan: &CommPlan,
        blocks: &[LocalBlocks],
        sched: Option<&HierSchedule>,
        rep: Option<&RepSchedule>,
        topo: &Topology,
        x: Option<&Dense>,
        b: &Dense,
        opts: &ExecOpts,
        popts: &ProcOpts,
        policy: FaultPolicy,
    ) -> Result<(Dense, Option<Csr>, ExecStats, Option<RecoveryReport>), RankFailure> {
        let nranks = self.nranks;
        debug_assert_eq!(part.nparts, rep.map_or(nranks, |rs| rs.map.ngroups()));
        let n_dense = b.ncols;
        // SDDMM workers produce edge values, not a dense block: their C
        // has width 0 and the payload of interest rides the DONE frame.
        let c_cols = if op == KernelOp::Sddmm { 0 } else { n_dense };
        self.timeout = popts.timeout;

        let t0 = Instant::now();
        self.readmit(popts.timeout)?;
        if self.served {
            self.stats.reuses += 1;
        }
        self.served = true;
        self.generation += 1;
        let base_epoch = self.epoch;

        // Publish the request's routing epoch before the first JOB ships
        // (a worker may start sending DATA the moment it decodes), and
        // grab the writer handles while the lock is held.
        let writers: Vec<Arc<Mutex<TcpStream>>> = {
            let mut rt = self.route.lock().unwrap();
            rt.epoch = base_epoch;
            rt.active = true;
            rt.worker_of_rank = (0..nranks).collect();
            rt.writers.iter().map(Arc::clone).collect()
        };

        // Encode every JOB for the base epoch before any frame ships. A
        // ship failure is carried into the collector as this request's
        // first failure event so it goes through the same quarantine/
        // replan path as a mid-step death (survivors that never saw the
        // base epoch just ABORT a no-op and pick up the replanned JOB).
        let xsched_owned =
            (op != KernelOp::Spmm).then(|| sched.map(hierarchy::sddmm_fetch)).flatten();
        let mut carried: Option<(usize, FailureCause)> = None;
        let mut payloads: Vec<(u64, Vec<u8>)> = Vec::with_capacity(nranks);
        for rank in 0..nranks {
            // Replicated requests ship the group's blocks to every member
            // and B rows only to the home — exactly how the thread path
            // slices its operands.
            let blk = match rep {
                None => &blocks[rank],
                Some(rs) => &blocks[rs.map.group_of(rank)],
            };
            let b_slice = match rep {
                None => slice_rows(b, part, rank),
                Some(rs) if rs.map.member_of(rank) == 0 => {
                    slice_rows(b, part, rs.map.group_of(rank))
                }
                Some(_) => Dense::zeros(0, b.ncols),
            };
            let fp = wire::job_fingerprint(rank, part, topo, plan, sched, rep, blk);
            let warm = self.last_fp[rank] == Some(fp);
            let blob = if warm {
                wire::encode_job_delta(
                    rank,
                    op,
                    opts,
                    &b_slice,
                    x.map(|x| slice_rows(x, part, rank)).as_ref(),
                )
            } else {
                wire::encode_job(
                    rank,
                    op,
                    opts,
                    part,
                    topo,
                    plan,
                    sched,
                    xsched_owned.as_ref(),
                    rep,
                    blk,
                    &b_slice,
                    x.map(|x| slice_rows(x, part, rank)).as_ref(),
                )
            };
            let blob = match blob {
                Ok(j) => j,
                Err(e) => {
                    carried = Some((rank, FailureCause::Protocol(format!("encode job: {e:#}"))));
                    break;
                }
            };
            // Fault injection rides the JOB frame: armed for exactly the
            // targeted slot, exactly this request.
            let crash = popts.fault.and_then(|fpl| (fpl.rank == rank).then_some(fpl.phase));
            let mut payload = wire::encode_job_header(&wire::JobHeader {
                generation: self.generation,
                epoch: base_epoch,
                mode: if warm { wire::JOB_MODE_DELTA } else { wire::JOB_MODE_FULL },
                crash,
                fp,
            });
            payload.extend_from_slice(&blob);
            payloads.push((fp, payload));
        }
        // Write every JOB while holding *all* writer locks: a reader
        // routing an early worker's DATA blocks on the destination's
        // writer lock, so no routed frame can land on a stream before
        // that stream's own JOB — the worker would drop it as stale and
        // the exchange would hang. (Workers always drain their socket,
        // so these writes cannot deadlock against blocked readers.)
        if carried.is_none() {
            let mut guards: Vec<_> = writers.iter().map(|w| w.lock().unwrap()).collect();
            for (rank, (fp, payload)) in payloads.iter().enumerate() {
                match wire::write_frame(&mut *guards[rank], kind::JOB, payload) {
                    Ok(()) => self.last_fp[rank] = Some(*fp),
                    Err(e) => {
                        carried =
                            Some((rank, FailureCause::Disconnected(format!("send job: {e:#}"))));
                        break;
                    }
                }
            }
        }
        drop(payloads);

        // Collector state. Workers are tracked by pool slot; the current
        // epoch's rank of each live worker lives in `rank_of_worker`, and
        // `results` is indexed by epoch-relative rank.
        let mut rank_of_worker: Vec<Option<usize>> = (0..nranks).map(Some).collect();
        let mut n_alive = nranks;
        let mut epoch: u64 = base_epoch;
        let mut last_seen = vec![Instant::now(); nranks];
        let mut results: Vec<Option<RankResult>> = (0..nranks).map(|_| None).collect();
        let mut n_done = 0;
        let mut live: Option<Live> = None;
        let mut a_full: Option<Csr> = None;
        let mut retries_left = match policy {
            FaultPolicy::Fail => 0,
            FaultPolicy::Recover { max_retries } => max_retries,
        };
        if rep.is_some() {
            // The recovery replan machinery is flat-only: a replicated run
            // fails fast and surfaces the RankFailure instead.
            retries_left = 0;
        }
        let mut report = RecoveryReport::default();
        let mut failure: Option<RankFailure> = None;

        'collect: while n_done < n_alive {
            let missing = |rank_of_worker: &[Option<usize>],
                           results: &[Option<RankResult>],
                           w: usize| {
                rank_of_worker[w].is_some_and(|r| results[r].is_none())
            };
            let mut fail_ev: Option<(usize, FailureCause)> = if carried.is_some() {
                carried.take()
            } else {
                match self.ev_rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(Event::Done(w, conn, e, rank, c, vals, st)) => {
                        if conn != self.conn_id[w] {
                            None // ghost of a replaced connection
                        } else {
                            last_seen[w] = Instant::now();
                            if !self.alive[w] || e != epoch {
                                None // stale epoch or quarantined worker
                            } else if rank_of_worker[w] == Some(rank) {
                                if results[rank].is_none() {
                                    results[rank] = Some((c, vals, st));
                                    n_done += 1;
                                }
                                None
                            } else {
                                Some((
                                    w,
                                    FailureCause::Protocol(format!(
                                        "DONE claims rank {rank} on worker {w}'s stream"
                                    )),
                                ))
                            }
                        }
                    }
                    Ok(Event::Beat(w, conn)) => {
                        if conn == self.conn_id[w] {
                            last_seen[w] = Instant::now();
                        }
                        None
                    }
                    Ok(Event::WorkerErr(w, conn, e, msg)) => {
                        if conn != self.conn_id[w] {
                            None
                        } else {
                            last_seen[w] = Instant::now();
                            (self.alive[w] && e == epoch)
                                .then(|| (w, FailureCause::Worker(msg)))
                        }
                    }
                    Ok(Event::Fail(w, conn, cause)) => {
                        (conn == self.conn_id[w] && self.alive[w]).then_some((w, cause))
                    }
                    Ok(Event::Eof(w, conn, msg)) => (conn == self.conn_id[w]
                        && self.alive[w]
                        && missing(&rank_of_worker, &results, w))
                    .then(|| (w, FailureCause::Disconnected(msg))),
                    // Timeout tick. (The pool holds its own sender, so
                    // the channel can never disconnect; a fleet-wide
                    // wipeout surfaces through EOFs and the heartbeat
                    // scan below instead.)
                    Err(_) => None,
                }
            };
            if fail_ev.is_none() {
                fail_ev = (0..nranks)
                    .find(|&w| {
                        self.alive[w]
                            && missing(&rank_of_worker, &results, w)
                            && last_seen[w].elapsed() > popts.timeout
                    })
                    .map(|w| (w, FailureCause::HeartbeatTimeout(popts.timeout)));
            }

            // Failure handling. A replan that fails mid-ship (another
            // worker died under us) loops back through with the new
            // victim rather than recursing.
            let mut pending = fail_ev;
            while let Some((fw, fc)) = pending.take() {
                self.alive[fw] = false;
                let lost_rank = rank_of_worker[fw].take().expect("live worker had a rank");
                n_alive -= 1;
                if retries_left == 0 || n_alive == 0 {
                    failure = Some(RankFailure { rank: fw, cause: fc });
                    break 'collect;
                }
                retries_left -= 1;
                let t_rec = Instant::now();
                report.lost_ranks.push(fw);
                report.replans += 1;

                // Cancel the in-flight step on every survivor before the
                // replanned JOB lands on the same stream (TCP order
                // guarantees ABORT is seen first).
                let abort = wire::epoch_payload(epoch);
                for w2 in (0..nranks).filter(|&w2| self.alive[w2]) {
                    let mut ws = writers[w2].lock().unwrap();
                    let _ = wire::write_frame(&mut *ws, kind::ABORT, &abort);
                }

                // Rebuild the plan state on the surviving partition. The
                // replan is the same pure function of (A, partition,
                // strategy, topology) a cold start runs — that purity is
                // the bitwise-replay guarantee the fault suite pins.
                let (new_part, strategy, had_sched, new_topo);
                {
                    let (cpart, cblocks): (&RowPartition, &[LocalBlocks]) = match &live {
                        None => (part, blocks),
                        Some(l) => (&l.part, l.blocks.as_slice()),
                    };
                    if a_full.is_none() {
                        a_full = Some(assemble_1d(cblocks, cpart));
                    }
                    new_part = recover_partition(cpart, lost_rank);
                    let (cplan, csched, ctopo) = match &live {
                        None => (plan, sched, topo),
                        Some(l) => (&l.plan, l.sched.as_ref(), &l.topo),
                    };
                    strategy = cplan.strategy;
                    had_sched = csched.is_some();
                    new_topo = Topology { nranks: n_alive, ..ctopo.clone() };
                }
                let a = a_full.as_ref().expect("assembled above");
                let new_blocks = split_1d(a, &new_part);
                let new_plan = crate::comm::plan(&new_blocks, &new_part, strategy, None);
                let new_sched = had_sched.then(|| hierarchy::build(&new_plan, &new_topo));
                live = Some(Live {
                    part: new_part,
                    plan: new_plan,
                    blocks: new_blocks,
                    sched: new_sched,
                    topo: new_topo,
                });

                // Renumber survivors 0..n_alive in spawn order and
                // publish the new routing epoch before any survivor can
                // learn of it from its JOB frame.
                epoch += 1;
                let survivors: Vec<usize> =
                    (0..nranks).filter(|&w2| self.alive[w2]).collect();
                for (r, &w2) in survivors.iter().enumerate() {
                    rank_of_worker[w2] = Some(r);
                }
                {
                    let mut rt = self.route.lock().unwrap();
                    rt.epoch = epoch;
                    rt.worker_of_rank = survivors.clone();
                }
                results = (0..n_alive).map(|_| None).collect();
                n_done = 0;

                let l = live.as_ref().expect("just replanned");
                let xsched_owned = (op != KernelOp::Spmm)
                    .then(|| l.sched.as_ref().map(hierarchy::sddmm_fetch))
                    .flatten();
                let mut reship: Vec<(usize, u64, Vec<u8>)> =
                    Vec::with_capacity(survivors.len());
                for (r, &w2) in survivors.iter().enumerate() {
                    // Replanned bodies always ship full — the fingerprint
                    // just changed with the partition — and re-arm
                    // nothing: a fault plan fires at most once.
                    let fp2 = wire::job_fingerprint(
                        r,
                        &l.part,
                        &l.topo,
                        &l.plan,
                        l.sched.as_ref(),
                        None,
                        &l.blocks[r],
                    );
                    let job = match wire::encode_job(
                        r,
                        op,
                        opts,
                        &l.part,
                        &l.topo,
                        &l.plan,
                        l.sched.as_ref(),
                        xsched_owned.as_ref(),
                        None,
                        &l.blocks[r],
                        &slice_rows(b, &l.part, r),
                        x.map(|x| slice_rows(x, &l.part, r)).as_ref(),
                    ) {
                        Ok(j) => j,
                        Err(e) => {
                            pending = Some((
                                w2,
                                FailureCause::Protocol(format!("encode job: {e:#}")),
                            ));
                            break;
                        }
                    };
                    let mut payload = wire::encode_job_header(&wire::JobHeader {
                        generation: self.generation,
                        epoch,
                        mode: wire::JOB_MODE_FULL,
                        crash: None,
                        fp: fp2,
                    });
                    payload.extend_from_slice(&job);
                    reship.push((w2, fp2, payload));
                }
                // Same all-locks write as the base ship: no survivor may
                // see another survivor's routed DATA before its own
                // replanned JOB on the new epoch.
                if pending.is_none() {
                    let mut guards: Vec<_> =
                        survivors.iter().map(|&w2| writers[w2].lock().unwrap()).collect();
                    for (i, (w2, fp2, payload)) in reship.iter().enumerate() {
                        match wire::write_frame(&mut *guards[i], kind::JOB, payload) {
                            Ok(()) => self.last_fp[*w2] = Some(*fp2),
                            Err(e) => {
                                pending = Some((
                                    *w2,
                                    FailureCause::Disconnected(format!("send job: {e:#}")),
                                ));
                                break;
                            }
                        }
                    }
                }
                report.replan_secs.push(t_rec.elapsed().as_secs_f64());
                // Replanning can outlast the heartbeat budget on big
                // inputs; restart every survivor's liveness clock.
                for &w2 in &survivors {
                    last_seen[w2] = Instant::now();
                }
            }
        }

        // Request teardown: the fleet stays alive, the route goes idle,
        // and the next request's base epoch clears every epoch this one
        // used. On failure, ABORT the in-flight epoch on the survivors so
        // their job threads wind down instead of blocking on an exchange
        // that will never complete; dead slots heal by re-admission at
        // the next request.
        self.epoch = epoch + 1;
        if failure.is_some() {
            let abort = wire::epoch_payload(epoch);
            for w2 in (0..nranks).filter(|&w2| self.alive[w2]) {
                let mut ws = writers[w2].lock().unwrap();
                let _ = wire::write_frame(&mut *ws, kind::ABORT, &abort);
            }
        }
        self.route.lock().unwrap().active = false;
        if let Some(f) = failure {
            return Err(f);
        }
        let results: Vec<RankResult> =
            results.into_iter().map(|r| r.expect("counted done")).collect();

        // Assemble under the *final* partition — post-recovery it differs
        // from the caller's.
        let (fpart, fblocks, fplan): (&RowPartition, &[LocalBlocks], &CommPlan) = match &live {
            None => (part, blocks, plan),
            Some(l) => (&l.part, l.blocks.as_slice(), &l.plan),
        };
        let mut c_global = Dense::zeros(fpart.n, c_cols);
        let mut all_vals = Vec::with_capacity(results.len());
        let mut per_rank = Vec::with_capacity(results.len());
        for (rank, (c_local, vals, stats)) in results.into_iter().enumerate() {
            // Under replication only group homes return C rows; members
            // report an empty block.
            let (r0, r1) = match rep {
                None => fpart.range(rank),
                Some(rs) if rs.map.member_of(rank) == 0 => {
                    fpart.range(rs.map.group_of(rank))
                }
                Some(_) => (0, 0),
            };
            if c_local.nrows != r1 - r0 || c_local.ncols != c_cols {
                return Err(RankFailure {
                    rank,
                    cause: FailureCause::Protocol(format!(
                        "C block shape {}x{}, expected {}x{c_cols}",
                        c_local.nrows,
                        c_local.ncols,
                        r1 - r0
                    )),
                });
            }
            c_global.data[r0 * c_cols..r1 * c_cols].copy_from_slice(&c_local.data);
            all_vals.push(vals);
            per_rank.push(stats);
        }
        let e =
            (op == KernelOp::Sddmm).then(|| assemble_sddmm(fpart, fblocks, fplan, &all_vals));
        let report = (report.replans > 0).then(|| RecoveryReport {
            recovered: true,
            final_starts: fpart.starts.clone(),
            ..report
        });
        let stats = ExecStats { per_rank, wall_secs: t0.elapsed().as_secs_f64() };
        Ok((c_global, e, stats, report))
    }
}

/// Spawn one rank process pointed at the pool's control port. The crash
/// plan deliberately does *not* ride the environment anymore: fault
/// injection is per-JOB (wire v4), so a pooled worker can be armed for
/// one request and clean for the next without respawning.
fn spawn_worker(exe: &PathBuf, port: u16, rank: usize) -> std::io::Result<Child> {
    let mut cmd = Command::new(exe);
    cmd.env(wire::ENV_PORT, port.to_string()).env(wire::ENV_RANK, rank.to_string());
    cmd.spawn()
}

/// Accept + HELLO every rank in `expect` under one hard deadline, so a
/// worker that dies before connecting (or never says hello) cannot hang
/// the control plane. The listener stays nonblocking for the pool's whole
/// lifetime. Handshake failures are not recoverable — [`FaultPolicy`]
/// governs mid-step deaths, not a fleet (or a re-admission) that never
/// formed.
fn accept_hellos(
    listener: &TcpListener,
    expect: &mut BTreeSet<usize>,
    timeout: Duration,
) -> Result<Vec<(usize, TcpStream)>, RankFailure> {
    let fail = |rank: usize, cause: FailureCause| RankFailure { rank, cause };
    let mut got = Vec::new();
    let deadline = Instant::now() + timeout;
    while !expect.is_empty() {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(timeout)).ok();
                let hello = wire::read_frame(&mut (&stream)).and_then(|(k, payload)| {
                    if k != kind::HELLO {
                        anyhow::bail!("expected HELLO, got frame kind {k}");
                    }
                    wire::decode_hello(&payload)
                });
                match hello {
                    Ok((v, rank)) if v != wire::WIRE_VERSION => {
                        return Err(fail(
                            rank,
                            FailureCause::Protocol(format!(
                                "worker wire version {v} != {}",
                                wire::WIRE_VERSION
                            )),
                        ));
                    }
                    Ok((_, rank)) if !expect.contains(&rank) => {
                        return Err(fail(
                            0,
                            FailureCause::Protocol(format!(
                                "unexpected HELLO from rank {rank}"
                            )),
                        ));
                    }
                    Ok((_, rank)) => {
                        stream.set_read_timeout(None).ok();
                        expect.remove(&rank);
                        got.push((rank, stream));
                    }
                    Err(e) => {
                        return Err(fail(
                            0,
                            FailureCause::Protocol(format!("bad handshake: {e:#}")),
                        ));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    let missing = expect.iter().next().copied().unwrap_or(0);
                    return Err(fail(
                        missing,
                        FailureCause::Disconnected(format!(
                            "worker never connected within {:.1}s",
                            timeout.as_secs_f64()
                        )),
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                return Err(fail(0, FailureCause::Spawn(format!("accept: {e}"))));
            }
        }
    }
    Ok(got)
}

/// Detached reader for one worker connection. Outlives individual
/// requests; exits on socket EOF (worker death or pool teardown). EOF and
/// terminal protocol failures are reported unconditionally — routine
/// events are gated on an active request so the idle heartbeats workers
/// keep sending between requests cannot grow the event queue.
fn reader_loop(
    slot: usize,
    conn: u64,
    stream: TcpStream,
    route: Arc<Mutex<RouteState>>,
    ev_tx: mpsc::Sender<Event>,
) {
    let mut rd = BufReader::new(stream);
    loop {
        let (k, payload) = match wire::read_frame(&mut rd) {
            Ok(f) => f,
            Err(e) => {
                let _ = ev_tx.send(Event::Eof(slot, conn, format!("{e:#}")));
                return;
            }
        };
        match k {
            kind::DATA => {
                let (dst, epoch) = match wire::decode_data_header(&payload) {
                    Ok(h) => h,
                    Err(e) => {
                        let _ = ev_tx.send(Event::Fail(
                            slot,
                            conn,
                            FailureCause::Protocol(format!("bad DATA: {e:#}")),
                        ));
                        return;
                    }
                };
                // Route by the *current* epoch's rank→slot map; frames
                // from an aborted (or already-finished) epoch are dropped
                // here, before they can reach a replanned job.
                let target = {
                    let rt = route.lock().unwrap();
                    if !rt.active || epoch != rt.epoch {
                        continue;
                    }
                    rt.worker_of_rank.get(dst).map(|&t| Arc::clone(&rt.writers[t]))
                };
                match target {
                    Some(w) => {
                        // Routed verbatim. A write failure means *dst*
                        // died; dst's own reader reports that as EOF, so
                        // it is not this stream's failure.
                        let mut ws = w.lock().unwrap();
                        let _ = wire::write_frame(&mut *ws, kind::DATA, &payload);
                    }
                    None => {
                        let _ = ev_tx.send(Event::Fail(
                            slot,
                            conn,
                            FailureCause::Protocol(format!("DATA for bad rank {dst}")),
                        ));
                        return;
                    }
                }
            }
            kind::DONE => match wire::decode_done(&payload) {
                Ok((epoch, rank, c, vals, st)) => {
                    if route.lock().unwrap().active {
                        let _ = ev_tx.send(Event::Done(slot, conn, epoch, rank, c, vals, st));
                    }
                }
                Err(e) => {
                    let _ = ev_tx.send(Event::Fail(
                        slot,
                        conn,
                        FailureCause::Protocol(format!("bad DONE: {e:#}")),
                    ));
                    return;
                }
            },
            kind::BEAT => {
                if route.lock().unwrap().active {
                    let _ = ev_tx.send(Event::Beat(slot, conn));
                }
            }
            kind::ERROR => match wire::decode_error(&payload) {
                // Keep reading: a stale-epoch ERROR is an aborted job
                // winding down, and this worker may still serve later
                // epochs.
                Ok((epoch, _, msg)) => {
                    if route.lock().unwrap().active {
                        let _ = ev_tx.send(Event::WorkerErr(slot, conn, epoch, msg));
                    }
                }
                Err(e) => {
                    let _ = ev_tx.send(Event::Fail(
                        slot,
                        conn,
                        FailureCause::Protocol(format!("bad ERROR: {e:#}")),
                    ));
                    return;
                }
            },
            k => {
                let _ = ev_tx.send(Event::Fail(
                    slot,
                    conn,
                    FailureCause::Protocol(format!("unexpected frame kind {k}")),
                ));
                return;
            }
        }
    }
}

/// One rank's slice of a row-partitioned dense operand.
fn slice_rows(d: &Dense, part: &RowPartition, rank: usize) -> Dense {
    let (r0, r1) = part.range(rank);
    let n = d.ncols;
    Dense::from_vec(r1 - r0, n, d.data[r0 * n..r1 * n].to_vec())
}

/// Teardown grace derived from the configured failure timeout (~10%,
/// clamped): the 30 s default allows children 3 s to exit, a
/// short-timeout test tears down in a few hundred ms, and a long-haul
/// run never stalls shutdown more than 10 s.
fn reap_grace(timeout: Duration) -> Duration {
    (timeout / 10).clamp(Duration::from_millis(100), Duration::from_secs(10))
}

fn kill_all(children: &mut [Option<Child>]) {
    for c in children.iter_mut().flatten() {
        let _ = c.kill();
    }
}

/// Reap with a bounded grace period, then force-kill: no zombies, bounded
/// shutdown on every path.
fn reap(children: &mut [Option<Child>], grace: Duration) {
    let deadline = Instant::now() + grace;
    for slot in children.iter_mut() {
        if let Some(c) = slot.as_mut() {
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = c.kill();
                        let _ = c.wait();
                        break;
                    }
                }
            }
        }
        *slot = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts() {
        let o = ProcOpts::default();
        assert_eq!(o.timeout, Duration::from_secs(30));
        assert!(o.worker_exe.is_none());
        assert!(o.fault.is_none());
        assert!(o.pool.is_none());
        assert_eq!(FaultPolicy::default(), FaultPolicy::Fail);
    }

    #[test]
    fn fresh_pool_handle_reports_zero_stats() {
        let h = PoolHandle::new();
        assert_eq!(h.stats(), PoolStats::default());
        // Clones observe the same pool slot.
        let h2 = h.clone();
        assert_eq!(h2.stats(), h.stats());
        assert!(format!("{h:?}").contains("spawns 0"));
    }

    #[test]
    fn reap_grace_tracks_the_configured_timeout() {
        // ~10% of the timeout, clamped to [100ms, 10s].
        assert_eq!(reap_grace(Duration::from_secs(30)), Duration::from_secs(3));
        assert_eq!(reap_grace(Duration::from_secs(10)), Duration::from_secs(1));
        assert_eq!(reap_grace(Duration::from_millis(200)), Duration::from_millis(100));
        assert_eq!(reap_grace(Duration::from_secs(600)), Duration::from_secs(10));
    }

    #[test]
    fn crash_phase_names_roundtrip() {
        for p in CrashPhase::ALL {
            assert_eq!(CrashPhase::by_name(p.name()), Some(p));
        }
        assert_eq!(CrashPhase::by_name("nope"), None);
        assert_eq!(FaultPlan::post_decode(2).phase, CrashPhase::PostDecode);
    }

    #[test]
    fn seeded_fault_plans_are_deterministic_and_in_range() {
        for seed in 0..64u64 {
            for nranks in [1usize, 2, 4, 8] {
                let a = FaultPlan::seeded(seed, nranks);
                let b = FaultPlan::seeded(seed, nranks);
                assert_eq!(a, b, "seed {seed} must be reproducible");
                assert!(a.rank < nranks);
            }
        }
        // Distinct seeds actually vary the choice.
        let plans: std::collections::BTreeSet<_> = (0..64u64)
            .map(|s| {
                let p = FaultPlan::seeded(s, 8);
                (p.rank, p.phase.name())
            })
            .collect();
        assert!(plans.len() > 4, "seeded plans barely vary: {plans:?}");
    }

    #[test]
    fn recovery_report_latency_uses_metrics_samples() {
        let rep = RecoveryReport {
            lost_ranks: vec![1, 3],
            replans: 2,
            recovered: true,
            final_starts: vec![0, 4, 8],
            replan_secs: vec![0.25, 0.75],
        };
        let (stats, total) = rep.latency();
        assert_eq!(stats.count, 2);
        assert_eq!(total, 1.0);
        assert_eq!(stats.max, 0.75);
    }

    #[test]
    fn failure_display_is_structured() {
        let f = RankFailure {
            rank: 3,
            cause: FailureCause::HeartbeatTimeout(Duration::from_secs(10)),
        };
        let s = f.to_string();
        assert!(s.contains("rank 3"), "{s}");
        assert!(s.contains("10.0s"), "{s}");
        let f = RankFailure { rank: 1, cause: FailureCause::Worker("inbox closed".into()) };
        assert!(f.to_string().contains("inbox closed"));
        let f = RankFailure { rank: 0, cause: FailureCause::Disconnected("eof".into()) };
        assert!(f.to_string().contains("disconnected"));
        // RankFailure is a std error, so `?` and anyhow interop work.
        let _: &dyn std::error::Error = &f;
    }
}
