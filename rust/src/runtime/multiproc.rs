//! Multi-process executor backend: every rank is an OS process, driven by
//! a socket message queue (DESIGN.md §10).
//!
//! The parent is a pure control plane — it never touches the numerics. It
//! spawns one worker per rank (re-executing its own binary;
//! [`maybe_run_worker`] intercepts the env-var handshake before CLI
//! dispatch), serializes each rank's job with [`crate::exec::wire`] — the
//! *same* frozen step program the thread executor runs — and then routes
//! DATA frames between workers verbatim. Workers run the identical
//! `rank_main`; since every scatter-add folds in canonical (origin, row)
//! order, the proc backend's C is bitwise-identical to the thread
//! backend's (`tests/multiproc_suite.rs`).
//!
//! Failure semantics: workers heartbeat every
//! [`crate::exec::wire::BEAT_MILLIS`] ms; a worker that panics reports a
//! structured ERROR frame; one that dies silently is detected by its
//! socket closing or by heartbeat silence past [`ProcOpts::timeout`].
//! Every failure path kills and reaps all children and surfaces a
//! [`RankFailure`] instead of hanging.

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::exec::wire::{self, kind};
use crate::exec::{assemble_sddmm, ExecOpts, ExecStats, KernelOp, RankStats, SddmmVals};
use crate::hierarchy::{self, HierSchedule};
use crate::partition::{LocalBlocks, RowPartition};
use crate::sparse::Csr;
use crate::topology::Topology;
use std::fmt;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Control-plane options for one multi-process run.
#[derive(Clone, Debug)]
pub struct ProcOpts {
    /// Declare a rank dead after this long without any frame from it
    /// (heartbeats arrive every [`wire::BEAT_MILLIS`] ms, so this allows
    /// hundreds of missed beats). Also bounds worker connect time.
    pub timeout: Duration,
    /// Worker binary; defaults to `std::env::current_exe()`. Tests pass
    /// `env!("CARGO_BIN_EXE_shiro")` because their own executable is the
    /// test harness, not the CLI.
    pub worker_exe: Option<PathBuf>,
    /// Fault injection: this rank aborts right after the handshake,
    /// standing in for a segfaulted or OOM-killed worker.
    pub crash_rank: Option<usize>,
}

impl Default for ProcOpts {
    fn default() -> ProcOpts {
        ProcOpts { timeout: Duration::from_secs(30), worker_exe: None, crash_rank: None }
    }
}

/// Structured report of the first rank failure the control plane saw.
#[derive(Debug)]
pub struct RankFailure {
    pub rank: usize,
    pub cause: FailureCause,
}

#[derive(Debug)]
pub enum FailureCause {
    /// The worker process could not be spawned (or the control socket
    /// could not be set up — reported as rank 0).
    Spawn(String),
    /// The worker's socket closed before it reported DONE (crash, abort,
    /// OOM kill — anything that dies without a word).
    Disconnected(String),
    /// No frame of any kind within the timeout: the worker is alive-ish
    /// but wedged, or the host lost it.
    HeartbeatTimeout(Duration),
    /// The worker itself reported an error (panic message or job
    /// rejection) via an ERROR frame.
    Worker(String),
    /// The worker sent something the protocol does not allow.
    Protocol(String),
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.cause {
            FailureCause::Spawn(e) => {
                write!(f, "rank {}: failed to spawn worker: {e}", self.rank)
            }
            FailureCause::Disconnected(e) => {
                write!(f, "rank {}: worker disconnected before finishing: {e}", self.rank)
            }
            FailureCause::HeartbeatTimeout(d) => write!(
                f,
                "rank {}: no heartbeat for {:.1}s — worker presumed dead",
                self.rank,
                d.as_secs_f64()
            ),
            FailureCause::Worker(m) => write!(f, "rank {}: worker error: {m}", self.rank),
            FailureCause::Protocol(m) => {
                write!(f, "rank {}: protocol violation: {m}", self.rank)
            }
        }
    }
}

impl std::error::Error for RankFailure {}

/// Call first thing in `main()`: if the worker env vars are set, this
/// process is a spawned rank — run the worker loop and never return.
/// A no-op in ordinary CLI invocations.
pub fn maybe_run_worker() {
    let (Some(port), Some(rank)) =
        (std::env::var(wire::ENV_PORT).ok(), std::env::var(wire::ENV_RANK).ok())
    else {
        return;
    };
    let (Ok(port), Ok(rank)) = (port.parse::<u16>(), rank.parse::<usize>()) else {
        eprintln!(
            "shiro worker: unparseable {}={port:?} / {}={rank:?}",
            wire::ENV_PORT,
            wire::ENV_RANK
        );
        std::process::exit(3);
    };
    wire::worker_main(port, rank)
}

/// Distributed SpMM across worker processes: the proc-backend counterpart
/// of [`crate::exec::run_with`], same plan inputs, same bitwise result.
#[allow(clippy::too_many_arguments)]
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
) -> Result<(Dense, ExecStats), RankFailure> {
    run_op(KernelOp::Spmm, part, plan, blocks, sched, topo, None, b, opts, popts)
        .map(|(c, _, st)| (c, st))
}

/// Fused SDDMM→SpMM across worker processes: counterpart of
/// [`crate::exec::run_fused_with`]. Exercises `Msg::X` over the wire.
#[allow(clippy::too_many_arguments)]
pub fn run_fused(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
) -> Result<(Dense, ExecStats), RankFailure> {
    run_op(KernelOp::FusedSddmmSpmm, part, plan, blocks, sched, topo, Some(x), y, opts, popts)
        .map(|(c, _, st)| (c, st))
}

/// Distributed SDDMM across worker processes: counterpart of
/// [`crate::exec::run_sddmm_with`]. Each worker's DONE frame carries its
/// pool of edge-value buffers (the v2 wire payload); the parent assembles
/// them into the global E exactly as the thread backend does, so the
/// result is bitwise-identical to [`Csr::sddmm`].
#[allow(clippy::too_many_arguments)]
pub fn run_sddmm(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
) -> Result<(Csr, ExecStats), RankFailure> {
    let (_, vals, stats) =
        run_op(KernelOp::Sddmm, part, plan, blocks, sched, topo, Some(x), y, opts, popts)?;
    Ok((assemble_sddmm(part, blocks, plan, &vals), stats))
}

/// One event from a worker's reader thread to the collector.
enum Event {
    Done(usize, Dense, SddmmVals, RankStats),
    Beat(usize),
    Fail(usize, FailureCause),
    /// Stream closed (or read error). Benign after DONE, fatal before.
    Eof(usize, String),
}

#[allow(clippy::too_many_arguments)]
fn run_op(
    op: KernelOp,
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: Option<&Dense>,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
) -> Result<(Dense, Vec<SddmmVals>, ExecStats), RankFailure> {
    let nranks = part.nparts;
    assert_eq!(plan.nranks, nranks);
    assert_eq!(part.n, b.nrows);
    let n_dense = b.ncols;
    // SDDMM workers produce edge values, not a dense block: their C has
    // width 0 and the payload of interest rides the DONE frame instead.
    let c_cols = if op == KernelOp::Sddmm { 0 } else { n_dense };
    let fail = |rank: usize, cause: FailureCause| RankFailure { rank, cause };

    let listener = TcpListener::bind(("127.0.0.1", 0))
        .map_err(|e| fail(0, FailureCause::Spawn(format!("bind control socket: {e}"))))?;
    let port = listener
        .local_addr()
        .map_err(|e| fail(0, FailureCause::Spawn(format!("control socket addr: {e}"))))?
        .port();
    let exe = match &popts.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| fail(0, FailureCause::Spawn(format!("current_exe: {e}"))))?,
    };

    let t0 = Instant::now();
    let mut children: Vec<Child> = Vec::new();
    for rank in 0..nranks {
        let mut cmd = Command::new(&exe);
        cmd.env(wire::ENV_PORT, port.to_string()).env(wire::ENV_RANK, rank.to_string());
        if popts.crash_rank == Some(rank) {
            cmd.env(wire::ENV_CRASH, "1");
        }
        match cmd.spawn() {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(rank, FailureCause::Spawn(e.to_string())));
            }
        }
    }

    // Accept + HELLO with a hard deadline so a worker that dies before
    // connecting (or never says hello) cannot hang the control plane.
    // Non-blocking accept + poll keeps one deadline across all workers.
    let mut streams: Vec<Option<TcpStream>> = (0..nranks).map(|_| None).collect();
    let mut err = None;
    listener.set_nonblocking(true).ok();
    let deadline = Instant::now() + popts.timeout;
    let mut accepted = 0;
    while accepted < nranks && err.is_none() {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(popts.timeout)).ok();
                let hello = wire::read_frame(&mut (&stream)).and_then(|(k, payload)| {
                    if k != kind::HELLO {
                        anyhow::bail!("expected HELLO, got frame kind {k}");
                    }
                    wire::decode_hello(&payload)
                });
                match hello {
                    Ok((v, rank)) if v != wire::WIRE_VERSION => {
                        err = Some(fail(
                            rank.min(nranks.saturating_sub(1)),
                            FailureCause::Protocol(format!(
                                "worker wire version {v} != {}",
                                wire::WIRE_VERSION
                            )),
                        ));
                    }
                    Ok((_, rank)) if rank >= nranks => {
                        err = Some(fail(
                            0,
                            FailureCause::Protocol(format!("HELLO from unknown rank {rank}")),
                        ));
                    }
                    Ok((_, rank)) if streams[rank].is_some() => {
                        err = Some(fail(
                            rank,
                            FailureCause::Protocol(format!("duplicate HELLO from rank {rank}")),
                        ));
                    }
                    Ok((_, rank)) => {
                        stream.set_read_timeout(None).ok();
                        streams[rank] = Some(stream);
                        accepted += 1;
                    }
                    Err(e) => {
                        err = Some(fail(
                            0,
                            FailureCause::Protocol(format!("bad handshake: {e:#}")),
                        ));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    let missing = streams.iter().position(Option::is_none).unwrap_or(0);
                    err = Some(fail(
                        missing,
                        FailureCause::Disconnected(format!(
                            "worker never connected within {:.1}s",
                            popts.timeout.as_secs_f64()
                        )),
                    ));
                } else {
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
            Err(e) => {
                err = Some(fail(0, FailureCause::Spawn(format!("accept: {e}"))));
            }
        }
    }
    if let Some(f) = err {
        kill_all(&mut children);
        reap(&mut children);
        return Err(f);
    }

    // Ship every JOB before any routing starts: a routed DATA frame must
    // never precede JOB on a worker's stream (per-stream writes are only
    // serialized once the writer mutexes exist).
    let xsched_owned =
        (op != KernelOp::Spmm).then(|| sched.map(hierarchy::sddmm_fetch)).flatten();
    for rank in 0..nranks {
        let (r0, r1) = part.range(rank);
        let b_local =
            Dense::from_vec(r1 - r0, n_dense, b.data[r0 * n_dense..r1 * n_dense].to_vec());
        let x_local = x.map(|x| {
            Dense::from_vec(r1 - r0, n_dense, x.data[r0 * n_dense..r1 * n_dense].to_vec())
        });
        let job = match wire::encode_job(
            rank,
            op,
            opts,
            part,
            topo,
            plan,
            sched,
            xsched_owned.as_ref(),
            &blocks[rank],
            &b_local,
            x_local.as_ref(),
        ) {
            Ok(j) => j,
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(rank, FailureCause::Protocol(format!("encode job: {e:#}"))));
            }
        };
        let stream = streams[rank].as_mut().expect("accepted above");
        if let Err(e) = wire::write_frame(stream, kind::JOB, &job) {
            kill_all(&mut children);
            reap(&mut children);
            return Err(fail(rank, FailureCause::Disconnected(format!("send job: {e:#}"))));
        }
    }

    // Split each stream: one cloned read half per reader thread, the
    // original write half behind a mutex for routed DATA frames.
    let mut readers = Vec::with_capacity(nranks);
    for s in &streams {
        match s.as_ref().expect("accepted above").try_clone() {
            Ok(c) => readers.push(c),
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(0, FailureCause::Spawn(format!("clone stream: {e}"))));
            }
        }
    }
    let writers: Vec<Mutex<TcpStream>> =
        streams.into_iter().map(|s| Mutex::new(s.expect("accepted above"))).collect();
    let writers = &writers;

    let (ev_tx, ev_rx) = mpsc::channel::<Event>();
    type RankResult = (Dense, SddmmVals, RankStats);
    let collected: Result<Vec<RankResult>, RankFailure> = std::thread::scope(|scope| {
        for (w, rd) in readers.into_iter().enumerate() {
            let ev_tx = ev_tx.clone();
            scope.spawn(move || {
                let mut rd = BufReader::new(rd);
                loop {
                    let (k, payload) = match wire::read_frame(&mut rd) {
                        Ok(f) => f,
                        Err(e) => {
                            let _ = ev_tx.send(Event::Eof(w, format!("{e:#}")));
                            return;
                        }
                    };
                    match k {
                        kind::DATA => {
                            if payload.len() < 8 {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol("short DATA frame".into()),
                                ));
                                return;
                            }
                            let dst = u64::from_le_bytes(
                                payload[..8].try_into().expect("8-byte prefix"),
                            ) as usize;
                            if dst >= writers.len() {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol(format!("DATA for bad rank {dst}")),
                                ));
                                return;
                            }
                            // Routed verbatim. A write failure means *dst*
                            // died; dst's own reader reports that as EOF,
                            // so it is not this stream's failure.
                            let mut ws = writers[dst].lock().unwrap();
                            let _ = wire::write_frame(&mut *ws, kind::DATA, &payload);
                        }
                        kind::DONE => match wire::decode_done(&payload) {
                            Ok((rank, c, vals, st)) if rank == w => {
                                let _ = ev_tx.send(Event::Done(w, c, vals, st));
                            }
                            Ok((rank, ..)) => {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol(format!(
                                        "DONE claims rank {rank} on rank {w}'s stream"
                                    )),
                                ));
                                return;
                            }
                            Err(e) => {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol(format!("bad DONE: {e:#}")),
                                ));
                                return;
                            }
                        },
                        kind::BEAT => {
                            let _ = ev_tx.send(Event::Beat(w));
                        }
                        kind::ERROR => {
                            let cause = match wire::decode_error(&payload) {
                                Ok((_, msg)) => FailureCause::Worker(msg),
                                Err(e) => FailureCause::Protocol(format!("bad ERROR: {e:#}")),
                            };
                            let _ = ev_tx.send(Event::Fail(w, cause));
                            return;
                        }
                        k => {
                            let _ = ev_tx.send(Event::Fail(
                                w,
                                FailureCause::Protocol(format!("unexpected frame kind {k}")),
                            ));
                            return;
                        }
                    }
                }
            });
        }
        drop(ev_tx);

        let mut last_seen = vec![Instant::now(); nranks];
        let mut results: Vec<Option<RankResult>> = (0..nranks).map(|_| None).collect();
        let mut n_done = 0;
        let mut failure: Option<RankFailure> = None;
        while n_done < nranks {
            match ev_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(Event::Done(w, c, vals, st)) => {
                    last_seen[w] = Instant::now();
                    if results[w].is_none() {
                        results[w] = Some((c, vals, st));
                        n_done += 1;
                    }
                }
                Ok(Event::Beat(w)) => last_seen[w] = Instant::now(),
                Ok(Event::Fail(w, cause)) => {
                    failure = Some(RankFailure { rank: w, cause });
                    break;
                }
                Ok(Event::Eof(w, msg)) => {
                    // EOF after DONE is the worker exiting normally.
                    if results[w].is_none() {
                        failure =
                            Some(RankFailure { rank: w, cause: FailureCause::Disconnected(msg) });
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if let Some(w) = results.iter().position(Option::is_none) {
                        failure = Some(RankFailure {
                            rank: w,
                            cause: FailureCause::Disconnected("all streams closed".into()),
                        });
                    }
                    break;
                }
            }
            if failure.is_none() {
                if let Some(w) = (0..nranks)
                    .find(|&w| results[w].is_none() && last_seen[w].elapsed() > popts.timeout)
                {
                    failure = Some(RankFailure {
                        rank: w,
                        cause: FailureCause::HeartbeatTimeout(popts.timeout),
                    });
                    break;
                }
            }
        }
        // Kill every child before the scope joins its reader threads: the
        // sockets close, every blocked `read_frame` returns EOF, and the
        // scope can exit instead of deadlocking. On success the children
        // have already exited and this is a no-op.
        kill_all(&mut children);
        match failure {
            Some(f) => Err(f),
            None => Ok(results.into_iter().map(|r| r.expect("counted done")).collect()),
        }
    });
    reap(&mut children);
    let results = collected?;

    let mut c_global = Dense::zeros(part.n, c_cols);
    let mut all_vals = Vec::with_capacity(nranks);
    let mut per_rank = Vec::with_capacity(nranks);
    for (rank, (c_local, vals, stats)) in results.into_iter().enumerate() {
        let (r0, r1) = part.range(rank);
        if c_local.nrows != r1 - r0 || c_local.ncols != c_cols {
            return Err(fail(
                rank,
                FailureCause::Protocol(format!(
                    "C block shape {}x{}, expected {}x{c_cols}",
                    c_local.nrows,
                    c_local.ncols,
                    r1 - r0
                )),
            ));
        }
        c_global.data[r0 * c_cols..r1 * c_cols].copy_from_slice(&c_local.data);
        all_vals.push(vals);
        per_rank.push(stats);
    }
    Ok((c_global, all_vals, ExecStats { per_rank, wall_secs: t0.elapsed().as_secs_f64() }))
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
    }
}

/// Reap with a short grace period, then force-kill: no zombies, bounded
/// shutdown on every path.
fn reap(children: &mut Vec<Child>) {
    let deadline = Instant::now() + Duration::from_secs(2);
    for c in children.iter_mut() {
        loop {
            match c.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(10))
                }
                _ => {
                    let _ = c.kill();
                    let _ = c.wait();
                    break;
                }
            }
        }
    }
    children.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts() {
        let o = ProcOpts::default();
        assert_eq!(o.timeout, Duration::from_secs(30));
        assert!(o.worker_exe.is_none());
        assert!(o.crash_rank.is_none());
    }

    #[test]
    fn failure_display_is_structured() {
        let f = RankFailure {
            rank: 3,
            cause: FailureCause::HeartbeatTimeout(Duration::from_secs(10)),
        };
        let s = f.to_string();
        assert!(s.contains("rank 3"), "{s}");
        assert!(s.contains("10.0s"), "{s}");
        let f = RankFailure { rank: 1, cause: FailureCause::Worker("inbox closed".into()) };
        assert!(f.to_string().contains("inbox closed"));
        let f = RankFailure { rank: 0, cause: FailureCause::Disconnected("eof".into()) };
        assert!(f.to_string().contains("disconnected"));
        // RankFailure is a std error, so `?` and anyhow interop work.
        let _: &dyn std::error::Error = &f;
    }
}
