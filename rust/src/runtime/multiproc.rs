//! Multi-process executor backend: every rank is an OS process, driven by
//! a socket message queue (DESIGN.md §10), with crash recovery by
//! replanning over the survivors (DESIGN.md §12).
//!
//! The parent is a pure control plane — it never touches the numerics. It
//! spawns one worker per rank (re-executing its own binary;
//! [`maybe_run_worker`] intercepts the env-var handshake before CLI
//! dispatch), serializes each rank's job with [`crate::exec::wire`] — the
//! *same* frozen step program the thread executor runs — and then routes
//! DATA frames between workers verbatim. Workers run the identical
//! `rank_main`; since every scatter-add folds in canonical (origin, row)
//! order, the proc backend's C is bitwise-identical to the thread
//! backend's (`tests/multiproc_suite.rs`).
//!
//! Failure semantics: workers heartbeat every
//! [`crate::exec::wire::BEAT_MILLIS`] ms; a worker that panics reports a
//! structured ERROR frame; one that dies silently is detected by its
//! socket closing or by heartbeat silence past [`ProcOpts::timeout`].
//! Under [`FaultPolicy::Fail`] (the default) every failure path kills and
//! reaps all children and surfaces a [`RankFailure`] instead of hanging.
//! Under [`FaultPolicy::Recover`] a mid-step failure triggers recovery
//! instead: the dead worker is quarantined, its row block is merged into
//! an adjacent survivor ([`crate::partition::recover_partition`]), the
//! comm plan and hierarchical schedule are recompiled for the shrunken
//! topology, survivors get an ABORT for the in-flight epoch followed by
//! replanned JOBs under a new epoch, and the step replays from scratch.
//! The parent holds the full `Csr` and dense operands, so no worker state
//! survives into the retry — which is exactly why the recovered C is
//! bitwise-identical to a cold run on the post-recovery partition
//! (`tests/fault_suite.rs`).

use crate::comm::CommPlan;
use crate::dense::Dense;
use crate::exec::wire::{self, kind};
use crate::exec::{assemble_sddmm, ExecOpts, ExecStats, KernelOp, RankStats, SddmmVals};
use crate::hierarchy::{self, HierSchedule};
use crate::metrics::{recovery_latency, LatencyStats};
use crate::partition::{assemble_1d, recover_partition, split_1d, LocalBlocks, RowPartition};
use crate::sparse::Csr;
use crate::topology::Topology;
use crate::util::rng::Rng;
use std::fmt;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Where in the step a [`FaultPlan`] kills its worker. The three phases
/// cover the distinct in-flight states the recovery protocol must handle:
/// before any traffic, mid-exchange with partial data already folded into
/// peers, and after compute with the result one frame from home.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPhase {
    /// Right after the worker decodes its job — no traffic yet (the old
    /// `crash_rank` behavior).
    PostDecode,
    /// Right after the worker's first outgoing DATA frame hits the wire,
    /// so peers hold partial state from the dead rank. Degenerates to
    /// [`CrashPhase::PreDone`] when the program has nothing to send.
    MidExchange,
    /// After compute completes, right before the DONE frame — peers may
    /// have finished already.
    PreDone,
}

impl CrashPhase {
    pub const ALL: [CrashPhase; 3] =
        [CrashPhase::PostDecode, CrashPhase::MidExchange, CrashPhase::PreDone];

    pub fn name(&self) -> &'static str {
        match self {
            CrashPhase::PostDecode => "post-decode",
            CrashPhase::MidExchange => "mid-exchange",
            CrashPhase::PreDone => "pre-done",
        }
    }

    /// Inverse of [`CrashPhase::name`]; how the worker decodes the
    /// [`wire::ENV_CRASH`] value the parent set.
    pub fn by_name(name: &str) -> Option<CrashPhase> {
        CrashPhase::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// Deterministic fault injection: kill rank `rank` at `phase`. Shipped to
/// the worker through its spawn environment, so the crash is reproducible
/// run over run — the property the fault suite's differential assertions
/// stand on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Spawn-time identity (epoch-0 rank) of the worker to kill.
    pub rank: usize,
    pub phase: CrashPhase,
}

impl FaultPlan {
    pub fn new(rank: usize, phase: CrashPhase) -> FaultPlan {
        FaultPlan { rank, phase }
    }

    /// The old `crash_rank` behavior: abort right after decoding the job.
    pub fn post_decode(rank: usize) -> FaultPlan {
        FaultPlan { rank, phase: CrashPhase::PostDecode }
    }

    /// Seeded (rank, phase) choice over `nranks` workers — what the chaos
    /// soak uses to vary its kills reproducibly.
    pub fn seeded(seed: u64, nranks: usize) -> FaultPlan {
        assert!(nranks > 0);
        let mut rng = Rng::new(seed);
        FaultPlan {
            rank: rng.below(nranks),
            phase: CrashPhase::ALL[rng.below(CrashPhase::ALL.len())],
        }
    }
}

/// What the control plane does when a rank dies mid-step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Surface the structured [`RankFailure`] — bitwise the pre-recovery
    /// behavior, and the default.
    #[default]
    Fail,
    /// Repartition the lost rank's rows over the survivors, replan, and
    /// replay the step. At most `max_retries` workers may be lost across
    /// one run; the next failure (or losing the last worker) surfaces the
    /// [`RankFailure`] like [`FaultPolicy::Fail`] does.
    Recover {
        max_retries: usize,
    },
}

/// What recovery did, returned alongside the result when at least one
/// replan happened. `final_starts` pins the post-recovery partition, so a
/// differential test can replay the recovered run as a cold start on the
/// surviving ranks and demand bitwise equality.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryReport {
    /// Spawn-time identities (epoch-0 ranks) of the lost workers, in
    /// failure order.
    pub lost_ranks: Vec<usize>,
    /// Replan rounds performed (== `lost_ranks.len()`).
    pub replans: usize,
    /// The run completed after recovery. (Exhausted retries surface the
    /// final [`RankFailure`] as an error instead of a report.)
    pub recovered: bool,
    /// Row boundaries of the final partition.
    pub final_starts: Vec<usize>,
    /// Seconds per replan round: failure detected → survivor jobs
    /// re-shipped.
    pub replan_secs: Vec<f64>,
}

impl RecoveryReport {
    /// Order statistics plus total over the replan latency samples
    /// ([`crate::metrics::recovery_latency`]).
    pub fn latency(&self) -> (LatencyStats, f64) {
        recovery_latency(&self.replan_secs)
    }
}

/// Control-plane options for one multi-process run.
#[derive(Clone, Debug)]
pub struct ProcOpts {
    /// Declare a rank dead after this long without any frame from it
    /// (heartbeats arrive every [`wire::BEAT_MILLIS`] ms, so this allows
    /// hundreds of missed beats). Also bounds worker connect time.
    pub timeout: Duration,
    /// Worker binary; defaults to `std::env::current_exe()`. Tests pass
    /// `env!("CARGO_BIN_EXE_shiro")` because their own executable is the
    /// test harness, not the CLI.
    pub worker_exe: Option<PathBuf>,
    /// Deterministic fault injection: kill one rank at a chosen phase of
    /// its first step, standing in for a segfaulted or OOM-killed worker.
    pub fault: Option<FaultPlan>,
}

impl Default for ProcOpts {
    fn default() -> ProcOpts {
        ProcOpts { timeout: Duration::from_secs(30), worker_exe: None, fault: None }
    }
}

/// Structured report of the first unrecovered rank failure the control
/// plane saw.
#[derive(Debug)]
pub struct RankFailure {
    pub rank: usize,
    pub cause: FailureCause,
}

#[derive(Debug)]
pub enum FailureCause {
    /// The worker process could not be spawned (or the control socket
    /// could not be set up — reported as rank 0).
    Spawn(String),
    /// The worker's socket closed before it reported DONE (crash, abort,
    /// OOM kill — anything that dies without a word).
    Disconnected(String),
    /// No frame of any kind within the timeout: the worker is alive-ish
    /// but wedged, or the host lost it.
    HeartbeatTimeout(Duration),
    /// The worker itself reported an error (panic message or job
    /// rejection) via an ERROR frame.
    Worker(String),
    /// The worker sent something the protocol does not allow.
    Protocol(String),
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.cause {
            FailureCause::Spawn(e) => {
                write!(f, "rank {}: failed to spawn worker: {e}", self.rank)
            }
            FailureCause::Disconnected(e) => {
                write!(f, "rank {}: worker disconnected before finishing: {e}", self.rank)
            }
            FailureCause::HeartbeatTimeout(d) => write!(
                f,
                "rank {}: no heartbeat for {:.1}s — worker presumed dead",
                self.rank,
                d.as_secs_f64()
            ),
            FailureCause::Worker(m) => write!(f, "rank {}: worker error: {m}", self.rank),
            FailureCause::Protocol(m) => {
                write!(f, "rank {}: protocol violation: {m}", self.rank)
            }
        }
    }
}

impl std::error::Error for RankFailure {}

/// Call first thing in `main()`: if the worker env vars are set, this
/// process is a spawned rank — run the worker loop and never return.
/// A no-op in ordinary CLI invocations.
pub fn maybe_run_worker() {
    let (Some(port), Some(rank)) =
        (std::env::var(wire::ENV_PORT).ok(), std::env::var(wire::ENV_RANK).ok())
    else {
        return;
    };
    let (Ok(port), Ok(rank)) = (port.parse::<u16>(), rank.parse::<usize>()) else {
        eprintln!(
            "shiro worker: unparseable {}={port:?} / {}={rank:?}",
            wire::ENV_PORT,
            wire::ENV_RANK
        );
        std::process::exit(3);
    };
    wire::worker_main(port, rank)
}

/// Distributed SpMM across worker processes: the proc-backend counterpart
/// of [`crate::exec::run_with`], same plan inputs, same bitwise result.
#[allow(clippy::too_many_arguments)]
pub fn run(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, ExecStats, Option<RecoveryReport>), RankFailure> {
    run_op(KernelOp::Spmm, part, plan, blocks, sched, topo, None, b, opts, popts, policy)
        .map(|(c, _, st, rec)| (c, st, rec))
}

/// Fused SDDMM→SpMM across worker processes: counterpart of
/// [`crate::exec::run_fused_with`]. Exercises `Msg::X` over the wire.
#[allow(clippy::too_many_arguments)]
pub fn run_fused(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, ExecStats, Option<RecoveryReport>), RankFailure> {
    run_op(
        KernelOp::FusedSddmmSpmm,
        part,
        plan,
        blocks,
        sched,
        topo,
        Some(x),
        y,
        opts,
        popts,
        policy,
    )
    .map(|(c, _, st, rec)| (c, st, rec))
}

/// Distributed SDDMM across worker processes: counterpart of
/// [`crate::exec::run_sddmm_with`]. Each worker's DONE frame carries its
/// pool of edge-value buffers (the v2 wire payload); the parent assembles
/// them into the global E — under the *final* (possibly post-recovery)
/// partition — exactly as the thread backend does, so the result is
/// bitwise-identical to [`Csr::sddmm`].
#[allow(clippy::too_many_arguments)]
pub fn run_sddmm(
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: &Dense,
    y: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Csr, ExecStats, Option<RecoveryReport>), RankFailure> {
    let (_, e, stats, rec) = run_op(
        KernelOp::Sddmm,
        part,
        plan,
        blocks,
        sched,
        topo,
        Some(x),
        y,
        opts,
        popts,
        policy,
    )?;
    Ok((e.expect("SDDMM always assembles E"), stats, rec))
}

/// One event from a worker's reader thread to the collector. Workers are
/// identified by their stream index (spawn-time identity), not by any
/// epoch-relative rank a payload claims.
enum Event {
    /// DONE frame: (worker, epoch, claimed rank, C block, vals, stats).
    Done(usize, u64, usize, Dense, SddmmVals, RankStats),
    Beat(usize),
    /// Unrecoverable protocol-level problem on this worker's stream.
    Fail(usize, FailureCause),
    /// ERROR frame: (worker, epoch, message). Stale epochs are the normal
    /// "inbox closed" wake-up of an aborted job and are discarded.
    WorkerErr(usize, u64, String),
    /// Stream closed (or read error). Benign after DONE, fatal before.
    Eof(usize, String),
}

/// Plan state for the current epoch, owned by the collector once the
/// first recovery replan replaces the caller's borrowed epoch-0 state.
struct Live {
    part: RowPartition,
    plan: CommPlan,
    blocks: Vec<LocalBlocks>,
    sched: Option<HierSchedule>,
    topo: Topology,
}

/// Routing table shared with the per-worker reader threads: DATA frames
/// carry an epoch-relative `dst` rank, so the rank→worker map must swap
/// atomically with the epoch bump.
struct Route {
    epoch: u64,
    worker_of_rank: Vec<usize>,
}

#[allow(clippy::too_many_arguments)]
fn run_op(
    op: KernelOp,
    part: &RowPartition,
    plan: &CommPlan,
    blocks: &[LocalBlocks],
    sched: Option<&HierSchedule>,
    topo: &Topology,
    x: Option<&Dense>,
    b: &Dense,
    opts: &ExecOpts,
    popts: &ProcOpts,
    policy: FaultPolicy,
) -> Result<(Dense, Option<Csr>, ExecStats, Option<RecoveryReport>), RankFailure> {
    let nranks = part.nparts;
    assert_eq!(plan.nranks, nranks);
    assert_eq!(part.n, b.nrows);
    let n_dense = b.ncols;
    // SDDMM workers produce edge values, not a dense block: their C has
    // width 0 and the payload of interest rides the DONE frame instead.
    let c_cols = if op == KernelOp::Sddmm { 0 } else { n_dense };
    let fail = |rank: usize, cause: FailureCause| RankFailure { rank, cause };

    let listener = TcpListener::bind(("127.0.0.1", 0))
        .map_err(|e| fail(0, FailureCause::Spawn(format!("bind control socket: {e}"))))?;
    let port = listener
        .local_addr()
        .map_err(|e| fail(0, FailureCause::Spawn(format!("control socket addr: {e}"))))?
        .port();
    let exe = match &popts.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| fail(0, FailureCause::Spawn(format!("current_exe: {e}"))))?,
    };

    let t0 = Instant::now();
    let mut children: Vec<Child> = Vec::new();
    for rank in 0..nranks {
        let mut cmd = Command::new(&exe);
        cmd.env(wire::ENV_PORT, port.to_string()).env(wire::ENV_RANK, rank.to_string());
        if let Some(fp) = popts.fault {
            if fp.rank == rank {
                cmd.env(wire::ENV_CRASH, fp.phase.name());
            }
        }
        match cmd.spawn() {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(rank, FailureCause::Spawn(e.to_string())));
            }
        }
    }

    // Accept + HELLO with a hard deadline so a worker that dies before
    // connecting (or never says hello) cannot hang the control plane.
    // Non-blocking accept + poll keeps one deadline across all workers.
    // Handshake failures are not recoverable — FaultPolicy governs
    // mid-step deaths, not a fleet that never formed.
    let mut streams: Vec<Option<TcpStream>> = (0..nranks).map(|_| None).collect();
    let mut err = None;
    listener.set_nonblocking(true).ok();
    let deadline = Instant::now() + popts.timeout;
    let mut accepted = 0;
    while accepted < nranks && err.is_none() {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(popts.timeout)).ok();
                let hello = wire::read_frame(&mut (&stream)).and_then(|(k, payload)| {
                    if k != kind::HELLO {
                        anyhow::bail!("expected HELLO, got frame kind {k}");
                    }
                    wire::decode_hello(&payload)
                });
                match hello {
                    Ok((v, rank)) if v != wire::WIRE_VERSION => {
                        err = Some(fail(
                            rank.min(nranks.saturating_sub(1)),
                            FailureCause::Protocol(format!(
                                "worker wire version {v} != {}",
                                wire::WIRE_VERSION
                            )),
                        ));
                    }
                    Ok((_, rank)) if rank >= nranks => {
                        err = Some(fail(
                            0,
                            FailureCause::Protocol(format!("HELLO from unknown rank {rank}")),
                        ));
                    }
                    Ok((_, rank)) if streams[rank].is_some() => {
                        err = Some(fail(
                            rank,
                            FailureCause::Protocol(format!("duplicate HELLO from rank {rank}")),
                        ));
                    }
                    Ok((_, rank)) => {
                        stream.set_read_timeout(None).ok();
                        streams[rank] = Some(stream);
                        accepted += 1;
                    }
                    Err(e) => {
                        err = Some(fail(
                            0,
                            FailureCause::Protocol(format!("bad handshake: {e:#}")),
                        ));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    let missing = streams.iter().position(Option::is_none).unwrap_or(0);
                    err = Some(fail(
                        missing,
                        FailureCause::Disconnected(format!(
                            "worker never connected within {:.1}s",
                            popts.timeout.as_secs_f64()
                        )),
                    ));
                } else {
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
            Err(e) => {
                err = Some(fail(0, FailureCause::Spawn(format!("accept: {e}"))));
            }
        }
    }
    if let Some(f) = err {
        kill_all(&mut children);
        reap(&mut children);
        return Err(f);
    }

    // Ship every epoch-0 JOB before any routing starts: a routed DATA
    // frame must never precede JOB on a worker's stream (per-stream
    // writes are only serialized once the writer mutexes exist).
    let xsched_owned =
        (op != KernelOp::Spmm).then(|| sched.map(hierarchy::sddmm_fetch)).flatten();
    for rank in 0..nranks {
        let job = match wire::encode_job(
            rank,
            op,
            opts,
            part,
            topo,
            plan,
            sched,
            xsched_owned.as_ref(),
            &blocks[rank],
            &slice_rows(b, part, rank),
            x.map(|x| slice_rows(x, part, rank)).as_ref(),
        ) {
            Ok(j) => j,
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(rank, FailureCause::Protocol(format!("encode job: {e:#}"))));
            }
        };
        let mut payload = wire::epoch_payload(0);
        payload.extend_from_slice(&job);
        let stream = streams[rank].as_mut().expect("accepted above");
        if let Err(e) = wire::write_frame(stream, kind::JOB, &payload) {
            kill_all(&mut children);
            reap(&mut children);
            return Err(fail(rank, FailureCause::Disconnected(format!("send job: {e:#}"))));
        }
    }

    // Split each stream: one cloned read half per reader thread, the
    // original write half behind a mutex for routed DATA frames and
    // recovery-control (ABORT / replanned JOB) frames.
    let mut readers = Vec::with_capacity(nranks);
    for s in &streams {
        match s.as_ref().expect("accepted above").try_clone() {
            Ok(c) => readers.push(c),
            Err(e) => {
                kill_all(&mut children);
                reap(&mut children);
                return Err(fail(0, FailureCause::Spawn(format!("clone stream: {e}"))));
            }
        }
    }
    let writers: Vec<Mutex<TcpStream>> =
        streams.into_iter().map(|s| Mutex::new(s.expect("accepted above"))).collect();
    let writers = &writers;
    let route = Mutex::new(Route { epoch: 0, worker_of_rank: (0..nranks).collect() });
    let route = &route;

    let (ev_tx, ev_rx) = mpsc::channel::<Event>();
    type RankResult = (Dense, SddmmVals, RankStats);
    type Collected = (Vec<RankResult>, Option<Live>, RecoveryReport);
    let collected: Result<Collected, RankFailure> = std::thread::scope(|scope| {
        for (w, rd) in readers.into_iter().enumerate() {
            let ev_tx = ev_tx.clone();
            scope.spawn(move || {
                let mut rd = BufReader::new(rd);
                loop {
                    let (k, payload) = match wire::read_frame(&mut rd) {
                        Ok(f) => f,
                        Err(e) => {
                            let _ = ev_tx.send(Event::Eof(w, format!("{e:#}")));
                            return;
                        }
                    };
                    match k {
                        kind::DATA => {
                            let (dst, epoch) = match wire::decode_data_header(&payload) {
                                Ok(h) => h,
                                Err(e) => {
                                    let _ = ev_tx.send(Event::Fail(
                                        w,
                                        FailureCause::Protocol(format!("bad DATA: {e:#}")),
                                    ));
                                    return;
                                }
                            };
                            // Route by the *current* epoch's rank→worker
                            // map; frames from an aborted epoch are
                            // dropped here, before they can reach a
                            // replanned job.
                            let target = {
                                let rt = route.lock().unwrap();
                                if epoch != rt.epoch {
                                    continue;
                                }
                                rt.worker_of_rank.get(dst).copied()
                            };
                            match target {
                                Some(t) => {
                                    // Routed verbatim. A write failure
                                    // means *dst* died; dst's own reader
                                    // reports that as EOF, so it is not
                                    // this stream's failure.
                                    let mut ws = writers[t].lock().unwrap();
                                    let _ = wire::write_frame(&mut *ws, kind::DATA, &payload);
                                }
                                None => {
                                    let _ = ev_tx.send(Event::Fail(
                                        w,
                                        FailureCause::Protocol(format!(
                                            "DATA for bad rank {dst}"
                                        )),
                                    ));
                                    return;
                                }
                            }
                        }
                        kind::DONE => match wire::decode_done(&payload) {
                            Ok((epoch, rank, c, vals, st)) => {
                                let _ = ev_tx.send(Event::Done(w, epoch, rank, c, vals, st));
                            }
                            Err(e) => {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol(format!("bad DONE: {e:#}")),
                                ));
                                return;
                            }
                        },
                        kind::BEAT => {
                            let _ = ev_tx.send(Event::Beat(w));
                        }
                        kind::ERROR => match wire::decode_error(&payload) {
                            // Keep reading: a stale-epoch ERROR is an
                            // aborted job winding down, and this worker
                            // may still serve later epochs.
                            Ok((epoch, _, msg)) => {
                                let _ = ev_tx.send(Event::WorkerErr(w, epoch, msg));
                            }
                            Err(e) => {
                                let _ = ev_tx.send(Event::Fail(
                                    w,
                                    FailureCause::Protocol(format!("bad ERROR: {e:#}")),
                                ));
                                return;
                            }
                        },
                        k => {
                            let _ = ev_tx.send(Event::Fail(
                                w,
                                FailureCause::Protocol(format!("unexpected frame kind {k}")),
                            ));
                            return;
                        }
                    }
                }
            });
        }
        drop(ev_tx);

        // Collector state. Workers are tracked by spawn index; the
        // current epoch's rank of each live worker lives in
        // `rank_of_worker`, and `results` is indexed by epoch-relative
        // rank.
        let mut alive = vec![true; nranks];
        let mut rank_of_worker: Vec<Option<usize>> = (0..nranks).map(Some).collect();
        let mut n_alive = nranks;
        let mut epoch: u64 = 0;
        let mut last_seen = vec![Instant::now(); nranks];
        let mut results: Vec<Option<RankResult>> = (0..nranks).map(|_| None).collect();
        let mut n_done = 0;
        let mut live: Option<Live> = None;
        let mut a_full: Option<Csr> = None;
        let mut retries_left = match policy {
            FaultPolicy::Fail => 0,
            FaultPolicy::Recover { max_retries } => max_retries,
        };
        let mut report = RecoveryReport::default();
        let mut failure: Option<RankFailure> = None;

        'collect: while n_done < n_alive {
            let missing = |rank_of_worker: &[Option<usize>],
                           results: &[Option<RankResult>],
                           w: usize| {
                rank_of_worker[w].is_some_and(|r| results[r].is_none())
            };
            let mut fail_ev: Option<(usize, FailureCause)> =
                match ev_rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(Event::Done(w, e, rank, c, vals, st)) => {
                        last_seen[w] = Instant::now();
                        if !alive[w] || e != epoch {
                            None // stale epoch or quarantined worker
                        } else if rank_of_worker[w] == Some(rank) {
                            if results[rank].is_none() {
                                results[rank] = Some((c, vals, st));
                                n_done += 1;
                            }
                            None
                        } else {
                            Some((
                                w,
                                FailureCause::Protocol(format!(
                                    "DONE claims rank {rank} on worker {w}'s stream"
                                )),
                            ))
                        }
                    }
                    Ok(Event::Beat(w)) => {
                        last_seen[w] = Instant::now();
                        None
                    }
                    Ok(Event::WorkerErr(w, e, msg)) => {
                        last_seen[w] = Instant::now();
                        (alive[w] && e == epoch).then(|| (w, FailureCause::Worker(msg)))
                    }
                    Ok(Event::Fail(w, cause)) => alive[w].then_some((w, cause)),
                    Ok(Event::Eof(w, msg)) => (alive[w]
                        && missing(&rank_of_worker, &results, w))
                    .then(|| (w, FailureCause::Disconnected(msg))),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Every reader thread exited with work missing:
                        // attribute to the first live worker still owed a
                        // result (the loop guard guarantees one exists).
                        let w = (0..nranks)
                            .find(|&w| alive[w] && missing(&rank_of_worker, &results, w));
                        match w {
                            Some(w) => Some((
                                w,
                                FailureCause::Disconnected("all streams closed".into()),
                            )),
                            None => break 'collect,
                        }
                    }
                };
            if fail_ev.is_none() {
                fail_ev = (0..nranks)
                    .find(|&w| {
                        alive[w]
                            && missing(&rank_of_worker, &results, w)
                            && last_seen[w].elapsed() > popts.timeout
                    })
                    .map(|w| (w, FailureCause::HeartbeatTimeout(popts.timeout)));
            }

            // Failure handling. A replan that fails mid-ship (another
            // worker died under us) loops back through with the new
            // victim rather than recursing.
            let mut pending = fail_ev;
            while let Some((fw, fc)) = pending.take() {
                alive[fw] = false;
                let lost_rank = rank_of_worker[fw].take().expect("live worker had a rank");
                n_alive -= 1;
                if retries_left == 0 || n_alive == 0 {
                    failure = Some(RankFailure { rank: fw, cause: fc });
                    break 'collect;
                }
                retries_left -= 1;
                let t_rec = Instant::now();
                report.lost_ranks.push(fw);
                report.replans += 1;

                // Cancel the in-flight step on every survivor before the
                // replanned JOB lands on the same stream (TCP order
                // guarantees ABORT is seen first).
                let abort = wire::epoch_payload(epoch);
                for w2 in (0..nranks).filter(|&w2| alive[w2]) {
                    let mut ws = writers[w2].lock().unwrap();
                    let _ = wire::write_frame(&mut *ws, kind::ABORT, &abort);
                }

                // Rebuild the plan state on the surviving partition. The
                // replan is the same pure function of (A, partition,
                // strategy, topology) a cold start runs — that purity is
                // the bitwise-replay guarantee the fault suite pins.
                let (new_part, strategy, had_sched, new_topo);
                {
                    let (cpart, cblocks): (&RowPartition, &[LocalBlocks]) = match &live {
                        None => (part, blocks),
                        Some(l) => (&l.part, l.blocks.as_slice()),
                    };
                    if a_full.is_none() {
                        a_full = Some(assemble_1d(cblocks, cpart));
                    }
                    new_part = recover_partition(cpart, lost_rank);
                    let (cplan, csched, ctopo) = match &live {
                        None => (plan, sched, topo),
                        Some(l) => (&l.plan, l.sched.as_ref(), &l.topo),
                    };
                    strategy = cplan.strategy;
                    had_sched = csched.is_some();
                    new_topo = Topology { nranks: n_alive, ..ctopo.clone() };
                }
                let a = a_full.as_ref().expect("assembled above");
                let new_blocks = split_1d(a, &new_part);
                let new_plan = crate::comm::plan(&new_blocks, &new_part, strategy, None);
                let new_sched = had_sched.then(|| hierarchy::build(&new_plan, &new_topo));
                live = Some(Live {
                    part: new_part,
                    plan: new_plan,
                    blocks: new_blocks,
                    sched: new_sched,
                    topo: new_topo,
                });

                // Renumber survivors 0..n_alive in spawn order and
                // publish the new routing epoch before any survivor can
                // learn of it from its JOB frame.
                epoch += 1;
                let survivors: Vec<usize> = (0..nranks).filter(|&w2| alive[w2]).collect();
                for (r, &w2) in survivors.iter().enumerate() {
                    rank_of_worker[w2] = Some(r);
                }
                {
                    let mut rt = route.lock().unwrap();
                    rt.epoch = epoch;
                    rt.worker_of_rank = survivors.clone();
                }
                results = (0..n_alive).map(|_| None).collect();
                n_done = 0;

                let l = live.as_ref().expect("just replanned");
                let xsched_owned = (op != KernelOp::Spmm)
                    .then(|| l.sched.as_ref().map(hierarchy::sddmm_fetch))
                    .flatten();
                for (r, &w2) in survivors.iter().enumerate() {
                    let job = match wire::encode_job(
                        r,
                        op,
                        opts,
                        &l.part,
                        &l.topo,
                        &l.plan,
                        l.sched.as_ref(),
                        xsched_owned.as_ref(),
                        &l.blocks[r],
                        &slice_rows(b, &l.part, r),
                        x.map(|x| slice_rows(x, &l.part, r)).as_ref(),
                    ) {
                        Ok(j) => j,
                        Err(e) => {
                            pending = Some((
                                w2,
                                FailureCause::Protocol(format!("encode job: {e:#}")),
                            ));
                            break;
                        }
                    };
                    let mut payload = wire::epoch_payload(epoch);
                    payload.extend_from_slice(&job);
                    let sent = {
                        let mut ws = writers[w2].lock().unwrap();
                        wire::write_frame(&mut *ws, kind::JOB, &payload)
                    };
                    if let Err(e) = sent {
                        pending = Some((
                            w2,
                            FailureCause::Disconnected(format!("send job: {e:#}")),
                        ));
                        break;
                    }
                }
                report.replan_secs.push(t_rec.elapsed().as_secs_f64());
                // Replanning can outlast the heartbeat budget on big
                // inputs; restart every survivor's liveness clock.
                for &w2 in &survivors {
                    last_seen[w2] = Instant::now();
                }
            }
        }
        // Kill every child before the scope joins its reader threads: the
        // sockets close, every blocked `read_frame` returns EOF, and the
        // scope can exit instead of deadlocking. On success the children
        // are idle and die here.
        kill_all(&mut children);
        match failure {
            Some(f) => Err(f),
            None => Ok((
                results.into_iter().map(|r| r.expect("counted done")).collect(),
                live,
                report,
            )),
        }
    });
    reap(&mut children);
    let (results, live, report) = collected?;

    // Assemble under the *final* partition — post-recovery it differs
    // from the caller's.
    let (fpart, fblocks, fplan): (&RowPartition, &[LocalBlocks], &CommPlan) = match &live {
        None => (part, blocks, plan),
        Some(l) => (&l.part, l.blocks.as_slice(), &l.plan),
    };
    let mut c_global = Dense::zeros(fpart.n, c_cols);
    let mut all_vals = Vec::with_capacity(results.len());
    let mut per_rank = Vec::with_capacity(results.len());
    for (rank, (c_local, vals, stats)) in results.into_iter().enumerate() {
        let (r0, r1) = fpart.range(rank);
        if c_local.nrows != r1 - r0 || c_local.ncols != c_cols {
            return Err(fail(
                rank,
                FailureCause::Protocol(format!(
                    "C block shape {}x{}, expected {}x{c_cols}",
                    c_local.nrows,
                    c_local.ncols,
                    r1 - r0
                )),
            ));
        }
        c_global.data[r0 * c_cols..r1 * c_cols].copy_from_slice(&c_local.data);
        all_vals.push(vals);
        per_rank.push(stats);
    }
    let e = (op == KernelOp::Sddmm).then(|| assemble_sddmm(fpart, fblocks, fplan, &all_vals));
    let report = (report.replans > 0).then(|| RecoveryReport {
        recovered: true,
        final_starts: fpart.starts.clone(),
        ..report
    });
    let stats = ExecStats { per_rank, wall_secs: t0.elapsed().as_secs_f64() };
    Ok((c_global, e, stats, report))
}

/// One rank's slice of a row-partitioned dense operand.
fn slice_rows(d: &Dense, part: &RowPartition, rank: usize) -> Dense {
    let (r0, r1) = part.range(rank);
    let n = d.ncols;
    Dense::from_vec(r1 - r0, n, d.data[r0 * n..r1 * n].to_vec())
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
    }
}

/// Reap with a short grace period, then force-kill: no zombies, bounded
/// shutdown on every path.
fn reap(children: &mut Vec<Child>) {
    let deadline = Instant::now() + Duration::from_secs(2);
    for c in children.iter_mut() {
        loop {
            match c.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(10))
                }
                _ => {
                    let _ = c.kill();
                    let _ = c.wait();
                    break;
                }
            }
        }
    }
    children.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts() {
        let o = ProcOpts::default();
        assert_eq!(o.timeout, Duration::from_secs(30));
        assert!(o.worker_exe.is_none());
        assert!(o.fault.is_none());
        assert_eq!(FaultPolicy::default(), FaultPolicy::Fail);
    }

    #[test]
    fn crash_phase_names_roundtrip() {
        for p in CrashPhase::ALL {
            assert_eq!(CrashPhase::by_name(p.name()), Some(p));
        }
        assert_eq!(CrashPhase::by_name("nope"), None);
        assert_eq!(FaultPlan::post_decode(2).phase, CrashPhase::PostDecode);
    }

    #[test]
    fn seeded_fault_plans_are_deterministic_and_in_range() {
        for seed in 0..64u64 {
            for nranks in [1usize, 2, 4, 8] {
                let a = FaultPlan::seeded(seed, nranks);
                let b = FaultPlan::seeded(seed, nranks);
                assert_eq!(a, b, "seed {seed} must be reproducible");
                assert!(a.rank < nranks);
            }
        }
        // Distinct seeds actually vary the choice.
        let plans: std::collections::BTreeSet<_> = (0..64u64)
            .map(|s| {
                let p = FaultPlan::seeded(s, 8);
                (p.rank, p.phase.name())
            })
            .collect();
        assert!(plans.len() > 4, "seeded plans barely vary: {plans:?}");
    }

    #[test]
    fn recovery_report_latency_uses_metrics_samples() {
        let rep = RecoveryReport {
            lost_ranks: vec![1, 3],
            replans: 2,
            recovered: true,
            final_starts: vec![0, 4, 8],
            replan_secs: vec![0.25, 0.75],
        };
        let (stats, total) = rep.latency();
        assert_eq!(stats.count, 2);
        assert_eq!(total, 1.0);
        assert_eq!(stats.max, 0.75);
    }

    #[test]
    fn failure_display_is_structured() {
        let f = RankFailure {
            rank: 3,
            cause: FailureCause::HeartbeatTimeout(Duration::from_secs(10)),
        };
        let s = f.to_string();
        assert!(s.contains("rank 3"), "{s}");
        assert!(s.contains("10.0s"), "{s}");
        let f = RankFailure { rank: 1, cause: FailureCause::Worker("inbox closed".into()) };
        assert!(f.to_string().contains("inbox closed"));
        let f = RankFailure { rank: 0, cause: FailureCause::Disconnected("eof".into()) };
        assert!(f.to_string().contains("disconnected"));
        // RankFailure is a std error, so `?` and anyhow interop work.
        let _: &dyn std::error::Error = &f;
    }
}
