//! Closed-loop saturation driver for `shiro serve --bench`: spawns C
//! synchronous clients against a live [`Server`], sweeps C over a preset's
//! levels, and reports the latency/throughput curve (p50/p99/throughput
//! per level, plus batching and registry hit-rate counters), writing the
//! same rows as JSON under `bench_results/`.
//!
//! Every run starts with the batching gate: a `workers == 0` server
//! coalesces a mixed-width burst of same-graph SpMM requests into one
//! execute, and each split-back result must be **bitwise identical** to
//! direct unbatched execution. A run that prints a curve has re-proven
//! the micro-batcher's correctness contract first.

use super::{Server, ServeConfig, ServeError, ServeRequest, Ticket};
use crate::dense::Dense;
use crate::metrics::{latency_stats, Table};
use crate::sparse::{gen, Csr};
use crate::spmm::{Backend, ExecRequest};
use crate::topology::Topology;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread;
use std::time::{Duration, Instant};

/// One bench configuration. `ci` is sized to finish in seconds inside the
/// CI smoke job; `full` sweeps enough load levels to show the knee.
#[derive(Clone, Debug)]
pub struct BenchPreset {
    pub name: &'static str,
    pub graphs: usize,
    pub nrows: usize,
    pub nnz: usize,
    pub n_dense: usize,
    pub nranks: usize,
    pub workers: usize,
    pub client_counts: &'static [usize],
    pub reqs_per_client: usize,
}

/// Look up a preset by name (`ci` / `full`).
pub fn preset(name: &str) -> Option<BenchPreset> {
    match name {
        "ci" => Some(BenchPreset {
            name: "ci",
            graphs: 2,
            nrows: 256,
            nnz: 3_000,
            n_dense: 8,
            nranks: 4,
            workers: 2,
            client_counts: &[1, 4],
            reqs_per_client: 8,
        }),
        "full" => Some(BenchPreset {
            name: "full",
            graphs: 4,
            nrows: 2_048,
            nnz: 40_000,
            n_dense: 32,
            nranks: 8,
            workers: 4,
            client_counts: &[1, 2, 4, 8, 16],
            reqs_per_client: 32,
        }),
        _ => None,
    }
}

/// One measured load level of the curve.
#[derive(Clone, Debug)]
pub struct LevelRow {
    pub clients: usize,
    pub requests: usize,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    pub hit_rate: f64,
    /// Saturated-and-retried submissions (back-pressure events).
    pub retries: u64,
    /// Proc-pool worker spawns at this level (0 on the thread backend).
    pub pool_spawns: u64,
    /// Proc requests served over already-live pool connections.
    pub pool_reuses: u64,
}

fn serve_config(p: &BenchPreset, workers: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(Topology::tsubame4(p.nranks));
    cfg.workers = workers;
    cfg.spec.params.n_dense = p.n_dense;
    cfg
}

fn bench_graphs(p: &BenchPreset) -> Vec<Csr> {
    (0..p.graphs)
        .map(|i| gen::rmat(p.nrows, p.nnz, (0.55, 0.2, 0.19), false, 1000 + i as u64))
        .collect()
}

/// The batching correctness gate: submit a mixed-width same-graph SpMM
/// burst to a drain-mode server, force one coalesced execute, and check
/// every split-back result bitwise against direct execution.
pub fn verify_batching(p: &BenchPreset) -> Result<()> {
    let a = &bench_graphs(p)[0];
    let mut cfg = serve_config(p, 0);
    cfg.max_batch = 4;
    let srv = Server::new(cfg.clone());
    srv.register_graph("gate", a.clone());
    let mut rng = Rng::new(42);
    let widths = [p.n_dense, p.n_dense / 2 + 1, p.n_dense, 3];
    let bs: Vec<Dense> = widths.iter().map(|&w| Dense::random(a.nrows, w, &mut rng)).collect();
    let tickets: Vec<Ticket> = bs
        .iter()
        .map(|b| {
            srv.try_submit(ServeRequest::spmm("gate", b.clone()))
                .map_err(|e| anyhow!("gate submission rejected: {e}"))
        })
        .collect::<Result<_>>()?;
    let executes = srv.drain_all();
    if executes != 1 {
        bail!("batching gate: expected 1 coalesced execute for 4 requests, got {executes}");
    }
    let dist = cfg.spec.plan(a);
    for (t, b) in tickets.into_iter().zip(&bs) {
        let got = t.wait().map_err(|e| anyhow!("gate request failed: {e}"))?;
        if got.batch_size != bs.len() {
            bail!("batching gate: batch_size {} != {}", got.batch_size, bs.len());
        }
        let got = got.into_dense();
        let (want, _) = dist
            .execute(&ExecRequest::spmm(b))
            .map_err(|e| anyhow!("gate oracle failed: {e}"))?
            .into_dense();
        let identical = got.nrows == want.nrows
            && got.ncols == want.ncols
            && got.data.iter().zip(want.data.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
        if !identical {
            bail!("batching gate: batched result differs bitwise from unbatched (ncols {})", b.ncols);
        }
    }
    Ok(())
}

/// Run one load level: C closed-loop clients, each issuing R synchronous
/// SpMM requests round-robin over the registered graphs, retrying briefly
/// on back-pressure. With `proc` set, every request runs on the proc
/// backend over the server's shared worker pool.
fn run_level(p: &BenchPreset, graphs: &[Csr], clients: usize, proc: bool) -> LevelRow {
    let mut srv = Server::new(serve_config(p, p.workers.max(1)));
    for (i, a) in graphs.iter().enumerate() {
        srv.register_graph(&format!("g{i}"), a.clone());
    }
    let mut rng = Rng::new(7);
    let b_pool: Vec<Dense> =
        graphs.iter().map(|a| Dense::random(a.nrows, p.n_dense, &mut rng)).collect();
    let retries = AtomicU64::new(0);
    let t0 = Instant::now();
    thread::scope(|s| {
        for c in 0..clients {
            let srv = &srv;
            let b_pool = &b_pool;
            let retries = &retries;
            s.spawn(move || {
                for r in 0..p.reqs_per_client {
                    let gi = (c + r) % b_pool.len();
                    loop {
                        let mut req =
                            ServeRequest::spmm(&format!("g{gi}"), b_pool[gi].clone());
                        if proc {
                            req = req.backend(Backend::proc());
                        }
                        match srv.submit_wait(req) {
                            Ok(_) => break,
                            Err(ServeError::Saturated { .. }) => {
                                retries.fetch_add(1, Ordering::Relaxed);
                                thread::sleep(Duration::from_micros(200));
                            }
                            Err(e) => panic!("bench request failed: {e}"),
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = srv.shutdown();
    let lat = latency_stats(&stats.total_secs);
    let requests = clients * p.reqs_per_client;
    LevelRow {
        clients,
        requests,
        throughput_rps: requests as f64 / wall.max(1e-12),
        p50_ms: lat.p50 * 1e3,
        p99_ms: lat.p99 * 1e3,
        mean_batch: stats.mean_batch(),
        hit_rate: stats.hit_rate(),
        retries: retries.load(Ordering::Relaxed),
        pool_spawns: stats.pool_spawns,
        pool_reuses: stats.pool_reuses,
    }
}

fn json_report(p: &BenchPreset, rows: &[LevelRow]) -> String {
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"preset\": \"{}\",", p.name);
    let _ = writeln!(j, "  \"nranks\": {},", p.nranks);
    let _ = writeln!(j, "  \"graphs\": {},", p.graphs);
    let _ = writeln!(j, "  \"nrows\": {},", p.nrows);
    let _ = writeln!(j, "  \"n_dense\": {},", p.n_dense);
    let _ = writeln!(j, "  \"workers\": {},", p.workers);
    j.push_str("  \"levels\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"clients\": {}, \"requests\": {}, \"throughput_rps\": {:.3}, \
             \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \"mean_batch\": {:.3}, \
             \"hit_rate\": {:.4}, \"retries\": {}, \"pool_spawns\": {}, \
             \"pool_reuses\": {}}}",
            r.clients,
            r.requests,
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            r.mean_batch,
            r.hit_rate,
            r.retries,
            r.pool_spawns,
            r.pool_reuses
        );
        j.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");
    j
}

/// Run the full bench — gate, sweep, table, JSON — returning the printable
/// report. `out` is the JSON path (conventionally
/// `bench_results/serve_bench.json`). With `proc` set, the sweep runs on
/// the proc backend over the server's persistent worker pools, and the
/// run fails unless pool reuse actually engaged — the CI gate against
/// silently regressing back to respawn-per-request.
pub fn run(p: &BenchPreset, out: &Path, proc: bool) -> Result<String> {
    verify_batching(p)?;
    let graphs = bench_graphs(p);
    let mut table = Table::new(&[
        "clients", "req/s", "p50 ms", "p99 ms", "mean batch", "hit rate", "retries", "pool s/r",
    ]);
    let mut rows = Vec::new();
    for &clients in p.client_counts {
        let row = run_level(p, &graphs, clients, proc);
        table.row(vec![
            row.clients.to_string(),
            format!("{:.1}", row.throughput_rps),
            format!("{:.3}", row.p50_ms),
            format!("{:.3}", row.p99_ms),
            format!("{:.2}", row.mean_batch),
            format!("{:.2}", row.hit_rate),
            row.retries.to_string(),
            format!("{}/{}", row.pool_spawns, row.pool_reuses),
        ]);
        rows.push(row);
    }
    if proc {
        let reuses: u64 = rows.iter().map(|r| r.pool_reuses).sum();
        let spawns: u64 = rows.iter().map(|r| r.pool_spawns).sum();
        if reuses == 0 {
            bail!("proc bench: pool reuse never engaged ({spawns} spawns, 0 reuses)");
        }
    }
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create bench output dir {}", dir.display()))?;
    }
    std::fs::write(out, json_report(p, &rows))
        .with_context(|| format!("write {}", out.display()))?;
    let mut report = String::new();
    let _ = writeln!(
        report,
        "serve bench (preset {}, backend {}): batching gate OK (bitwise)",
        p.name,
        if proc { "proc" } else { "thread" }
    );
    report.push_str(&table.render());
    let _ = writeln!(report, "wrote {}", out.display());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert!(preset("ci").is_some());
        assert!(preset("full").is_some());
        assert!(preset("nope").is_none());
        let ci = preset("ci").unwrap();
        assert!(ci.graphs >= 2 && ci.reqs_per_client >= 4);
    }

    #[test]
    fn batching_gate_passes_on_the_ci_preset() {
        verify_batching(&preset("ci").unwrap()).unwrap();
    }

    #[test]
    fn json_report_shape() {
        let p = preset("ci").unwrap();
        let rows = vec![LevelRow {
            clients: 2,
            requests: 16,
            throughput_rps: 123.4,
            p50_ms: 1.5,
            p99_ms: 4.0,
            mean_batch: 1.2,
            hit_rate: 0.9,
            retries: 0,
            pool_spawns: 4,
            pool_reuses: 12,
        }];
        let j = json_report(&p, &rows);
        assert!(j.contains("\"preset\": \"ci\""));
        assert!(j.contains("\"clients\": 2"));
        assert!(j.contains("\"pool_reuses\": 12"));
        assert!(j.trim_end().ends_with('}'));
    }
}
